"""Graceful degradation when ``hypothesis`` isn't installed.

hypothesis is an OPTIONAL test dependency (``pip install -e .[test]``
brings it in). On a bare environment the seed suite used to die at
collection with ModuleNotFoundError; importing ``given``/``settings``/``st``
from this shim instead keeps every non-property test running and turns each
property test into a single skipped item (the importorskip outcome, scoped
to just the tests that actually need hypothesis).
"""

import pytest

try:
    from hypothesis import assume, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare envs only
    HAVE_HYPOTHESIS = False

    def assume(condition):  # noqa: ARG001
        return True

    def given(*_args, **_kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[test])")
            def skipped():
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for ``st``: strategy constructors are evaluated at module
        import (decorator arguments), so every attribute must be callable and
        accept anything."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
