"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real single CPU device; only the dry-run forces 512
placeholder devices (and only inside its own process)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
