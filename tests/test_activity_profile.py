"""Fused switching-activity engine: bit-exact equivalence vs the numpy
oracle on randomized shapes/bus widths (non-block-aligned T/R/C, negative
int16 operands), backend dispatch, the content-keyed profile cache, and the
element-weighted combine fix.

The Pallas kernel runs in interpret=True so everything executes on CPU CI;
the XLA engine is what `backend="pallas"` actually dispatches to on
non-TPU hosts and is tested across the full case matrix.
"""

import numpy as np
import pytest

from repro.core.switching import (
    ActivityProfile,
    clear_profile_cache,
    combine_profiles,
    profile_cache_info,
    profile_gemm,
)
from repro.kernels.activity_profile.ops import (
    ToggleCounts,
    operands_fit_fused,
    profile_gemm_toggles,
)
from repro.kernels.activity_profile.ref import profile_gemm_toggles_ref

RNG = np.random.default_rng(0)


def _rand_gemm(m, k, n, lo=-32767, hi=32768):
    return (
        RNG.integers(lo, hi, size=(m, k)),
        RNG.integers(lo, hi, size=(k, n)),
    )


# randomized shapes incl. non-block-aligned T/R/C and degenerate cases
CASES = [
    # m, k, n, rows, cols, b_h, b_v
    (7, 5, 3, 32, 32, 16, 37),
    (64, 64, 48, 32, 32, 16, 37),
    (100, 37, 29, 16, 8, 8, 20),
    (33, 70, 10, 32, 32, 16, 64),
    (2, 1, 1, 8, 8, 16, 37),
    (17, 16, 16, 16, 16, 32, 32),
    (257, 40, 33, 16, 16, 37, 33),  # b_h > 32: sign-extension toggles
    (1025, 96, 64, 32, 32, 16, 37),  # multiple t-blocks: boundary carry
]


@pytest.mark.parametrize("case", CASES)
def test_xla_engine_matches_oracle_bit_exact(case):
    m, k, n, rows, cols, b_h, b_v = case
    a, w = _rand_gemm(m, k, n)
    ref = profile_gemm_toggles_ref(a, w, rows, cols, b_h, b_v)
    got = profile_gemm_toggles(a, w, rows, cols, b_h, b_v, engine="xla")
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


@pytest.mark.parametrize("case", CASES[:5])
def test_pallas_kernel_matches_oracle_bit_exact(case):
    m, k, n, rows, cols, b_h, b_v = case
    a, w = _rand_gemm(m, k, n)
    ref = profile_gemm_toggles_ref(a, w, rows, cols, b_h, b_v)
    got = profile_gemm_toggles(
        a, w, rows, cols, b_h, b_v, engine="pallas", interpret=True
    )
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


def test_pallas_kernel_small_block_t_carries_across_blocks():
    # force many t-blocks so the VMEM scratch carry is exercised hard
    a, w = _rand_gemm(100, 16, 8)
    ref = profile_gemm_toggles_ref(a, w, 16, 8, 16, 37)
    got = profile_gemm_toggles(
        a, w, 16, 8, 16, 37, engine="pallas", interpret=True, block_t=8
    )
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


def test_fused_37bit_partial_sums_exact_at_extremes():
    """Worst-case magnitudes: +/-32767 operands, R=32 deep — 37-bit sums."""
    m, k, n = 64, 32, 8
    a = np.full((m, k), 32767, dtype=np.int64)
    a[::2] = -32767  # alternate rows: huge sign-flipping partial sums
    w = np.full((k, n), 32767, dtype=np.int64)
    w[:, ::2] = -32767
    ref = profile_gemm_toggles_ref(a, w, 32, 8, 16, 37)
    got = profile_gemm_toggles(a, w, 32, 8, 16, 37, engine="xla")
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


def test_operand_width_contract():
    a = np.full((4, 4), 40000, dtype=np.int64)
    w = np.ones((4, 4), dtype=np.int64)
    assert not operands_fit_fused(a, w)
    with pytest.raises(ValueError, match="int16-range"):
        profile_gemm_toggles(a, w, 4, 4, 16, 37, engine="xla")


def test_toggle_counts_add_and_activities():
    c = ToggleCounts(10, 20, 5, 8) + ToggleCounts(1, 2, 3, 4)
    assert c == ToggleCounts(11, 22, 8, 12)
    a_h, a_v = c.activities(b_h=2, b_v=4)
    assert a_h == 11 / (8 * 2) and a_v == 22 / (12 * 4)
    assert ToggleCounts(0, 0, 0, 0).activities(16, 37) == (0.0, 0.0)


# ---------------------------------------------------------------------------
# backend dispatch in core.switching
# ---------------------------------------------------------------------------


def test_profile_gemm_backends_agree_exact():
    a, w = _rand_gemm(64, 64, 48, lo=-1000, hi=1000)
    pn = profile_gemm(a, w, 32, 32, 16, 37, backend="numpy", use_cache=False)
    pp = profile_gemm(a, w, 32, 32, 16, 37, backend="pallas", use_cache=False)
    assert pp.a_h == pytest.approx(pn.a_h, abs=1e-12)
    assert pp.a_v == pytest.approx(pn.a_v, abs=1e-12)
    assert (pp.h_transitions, pp.v_transitions) == (pn.h_transitions, pn.v_transitions)
    assert pp.input_zero_fraction == pn.input_zero_fraction
    assert pp.input_elements == a.size


def test_profile_gemm_backends_agree_subsampled():
    """Opt-in subsampling draws the identical plan on both backends."""
    a, w = _rand_gemm(300, 80, 70, lo=0, hi=500)
    kw = dict(max_tiles=3, max_stream=64, seed=11, use_cache=False)
    pn = profile_gemm(a, w, 32, 32, 16, 37, backend="numpy", **kw)
    pp = profile_gemm(a, w, 32, 32, 16, 37, backend="pallas", **kw)
    assert pp.a_h == pytest.approx(pn.a_h, abs=1e-12)
    assert pp.a_v == pytest.approx(pn.a_v, abs=1e-12)
    assert (pp.h_transitions, pp.v_transitions) == (pn.h_transitions, pn.v_transitions)


def test_auto_backend_falls_back_for_wide_operands():
    a = RNG.integers(-(2**30), 2**30, size=(16, 8))
    w = RNG.integers(-(2**30), 2**30, size=(8, 4))
    p = profile_gemm(a, w, 8, 8, 16, 37, use_cache=False)  # must not raise
    assert 0.0 <= p.a_v <= 1.0


def test_nonbinding_subsample_limits_are_exact():
    """max_tiles/max_stream that don't bind produce the exact profile."""
    a, w = _rand_gemm(50, 40, 20, lo=0, hi=100)
    exact = profile_gemm(a, w, 32, 32, 16, 37, use_cache=False)
    loose = profile_gemm(
        a, w, 32, 32, 16, 37, max_tiles=100, max_stream=1000, use_cache=False
    )
    assert loose == exact


# ---------------------------------------------------------------------------
# content-keyed profile cache
# ---------------------------------------------------------------------------


def test_profile_cache_hits_on_identical_content():
    clear_profile_cache()
    a, w = _rand_gemm(32, 16, 8, lo=0, hi=100)
    p1 = profile_gemm(a, w, 16, 8, 16, 37)
    # same content in a different dtype/array must hit
    p2 = profile_gemm(a.astype(np.int32), w.copy(), 16, 8, 16, 37)
    info = profile_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1 and info["size"] == 1
    assert p1 is p2
    # exact-mode key ignores the (unused) subsample seed
    p3 = profile_gemm(a, w, 16, 8, 16, 37, seed=123)
    assert p3 is p1
    # different content misses
    a2 = a.copy()
    a2[0, 0] += 1
    profile_gemm(a2, w, 16, 8, 16, 37)
    assert profile_cache_info()["misses"] == 2
    clear_profile_cache()
    info = profile_cache_info()
    assert info["size"] == info["hits"] == info["misses"] == 0
    assert info["store_hits"] == info["evictions"] == 0
    assert info["capacity"] >= 1


def test_profile_cache_distinguishes_geometry_and_backend():
    clear_profile_cache()
    a, w = _rand_gemm(32, 16, 8, lo=0, hi=100)
    profile_gemm(a, w, 16, 8, 16, 37)
    profile_gemm(a, w, 8, 8, 16, 37)
    profile_gemm(a, w, 16, 8, 16, 40)
    assert profile_cache_info()["misses"] == 3
    # an explicit backend request must never be served the other backend's
    # cached result (oracle cross-checks would compare an object with itself)
    pn = profile_gemm(a, w, 16, 8, 16, 37, backend="numpy")
    pp = profile_gemm(a, w, 16, 8, 16, 37, backend="pallas")
    assert profile_cache_info()["misses"] == 4  # numpy missed; pallas hit entry 1
    assert pn is not pp
    clear_profile_cache()


# ---------------------------------------------------------------------------
# combine_profiles weighting fix
# ---------------------------------------------------------------------------


def test_combine_zero_fraction_weighted_by_elements():
    tiny = ActivityProfile(0.1, 0.2, 16, 37, 10, 10, 1.0, input_elements=10)
    huge = ActivityProfile(0.1, 0.2, 16, 37, 10, 10, 0.0, input_elements=990)
    c = combine_profiles([tiny, huge])
    assert c.input_zero_fraction == pytest.approx(0.01)
    assert c.input_elements == 1000


def test_combine_zero_fraction_unweighted_fallback():
    """Hand-built profiles without element counts keep the seed behavior."""
    p1 = ActivityProfile(0.1, 0.2, 16, 37, 10, 10, 1.0)
    p2 = ActivityProfile(0.1, 0.2, 16, 37, 10, 10, 0.0)
    assert combine_profiles([p1, p2]).input_zero_fraction == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Output-stationary dataflow: fused engines vs the tile-walking oracle
# ---------------------------------------------------------------------------

# ragged shapes incl. non-aligned M/K/N, degenerate K, wide buses
OS_CASES = [
    # m, k, n, rows, cols, b_h, b_v
    (7, 5, 3, 32, 32, 16, 16),
    (64, 64, 48, 32, 32, 16, 16),
    (100, 37, 29, 16, 8, 8, 8),
    (33, 70, 10, 32, 32, 16, 64),
    (1, 2, 1, 8, 8, 16, 37),
    (17, 16, 16, 16, 16, 32, 32),
    (257, 40, 33, 16, 16, 37, 33),  # b > 32: sign-extension toggles
    (12, 1025, 16, 8, 8, 16, 12),  # long K stream: multiple t-blocks
]


@pytest.mark.parametrize("case", OS_CASES)
def test_os_xla_engine_matches_oracle_bit_exact(case):
    m, k, n, rows, cols, b_h, b_v = case
    a, w = _rand_gemm(m, k, n)
    ref = profile_gemm_toggles_ref(a, w, rows, cols, b_h, b_v, dataflow="OS")
    got = profile_gemm_toggles(a, w, rows, cols, b_h, b_v, dataflow="OS", engine="xla")
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


@pytest.mark.parametrize("case", OS_CASES[:5])
def test_os_pallas_kernel_matches_oracle_bit_exact(case):
    m, k, n, rows, cols, b_h, b_v = case
    a, w = _rand_gemm(m, k, n)
    ref = profile_gemm_toggles_ref(a, w, rows, cols, b_h, b_v, dataflow="OS")
    got = profile_gemm_toggles(
        a, w, rows, cols, b_h, b_v, dataflow="OS", engine="pallas", interpret=True
    )
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


def test_os_pallas_small_block_t_carries_across_blocks():
    a, w = _rand_gemm(10, 100, 8)  # K = 100 stream, many 8-step blocks
    ref = profile_gemm_toggles_ref(a, w, 8, 8, 16, 16, dataflow="OS")
    got = profile_gemm_toggles(
        a, w, 8, 8, 16, 16, dataflow="OS", engine="pallas", interpret=True, block_t=8
    )
    assert (got.h_toggles, got.v_toggles, got.h_transitions, got.v_transitions) == ref


def test_os_profile_gemm_backends_agree_exact():
    a, w = _rand_gemm(33, 70, 10, lo=-1000, hi=1000)
    pn = profile_gemm(a, w, 16, 8, 16, 16, dataflow="OS", backend="numpy", use_cache=False)
    pp = profile_gemm(a, w, 16, 8, 16, 16, dataflow="OS", backend="pallas", use_cache=False)
    assert pp.a_h == pytest.approx(pn.a_h, abs=1e-12)
    assert pp.a_v == pytest.approx(pn.a_v, abs=1e-12)
    assert (pp.h_transitions, pp.v_transitions) == (pn.h_transitions, pn.v_transitions)


def test_os_auto_backend_falls_back_for_wide_operands():
    a = RNG.integers(-(2**30), 2**30, size=(16, 8))
    w = RNG.integers(-(2**30), 2**30, size=(8, 4))
    with pytest.warns(RuntimeWarning):
        p = profile_gemm(a, w, 8, 8, 16, 16, dataflow="OS", use_cache=False)
    assert 0.0 <= p.a_v <= 1.0


# ---------------------------------------------------------------------------
# WS bit-for-bit regression: counts captured BEFORE the dataflow refactor
# ---------------------------------------------------------------------------

# profile_gemm_toggles(engine="xla") outputs on rng(42) operands, recorded
# from the pre-refactor engine — the dataflow dispatch must not perturb a
# single WS toggle.
WS_GOLDEN = {
    (64, 64, 48, 32, 32, 16, 37): (64626, 3555919, 8064, 193536),
    (33, 70, 10, 16, 8, 16, 37): (35552, 413326, 4480, 22400),
    (100, 37, 29, 16, 8, 8, 20): (58320, 1054295, 14652, 106227),
}


def test_ws_counts_unchanged_by_dataflow_refactor():
    rng = np.random.default_rng(42)
    for (m, k, n, rows, cols, b_h, b_v), want in WS_GOLDEN.items():
        a = rng.integers(-1000, 1000, size=(m, k))
        w = rng.integers(-1000, 1000, size=(k, n))
        t = profile_gemm_toggles(a, w, rows, cols, b_h, b_v, engine="xla")
        assert (t.h_toggles, t.v_toggles, t.h_transitions, t.v_transitions) == want
