"""Substrate: checkpoint atomicity/roundtrip, data determinism/sharding,
coordinator crash-restart resume identity, health + elastic policies."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig, DataIterator, batch_at_step
from repro.launch.train import build
from repro.models import model
from repro.optim import adamw
from repro.runtime.elastic import largest_usable, plan_remesh
from repro.runtime.health import HealthMonitor


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _state():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": {"m": jnp.ones((2, 3)), "step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    mgr.save(3, state, extra={"data_step": 3})
    restored, extra = mgr.restore(3, like=state)
    assert extra == {"data_step": 3}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_tmp_dirs_invisible(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _state())
    (tmp_path / "step_0000000009.tmp").mkdir()  # simulated crashed save
    assert mgr.all_steps() == [1]
    assert mgr.restore_latest(like=_state())[0] == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_in_seed_step():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    a = batch_at_step(cfg, 5)
    b = batch_at_step(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at_step(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_shards_disjoint():
    kw = dict(vocab_size=1000, seq_len=16, global_batch=8, num_hosts=2, seed=0)
    h0 = batch_at_step(DataConfig(host_id=0, **kw), 3)
    h1 = batch_at_step(DataConfig(host_id=1, **kw), 3)
    assert h0["tokens"].shape == (4, 16)  # global/hosts
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_labels_are_next_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
    b = batch_at_step(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_iterator_seek_resume():
    cfg = DataConfig(vocab_size=100, seq_len=4, global_batch=2)
    it = DataIterator(cfg)
    for _ in range(3):
        next(it)
    state = it.state()
    step, batch = next(it)
    it2 = DataIterator.restore(cfg, state)
    step2, batch2 = next(it2)
    assert step == step2
    np.testing.assert_array_equal(batch["tokens"], batch2["tokens"])


# ---------------------------------------------------------------------------
# coordinator: crash-restart resume identity
# ---------------------------------------------------------------------------


def test_crash_restart_resumes_bit_identical(tmp_path):
    """Train 8 steps straight vs train-crash-at-5-restart: identical state."""

    def run(ckpt_dir, fail_at=None, steps=8):
        coord = build(
            "yi_6b", reduced=True, batch=2, seq=16, steps=steps,
            ckpt_dir=str(ckpt_dir),
        )
        try:
            coord.run(steps=steps, fail_at_step=fail_at)
        except RuntimeError:
            pass
        return coord

    c1 = run(tmp_path / "a")  # uninterrupted
    c2 = run(tmp_path / "b", fail_at=5)  # crashes after step 5
    c2b = run(tmp_path / "b")  # restart, resumes from checkpoint

    like = jax.eval_shape(lambda: None) or None
    m1 = CheckpointManager(tmp_path / "a").restore_latest(
        like=_train_state_like(c1)
    )
    m2 = CheckpointManager(tmp_path / "b").restore_latest(
        like=_train_state_like(c2b)
    )
    assert m1[0] == m2[0] == 8
    for a, b in zip(jax.tree.leaves(m1[1]), jax.tree.leaves(m2[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _train_state_like(coord):
    return jax.eval_shape(coord.init_state_fn)


def test_training_loss_improves(tmp_path):
    coord = build("qwen3_8b", reduced=True, batch=2, seq=16, steps=12,
                  ckpt_dir=str(tmp_path / "c"), lr=1e-3)
    coord.run(steps=12)
    losses = [m["loss"] for m in coord.metrics_log]
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# health + elastic
# ---------------------------------------------------------------------------


def test_health_dead_host_detection():
    mon = HealthMonitor(range(4), timeout_s=10)
    for h in range(4):
        mon.heartbeat(h, now=100.0)
    mon.heartbeat(2, now=130.0)
    dead = mon.dead_hosts(now=135.0)
    assert dead == [0, 1, 3]
    assert mon.alive_hosts() == [2]


def test_straggler_needs_patience():
    mon = HealthMonitor(range(4), straggler_factor=1.5, patience=3, ema_alpha=1.0)
    for h in range(4):
        mon.heartbeat(h, 0.0)
    for step in range(3):
        for h in range(4):
            mon.report_step_time(h, 10.0 if h == 1 else 1.0)
        s = mon.stragglers()
    assert s == [1]
    # one fast step resets the streak
    mon.report_step_time(1, 1.0)
    for h in (0, 2, 3):
        mon.report_step_time(h, 1.0)
    assert mon.stragglers() == []


def test_elastic_plan_prefers_power_of_two():
    assert largest_usable(16, 256, 1) == 16
    assert largest_usable(13, 256, 1) == 8  # 13 alive -> use 8
    plan = plan_remesh([0, 1, 2, 3, 5, 6, 7, 9, 10, 11, 12, 13, 14], 256)
    assert plan.num_hosts == 8
    assert len(plan.hosts) == 8
    assert plan.global_batch % plan.num_hosts == 0


def test_elastic_plan_no_survivors_raises():
    with pytest.raises(RuntimeError):
        plan_remesh([], 256)
