"""MoE routing/dispatch invariants (property-tested) + replication groups."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs.registry import get_arch
from repro.models.blocks import _combine_local, _dispatch_local, moe_apply, moe_init


@settings(deadline=None, max_examples=30)
@given(
    t=st.integers(4, 64),
    e=st.integers(2, 8),
    k=st.integers(1, 2),
    seed=st.integers(0, 100),
)
def test_dispatch_capacity_and_routing_invariants(t, e, k, seed):
    rng = np.random.default_rng(seed)
    d = 8
    cap = max((t * k) // e, 1)
    x = jnp.asarray(rng.normal(size=(t, d)), dtype=jnp.float32)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), dtype=jnp.int32)
    buf, dest = _dispatch_local(x, idx, e, k, cap, e)
    buf = np.asarray(buf)
    dest = np.asarray(dest)

    # every slot dest is a valid buffer row or the overflow sentinel
    assert ((0 <= dest) & (dest <= e * cap)).all()
    # no two valid slots share a row (capacity rows are unique)
    valid = dest < e * cap
    assert len(np.unique(dest[valid])) == valid.sum()
    # each dispatched row equals its source token
    xf = np.asarray(x)
    tok_of_slot = np.arange(t * k) // k
    flat = buf.reshape(e * cap, d)
    for slot in np.nonzero(valid)[0][:50]:
        np.testing.assert_allclose(flat[dest[slot]], xf[tok_of_slot[slot]], rtol=1e-6)
    # per-expert occupancy never exceeds capacity
    rows = dest[valid]
    experts_of_rows = rows // cap
    for ee in range(e):
        assert (experts_of_rows == ee).sum() <= cap


@settings(deadline=None, max_examples=20)
@given(t=st.integers(4, 32), e=st.integers(2, 4), seed=st.integers(0, 50))
def test_dispatch_combine_roundtrip_identity(t, e, seed):
    """With capacity >= all tokens and gates == 1, combine(dispatch(x)) == x
    per selected expert (top-1)."""
    rng = np.random.default_rng(seed)
    d = 4
    k = 1
    cap = t  # no drops possible
    x = jnp.asarray(rng.normal(size=(t, d)), dtype=jnp.float32)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)), dtype=jnp.int32)
    buf, dest = _dispatch_local(x, idx, e, k, cap, e)
    gates = jnp.ones((t, k), jnp.float32)
    out = _combine_local(buf, dest, gates, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_expert_replication_shards_equivalent():
    """expert_shards > E must not change the MoE output at all."""
    cfg_base = get_arch("mixtral_8x7b").reduced()  # E=4 after reduction
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, cfg_base.d_model), jnp.float32)
    p, _ = moe_init(key, cfg_base, stack=None)
    outs = []
    for shards in (4, 8, 16):
        cfg = dataclasses.replace(cfg_base, expert_shards=shards)
        out, aux = moe_apply(p, x, cfg)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)


def test_aux_loss_balanced_router_is_one():
    """Switch aux loss: perfectly uniform routing gives E * E * (1/E * 1/E)
    summed = 1.0 (its minimum)."""
    cfg = get_arch("mixtral_8x7b").reduced()
    e = cfg.num_experts
    key = jax.random.PRNGKey(0)
    p, _ = moe_init(key, cfg, stack=None)
    # zero router weights -> uniform probs; top-1 assignment then argmax ties
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    t = 64
    x = jax.random.normal(key, (1, t, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, x, cfg)
    # P_e uniform = 1/E; f_e concentrated on expert 0 (argmax tie-break)
    # aux = E * sum_e f_e P_e = E * (1 * 1/E) = 1
    assert float(aux) == pytest.approx(1.0, abs=1e-3)


def test_moe_gradients_flow_to_all_parts():
    cfg = get_arch("mixtral_8x7b").reduced()
    key = jax.random.PRNGKey(3)
    p, _ = moe_init(key, cfg, stack=None)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)

    def loss(params):
        out, aux = moe_apply(params, x, cfg)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0, f"no grad for {name}"
