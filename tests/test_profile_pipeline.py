"""Batched network-level profiling pipeline: bit-exact equivalence of the
batched engine vs the per-GEMM engine and the numpy counts oracle on ragged
job sets, cache-hit accounting across a batch, geometry-sweep pass reuse,
device sharding, serial fallbacks, and the workload-level profile_network
wrapper. The Pallas task kernel runs under interpret=True for CPU CI."""

import numpy as np
import pytest

from repro.core.pipeline import BatchStats, ProfileJob, run_profile_batch
from repro.core.switching import (
    clear_profile_cache,
    profile_cache_info,
    profile_gemm,
    profile_gemms,
)
from repro.core.workloads import ConvLayer, conv_layer_job, profile_network
from repro.kernels.activity_profile.ref import profile_gemm_toggles_ref

RNG = np.random.default_rng(0)


def _rand_gemm(m, k, n, lo=-32767, hi=32768):
    return (
        RNG.integers(lo, hi, size=(m, k)),
        RNG.integers(lo, hi, size=(k, n)),
    )


def _counts(p):
    """Exact integer toggle totals back out of a profile (lossless: the
    activities are integer ratios held in float64 far below 2^53)."""
    return (
        round(p.a_h * p.h_transitions * p.b_h),
        round(p.a_v * p.v_transitions * p.b_v),
        p.h_transitions,
        p.v_transitions,
    )


# Ragged multi-job batch: mixed M/K/N, non-aligned shapes, several
# geometries and bus widths, negative operands — one pipeline call.
RAGGED = [
    # m, k, n, rows, cols, b_h, b_v
    (7, 5, 3, 16, 8, 16, 37),
    (33, 70, 10, 16, 8, 16, 37),
    (100, 37, 29, 16, 8, 8, 20),
    (64, 64, 48, 32, 32, 16, 37),
    (257, 40, 33, 16, 16, 37, 33),
    (300, 80, 70, 32, 32, 16, 64),
    (50, 24, 16, 8, 8, 8, 23),  # b_v <= 32: lo-plane fast path
]


@pytest.mark.parametrize("engine,interpret", [("xla", False), ("pallas", True)])
def test_batched_ragged_set_bit_exact(engine, interpret):
    jobs = [
        ProfileJob(rows=r, cols=c, b_h=bh, b_v=bv, a=a, w=w, name=f"{m}x{k}x{n}")
        for (m, k, n, r, c, bh, bv) in RAGGED
        for a, w in [_rand_gemm(m, k, n)]
    ]
    profiles, stats = run_profile_batch(
        jobs, use_cache=False, engine=engine, interpret=interpret
    )
    assert stats.jobs == len(jobs) and stats.serial_fallbacks == 0
    for job, p in zip(jobs, profiles):
        ref = profile_gemm_toggles_ref(
            job.a, job.w, job.rows, job.cols, job.b_h, job.b_v
        )
        assert _counts(p) == ref, job.name
        s = profile_gemm(
            job.a, job.w, job.rows, job.cols, job.b_h, job.b_v,
            backend="pallas", use_cache=False,
        )
        assert (p.a_h, p.a_v) == (s.a_h, s.a_v), job.name
        assert p.input_zero_fraction == s.input_zero_fraction
        assert p.input_elements == job.a.size


def test_batched_matches_serial_on_long_streams():
    """Multi-segment streams (m >> t_seg) exercise the seeded-window splits."""
    a, w = _rand_gemm(1025, 96, 64)
    (p,), _ = run_profile_batch(
        [ProfileJob(rows=32, cols=32, b_h=16, b_v=37, a=a, w=w)], use_cache=False
    )
    s = profile_gemm(a, w, 32, 32, 16, 37, backend="pallas", use_cache=False)
    assert _counts(p) == _counts(s)


def test_geometry_sweep_shares_one_pass():
    """One GEMM profiled across several (rows, cols): the h-strip totals and
    the rows-dependent v pass are computed once and shared (cols only
    rescales ceil(N/cols)); profiles stay bit-exact vs per-GEMM calls."""
    a, w = _rand_gemm(50, 40, 20, lo=-500, hi=500)
    jobs = [
        ProfileJob(rows=32, cols=c, b_h=16, b_v=37, a=a, w=w) for c in (32, 16, 8)
    ]
    profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.passes == 1 and stats.pass_reuse == 2
    for c, p in zip((32, 16, 8), profiles):
        s = profile_gemm(a, w, 32, c, 16, 37, backend="pallas", use_cache=False)
        assert _counts(p) == _counts(s)
    # different rows => new v pass required
    jobs.append(ProfileJob(rows=16, cols=32, b_h=16, b_v=37, a=a, w=w))
    _, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.passes == 2 and stats.pass_reuse == 2


def test_shape_aliased_operands_do_not_share_a_pass():
    """Same bytes reshaped to different (M, K)/(K, N) are different streams:
    the pass key must include shapes, not just content digests."""
    buf_a = RNG.integers(-50, 50, size=64)
    buf_w = RNG.integers(-50, 50, size=64)
    jobs = [
        ProfileJob(rows=8, cols=8, b_h=16, b_v=37,
                   a=buf_a.reshape(8, 8), w=buf_w.reshape(8, 8)),
        ProfileJob(rows=8, cols=8, b_h=16, b_v=37,
                   a=buf_a.reshape(4, 16), w=buf_w.reshape(16, 4)),
    ]
    profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.passes == 2 and stats.pass_reuse == 0
    for job, p in zip(jobs, profiles):
        assert _counts(p) == profile_gemm_toggles_ref(
            job.a, job.w, 8, 8, 16, 37
        )


def test_intra_batch_dedup_and_cache_accounting():
    clear_profile_cache()
    a, w = _rand_gemm(32, 16, 8, lo=0, hi=100)
    jobs = [
        ProfileJob(rows=16, cols=8, b_h=16, b_v=37, a=a, w=w),
        # same content, different dtype/copy: must dedup to one device pass
        ProfileJob(rows=16, cols=8, b_h=16, b_v=37, a=a.astype(np.int32), w=w.copy()),
    ]
    profiles, stats = run_profile_batch(jobs)
    assert stats.passes == 1 and stats.pass_reuse == 1 and stats.cache_hits == 0
    assert _counts(profiles[0]) == _counts(profiles[1])
    # second batch: every job is a content-cache hit, nothing runs on device
    profiles2, stats2 = run_profile_batch(jobs)
    assert stats2.cache_hits == 2 and stats2.passes == 0 and stats2.buckets == 0
    assert profiles2[0] == profiles[0]
    # the cache is shared with the serial API (same keys)
    hits_before = profile_cache_info()["hits"]
    profile_gemm(a, w, 16, 8, 16, 37)
    assert profile_cache_info()["hits"] == hits_before + 1
    clear_profile_cache()


def test_serial_fallbacks_and_degenerate_shapes():
    wide_a = RNG.integers(-(2**30), 2**30, size=(16, 8))
    wide_w = RNG.integers(-(2**30), 2**30, size=(8, 4))
    tiny_a, tiny_w = _rand_gemm(1, 4, 4)  # m < 2: zero transitions
    a, w = _rand_gemm(20, 8, 4, lo=0, hi=50)
    jobs = [
        ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=wide_a, w=wide_w),
        ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=tiny_a, w=tiny_w),
        ProfileJob(rows=8, cols=4, b_h=16, b_v=37, a=a, w=w),
    ]
    with pytest.warns(RuntimeWarning):
        profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.serial_fallbacks == 2 and stats.passes == 1
    s_wide = profile_gemm(wide_a, wide_w, 8, 8, 16, 37, backend="numpy",
                             use_cache=False)
    assert profiles[0] == s_wide
    assert profiles[1].h_transitions == 0 and profiles[1].a_v == 0.0
    assert _counts(profiles[2]) == profile_gemm_toggles_ref(a, w, 8, 4, 16, 37)


def test_backend_numpy_runs_serial_oracle():
    a, w = _rand_gemm(12, 6, 5, lo=0, hi=50)
    jobs = [ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w)]
    profiles, stats = run_profile_batch(jobs, backend="numpy", use_cache=False)
    assert stats.serial_fallbacks == 1 and stats.buckets == 0
    assert _counts(profiles[0]) == profile_gemm_toggles_ref(a, w, 8, 8, 16, 37)


def test_device_sharding_bit_exact(monkeypatch):
    """Simulated multi-device host: task-axis shards stay bit-exact."""
    import jax

    real = jax.local_devices()
    monkeypatch.setattr(jax, "local_devices", lambda *a, **k: real * 2)
    a, w = _rand_gemm(300, 80, 70)
    (p,), _ = run_profile_batch(
        [ProfileJob(rows=32, cols=32, b_h=16, b_v=37, a=a, w=w)], use_cache=False
    )
    assert _counts(p) == profile_gemm_toggles_ref(a, w, 32, 32, 16, 37)


def test_lazy_jobs_and_shape_validation():
    a, w = _rand_gemm(10, 6, 4, lo=0, hi=50)
    job = ProfileJob(
        rows=8, cols=8, b_h=16, b_v=37, make=lambda: (a, w), shape=(10, 6, 4)
    )
    (p,), _ = run_profile_batch([job], use_cache=False)
    assert _counts(p) == profile_gemm_toggles_ref(a, w, 8, 8, 16, 37)
    bad = ProfileJob(
        rows=8, cols=8, b_h=16, b_v=37, make=lambda: (a, w), shape=(11, 6, 4)
    )
    with pytest.raises(ValueError, match="declared shape"):
        run_profile_batch([bad], use_cache=False)
    with pytest.raises(ValueError, match="needs shape"):
        ProfileJob(rows=8, cols=8, b_h=16, b_v=37, make=lambda: (a, w)).gemm_shape()


def test_profile_gemms_wrapper_and_order():
    jobs = []
    expect = []
    for m, k, n in [(9, 5, 4), (21, 17, 3), (6, 2, 2)]:
        a, w = _rand_gemm(m, k, n, lo=-200, hi=200)
        jobs.append(ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w))
        expect.append(profile_gemm_toggles_ref(a, w, 8, 8, 16, 37))
    profiles = profile_gemms(jobs, use_cache=False)
    assert [_counts(p) for p in profiles] == expect


def test_profile_network_matches_serial_layers():
    layers = [
        ConvLayer("t1", k=1, h=5, w=5, c=40, m=9, input_density=0.5),
        ConvLayer("t2", k=3, h=3, w=3, c=7, m=17, input_density=0.4),
    ]
    clear_profile_cache()
    batched, stats = profile_network(
        layers, rows=16, cols=8, bits=8, use_cache=False, return_stats=True
    )
    assert isinstance(stats, BatchStats) and stats.jobs == 2
    for i, layer in enumerate(layers):
        job = conv_layer_job(layer, rows=16, cols=8, bits=8, seed=i)
        a, w = job.operands()
        assert _counts(batched[i]) == profile_gemm_toggles_ref(
            a, w, 16, 8, job.b_h, job.b_v
        )
    # subsampling falls back to the serial per-GEMM estimate
    sub, stats_sub = profile_network(
        layers, rows=16, cols=8, bits=8, max_tiles=1, max_stream=8,
        use_cache=False, return_stats=True,
    )
    assert stats_sub.serial_fallbacks == 2
    assert all(0.0 <= p.a_v <= 1.0 for p in sub)


# ---------------------------------------------------------------------------
# Output-stationary jobs: stream buckets, geometry-free pass reuse
# ---------------------------------------------------------------------------

OS_RAGGED = [
    # m, k, n, rows, cols, b_h, b_v
    (7, 5, 3, 16, 8, 16, 16),
    (33, 70, 10, 16, 8, 16, 12),
    (100, 37, 29, 16, 8, 8, 8),
    (257, 40, 33, 16, 16, 37, 33),
    (12, 300, 16, 8, 8, 16, 16),  # long K: multi-segment stream windows
]


@pytest.mark.parametrize("engine,interpret", [("xla", False), ("pallas", True)])
def test_batched_os_ragged_set_bit_exact(engine, interpret):
    jobs = [
        ProfileJob(
            rows=r, cols=c, b_h=bh, b_v=bv, a=a, w=w,
            dataflow="OS", name=f"os{m}x{k}x{n}",
        )
        for (m, k, n, r, c, bh, bv) in OS_RAGGED
        for a, w in [_rand_gemm(m, k, n)]
    ]
    profiles, stats = run_profile_batch(
        jobs, use_cache=False, engine=engine, interpret=interpret
    )
    assert stats.serial_fallbacks == 0 and stats.tasks == 0
    for job, p in zip(jobs, profiles):
        ref = profile_gemm_toggles_ref(
            job.a, job.w, job.rows, job.cols, job.b_h, job.b_v, dataflow="OS"
        )
        assert _counts(p) == ref, job.name
        s = profile_gemm(
            job.a, job.w, job.rows, job.cols, job.b_h, job.b_v,
            dataflow="OS", backend="pallas", use_cache=False,
        )
        assert (p.a_h, p.a_v) == (s.a_h, s.a_v), job.name


def test_mixed_ws_os_batch_bit_exact():
    a, w = _rand_gemm(50, 40, 20, lo=-500, hi=500)
    jobs = [
        ProfileJob(rows=16, cols=8, b_h=16, b_v=37, a=a, w=w, dataflow="WS"),
        ProfileJob(rows=16, cols=8, b_h=16, b_v=16, a=a, w=w, dataflow="OS"),
    ]
    profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.serial_fallbacks == 0
    for job, p in zip(jobs, profiles):
        assert _counts(p) == profile_gemm_toggles_ref(
            a, w, job.rows, job.cols, job.b_h, job.b_v, dataflow=job.dataflow
        ), job.dataflow


def test_os_geometry_sweep_shares_stream_passes():
    """OS stream passes carry no geometry: one A pass + one W pass serve
    every (rows, cols) combination, bit-exact against per-GEMM calls."""
    a, w = _rand_gemm(50, 40, 20, lo=-500, hi=500)
    geoms = [(32, 32), (16, 8), (8, 4)]
    jobs = [
        ProfileJob(rows=r, cols=c, b_h=16, b_v=16, a=a, w=w, dataflow="OS")
        for (r, c) in geoms
    ]
    profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.passes == 2 and stats.pass_reuse == 2 * (len(geoms) - 1)
    for (r, c), p in zip(geoms, profiles):
        assert _counts(p) == profile_gemm_toggles_ref(
            a, w, r, c, 16, 16, dataflow="OS"
        )
    # different bus width => the affected stream re-profiles, the other reuses
    jobs.append(ProfileJob(rows=32, cols=32, b_h=16, b_v=12, a=a, w=w, dataflow="OS"))
    _, stats2 = run_profile_batch(jobs, use_cache=False)
    assert stats2.passes == 3  # A@16 + W@16 + W@12


def test_os_degenerate_and_serial_fallbacks():
    tiny_a, tiny_w = _rand_gemm(4, 1, 4)  # K < 2: zero transitions
    wide_a = RNG.integers(-(2**30), 2**30, size=(6, 8))
    wide_w = RNG.integers(-(2**30), 2**30, size=(8, 4))
    a, w = _rand_gemm(10, 12, 6, lo=0, hi=50)
    jobs = [
        ProfileJob(rows=4, cols=4, b_h=16, b_v=16, a=tiny_a, w=tiny_w, dataflow="OS"),
        ProfileJob(rows=4, cols=4, b_h=16, b_v=16, a=wide_a, w=wide_w, dataflow="OS"),
        ProfileJob(rows=4, cols=4, b_h=16, b_v=16, a=a, w=w, dataflow="OS"),
    ]
    with pytest.warns(RuntimeWarning):
        profiles, stats = run_profile_batch(jobs, use_cache=False)
    assert stats.serial_fallbacks == 2
    assert profiles[0].h_transitions == 0 and profiles[0].a_v == 0.0
    assert _counts(profiles[1]) == profile_gemm_toggles_ref(
        wide_a, wide_w, 4, 4, 16, 16, dataflow="OS"
    )
    assert _counts(profiles[2]) == profile_gemm_toggles_ref(
        a, w, 4, 4, 16, 16, dataflow="OS"
    )


def test_os_cache_roundtrip_and_dataflow_isolation():
    clear_profile_cache()
    a, w = _rand_gemm(16, 12, 8, lo=0, hi=100)
    ws_job = ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w)
    os_job = ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w, dataflow="OS")
    profiles, stats = run_profile_batch([ws_job, os_job])
    assert stats.cache_hits == 0
    # same operands+geometry, different dataflow: distinct cache entries
    profiles2, stats2 = run_profile_batch([ws_job, os_job])
    assert stats2.cache_hits == 2 and stats2.passes == 0
    assert profiles2[0] == profiles[0] and profiles2[1] == profiles[1]
    assert profiles[0].a_v != profiles[1].a_v
    # the cache is shared with the serial API (same v3 keys)
    hits = profile_cache_info()["hits"]
    profile_gemm(a, w, 8, 8, 16, 37, dataflow="OS")
    assert profile_cache_info()["hits"] == hits + 1
    clear_profile_cache()


def test_os_profile_network_matches_serial_layers():
    layers = [
        ConvLayer("t1", k=1, h=5, w=5, c=40, m=9, input_density=0.5),
        ConvLayer("t2", k=3, h=3, w=3, c=7, m=17, input_density=0.4),
    ]
    batched, stats = profile_network(
        layers, rows=16, cols=8, bits=8, dataflow="OS",
        use_cache=False, return_stats=True,
    )
    assert isinstance(stats, BatchStats) and stats.jobs == 2
    for i, layer in enumerate(layers):
        job = conv_layer_job(layer, rows=16, cols=8, bits=8, seed=i, dataflow="OS")
        a, w = job.operands()
        assert job.b_v == 8  # OS default: operand width, not accumulator width
        assert _counts(batched[i]) == profile_gemm_toggles_ref(
            a, w, 16, 8, job.b_h, job.b_v, dataflow="OS"
        )
