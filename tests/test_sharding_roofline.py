"""Sharding rules (fallback semantics), HLO collective parsing, roofline math,
and a subprocess end-to-end dry-run on a small forced-device mesh."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.hlo import collective_stats
from repro.analysis.roofline import model_flops_for, roofline
from repro.configs.registry import SHAPES, get_arch
from repro.models.model import count_params_analytic
from repro.parallel.sharding import (
    DEFAULT_ACT_RULES,
    DEFAULT_PARAM_RULES,
    spec_for_axes,
)


class FakeMesh:
    """Duck-typed mesh: only axis_names + devices.shape are consulted."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np

        self.devices = np.zeros(tuple(sizes.values()))


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_rules_basic_tp_fsdp():
    spec = spec_for_axes(("embed", "mlp"), (4096, 14336), MESH1, DEFAULT_PARAM_RULES)
    assert tuple(spec) == ("data", "model")


def test_kv_heads_fallback_replicated():
    """granite: kv=1 cannot shard over model=16 -> replicated dim."""
    spec = spec_for_axes(
        ("embed", "kv_heads", None), (6144, 1, 128), MESH1, DEFAULT_PARAM_RULES
    )
    assert tuple(spec) == ("data", None, None)


def test_experts_fallback_to_mlp_tp():
    """mixtral: 8 experts % 16 != 0 -> experts dim unsharded, mlp takes TP."""
    spec = spec_for_axes(
        ("experts", "embed", "mlp"), (8, 4096, 14336), MESH1, DEFAULT_PARAM_RULES
    )
    assert tuple(spec) == (None, "data", "model")
    # 128 experts divide -> EP
    spec = spec_for_axes(
        ("experts", "embed", "mlp"), (128, 5120, 8192), MESH1, DEFAULT_PARAM_RULES
    )
    assert tuple(spec) == ("model", "data", None)


def test_no_mesh_axis_used_twice():
    spec = spec_for_axes(
        ("heads", "mlp", "vocab"), (32, 14336, 32000), MESH1, DEFAULT_PARAM_RULES
    )
    used = [s for s in spec if s is not None]
    flat = []
    for u in used:
        flat.extend(u if isinstance(u, tuple) else (u,))
    assert len(flat) == len(set(flat))


from _hyp import given, settings, st  # optional-hypothesis shim

_AXIS_NAMES = [
    "batch", "seq", "embed", "heads", "kv_heads", "mlp", "experts",
    "expert_cap", "vocab", "cache_seq", "inner", None,
]


@settings(deadline=None, max_examples=200)
@given(
    axes=st.lists(st.sampled_from(_AXIS_NAMES), min_size=1, max_size=5),
    dims=st.lists(st.integers(1, 4096), min_size=5, max_size=5),
    multi_pod=st.booleans(),
    rules_kind=st.booleans(),
)
def test_spec_invariants_hold_for_any_axes(axes, dims, multi_pod, rules_kind):
    """Allocator invariants for ANY logical-axes tuple: (a) every assigned
    mesh-axis group divides its dim, (b) no mesh axis is used twice, (c) the
    spec has one entry per dim."""
    mesh = MESH2 if multi_pod else MESH1
    rules = DEFAULT_PARAM_RULES if rules_kind else DEFAULT_ACT_RULES
    shape = tuple(dims[: len(axes)])
    spec = spec_for_axes(axes, shape, mesh, rules)
    assert len(tuple(spec)) == len(axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = []
    for entry, dim in zip(tuple(spec), shape):
        if entry is None:
            continue
        group = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for g in group:
            prod *= sizes[g]
            used.append(g)
        assert dim % prod == 0, f"{entry} does not divide {dim}"
    assert len(used) == len(set(used)), f"axis reused in {tuple(spec)}"


def test_batch_2d_and_fallbacks():
    # full 2D when divisible by 256
    spec = spec_for_axes(("batch", "seq"), (256, 4096), MESH1, DEFAULT_ACT_RULES)
    assert tuple(spec)[0] == ("data", "model")
    # multi-pod 256 % 512 != 0 -> (pod, data)
    spec = spec_for_axes(("batch", "seq"), (256, 4096), MESH2, DEFAULT_ACT_RULES)
    assert tuple(spec)[0] == ("pod", "data")
    # batch=1: unsharded; cache_seq then takes data
    spec = spec_for_axes(
        ("batch", "kv_heads", "cache_seq", None),
        (1, 8, 524288, 128),
        MESH1,
        DEFAULT_ACT_RULES,
    )
    assert tuple(spec) == (None, None, "data", None)
    # batch=128 takes DP axes; cache_seq falls to model
    spec = spec_for_axes(
        ("batch", "kv_heads", "cache_seq", None),
        (128, 8, 32768, 128),
        MESH1,
        DEFAULT_ACT_RULES,
    )
    assert tuple(spec)[0] == ("data", "model") or tuple(spec)[0] == "data"


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = textwrap.dedent(
    """
    %ag = bf16[16,4096]{1,0} all-gather(%p0), replica_groups={{0,1}}
    %ar.1 = f32[256,128]{1,0} all-reduce(%x), to_apply=%add
    %t = (f32[8,8]{1,0}, bf16[4,4]{1,0}) all-to-all(%a, %b)
    %rs = f32[128]{0} reduce-scatter(%y), dimensions={0}
    %cp = bf16[2,2]{1,0} collective-permute-start(%z)
    %not_a_collective = f32[10]{0} add(%u, %v)
    """
)


def test_collective_stats_parses_ops_and_bytes():
    cs = collective_stats(HLO_SAMPLE)
    assert cs.count_by_op == {
        "all-gather": 1,
        "all-reduce": 1,
        "all-to-all": 1,
        "reduce-scatter": 1,
        "collective-permute": 1,
    }
    assert cs.bytes_by_op["all-gather"] == 16 * 4096 * 2
    assert cs.bytes_by_op["all-reduce"] == 256 * 128 * 4
    assert cs.bytes_by_op["all-to-all"] == 8 * 8 * 4 + 4 * 4 * 2
    assert cs.bytes_by_op["reduce-scatter"] == 128 * 4
    assert cs.total_count == 5


# ---------------------------------------------------------------------------
# roofline math
# ---------------------------------------------------------------------------


def test_roofline_terms_and_dominance():
    r = roofline(
        flops_per_device=197e12,  # exactly 1s of compute
        bytes_per_device=819e9 * 2,  # 2s of memory
        coll_bytes_per_device=50e9 * 0.5,  # 0.5s of collectives
        chips=256,
        model_flops=197e12 * 256 * 0.5,  # half the compute is "useful"
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.25)  # 0.5s useful / 2s bound


def test_model_flops_train_vs_decode():
    cfg = get_arch("yi_6b")
    n = count_params_analytic(cfg, active_only=True, exclude_embed=True)
    train = model_flops_for(cfg, SHAPES["train_4k"])
    assert train == pytest.approx(6.0 * n * 256 * 4096)
    dec = model_flops_for(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2.0 * n * 128)


# ---------------------------------------------------------------------------
# end-to-end mini dry-run in a subprocess (8 forced host devices)
# ---------------------------------------------------------------------------

MINI = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, jax
    from repro.configs.registry import get_arch, ShapeSpec
    from repro.launch.mesh import make_mesh
    from repro.launch import specs as specs_lib
    from repro.launch.steps import step_for_shape
    from repro.optim import adamw
    from repro.parallel import sharding as sh
    from repro.analysis.hlo import collective_stats

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_arch("yi_6b").reduced().with_dtypes("bfloat16", "bfloat16")
    shape = ShapeSpec("t", "train", 64, 8)
    in_specs, in_axes = specs_lib.input_specs(cfg, shape)
    step, donate = step_for_shape(cfg, shape, adamw.AdamWConfig())
    args = (in_specs["state"], in_specs["batch"])
    aaxes = (in_axes["state"], in_axes["batch"])
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    in_sh = jax.tree.map(
        lambda ax, s: sh.sharding_for(ax, s.shape, mesh, sh.DEFAULT_PARAM_RULES),
        aaxes, args, is_leaf=is_leaf)
    with sh.activation_sharding(mesh):
        compiled = jax.jit(step, in_shardings=in_sh,
                           donate_argnums=donate).lower(*args).compile()
    cs = collective_stats(compiled.as_text())
    print(json.dumps({
        "ok": True,
        "temp": compiled.memory_analysis().temp_size_in_bytes,
        "colls": cs.total_count,
    }))
    """
)


@pytest.mark.slow
def test_subprocess_mini_dryrun():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", MINI],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["colls"] > 0
