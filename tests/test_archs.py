"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU, asserting output shapes and finiteness (no NaNs); plus
full-config analytic parameter counts against the published model sizes."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, all_cells, applicable, get_arch
from repro.models import model

# published sizes (total params, billions) with tolerance bands
EXPECTED_B = {
    "musicgen_medium": (1.38, 0.3),  # 1.5B-class (4 codebook heads)
    "jamba_v01_52b": (52, 3),
    "qwen2_vl_7b": (7.6, 0.8),
    "xlstm_1p3b": (2.0, 0.7),  # unverified config; block-internal projections
    "granite_20b": (20, 1.5),
    "yi_6b": (6, 0.5),
    "qwen15_4b": (4, 0.4),
    "qwen3_8b": (8.2, 0.6),
    "llama4_maverick_400b": (400, 15),
    "mixtral_8x7b": (46.7, 2),
}

ACTIVE_B = {  # active (FLOP-bearing) params for the MoE archs
    "llama4_maverick_400b": (17, 3),
    "mixtral_8x7b": (12.9, 1.5),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = get_arch(arch)
    n = model.count_params_analytic(cfg) / 1e9
    want, tol = EXPECTED_B[arch]
    assert abs(n - want) <= tol, f"{arch}: {n:.2f}B vs {want}B"
    if arch in ACTIVE_B:
        na = model.count_params_analytic(cfg, active_only=True) / 1e9
        want_a, tol_a = ACTIVE_B[arch]
        assert abs(na - want_a) <= tol_a


def _tokens(cfg, key, b, s):
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, axes = model.init_params(cfg, key)
    # axes tree mirrors params tree
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda _: 0, axes, is_leaf=lambda x: isinstance(x, tuple))
    )
    b, s = 2, 32
    toks = _tokens(cfg, key, b, s)
    logits, aux = model.forward(cfg, params, toks)
    want_shape = (
        (b, s, cfg.num_codebooks, cfg.vocab_size)
        if cfg.num_codebooks > 1
        else (b, s, cfg.vocab_size)
    )
    assert logits.shape == want_shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    batch = {"tokens": toks, "labels": toks}
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(1)
    params, _ = model.init_params(cfg, key)
    b = 2
    cache, caxes = model.init_cache(cfg, b, 16)
    tok = _tokens(cfg, key, b, 1)
    logits, cache2 = model.decode_step(cfg, params, cache, tok, jnp.int32(0))
    want = (b, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks > 1 else (b, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
    for a, bb in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == bb.shape and a.dtype == bb.dtype


def test_cell_matrix_counts():
    """33 runnable cells: 10 archs x 4 shapes - 7 long_500k skips."""
    cells = all_cells()
    assert len(cells) == 33
    skipped = [
        a for a in ARCH_IDS if not applicable(get_arch(a), SHAPES["long_500k"])
    ]
    assert len(skipped) == 7
    for a in ("jamba_v01_52b", "xlstm_1p3b", "mixtral_8x7b"):
        assert (a, "long_500k") in cells


def test_mixtral_window_bounds_cache():
    cfg = get_arch("mixtral_8x7b")
    assert model.cache_len_for(cfg, 524288) == 4096
    cfg_full = get_arch("yi_6b")
    assert model.cache_len_for(cfg_full, 32768) == 32768
