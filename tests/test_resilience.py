"""Failure taxonomy, retry/degradation ladder, fault-injection harness, and
the pipeline's recovery paths: every injector class (backend, hang,
device_loss, bitflip) drives its recovery end-to-end, recovered profiles
stay bit-exact vs the numpy oracle, and ``BatchStats.failure_report``
accounts for every injected fault with a typed cause + action."""

import concurrent.futures

import numpy as np
import pytest

from repro.core.pipeline import ProfileJob, run_profile_batch
from repro.core.switching import clear_profile_cache, profile_cache_info
from repro.kernels.activity_profile.ref import profile_gemm_toggles_ref
from repro.runtime import faults
from repro.runtime.resilience import (
    BackendCompileError,
    CacheCorruptionError,
    ContractViolationError,
    DeviceDispatchError,
    DeviceLossError,
    FailureReport,
    ProfileError,
    ProfileTimeoutError,
    RetryPolicy,
    call_with_retry,
    classify_exception,
    degradation_ladder,
)

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _pin_faults():
    """Exact-report tests must see ONLY their own injected faults: shield
    them from env-armed chaos injection (the chaos CI job sets
    $REPRO_FAULTS suite-wide)."""
    with faults.injected([]):
        yield


def _rand_gemm(m, k, n, lo=-500, hi=500):
    return (
        RNG.integers(lo, hi, size=(m, k)),
        RNG.integers(lo, hi, size=(k, n)),
    )


def _counts(p):
    return (
        round(p.a_h * p.h_transitions * p.b_h),
        round(p.a_v * p.v_transitions * p.b_v),
        p.h_transitions,
        p.v_transitions,
    )


def _jobs(n=3, dataflow="WS"):
    shapes = [(33, 20, 10), (16, 12, 8), (48, 24, 16)]
    return [
        ProfileJob(
            rows=8, cols=8, b_h=16, b_v=37, a=a, w=w,
            name=f"j{i}", dataflow=dataflow,
        )
        for i, (m, k, n_) in enumerate(shapes[:n])
        for a, w in [_rand_gemm(m, k, n_)]
    ]


def _assert_bit_exact(jobs, profiles):
    for job, p in zip(jobs, profiles):
        ref = profile_gemm_toggles_ref(
            job.a, job.w, job.rows, job.cols, job.b_h, job.b_v,
            dataflow=job.dataflow,
        )
        assert _counts(p) == ref, job.name


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------


def test_classify_exception_taxonomy():
    assert isinstance(classify_exception(TimeoutError("t")), ProfileTimeoutError)
    assert isinstance(
        classify_exception(concurrent.futures.TimeoutError()), ProfileTimeoutError
    )
    assert isinstance(classify_exception(ValueError("v")), ContractViolationError)
    assert isinstance(classify_exception(ImportError("m")), BackendCompileError)
    assert isinstance(
        classify_exception(RuntimeError("pallas lowering failed")),
        BackendCompileError,
    )
    assert isinstance(
        classify_exception(RuntimeError("transfer aborted")), DeviceDispatchError
    )
    # idempotent: typed errors pass through, annotating job/stage
    err = DeviceLossError("gone")
    assert classify_exception(err, job="j1", stage="dispatch") is err
    assert err.job == "j1" and err.stage == "dispatch"
    assert err.kind == "device-loss"
    assert isinstance(err, DeviceDispatchError)  # loss subclasses dispatch
    # pre-taxonomy ValueError handlers keep catching contract violations
    assert isinstance(ContractViolationError("bad"), ValueError)
    assert "device-loss" in err.describe()


def test_degradation_ladder_rungs():
    assert degradation_ladder() == ("pallas", "xla", "numpy")
    assert degradation_ladder("auto") == ("pallas", "xla", "numpy")
    assert degradation_ladder("xla") == ("xla", "numpy")
    assert degradation_ladder("pallas")[-1] == "numpy"


def test_failure_report_accounting():
    rep = FailureReport()
    assert not rep and len(rep) == 0
    rep.add(BackendCompileError("x", job="a"), action="degraded:xla")
    rep.add(ProfileTimeoutError("y", job="b"), action="skipped")
    rep.add(BackendCompileError("z", job="b"), action="degraded:numpy")
    assert rep and len(rep) == 3
    assert rep.counts() == {"backend-compile": 2, "timeout": 1}
    assert rep.actions() == {
        "degraded:xla": 1,
        "skipped": 1,
        "degraded:numpy": 1,
    }
    assert [r.action for r in rep.for_job("b")] == ["skipped", "degraded:numpy"]
    assert "3 failures" in rep.summary()
    d = rep.as_dict()
    assert len(d["records"]) == 3 and d["counts"]["backend-compile"] == 2


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------


def test_retry_policy_deterministic_backoff():
    pol = RetryPolicy(base_delay_s=0.1, multiplier=2.0, jitter=0.5, seed=42)
    d0, d1 = pol.delay(0, "site"), pol.delay(1, "site")
    assert pol.delay(0, "site") == d0  # pure function of (seed, key, attempt)
    assert 0.1 <= d0 <= 0.15 and 0.2 <= d1 <= 0.3
    assert pol.delay(0, "other") != d0  # distinct sites decorrelate
    assert pol.delay(10, "site") <= pol.max_delay_s * (1 + pol.jitter)


def test_call_with_retry_recovers_transient_fault():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise DeviceLossError("transient")
        return "ok"

    out, attempts, last = call_with_retry(
        flaky, policy=RetryPolicy(max_attempts=3), key="k", sleep=sleeps.append
    )
    assert out == "ok" and attempts == 3
    assert last is not None and last.kind == "device-loss"
    assert len(sleeps) == 2 and sleeps[1] > sleeps[0]


def test_call_with_retry_exhaustion_raises_typed():
    def dead():
        raise RuntimeError("device transfer aborted")

    with pytest.raises(DeviceDispatchError) as ei:
        call_with_retry(
            dead, policy=RetryPolicy(max_attempts=2), sleep=lambda s: None
        )
    assert ei.value.attempts == 2


def test_call_with_retry_never_retries_contract_violations():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("bad shapes")

    with pytest.raises(ContractViolationError):
        call_with_retry(bad, policy=RetryPolicy(max_attempts=5), sleep=lambda s: None)
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


def test_fault_injector_deterministic_and_scoped():
    spec = [faults.FaultSpec("backend", rate=0.5)]
    fires = []
    for _ in range(2):  # identical schedule on replay
        inj = faults.FaultInjector(spec, seed=9)
        seq = []
        for i in range(20):
            try:
                inj.maybe_fail_backend("site", f"k{i}")
                seq.append(0)
            except BackendCompileError:
                seq.append(1)
        fires.append(seq)
    assert fires[0] == fires[1]
    assert 0 < sum(fires[0]) < 20  # rate=0.5 actually splits

    # match pins a fault to one site; max_fires caps it
    inj = faults.FaultInjector(
        [faults.FaultSpec("device_loss", match="d1", max_fires=1)]
    )
    inj.maybe_lose_device("shard", "d0")  # no match: silent
    with pytest.raises(DeviceLossError):
        inj.maybe_lose_device("shard", "d1")
    inj.maybe_lose_device("shard", "d1")  # capped: silent
    assert inj.fired_kinds() == {"device_loss"}
    assert [f.site for f in inj.fired] == ["shard"]


def test_fault_injector_bitflip_is_single_deterministic_bit():
    inj = faults.FaultInjector([faults.FaultSpec("bitflip")], seed=5)
    raw = b"hello profile store"
    out = inj.maybe_corrupt(raw, "store-read", "k")
    assert out != raw and len(out) == len(raw)
    diff = [i for i, (x, y) in enumerate(zip(raw, out)) if x != y]
    assert len(diff) == 1
    assert bin(raw[diff[0]] ^ out[diff[0]]).count("1") == 1
    inj2 = faults.FaultInjector([faults.FaultSpec("bitflip")], seed=5)
    assert inj2.maybe_corrupt(raw, "store-read", "k") == out


def test_fault_env_activation(monkeypatch):
    faults.clear()
    monkeypatch.setenv("REPRO_FAULTS", "backend=0.25,hang=1,seed=3,hang_s=0.01")
    inj = faults.active()
    assert inj is not None and inj.seed == 3 and inj.hang_s == 0.01
    assert {s.kind for s in inj.specs} == {"backend", "hang"}
    faults.clear()
    monkeypatch.setenv("REPRO_FAULTS", "")
    assert faults.active() is None
    monkeypatch.setenv("REPRO_FAULTS", "warp=1")
    faults.clear()
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.active()
    faults.clear()


# ---------------------------------------------------------------------------
# pipeline recovery paths (XLA rendering: runs on CPU CI)
# ---------------------------------------------------------------------------


def test_ladder_lands_on_numpy_bit_exact():
    """Fused dispatch AND both device rungs fail -> numpy, bit-exact."""
    jobs = _jobs()
    specs = [
        faults.FaultSpec("backend", match="bucket-dispatch"),
        faults.FaultSpec("backend", match="ladder:pallas"),
        faults.FaultSpec("backend", match="ladder:xla"),
    ]
    with faults.injected(specs, seed=1) as inj:
        profiles, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="degrade"
        )
    assert all(p is not None for p in profiles)
    _assert_bit_exact(jobs, profiles)
    assert stats.degraded == len(jobs) and stats.skipped == 0
    rep = stats.failure_report
    assert rep.actions() == {"degraded:numpy": len(jobs)}
    assert set(rep.counts()) == {"backend-compile"}
    assert "backend" in inj.fired_kinds()
    # engine="xla" ladder never visits the pallas rung
    assert not any(f.site == "ladder:pallas" for f in inj.fired)


def test_ladder_first_rung_recovers_without_numpy():
    """Only the fused batched dispatch fails -> first ladder rung lands."""
    jobs = _jobs(2)
    with faults.injected([faults.FaultSpec("backend", match="bucket-dispatch")]):
        profiles, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="degrade"
        )
    _assert_bit_exact(jobs, profiles)
    assert stats.failure_report.actions() == {"degraded:xla": len(jobs)}


def test_transient_fault_retried_within_rung():
    """One injected device loss at the first rung -> retry succeeds there."""
    jobs = _jobs(1)
    specs = [
        faults.FaultSpec("backend", match="bucket-dispatch"),
        faults.FaultSpec("device_loss", match="ladder:xla", max_fires=1),
    ]
    with faults.injected(specs):
        profiles, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="degrade",
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.001),
        )
    _assert_bit_exact(jobs, profiles)
    assert stats.retries == 1
    assert stats.failure_report.actions() == {"degraded:xla": 1}


def test_on_error_skip_keeps_successes():
    jobs = _jobs(3)
    with faults.injected(
        [
            faults.FaultSpec("backend", match="bucket-dispatch"),
            faults.FaultSpec("backend", match="ladder"),
        ]
    ):
        profiles, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="skip"
        )
    # all three share one bucket: the whole bucket failed, all skipped
    assert profiles == [None, None, None]
    assert stats.skipped == 3
    assert stats.failure_report.actions() == {"skipped": 3}
    # mixed outcome: only the serial-path job is poisoned, batch survives
    a1, w1 = _rand_gemm(1, 6, 4)
    degenerate = ProfileJob(  # M=1 stream: serial fallback path
        rows=8, cols=8, b_h=16, b_v=37, a=a1, w=w1, name="deg"
    )
    jobs2 = _jobs(2) + [degenerate]
    with faults.injected([faults.FaultSpec("backend", match="serial")]):
        profiles, stats = run_profile_batch(
            jobs2, use_cache=False, engine="xla", on_error="skip"
        )
    assert profiles[2] is None and stats.skipped == 1
    _assert_bit_exact(jobs2[:2], profiles[:2])
    assert stats.failure_report.for_job("deg")[0].action == "skipped"


def test_on_error_raise_is_typed_and_default():
    import os

    from repro.core.pipeline import DEFAULT_ON_ERROR

    jobs = _jobs(1)
    with faults.injected([faults.FaultSpec("backend", match="bucket-dispatch")]):
        with pytest.raises(BackendCompileError):
            run_profile_batch(
                jobs, use_cache=False, engine="xla", on_error="raise"
            )
    # the default tracks $REPRO_ON_ERROR and falls back to "raise" (the
    # chaos CI job runs this suite with the env knob set to "degrade")
    assert DEFAULT_ON_ERROR == os.environ.get("REPRO_ON_ERROR", "raise")
    with pytest.raises(ContractViolationError, match="unknown on_error"):
        run_profile_batch(jobs, use_cache=False, on_error="panic")


def test_contract_violations_raise_in_every_mode():
    a, w = _rand_gemm(10, 6, 4)
    bad = ProfileJob(
        rows=8, cols=8, b_h=16, b_v=37, make=lambda: (a, w), shape=(11, 6, 4)
    )
    for mode in ("raise", "degrade", "skip"):
        with pytest.raises(ValueError, match="declared shape"):
            run_profile_batch([bad], use_cache=False, on_error=mode)


def test_timeout_evicts_device_and_resubmits(monkeypatch):
    """A hung shard on a 2-device host: evict, resubmit once, bit-exact."""
    import jax

    from repro.runtime.health import HealthMonitor

    real = jax.local_devices()
    monkeypatch.setattr(jax, "local_devices", lambda *a, **k: real * 2)
    # 16 k_tiles x 8 n_tiles = 128 tasks -> 2 shards on 2 devices
    a, w = _rand_gemm(16, 128, 64)
    job = ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w, name="big")
    health = HealthMonitor(range(2))
    with faults.injected(
        [faults.FaultSpec("hang", match="b0s1d1", max_fires=1)], hang_s=2.0
    ) as inj:
        (p,), stats = run_profile_batch(
            [job], use_cache=False, engine="xla", on_error="degrade",
            timeout_s=0.5, health=health,
        )
    assert inj.fired_kinds() == {"hang"}
    assert _counts(p) == profile_gemm_toggles_ref(a, w, 8, 8, 16, 37)
    assert stats.resubmits == 1 and stats.degraded == 0
    assert health.alive_hosts() == [0]  # device 1 was evicted
    rep = stats.failure_report
    assert rep.actions() == {"device-evicted:resubmitted": 1}
    assert rep.counts() == {"timeout": 1}


def test_device_loss_evicts_and_resubmits(monkeypatch):
    import jax

    from repro.runtime.health import HealthMonitor

    real = jax.local_devices()
    monkeypatch.setattr(jax, "local_devices", lambda *a, **k: real * 2)
    a, w = _rand_gemm(16, 128, 64)
    job = ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w)
    health = HealthMonitor(range(2))
    with faults.injected(
        [faults.FaultSpec("device_loss", match="d1", max_fires=1)]
    ):
        (p,), stats = run_profile_batch(
            [job], use_cache=False, engine="xla", on_error="degrade",
            health=health,
        )
    assert _counts(p) == profile_gemm_toggles_ref(a, w, 8, 8, 16, 37)
    assert stats.resubmits == 1
    assert stats.failure_report.counts() == {"device-loss": 1}
    assert health.alive_hosts() == [0]


def test_os_stream_bucket_failure_degrades_bit_exact():
    jobs = _jobs(2, dataflow="OS")
    with faults.injected([faults.FaultSpec("backend", match="stream-dispatch")]):
        profiles, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="degrade"
        )
    _assert_bit_exact(jobs, profiles)
    assert stats.degraded == len(jobs)
    assert stats.failure_report.actions() == {"degraded:xla": len(jobs)}


def test_recovered_profile_lands_in_cache_under_original_key():
    """Ladder recovery stores under the batched-path key: the next batch
    (no faults) serves the SAME jobs from cache without device work."""
    clear_profile_cache()
    jobs = _jobs(2)
    with faults.injected([faults.FaultSpec("backend", match="bucket-dispatch")]):
        profiles, stats = run_profile_batch(jobs, engine="xla", on_error="degrade")
    assert stats.degraded == 2
    profiles2, stats2 = run_profile_batch(jobs, engine="xla")
    assert stats2.cache_hits == 2 and stats2.degraded == 0
    assert profiles2 == profiles
    assert profile_cache_info()["hits"] >= 2
    clear_profile_cache()


def test_numpy_backend_never_touches_device_paths():
    """backend="numpy" must not trip device/bucket fault sites at all."""
    jobs = _jobs(2)
    with faults.injected(
        [
            faults.FaultSpec("backend", match="bucket"),
            faults.FaultSpec("hang", match="bucket"),
            faults.FaultSpec("device_loss"),
        ]
    ) as inj:
        profiles, stats = run_profile_batch(jobs, backend="numpy", use_cache=False)
    _assert_bit_exact(jobs, profiles)
    assert inj.fired == [] and stats.serial_fallbacks == len(jobs)


def test_failure_report_in_stats_dict():
    jobs = _jobs(1)
    with faults.injected([faults.FaultSpec("backend", match="bucket-dispatch")]):
        _, stats = run_profile_batch(
            jobs, use_cache=False, engine="xla", on_error="degrade"
        )
    d = stats.as_dict()
    assert d["degraded"] == 1
    assert d["failure_report"]["actions"] == {"degraded:xla": 1}
    assert d["failure_report"]["records"][0]["error"] == "backend-compile"
