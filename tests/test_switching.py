"""Switching-activity profiler: toggle counting + WS/OS stream statistics,
the dataflow-generic API, its cache-key regression, and the deprecated WS
aliases."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.switching import (
    _cache_key,
    clear_profile_cache,
    combine_profiles,
    os_operand_streams,
    popcount,
    profile_cache_info,
    profile_gemm,
    profile_tile,
    stream_toggle_rate,
    toggles_between,
    vertical_partial_sums,
)


@settings(deadline=None, max_examples=100)
@given(st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=50))
def test_popcount_matches_python_bit_count(vals):
    got = popcount(np.array(vals, dtype=np.uint64))
    want = [v.bit_count() for v in vals]
    assert got.tolist() == want


@settings(deadline=None, max_examples=60)
@given(
    st.lists(st.integers(-(2**36), 2**36), min_size=2, max_size=40),
    st.integers(2, 64),
)
def test_stream_toggle_rate_matches_naive(vals, bits):
    s = np.array(vals, dtype=np.int64)[:, None]
    got = stream_toggle_rate(s, bits)
    mask = (1 << bits) - 1 if bits < 64 else ~0 & 0xFFFFFFFFFFFFFFFF
    naive = [
        ((int(a) & mask) ^ (int(b) & mask)).bit_count()
        for a, b in zip(vals[:-1], vals[1:])
    ]
    assert got == pytest.approx(sum(naive) / (len(naive) * bits))


def test_constant_stream_has_zero_activity():
    s = np.full((100, 4), 12345, dtype=np.int64)
    assert stream_toggle_rate(s, 16) == 0.0


def test_alternating_all_bits_is_activity_one():
    # 0b0101.. <-> 0b1010.. flips every one of the low 16 bits
    a = 0x5555
    b = 0xAAAA
    s = np.array([a, b] * 10, dtype=np.int64)[:, None]
    assert stream_toggle_rate(s, 16) == pytest.approx(1.0)


def test_vertical_partial_sums_match_cumsum_of_products():
    rng = np.random.default_rng(1)
    a = rng.integers(-100, 100, size=(7, 5))
    w = rng.integers(-100, 100, size=(5, 3))
    v = vertical_partial_sums(a, w)
    assert v.shape == (7, 5, 3)
    # bottom row equals the full dot product
    np.testing.assert_array_equal(v[:, -1, :], a @ w)


def test_relu_sparsity_lowers_horizontal_activity():
    """The paper: layers with sparser (more zero) inputs toggle less."""
    rng = np.random.default_rng(0)
    w = rng.integers(-500, 500, size=(32, 32))

    def act_for_density(density):
        mask = rng.random((256, 32)) < density
        a = np.where(mask, np.abs(rng.integers(0, 2**15, size=(256, 32))), 0)
        ah, _, _, _ = profile_tile(a, w, b_h=16, b_v=37)
        return ah

    dense = act_for_density(0.9)
    sparse = act_for_density(0.2)
    assert sparse < dense


def test_signed_sums_toggle_more_than_unsigned_inputs():
    """The paper: partial sums oscillate around zero (sign-extension flips)
    => a_v > a_h for REALISTIC inputs (post-ReLU: zeros + folded-Gaussian
    magnitudes, as ImageNet activations are) and zero-mean weights. Dense
    uniform-random inputs would NOT show this — their bits are already coin
    flips; the asymmetry comes from the input distribution, exactly as the
    paper argues."""
    from repro.core.quant import quantize_symmetric
    from repro.core.workloads import synth_activations, synth_weights

    a_f = synth_activations(512, 32, density=0.5, seed=2)
    w_f = synth_weights(32, 32, seed=3)
    a = quantize_symmetric(a_f, 16).values
    w = quantize_symmetric(w_f, 16).values
    ah, av, _, _ = profile_tile(a, w, b_h=16, b_v=37)
    assert av > ah


def test_profile_gemm_full_vs_subsampled_close():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1000, size=(64, 64))
    w = rng.integers(-1000, 1000, size=(64, 48))
    full = profile_gemm(a, w, 32, 32, 16, 37, max_tiles=None, max_stream=None)
    sub = profile_gemm(a, w, 32, 32, 16, 37, max_tiles=2, max_stream=32)
    assert abs(full.a_v - sub.a_v) < 0.1
    assert abs(full.a_h - sub.a_h) < 0.1


def test_combine_profiles_weighted_by_transitions():
    rng = np.random.default_rng(4)
    a = rng.integers(0, 100, size=(32, 32))
    w = rng.integers(-100, 100, size=(32, 32))
    p1 = profile_gemm(a, w, 16, 16, 16, 37, max_tiles=None, max_stream=None)
    combined = combine_profiles([p1, p1])
    assert combined.a_h == pytest.approx(p1.a_h)
    assert combined.a_v == pytest.approx(p1.a_v)
    assert combined.h_transitions == 2 * p1.h_transitions


# ---------------------------------------------------------------------------
# Output-stationary dataflow
# ---------------------------------------------------------------------------


def test_os_operand_streams_orientation():
    a = np.arange(6).reshape(2, 3)  # (Mt, K)
    w = np.arange(12).reshape(3, 4)  # (K, Nt)
    h, v = os_operand_streams(a, w)
    # horizontal: A rows stream over K -> (K, Mt); vertical: W columns -> (K, Nt)
    np.testing.assert_array_equal(h, a.T)
    np.testing.assert_array_equal(v, w)


def test_profile_tile_os_matches_stream_rates():
    rng = np.random.default_rng(5)
    a = rng.integers(0, 500, size=(8, 40))  # (Mt, K)
    w = rng.integers(-500, 500, size=(40, 6))  # (K, Nt)
    ah, av, ht, vt = profile_tile(a, w, b_h=16, b_v=16, dataflow="OS")
    assert ah == pytest.approx(stream_toggle_rate(a.T, 16))
    assert av == pytest.approx(stream_toggle_rate(w, 16))
    assert ht == 39 * 8 and vt == 39 * 6


def test_profile_gemm_os_matches_per_tile_oracle():
    """Full-GEMM OS numpy path vs the tile-walking reference (different
    accounting: per-lane totals scaled by tile counts vs per-tile loops)."""
    from repro.kernels.activity_profile.ref import profile_gemm_toggles_ref

    rng = np.random.default_rng(6)
    a = rng.integers(-900, 900, size=(33, 21))
    w = rng.integers(-900, 900, size=(21, 13))
    p = profile_gemm(a, w, 8, 4, 16, 16, dataflow="OS", backend="numpy", use_cache=False)
    ref = profile_gemm_toggles_ref(a, w, 8, 4, 16, 16, dataflow="OS")
    got = (
        round(p.a_h * p.h_transitions * p.b_h),
        round(p.a_v * p.v_transitions * p.b_v),
        p.h_transitions,
        p.v_transitions,
    )
    assert got == ref


def test_os_activities_are_geometry_invariant():
    rng = np.random.default_rng(7)
    a = rng.integers(0, 300, size=(24, 40))
    w = rng.integers(-300, 300, size=(40, 20))
    ps = [
        profile_gemm(a, w, r, c, 8, 8, dataflow="OS", use_cache=False)
        for (r, c) in [(8, 8), (16, 4), (32, 32)]
    ]
    for p in ps[1:]:
        assert p.a_h == pytest.approx(ps[0].a_h, abs=1e-15)
        assert p.a_v == pytest.approx(ps[0].a_v, abs=1e-15)


def test_os_rejects_subsampling_and_unknown_dataflow():
    a = np.zeros((4, 4), np.int64)
    w = np.zeros((4, 4), np.int64)
    with pytest.raises(ValueError, match="exact-only"):
        profile_gemm(a, w, 4, 4, 8, 8, max_tiles=1, dataflow="OS")
    with pytest.raises(ValueError, match="unknown dataflow"):
        profile_gemm(a, w, 4, 4, 8, 8, dataflow="IS")
    with pytest.raises(ValueError, match="unknown dataflow"):
        profile_tile(a, w, 8, 8, dataflow="XX")


# ---------------------------------------------------------------------------
# Cache-key regression + deprecated aliases
# ---------------------------------------------------------------------------


def test_cache_key_encodes_dataflow():
    """Latent-collision regression: WS and OS profiles of identical operands
    and geometry must never alias in the content cache."""
    rng = np.random.default_rng(8)
    a = rng.integers(0, 100, size=(16, 16))
    w = rng.integers(-100, 100, size=(16, 16))
    k_ws = _cache_key(a, w, 8, 8, 16, 16, ("pallas", "WS", "exact"))
    k_os = _cache_key(a, w, 8, 8, 16, 16, ("pallas", "OS", "exact"))
    assert k_ws != k_os
    # end to end: both dataflows cached under the same operands+geometry,
    # each served its own profile
    clear_profile_cache()
    p_ws = profile_gemm(a, w, 8, 8, 16, 16, dataflow="WS")
    p_os = profile_gemm(a, w, 8, 8, 16, 16, dataflow="OS")
    assert profile_cache_info()["misses"] == 2
    assert profile_gemm(a, w, 8, 8, 16, 16, dataflow="WS") is p_ws
    assert profile_gemm(a, w, 8, 8, 16, 16, dataflow="OS") is p_os
    assert profile_cache_info()["hits"] == 2
    assert p_ws.a_v != p_os.a_v
    clear_profile_cache()


def test_deprecated_ws_aliases_warn_and_forward():
    from repro.core.switching import profile_ws_gemm, profile_ws_gemms, profile_ws_tile
    from repro.core.pipeline import ProfileJob

    rng = np.random.default_rng(9)
    a = rng.integers(0, 100, size=(12, 8))
    w = rng.integers(-100, 100, size=(8, 4))
    with pytest.warns(DeprecationWarning, match="profile_ws_gemm is deprecated"):
        old = profile_ws_gemm(a, w, 8, 4, 16, 37, use_cache=False)
    assert old == profile_gemm(a, w, 8, 4, 16, 37, use_cache=False)
    with pytest.warns(DeprecationWarning, match="profile_ws_tile is deprecated"):
        old_tile = profile_ws_tile(a, w, 16, 37)
    assert old_tile == profile_tile(a, w, 16, 37)
    with pytest.warns(DeprecationWarning, match="profile_ws_gemms is deprecated"):
        (old_batch,) = profile_ws_gemms(
            [ProfileJob(rows=8, cols=4, b_h=16, b_v=37, a=a, w=w)], use_cache=False
        )
    assert (old_batch.a_h, old_batch.a_v) == (old.a_h, old.a_v)


# ---------------------------------------------------------------------------
# Per-bit-lane toggle totals (lane_detail=True)
# ---------------------------------------------------------------------------


def _rand_gemm(seed=0, m=23, k=21, n=13):
    rng = np.random.default_rng(seed)
    a = rng.integers(-60, 200, (m, k)).astype(np.int64)
    a[a < 0] = 0
    w = rng.integers(-70, 70, (k, n)).astype(np.int64)
    return a, w


@pytest.mark.parametrize("dataflow,b_v", [("WS", 37), ("OS", 16)])
def test_lane_detail_backends_bit_exact_and_sum_to_aggregate(dataflow, b_v):
    """Numpy lane oracle == fused lane pass, and lane sums reproduce the
    aggregate toggle counts bit-for-bit (the satellite's regression)."""
    a, w = _rand_gemm()
    kw = dict(dataflow=dataflow, lane_detail=True, use_cache=False)
    p_np = profile_gemm(a, w, 8, 4, 16, b_v, backend="numpy", **kw)
    p_fx = profile_gemm(a, w, 8, 4, 16, b_v, backend="pallas", **kw)
    assert p_np.h_lane_toggles == p_fx.h_lane_toggles
    assert p_np.v_lane_toggles == p_fx.v_lane_toggles
    assert len(p_fx.h_lane_toggles) == 16
    assert len(p_fx.v_lane_toggles) == b_v
    # aggregate profile (no lanes) agrees bit-exactly with the lane sums
    agg = profile_gemm(a, w, 8, 4, 16, b_v, dataflow=dataflow, use_cache=False)
    assert sum(p_fx.h_lane_toggles) == round(agg.a_h * agg.h_transitions * 16)
    assert sum(p_fx.v_lane_toggles) == round(agg.a_v * agg.v_transitions * b_v)
    assert p_fx.h_transitions == agg.h_transitions
    assert p_fx.v_transitions == agg.v_transitions
    assert p_fx.a_h == pytest.approx(agg.a_h, abs=1e-15)
    assert p_fx.a_v == pytest.approx(agg.a_v, abs=1e-15)
    # per-lane activity arrays average back to the aggregates
    np.testing.assert_allclose(p_fx.a_h_lanes.mean(), p_fx.a_h)
    np.testing.assert_allclose(p_fx.a_v_lanes.mean(), p_fx.a_v)


def test_lane_detail_sign_extension_lanes():
    """Bus lanes above bit 31 of an operand stream are sign-extension copies:
    they all carry the sign-flip count (WS h bus widened past 32)."""
    a, w = _rand_gemm(seed=3, m=17, k=9, n=5)
    a[::2] -= 90  # force sign flips on the h stream
    p = profile_gemm(a, w, 4, 4, 40, 48, lane_detail=True, use_cache=False,
                     backend="numpy")
    lanes = np.asarray(p.h_lane_toggles)
    assert (lanes[32:] == lanes[32]).all()
    p_fx = profile_gemm(a, w, 4, 4, 40, 48, lane_detail=True, use_cache=False,
                        backend="pallas")
    assert p.h_lane_toggles == p_fx.h_lane_toggles
    assert p.v_lane_toggles == p_fx.v_lane_toggles


def test_lane_detail_rejects_subsampling():
    a, w = _rand_gemm()
    with pytest.raises(ValueError, match="lane_detail requires exact"):
        profile_gemm(a, w, 8, 4, 16, 37, max_tiles=1, lane_detail=True)


def test_lane_detail_cache_key_v4_no_alias():
    """Lane-detailed and aggregate profiles of identical operands never share
    a cache entry (the v4 key bump), and lane profiles do cache."""
    a, w = _rand_gemm(seed=5)
    clear_profile_cache()
    p_agg = profile_gemm(a, w, 8, 4, 16, 37)
    p_lane = profile_gemm(a, w, 8, 4, 16, 37, lane_detail=True)
    info = profile_cache_info()
    assert info["misses"] == 2 and info["hits"] == 0
    assert p_agg.h_lane_toggles is None and p_lane.h_lane_toggles is not None
    assert profile_gemm(a, w, 8, 4, 16, 37, lane_detail=True) == p_lane
    assert profile_cache_info()["hits"] == 1
    # and the raw keys differ
    k_agg = _cache_key(a, w, 8, 4, 16, 37, ("pallas", "WS", "exact"))
    k_lane = _cache_key(a, w, 8, 4, 16, 37, ("pallas", "WS", "exact", "lanes"))
    assert k_agg != k_lane


def test_combine_profiles_sums_lane_counts():
    a, w = _rand_gemm(seed=7)
    a2, w2 = _rand_gemm(seed=8, m=19)
    p1 = profile_gemm(a, w, 8, 4, 16, 37, lane_detail=True, use_cache=False)
    p2 = profile_gemm(a2, w2, 8, 4, 16, 37, lane_detail=True, use_cache=False)
    comb = combine_profiles([p1, p2])
    assert comb.h_lane_toggles == tuple(
        x + y for x, y in zip(p1.h_lane_toggles, p2.h_lane_toggles)
    )
    assert comb.v_lane_toggles == tuple(
        x + y for x, y in zip(p1.v_lane_toggles, p2.v_lane_toggles)
    )
    # mixing lane-detailed and aggregate profiles drops the lanes
    p3 = profile_gemm(a, w, 8, 4, 16, 37, use_cache=False)
    assert combine_profiles([p1, p3]).h_lane_toggles is None


def test_stream_lane_toggles_sum_matches_rate():
    rng = np.random.default_rng(11)
    s = rng.integers(-300, 300, (29, 7))
    from repro.core.switching import stream_lane_toggles

    lanes = stream_lane_toggles(s, 12)
    want = stream_toggle_rate(s, 12) * 12 * (29 - 1) * 7
    assert lanes.sum() == round(want)
