"""Switching-activity profiler: toggle counting + WS stream statistics."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.switching import (
    combine_profiles,
    popcount,
    profile_ws_gemm,
    profile_ws_tile,
    stream_toggle_rate,
    toggles_between,
    vertical_partial_sums,
)


@settings(deadline=None, max_examples=100)
@given(st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=50))
def test_popcount_matches_python_bit_count(vals):
    got = popcount(np.array(vals, dtype=np.uint64))
    want = [v.bit_count() for v in vals]
    assert got.tolist() == want


@settings(deadline=None, max_examples=60)
@given(
    st.lists(st.integers(-(2**36), 2**36), min_size=2, max_size=40),
    st.integers(2, 64),
)
def test_stream_toggle_rate_matches_naive(vals, bits):
    s = np.array(vals, dtype=np.int64)[:, None]
    got = stream_toggle_rate(s, bits)
    mask = (1 << bits) - 1 if bits < 64 else ~0 & 0xFFFFFFFFFFFFFFFF
    naive = [
        ((int(a) & mask) ^ (int(b) & mask)).bit_count()
        for a, b in zip(vals[:-1], vals[1:])
    ]
    assert got == pytest.approx(sum(naive) / (len(naive) * bits))


def test_constant_stream_has_zero_activity():
    s = np.full((100, 4), 12345, dtype=np.int64)
    assert stream_toggle_rate(s, 16) == 0.0


def test_alternating_all_bits_is_activity_one():
    # 0b0101.. <-> 0b1010.. flips every one of the low 16 bits
    a = 0x5555
    b = 0xAAAA
    s = np.array([a, b] * 10, dtype=np.int64)[:, None]
    assert stream_toggle_rate(s, 16) == pytest.approx(1.0)


def test_vertical_partial_sums_match_cumsum_of_products():
    rng = np.random.default_rng(1)
    a = rng.integers(-100, 100, size=(7, 5))
    w = rng.integers(-100, 100, size=(5, 3))
    v = vertical_partial_sums(a, w)
    assert v.shape == (7, 5, 3)
    # bottom row equals the full dot product
    np.testing.assert_array_equal(v[:, -1, :], a @ w)


def test_relu_sparsity_lowers_horizontal_activity():
    """The paper: layers with sparser (more zero) inputs toggle less."""
    rng = np.random.default_rng(0)
    w = rng.integers(-500, 500, size=(32, 32))

    def act_for_density(density):
        mask = rng.random((256, 32)) < density
        a = np.where(mask, np.abs(rng.integers(0, 2**15, size=(256, 32))), 0)
        ah, _, _, _ = profile_ws_tile(a, w, b_h=16, b_v=37)
        return ah

    dense = act_for_density(0.9)
    sparse = act_for_density(0.2)
    assert sparse < dense


def test_signed_sums_toggle_more_than_unsigned_inputs():
    """The paper: partial sums oscillate around zero (sign-extension flips)
    => a_v > a_h for REALISTIC inputs (post-ReLU: zeros + folded-Gaussian
    magnitudes, as ImageNet activations are) and zero-mean weights. Dense
    uniform-random inputs would NOT show this — their bits are already coin
    flips; the asymmetry comes from the input distribution, exactly as the
    paper argues."""
    from repro.core.quant import quantize_symmetric
    from repro.core.workloads import synth_activations, synth_weights

    a_f = synth_activations(512, 32, density=0.5, seed=2)
    w_f = synth_weights(32, 32, seed=3)
    a = quantize_symmetric(a_f, 16).values
    w = quantize_symmetric(w_f, 16).values
    ah, av, _, _ = profile_ws_tile(a, w, b_h=16, b_v=37)
    assert av > ah


def test_profile_ws_gemm_full_vs_subsampled_close():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1000, size=(64, 64))
    w = rng.integers(-1000, 1000, size=(64, 48))
    full = profile_ws_gemm(a, w, 32, 32, 16, 37, max_tiles=None, max_stream=None)
    sub = profile_ws_gemm(a, w, 32, 32, 16, 37, max_tiles=2, max_stream=32)
    assert abs(full.a_v - sub.a_v) < 0.1
    assert abs(full.a_h - sub.a_h) < 0.1


def test_combine_profiles_weighted_by_transitions():
    rng = np.random.default_rng(4)
    a = rng.integers(0, 100, size=(32, 32))
    w = rng.integers(-100, 100, size=(32, 32))
    p1 = profile_ws_gemm(a, w, 16, 16, 16, 37, max_tiles=None, max_stream=None)
    combined = combine_profiles([p1, p1])
    assert combined.a_h == pytest.approx(p1.a_h)
    assert combined.a_v == pytest.approx(p1.a_v)
    assert combined.h_transitions == 2 * p1.h_transitions
