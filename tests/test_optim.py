"""Optimizer: AdamW reference math, clipping, schedules, grad compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.optim import adamw, compress
from repro.optim.schedule import constant, linear_warmup_cosine


def test_adamw_matches_hand_reference():
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                            clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st_ = adamw.init_state(cfg, p)
    new_p, new_st, _ = adamw.apply_updates(cfg, p, st_, g)
    # hand math, step 1: mhat = g, vhat = g^2
    gh = np.array([0.5, 0.25])
    delta = gh / (np.sqrt(gh**2) + 1e-8) + 0.01 * np.array([1.0, -2.0])
    want = np.array([1.0, -2.0]) - 0.1 * delta
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_st["step"]) == 1


def test_clip_norm_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    st_ = adamw.init_state(cfg, p)
    _, _, metrics = adamw.apply_updates(cfg, p, st_, g)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_moment_dtype_bf16_halves_state():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    p = {"w": jnp.zeros((8, 8), jnp.float32)}
    st_ = adamw.init_state(cfg, p)
    assert st_["m"]["w"].dtype == jnp.bfloat16
    assert st_["v"]["w"].dtype == jnp.bfloat16


def test_schedule_warmup_and_decay():
    fn = linear_warmup_cosine(warmup=10, total=110, final_scale=0.1)
    assert float(fn(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(fn(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(110))) == pytest.approx(0.1, abs=1e-6)
    assert float(constant()(jnp.asarray(7))) == 1.0


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=None)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st_ = adamw.init_state(cfg, p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}  # d/dw w^2
        p, st_, _ = adamw.apply_updates(cfg, p, st_, g)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_bf16_compress_is_cast_roundtrip():
    g = {"w": jnp.asarray([1.0 + 1e-4, -2.0])}
    c = compress.compress_bf16(g)
    np.testing.assert_allclose(
        np.asarray(c["w"]), np.asarray(g["w"].astype(jnp.bfloat16), np.float32)
    )


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 1000), steps=st.integers(5, 40))
def test_int8_error_feedback_sum_is_unbiased(seed, steps):
    """Error feedback: the SUM of compressed gradients tracks the sum of raw
    gradients to within one quantization step (the residual bound)."""
    rng = np.random.default_rng(seed)
    grads = [
        {"w": jnp.asarray(rng.normal(size=(16,)), dtype=jnp.float32)}
        for _ in range(steps)
    ]
    residual = compress.init_error_feedback(grads[0])
    total_raw = np.zeros(16)
    total_comp = np.zeros(16)
    max_scale = 0.0
    for g in grads:
        comp, residual = compress.compress_int8_ef(g, residual)
        total_raw += np.asarray(g["w"])
        total_comp += np.asarray(comp["w"])
        max_scale = max(max_scale, float(jnp.max(jnp.abs(g["w"]))) / 127.0)
    # |sum raw - sum compressed| == |final residual| <= one quant step bound
    err = np.abs(total_raw - total_comp)
    np.testing.assert_allclose(err, np.abs(np.asarray(residual["w"])), atol=1e-5)
    assert err.max() <= max_scale * 2 + 1e-6
