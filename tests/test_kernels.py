"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes as required for every kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.switching import stream_toggle_rate
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.toggle_count.ops import (
    stream_activity,
    stream_toggle_count,
    stream_toggle_count_i64,
)
from repro.kernels.toggle_count.ref import stream_toggle_count_ref
from repro.kernels.ws_matmul.ops import ws_matmul
from repro.kernels.ws_matmul.ref import ws_matmul_ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# toggle_count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "shape", [(2, 1), (17, 3), (100, 64), (257, 129), (512, 256), (1000, 7)]
)
def test_toggle_count_shapes(shape):
    s = jnp.asarray(RNG.integers(-(2**31), 2**31, size=shape, dtype=np.int64).astype(np.int32))
    got = stream_toggle_count(s, interpret=True)
    want = int(stream_toggle_count_ref(s))
    assert got == want


@pytest.mark.parametrize("bits", [8, 16, 32, 37, 48, 64])
def test_stream_activity_matches_numpy_oracle(bits):
    vals = RNG.integers(-(2 ** (bits - 1)) + 1, 2 ** (bits - 1) - 1, size=(60, 5))
    got = stream_activity(vals, bits=bits, interpret=True)
    want = stream_toggle_rate(vals, bits=bits)
    assert got == pytest.approx(want, abs=1e-12)


def test_toggle_count_i64_splits_planes_exactly():
    vals = RNG.integers(-(2**62), 2**62, size=(40, 3))
    got = stream_toggle_count_i64(vals, interpret=True)
    want = sum(
        (int(a) ^ int(b)).bit_count() & 0xFFFFFFFFFFFFFFFF
        for col in vals.T
        for a, b in zip(col[:-1].view(np.uint64), col[1:].view(np.uint64))
    )
    assert got == want


def test_toggle_count_1d_and_degenerate():
    s = jnp.asarray(RNG.integers(0, 100, size=(50,), dtype=np.int32))
    got = stream_toggle_count(s, interpret=True)
    want = int(stream_toggle_count_ref(s[:, None]))
    assert got == want
    assert stream_toggle_count(s[:1], interpret=True) == 0


# ---------------------------------------------------------------------------
# ws_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [(128, 128, 128), (1, 1, 1), (200, 300, 170), (127, 129, 255), (384, 256, 512)],
)
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
def test_ws_matmul_int_exact(m, k, n, dtype):
    info = jnp.iinfo(dtype)
    lo = max(info.min, -1000)
    hi = min(info.max, 1000)
    a = jnp.asarray(RNG.integers(lo, hi, size=(m, k)), dtype=dtype)
    w = jnp.asarray(RNG.integers(lo, hi, size=(k, n)), dtype=dtype)
    got = ws_matmul(a, w, interpret=True)
    want = ws_matmul_ref(a, w)
    assert got.dtype == jnp.int32
    assert jnp.all(got == want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(130, 260, 140), (64, 512, 64)])
def test_ws_matmul_float_close(dtype, m, k, n):
    a = jnp.asarray(RNG.normal(size=(m, k)), dtype=dtype)
    w = jnp.asarray(RNG.normal(size=(k, n)), dtype=dtype)
    got = ws_matmul(a, w, interpret=True)
    want = ws_matmul_ref(a, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


def test_ws_matmul_block_shapes():
    a = jnp.asarray(RNG.integers(-50, 50, size=(100, 90)), dtype=jnp.int8)
    w = jnp.asarray(RNG.integers(-50, 50, size=(90, 60)), dtype=jnp.int8)
    want = ws_matmul_ref(a, w)
    for bm, bn, bk in [(32, 32, 32), (64, 128, 32), (128, 64, 64)]:
        got = ws_matmul(a, w, block_m=bm, block_n=bn, block_k=bk, interpret=True)
        assert jnp.all(got == want)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


def _ref(q, k, v, **kw):
    b, h, s, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    kr = jnp.repeat(k, rep, axis=1).reshape(b * h, s, d)
    vr = jnp.repeat(v, rep, axis=1).reshape(b * h, s, d)
    return attention_ref(q.reshape(b * h, s, d), kr, vr, **kw).reshape(b, h, s, d)


@pytest.mark.parametrize(
    "b,h,kv,s,d", [(1, 1, 1, 128, 64), (2, 4, 2, 200, 64), (1, 8, 1, 256, 128)]
)
def test_flash_causal_gqa(b, h, kv, s, d):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_sliding_window(window):
    b, h, kv, s, d = 1, 2, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = _ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    b, h, kv, s, d = 2, 2, 1, 128, 64
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_flash_block_size_invariance():
    b, h, kv, s, d = 1, 2, 2, 512, 64
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    bq = flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), rtol=2e-5, atol=2e-5)
