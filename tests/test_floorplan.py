"""Paper Eq. 1-6: wirelength + power-optimal aspect ratio (property-tested)."""

import math

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

import numpy as np

from repro.core.floorplan import (
    ASPECT_MAX,
    ASPECT_MIN,
    BusActivity,
    SystolicArrayGeometry,
    accumulator_width,
    bus_power,
    bus_power_arr,
    bus_power_ratio_vs_square,
    bus_power_ratio_vs_square_arr,
    golden_section_minimize_arr,
    numeric_optimal_aspect,
    optimal_aspect_power,
    optimal_aspect_power_arr,
    optimal_aspect_wirelength,
    pe_dims_from_aspect,
    wirelength_h,
    wirelength_total,
    wirelength_total_arr,
    wirelength_v,
)

GEOM = SystolicArrayGeometry.paper_32x32()
ACT = BusActivity.paper_resnet50()


def test_paper_accumulator_width_is_37_bits():
    assert accumulator_width(16, 32) == 37
    assert GEOM.b_h == 16 and GEOM.b_v == 37


def test_paper_optimal_aspect_is_3p8():
    """Section IV: 'we selected an aspect ratio of W/H=3.8'."""
    assert optimal_aspect_power(GEOM, ACT) == pytest.approx(3.8, abs=0.05)


def test_wirelength_optimum_is_bv_over_bh():
    """Eq. 5: W/H = B_v/B_h (uniform activity reduces Eq. 6 to Eq. 5)."""
    uniform = BusActivity(a_h=0.3, a_v=0.3)
    assert optimal_aspect_power(GEOM, uniform) == pytest.approx(
        optimal_aspect_wirelength(GEOM)
    )
    assert optimal_aspect_wirelength(GEOM) == pytest.approx(37 / 16)


geoms = st.builds(
    SystolicArrayGeometry,
    rows=st.integers(2, 256),
    cols=st.integers(2, 256),
    b_h=st.integers(1, 64),
    b_v=st.integers(1, 64),
    pe_area_um2=st.floats(10.0, 1e5),
)
acts = st.builds(
    BusActivity,
    a_h=st.floats(0.01, 1.0),
    a_v=st.floats(0.01, 1.0),
)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts)
def test_closed_form_matches_numeric_minimizer(geom, act):
    """Envelope-clamped Eq. 6 equals golden-section search over the envelope
    (an out-of-envelope optimum converges to the clamped boundary)."""
    closed = optimal_aspect_power(geom, act)
    assert ASPECT_MIN <= closed <= ASPECT_MAX
    numeric = numeric_optimal_aspect(geom, act)
    assert numeric == pytest.approx(closed, rel=1e-4)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts, aspect=st.floats(ASPECT_MIN, ASPECT_MAX))
def test_optimal_aspect_never_worse_than_any_other(geom, act, aspect):
    """The clamped optimum beats every other aspect INSIDE the envelope."""
    opt = optimal_aspect_power(geom, act)
    assert bus_power(geom, act, opt) <= bus_power(geom, act, aspect) * (1 + 1e-9)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts)
def test_amgm_ratio_formula(geom, act):
    """P_opt / P_square == 2 sqrt(xy)/(x+y) while Eq. 6 stays in the
    envelope; at the clamped boundary the ratio matches the boundary power."""
    x = geom.b_h * act.a_h
    y = geom.b_v * act.a_v
    opt = optimal_aspect_power(geom, act)
    got = bus_power(geom, act, opt) / bus_power(geom, act, 1.0)
    if ASPECT_MIN < y / x < ASPECT_MAX:
        want = 2 * math.sqrt(x * y) / (x + y)
        assert got == pytest.approx(want, rel=1e-9)
    else:
        assert opt in (ASPECT_MIN, ASPECT_MAX)
    assert bus_power_ratio_vs_square(geom, act) == pytest.approx(got, rel=1e-9)


def test_envelope_clamps_general_branch():
    """Extreme B_v a_v / (B_h a_h) ratios clamp to the practical envelope."""
    g = SystolicArrayGeometry(rows=8, cols=8, b_h=1, b_v=64)
    assert optimal_aspect_power(g, BusActivity(0.01, 1.0)) == ASPECT_MAX
    g2 = SystolicArrayGeometry(rows=8, cols=8, b_h=64, b_v=1)
    assert optimal_aspect_power(g2, BusActivity(1.0, 0.01)) == ASPECT_MIN
    # degenerate branches land on the same envelope
    assert optimal_aspect_power(g, BusActivity(0.0, 0.5)) == ASPECT_MAX
    assert optimal_aspect_power(g, BusActivity(0.5, 0.0)) == ASPECT_MIN


@settings(deadline=None, max_examples=40)
@given(geom=geoms, aspect=st.floats(0.05, 20.0))
def test_wirelength_decomposition_and_area_conservation(geom, aspect):
    w, h = pe_dims_from_aspect(geom, aspect)
    assert w * h == pytest.approx(geom.pe_area_um2, rel=1e-9)
    assert w / h == pytest.approx(aspect, rel=1e-9)
    assert wirelength_total(geom, aspect) == pytest.approx(
        wirelength_h(geom, aspect) + wirelength_v(geom, aspect)
    )
    # Eq. 1/2 exact forms
    assert wirelength_h(geom, aspect) == pytest.approx(
        geom.rows * geom.cols * w * geom.b_h
    )
    assert wirelength_v(geom, aspect) == pytest.approx(
        geom.rows * geom.cols * h * geom.b_v
    )


def test_square_is_optimal_iff_balanced():
    """x == y  =>  the square layout is already optimal (ratio 1)."""
    g = SystolicArrayGeometry(rows=8, cols=8, b_h=20, b_v=10)
    act = BusActivity(a_h=0.2, a_v=0.4)  # x = 4.0, y = 4.0
    assert optimal_aspect_power(g, act) == pytest.approx(1.0)
    assert bus_power_ratio_vs_square(g, act) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Vectorized-kernel vs scalar-wrapper parity (the scalar API is a thin shim
# over the same kernels; stacking inputs into arrays must reproduce each
# scalar result bit-for-bit on the float64 numpy path)
# ---------------------------------------------------------------------------

batch = st.lists(
    st.tuples(
        st.integers(2, 256),  # rows
        st.integers(2, 256),  # cols
        st.integers(1, 64),  # b_h
        st.integers(1, 64),  # b_v
        st.floats(10.0, 1e5),  # pe_area
        st.floats(0.0, 1.0),  # a_h (0 included: degenerate branch)
        st.floats(0.0, 1.0),  # a_v
        st.floats(ASPECT_MIN, ASPECT_MAX),  # aspect
    ),
    min_size=1,
    max_size=16,
)


def _stack(points):
    cols = list(zip(*points))
    return [np.asarray(c) for c in cols]


@settings(deadline=None, max_examples=40)
@given(points=batch)
def test_vectorized_kernels_match_scalar_wrappers_bitwise(points):
    rows, cols, b_h, b_v, area, a_h, a_v, aspect = _stack(points)
    opt_vec = optimal_aspect_power_arr(b_h, b_v, a_h, a_v)
    pow_vec = bus_power_arr(rows, cols, b_h, b_v, area, a_h, a_v, aspect)
    wl_vec = wirelength_total_arr(rows, cols, b_h, b_v, area, aspect)
    ratio_vec = bus_power_ratio_vs_square_arr(b_h, b_v, a_h, a_v)
    for i, (r, c, bh, bv, ar, ah, av, asp) in enumerate(points):
        geom = SystolicArrayGeometry(rows=r, cols=c, b_h=bh, b_v=bv, pe_area_um2=ar)
        act = BusActivity(a_h=ah, a_v=av)
        assert float(opt_vec[i]) == optimal_aspect_power(geom, act)
        assert float(pow_vec[i]) == bus_power(geom, act, asp)
        assert float(wl_vec[i]) == wirelength_total(geom, asp)
        assert float(ratio_vec[i]) == bus_power_ratio_vs_square(geom, act)


def test_batched_golden_section_minimizes_elementwise():
    """Each element converges to its own minimizer (here: min of (x-t)^2)."""
    targets = np.asarray([-2.0, 0.0, 0.5, 3.0])
    got = golden_section_minimize_arr(
        lambda x: (x - targets) ** 2, -5.0, 5.0, iters=80
    )
    assert np.allclose(got, targets, atol=1e-8)


def test_kernels_jit_compatible():
    """The same kernels trace under jax.jit (float32 tolerances)."""
    jax = pytest.importorskip("jax")
    jnp = jax.numpy

    b_h = jnp.asarray([16.0, 8.0])
    b_v = jnp.asarray([37.0, 21.0])
    a_h = jnp.asarray([0.22, 0.0])
    a_v = jnp.asarray([0.36, 0.3])
    got = jax.jit(optimal_aspect_power_arr)(b_h, b_v, a_h, a_v)
    want = [
        optimal_aspect_power(
            SystolicArrayGeometry(4, 4, int(h), int(v)), BusActivity(float(x), float(y))
        )
        for h, v, x, y in zip(b_h, b_v, a_h, a_v)
    ]
    assert np.allclose(np.asarray(got), want, rtol=1e-5)
    p = jax.jit(bus_power_arr)(
        jnp.asarray([32.0]), jnp.asarray([32.0]), b_h[:1], b_v[:1],
        jnp.asarray([1200.0]), a_h[:1], a_v[:1], jnp.asarray([3.8]),
    )
    want_p = bus_power(SystolicArrayGeometry.paper_32x32(), BusActivity(0.22, 0.36), 3.8)
    assert np.allclose(np.asarray(p), want_p, rtol=1e-5)
