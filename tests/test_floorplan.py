"""Paper Eq. 1-6: wirelength + power-optimal aspect ratio (property-tested)."""

import math

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    accumulator_width,
    bus_power,
    bus_power_ratio_vs_square,
    numeric_optimal_aspect,
    optimal_aspect_power,
    optimal_aspect_wirelength,
    pe_dims_from_aspect,
    wirelength_h,
    wirelength_total,
    wirelength_v,
)

GEOM = SystolicArrayGeometry.paper_32x32()
ACT = BusActivity.paper_resnet50()


def test_paper_accumulator_width_is_37_bits():
    assert accumulator_width(16, 32) == 37
    assert GEOM.b_h == 16 and GEOM.b_v == 37


def test_paper_optimal_aspect_is_3p8():
    """Section IV: 'we selected an aspect ratio of W/H=3.8'."""
    assert optimal_aspect_power(GEOM, ACT) == pytest.approx(3.8, abs=0.05)


def test_wirelength_optimum_is_bv_over_bh():
    """Eq. 5: W/H = B_v/B_h (uniform activity reduces Eq. 6 to Eq. 5)."""
    uniform = BusActivity(a_h=0.3, a_v=0.3)
    assert optimal_aspect_power(GEOM, uniform) == pytest.approx(
        optimal_aspect_wirelength(GEOM)
    )
    assert optimal_aspect_wirelength(GEOM) == pytest.approx(37 / 16)


geoms = st.builds(
    SystolicArrayGeometry,
    rows=st.integers(2, 256),
    cols=st.integers(2, 256),
    b_h=st.integers(1, 64),
    b_v=st.integers(1, 64),
    pe_area_um2=st.floats(10.0, 1e5),
)
acts = st.builds(
    BusActivity,
    a_h=st.floats(0.01, 1.0),
    a_v=st.floats(0.01, 1.0),
)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts)
def test_closed_form_matches_numeric_minimizer(geom, act):
    """Eq. 6 equals brute-force golden-section search on the power curve."""
    closed = optimal_aspect_power(geom, act)
    if not (1 / 64 < closed < 64):  # numeric search window
        return
    numeric = numeric_optimal_aspect(geom, act)
    assert numeric == pytest.approx(closed, rel=1e-4)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts, aspect=st.floats(0.05, 20.0))
def test_optimal_aspect_never_worse_than_any_other(geom, act, aspect):
    opt = optimal_aspect_power(geom, act)
    assert bus_power(geom, act, opt) <= bus_power(geom, act, aspect) * (1 + 1e-9)


@settings(deadline=None, max_examples=60)
@given(geom=geoms, act=acts)
def test_amgm_ratio_formula(geom, act):
    """P_opt / P_square == 2 sqrt(xy)/(x+y) with x=B_h a_h, y=B_v a_v."""
    x = geom.b_h * act.a_h
    y = geom.b_v * act.a_v
    want = 2 * math.sqrt(x * y) / (x + y)
    opt = optimal_aspect_power(geom, act)
    got = bus_power(geom, act, opt) / bus_power(geom, act, 1.0)
    assert got == pytest.approx(want, rel=1e-9)
    assert bus_power_ratio_vs_square(geom, act) == pytest.approx(want, rel=1e-9)


@settings(deadline=None, max_examples=40)
@given(geom=geoms, aspect=st.floats(0.05, 20.0))
def test_wirelength_decomposition_and_area_conservation(geom, aspect):
    w, h = pe_dims_from_aspect(geom, aspect)
    assert w * h == pytest.approx(geom.pe_area_um2, rel=1e-9)
    assert w / h == pytest.approx(aspect, rel=1e-9)
    assert wirelength_total(geom, aspect) == pytest.approx(
        wirelength_h(geom, aspect) + wirelength_v(geom, aspect)
    )
    # Eq. 1/2 exact forms
    assert wirelength_h(geom, aspect) == pytest.approx(
        geom.rows * geom.cols * w * geom.b_h
    )
    assert wirelength_v(geom, aspect) == pytest.approx(
        geom.rows * geom.cols * h * geom.b_v
    )


def test_square_is_optimal_iff_balanced():
    """x == y  =>  the square layout is already optimal (ratio 1)."""
    g = SystolicArrayGeometry(rows=8, cols=8, b_h=20, b_v=10)
    act = BusActivity(a_h=0.2, a_v=0.4)  # x = 4.0, y = 4.0
    assert optimal_aspect_power(g, act) == pytest.approx(1.0)
    assert bus_power_ratio_vs_square(g, act) == pytest.approx(1.0)
