"""End-to-end validation against the paper's own claims (Section IV):

  * B_h=16, B_v=37 at 32x32 / int16,
  * power-optimal aspect ratio W/H = 3.8,
  * interconnect power saving 9.1%, total 2.1% (ResNet50 average),
  * simulated switching activities in the paper's measured band with
    a_v > a_h and per-layer a_h ordered by input density,
  * Table I conv->GEMM lowering dimensions.
"""

import numpy as np
import pytest

from repro.core.energy import average_comparison, compare_sym_asym
from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    optimal_aspect_power,
)
from repro.core.quant import dequantize, quantize_symmetric
from repro.core.switching import combine_profiles
from repro.core.workloads import (
    RESNET50_TABLE1,
    conv_to_gemm,
    gemms_for_arch,
    profile_conv_layer,
)

GEOM = SystolicArrayGeometry.paper_32x32()
PAPER_ACT = BusActivity.paper_resnet50()


def test_headline_numbers():
    assert optimal_aspect_power(GEOM, PAPER_ACT) == pytest.approx(3.8, abs=0.05)
    c = compare_sym_asym(GEOM, PAPER_ACT)
    assert c.interconnect_saving == pytest.approx(0.091, abs=0.002)
    assert c.total_saving == pytest.approx(0.021, abs=0.002)


def test_table1_gemm_lowering():
    dims = {g.name: g for g in map(conv_to_gemm, RESNET50_TABLE1)}
    # L1: K=1, H=W=56, C=256, M=64 -> (3136, 256) x (256, 64)
    assert (dims["L1"].m, dims["L1"].k, dims["L1"].n) == (3136, 256, 64)
    # L2: K=3, H=W=28, C=128, M=128 -> (784, 1152) x (1152, 128)
    assert (dims["L2"].m, dims["L2"].k, dims["L2"].n) == (784, 1152, 128)
    # L6: K=3, H=W=14, C=256, M=256 -> (196, 2304, 256)
    assert (dims["L6"].m, dims["L6"].k, dims["L6"].n) == (196, 2304, 256)


@pytest.mark.slow
def test_simulated_activities_in_paper_band():
    """Synthetic-input profiling lands in the paper's regime: a_h in the
    0.15-0.35 band, a_v in 0.3-0.55, and a_v > a_h for EVERY layer."""
    profiles = [
        profile_conv_layer(layer, max_tiles=4, max_stream=128, seed=i)
        for i, layer in enumerate(RESNET50_TABLE1)
    ]
    for p in profiles:
        assert p.a_v > p.a_h
    avg = combine_profiles(profiles)
    assert 0.1 < avg.a_h < 0.4
    assert 0.25 < avg.a_v < 0.6
    # denser-input layers toggle more horizontally (paper's per-layer spread)
    by_density = sorted(zip(RESNET50_TABLE1, profiles), key=lambda t: t[0].input_density)
    assert by_density[0][1].a_h < by_density[-1][1].a_h


@pytest.mark.slow
def test_end_to_end_simulated_savings_positive():
    """Full pipeline on simulated data (no paper constants): per-layer asym
    floorplan still saves interconnect power on every Table I layer."""
    profiles = [
        profile_conv_layer(layer, max_tiles=3, max_stream=96, seed=i)
        for i, layer in enumerate(RESNET50_TABLE1)
    ]
    avg = combine_profiles(profiles).as_bus_activity()
    comps = [
        compare_sym_asym(GEOM, p.as_bus_activity(), design_act=avg)
        for p in profiles
    ]
    for c in comps:
        assert c.interconnect_saving > 0.02
    agg = average_comparison(comps)
    assert 0.04 < agg["interconnect_saving"] < 0.15
    assert 0.005 < agg["total_saving"] < 0.04


def test_quantization_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 64))
    for bits in (8, 16):
        q = quantize_symmetric(x, bits)
        err = np.max(np.abs(dequantize(q) - x))
        assert err <= q.scale * 0.5 + 1e-12
        assert np.max(np.abs(q.values)) <= 2 ** (bits - 1) - 1


def test_llm_gemm_extraction():
    """Beyond-paper: the SA analysis consumes LLM layer GEMMs too."""
    from repro.configs.registry import get_arch

    gemms = gemms_for_arch(get_arch("yi_6b"), seq_len=128, batch=1)
    names = {g.name for g in gemms}
    assert {"q_proj", "k_proj", "o_proj", "ffn_up", "lm_head"} <= names
    q = next(g for g in gemms if g.name == "q_proj")
    assert (q.m, q.k, q.n) == (128, 4096, 4096)
    moe = gemms_for_arch(get_arch("mixtral_8x7b"), seq_len=128, batch=1)
    eu = next(g for g in moe if g.name == "expert_up")
    assert eu.m == 128 * 2  # top-2 active tokens
