"""Calibrated energy model: reproduces the paper's headline numbers."""

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.energy import (
    EnergyModelConfig,
    average_comparison,
    compare_sym_asym,
    power_breakdown,
)
from repro.core.floorplan import BusActivity, SystolicArrayGeometry

GEOM = SystolicArrayGeometry.paper_32x32()
ACT = BusActivity.paper_resnet50()


def test_paper_interconnect_saving_9p1_percent():
    c = compare_sym_asym(GEOM, ACT)
    assert c.interconnect_saving == pytest.approx(0.091, abs=0.002)


def test_paper_total_saving_2p1_percent():
    c = compare_sym_asym(GEOM, ACT)
    assert c.total_saving == pytest.approx(0.021, abs=0.002)


def test_paper_bus_saving_matches_amgm():
    c = compare_sym_asym(GEOM, ACT)
    assert c.bus_saving == pytest.approx(0.187, abs=0.002)


def test_power_breakdown_sums():
    b = power_breakdown(GEOM, ACT, 1.0)
    assert b.total_w == pytest.approx(b.bus_w + b.fixed_interconnect_w + b.compute_w)
    assert b.interconnect_w / b.total_w == pytest.approx(
        EnergyModelConfig().interconnect_share_of_total, rel=1e-6
    )


@settings(deadline=None, max_examples=40)
@given(
    a_h=st.floats(0.02, 1.0),
    a_v=st.floats(0.02, 1.0),
    b_h=st.integers(2, 64),
    b_v=st.integers(2, 64),
)
def test_asymmetric_never_worse(a_h, a_v, b_h, b_v):
    geom = SystolicArrayGeometry(rows=16, cols=16, b_h=b_h, b_v=b_v)
    c = compare_sym_asym(geom, BusActivity(a_h=a_h, a_v=a_v))
    assert c.interconnect_saving >= -1e-9
    assert c.total_saving >= -1e-9


def test_per_layer_design_point_fixed_at_average():
    """Fig. 4 methodology: ONE aspect ratio (from the average profile) is used
    for all layers; per-layer savings vary but stay non-negative when layer
    activities keep a_v*B_v > a_h*B_h (always true here)."""
    layers = [BusActivity(0.15, 0.30), BusActivity(0.25, 0.40), BusActivity(0.30, 0.35)]
    comps = [
        compare_sym_asym(GEOM, la, design_act=ACT, reference_act=la) for la in layers
    ]
    for c in comps:
        assert c.aspect_opt == pytest.approx(3.8, abs=0.05)  # fixed design point
        assert c.interconnect_saving > 0
    avg = average_comparison(comps)
    assert 0 < avg["interconnect_saving"] < 0.2
    assert 0 < avg["total_saving"] < 0.05
