"""Fused fleet J/op objective (``core.objective`` + the lowered partition/
coding tensors of ``layout.coeffs``).

Contracts under test:

  * the lowered (GEMM, layout, point) partition arrays equal the scalar
    ``partition_gemm`` oracle on every cell (seeded + hypothesis, <= 1e-9);
  * partition edge cases — k=1 identity vs uniform, ragged GEMMs smaller
    than one pod, OS drain semantics, zero-MAC degeneracy, K-split trunk
    accounting at k=8;
  * the coding lowering equals the closed-form bus-invert activity, and the
    engine prices BI grids exactly as the segment enumeration at the coded
    activity;
  * the fused ``j_per_mac`` recombines bit-for-bit (<= 1e-9) from its
    independently priced components in host float64;
  * the J/op objective flips the winning layout family on workloads where
    utilization/traffic beat wire power — the paper's scale-in claim;
  * objective sweeps chunk, checkpoint, resume bit-identically, and a
    NaN-poisoned objective chunk trips the J/op guard.
"""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.design_space import DesignSpace
from repro.core.objective import evaluate_fleet_objective, fleet_static_power
from repro.core.optimize import bus_invert_activity
from repro.core.sweep import SweepConfig, SweepInterrupted
from repro.core.workloads import Gemm, design_pod_partition, partition_gemm
from repro.layout import (
    CODING_SCHEMES,
    MultiPodLayout,
    evaluate_layout_space,
    get_layout,
    grid_coding_effective,
    layout_feasible,
    lower_coding_multipliers,
    lower_partition_coeffs,
    pod_layouts,
    segment_bus_power,
)
from repro.layout.coeffs import (
    DATA_CLASS_IDX,
    DATA_IS_H,
    V_CROSS_DATA_IDX,
    V_HOP_DATA_IDX,
    lower_layout_coeffs,
)
from repro.core.floorplan import BusActivity
from repro.runtime import faults


@pytest.fixture(autouse=True)
def _pin_faults():
    """Shield exact-report tests from env-armed chaos injection."""
    with faults.injected([]):
        yield


GEMMS = [Gemm("a", 64, 128, 64), Gemm("b", 100, 20, 30), Gemm("c", 512, 512, 64)]


def _grid(**kw):
    kw.setdefault("rows", (16, 32))
    kw.setdefault("cols", (16, 32))
    kw.setdefault("input_bits", (8,))
    kw.setdefault("dataflows", ("WS", "OS"))
    kw.setdefault("pe_area_um2", (900.0,))
    return DesignSpace(**kw).expand()


# ---------------------------------------------------------------------------
# Lowered partition arrays vs the scalar oracle
# ---------------------------------------------------------------------------


def _check_partition_parity(grid, layouts, gemms):
    host = lower_partition_coeffs(grid, layouts, gemms).host
    rows = np.asarray(grid.rows, np.int64)
    cols = np.asarray(grid.cols, np.int64)
    os_mask = np.asarray(grid.dataflow_os, bool)
    for gi, g in enumerate(gemms):
        for li, name in enumerate(layouts):
            layout = get_layout(name)
            k = layout.k if isinstance(layout, MultiPodLayout) else 1
            feas = layout_feasible(layout, rows, cols)
            for pj in range(grid.n_points):
                cell = (gi, li, pj)
                if not feas[pj] or g.macs == 0:
                    assert host["utilization"][cell] == 0.0
                    assert host["spill_words_per_mac"][cell] == 0.0
                    assert host["trunk_words_per_mac"][cell] == 0.0
                    continue
                ref = partition_gemm(
                    g,
                    int(rows[pj]),
                    int(cols[pj]),
                    k=k,
                    dataflow="OS" if os_mask[pj] else "WS",
                )
                assert host["utilization"][cell] == pytest.approx(
                    ref.utilization, rel=1e-9
                )
                assert host["spill_words_per_mac"][cell] == pytest.approx(
                    ref.spill_words / g.macs, rel=1e-9
                )
                assert host["trunk_words_per_mac"][cell] == pytest.approx(
                    ref.trunk_words / g.macs, rel=1e-9
                )
                assert host["ksplit"][cell] == float(ref.mode == "ksplit")


def test_lowered_partition_matches_oracle_seeded():
    rng = np.random.default_rng(77)
    for _ in range(6):
        grid = _grid(
            rows=tuple(int(8 * rng.integers(1, 9)) for _ in range(2)),
            cols=tuple(int(8 * rng.integers(1, 9)) for _ in range(2)),
        )
        gemms = [
            Gemm(
                f"g{i}",
                int(rng.integers(1, 600)),
                int(rng.integers(1, 600)),
                int(rng.integers(1, 600)),
            )
            for i in range(3)
        ]
        _check_partition_parity(
            grid, ("uniform", "serpentine2") + pod_layouts((2, 3, 8)), gemms
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_lowered_partition_matches_oracle_hypothesis(seed):
    rng = np.random.default_rng(seed)
    grid = _grid(
        rows=(int(8 * rng.integers(1, 9)),), cols=(int(8 * rng.integers(1, 9)),)
    )
    gemms = [
        Gemm(
            "g",
            int(rng.integers(1, 2000)),
            int(rng.integers(1, 2000)),
            int(rng.integers(1, 2000)),
        )
    ]
    _check_partition_parity(grid, ("uniform",) + pod_layouts((2, 4, 8)), gemms)


# ---------------------------------------------------------------------------
# Partition edge cases (the oracle the lowered arrays are tested against)
# ---------------------------------------------------------------------------


def test_partition_k1_identity_vs_uniform():
    """pods1x1 degenerates to the monolithic array: identical statistics
    through both the scalar oracle and the lowered arrays."""
    g = Gemm("g", 200, 300, 150)
    p1 = partition_gemm(g, 32, 32, k=1)
    assert p1.mode == "tile" and p1.trunk_words == 0
    host = lower_partition_coeffs(_grid(), ("uniform", "pods1x1"), [g]).host
    for f in ("utilization", "spill_words_per_mac", "trunk_words_per_mac", "ksplit"):
        np.testing.assert_array_equal(host[f][:, 0], host[f][:, 1])


def test_partition_ragged_gemm_smaller_than_one_pod():
    """An M/N footprint smaller than a single pod still occupies one full
    wave: exactly macs/(rows*cols*stream) utilization, one round."""
    g = Gemm("tiny", 4, 8, 4)
    for dataflow in ("WS", "OS"):
        p = partition_gemm(g, 32, 32, k=4, dataflow=dataflow)
        stream = g.k if dataflow == "OS" else g.m
        assert p.rounds == 1
        assert p.utilization == pytest.approx(g.macs / (32 * 32 * stream))
        assert p.utilization < 1.0 / 16  # worse than even one pod's share


def test_partition_os_drain_semantics():
    """Under OS both operands stream over K: pods never cooperate (no
    reduction to share), so no trunk traffic and no partial-sum spills —
    the drain traffic is priced by the layout engine's drain net instead."""
    g = Gemm("deep", 64, 4096, 64)
    os_ = partition_gemm(g, 32, 32, k=4, dataflow="OS")
    assert os_.mode == "tile"
    assert os_.spill_words == 0 and os_.trunk_words == 0
    assert os_.cycles == os_.rounds * g.k  # K streams temporally
    # the same deep-K GEMM under WS must spill or reduce in-array
    ws = partition_gemm(g, 32, 32, k=4, dataflow="WS")
    assert ws.spill_words > 0 or ws.trunk_words > 0


def test_partition_zero_mac_gemm():
    g0 = Gemm("empty", 0, 128, 64)
    p = partition_gemm(g0, 32, 32, k=2)
    assert p.utilization == 0.0 and g0.macs == 0
    # lowered arrays: zero everywhere, no division by zero
    host = lower_partition_coeffs(_grid(), ("uniform", "pods2x2"), [g0]).host
    for f in ("utilization", "spill_words_per_mac", "trunk_words_per_mac"):
        assert (host[f] == 0.0).all()
    # MAC-weighted aggregation drops the degenerate GEMM entirely
    grid = _grid()
    both = design_pod_partition(grid, ("uniform", "pods2x2"), [g0, GEMMS[0]])
    alone = design_pod_partition(grid, ("uniform", "pods2x2"), [GEMMS[0]])
    for f in both:
        np.testing.assert_allclose(both[f], alone[f], rtol=1e-12)


def test_partition_ksplit_trunk_accounting_k8():
    """K-split at k=8: trunk words = ceil(K/rows) * M * N * (k-1) exactly
    (every partial crosses k-1 gutters down the reduction column)."""
    g = Gemm("deep", 512, 512, 64)
    p = partition_gemm(g, 64, 64, k=8)
    assert p.mode == "ksplit"
    want = -(-g.k // 64) * g.m * g.n * (8 - 1)
    assert p.trunk_words == want
    assert p.spill_words == (-(-g.k // 64) - 1) * g.m * g.n
    # and the lowered tensor carries the same count per MAC
    grid = _grid(rows=(64,), cols=(64,), dataflows=("WS",))
    host = lower_partition_coeffs(grid, ("pods8x8",), [g]).host
    assert host["trunk_words_per_mac"][0, 0, 0] == pytest.approx(
        want / g.macs, rel=1e-12
    )


def test_design_pod_partition_is_the_lowered_aggregation():
    """The legacy dict API delegates to the lowered arrays — the two paths
    cannot disagree (the bus_energy_per_mac_j/utilization footgun fix)."""
    grid = _grid()
    layouts = ("uniform",) + pod_layouts((1, 2))
    stats = design_pod_partition(grid, layouts, GEMMS)
    host = lower_partition_coeffs(grid, layouts, GEMMS).host
    w = np.asarray([g.macs for g in GEMMS], float)
    w3 = (w / w.sum())[:, None, None]
    np.testing.assert_array_equal(
        stats["utilization"], (w3 * host["utilization"]).sum(0)
    )
    np.testing.assert_array_equal(
        stats["trunk_words_per_mac"], (w3 * host["trunk_words_per_mac"]).sum(0)
    )


# ---------------------------------------------------------------------------
# Coding lowering
# ---------------------------------------------------------------------------


def test_coding_multipliers_match_closed_form():
    grid = _grid(bus_invert=(False, True))
    rng = np.random.default_rng(3)
    a_v = rng.uniform(0.05, 0.8, (2, grid.n_points))
    mult = lower_coding_multipliers(grid, a_v).host["act_mult"]
    assert mult.shape == (2, len(DATA_CLASS_IDX), grid.n_points)
    bi = np.asarray(grid.bus_invert, bool)
    bits = np.asarray(grid.b_v_data, np.int64)
    is_h = DATA_IS_H.astype(bool)
    np.testing.assert_array_equal(mult[:, is_h, :], 1.0)
    for w in range(2):
        for pj in range(grid.n_points):
            want = (
                bus_invert_activity(float(a_v[w, pj]), int(bits[pj]))
                / float(a_v[w, pj])
                if bi[pj]
                else 1.0
            )
            for c in np.nonzero(~is_h)[0]:
                assert mult[w, c, pj] == pytest.approx(want, rel=1e-12)
    # identity lowering on a coding-free grid
    unc = _grid()
    assert (lower_coding_multipliers(unc, a_v).host["act_mult"] == 1.0).all()
    np.testing.assert_array_equal(grid_coding_effective(unc, a_v), a_v)


def test_coding_scheme_registry():
    assert set(CODING_SCHEMES) == {"none", "bus_invert", "zvcg"}
    a = np.asarray([0.3])
    np.testing.assert_array_equal(CODING_SCHEMES["none"](a, 8), a)
    np.testing.assert_allclose(
        CODING_SCHEMES["bus_invert"](a, 8), [bus_invert_activity(0.3, 8)]
    )
    with pytest.raises(NotImplementedError, match="zero-run"):
        CODING_SCHEMES["zvcg"](a, 8)


def test_bus_invert_layout_engine_parity():
    """The layout engine prices BI points exactly as the explicit segment
    enumeration at the coded activity (the de-special-casing contract)."""
    grid = _grid(rows=(16,), cols=(16, 32), bus_invert=(False, True))
    a_h, a_v = 0.3, 0.45
    ev = evaluate_layout_space(
        grid, a_h, a_v, layouts=("uniform", "pods2x2"), use_jit=False
    )
    bi = np.asarray(grid.bus_invert, bool)
    bits = np.asarray(grid.b_v_data, np.int64)
    for li, name in enumerate(("uniform", "pods2x2")):
        for pj in range(grid.n_points):
            if not ev.feasible[li, pj]:
                continue
            av_eff = bus_invert_activity(a_v, int(bits[pj])) if bi[pj] else a_v
            ref = segment_bus_power(
                get_layout(name),
                grid.geometry(pj),
                BusActivity(a_h, av_eff),
                float(ev.aspect_opt[0, li, pj]),
                dataflow="OS" if grid.dataflow_os[pj] else "WS",
            )
            assert float(ev.bus_power_opt[0, li, pj]) == pytest.approx(
                ref, rel=1e-12
            )


# ---------------------------------------------------------------------------
# The fused objective
# ---------------------------------------------------------------------------


def test_j_per_mac_matches_host_recombination():
    """Single-GEMM fleet: j_per_mac recombines in host f64 from the eval's
    own wire-power outputs + the scalar partition oracle + the calibrated
    static split + the schema's v-class lengths — to 1e-9."""
    from repro.layout.power import LayoutPowerConfig

    grid = _grid(bus_invert=(False, True))
    g = GEMMS[2]
    rng = np.random.default_rng(11)
    a_h = rng.uniform(0.1, 0.5, (1, grid.n_points))
    a_v = rng.uniform(0.1, 0.6, (1, grid.n_points))
    layouts = ("uniform", "pods2x2", "pods4x4")
    cfg = LayoutPowerConfig()
    ev = evaluate_fleet_objective(grid, a_h, a_v, [g], layouts=layouts, use_jit=False)

    host = lower_partition_coeffs(grid, layouts, [g]).host
    static = fleet_static_power(grid, a_h, a_v)
    coeffs = lower_layout_coeffs(
        grid, layouts,
        max_envelope_aspect=cfg.max_envelope_aspect,
        repeater_spacing_um=cfg.repeater_spacing_um,
    ).host
    a_v_eff = grid_coding_effective(grid, a_v)
    pref = 0.5 * cfg.wire_cap_f_per_um * cfg.vdd**2 * cfg.freq_hz
    t_r = np.sqrt(ev.aspect_robust)  # (L, P)
    rows = np.asarray(grid.rows, float)
    cols = np.asarray(grid.cols, float)

    def word_energy(cls_idx, hops):
        ln = (
            coeffs["alpha_d"][:, cls_idx] * t_r
            + coeffs["beta_d"][:, cls_idx] / t_r
            + coeffs["gamma_d"][:, cls_idx]
        )
        rep = 1.0 + cfg.repeater_overhead * np.maximum(
            ln / cfg.repeater_spacing_um - 1.0, 0.0
        )
        wires = a_v_eff[0][None, :] * coeffs["width_d"][:, cls_idx]
        return hops * (pref / cfg.freq_hz) * ln * rep * wires

    e_spill = word_energy(V_HOP_DATA_IDX, 2.0 * rows[None, :])
    e_trunk = word_energy(V_CROSS_DATA_IDX, 1.0)
    util = host["utilization"][0]
    p_tot = np.asarray(ev.bus_power_robust) + np.asarray(ev.overhead_w) + static[0]
    with np.errstate(divide="ignore", invalid="ignore"):
        want = (
            p_tot / (cfg.freq_hz * rows * cols * util)
            + host["spill_words_per_mac"][0] * e_spill
            + host["trunk_words_per_mac"][0] * e_trunk
        )
    want = np.where((util > 0) & ev.feasible, want, np.inf)
    got = np.asarray(ev.j_per_mac)[0]
    m = np.isfinite(want)
    assert (np.isfinite(got) == m).all()
    np.testing.assert_allclose(got[m], want[m], rtol=1e-9)
    # single-GEMM fleet slot == the per-workload row
    np.testing.assert_allclose(
        np.asarray(ev.j_per_mac_robust)[m], got[m], rtol=1e-12
    )
    np.testing.assert_array_equal(
        np.asarray(ev.utilization)[0], host["utilization"][0]
    )


def test_fleet_objective_jit_matches_eager():
    grid = _grid(bus_invert=(False, True))
    rng = np.random.default_rng(5)
    a_h = rng.uniform(0.1, 0.4, (3, grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (3, grid.n_points))
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"))
    j = evaluate_fleet_objective(grid, a_h, a_v, GEMMS, use_jit=True, **kw)
    e = evaluate_fleet_objective(grid, a_h, a_v, GEMMS, use_jit=False, **kw)
    m = np.isfinite(e.j_per_mac_robust)
    assert (np.isfinite(np.asarray(j.j_per_mac_robust)) == m).all()
    np.testing.assert_allclose(
        np.asarray(j.j_per_mac_robust)[m], np.asarray(e.j_per_mac_robust)[m],
        rtol=2e-4,
    )


def test_jpo_flips_winner_vs_bus_power():
    """The paper's scale-in claim: on a mixed fleet there are cells where
    the J/op winner is NOT the wire-power winner (utilization and traffic
    flip the ranking)."""
    grid = _grid(rows=(8, 16), cols=(8, 16, 32), bus_invert=(False, True))
    rng = np.random.default_rng(0)
    a_h = rng.uniform(0.1, 0.4, (3, grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (3, grid.n_points))
    ev = evaluate_fleet_objective(
        grid, a_h, a_v, GEMMS, layouts=("uniform", "serpentine2", "pods2x2")
    )
    assert int(np.sum(ev.best_layout != ev.best_layout_jpo)) >= 1
    # and the objective fields satisfy their contracts
    util = np.asarray(ev.utilization)
    assert ((util >= 0) & (util <= 1.0 + 1e-9)).all()
    jpm = np.asarray(ev.j_per_mac)
    live = ev.feasible[None] & (util > 0)
    assert np.isfinite(jpm[live]).all() and (jpm[live] > 0).all()
    assert np.isinf(jpm[~live]).all()


def test_fleet_objective_validates_axes():
    grid = _grid()
    with pytest.raises(ValueError, match="GEMM"):
        evaluate_fleet_objective(
            grid, np.full((2, grid.n_points), 0.3), np.full((2, grid.n_points), 0.3),
            GEMMS,
        )
    with pytest.raises(ValueError, match="no gemms"):
        evaluate_fleet_objective(grid, 0.3, 0.3, [])
    # plain layout evals have no J/op fields
    ev = evaluate_layout_space(grid, 0.3, 0.3, use_jit=False)
    assert ev.j_per_mac is None
    with pytest.raises(ValueError, match="J/op"):
        _ = ev.best_layout_jpo


# ---------------------------------------------------------------------------
# Objective sweeps: chunking, resume, guards
# ---------------------------------------------------------------------------


def _fleet_args():
    grid = _grid(rows=(8, 16), cols=(8, 16, 32), bus_invert=(False, True))
    rng = np.random.default_rng(0)
    a_h = rng.uniform(0.1, 0.4, (3, grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (3, grid.n_points))
    return grid, a_h, a_v


def test_objective_sweep_chunked_resume_bit_identical(tmp_path):
    grid, a_h, a_v = _fleet_args()
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"), use_jit=True)
    plain = evaluate_fleet_objective(grid, a_h, a_v, GEMMS, **kw)
    store = tmp_path / "chunks"
    with pytest.raises(SweepInterrupted) as ei:
        evaluate_fleet_objective(
            grid, a_h, a_v, GEMMS, **kw,
            sweep=SweepConfig(chunk_size=7, store=store, max_chunks=2),
        )
    assert ei.value.report.chunks_evaluated == 2
    done = evaluate_fleet_objective(
        grid, a_h, a_v, GEMMS, **kw, sweep=SweepConfig(chunk_size=7, store=store)
    )
    rep = done.sweep_report
    assert rep.kind == "objective"
    assert rep.chunks_resumed == 2 and rep.chunks_evaluated == 2
    for f in (
        "feasible", "utilization", "j_per_mac", "j_per_mac_robust",
        "bus_power_robust", "overhead_w",
    ):
        a, b = np.asarray(getattr(plain, f)), np.asarray(getattr(done, f))
        assert a.tobytes() == b.tobytes(), f
    np.testing.assert_array_equal(plain.best_layout_jpo, done.best_layout_jpo)


def test_objective_sweep_never_aliases_layout_chunks(tmp_path):
    """J/op chunks carry extra fields: the spec must keep them apart from
    wire-power chunks over the same grid/activities."""
    grid, a_h, a_v = _fleet_args()
    store = tmp_path / "chunks"
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"), use_jit=False)
    evaluate_layout_space(
        grid, a_h, a_v, **kw, sweep=SweepConfig(chunk_size=9, store=store)
    )
    ev = evaluate_fleet_objective(
        grid, a_h, a_v, GEMMS, **kw, sweep=SweepConfig(chunk_size=9, store=store)
    )
    assert ev.sweep_report.chunks_resumed == 0  # nothing mis-served


def test_nan_poisoned_objective_chunk_trips_jop_guard():
    grid, a_h, a_v = _fleet_args()
    with faults.injected(
        [faults.FaultSpec("nan", match="jit:j_per_mac|chunk0", max_fires=1)]
    ) as inj:
        ev = evaluate_fleet_objective(
            grid, a_h, a_v, GEMMS,
            layouts=("uniform", "serpentine2", "pods2x2"),
            use_jit=True, sweep=SweepConfig(chunk_size=7),
        )
    assert inj.fired_kinds() == {"nan"}
    rep = ev.sweep_report
    assert rep.guard_failures == 1
    assert rep.failures.actions().get("degraded:eager") == 1
    # the poison never reached the assembled output
    jpm = np.asarray(ev.j_per_mac)
    assert not np.isnan(jpm).any()
    live = ev.feasible[None] & (np.asarray(ev.utilization) > 0)
    assert np.isfinite(jpm[live]).all() and (jpm[live] > 0).all()


def test_tampered_utilization_fails_exact_passthrough_guard(tmp_path):
    """utilization is a pure pass-through of the lowered arrays: a stored
    chunk with a perturbed (finite, in-range) utilization must still fail."""
    import pathlib

    grid, a_h, a_v = _fleet_args()
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"), use_jit=False)
    store = tmp_path / "chunks"
    evaluate_fleet_objective(
        grid, a_h, a_v, GEMMS, **kw, sweep=SweepConfig(chunk_size=9, store=store)
    )
    # tamper every stored entry through the store's own put (valid sha)
    from repro.core.store import ContentStore
    from repro.core.sweep import SWEEP_STORE_VERSION, _decode_chunk, _encode_chunk
    from repro.core.sweep import _OBJECTIVE_FIELDS

    s = ContentStore(store, version=SWEEP_STORE_VERSION)
    tampered = 0
    for path in list(s.entries()):
        key = bytes.fromhex(pathlib.Path(path).stem)
        payload = s.get_payload(key)
        if payload is None or payload.get("kind") != "objective":
            continue
        out, rung = _decode_chunk(
            payload, "objective", payload["chunk"], _OBJECTIVE_FIELDS
        )
        u = out["utilization"]
        u[u > 0] = np.clip(u[u > 0] * 0.99, 0.0, 1.0)  # finite, in-range, wrong
        s.put_payload(key, _encode_chunk("objective", payload["chunk"], rung, out))
        tampered += 1
    assert tampered > 0
    warm = evaluate_fleet_objective(
        grid, a_h, a_v, GEMMS, **kw, sweep=SweepConfig(chunk_size=9, store=store)
    )
    rep = warm.sweep_report
    assert rep.guard_failures >= tampered
    assert rep.chunks_quarantined == tampered and rep.chunks_resumed == 0
