"""Chunked, checkpointed, self-validating sweeps (``core.sweep``): chunked
output bit-identical to unchunked, kill-and-resume bit-identical to an
uninterrupted run, every injected fault caught by a guard or recovered down
the jit -> eager -> scalar ladder, and the SweepReport accounts for all of
it in typed records."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.design_space import DesignSpace, evaluate_design_space
from repro.core.store import ContentStore
from repro.core.sweep import (
    SWEEP_STORE_VERSION,
    SweepConfig,
    SweepInterrupted,
    _chunk_idx,
    _decode_chunk,
    _encode_chunk,
)
from repro.layout.power import evaluate_layout_space
from repro.runtime import faults
from repro.runtime.health import HealthMonitor
from repro.runtime.resilience import (
    ContractViolationError,
    CrossEngineMismatchError,
    GuardViolationError,
)


@pytest.fixture(autouse=True)
def _pin_faults():
    """Exact-report tests must see ONLY their own injected faults: shield
    them from env-armed chaos injection (the chaos CI job sets
    $REPRO_FAULTS suite-wide)."""
    with faults.injected([]):
        yield


SPACE = DesignSpace(
    rows=(8, 16),
    cols=(8, 16),
    input_bits=(8,),
    dataflows=("WS", "OS"),
    bus_invert=(False, True),
)
GRID = SPACE.expand()  # 16 points

rng = np.random.default_rng(23)
W = 2
A_H = rng.uniform(0.1, 0.4, (W, GRID.n_points))
A_V = rng.uniform(0.2, 0.6, (W, GRID.n_points))

FIELDS = (
    "a_v_eff",
    "aspect_opt",
    "aspect_opt_gss",
    "bus_power_opt",
    "bus_power_sym",
    "aspect_robust",
    "max_regret",
    "bus_power_robust",
    "bus_power_square",
    "interconnect_saving",
    "total_saving",
    "area_um2",
    "bus_energy_per_mac_j",
    "neg_macs_per_cycle",
)
LFIELDS = (
    "feasible",
    "aspect_lo",
    "aspect_hi",
    "aspect_opt",
    "bus_power_opt",
    "aspect_robust",
    "bus_power_robust",
    "overhead_w",
    "wirelength_um",
)


def _assert_bit_identical(a, b, fields):
    for f in fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        assert x.dtype == y.dtype and x.shape == y.shape, f
        assert np.ascontiguousarray(x).tobytes() == np.ascontiguousarray(y).tobytes(), f


# ---------------------------------------------------------------------------
# Chunked == unchunked (the sweep runner changes execution, never the math)
# ---------------------------------------------------------------------------


def test_chunked_matches_unchunked_jit():
    plain = evaluate_design_space(GRID, A_H, A_V, use_jit=True)
    # chunk_size=7 forces a ragged (clamp-padded) last chunk: 16 -> 7+7+2
    chunked = evaluate_design_space(
        GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7)
    )
    _assert_bit_identical(plain, chunked, FIELDS)
    rep = chunked.sweep_report
    assert rep.kind == "design" and rep.chunks_total == 3
    assert rep.chunks_evaluated == 3 and rep.chunks_resumed == 0
    assert rep.guard_failures == 0 and rep.guard_checks == 3
    assert rep.rung_counts() == {"jit": 3}
    assert np.array_equal(plain.pareto(), chunked.pareto())


def test_chunked_matches_unchunked_eager():
    plain = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    chunked = evaluate_design_space(
        GRID, A_H, A_V, use_jit=False, sweep=SweepConfig(chunk_size=5)
    )
    _assert_bit_identical(plain, chunked, FIELDS)
    assert chunked.sweep_report.rung_counts() == {"eager": 4}


def test_chunked_matches_unchunked_layout(tmp_path):
    # the layout engine prices physical buses: BI-free grid (8 points)
    lgrid = DesignSpace(
        rows=(8, 16), cols=(8, 16), input_bits=(8,), dataflows=("WS", "OS")
    ).expand()
    la_h, la_v = A_H[:, : lgrid.n_points], A_V[:, : lgrid.n_points]
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"), use_jit=False)
    plain = evaluate_layout_space(lgrid, la_h, la_v, **kw)
    chunked = evaluate_layout_space(
        lgrid, la_h, la_v, **kw, sweep=SweepConfig(chunk_size=3, store=tmp_path / "s")
    )
    _assert_bit_identical(plain, chunked, LFIELDS)
    assert chunked.sweep_report.kind == "layout"
    assert chunked.sweep_report.chunks_evaluated == 3
    # resumed run serves every chunk from the store, bit-identically
    resumed = evaluate_layout_space(
        lgrid, la_h, la_v, **kw, sweep=SweepConfig(chunk_size=3, store=tmp_path / "s")
    )
    _assert_bit_identical(plain, resumed, LFIELDS)
    rep = resumed.sweep_report
    assert rep.chunks_resumed == 3 and rep.chunks_evaluated == 0
    assert np.array_equal(plain.best_layout, resumed.best_layout)


# ---------------------------------------------------------------------------
# Resume: store round-trip, interruption, kill -9, corruption
# ---------------------------------------------------------------------------


def test_resume_serves_all_chunks_bit_identically(tmp_path):
    sw = lambda: SweepConfig(chunk_size=7, store=tmp_path / "chunks")
    cold = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    warm = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    _assert_bit_identical(cold, warm, FIELDS)
    rep = warm.sweep_report
    assert rep.chunks_resumed == 3 and rep.chunks_evaluated == 0
    # resumed chunks still pass the guards (rung "stored")
    assert rep.guard_checks == 3 and rep.guard_failures == 0
    assert all(r.status == "resumed" for r in rep.records)


def test_jit_and_eager_runs_never_share_chunks(tmp_path):
    """The starting rung is part of the spec key: f32 jit chunks must not be
    served to an f64 eager run (they agree to tolerance, not bit-for-bit)."""
    store = tmp_path / "chunks"
    evaluate_design_space(
        GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7, store=store)
    )
    ev = evaluate_design_space(
        GRID, A_H, A_V, use_jit=False, sweep=SweepConfig(chunk_size=7, store=store)
    )
    assert ev.sweep_report.chunks_resumed == 0
    assert ev.sweep_report.chunks_evaluated == 3


def test_max_chunks_interrupts_then_resume_completes(tmp_path):
    store = tmp_path / "chunks"
    baseline = evaluate_design_space(GRID, A_H, A_V, use_jit=True)
    with pytest.raises(SweepInterrupted) as ei:
        evaluate_design_space(
            GRID, A_H, A_V, use_jit=True,
            sweep=SweepConfig(chunk_size=7, store=store, max_chunks=2),
        )
    assert ei.value.report.chunks_evaluated == 2  # committed before the stop
    done = evaluate_design_space(
        GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7, store=store)
    )
    rep = done.sweep_report
    assert rep.chunks_resumed == 2 and rep.chunks_evaluated == 1
    _assert_bit_identical(baseline, done, FIELDS)
    assert np.array_equal(baseline.pareto(), done.pareto())


def test_injected_abort_then_resume_bit_identical(tmp_path):
    """kill -9 mid-sweep: the abort lands at a chunk commit boundary, so
    exactly the committed chunks survive; resume reproduces the
    uninterrupted run bit-for-bit."""
    store = tmp_path / "chunks"
    baseline = evaluate_design_space(GRID, A_H, A_V, use_jit=True)
    with faults.injected([faults.FaultSpec("abort", match="chunk1")]) as inj:
        with pytest.raises(faults.InjectedAbortError):
            evaluate_design_space(
                GRID, A_H, A_V, use_jit=True,
                sweep=SweepConfig(chunk_size=7, store=store),
            )
        assert inj.fired_kinds() == {"abort"}
    # chunks 0 and 1 committed before the abort tore the process down
    assert len(ContentStore(store, version=SWEEP_STORE_VERSION).entries()) == 2
    done = evaluate_design_space(
        GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7, store=store)
    )
    rep = done.sweep_report
    assert rep.chunks_resumed == 2 and rep.chunks_evaluated == 1
    _assert_bit_identical(baseline, done, FIELDS)


def test_bitflip_quarantines_and_recomputes(tmp_path):
    store = tmp_path / "chunks"
    sw = lambda: SweepConfig(chunk_size=7, store=store)
    cold = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    with faults.injected([faults.FaultSpec("bitflip", max_fires=1)]) as inj:
        warm = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    assert inj.fired_kinds() == {"bitflip"}
    rep = warm.sweep_report
    assert rep.chunks_quarantined == 1
    assert rep.chunks_resumed == 2 and rep.chunks_evaluated == 1
    assert rep.failures.actions().get("quarantined:recomputed") == 1
    _assert_bit_identical(cold, warm, FIELDS)
    s = ContentStore(store, version=SWEEP_STORE_VERSION)
    assert len(s.quarantined()) == 1  # the torn entry is preserved forensics
    assert len(s.entries()) == 3  # ... and its slot was rewritten


# ---------------------------------------------------------------------------
# Guards + degradation ladder
# ---------------------------------------------------------------------------


def test_transient_poison_caught_and_degraded_to_eager():
    """A NaN poked into one jit result field is indistinguishable from a
    silent miscompute — the guard must catch it and the ladder recover."""
    with faults.injected(
        [faults.FaultSpec("nan", match="jit:bus_power_opt|chunk0", max_fires=1)]
    ) as inj:
        ev = evaluate_design_space(
            GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7)
        )
    assert inj.fired_kinds() == {"nan"}
    rep = ev.sweep_report
    assert rep.guard_failures == 1
    assert rep.rung_counts() == {"jit": 2, "eager": 1}
    assert rep.failures.actions().get("degraded:eager") == 1
    for f in FIELDS:  # the poison never reached the assembled output
        assert np.isfinite(np.asarray(getattr(ev, f))).all(), f
    # the recovered chunk is the f64 eager evaluation of those points
    plain = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    idx = _chunk_idx(0, 7, GRID.n_points)
    np.testing.assert_allclose(
        np.asarray(ev.bus_power_robust)[idx],
        np.asarray(plain.bus_power_robust)[idx],
        rtol=1e-4,
    )


def test_permanent_poison_exhausts_ladder_and_raises():
    with faults.injected(
        [faults.FaultSpec("nan", match="sweep-result")]  # every rung, forever
    ):
        with pytest.raises(GuardViolationError) as ei:
            evaluate_design_space(
                GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7)
            )
    assert ei.value.violations  # machine-readable guard verdicts ride along
    assert any("non-finite" in s for s in ei.value.violations)


def test_on_violation_raise_surfaces_first_guard_failure():
    with faults.injected(
        [faults.FaultSpec("nan", match="jit:bus_power_opt|chunk0", max_fires=1)]
    ):
        with pytest.raises(GuardViolationError):
            evaluate_design_space(
                GRID, A_H, A_V, use_jit=True,
                sweep=SweepConfig(chunk_size=7, on_violation="raise"),
            )


def test_cross_engine_mismatch_is_typed():
    """A tampered stored chunk whose fields are finite but wrong must fail
    the scalar-oracle cross-check with the typed mismatch error."""
    from repro.core.sweep import _guard_error

    err = _guard_error(
        ["cross-engine:aspect_opt[0,3] vs scalar Eq. 6"], job="chunk0", stage="t"
    )
    assert isinstance(err, CrossEngineMismatchError)
    assert isinstance(err, GuardViolationError)
    err2 = _guard_error(["negative power in bus_power_opt"], job="chunk0", stage="t")
    assert isinstance(err2, GuardViolationError)
    assert not isinstance(err2, CrossEngineMismatchError)


def test_tampered_store_entry_fails_guard_and_recomputes(tmp_path):
    """Rewrite a stored chunk with finite-but-wrong physics (negative power)
    through the store's own put (valid sha) — only the guard can catch it."""
    from repro.core.sweep import _chunk_key, _spec_key
    import dataclasses as dc

    from repro.core.design_space import EnergyModelConfig

    store_dir = tmp_path / "chunks"
    sw = lambda: SweepConfig(chunk_size=7, store=store_dir)
    cold = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    # re-derive chunk 1's key exactly as the runner does
    w = np.full(W, 1.0 / W)
    spec = _spec_key(
        "design", GRID, A_H, A_V, w,
        extra=[
            ("cfg", repr(dc.astuple(EnergyModelConfig()))),
            ("gss_iters", 64),
            ("chunk_size", 7),
            ("start_rung", "jit"),
        ],
    )
    store = ContentStore(
        store_dir, version=SWEEP_STORE_VERSION, corrupt_site="chunk-store-read"
    )
    key = _chunk_key(spec, 1)
    payload = store.get_payload(key)
    assert payload is not None, "spec key derivation drifted from the runner"
    out, _ = _decode_chunk(payload, "design", 1, FIELDS)
    out["bus_power_robust"] = -np.abs(out["bus_power_robust"])  # finite, wrong
    store.put_payload(key, _encode_chunk("design", 1, "jit", out))
    warm = evaluate_design_space(GRID, A_H, A_V, use_jit=True, sweep=sw())
    rep = warm.sweep_report
    assert rep.chunks_quarantined == 1 and rep.guard_failures == 1
    assert rep.chunks_resumed == 2 and rep.chunks_evaluated == 1
    _assert_bit_identical(cold, warm, FIELDS)


def test_backend_fault_is_retried():
    with faults.injected(
        [faults.FaultSpec("backend", match="chunk1", max_fires=1)]
    ) as inj:
        ev = evaluate_design_space(
            GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7)
        )
    assert inj.fired_kinds() == {"backend"}
    rep = ev.sweep_report
    assert rep.failures.actions().get("retried") == 1
    assert rep.rung_counts() == {"jit": 3}  # recovered on the same rung
    assert next(r for r in rep.records if r.index == 1).attempts == 2


def test_hang_evicts_device_and_resubmits():
    """A wedged simulated device: timeout -> evict -> resubmit the chunk
    once to a survivor (PR 6 eviction semantics at the sweep layer)."""
    import jax

    real = list(jax.local_devices())
    devices = tuple(real * 2)  # simulate a 2-device fleet on one backend
    # warm the compile cache first: the cold jit compile runs INSIDE the
    # timed dispatch future, so an un-warmed first chunk would trip the
    # timeout on healthy devices too
    evaluate_design_space(
        GRID, A_H, A_V, use_jit=True, sweep=SweepConfig(chunk_size=7)
    )
    health = HealthMonitor(range(2))
    with faults.injected(
        [faults.FaultSpec("hang", match="sweep-chunk:d1", max_fires=1)], hang_s=2.0
    ) as inj:
        ev = evaluate_design_space(
            GRID, A_H, A_V, use_jit=True,
            sweep=SweepConfig(
                chunk_size=7, timeout_s=0.5, devices=devices, health=health
            ),
        )
    assert inj.fired_kinds() == {"hang"}
    rep = ev.sweep_report
    assert rep.resubmits == 1
    assert rep.failures.actions().get("device-evicted:resubmitted") == 1
    assert health.alive_hosts() == [0]
    plain = evaluate_design_space(GRID, A_H, A_V, use_jit=True)
    _assert_bit_identical(plain, ev, FIELDS)


# ---------------------------------------------------------------------------
# Codec + config validation
# ---------------------------------------------------------------------------


def test_chunk_codec_round_trips_every_bit_pattern():
    arr = np.asarray([np.nan, np.inf, -np.inf, -0.0, 1e-300, 7.25], np.float64)
    f32 = arr.astype(np.float32)
    out = {"a": arr.reshape(2, 3), "b": f32, "c": np.asarray([True, False])}
    enc = _encode_chunk("design", 4, "eager", out)
    dec, rung = _decode_chunk(enc, "design", 4, ("a", "b", "c"))
    assert rung == "eager"
    for k in out:
        assert dec[k].dtype == out[k].dtype and dec[k].shape == out[k].shape
        assert dec[k].tobytes() == out[k].tobytes()  # NaN payload bits too
    with pytest.raises(ValueError, match="wanted"):
        _decode_chunk(enc, "design", 5, ("a", "b", "c"))
    with pytest.raises(ValueError, match="wanted"):
        _decode_chunk(enc, "layout", 4, ("a", "b", "c"))
    with pytest.raises(ValueError, match="field set"):
        _decode_chunk(enc, "design", 4, ("a", "b"))


def test_sweep_config_validation():
    with pytest.raises(ContractViolationError):
        SweepConfig(chunk_size=0)
    with pytest.raises(ContractViolationError):
        SweepConfig(on_violation="explode")
    with pytest.raises(ContractViolationError):
        SweepConfig(max_chunks=0)


# ---------------------------------------------------------------------------
# Guards have no false positives on valid inputs (property test)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=15)
@given(
    rows=st.sampled_from([4, 8, 16, 32]),
    cols=st.sampled_from([4, 8, 16]),
    bits=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    chunk=st.integers(1, 9),
)
def test_guards_no_false_positives_on_valid_grids(rows, cols, bits, seed, chunk):
    """Random valid grids + random activities must sail through every guard
    on the strict (eager, f64) rung — a guard that cries wolf would send
    healthy sweeps down the scalar ladder."""
    space = DesignSpace(
        rows=(rows, rows * 2),
        cols=(cols,),
        input_bits=(bits,),
        dataflows=("WS", "OS"),
        bus_invert=(False, True),
    )
    grid = space.expand()
    r = np.random.default_rng(seed)
    a_h = r.uniform(0.01, 0.7, (2, grid.n_points))
    a_v = r.uniform(0.01, 0.9, (2, grid.n_points))
    ev = evaluate_design_space(
        grid, a_h, a_v, use_jit=False,
        sweep=SweepConfig(chunk_size=chunk, seed=seed),
    )
    rep = ev.sweep_report
    assert rep.guard_failures == 0
    assert rep.guard_checks == rep.chunks_total


def test_report_as_dict_is_json_ready():
    import json

    ev = evaluate_design_space(
        GRID, A_H, A_V, use_jit=False, sweep=SweepConfig(chunk_size=7)
    )
    d = ev.sweep_report.as_dict()
    json.dumps(d)  # no numpy scalars / arrays leak into the report
    assert d["kind"] == "design" and d["chunks_total"] == 3
    assert d["guard_verdicts"]["pass"] == 3
    assert "sweep:" not in ev.sweep_report.summary() or True
    assert "3 chunks" in ev.sweep_report.summary()
