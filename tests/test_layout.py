"""Segment-level layout engine: registry, placement, explicit-vs-coefficient
parity, closed-form equivalence on the uniform family (incl. the Eq. 6
argmin property test), envelope-constrained family wins, and the per-lane
vs mean-lane roll-up contract."""

import math

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.design_space import (
    DesignSpace,
    evaluate_layout_design_space,
)
from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    bus_power_arr,
    optimal_aspect_power,
    optimal_aspect_power_arr,
    pe_dims_arr,
    wirelength_total,
    wirelength_total_arr,
)
from repro.layout import (
    LAYOUTS,
    LayoutPowerConfig,
    MultiPodLayout,
    SerpentineLayout,
    UniformLayout,
    enumerate_segments,
    evaluate_layout_space,
    rollup_segments,
    segment_bus_power,
    segment_class_coeffs,
    segment_wirelength,
)
from repro.layout.geometry import (
    clock_tree_coeffs,
    clock_tree_depth,
    envelope,
    htree_segments,
    layout_feasible,
    place_pes,
    register_layout,
)
from repro.layout.segments import SEGMENT_CLASS_SCHEMA

GEOM = SystolicArrayGeometry.paper_32x32()
ACT = BusActivity.paper_resnet50()


# ---------------------------------------------------------------------------
# Registry + placement
# ---------------------------------------------------------------------------


def test_registry_families():
    assert isinstance(LAYOUTS["uniform"], UniformLayout)
    assert isinstance(LAYOUTS["serpentine2"], SerpentineLayout)
    assert isinstance(LAYOUTS["pods4x4"], MultiPodLayout)
    register_layout("serpentine8", SerpentineLayout(folds=8))
    try:
        assert LAYOUTS["serpentine8"].folds == 8
    finally:
        del LAYOUTS["serpentine8"]
    with pytest.raises(TypeError):
        register_layout("bad", object())
    with pytest.raises(ValueError):
        SerpentineLayout(folds=1)
    # k=1 is the legal degenerate single-pod case (== uniform); k=0 is not
    assert isinstance(MultiPodLayout(k=1), MultiPodLayout)
    with pytest.raises(ValueError):
        MultiPodLayout(k=0)


def test_feasibility_divisibility():
    assert layout_feasible(LAYOUTS["serpentine2"], 8, 10)
    assert not layout_feasible(LAYOUTS["serpentine2"], 8, 9)
    assert not layout_feasible(LAYOUTS["pods2x2"], 7, 8)
    got = layout_feasible(LAYOUTS["pods4x4"], np.asarray([8, 9]), np.asarray([8, 8]))
    assert got.tolist() == [True, False]
    with pytest.raises(ValueError):
        place_pes(LAYOUTS["serpentine2"], 4, 9, 10.0, 10.0)


def test_serpentine_placement_folds_and_turnarounds():
    rows, cols, f, w, h = 4, 8, 2, 10.0, 20.0
    x, y = place_pes(SerpentineLayout(folds=f), rows, cols, w, h)
    # band 0 left-to-right, band 1 mirrored; fold boundary x-aligned
    assert x[0, :4].tolist() == [0.0, 10.0, 20.0, 30.0]
    assert x[0, 4:].tolist() == [30.0, 20.0, 10.0, 0.0]
    assert (y[:, 4] - y[:, 3] == rows * h).all()
    assert envelope(SerpentineLayout(folds=f), rows, cols, w, h) == (
        (cols / f) * w,
        f * rows * h,
    )
    segs = enumerate_segments("serpentine2", rows, cols, 8, 20, 200.0, 1.0)
    turns = segs.select((segs.net == "h") & (segs.kind == "turn"))
    assert turns.n_segments == rows * (f - 1)
    hpe = float(pe_dims_arr(200.0, 1.0, xp=np)[1])
    np.testing.assert_allclose(turns.length, rows * hpe)


def test_multipod_placement_gutters_and_widths():
    rows = cols = 8
    lay = MultiPodLayout(k=2, gutter_um=30.0)
    register_layout("podstest", lay)
    try:
        w, h = (float(v) for v in pe_dims_arr(400.0, 1.0, xp=np))
        x, y = place_pes(lay, rows, cols, w, h)
        assert x[0, 4] - x[0, 3] == pytest.approx(w + 30.0)
        assert y[4, 0] - y[3, 0] == pytest.approx(h + 30.0)
        segs = enumerate_segments("podstest", rows, cols, 16, 37, 400.0, 1.0)
        v = segs.for_net("v")
        trunks = v.select(v.kind == "trunk")
        assert trunks.n_segments == cols * (lay.k - 1)
        np.testing.assert_allclose(trunks.length, h + 30.0)
        assert (trunks.width == 37).all()
        interior = v.select(v.kind == "hop")
        # pod-local accumulator: 2*16 + ceil(log2 4) = 34 bits
        assert (interior.width == 34).all()
        # OS: no pod narrowing (v is an operand stream)
        segs_os = enumerate_segments("podstest", rows, cols, 16, 16, 400.0, 1.0,
                                     dataflow="OS")
        v_os = segs_os.for_net("v")
        assert (v_os.width == 16).all()
        assert segs_os.for_net("drain").n_segments == rows * cols
        assert segs.for_net("preload").n_segments == rows * cols
        assert segs_os.for_net("preload").n_segments == 0
    finally:
        del LAYOUTS["podstest"]


def test_htree_total_length_matches_coeffs():
    for depth in (1, 2, 5, 8):
        segs = htree_segments(0.0, 0.0, 120.0, 70.0, depth)
        assert len(segs) == 2**depth - 1
        tot = sum(abs(x1 - x0) + abs(y1 - y0) for x0, y0, x1, y1 in segs)
        cw, ch = clock_tree_coeffs(depth)
        assert tot == pytest.approx(float(cw) * 120.0 + float(ch) * 70.0)
    assert int(clock_tree_depth(1024)) == 10
    assert int(clock_tree_depth(1025)) == 11


# ---------------------------------------------------------------------------
# Explicit enumeration vs class coefficients (per family, per dataflow)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(LAYOUTS))
@pytest.mark.parametrize("dataflow", ["WS", "OS"])
def test_explicit_matches_class_coeffs(name, dataflow):
    rows, cols, b_h = 16, 32, 16
    b_v = 37 if dataflow == "WS" else 16
    aspect = 2.7
    segs = enumerate_segments(name, rows, cols, b_h, b_v, 1200.0, aspect,
                              dataflow=dataflow)
    cc = segment_class_coeffs(
        name,
        np.asarray([float(rows)]),
        np.asarray([float(cols)]),
        np.asarray([float(b_h)]),
        np.asarray([float(b_v)]),
        np.asarray([dataflow == "OS"]),
    )
    w, h = pe_dims_arr(1200.0, aspect, xp=np)
    ln = cc["len_w"] * w + cc["len_h"] * h + cc["len_c"]
    for net in ("h", "v", "preload", "drain", "clk"):
        mask = np.asarray([n == net for n, _ in SEGMENT_CLASS_SCHEMA])
        tot_c = float((cc["count"][mask, 0] * ln[mask, 0]).sum())
        wl_c = float((cc["count"][mask, 0] * ln[mask, 0] * cc["width"][mask, 0]).sum())
        s = segs.for_net(net)
        np.testing.assert_allclose(tot_c, s.length.sum(), rtol=1e-9)
        np.testing.assert_allclose(wl_c, (s.length * s.width).sum(), rtol=1e-9)


# ---------------------------------------------------------------------------
# Closed-form equivalence on the uniform family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("aspect", [0.25, 1.0, 3.8, 9.0])
def test_uniform_reduces_to_closed_form(aspect):
    assert segment_wirelength("uniform", GEOM, aspect) == pytest.approx(
        wirelength_total(GEOM, aspect), rel=1e-12
    )
    assert segment_bus_power("uniform", GEOM, ACT, aspect) == pytest.approx(
        bus_power(GEOM, ACT, aspect), rel=1e-12
    )


def test_uniform_segment_counts_are_eq12():
    segs = enumerate_segments("uniform", 32, 32, 16, 37, 1200.0, 1.0, nets=("h", "v"))
    h = segs.for_net("h")
    v = segs.for_net("v")
    assert h.n_segments == 32 * 32 and v.n_segments == 32 * 32
    w, hh = pe_dims_arr(1200.0, 1.0, xp=np)
    np.testing.assert_allclose(h.length, float(w))
    np.testing.assert_allclose(v.length, float(hh))


@settings(deadline=None, max_examples=30)
@given(
    st.integers(2, 64),
    st.integers(2, 64),
    st.integers(2, 24),
    st.integers(2, 48),
    st.floats(0.01, 1.0),
    st.floats(0.01, 1.0),
)
def test_uniform_segment_argmin_matches_eq6(rows, cols, b_h, b_v, a_h, a_v):
    """Property (satellite): on the uniform family the segment-level optimal
    aspect equals the envelope-clamped Eq. 6 closed form, across random
    geometry and activities."""
    space = DesignSpace(rows=(rows,), cols=(cols,), input_bits=(8,))
    grid = space.expand()
    # overwrite the derived widths with the drawn ones (the engine only
    # reads the grid's struct-of-arrays fields)
    object.__setattr__(grid, "b_h", np.asarray([b_h], np.int64))
    object.__setattr__(grid, "b_v", np.asarray([b_v], np.int64))
    object.__setattr__(grid, "b_v_data", np.asarray([b_v], np.int64))
    ev = evaluate_layout_space(
        grid, float(a_h), float(a_v), layouts=("uniform",), use_jit=False
    )
    want = optimal_aspect_power(
        SystolicArrayGeometry(rows=rows, cols=cols, b_h=b_h, b_v=b_v),
        BusActivity(a_h=a_h, a_v=a_v),
    )
    assert math.log(float(ev.aspect_opt[0, 0, 0])) == pytest.approx(
        math.log(want), abs=1e-6
    )
    p_cf = bus_power(
        SystolicArrayGeometry(rows=rows, cols=cols, b_h=b_h, b_v=b_v),
        BusActivity(a_h=a_h, a_v=a_v),
        want,
    )
    assert float(ev.bus_power_opt[0, 0, 0]) == pytest.approx(p_cf, rel=1e-9)


# ---------------------------------------------------------------------------
# Batched evaluator
# ---------------------------------------------------------------------------


def _grid_and_acts():
    space = DesignSpace(
        rows=(8, 16), cols=(16, 32), input_bits=(8, 16), dataflows=("WS", "OS")
    )
    grid = space.expand()
    rng = np.random.default_rng(0)
    return grid, rng.uniform(0.1, 0.4, (3, grid.n_points)), rng.uniform(
        0.2, 0.6, (3, grid.n_points)
    )


def test_evaluator_uniform_matches_closed_forms_across_grid():
    grid, a_h, a_v = _grid_and_acts()
    ev = evaluate_layout_space(grid, a_h, a_v, layouts=("uniform",), use_jit=False)
    opt = optimal_aspect_power_arr(grid.b_h, grid.b_v, a_h, a_v)
    p = bus_power_arr(
        grid.rows, grid.cols, grid.b_h, grid.b_v, grid.pe_area_um2, a_h, a_v, opt
    )
    np.testing.assert_allclose(ev.aspect_opt[:, 0, :], opt, rtol=1e-6)
    np.testing.assert_allclose(ev.bus_power_opt[:, 0, :], p, rtol=1e-9)
    wl = wirelength_total_arr(
        grid.rows, grid.cols, grid.b_h, grid.b_v, grid.pe_area_um2, ev.aspect_robust[0]
    )
    np.testing.assert_allclose(ev.wirelength_um[0], wl, rtol=1e-9)
    assert ev.feasible.all()
    assert np.isfinite(ev.overhead_w).all() and (ev.overhead_w > 0).all()


def test_evaluator_jit_matches_numpy_path():
    pytest.importorskip("jax")
    grid, a_h, a_v = _grid_and_acts()
    kw = dict(layouts=("uniform", "serpentine2", "pods2x2"))
    ev_np = evaluate_layout_space(grid, a_h, a_v, use_jit=False, **kw)
    ev_j = evaluate_layout_space(grid, a_h, a_v, use_jit=True, **kw)
    # aspects sit in a flat basin (the float32 argmin wobbles ~1e-3); power
    # sums accumulate float32 rounding across segment classes (~3e-4).
    tol = {"aspect_robust": 5e-3}
    for f in ("aspect_robust", "bus_power_robust", "overhead_w", "wirelength_um"):
        a = getattr(ev_np, f)
        b = getattr(ev_j, f)
        ok = np.isfinite(a)
        np.testing.assert_allclose(b[ok], a[ok], rtol=tol.get(f, 1e-3))
        assert (np.isfinite(b) == ok).all()


def test_infeasible_family_points_are_inf():
    space = DesignSpace(rows=(6,), cols=(9,), input_bits=(8,))
    ev = evaluate_layout_space(
        space.expand(), 0.2, 0.4, layouts=("uniform", "pods4x4"), use_jit=False
    )
    assert ev.feasible[0, 0] and not ev.feasible[1, 0]
    assert np.isinf(ev.bus_power_robust[1, 0])
    assert ev.best_layout_name(0) == "uniform"


def test_envelope_limit_flips_winner_to_serpentine():
    """The result the closed form cannot express: under a die-envelope
    constraint an elongated array's Eq. 6 optimum is unreachable for the
    uniform rectangle, and folding wins."""
    space = DesignSpace(rows=(8,), cols=(128,), input_bits=(16,))
    grid = space.expand()
    free = evaluate_layout_space(
        grid, 0.22, 0.36, layouts=("uniform", "serpentine4"), use_jit=False
    )
    # unconstrained: folding only adds turnaround wire -> uniform's BUS power
    # wins (its clock spine may still lose: the folded envelope is squarer)
    assert float(free.bus_power_robust[0, 0]) < float(free.bus_power_robust[1, 0])
    boxed = evaluate_layout_space(
        grid,
        0.22,
        0.36,
        layouts=("uniform", "serpentine4"),
        cfg=LayoutPowerConfig(max_envelope_aspect=4.0),
        use_jit=False,
    )
    assert boxed.best_layout_name(0) == "serpentine4"
    assert float(boxed.bus_power_robust[1, 0]) < 0.75 * float(
        boxed.bus_power_robust[0, 0]
    )
    # the uniform family got clamped to C/R * aspect <= 4
    assert float(boxed.aspect_hi[0, 0]) == pytest.approx(4.0 * 8 / 128)


def test_zero_gutter_pods_still_classify_boundaries():
    """Boundary hops are classified by logical index: a zero-width gutter
    still crosses a pod boundary and must carry the full trunk width."""
    register_layout("pods0g", MultiPodLayout(k=2, gutter_um=0.0))
    try:
        segs = enumerate_segments("pods0g", 8, 8, 16, 37, 400.0, 1.0, nets=("v",))
        trunks = segs.select(segs.kind == "trunk")
        assert trunks.n_segments == 8 * (2 - 1)
        assert (trunks.width == 37).all()
        cc = segment_class_coeffs(
            "pods0g",
            np.asarray([8.0]),
            np.asarray([8.0]),
            np.asarray([16.0]),
            np.asarray([37.0]),
            np.asarray([False]),
        )
        w, h = pe_dims_arr(400.0, 1.0, xp=np)
        ln = cc["len_w"] * w + cc["len_h"] * h + cc["len_c"]
        mask = np.asarray([n == "v" for n, _ in SEGMENT_CLASS_SCHEMA])
        wl_c = float((cc["count"][mask, 0] * ln[mask, 0] * cc["width"][mask, 0]).sum())
        v = segs.for_net("v")
        np.testing.assert_allclose(wl_c, (v.length * v.width).sum(), rtol=1e-9)
    finally:
        del LAYOUTS["pods0g"]


def test_evaluate_layout_design_space_wrapper():
    space = DesignSpace(
        rows=(8,), cols=(16,), input_bits=(8,), layouts=("uniform", "serpentine2")
    )
    ev = evaluate_layout_design_space(space, 0.2, 0.4, use_jit=False)
    assert ev.layouts == ("uniform", "serpentine2")
    # a bare grid does not carry the layout axis: require explicit layouts=
    with pytest.raises(ValueError, match="layouts"):
        evaluate_layout_design_space(space.expand(), 0.2, 0.4, use_jit=False)
    ev2 = evaluate_layout_design_space(
        space.expand(), 0.2, 0.4, layouts=("uniform",), use_jit=False
    )
    assert ev2.layouts == ("uniform",)
    with pytest.raises(ValueError, match="unknown layout"):
        DesignSpace(rows=(8,), cols=(8,), layouts=("nope",))
    # BI grids are priced through the lowered coding multipliers...
    bi = DesignSpace(rows=(8,), cols=(8,), bus_invert=(True,))
    ev_bi = evaluate_layout_design_space(bi, 0.2, 0.4, use_jit=False)
    assert np.isfinite(ev_bi.bus_power_robust).all()
    # ... but lane arrays describe physical (uncoded) buses, so the
    # combination is rejected.
    lanes = np.full((1, 1, 64), 0.4)
    with pytest.raises(ValueError, match="uncoded"):
        evaluate_layout_design_space(bi, 0.2, 0.4, v_lanes=lanes, use_jit=False)


# ---------------------------------------------------------------------------
# Per-lane vs mean-lane roll-up
# ---------------------------------------------------------------------------


def test_mean_lane_is_exact_on_full_width_segments():
    """The aggregate-a path == per-lane roll-up whenever every segment
    carries the whole bus (uniform family) — the documented contract of
    ``bus_switched_capacitance_arr``'s uniform-activity assumption."""
    b_h, b_v = 16, 37
    rng = np.random.default_rng(1)
    h_lanes = np.zeros(64)
    v_lanes = np.zeros(64)
    h_lanes[:b_h] = rng.uniform(0.05, 0.5, b_h)
    v_lanes[:b_v] = rng.uniform(0.05, 0.8, b_v)
    a_h = float(h_lanes[:b_h].mean())
    a_v = float(v_lanes[:b_v].mean())
    segs = enumerate_segments("uniform", 16, 16, b_h, b_v, 1200.0, 2.0, nets=("h", "v"))
    lane = rollup_segments(segs, a_h, a_v, h_lanes=h_lanes, v_lanes=v_lanes)
    mean = rollup_segments(segs, a_h, a_v)
    assert lane["bus_w"] == pytest.approx(mean["bus_w"], rel=1e-12)
    # multi-pod interior buses carry a lane SUBSET -> the paths diverge
    segs_p = enumerate_segments("pods4x4", 16, 16, b_h, b_v, 1200.0, 2.0, nets=("h", "v"))
    lane_p = rollup_segments(segs_p, a_h, a_v, h_lanes=h_lanes, v_lanes=v_lanes)
    mean_p = rollup_segments(segs_p, a_h, a_v)
    assert lane_p["bus_w"] != pytest.approx(mean_p["bus_w"], rel=1e-6)


def test_measured_lane_activities_feed_the_evaluator():
    from repro.core.workloads import ConvLayer, measured_design_lane_activities

    space = DesignSpace(rows=(8,), cols=(8,), input_bits=(8,))
    grid = space.expand()
    layers = [ConvLayer("T1", k=1, h=6, w=6, c=32, m=24, input_density=0.5)]
    a_h, a_v, h_lanes, v_lanes = measured_design_lane_activities(grid, layers)
    assert h_lanes.shape == (1, 1, 64) and v_lanes.shape == (1, 1, 64)
    # lane means reproduce the aggregates
    np.testing.assert_allclose(h_lanes.sum(-1), a_h * grid.b_h[None, :])
    np.testing.assert_allclose(v_lanes.sum(-1), a_v * grid.b_v[None, :])
    ev = evaluate_layout_space(
        grid, a_h, a_v, layouts=("uniform", "pods2x2"),
        h_lanes=h_lanes, v_lanes=v_lanes, use_jit=False,
    )
    assert np.isfinite(ev.bus_power_robust).all()


def test_repeater_scaling_prices_long_segments_only():
    cfg = LayoutPowerConfig()
    segs = enumerate_segments("serpentine2", 32, 16, 16, 37, 1200.0, 1.0,
                              nets=("h", "v"))
    turns = segs.select(segs.kind == "turn")
    assert (turns.length > cfg.repeater_spacing_um).all()
    hops = segs.select(segs.kind == "hop")
    assert (hops.length < cfg.repeater_spacing_um).all()
    # power with repeater overhead zeroed is strictly lower on serpentine...
    p_rep = rollup_segments(segs, ACT.a_h, ACT.a_v, cfg=cfg)["bus_w"]
    cfg0 = LayoutPowerConfig(repeater_overhead=0.0)
    p_no = rollup_segments(segs, ACT.a_h, ACT.a_v, cfg=cfg0)["bus_w"]
    assert p_rep > p_no
    # ...and identical on uniform (every hop under the spacing -> exact 1.0)
    u = enumerate_segments("uniform", 32, 16, 16, 37, 1200.0, 1.0, nets=("h", "v"))
    assert rollup_segments(u, ACT.a_h, ACT.a_v, cfg=cfg)["bus_w"] == pytest.approx(
        rollup_segments(u, ACT.a_h, ACT.a_v, cfg=cfg0)["bus_w"], rel=1e-12
    )


def test_overhead_nets_default_off_and_priceable():
    segs = enumerate_segments("uniform", 8, 8, 16, 37, 1200.0, 1.0)
    base = rollup_segments(segs, 0.2, 0.4)
    assert base["preload"] == 0.0
    cfg = LayoutPowerConfig(preload_duty=0.05)
    assert rollup_segments(segs, 0.2, 0.4, cfg=cfg)["preload"] > 0.0
    assert base["clk"] > 0.0  # the spine always burns
    assert base["total_w"] == pytest.approx(base["bus_w"] + base["overhead_w"])
