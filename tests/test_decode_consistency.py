"""Decode-vs-forward consistency: prefilling a cache token-by-token and the
full-sequence forward must produce identical next-token logits — the
serving path is exact, not an approximation. Covers attention (GQA), SWA
ring buffer, MoE, Mamba, and xLSTM state caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import model

CASES = ["yi_6b", "mixtral_8x7b", "jamba_v01_52b", "xlstm_1p3b", "qwen2_vl_7b"]


def _no_drop(cfg):
    """Forward==decode requires no capacity drops on the forward side (decode
    is dropless by construction); give the training path worst-case capacity."""
    if cfg.num_experts > 1:
        return dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.mark.parametrize("arch", CASES)
def test_stepwise_decode_matches_forward(arch):
    cfg = _no_drop(get_arch(arch).reduced())
    key = jax.random.PRNGKey(0)
    params, _ = model.init_params(cfg, key)
    b, s = 2, 12
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=jnp.int32)

    # full forward logits at the last position
    logits_fwd, _ = model.forward(cfg, params, toks)
    last_fwd = logits_fwd[:, -1]

    # token-by-token decode through the cache
    cache, _ = model.init_cache(cfg, b, s)
    logits_dec = None
    for t in range(s):
        tok_t = toks[:, t : t + 1]
        logits_dec, cache = model.decode_step(cfg, params, cache, tok_t, jnp.int32(t))

    np.testing.assert_allclose(
        np.asarray(last_fwd, np.float32),
        np.asarray(logits_dec, np.float32),
        rtol=2e-3,
        atol=2e-3,
    )


def test_swa_ring_buffer_evicts_old_tokens():
    """With window w, decoding past w positions must only attend to the last
    w tokens — verified against a forward pass over the suffix window."""
    cfg = _no_drop(get_arch("mixtral_8x7b").reduced())  # window = 16
    w = cfg.window
    key = jax.random.PRNGKey(1)
    params, _ = model.init_params(cfg, key)
    b, s = 1, 24  # > window
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)

    cache, _ = model.init_cache(cfg, b, s)  # cache_len = window
    assert cache["block0"]["k"].shape[3] == w
    logits_dec = None
    for t in range(s):
        logits_dec, cache = model.decode_step(
            cfg, params, cache, toks[:, t : t + 1], jnp.int32(t)
        )

    logits_fwd, _ = model.forward(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(logits_fwd[:, -1], np.float32),
        np.asarray(logits_dec, np.float32),
        rtol=2e-3,
        atol=2e-3,
    )


def test_prefill_with_cache_matches_forward():
    cfg = get_arch("yi_6b").reduced()
    key = jax.random.PRNGKey(2)
    params, _ = model.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size, dtype=jnp.int32)
    last, cache = model.prefill_with_cache(cfg, params, toks)
    logits_fwd, _ = model.forward(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(logits_fwd[:, -1], np.float32),
        np.asarray(last, np.float32),
        rtol=2e-3,
        atol=2e-3,
    )
