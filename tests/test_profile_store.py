"""Crash-safe on-disk profile store: roundtrip (incl. lane-resolved
profiles), atomic-write crash safety, integrity quarantine + recompute,
LRU-by-mtime size bounding, the layered memory -> store -> compute lookup in
``core.switching``, and the in-memory cache capacity/eviction/thrash
satellites."""

import json
import os

import numpy as np
import pytest

from repro.core.profile_store import STORE_VERSION, ProfileStore
from repro.core.switching import (
    ActivityProfile,
    CacheThrashWarning,
    clear_profile_cache,
    configure_profile_store,
    profile_cache_info,
    profile_gemm,
    profile_store_info,
    set_profile_cache_capacity,
)
from repro.runtime import faults
from repro.runtime.resilience import ContractViolationError

RNG = np.random.default_rng(7)


@pytest.fixture(autouse=True)
def _pin_faults():
    """These tests assert exact store hit/corruption behavior: shield them
    from env-armed chaos injection (the chaos CI job sets $REPRO_FAULTS for
    the whole suite); tests inject their own faults explicitly."""
    with faults.injected([]):
        yield


@pytest.fixture
def store(tmp_path):
    return ProfileStore(tmp_path / "store")


@pytest.fixture
def switching_store(tmp_path):
    """Wire the layered cache to a temp store; restore store-off after."""
    clear_profile_cache()
    store = configure_profile_store(tmp_path / "store")
    yield store
    configure_profile_store(None)
    clear_profile_cache()


def _profile(**over):
    base = dict(
        a_h=0.25,
        a_v=0.5,
        b_h=16,
        b_v=37,
        h_transitions=1200,
        v_transitions=3400,
        input_zero_fraction=0.125,
        input_elements=512,
    )
    base.update(over)
    return ActivityProfile(**base)


def _rand_gemm(m, k, n):
    return (
        RNG.integers(0, 100, size=(m, k)),
        RNG.integers(0, 100, size=(k, n)),
    )


def test_store_roundtrip_exact(store):
    key = bytes(range(32))
    assert store.get(key) is None
    p = _profile()
    assert store.put(key, p)
    got = store.get(key)
    assert got == p
    assert store.stats["hits"] == 1 and store.stats["misses"] == 1
    assert store.entry_path(key).startswith(
        os.path.join(store.root, STORE_VERSION)
    )


def test_store_roundtrip_lane_detail(store):
    """Per-lane tuples survive the JSON encode/decode as tuples of int."""
    p = _profile(
        h_lane_toggles=tuple(int(x) for x in range(16)),
        v_lane_toggles=tuple(int(x) * 3 for x in range(37)),
    )
    key = b"\x42" * 32
    store.put(key, p)
    got = store.get(key)
    assert got == p
    assert isinstance(got.h_lane_toggles, tuple)
    assert got.a_h_lanes is not None


def test_store_corruption_quarantined_not_crashed(store):
    key = b"\x01" * 32
    store.put(key, _profile())
    path = store.entry_path(key)
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0x10  # flip one payload bit
    open(path, "wb").write(bytes(raw))

    assert store.get(key) is None  # miss, not an exception
    assert store.stats["integrity_failures"] == 1
    assert not os.path.exists(path)  # moved aside
    assert len(store.quarantined()) == 1
    assert store.drain_quarantine_events() == [key.hex()]
    assert store.drain_quarantine_events() == []  # drained once

    # recompute-and-overwrite path: a fresh put fully heals the key
    store.put(key, _profile())
    assert store.get(key) == _profile()


def test_store_version_mismatch_is_quarantined(store):
    key = b"\x02" * 32
    store.put(key, _profile())
    path = store.entry_path(key)
    doc = json.load(open(path))
    doc["v"] = "v0"
    json.dump(doc, open(path, "w"))
    assert store.get(key) is None
    assert store.stats["integrity_failures"] == 1


def test_store_put_is_atomic_wrt_crash(store, tmp_path):
    """A writer killed mid-write must leave the old entry intact.

    Simulated by doing exactly what an interrupted ``put`` leaves behind: a
    half-written temp file, with no ``os.replace``."""
    key = b"\x03" * 32
    store.put(key, _profile(a_h=0.1))
    # fake a crashed writer: partial bytes in the temp-file namespace
    tmp = os.path.join(store.root, STORE_VERSION, ".tmp-99999-deadbeef")
    with open(tmp, "wb") as f:
        f.write(b'{"v": "v4", "sha256": "tru')  # torn write
    # the live entry is untouched and verifies
    assert store.get(key) == _profile(a_h=0.1)
    # the next size scan sweeps the stray temp file
    store._scan()
    assert not os.path.exists(tmp)


def test_store_eviction_is_lru_by_mtime(tmp_path):
    keys = [bytes([i]) * 32 for i in range(4)]
    big = ProfileStore(tmp_path / "s2", max_bytes=1 << 20)
    for i, k in enumerate(keys):
        big.put(k, _profile())
        os.utime(big.entry_path(k), (1000 + i, 1000 + i))
    entry_size = os.path.getsize(big.entry_path(keys[0]))
    big.max_bytes = entry_size * 2  # room for 2 of 4
    big._evict_if_needed()
    survivors = big.entries()
    assert len(survivors) == 2
    # the two NEWEST mtimes survive
    assert {os.path.basename(p) for p in survivors} == {
        keys[2].hex() + ".json",
        keys[3].hex() + ".json",
    }


def test_store_never_raises_on_io_failure(tmp_path):
    store = ProfileStore(tmp_path / "nope")
    # root not yet created: get is a plain miss
    assert store.get(b"\x00" * 32) is None
    # unwritable root (a regular file shadows the path — chmod tricks don't
    # bind under root): put degrades to False, counted, never raises
    blocked = tmp_path / "blocked"
    blocked.write_text("not a directory")
    ro = ProfileStore(blocked / "sub")
    assert ro.put(b"\x00" * 32, _profile()) is False
    assert ro.stats["io_errors"] == 1


# ---------------------------------------------------------------------------
# layered lookup through core.switching
# ---------------------------------------------------------------------------


def test_layered_lookup_memory_then_store_then_compute(switching_store):
    a, w = _rand_gemm(32, 16, 8)
    p1 = profile_gemm(a, w, 16, 8, 16, 37)
    assert switching_store.stats["puts"] == 1  # computed -> persisted
    # memory hit: store untouched
    p2 = profile_gemm(a, w, 16, 8, 16, 37)
    assert p2 is p1
    assert switching_store.stats["hits"] == 0
    # cold memory, warm disk: served from the store, promoted to memory
    clear_profile_cache()
    p3 = profile_gemm(a, w, 16, 8, 16, 37)
    assert p3 == p1
    assert switching_store.stats["hits"] == 1
    assert profile_cache_info()["store_hits"] == 1
    assert switching_store.stats["puts"] == 1  # promotion does NOT re-write
    p4 = profile_gemm(a, w, 16, 8, 16, 37)
    assert p4 is p3  # now a memory hit again
    info = profile_store_info()
    assert info is not None and info["entries"] == 1


def test_layered_lookup_corrupted_entry_recomputes(switching_store):
    a, w = _rand_gemm(32, 16, 8)
    expect = profile_gemm(a, w, 16, 8, 16, 37)
    clear_profile_cache()
    with faults.injected([faults.FaultSpec("bitflip", rate=1.0)], seed=3):
        got = profile_gemm(a, w, 16, 8, 16, 37)
    assert got == expect  # bit-exact recompute, no crash
    assert switching_store.stats["integrity_failures"] == 1
    assert len(switching_store.quarantined()) == 1
    # the recompute overwrote the quarantined key: next cold read verifies
    clear_profile_cache()
    assert profile_gemm(a, w, 16, 8, 16, 37) == expect
    assert switching_store.stats["integrity_failures"] == 1  # no new failure


def test_store_disabled_is_the_old_memory_only_cache(tmp_path):
    clear_profile_cache()
    configure_profile_store(None)
    a, w = _rand_gemm(16, 8, 4)
    profile_gemm(a, w, 8, 8, 16, 37)
    clear_profile_cache()
    profile_gemm(a, w, 8, 8, 16, 37)
    assert profile_cache_info()["store_hits"] == 0
    assert profile_store_info() is None


# ---------------------------------------------------------------------------
# in-memory cache capacity / eviction / thrash satellites
# ---------------------------------------------------------------------------


def test_cache_capacity_kwarg_and_evictions_counter():
    clear_profile_cache()
    prev = set_profile_cache_capacity(2)
    try:
        gemms = [_rand_gemm(16, 8, 4) for _ in range(3)]
        for a, w in gemms:
            profile_gemm(a, w, 8, 8, 16, 37)
        info = profile_cache_info()
        assert info["capacity"] == 2
        assert info["size"] == 2
        assert info["evictions"] == 1
        # oldest entry was evicted: re-profiling it misses
        profile_gemm(*gemms[0], 8, 8, 16, 37)
        assert profile_cache_info()["misses"] == 4
        # shrinking below the live size evicts immediately
        set_profile_cache_capacity(1)
        assert profile_cache_info()["size"] == 1
        with pytest.raises(ContractViolationError):
            set_profile_cache_capacity(0)
    finally:
        set_profile_cache_capacity(prev)
        clear_profile_cache()


def test_cache_capacity_env_override(tmp_path):
    import subprocess
    import sys

    code = (
        "from repro.core.switching import profile_cache_info;"
        "print(profile_cache_info()['capacity'])"
    )
    env = dict(os.environ, REPRO_PROFILE_CACHE_CAPACITY="7")
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert out.stdout.strip() == "7", out.stderr


def test_cache_thrash_warning_fires_once_per_overflowing_batch():
    from repro.core.pipeline import ProfileJob, run_profile_batch

    clear_profile_cache()
    prev = set_profile_cache_capacity(2)
    try:
        jobs = [
            ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w)
            for a, w in (_rand_gemm(16, 8, 4) for _ in range(4))
        ]
        with pytest.warns(CacheThrashWarning, match="stored 4 profiles"):
            run_profile_batch(jobs, engine="xla")
        # one-shot: the same overflow again stays quiet until cache reset
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error", CacheThrashWarning)
            run_profile_batch(jobs, engine="xla")
    finally:
        set_profile_cache_capacity(prev)
        clear_profile_cache()
