"""Beyond-paper design-space extensions: robust design points, OS dataflow,
bus-invert coding."""

import math

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    optimal_aspect_power,
)
from repro.core.optimize import (
    bus_invert_activity,
    bus_invert_activity_arr,
    bus_invert_geometry,
    max_regret,
    max_regret_arr,
    minimax_aspect_arr,
    os_dataflow_geometry,
    robust_design_point,
)
from repro.core.switching import ActivityProfile

GEOM = SystolicArrayGeometry.paper_32x32()


def _profile(a_h, a_v, weight=1000):
    return ActivityProfile(
        a_h=a_h, a_v=a_v, b_h=GEOM.b_h, b_v=GEOM.b_v,
        h_transitions=weight, v_transitions=weight, input_zero_fraction=0.5,
    )


PROFILES = [_profile(0.15, 0.30), _profile(0.25, 0.40), _profile(0.35, 0.45)]


def test_average_strategy_matches_paper_method():
    d = robust_design_point(GEOM, PROFILES, "average")
    # transition-weighted mean == plain mean here (equal weights)
    mean = BusActivity(a_h=np.mean([0.15, 0.25, 0.35]), a_v=np.mean([0.30, 0.40, 0.45]))
    assert d == pytest.approx(optimal_aspect_power(GEOM, mean), rel=1e-9)


def test_weighted_strategy_tracks_dominant_workload():
    d_all = robust_design_point(GEOM, PROFILES, "weighted", weights=[1, 1, 1])
    d_first = robust_design_point(GEOM, PROFILES, "weighted", weights=[100, 0.01, 0.01])
    own_first = optimal_aspect_power(GEOM, PROFILES[0].as_bus_activity())
    assert abs(d_first - own_first) < abs(d_all - own_first)


def test_minimax_bounds_worst_case_regret():
    acts = [p.as_bus_activity() for p in PROFILES]
    d_avg = robust_design_point(GEOM, PROFILES, "average")
    d_mm = robust_design_point(GEOM, PROFILES, "minimax")
    assert max_regret(GEOM, acts, d_mm) <= max_regret(GEOM, acts, d_avg) + 1e-9
    # cross-check against a dense grid: golden-section must be at least as
    # good as the best grid point (the objective is convex in log-aspect)
    grid = np.exp(np.linspace(np.log(1 / 64), np.log(64), 4001))
    grid_best = min(max_regret(GEOM, acts, float(a)) for a in grid)
    assert max_regret(GEOM, acts, d_mm) <= grid_best + 1e-7


def test_os_dataflow_prefers_square():
    """OS: equal bus widths; with equal stream activities, W/H* == 1 — the
    paper's asymmetry is specific to the weight-stationary dataflow."""
    geom = os_dataflow_geometry(16, 32, 32)
    assert geom.b_h == geom.b_v == 16
    act = BusActivity(a_h=0.3, a_v=0.3)
    assert optimal_aspect_power(geom, act) == pytest.approx(1.0)


@settings(deadline=None, max_examples=40)
@given(a=st.floats(0.01, 0.99), bits=st.integers(4, 48))
def test_bus_invert_never_increases_activity(a, bits):
    coded = bus_invert_activity(a, bits)
    # BI toggles at most (b+1)/2 wires and at most the uncoded count
    assert coded <= 0.5 + 1e-9
    assert coded <= a * bits / (bits + 1) + 1e-9 or coded <= a + 1e-9


def test_bus_invert_known_limits():
    # a -> 0: coding overhead vanishes; a = 0.5 on a wide bus: ~ sqrt saving
    assert bus_invert_activity(0.0, 16) == 0.0
    assert bus_invert_activity(0.5, 32) < 0.5
    # exact small case: b=1, a=0.5 -> d in {0,1} equally; min(d, 2-d) in {0,1}
    # -> E = 0.5 over 2 wires = 0.25
    assert bus_invert_activity(0.5, 1) == pytest.approx(0.25)


def test_bus_invert_endpoints_exact():
    """a=0: nothing toggles; a=1: every data line would flip every cycle, so
    BI always sends the inverted word — only the invert line toggles."""
    for bits in (1, 4, 16, 37, 64):
        assert bus_invert_activity(0.0, bits) == 0.0
        assert bus_invert_activity(1.0, bits) == pytest.approx(1.0 / (bits + 1))


@settings(deadline=None, max_examples=60)
@given(a=st.floats(0.0, 1.0), bits=st.integers(1, 64))
def test_bus_invert_invariant_coded_at_most_uncoded(a, bits):
    """E[min(d, b+1-d)]/(b+1) <= a: BI coding never raises the activity."""
    coded = bus_invert_activity(a, bits)
    assert 0.0 <= coded <= a + 1e-12


def test_bus_invert_stable_near_one():
    """The naive pmf recurrence seeds with (1-a)**b == 0.0 for a near 1 and
    returns exactly 0; the log-space form stays finite and approaches the
    exact a=1 limit 1/(b+1) from above-zero."""
    for bits in (16, 37, 48, 64):
        coded = bus_invert_activity(1.0 - 1e-12, bits)
        assert coded > 0.0
        assert coded == pytest.approx(1.0 / (bits + 1), rel=1e-3)
    # monotone tail: approaching 1 converges smoothly to the endpoint
    vals = [bus_invert_activity(a, 37) for a in (0.99, 0.999, 0.9999, 1.0)]
    assert all(v > 0 for v in vals)
    assert abs(vals[-2] - vals[-1]) < 1e-3


def test_bus_invert_vectorized_matches_scalar():
    a = np.linspace(0.0, 1.0, 23)
    bits = np.asarray([1, 7, 16, 37, 64])
    vec = bus_invert_activity_arr(a[:, None], bits[None, :])
    for i, ai in enumerate(a):
        for j, bj in enumerate(bits):
            assert float(vec[i, j]) == bus_invert_activity(float(ai), int(bj))


def test_vectorized_minimax_matches_scalar_robust_point():
    acts = [p.as_bus_activity() for p in PROFILES]
    a_h = np.asarray([a.a_h for a in acts])
    a_v = np.asarray([a.a_v for a in acts])
    d_scalar = robust_design_point(GEOM, PROFILES, "minimax")
    d_vec = float(minimax_aspect_arr(GEOM.b_h, GEOM.b_v, a_h, a_v, iters=80))
    # compare achieved objectives (the regret curve is flat near the optimum)
    mr_s = max_regret(GEOM, acts, d_scalar)
    mr_v = float(max_regret_arr(GEOM.b_h, GEOM.b_v, a_h, a_v, d_vec))
    assert mr_v == pytest.approx(mr_s, rel=1e-6, abs=1e-9)
    # batched: stacking the same point twice returns the same aspect twice
    both = minimax_aspect_arr(
        GEOM.b_h, GEOM.b_v, np.stack([a_h, a_h], -1), np.stack([a_v, a_v], -1), iters=80
    )
    assert np.allclose(both, d_vec)


def test_bus_invert_composes_with_floorplan():
    """BI on the vertical bus lowers a_v -> smaller optimal W/H, and the
    combined (BI + asym) power beats either alone."""
    act = BusActivity.paper_resnet50()
    geom2, act2 = bus_invert_geometry(GEOM, act)
    assert geom2.b_v == GEOM.b_v + 1
    assert act2.a_v < act.a_v
    opt_plain = optimal_aspect_power(GEOM, act)
    opt_coded = optimal_aspect_power(geom2, act2)
    assert opt_coded < opt_plain
    p_asym_only = bus_power(GEOM, act, opt_plain)
    p_both = bus_power(geom2, act2, opt_coded)
    assert p_both < p_asym_only
