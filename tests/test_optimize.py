"""Beyond-paper design-space extensions: robust design points, OS dataflow,
bus-invert coding."""

import math

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    optimal_aspect_power,
)
from repro.core.optimize import (
    bus_invert_activity,
    bus_invert_geometry,
    max_regret,
    os_dataflow_geometry,
    robust_design_point,
)
from repro.core.switching import ActivityProfile

GEOM = SystolicArrayGeometry.paper_32x32()


def _profile(a_h, a_v, weight=1000):
    return ActivityProfile(
        a_h=a_h, a_v=a_v, b_h=GEOM.b_h, b_v=GEOM.b_v,
        h_transitions=weight, v_transitions=weight, input_zero_fraction=0.5,
    )


PROFILES = [_profile(0.15, 0.30), _profile(0.25, 0.40), _profile(0.35, 0.45)]


def test_average_strategy_matches_paper_method():
    d = robust_design_point(GEOM, PROFILES, "average")
    # transition-weighted mean == plain mean here (equal weights)
    mean = BusActivity(a_h=np.mean([0.15, 0.25, 0.35]), a_v=np.mean([0.30, 0.40, 0.45]))
    assert d == pytest.approx(optimal_aspect_power(GEOM, mean), rel=1e-9)


def test_weighted_strategy_tracks_dominant_workload():
    d_all = robust_design_point(GEOM, PROFILES, "weighted", weights=[1, 1, 1])
    d_first = robust_design_point(GEOM, PROFILES, "weighted", weights=[100, 0.01, 0.01])
    own_first = optimal_aspect_power(GEOM, PROFILES[0].as_bus_activity())
    assert abs(d_first - own_first) < abs(d_all - own_first)


def test_minimax_bounds_worst_case_regret():
    acts = [p.as_bus_activity() for p in PROFILES]
    d_avg = robust_design_point(GEOM, PROFILES, "average")
    d_mm = robust_design_point(GEOM, PROFILES, "minimax")
    assert max_regret(GEOM, acts, d_mm) <= max_regret(GEOM, acts, d_avg) + 1e-9
    # cross-check against a dense grid: golden-section must be at least as
    # good as the best grid point (the objective is convex in log-aspect)
    grid = np.exp(np.linspace(np.log(1 / 64), np.log(64), 4001))
    grid_best = min(max_regret(GEOM, acts, float(a)) for a in grid)
    assert max_regret(GEOM, acts, d_mm) <= grid_best + 1e-7


def test_os_dataflow_prefers_square():
    """OS: equal bus widths; with equal stream activities, W/H* == 1 — the
    paper's asymmetry is specific to the weight-stationary dataflow."""
    geom = os_dataflow_geometry(16, 32, 32)
    assert geom.b_h == geom.b_v == 16
    act = BusActivity(a_h=0.3, a_v=0.3)
    assert optimal_aspect_power(geom, act) == pytest.approx(1.0)


@settings(deadline=None, max_examples=40)
@given(a=st.floats(0.01, 0.99), bits=st.integers(4, 48))
def test_bus_invert_never_increases_activity(a, bits):
    coded = bus_invert_activity(a, bits)
    # BI toggles at most (b+1)/2 wires and at most the uncoded count
    assert coded <= 0.5 + 1e-9
    assert coded <= a * bits / (bits + 1) + 1e-9 or coded <= a + 1e-9


def test_bus_invert_known_limits():
    # a -> 0: coding overhead vanishes; a = 0.5 on a wide bus: ~ sqrt saving
    assert bus_invert_activity(0.0, 16) == 0.0
    assert bus_invert_activity(0.5, 32) < 0.5
    # exact small case: b=1, a=0.5 -> d in {0,1} equally; min(d, 2-d) in {0,1}
    # -> E = 0.5 over 2 wires = 0.25
    assert bus_invert_activity(0.5, 1) == pytest.approx(0.25)


def test_bus_invert_composes_with_floorplan():
    """BI on the vertical bus lowers a_v -> smaller optimal W/H, and the
    combined (BI + asym) power beats either alone."""
    act = BusActivity.paper_resnet50()
    geom2, act2 = bus_invert_geometry(GEOM, act)
    assert geom2.b_v == GEOM.b_v + 1
    assert act2.a_v < act.a_v
    opt_plain = optimal_aspect_power(GEOM, act)
    opt_coded = optimal_aspect_power(geom2, act2)
    assert opt_coded < opt_plain
    p_asym_only = bus_power(GEOM, act, opt_plain)
    p_both = bus_power(geom2, act2, opt_coded)
    assert p_both < p_asym_only
