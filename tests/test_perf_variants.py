"""Perf-lever exactness: the §Perf optimizations must not change the math.

  * chunked cross-entropy == full-logits cross-entropy (same dtype path),
  * block-level remat == stage-level remat (remat never changes values),
  * bf16 mamba state: bounded loss/grad deviation vs the f32-exact path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models import model


def _batch(cfg, key, b=2, s=32):
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=jnp.int32)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ["yi_6b", "mixtral_8x7b"])
def test_chunked_ce_matches_full(arch):
    cfg0 = get_arch(arch).reduced()
    cfg1 = dataclasses.replace(cfg0, loss_chunk=8)  # 32/8 = 4 chunks
    key = jax.random.PRNGKey(0)
    params, _ = model.init_params(cfg0, key)
    batch = _batch(cfg0, key)
    (l0, _), g0 = jax.value_and_grad(lambda p: model.loss_fn(cfg0, p, batch), has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(lambda p: model.loss_fn(cfg1, p, batch), has_aux=True)(params)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-4, atol=1e-5
        )


def test_block_remat_matches_stage_remat():
    cfg0 = get_arch("jamba_v01_52b").reduced()  # heterogeneous 8-block stage
    cfg1 = dataclasses.replace(cfg0, remat="block")
    key = jax.random.PRNGKey(1)
    params, _ = model.init_params(cfg0, key)
    batch = _batch(cfg0, key, s=16)
    (l0, _), g0 = jax.value_and_grad(lambda p: model.loss_fn(cfg0, p, batch), has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(lambda p: model.loss_fn(cfg1, p, batch), has_aux=True)(params)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
        )


def test_bf16_mamba_state_bounded_deviation():
    cfg0 = get_arch("jamba_v01_52b").reduced()
    cfg1 = dataclasses.replace(cfg0, mamba_state_dtype="bfloat16")
    key = jax.random.PRNGKey(2)
    params, _ = model.init_params(cfg0, key)
    batch = _batch(cfg0, key, s=32)
    (l0, _), g0 = jax.value_and_grad(lambda p: model.loss_fn(cfg0, p, batch), has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(lambda p: model.loss_fn(cfg1, p, batch), has_aux=True)(params)
    # bf16 state is an approximation: require <1% loss deviation and bounded
    # relative grad-norm deviation (the §Perf log records the measured value)
    assert float(l1) == pytest.approx(float(l0), rel=1e-2)
    n0 = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(g0)))
    n1 = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(g1)))
    assert n1 == pytest.approx(n0, rel=0.05)
