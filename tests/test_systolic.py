"""Systolic functional + timing models under both dataflows."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.systolic import (
    DATAFLOWS,
    matmul_reference,
    os_matmul_reference,
    os_tile_cycles,
    schedule_gemm,
    schedule_many,
    ws_matmul_reference,
    ws_tile_cycles,
)


@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    rows=st.integers(1, 16),
    cols=st.integers(1, 16),
)
def test_ws_tiled_execution_exact(m, k, n, rows, cols):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.integers(-50, 50, size=(m, k)), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-50, 50, size=(k, n)), dtype=jnp.int32)
    got = ws_matmul_reference(a, w, rows, cols)
    want = a @ w
    assert jnp.all(got == want)


def test_tile_cycles_formula():
    # R + (R + C - 2) + T
    assert ws_tile_cycles(32, 32, 100) == 32 + 62 + 100


def test_schedule_tile_counts():
    s = schedule_gemm(m=100, k=70, n=50, rows=32, cols=32)
    assert s.k_tiles == 3 and s.n_tiles == 2 and s.total_tiles == 6
    assert s.total_cycles == 6 * ws_tile_cycles(32, 32, 100)
    assert s.useful_macs == 100 * 70 * 50
    assert 0 < s.utilization <= 1.0


def test_utilization_improves_with_larger_stream():
    small = schedule_gemm(m=10, k=32, n=32, rows=32, cols=32)
    large = schedule_gemm(m=10000, k=32, n=32, rows=32, cols=32)
    assert large.utilization > small.utilization
    assert large.utilization > 0.9  # fill/drain amortized


def test_schedule_many_aggregates():
    gemms = [(100, 64, 64), (50, 32, 96)]
    agg = schedule_many(gemms, 32, 32)
    parts = [schedule_gemm(*g, 32, 32) for g in gemms]
    assert agg.total_cycles == sum(p.total_cycles for p in parts)
    assert agg.useful_macs == sum(p.useful_macs for p in parts)


# ---------------------------------------------------------------------------
# Output-stationary dataflow
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    rows=st.integers(1, 16),
    cols=st.integers(1, 16),
)
def test_os_tiled_execution_exact(m, k, n, rows, cols):
    rng = np.random.default_rng(m * 1000 + k * 10 + n + 7)
    a = jnp.asarray(rng.integers(-50, 50, size=(m, k)), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-50, 50, size=(k, n)), dtype=jnp.int32)
    got = os_matmul_reference(a, w, rows, cols)
    assert jnp.all(got == a @ w)
    assert jnp.all(matmul_reference(a, w, rows, cols, dataflow="OS") == a @ w)


def test_os_tile_cycles_formula():
    # (R + C - 2) + K + R: skew + reduction stream + output drain
    assert os_tile_cycles(32, 32, 100) == 62 + 100 + 32


def test_os_schedule_tile_counts():
    s = schedule_gemm(m=100, k=70, n=50, rows=32, cols=32, dataflow="OS")
    assert s.dataflow == "OS"
    # OS tiles the OUTPUT: ceil(100/32) x ceil(50/32); K streams through time
    assert s.m_tiles == 4 and s.n_tiles == 2 and s.k_tiles == 1
    assert s.total_tiles == 8 and s.stream_len == 70
    assert s.total_cycles == 8 * os_tile_cycles(32, 32, 70)
    assert s.useful_macs == 100 * 70 * 50
    assert 0 < s.utilization <= 1.0


def test_ws_schedule_unchanged_by_dispatch():
    s = schedule_gemm(m=100, k=70, n=50, rows=32, cols=32)
    assert s.dataflow == "WS" and s.m_tiles == 1 and s.stream_len == 100
    assert s.k_tiles == 3 and s.n_tiles == 2 and s.total_tiles == 6
    assert s.total_cycles == 6 * ws_tile_cycles(32, 32, 100)


def test_os_utilization_improves_with_deeper_reduction():
    small = schedule_gemm(m=32, k=10, n=32, rows=32, cols=32, dataflow="OS")
    large = schedule_gemm(m=32, k=10000, n=32, rows=32, cols=32, dataflow="OS")
    assert large.utilization > small.utilization > 0


def test_schedule_many_os_and_unknown_dataflow():
    gemms = [(100, 64, 64), (50, 32, 96)]
    agg = schedule_many(gemms, 32, 32, dataflow="OS")
    parts = [schedule_gemm(*g, 32, 32, dataflow="OS") for g in gemms]
    assert agg.total_cycles == sum(p.total_cycles for p in parts)
    with pytest.raises(ValueError, match="unknown dataflow"):
        schedule_gemm(10, 10, 10, 4, 4, dataflow="ZZ")
    assert set(DATAFLOWS) == {"WS", "OS"}
