"""WS systolic functional + timing model."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.systolic import (
    schedule_gemm,
    schedule_many,
    ws_matmul_reference,
    ws_tile_cycles,
)


@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    rows=st.integers(1, 16),
    cols=st.integers(1, 16),
)
def test_ws_tiled_execution_exact(m, k, n, rows, cols):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.integers(-50, 50, size=(m, k)), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-50, 50, size=(k, n)), dtype=jnp.int32)
    got = ws_matmul_reference(a, w, rows, cols)
    want = a @ w
    assert jnp.all(got == want)


def test_tile_cycles_formula():
    # R + (R + C - 2) + T
    assert ws_tile_cycles(32, 32, 100) == 32 + 62 + 100


def test_schedule_tile_counts():
    s = schedule_gemm(m=100, k=70, n=50, rows=32, cols=32)
    assert s.k_tiles == 3 and s.n_tiles == 2 and s.total_tiles == 6
    assert s.total_cycles == 6 * ws_tile_cycles(32, 32, 100)
    assert s.useful_macs == 100 * 70 * 50
    assert 0 < s.utilization <= 1.0


def test_utilization_improves_with_larger_stream():
    small = schedule_gemm(m=10, k=32, n=32, rows=32, cols=32)
    large = schedule_gemm(m=10000, k=32, n=32, rows=32, cols=32)
    assert large.utilization > small.utilization
    assert large.utilization > 0.9  # fill/drain amortized


def test_schedule_many_aggregates():
    gemms = [(100, 64, 64), (50, 32, 96)]
    agg = schedule_many(gemms, 32, 32)
    parts = [schedule_gemm(*g, 32, 32) for g in gemms]
    assert agg.total_cycles == sum(p.total_cycles for p in parts)
    assert agg.useful_macs == sum(p.useful_macs for p in parts)
