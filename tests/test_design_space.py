"""Design-space engine: grid expansion, jitted evaluation vs the scalar API,
Pareto extraction vs the O(n^2) oracle, measured-profile coupling."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.design_space import (
    DesignSpace,
    evaluate_design_space,
    pareto_mask,
    sweep_bus_power,
)
from repro.core.energy import power_breakdown
from repro.core.floorplan import (
    ASPECT_MAX,
    ASPECT_MIN,
    BusActivity,
    accumulator_width,
    bus_power,
    optimal_aspect_power,
)
from repro.core.optimize import bus_invert_activity, max_regret

SPACE = DesignSpace(
    rows=(8, 32),
    cols=(8, 16),
    input_bits=(8, 16),
    dataflows=("WS", "OS"),
    bus_invert=(False, True),
    pe_area_um2=(900.0, 1200.0),
)
GRID = SPACE.expand()

rng = np.random.default_rng(7)
W = 3
A_H = np.broadcast_to(rng.uniform(0.1, 0.4, (W, 1)), (W, GRID.n_points)).copy()
A_V = np.broadcast_to(rng.uniform(0.2, 0.6, (W, 1)), (W, GRID.n_points)).copy()


def _oracle_pareto(obj):
    le = (obj[:, None, :] <= obj[None, :, :]).all(-1)
    lt = (obj[:, None, :] < obj[None, :, :]).any(-1)
    return ~(le & lt).any(axis=0)


# ---------------------------------------------------------------------------
# Expansion
# ---------------------------------------------------------------------------


def test_expand_cross_product_and_bus_widths():
    assert SPACE.n_points == GRID.n_points == 2 * 2 * 2 * 2 * 2 * 2
    for i in range(GRID.n_points):
        r, bits = int(GRID.rows[i]), int(GRID.b_h[i])
        want_data = bits if GRID.dataflow_os[i] else accumulator_width(bits, r)
        assert int(GRID.b_v_data[i]) == want_data
        assert int(GRID.b_v[i]) == want_data + int(GRID.bus_invert[i])
    # every combination appears exactly once
    combos = set(
        zip(GRID.rows, GRID.cols, GRID.b_h, GRID.dataflow_os, GRID.bus_invert, GRID.pe_area_um2)
    )
    assert len(combos) == GRID.n_points


def test_scalar_axes_auto_promote():
    sp = DesignSpace(rows=32, cols=32, input_bits=16)
    assert sp.rows == (32,) and sp.n_points == 1
    g = sp.expand()
    assert int(g.b_v[0]) == accumulator_width(16, 32)
    assert g.geometry(0).b_v == int(g.b_v[0])


def test_expand_validation():
    with pytest.raises(ValueError):
        DesignSpace(rows=(0,), cols=(8,))
    with pytest.raises(ValueError):
        DesignSpace(rows=(8,), cols=(8,), dataflows=("XX",))
    with pytest.raises(ValueError):
        DesignSpace(rows=(2**30,), cols=(8,), input_bits=(32,))  # >64-bit sums


# ---------------------------------------------------------------------------
# Evaluation vs the scalar API (float64 numpy path: tight tolerances)
# ---------------------------------------------------------------------------


def test_eval_matches_scalar_api_pointwise():
    ev = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    for i in range(GRID.n_points):
        geom = GRID.geometry(i)
        acts = []
        for w in range(W):
            a_v_eff = (
                bus_invert_activity(float(A_V[w, i]), int(GRID.b_v_data[i]))
                if GRID.bus_invert[i]
                else float(A_V[w, i])
            )
            assert float(ev.a_v_eff[w, i]) == pytest.approx(a_v_eff, rel=1e-12)
            act = BusActivity(float(A_H[w, i]), a_v_eff)
            acts.append(act)
            assert float(ev.aspect_opt[w, i]) == optimal_aspect_power(geom, act)
            assert float(ev.bus_power_opt[w, i]) == pytest.approx(
                bus_power(geom, act, float(ev.aspect_opt[w, i])), rel=1e-12
            )
            assert float(ev.bus_power_sym[w, i]) == pytest.approx(
                bus_power(geom, act, 1.0), rel=1e-12
            )
        # numeric cross-check of the closed form inside the engine
        assert np.allclose(ev.aspect_opt_gss[:, i], ev.aspect_opt[:, i], rtol=1e-6)
        # robust point: achieved worst-case regret matches the scalar oracle
        # and cannot beat (nor significantly lose to) a dense grid scan
        mr = float(ev.max_regret[i])
        assert mr == pytest.approx(
            max_regret(geom, acts, float(ev.aspect_robust[i])), rel=1e-9, abs=1e-12
        )
        grid_aspects = np.exp(
            np.linspace(np.log(ASPECT_MIN), np.log(ASPECT_MAX), 801)
        )
        grid_best = min(max_regret(geom, acts, float(a)) for a in grid_aspects)
        assert mr <= grid_best + 1e-7
        # aggregate powers are the uniform workload means
        assert float(ev.bus_power_square[i]) == pytest.approx(
            np.mean([bus_power(geom, a, 1.0) for a in acts]), rel=1e-12
        )
        assert float(ev.area_um2[i]) == pytest.approx(
            geom.rows * geom.cols * geom.pe_area_um2, rel=1e-12
        )


def test_eval_savings_match_energy_model():
    ev = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    for i in (0, 13, GRID.n_points - 1):
        geom = GRID.geometry(i)
        robust = float(ev.aspect_robust[i])
        sym_i = asym_i = comp = 0.0
        for w in range(W):
            act = BusActivity(float(A_H[w, i]), float(ev.a_v_eff[w, i]))
            b_sym = power_breakdown(geom, act, 1.0)
            b_asym = power_breakdown(geom, act, robust)
            sym_i += b_sym.interconnect_w
            asym_i += b_asym.interconnect_w
            comp += b_sym.compute_w
        assert float(ev.interconnect_saving[i]) == pytest.approx(
            1.0 - asym_i / sym_i, rel=1e-9
        )
        assert float(ev.total_saving[i]) == pytest.approx(
            1.0 - (asym_i + comp) / (sym_i + comp), rel=1e-9
        )


def test_eval_jit_matches_numpy_path():
    pytest.importorskip("jax")
    ev_np = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    ev_j = evaluate_design_space(GRID, A_H, A_V, use_jit=True)
    assert np.allclose(ev_j.aspect_opt, ev_np.aspect_opt, rtol=1e-4)
    assert np.allclose(ev_j.bus_power_opt, ev_np.bus_power_opt, rtol=1e-4)
    assert np.allclose(ev_j.aspect_robust, ev_np.aspect_robust, rtol=1e-3)
    assert np.allclose(ev_j.max_regret, ev_np.max_regret, rtol=1e-2, atol=1e-5)
    assert np.allclose(ev_j.bus_power_robust, ev_np.bus_power_robust, rtol=1e-4)
    assert np.allclose(ev_j.interconnect_saving, ev_np.interconnect_saving, atol=1e-4)
    assert np.allclose(ev_j.total_saving, ev_np.total_saving, atol=1e-4)


def test_eval_activity_broadcasting_and_weights():
    # scalar and (P,) activities broadcast to one workload row
    ev_s = evaluate_design_space(GRID, 0.22, 0.36, use_jit=False)
    assert ev_s.aspect_opt.shape == (1, GRID.n_points)
    ev_p = evaluate_design_space(
        GRID, np.full(GRID.n_points, 0.22), np.full(GRID.n_points, 0.36), use_jit=False
    )
    assert np.allclose(ev_s.aspect_opt, ev_p.aspect_opt)
    # degenerate weights select a single workload
    ev_one = evaluate_design_space(GRID, A_H[:1], A_V[:1], use_jit=False)
    ev_wt = evaluate_design_space(
        GRID, A_H, A_V, weights=[1.0, 0.0, 0.0], use_jit=False
    )
    assert np.allclose(ev_wt.bus_power_square, ev_one.bus_power_square)
    with pytest.raises(ValueError):
        evaluate_design_space(GRID, A_H, A_V, weights=[1.0], use_jit=False)
    with pytest.raises(ValueError):
        evaluate_design_space(GRID, 1.5, 0.3, use_jit=False)  # activity > 1


def test_sweep_matches_scalar_bus_power():
    aspects = np.exp(np.linspace(np.log(ASPECT_MIN), np.log(ASPECT_MAX), 9))
    a_h, a_v = A_H.mean(axis=0), A_V.mean(axis=0)
    surf = sweep_bus_power(GRID, a_h, a_v, aspects, use_jit=False)
    assert surf.shape == (GRID.n_points, len(aspects))
    for i in (0, 7, GRID.n_points - 1):
        geom = GRID.geometry(i)
        a_v_eff = (
            bus_invert_activity(float(a_v[i]), int(GRID.b_v_data[i]))
            if GRID.bus_invert[i]
            else float(a_v[i])
        )
        act = BusActivity(float(a_h[i]), a_v_eff)
        for s, asp in enumerate(aspects):
            assert float(surf[i, s]) == pytest.approx(
                bus_power(geom, act, float(asp)), rel=1e-12
            )


# ---------------------------------------------------------------------------
# Pareto extraction
# ---------------------------------------------------------------------------


def test_pareto_mask_matches_oracle_random():
    r = np.random.default_rng(3)
    for n, d in ((1, 2), (40, 2), (301, 3), (1500, 3), (97, 4)):
        obj = r.random((n, d)).round(2)  # rounding forces ties + duplicates
        got = pareto_mask(obj, chunk=64)
        assert np.array_equal(got, _oracle_pareto(obj)), (n, d)


def test_pareto_mask_edges():
    assert pareto_mask(np.zeros((0, 3))).shape == (0,)
    # identical rows: none dominates another -> all kept
    obj = np.ones((5, 2))
    assert pareto_mask(obj).all()
    # a single strictly-better row dominates everything
    obj = np.vstack([np.ones((5, 2)), [[0.5, 0.5]]])
    assert pareto_mask(obj).tolist() == [False] * 5 + [True]
    # non-finite rows never enter the frontier
    assert pareto_mask(np.asarray([[np.inf, 0.0]])).tolist() == [False]
    assert not pareto_mask(np.full((3, 2), np.nan)).any()


def test_pareto_mask_poisoned_cells_excluded():
    """NaN/Inf-poisoned rows are excluded and never break the finite frontier."""
    r = np.random.default_rng(11)
    obj = r.random((120, 3))
    poison = r.random(120) < 0.25
    rows = np.flatnonzero(poison)
    vals = np.asarray([np.nan, np.inf, -np.inf])
    obj[rows, r.integers(0, 3, rows.size)] = vals[r.integers(0, 3, rows.size)]
    got = pareto_mask(obj, chunk=32)
    assert not got[poison].any()
    finite = ~poison
    want = np.zeros(120, bool)
    want[finite] = _oracle_pareto(obj[finite])
    assert np.array_equal(got, want)
    # -inf rows are excluded too, even though they'd "dominate" everything
    assert not pareto_mask(np.asarray([[-np.inf, 0.0], [1.0, 1.0]]))[0]


@settings(deadline=None, max_examples=30)
@given(
    data=st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 5), st.integers(0, 5)),
        min_size=1,
        max_size=60,
    )
)
def test_pareto_mask_matches_oracle_hypothesis(data):
    obj = np.asarray(data, float)
    assert np.array_equal(pareto_mask(obj, chunk=7), _oracle_pareto(obj))


def test_eval_pareto_is_nonempty_and_nondominated():
    ev = evaluate_design_space(GRID, A_H, A_V, use_jit=False)
    mask = ev.pareto()
    assert mask.any()
    obj = ev.objectives()
    assert np.array_equal(mask, _oracle_pareto(obj))
    assert ev.grid.select(mask).n_points == int(mask.sum())


# ---------------------------------------------------------------------------
# Measured-profile coupling (tiny layers; exercises run_profile_batch)
# ---------------------------------------------------------------------------

TINY = None


def _tiny_layers():
    from repro.core.workloads import ConvLayer

    return [
        ConvLayer("T1", k=1, h=8, w=8, c=48, m=24, input_density=0.5),
        ConvLayer("T2", k=1, h=6, w=6, c=64, m=32, input_density=0.4),
    ]


def test_measured_activities_map_classes_onto_grid():
    from repro.core.workloads import measured_design_activities, profile_conv_layer

    sp = DesignSpace(
        rows=(4, 8), cols=(4, 8, 16), input_bits=(8,), bus_invert=(False, True)
    )
    grid = sp.expand()
    layers = _tiny_layers()
    a_h, a_v, stats = measured_design_activities(grid, layers, return_stats=True)
    assert a_h.shape == a_v.shape == (len(layers), grid.n_points)
    assert (0 <= a_h).all() and (a_h <= 1).all() and (0 <= a_v).all() and (a_v <= 1).all()
    # one job per (rows, b_h, b_v_data) class per layer — the cols and
    # bus-invert axes ride for free
    assert stats.jobs == 2 * len(layers)
    # activities are cols-invariant: identical across the cols axis
    for c in (8, 16):
        assert np.array_equal(a_h[:, grid.cols == 4], a_h[:, grid.cols == c])
        assert np.array_equal(a_v[:, grid.cols == 4], a_v[:, grid.cols == c])
    # ... and match the serial per-layer profiler (same operands, same seeds)
    for r in (4, 8):
        sel = np.asarray(grid.rows == r)
        for i, layer in enumerate(layers):
            p = profile_conv_layer(layer, rows=r, cols=4, bits=8, seed=i)
            assert np.allclose(a_h[i, sel], p.a_h)
            assert np.allclose(a_v[i, sel], p.a_v)


def test_measured_activities_os_points_are_measured():
    """The retired ``a_v := a_h`` shortcut: OS vertical activities now come
    from the real W-operand column streams, and OS horizontal activities
    from the A rows streamed along K (NOT the WS M-axis stream)."""
    from repro.core.switching import profile_gemm
    from repro.core.workloads import (
        conv_layer_job,
        measured_design_activities,
        profile_conv_layer,
    )

    sp = DesignSpace(rows=(4, 8), cols=(4,), input_bits=(8,), dataflows=("WS", "OS"))
    grid = sp.expand()
    layers = _tiny_layers()[:1]
    a_h, a_v, stats = measured_design_activities(grid, layers, return_stats=True)
    os_sel = np.asarray(grid.dataflow_os)
    # measured, not copied — and distinct from the WS activities
    assert not np.array_equal(a_v[:, os_sel], a_h[:, os_sel])
    assert not np.array_equal(a_h[:, os_sel], a_h[:, ~os_sel])
    # OS classes are geometry-free: one per (b_h, b_v), rows-invariant
    assert np.unique(a_v[:, os_sel], axis=1).shape[1] == 1
    # 2 WS rows-classes + 1 OS class, one job each per layer
    assert stats.jobs == 3 * len(layers)
    # ... and they match the serial OS profiler on the same operands/seed
    p = profile_conv_layer(layers[0], rows=4, cols=4, bits=8, seed=0, dataflow="OS")
    assert np.allclose(a_h[0, os_sel], p.a_h)
    assert np.allclose(a_v[0, os_sel], p.a_v)
    # ... which is itself the exact W-column stream measurement
    job = conv_layer_job(layers[0], rows=4, cols=4, bits=8, seed=0, dataflow="OS")
    a, w = job.operands()
    direct = profile_gemm(a, w, 4, 4, 8, 8, dataflow="OS", backend="numpy",
                          use_cache=False)
    assert p.a_v == pytest.approx(direct.a_v, abs=1e-12)


def test_measured_end_to_end_evaluation():
    """Measured activities -> jitted engine -> non-empty Pareto frontier."""
    from repro.core.workloads import measured_design_activities

    sp = DesignSpace(rows=(4, 8), cols=(4, 16), input_bits=(8,), bus_invert=(False, True))
    grid = sp.expand()
    a_h, a_v = measured_design_activities(grid, _tiny_layers())
    ev = evaluate_design_space(grid, a_h, a_v, use_jit=False)
    assert np.isfinite(ev.bus_power_robust).all()
    assert (ev.max_regret >= -1e-12).all()
    assert ev.pareto().any()
    # bus-invert points must never pay more optimal bus power than their
    # uncoded twins (BI lowers a_v and adds one wire; the optimum adapts)
    bi = np.asarray(grid.bus_invert)
    order = np.lexsort(
        (bi, np.asarray(grid.cols), np.asarray(grid.rows))
    )  # pairs (uncoded, coded) adjacent
    pts = order.reshape(-1, 2)
    for plain, coded in pts:
        assert (ev.a_v_eff[:, coded] <= ev.a_v_eff[:, plain] + 1e-12).all()
