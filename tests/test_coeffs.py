"""Coefficient-protocol parity: lowering cache + evaluator vs enumeration.

The contract under test: for ANY (family, k, point, activity, config) cell,
the coefficient evaluator's converged outputs — per-workload optima, the
robust aspect's data-net power, the duty-cycled overhead nets (WS preload
chain, OS drain chain, clock spine), the wirelength roll-up — equal the
explicit ``SegmentList`` enumeration re-priced at the same aspects to f64
round-off (<= 1e-12 relative).  Cells are drawn over pods k outside {2, 4}
as well (the free-k-axis claim), serpentine folds, both dataflows, and the
per-lane activity path.

The property runs twice: once under hypothesis (skipped gracefully where
hypothesis isn't installed — see ``tests/_hyp.py``) and once as a seeded
deterministic sweep so the parity claim is ALWAYS exercised.
"""

import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.core.design_space import DesignSpace
from repro.core.floorplan import BusActivity
from repro.core.workloads import Gemm, design_pod_partition, partition_gemm
from repro.layout import (
    LayoutPowerConfig,
    clear_coeff_cache,
    coeff_cache_info,
    evaluate_layout_space,
    get_layout,
    lower_layout_coeffs,
    pod_layouts,
    segment_bus_power,
    segment_wirelength,
    set_coeff_cache_capacity,
)
from repro.layout.power import rollup_segments
from repro.layout.segments import enumerate_segments

RTOL = 1e-12


def _cell_grid(rows, cols, bits, dataflow, area):
    return DesignSpace(
        rows=(rows,),
        cols=(cols,),
        input_bits=(bits,),
        dataflows=(dataflow,),
        pe_area_um2=(area,),
    ).expand()


def _check_cell(layout_name, rows, cols, bits, dataflow, area, a_h, a_v, rng):
    """Coefficient evaluator vs explicit enumeration on one cell, f64."""
    grid = _cell_grid(rows, cols, bits, dataflow, area)
    layout = get_layout(layout_name)
    # duty-cycled overhead nets ON so preload/drain/clk parity is exercised
    cfg = LayoutPowerConfig(
        preload_duty=float(rng.uniform(0.01, 0.2)),
        drain_duty=float(rng.uniform(0.01, 0.2)),
    )
    lanes = bool(rng.random() < 0.5)
    h_lanes = v_lanes = None
    if lanes:
        n = 64
        h_lanes = np.zeros((2, 1, n))
        v_lanes = np.zeros((2, 1, n))
        b_v = int(grid.b_v[0])
        h_lanes[:, 0, :bits] = rng.uniform(0.0, 1.0, (2, bits))
        v_lanes[:, 0, :b_v] = rng.uniform(0.0, 1.0, (2, b_v))
    w = rng.uniform(0.2, 1.0, 2)
    ev = evaluate_layout_space(
        grid,
        np.asarray([[a_h], [a_h * 0.6]]),
        np.asarray([[a_v], [a_v * 1.3]]),
        layouts=(layout_name,),
        h_lanes=h_lanes,
        v_lanes=v_lanes,
        weights=w,
        cfg=cfg,
        use_jit=False,
    )
    assert ev.feasible[0, 0]
    geom = grid.geometry(0)
    acts = [BusActivity(a_h, a_v), BusActivity(a_h * 0.6, a_v * 1.3)]
    w = w / w.sum()

    # per-workload optima re-priced through the explicit segment enumeration
    for wi, act in enumerate(acts):
        asp = float(ev.aspect_opt[wi, 0, 0])
        ref = segment_bus_power(
            layout,
            geom,
            act,
            asp,
            dataflow=dataflow,
            h_lanes=None if h_lanes is None else h_lanes[wi, 0],
            v_lanes=None if v_lanes is None else v_lanes[wi, 0],
            cfg=cfg,
        )
        got = float(ev.bus_power_opt[wi, 0, 0])
        assert got == pytest.approx(ref, rel=RTOL)

    # robust-aspect weighted data power, overhead nets, wirelength
    asp_r = float(ev.aspect_robust[0, 0])
    ref_rob = sum(
        wv
        * segment_bus_power(
            layout,
            geom,
            act,
            asp_r,
            dataflow=dataflow,
            h_lanes=None if h_lanes is None else h_lanes[wi, 0],
            v_lanes=None if v_lanes is None else v_lanes[wi, 0],
            cfg=cfg,
        )
        for wi, (wv, act) in enumerate(zip(w, acts))
    )
    assert float(ev.bus_power_robust[0, 0]) == pytest.approx(ref_rob, rel=RTOL)

    segs = enumerate_segments(
        layout,
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        asp_r,
        dataflow=dataflow,
        nets=("preload", "drain", "clk"),
    )
    ref_ov = rollup_segments(segs, 0.0, 0.0, cfg=cfg)["overhead_w"]
    assert float(ev.overhead_w[0, 0]) == pytest.approx(ref_ov, rel=RTOL, abs=1e-18)
    ref_wl = segment_wirelength(layout, geom, asp_r, dataflow=dataflow)
    assert float(ev.wirelength_um[0, 0]) == pytest.approx(ref_wl, rel=RTOL)


_FAMILIES = (
    ("uniform", 1),
    ("serpentine2", 2),
    ("serpentine4", 4),
    ("pods1x1", 1),
    ("pods2x2", 2),
    ("pods3x3", 3),
    ("pods4x4", 4),
    ("pods5x5", 5),
    ("pods8x8", 8),
)


def _random_cell(rng):
    name, div = _FAMILIES[int(rng.integers(len(_FAMILIES)))]
    rows = div * int(rng.integers(1, 7))
    cols = div * int(rng.integers(1, 7))
    if name.startswith("serpentine"):
        rows = int(rng.integers(2, 33))
    bits = int(rng.integers(4, 17))
    dataflow = "OS" if rng.random() < 0.5 else "WS"
    area = float(rng.uniform(200.0, 3000.0))
    a_h = float(rng.uniform(0.02, 0.6))
    a_v = float(rng.uniform(0.02, 0.6))
    return name, rows, cols, bits, dataflow, area, a_h, a_v


def test_coeff_matches_segment_rollup_seeded():
    """Deterministic property sweep: 24 random cells, every family class."""
    rng = np.random.default_rng(1234)
    seen = set()
    for _ in range(24):
        cell = _random_cell(rng)
        seen.add(cell[0])
        _check_cell(*cell, rng)
    # the draw must actually cover non-{2,4} pod counts
    assert seen & {"pods3x3", "pods5x5", "pods8x8"}


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_coeff_matches_segment_rollup_hypothesis(seed):
    rng = np.random.default_rng(seed)
    _check_cell(*_random_cell(rng), rng)


def test_pods1x1_equals_uniform_through_evaluator():
    grid = DesignSpace(
        rows=(8, 16), cols=(8, 32), input_bits=(8,), dataflows=("WS", "OS"),
        pe_area_um2=(900.0,),
    ).expand()
    ev = evaluate_layout_space(
        grid, 0.3, 0.2, layouts=("uniform", "pods1x1"), use_jit=False
    )
    for f in ("aspect_robust", "bus_power_robust", "overhead_w", "wirelength_um"):
        np.testing.assert_array_equal(getattr(ev, f)[0], getattr(ev, f)[1])


def test_k_axis_rides_the_layout_axis():
    """pod_layouts names resolve as a DesignSpace layout axis and evaluate."""
    space = DesignSpace(
        rows=(24,), cols=(24,), input_bits=(8,), pe_area_um2=(900.0,),
        layouts=("uniform",) + pod_layouts((2, 3)),
    )
    ev = evaluate_layout_space(
        space.expand(), 0.3, 0.25, layouts=space.layouts, use_jit=False
    )
    assert ev.feasible.all()
    assert ev.layouts == ("uniform", "pods2x2", "pods3x3")
    with pytest.raises(ValueError, match="unknown layout"):
        DesignSpace(rows=(8,), cols=(8,), layouts=("pods2x3",))


def test_coeff_cache_counters_and_eviction():
    grid = _cell_grid(8, 8, 8, "WS", 900.0)
    grid2 = _cell_grid(8, 16, 8, "WS", 900.0)
    clear_coeff_cache()
    prev = set_coeff_cache_capacity(1)
    try:
        c1 = lower_layout_coeffs(grid, ("uniform",))
        assert lower_layout_coeffs(grid, ("uniform",)) is c1
        info = coeff_cache_info()
        assert (info["hits"], info["misses"], info["size"]) == (1, 1, 1)
        lower_layout_coeffs(grid2, ("uniform",))  # evicts the first entry
        assert coeff_cache_info()["evictions"] == 1
        c3 = lower_layout_coeffs(grid, ("uniform",))
        assert c3 is not c1
        assert coeff_cache_info()["misses"] == 3
        # content key covers family params: same name, different instance
        from repro.layout import LAYOUTS, MultiPodLayout, register_layout

        register_layout("podsX", MultiPodLayout(k=2, gutter_um=10.0))
        try:
            ca = lower_layout_coeffs(grid, ("podsX",))
            register_layout("podsX", MultiPodLayout(k=2, gutter_um=99.0))
            cb = lower_layout_coeffs(grid, ("podsX",))
            assert ca.key != cb.key
        finally:
            del LAYOUTS["podsX"]
    finally:
        set_coeff_cache_capacity(prev)
        clear_coeff_cache()


def test_repeater_prune_is_exact():
    """Classes pruned from rep_idx never exceed the spacing in-window."""
    grid = DesignSpace(
        rows=(8, 32), cols=(8, 64), input_bits=(8,), dataflows=("WS",),
        pe_area_um2=(400.0, 2500.0),
    ).expand()
    c = lower_layout_coeffs(grid, ("uniform", "serpentine2", "pods2x2"))
    h = c.host
    for j in range(h["alpha_d"].shape[1]):
        ln_ends = np.maximum(
            h["alpha_d"][:, j] * h["t_lo"] + h["beta_d"][:, j] / h["t_lo"]
            + h["gamma_d"][:, j],
            h["alpha_d"][:, j] * h["t_hi"] + h["beta_d"][:, j] / h["t_hi"]
            + h["gamma_d"][:, j],
        )
        live = h["feasible"] & (h["count_d"][:, j] > 0)
        if j not in c.rep_idx:
            assert not (ln_ends[live] > 200.0).any()


# ---------------------------------------------------------------------------
# GEMM partitioning across pods
# ---------------------------------------------------------------------------


def test_partition_deep_k_prefers_ksplit():
    p = partition_gemm(Gemm("g", m=256, k=64, n=16), 32, 32, k=2)
    assert p.mode == "ksplit"
    assert p.trunk_words > 0
    # in-array reduction halves the off-array accumulation passes
    t = partition_gemm(Gemm("g", m=256, k=64, n=16), 32, 32, k=1)
    assert p.spill_words <= t.spill_words


def test_partition_small_ragged_underutilizes_large_arrays():
    small = Gemm("g", m=100, k=20, n=20)
    u32 = partition_gemm(small, 32, 32, k=1).utilization
    u128 = partition_gemm(small, 128, 128, k=4).utilization
    assert u128 < u32 < 1.0
    # exact-fit divisible GEMM fully utilizes
    assert partition_gemm(Gemm("g", m=64, k=32, n=32), 32, 32, k=2).utilization == 1.0


def test_partition_degeneracies():
    g = Gemm("g", m=64, k=64, n=64)
    k1 = partition_gemm(g, 32, 32, k=1)
    assert k1.trunk_words == 0
    os_ = partition_gemm(g, 32, 32, k=4, dataflow="OS")
    assert os_.mode == "tile" and os_.trunk_words == 0 and os_.spill_words == 0
    with pytest.raises(ValueError):
        partition_gemm(g, 30, 32, k=4)


def test_design_pod_partition_grid():
    grid = DesignSpace(
        rows=(16, 32), cols=(16, 32), input_bits=(8,), dataflows=("WS", "OS"),
        pe_area_um2=(900.0,),
    ).expand()
    gemms = [Gemm("a", 64, 128, 64), Gemm("b", 50, 20, 30)]
    stats = design_pod_partition(grid, ("uniform",) + pod_layouts((1, 2)), gemms)
    util = stats["utilization"]
    assert util.shape == (3, grid.n_points)
    np.testing.assert_array_equal(util[0], util[1])  # pods1x1 == uniform
    assert (util > 0).all() and (util <= 1.0).all()
    assert (stats["trunk_words_per_mac"][:2] == 0).all()
