"""Serving-traffic subsystem: registry expansion, traffic model, J/token.

Covers the three layers of ``repro.serving`` plus the shared decode-shape
authority in ``launch.specs``:

  * every registry config expands to a non-empty, positive-shape GEMM job
    set in both regimes, with MoE routing sparsity in (0, 1];
  * decode shapes can no longer drift: ``decode_batch_specs`` and the
    serving expansion both derive M from ``launch.specs.token_shape``;
  * the seeded traffic model is bit-deterministic, MAC-share weights sum
    to 1, and sweeping the prefill:decode ratio MOVES the design optimum
    (regression-pinned);
  * the J/token aggregation slot prices exactly j_per_mac * MACs/token
    and refuses half-configured evaluations.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, get_arch
from repro.core.design_space import DesignSpace
from repro.core.objective import evaluate_fleet_objective
from repro.core.workloads import (
    Gemm,
    gemm_profile_seed,
    measured_design_gemm_activities,
)
from repro.launch.specs import decode_batch_specs, token_shape
from repro.serving import (
    PRESETS,
    ServingGemm,
    TrafficModel,
    expand_arch,
    expand_shape,
    get_preset,
    regime_tokens,
    routing_sparsity,
    sample_requests,
    traffic_classes,
    weighted_gemms,
)

MOE_ARCHS = [a for a in ARCH_IDS if get_arch(a).num_experts > 1]
DENSE_ARCHS = [a for a in ARCH_IDS if get_arch(a).num_experts <= 1]


# ---------------------------------------------------------------------------
# Registry expansion (every config, both regimes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("regime,batch,seq", [("prefill", 4, 512), ("decode", 64, 1)])
def test_every_config_expands(arch, regime, batch, seq):
    cfg = get_arch(arch)
    jobs = expand_arch(cfg, regime, batch, seq)
    assert jobs, f"{arch}: empty {regime} job set"
    t = regime_tokens(cfg, regime, batch, seq)
    for j in jobs:
        assert min(j.gemm.m, j.gemm.k, j.gemm.n) >= 1, (arch, j.block)
        assert j.count >= 1 and j.macs > 0, (arch, j.block)
        assert j.regime == regime
        if j.input_density is not None:
            assert 0.0 < j.input_density <= 1.0
        # every non-expert GEMM runs at the regime's token batch
        if not j.block.startswith("moe.expert"):
            assert j.gemm.m == t, (arch, j.block, j.gemm.m, t)
    blocks = {j.block for j in jobs}
    assert "head.lm_head" in blocks


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_routing_sparsity_in_unit_interval(arch):
    cfg = get_arch(arch)
    s = routing_sparsity(cfg)
    assert 0.0 < s <= 1.0
    if cfg.num_experts > 1:
        assert s == cfg.top_k / cfg.num_experts < 1.0
    else:
        assert s == 1.0


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_moe_effective_expert_batch(arch):
    cfg = get_arch(arch)
    t = 256
    jobs = expand_arch(cfg, "prefill", 1, t)
    experts = [j for j in jobs if j.block.startswith("moe.expert")]
    assert experts, f"{arch}: no expert GEMMs"
    m_e = max(1, round(t * routing_sparsity(cfg)))
    assert all(j.gemm.m == m_e for j in experts)
    assert all(j.count % cfg.num_experts == 0 for j in experts)
    router = [j for j in jobs if j.block == "moe.router"]
    assert router and all(j.gemm.m == t and j.gemm.n == cfg.num_experts for j in router)


@pytest.mark.parametrize("shape_id", sorted(SHAPES))
def test_registry_shape_cells_expand(shape_id):
    shape = SHAPES[shape_id]
    for arch in ("mixtral_8x7b", "qwen3_8b"):
        jobs = expand_shape(get_arch(arch), shape)
        assert jobs and all(j.macs > 0 for j in jobs)
        want = "decode" if shape.kind == "decode" else "prefill"
        assert all(j.regime == want for j in jobs)


def test_expand_contract_errors():
    cfg = get_arch("qwen3_8b")
    with pytest.raises(ValueError, match="regime"):
        expand_arch(cfg, "train", 1, 16)
    with pytest.raises(ValueError, match="batch"):
        expand_arch(cfg, "prefill", 0, 16)
    with pytest.raises(ValueError, match="count"):
        ServingGemm(Gemm("x", 1, 1, 1), "b", "decode", count=0)
    with pytest.raises(ValueError, match="non-positive"):
        ServingGemm(Gemm("x", 1, 0, 1), "b", "decode", count=1)


# ---------------------------------------------------------------------------
# Decode-shape drift: launch specs and serving expansion share one authority
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_specs_match_token_shape(arch):
    cfg = get_arch(arch)
    shape = SHAPES["decode_32k"]
    specs, _axes = decode_batch_specs(cfg, shape)
    assert tuple(specs["tokens"].shape) == token_shape(cfg, shape.global_batch, 1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_expansion_matches_decode_specs(arch):
    cfg = get_arch(arch)
    b = SHAPES["decode_32k"].global_batch
    specs, _axes = decode_batch_specs(cfg, shape=SHAPES["decode_32k"])
    tok = tuple(specs["tokens"].shape)
    m = tok[0] * tok[1]  # codebook streams share one position
    assert regime_tokens(cfg, "decode", b) == m
    jobs = expand_arch(cfg, "decode", b)
    non_expert = [j for j in jobs if not j.block.startswith("moe.expert")]
    assert all(j.gemm.m == m for j in non_expert)
    # decode ignores any stray seq_len: M is the decode-step token count
    assert expand_arch(cfg, "decode", b, 999)[0].gemm.m == m


def test_prefill_tokens_are_batch_times_seq():
    for arch in ("qwen3_8b", "musicgen_medium"):
        cfg = get_arch(arch)
        assert regime_tokens(cfg, "prefill", 3, 128) == 3 * 128


# ---------------------------------------------------------------------------
# Traffic model: seeded determinism, weight invariants
# ---------------------------------------------------------------------------


def test_sample_requests_deterministic():
    tm = get_preset("balanced")
    a = sample_requests(tm)
    b = sample_requests(tm)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    c = sample_requests(dataclasses.replace(tm, seed=1))
    assert not all(np.array_equal(x, y) for x, y in zip(a, c))


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_traffic_classes_invariants(preset):
    tm = get_preset(preset)
    classes = traffic_classes(tm)
    regimes = {tc.regime for tc in classes}
    assert regimes == {"prefill", "decode"}
    prompts, gens, _ = sample_requests(tm)
    window_s = tm.n_samples / tm.qps
    tok = sum(tc.tokens_per_s for tc in classes)
    # every served token (unpadded) is attributed to exactly one class
    assert tok == pytest.approx(float(prompts.sum() + gens.sum()) / window_s)
    for tc in classes:
        assert tc.batch >= 1 and tc.seq_len >= 1
        assert tc.tokens_per_s > 0 and tc.execs_per_s > 0
        if tc.regime == "decode":
            assert tc.seq_len == 1 and tc.batch <= tm.max_decode_batch
        else:
            assert tc.batch <= tm.max_prefill_batch
            assert tc.seq_len & (tc.seq_len - 1) == 0  # power of two


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_jobset_weights_sum_to_one(preset):
    js = weighted_gemms(get_arch("mixtral_8x7b"), get_preset(preset))
    w = np.asarray(js.weights)
    assert w.sum() == pytest.approx(1.0, abs=1e-12)
    assert (w > 0).all()
    assert js.macs_per_token > 0
    # regime weights partition the total
    dec = js.regime_weights("decode").sum()
    pre = js.regime_weights("prefill").sum()
    assert dec + pre == pytest.approx(1.0, abs=1e-12)


def test_jobset_bit_deterministic():
    cfg = get_arch("jamba_v01_52b")
    tm = get_preset("decode_heavy")
    a = weighted_gemms(cfg, tm)
    b = weighted_gemms(cfg, tm)
    assert a.gemms == b.gemms
    assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))
    assert np.array_equal(np.asarray(a.mac_rate), np.asarray(b.mac_rate))
    assert a.macs_per_token == b.macs_per_token
    c = weighted_gemms(cfg, dataclasses.replace(tm, seed=3))
    assert not np.array_equal(np.asarray(a.weights), np.asarray(c.weights))


def test_jobset_mac_conservation():
    cfg = get_arch("qwen3_8b")
    tm = get_preset("balanced")
    js = weighted_gemms(cfg, tm)
    total = 0.0
    for tc in traffic_classes(tm):
        step = sum(sg.macs for sg in expand_arch(cfg, tc.regime, tc.batch, tc.seq_len))
        total += tc.execs_per_s * step
    assert float(np.asarray(js.mac_rate).sum()) == pytest.approx(total, rel=1e-12)
    assert js.macs_per_token == pytest.approx(total / js.tokens_per_s, rel=1e-12)


def test_preset_regime_shares():
    cfg = get_arch("mixtral_8x7b")
    dec_share = lambda p: float(
        weighted_gemms(cfg, get_preset(p)).regime_weights("decode").sum()
    )
    assert dec_share("decode_heavy") > 0.6
    assert dec_share("prefill_heavy") < 0.1
    assert dec_share("decode_heavy") > dec_share("balanced") > dec_share("prefill_heavy")


def test_with_ratio_rescales_gen_mean():
    tm = get_preset("balanced")
    t2 = tm.with_ratio(4.0)
    assert t2.prefill_decode_ratio == pytest.approx(4.0)
    assert t2.prompt_len == tm.prompt_len
    with pytest.raises(ValueError):
        tm.with_ratio(0.0)


def test_traffic_model_validation():
    with pytest.raises(ValueError, match="qps"):
        TrafficModel("x", qps=0.0, prompt_len=(64.0, 0.5), gen_len=(64.0, 0.5))
    with pytest.raises(ValueError, match="gen_len"):
        TrafficModel("x", qps=1.0, prompt_len=(64.0, 0.5), gen_len=(0.5, 0.5))
    with pytest.raises(KeyError):
        get_preset("nope")


# ---------------------------------------------------------------------------
# Ratio sweep moves the design optimum (regression-pinned)
# ---------------------------------------------------------------------------


def test_ratio_sweep_moves_optimum():
    cfg = get_arch("mixtral_8x7b")
    tm = get_preset("balanced")
    grid = DesignSpace(
        rows=(16, 32),
        cols=(8, 32, 128),
        input_bits=(16,),
        dataflows=("WS", "OS"),
        bus_invert=(False, True),
    ).expand()
    families = ("uniform", "serpentine2", "pods2x2", "pods4x4")

    cells, shares = {}, {}
    for ratio in (0.05, 4.0, 48.0):
        js = weighted_gemms(cfg, tm.with_ratio(ratio))
        shares[ratio] = float(js.regime_weights("decode").sum())
        rng = np.random.default_rng(7)
        a_h = rng.uniform(0.1, 0.4, (len(js.gemms), grid.n_points))
        a_v = rng.uniform(0.2, 0.6, (len(js.gemms), grid.n_points))
        ev = evaluate_fleet_objective(
            grid, a_h, a_v, js.gemms, layouts=families, weights=js.weights,
            macs_per_token=js.macs_per_token,
        )
        j = np.asarray(ev.j_per_mac_robust)
        cells[ratio] = tuple(
            int(i) for i in np.unravel_index(np.argmin(j), j.shape)
        )
    # longer generations (lower ratio) -> more decode MAC share, monotone
    assert shares[0.05] > shares[4.0] > shares[48.0]
    assert shares[0.05] == pytest.approx(0.8469, abs=0.05)
    assert shares[48.0] == pytest.approx(0.0172, abs=0.02)
    # the optimum must MOVE across the sweep: a decode-dominated second
    # picks a different (family, point) cell than a prefill-dominated one
    assert cells[0.05] != cells[48.0], cells


# ---------------------------------------------------------------------------
# J/token aggregation slot
# ---------------------------------------------------------------------------


def _tiny_eval(macs_per_token=None):
    grid = DesignSpace(
        rows=(8,), cols=(8, 16), input_bits=(8,), dataflows=("WS",)
    ).expand()
    gemms = [Gemm("a", 64, 32, 16), Gemm("b", 8, 32, 16)]
    rng = np.random.default_rng(0)
    a_h = rng.uniform(0.1, 0.4, (2, grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (2, grid.n_points))
    return evaluate_fleet_objective(
        grid, a_h, a_v, gemms, layouts=("uniform",),
        macs_per_token=macs_per_token,
    )


def test_j_per_token_is_j_per_mac_times_macs_per_token():
    ev = _tiny_eval(macs_per_token=1.5e9)
    assert ev.macs_per_token == 1.5e9
    got = np.asarray(ev.j_per_token_robust)
    want = np.asarray(ev.j_per_mac_robust) * 1.5e9
    assert np.array_equal(got, want)
    assert np.isfinite(got).any()


def test_j_per_token_requires_both_halves():
    ev = _tiny_eval()  # priced J/op, no macs_per_token
    with pytest.raises(ValueError, match="macs_per_token"):
        _ = ev.j_per_token_robust
    with pytest.raises(ValueError, match="positive"):
        _tiny_eval(macs_per_token=0.0)


def test_serving_jobset_through_objective():
    js = weighted_gemms(get_arch("qwen3_8b"), get_preset("decode_heavy"))
    grid = DesignSpace(
        rows=(16,), cols=(8, 16), input_bits=(16,), dataflows=("WS", "OS")
    ).expand()
    rng = np.random.default_rng(1)
    a_h = rng.uniform(0.1, 0.4, (len(js.gemms), grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (len(js.gemms), grid.n_points))
    ev = evaluate_fleet_objective(
        grid, a_h, a_v, js.gemms, layouts=("uniform", "pods2x2"),
        weights=js.weights, macs_per_token=js.macs_per_token,
    )
    jpt = np.asarray(ev.j_per_token_robust)
    assert jpt.shape == (2, grid.n_points)
    assert np.isfinite(jpt).any() and (jpt[np.isfinite(jpt)] > 0).all()


# ---------------------------------------------------------------------------
# Measured activities over a GEMM job set: dedup + determinism
# ---------------------------------------------------------------------------


def test_gemm_profile_seed_content_keyed():
    g1 = Gemm("dec.q", 64, 4096, 4096)
    g2 = Gemm("pre.q", 64, 4096, 4096)  # same content, different name
    clip = (128, 512, 256)
    assert gemm_profile_seed(g1, clip=clip) == gemm_profile_seed(g2, clip=clip)
    # clipped dims key the seed: 4096 and 600 both clip to 512
    g3 = Gemm("x", 64, 600, 4096)
    assert gemm_profile_seed(g1, clip=clip) == gemm_profile_seed(g3, clip=clip)
    assert gemm_profile_seed(g1, clip=clip) != gemm_profile_seed(
        g1, clip=clip, density=0.5
    )
    assert gemm_profile_seed(g1, clip=None) != gemm_profile_seed(g3, clip=None)


def test_measured_gemm_activities_dedup_and_determinism():
    grid = DesignSpace(
        rows=(8,), cols=(8,), input_bits=(8,), dataflows=("WS", "OS")
    ).expand()
    clip = (16, 32, 16)
    gemms = [
        Gemm("a", 16, 32, 16),
        Gemm("b", 999, 4096, 777),  # clips to the same operands as "a"
        Gemm("c", 4, 32, 16),
    ]
    a_h, a_v, stats = measured_design_gemm_activities(
        grid, gemms, clip=clip, return_stats=True
    )
    assert a_h.shape == a_v.shape == (3, grid.n_points)
    assert ((0 <= a_h) & (a_h <= 1)).all() and ((0 <= a_v) & (a_v <= 1)).all()
    # identical clipped content -> identical activity rows (profiled once)
    assert np.array_equal(a_h[0], a_h[1]) and np.array_equal(a_v[0], a_v[1])
    assert not np.array_equal(a_h[0], a_h[2])
    b_h, b_v = measured_design_gemm_activities(grid, gemms, clip=clip)
    assert np.array_equal(a_h, b_h) and np.array_equal(a_v, b_v)
