"""Workload expansion: ArchConfig x serving regime -> per-block GEMM sets.

Layer 1 of the serving subsystem (DESIGN.md §Serving-workloads).  A model
config from ``repro.configs.registry`` is walked block by block — attention,
Mamba, m/sLSTM, dense/MoE MLP, LM head — into the concrete GEMM shapes one
forward step executes under a serving regime:

  * ``prefill``: M = batch * seq_len tokens flow through every projection;
  * ``decode``:  M = the decode-step token count, derived from the SAME
    ``launch.specs.token_shape`` helper the dry-run batch specs use (seq
    axis == 1), so the serving expansion and ``decode_batch_specs`` can
    never drift apart.

MoE routing sparsity (top_k / num_experts) becomes the per-expert effective
batch: each of the E experts sees ``round(tokens * top_k / E)`` rows, so the
expansion prices exactly the active-parameter GEMM work, with the router and
any shared experts at the full token batch.  Attention score/context
products (QK^T, PV) are cache-shaped dynamic-by-dynamic products served by
the flash-attention kernel, not stationary-weight GEMMs, and are out of
scope here — same contract as ``core.workloads.gemms_for_arch``.

Every emitted ``ServingGemm`` carries a ``count`` multiplicity (layers x
heads x experts ...) so identical shapes collapse to one entry, and an
``input_density`` hint for post-activation operand streams (down
projections see ~half-zero SiLU/GELU outputs).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence

from repro.core.workloads import Gemm
from repro.launch.specs import token_shape

__all__ = [
    "ServingGemm",
    "REGIMES",
    "expand_arch",
    "expand_shape",
    "regime_tokens",
    "routing_sparsity",
    "validate_job_set",
]

REGIMES = ("prefill", "decode")

# density hint for operands that just passed a SiLU/GELU-style gate:
# roughly half the activations are (near-)zero, matching the synthetic
# post-activation streams ``core.workloads.gemm_job`` generates.
_POST_ACT_DENSITY = 0.5


@dataclasses.dataclass(frozen=True)
class ServingGemm:
    """One GEMM shape class a serving step executes ``count`` times.

    ``gemm.m`` is the token batch of the regime (or the per-expert
    effective batch for routed experts); K/N are the weight dims.
    """

    gemm: Gemm
    block: str  # "attn.q_proj", "moe.expert_up", "head.lm_head", ...
    regime: str  # "prefill" | "decode"
    count: int  # executions per model forward (layers x heads x experts)
    input_density: float | None = None  # post-activation stream density hint

    def __post_init__(self):
        if self.regime not in REGIMES:
            raise ValueError(f"regime must be one of {REGIMES}, got {self.regime!r}")
        if self.count < 1:
            raise ValueError(f"{self.block}: count must be >= 1, got {self.count}")
        if min(self.gemm.m, self.gemm.k, self.gemm.n) < 1:
            raise ValueError(
                f"{self.block}: non-positive GEMM dims "
                f"({self.gemm.m}, {self.gemm.k}, {self.gemm.n})"
            )

    @property
    def macs(self) -> int:
        """Total MACs this entry contributes to one forward step."""
        return self.count * self.gemm.macs


def regime_tokens(cfg, regime: str, batch: int, seq_len: int = 1) -> int:
    """Token batch M of one serving step, via the shared token-shape helper.

    Decode is DEFINED as ``token_shape(cfg, batch, 1)`` — the exact shape
    ``launch.specs.decode_batch_specs`` builds — so M is the product of its
    (batch, seq) leading axes (codebook streams share one position: the
    backbone hidden state is (B, S, d) with codebook embeddings summed).
    """
    if regime not in REGIMES:
        raise ValueError(f"regime must be one of {REGIMES}, got {regime!r}")
    if regime == "decode":
        seq_len = 1
    if batch < 1 or seq_len < 1:
        raise ValueError(f"need batch, seq_len >= 1; got {batch}, {seq_len}")
    shape = token_shape(cfg, batch, seq_len)
    return shape[0] * shape[1]


def routing_sparsity(cfg) -> float:
    """Expert-routing sparsity: active fraction of expert capacity, in (0, 1].

    ``top_k / num_experts`` for MoE configs (mixtral 2/8 = 0.25, llama4
    1/128), 1.0 for dense models (every FFN row is active).
    """
    if cfg.num_experts > 1:
        return cfg.top_k / cfg.num_experts
    return 1.0


# ---------------------------------------------------------------------------
# Per-block expansions (t = token batch of the step)
# ---------------------------------------------------------------------------


def _attn_gemms(cfg, t: int) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    q_out = cfg.num_heads * cfg.head_dim
    kv_out = cfg.num_kv_heads * cfg.head_dim
    return [
        ("attn.q_proj", Gemm("q_proj", t, d, q_out), 1, None),
        ("attn.k_proj", Gemm("k_proj", t, d, kv_out), 1, None),
        ("attn.v_proj", Gemm("v_proj", t, d, kv_out), 1, None),
        ("attn.o_proj", Gemm("o_proj", t, q_out, d), 1, None),
    ]


def _mamba_gemms(cfg, t: int) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = cfg.dt_rank
    # the depthwise conv is not a GEMM; x_proj/dt_proj consume post-SiLU
    # conv output (half-zero streams)
    return [
        ("mamba.in_proj", Gemm("in_proj", t, d, 2 * di), 1, None),
        ("mamba.x_proj", Gemm("x_proj", t, di, dtr + 2 * n), 1, _POST_ACT_DENSITY),
        ("mamba.dt_proj", Gemm("dt_proj", t, dtr, di), 1, None),
        ("mamba.out_proj", Gemm("out_proj", t, di, d), 1, _POST_ACT_DENSITY),
    ]


def _mlstm_gemms(cfg, t: int) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    h = cfg.num_heads
    dh = di // h
    return [
        ("mlstm.w_up", Gemm("w_up", t, d, 2 * di), 1, None),
        # block-diagonal per-head q/k/v: h independent (t, dh) @ (dh, dh)
        ("mlstm.wqkv", Gemm("wqkv", t, dh, dh), 3 * h, None),
        ("mlstm.gates", Gemm("gates", t, di, h), 2, None),
        ("mlstm.w_down", Gemm("w_down", t, di, d), 1, _POST_ACT_DENSITY),
    ]


def _slstm_gemms(cfg, t: int) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    # xLSTM sLSTM post-recurrence gated MLP width (models/xlstm.py)
    ff = max(128, int(round(cfg.xlstm_slstm_pf * d / 128)) * 128)
    return [
        # four gate input projections z/i/f/o, each (t, d) @ (d, d)
        ("slstm.w_gates", Gemm("w_gates", t, d, d), 4, None),
        # per-head block-diagonal recurrent matrices, every token, every gate
        ("slstm.r_gates", Gemm("r_gates", t, dh, dh), 4 * h, None),
        ("slstm.ff_gate", Gemm("ff_gate", t, d, ff), 1, None),
        ("slstm.ff_down", Gemm("ff_down", t, ff, d), 1, _POST_ACT_DENSITY),
    ]


def _dense_mlp_gemms(
    cfg, t: int, d_ff: int, prefix: str = "mlp"
) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    out = [(f"{prefix}.w_gate", Gemm("w_gate", t, d, d_ff), 1, None)]
    if cfg.gated_mlp:
        out.append((f"{prefix}.w_up", Gemm("w_up", t, d, d_ff), 1, None))
    out.append((f"{prefix}.w_down", Gemm("w_down", t, d_ff, d), 1, _POST_ACT_DENSITY))
    return out


def _moe_gemms(cfg, t: int) -> list[tuple[str, Gemm, int, float | None]]:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    # routing sparsity as per-expert effective batch: t*top_k active rows
    # spread over E experts — never below one row per expert
    m_e = max(1, round(t * routing_sparsity(cfg)))
    out = [
        ("moe.router", Gemm("router", t, d, e), 1, None),
        ("moe.expert_gate", Gemm("expert_gate", m_e, d, ff), e, None),
    ]
    if cfg.gated_mlp:
        out.append(("moe.expert_up", Gemm("expert_up", m_e, d, ff), e, None))
    out.append(("moe.expert_down", Gemm("expert_down", m_e, ff, d), e, _POST_ACT_DENSITY))
    if cfg.num_shared_experts:
        out += _dense_mlp_gemms(
            cfg, t, ff * cfg.num_shared_experts, prefix="moe.shared"
        )
    return out


_MIXERS = {
    "attn": _attn_gemms,
    "mamba": _mamba_gemms,
    "mlstm": _mlstm_gemms,
    "slstm": _slstm_gemms,
}


def expand_arch(
    cfg, regime: str, batch: int, seq_len: int = 1
) -> list[ServingGemm]:
    """Expand one serving step of ``cfg`` into its per-block GEMM job set.

    Walks the stage pattern once per distinct (mixer, mlp) pair and scales
    counts by how often the pair occurs across the whole stack (jamba's 7:1
    mamba:attn ratio collapses to two mixer entries with counts 28 and 4),
    then appends the LM head (one per codebook — musicgen's 4 parallel
    heads).  Returns entries in deterministic walk order.
    """
    t = regime_tokens(cfg, regime, batch, seq_len)
    pair_counts = Counter(cfg.stage_pattern)
    out: list[ServingGemm] = []

    def emit(entries, repeat: int):
        for block, gemm, count, density in entries:
            out.append(
                ServingGemm(
                    gemm=gemm,
                    block=block,
                    regime=regime,
                    count=count * repeat,
                    input_density=density,
                )
            )

    # iterate pairs in first-occurrence order for deterministic output
    seen: list[tuple] = []
    for pair in cfg.stage_pattern:
        if pair in seen:
            continue
        seen.append(pair)
        mixer, mlp = pair
        repeat = pair_counts[pair] * cfg.n_stages
        if mixer not in _MIXERS:
            raise ValueError(f"{cfg.name}: unknown mixer kind {mixer!r}")
        emit(_MIXERS[mixer](cfg, t), repeat)
        if mlp == "moe":
            emit(_moe_gemms(cfg, t), repeat)
        elif mlp == "dense":
            if cfg.d_ff <= 0:
                raise ValueError(f"{cfg.name}: dense MLP with d_ff={cfg.d_ff}")
            emit(_dense_mlp_gemms(cfg, t, cfg.d_ff), repeat)
        elif mlp != "none":
            raise ValueError(f"{cfg.name}: unknown mlp kind {mlp!r}")

    emit(
        [("head.lm_head", Gemm("lm_head", t, cfg.d_model, cfg.vocab_size), 1, None)],
        cfg.num_codebooks,
    )
    return validate_job_set(out)


def expand_shape(cfg, shape) -> list[ServingGemm]:
    """Expand a registry ``ShapeSpec`` cell (prefill_32k, decode_32k, ...).

    Decode cells use only the global batch (seq_len parameterizes the KV
    cache, not the per-step GEMMs); train cells expand like prefill (the
    forward GEMM set — backward doubles it but adds no new shapes).
    """
    regime = "decode" if shape.kind == "decode" else "prefill"
    if regime == "decode":
        return expand_arch(cfg, "decode", shape.global_batch)
    return expand_arch(cfg, "prefill", shape.global_batch, shape.seq_len)


def validate_job_set(jobs: Sequence[ServingGemm]) -> list[ServingGemm]:
    """Contract check: non-empty, positive shapes/counts, known regimes."""
    jobs = list(jobs)
    if not jobs:
        raise ValueError("empty GEMM job set")
    for j in jobs:
        # ServingGemm.__post_init__ already validated; re-assert the
        # aggregate invariant cheaply for externally assembled sets
        if j.macs <= 0:
            raise ValueError(f"{j.block}: non-positive MACs")
    return jobs
