"""Serving-traffic workload subsystem: model configs + traffic mixes ->
GEMM job sets -> J/token design-space answers (DESIGN.md §Serving-workloads).

Three layers: ``expand`` (ArchConfig x regime -> per-block GEMM shapes),
``traffic`` (seeded steady-state traffic -> MAC-share-weighted job sets),
``codesign`` (job sets -> measured activities -> fleet J/op -> J/token).
"""

from repro.serving.codesign import (
    DEFAULT_FAMILIES,
    DEFAULT_SPACE,
    CodesignResult,
    cnn_reference,
    codesign,
    regime_best_cell,
)
from repro.serving.expand import (
    REGIMES,
    ServingGemm,
    expand_arch,
    expand_shape,
    regime_tokens,
    routing_sparsity,
    validate_job_set,
)
from repro.serving.traffic import (
    PRESETS,
    ServingJobSet,
    TrafficClass,
    TrafficModel,
    get_preset,
    sample_requests,
    traffic_classes,
    weighted_gemms,
)

__all__ = [
    "REGIMES",
    "PRESETS",
    "DEFAULT_SPACE",
    "DEFAULT_FAMILIES",
    "ServingGemm",
    "ServingJobSet",
    "TrafficClass",
    "TrafficModel",
    "CodesignResult",
    "expand_arch",
    "expand_shape",
    "regime_tokens",
    "routing_sparsity",
    "validate_job_set",
    "get_preset",
    "sample_requests",
    "traffic_classes",
    "weighted_gemms",
    "codesign",
    "cnn_reference",
    "regime_best_cell",
]
