"""Seeded traffic model: request distributions -> MAC-share-weighted job sets.

Layer 2 of the serving subsystem (DESIGN.md §Serving-workloads).  A
``TrafficModel`` describes one serving replica's steady-state second —
request rate, log-normal prompt/generation length distributions, and the
continuous-batching knobs (decode step time, prefill batching window,
batch caps).  Everything downstream is a deterministic function of the
model's seed:

  1. ``sample_requests`` draws N requests (prompt len, gen len, arrival
     time) from one ``np.random.default_rng(seed)`` stream;
  2. ``traffic_classes`` folds them into a handful of (regime, batch,
     seq) shape classes: prefill requests bucket by power-of-two prompt
     length and batch by arrivals per batching window; decode batch sizes
     come from the sampled in-flight concurrency (each request occupies
     the decode pool for ``gen_len * decode_step_s`` seconds — Little's
     law made empirical), bucketed to powers of two under the
     continuous-batching cap.  Each class carries its token rate and
     execution rate for the steady-state second;
  3. ``weighted_gemms`` expands every class through ``serving.expand`` and
     weights each GEMM shape class by its MAC share of that second —
     weights sum to 1 exactly, and ``macs_per_token`` (total MAC/s over
     total served tokens/s) is the bridge from the design-space engine's
     J/op answers to J/token.

At fleet scale ("millions of users") traffic shards across replicas; the
QPS here is per replica — the quantity one systolic array actually sees.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.workloads import Gemm
from repro.serving.expand import ServingGemm, expand_arch

__all__ = [
    "TrafficModel",
    "TrafficClass",
    "ServingJobSet",
    "PRESETS",
    "get_preset",
    "sample_requests",
    "traffic_classes",
    "weighted_gemms",
]


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """One replica's steady-state serving traffic, fully seeded.

    ``prompt_len``/``gen_len`` are log-normal in TOKEN space: the tuple is
    (mean tokens, sigma of log) — mean is the actual distribution mean, so
    ``prefill_decode_ratio`` is exactly ``prompt_mean / gen_mean``.
    """

    name: str
    qps: float  # requests/s into this replica
    prompt_len: tuple[float, float]  # (mean tokens, log-space sigma)
    gen_len: tuple[float, float]
    max_prompt: int = 32768
    max_gen: int = 8192
    decode_step_s: float = 0.02  # nominal decode step latency (pool residency)
    prefill_window_s: float = 0.05  # arrivals batched per prefill launch
    max_decode_batch: int = 256  # continuous-batching concurrency cap
    max_prefill_batch: int = 32
    min_seq_bucket: int = 16  # smallest power-of-two prefill bucket
    n_samples: int = 2048  # sampled requests per draw
    n_probes: int = 256  # concurrency probe instants
    seed: int = 0

    def __post_init__(self):
        if self.qps <= 0:
            raise ValueError("qps must be positive")
        for label, (mean, sigma) in (
            ("prompt_len", self.prompt_len),
            ("gen_len", self.gen_len),
        ):
            if mean < 1 or sigma < 0:
                raise ValueError(f"{label}: need mean >= 1, sigma >= 0")
        if self.n_samples < 2 or self.n_probes < 2:
            raise ValueError("need n_samples, n_probes >= 2")

    @property
    def prefill_decode_ratio(self) -> float:
        """Target prefill:decode token ratio (prompt mean over gen mean)."""
        return self.prompt_len[0] / self.gen_len[0]

    def with_ratio(self, ratio: float) -> "TrafficModel":
        """Same traffic with the gen-length mean rescaled so that
        prompt:gen token ratio == ``ratio`` (the ratio-sweep knob)."""
        if ratio <= 0:
            raise ValueError("ratio must be positive")
        return dataclasses.replace(
            self,
            name=f"{self.name}@pd{ratio:g}",
            gen_len=(self.prompt_len[0] / ratio, self.gen_len[1]),
        )


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One (regime, batch, seq) shape class of the steady-state second."""

    regime: str  # "prefill" | "decode"
    batch: int  # prefill: requests per launch; decode: step batch size
    seq_len: int  # prefill: padded bucket length; decode: 1
    tokens_per_s: float  # actual (unpadded) served tokens attributed here
    execs_per_s: float  # forward-step executions per second

    @property
    def tokens_per_exec(self) -> int:
        return self.batch * self.seq_len


def _lognormal_lens(rng, mean: float, sigma: float, lo: int, hi: int, n: int):
    """Log-normal token lengths with the given DISTRIBUTION mean."""
    mu = math.log(mean) - 0.5 * sigma * sigma
    lens = np.rint(rng.lognormal(mu, sigma, size=n)).astype(np.int64)
    return np.clip(lens, lo, hi)


def sample_requests(tm: TrafficModel):
    """Seeded request draw: (prompt_lens, gen_lens, arrival_s), arrivals
    uniform over a window of ``n_samples / qps`` seconds (sorted)."""
    rng = np.random.default_rng(tm.seed)
    prompts = _lognormal_lens(rng, *tm.prompt_len, 1, tm.max_prompt, tm.n_samples)
    gens = _lognormal_lens(rng, *tm.gen_len, 1, tm.max_gen, tm.n_samples)
    window_s = tm.n_samples / tm.qps
    arrivals = np.sort(rng.uniform(0.0, window_s, size=tm.n_samples))
    return prompts, gens, arrivals


def _pow2_bucket(x, lo: int, hi: int):
    """Round up to the nearest power of two in [lo, hi] (vectorized)."""
    x = np.maximum(np.asarray(x, np.int64), 1)
    exp = np.ceil(np.log2(x)).astype(np.int64)
    return np.clip(2 ** exp, lo, hi)


def traffic_classes(tm: TrafficModel) -> list[TrafficClass]:
    """The steady-state second as a small list of weighted shape classes."""
    prompts, gens, arrivals = sample_requests(tm)
    window_s = tm.n_samples / tm.qps
    classes: list[TrafficClass] = []

    # --- prefill: bucket prompts by power-of-two length ---------------------
    seq_buckets = _pow2_bucket(prompts, tm.min_seq_bucket, tm.max_prompt)
    for bucket in sorted(np.unique(seq_buckets)):
        in_b = seq_buckets == bucket
        rate_b = float(in_b.sum()) / window_s  # requests/s at this length
        batch = int(np.clip(round(rate_b * tm.prefill_window_s), 1, tm.max_prefill_batch))
        classes.append(
            TrafficClass(
                regime="prefill",
                batch=batch,
                seq_len=int(bucket),
                tokens_per_s=float(prompts[in_b].sum()) / window_s,
                execs_per_s=rate_b / batch,
            )
        )

    # --- decode: in-flight concurrency under continuous batching ------------
    # each request occupies the decode pool for gen * decode_step_s seconds
    # starting at its arrival; probe the pool at n_probes instants of the
    # interior of the window (edges are cold-start / drain artifacts)
    durations = gens.astype(np.float64) * tm.decode_step_s
    t0, t1 = 0.1 * window_s, 0.9 * window_s
    probes = np.linspace(t0, t1, tm.n_probes)
    conc = (
        (arrivals[None, :] <= probes[:, None])
        & (probes[:, None] < (arrivals + durations)[None, :])
    ).sum(axis=1)
    live = conc > 0
    total_decode_tok = float(gens.sum()) / window_s  # served decode tokens/s
    if live.any():
        batch_eff = np.minimum(conc[live], tm.max_decode_batch)
        buckets = _pow2_bucket(batch_eff, 1, tm.max_decode_batch)
        # token throughput share of each batch bucket ~ observed step width
        share = np.zeros(0)
        uniq = sorted(np.unique(buckets))
        share = np.array(
            [float(batch_eff[buckets == b].sum()) for b in uniq], np.float64
        )
        share = share / share.sum()
        for b, s in zip(uniq, share):
            tok_b = total_decode_tok * float(s)
            classes.append(
                TrafficClass(
                    regime="decode",
                    batch=int(b),
                    seq_len=1,
                    tokens_per_s=tok_b,
                    execs_per_s=tok_b / float(b),
                )
            )
    else:  # degenerate ultra-light traffic: a single batch-1 decode class
        classes.append(
            TrafficClass("decode", 1, 1, total_decode_tok, total_decode_tok)
        )
    return classes


# ---------------------------------------------------------------------------
# The weighted GEMM job set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingJobSet:
    """(model x traffic) -> deduped GEMM shape classes + MAC-share weights.

    ``weights`` sum to 1 and are each GEMM's share of the replica's total
    MAC/s; ``mac_rate`` keeps the unnormalized MAC/s.  ``macs_per_token``
    bridges J/op to J/token: J/token = j_per_mac * macs_per_token.
    """

    arch: str
    traffic: str
    gemms: tuple[Gemm, ...]
    weights: np.ndarray  # (G,) MAC shares, sum == 1
    mac_rate: np.ndarray  # (G,) MAC/s
    regimes: tuple[str, ...]  # per-GEMM regime
    densities: tuple[float | None, ...]  # per-GEMM operand density hint
    classes: tuple[TrafficClass, ...]
    tokens_per_s: float  # served tokens/s (prefill + decode, unpadded)

    @property
    def macs_per_token(self) -> float:
        return float(self.mac_rate.sum() / self.tokens_per_s)

    def regime_weights(self, regime: str) -> np.ndarray:
        """Weights restricted to one regime (zero elsewhere, unnormalized)."""
        mask = np.asarray([r == regime for r in self.regimes], float)
        return np.asarray(self.weights) * mask


def weighted_gemms(cfg, tm: TrafficModel, *, arch_name: str | None = None) -> ServingJobSet:
    """Expand ``cfg`` under every traffic class and weight by MAC share.

    Identical (regime, block, m, k, n) shape classes across traffic classes
    merge into one entry whose MAC/s accumulates in deterministic class
    order — the numpy-oracle re-derivation in benchmarks/bench_serving.py
    reproduces these weights bit-exactly.
    """
    classes = traffic_classes(tm)
    order: dict[tuple, int] = {}
    entries: list[ServingGemm] = []
    rates: list[float] = []
    for tc in classes:
        for sg in expand_arch(cfg, tc.regime, tc.batch, tc.seq_len):
            key = (sg.regime, sg.block, sg.gemm.m, sg.gemm.k, sg.gemm.n)
            idx = order.get(key)
            if idx is None:
                order[key] = len(entries)
                entries.append(sg)
                rates.append(0.0)
                idx = order[key]
            rates[idx] += tc.execs_per_s * sg.macs
    mac_rate = np.asarray(rates, np.float64)
    weights = mac_rate / mac_rate.sum()
    gemms = tuple(
        Gemm(f"{sg.regime[:3]}.{sg.block}", sg.gemm.m, sg.gemm.k, sg.gemm.n)
        for sg in entries
    )
    return ServingJobSet(
        arch=arch_name or getattr(cfg, "name", "?"),
        traffic=tm.name,
        gemms=gemms,
        weights=weights,
        mac_rate=mac_rate,
        regimes=tuple(sg.regime for sg in entries),
        densities=tuple(sg.input_density for sg in entries),
        classes=tuple(classes),
        tokens_per_s=float(sum(tc.tokens_per_s for tc in classes)),
    )


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# Per-replica traffic regimes.  prefill_heavy is the RAG/summarization
# shape (long prompts, terse answers, ~48:1 prefill:decode tokens);
# decode_heavy is the chat/agent shape (short prompts, long generations,
# ~1:5) whose steady-state decode pool rides the continuous-batching cap —
# skinny M=batch GEMMs dominating the MAC budget.
PRESETS: dict[str, TrafficModel] = {
    "prefill_heavy": TrafficModel(
        name="prefill_heavy",
        qps=8.0,
        prompt_len=(6144.0, 0.6),
        gen_len=(128.0, 0.5),
    ),
    "decode_heavy": TrafficModel(
        name="decode_heavy",
        qps=8.0,
        prompt_len=(192.0, 0.6),
        gen_len=(1024.0, 0.5),
    ),
    "balanced": TrafficModel(
        name="balanced",
        qps=8.0,
        prompt_len=(1024.0, 0.7),
        gen_len=(512.0, 0.6),
    ),
}


def get_preset(name: str) -> TrafficModel:
    if isinstance(name, TrafficModel):
        return name
    if name not in PRESETS:
        raise KeyError(f"unknown traffic preset {name!r}; known: {sorted(PRESETS)}")
    return PRESETS[name]
