"""Serving co-design: (model, traffic) -> measured J/token design answers.

Layer 3 of the serving subsystem (DESIGN.md §Serving-workloads).  One call
answers "which array geometry x layout family x dataflow x coding
minimizes J/token for THIS model at THIS traffic mix":

  1. ``weighted_gemms`` turns (config, traffic model) into a MAC-share-
     weighted GEMM job set (``serving.traffic``);
  2. ``measured_design_gemm_activities`` profiles one synthetic-but-seeded
     operand stream per activity class per GEMM shape class (clipped dims,
     content-keyed seeds -> the v4 profile store dedups across models and
     traffic mixes);
  3. ``evaluate_fleet_objective`` prices total J per useful MAC over the
     (GEMM, layout, point) block in one jitted program — utilization and
     spill/trunk traffic from the FULL GEMM dims — with the job set's
     ``macs_per_token`` attached so ``j_per_token_robust`` is exact.

The result also carries per-regime optima (decode-only / prefill-only
re-weighting of the priced ``j_per_mac`` block): decode-time skinny GEMMs
should — and measurably do — pick different geometry/layout cells than
both the prefill mix and the paper's Table-I CNN layers.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.configs.registry import ArchConfig, get_arch
from repro.core.design_space import DesignSpace
from repro.core.objective import evaluate_fleet_objective
from repro.core.workloads import (
    RESNET50_TABLE1,
    conv_to_gemm,
    measured_design_activities,
    measured_design_gemm_activities,
)
from repro.serving.traffic import ServingJobSet, TrafficModel, get_preset, weighted_gemms

__all__ = [
    "CodesignResult",
    "DEFAULT_SPACE",
    "DEFAULT_FAMILIES",
    "codesign",
    "regime_best_cell",
    "cnn_reference",
]

# The explore-example grid: small enough for interactive runs, wide enough
# (rows x cols x WS/OS x coding) that serving mixes can move the optimum.
DEFAULT_SPACE = DesignSpace(
    rows=(16, 32),
    cols=(8, 16, 32, 64, 128),
    input_bits=(16,),
    dataflows=("WS", "OS"),
    bus_invert=(False, True),
)

DEFAULT_FAMILIES = ("uniform", "serpentine2", "pods2x2", "pods4x4")


@dataclasses.dataclass(frozen=True)
class CodesignResult:
    """One (model, traffic) co-design answer over a design grid."""

    arch: str
    traffic: str
    jobset: ServingJobSet
    grid: object  # DesignGrid
    eval: object  # LayoutSpaceEval with J/op + macs_per_token priced
    layouts: tuple[str, ...]

    @property
    def best_cell(self) -> tuple[int, int]:
        """(layout_idx, point_idx) minimizing fleet J/op == J/token."""
        j = np.asarray(self.eval.j_per_mac_robust)
        return tuple(int(i) for i in np.unravel_index(np.argmin(j), j.shape))

    @property
    def j_per_token(self) -> float:
        """J per served token at the best (layout, point) cell."""
        li, pi = self.best_cell
        return float(self.eval.j_per_token_robust[li, pi])

    def regime_cell(self, regime: str) -> tuple[int, int]:
        return regime_best_cell(self.eval, self.jobset, regime)

    def describe_cell(self, cell: tuple[int, int]) -> str:
        li, pi = cell
        return f"{self.layouts[li]} @ {self.grid.describe(pi)}"


def regime_best_cell(ev, jobset: ServingJobSet, regime: str) -> tuple[int, int]:
    """(layout_idx, point_idx) minimizing J/op under ONE regime's weights.

    Re-weights the already-priced per-GEMM ``j_per_mac`` block (W, L, P)
    with the job set's regime-restricted MAC shares — no re-evaluation.
    """
    w = jobset.regime_weights(regime)
    if w.sum() <= 0:
        raise ValueError(f"job set has no {regime!r} MAC share")
    w = w / w.sum()
    j = np.asarray(ev.j_per_mac)  # (W, L, P), +inf on infeasible cells
    jr = np.einsum("w,wlp->lp", w, j)
    jr = np.where(np.isfinite(jr), jr, np.inf)
    return tuple(int(i) for i in np.unravel_index(np.argmin(jr), jr.shape))


def codesign(
    arch: str | ArchConfig,
    traffic: str | TrafficModel,
    *,
    space: DesignSpace = DEFAULT_SPACE,
    layouts: Sequence[str] = DEFAULT_FAMILIES,
    clip: tuple[int, int, int] | None = (128, 512, 256),
    backend: str | None = None,
    use_cache: bool = True,
    use_jit: bool | None = None,
    sweep=None,
) -> CodesignResult:
    """Measured end-to-end serving co-design for one (model, traffic) pair."""
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    tm = get_preset(traffic) if isinstance(traffic, str) else traffic
    jobset = weighted_gemms(cfg, tm)
    grid = space.expand()
    a_h, a_v = measured_design_gemm_activities(
        grid,
        jobset.gemms,
        densities=jobset.densities,
        clip=clip,
        backend=backend,
        use_cache=use_cache,
    )
    ev = evaluate_fleet_objective(
        grid,
        a_h,
        a_v,
        jobset.gemms,
        layouts=tuple(layouts),
        weights=jobset.weights,
        use_jit=use_jit,
        sweep=sweep,
        macs_per_token=jobset.macs_per_token,
    )
    return CodesignResult(
        arch=jobset.arch,
        traffic=jobset.traffic,
        jobset=jobset,
        grid=grid,
        eval=ev,
        layouts=tuple(layouts),
    )


def cnn_reference(
    *,
    space: DesignSpace = DEFAULT_SPACE,
    layouts: Sequence[str] = DEFAULT_FAMILIES,
    n_layers: int = 3,
    backend: str | None = None,
    use_cache: bool = True,
    use_jit: bool | None = None,
) -> tuple[tuple[int, int], object]:
    """The Table-I CNN optimum on the same grid: ((layout, point), eval).

    The baseline the serving answers are compared against — the paper's
    workload never sees decode-time skinny GEMMs or MoE expert batches.
    """
    layers = RESNET50_TABLE1[:n_layers]
    grid = space.expand()
    a_h, a_v = measured_design_activities(
        grid, layers, backend=backend, use_cache=use_cache
    )
    ev = evaluate_fleet_objective(
        grid,
        a_h,
        a_v,
        [conv_to_gemm(c) for c in layers],
        layouts=tuple(layouts),
        use_jit=use_jit,
    )
    j = np.asarray(ev.j_per_mac_robust)
    cell = tuple(int(i) for i in np.unravel_index(np.argmin(j), j.shape))
    return cell, ev
