"""Memoized lowering of layout families to evaluation-ready coefficients.

``segment_class_coeffs`` renders one family over a grid; this module is the
step between it and the jitted evaluator: every requested family lowers
ONCE into stacked (layout, class, point) tensors pre-arranged for the
coefficient closed form the search runs on —

  * the DATA classes (h/v nets, schema slots 0-4) as per-class length
    polynomials in t = sqrt(aspect): ``len(t) = alpha*t + beta/t + gamma``
    with ``alpha = len_w*sqrt(area)``, ``beta = len_h*sqrt(area)``, plus
    the count-folded products (``count*alpha`` ...) the linear collapse
    consumes and ``count*width`` for the wirelength roll-up;
  * the OVERHEAD classes (preload/drain/clk, slots 5-11) kept whole for
    the single full-schema evaluation at the robust aspect;
  * the per-(layout, point) aspect window — the PE envelope intersected
    with the die-envelope constraint — and the feasibility mask;
  * the REPEATER class set: the (usually 1-2) data classes whose segment
    length can exceed the repeater spacing anywhere inside the aspect
    window.  ``len(t)`` is convex in t, so its maximum over the window
    sits at an endpoint — the prune is exact, not heuristic.  Every other
    class is plain wire (rep == 1) everywhere and folds into three linear
    scalars per cell.

Results are memoized in a small LRU keyed by a sha256 over everything the
tensors depend on (family parameters via their dataclass reprs, the grid's
struct-of-arrays fields, the aspect window, the die-envelope limit, the
repeater spacing), so repeated ``evaluate_layout_design_space`` calls in
examples/benchmarks skip re-enumeration entirely.  Each entry also holds a
lazily-created device-resident copy of its tensors: warm jitted calls reuse
the same device buffers instead of re-transferring ~tens of MB per call
(``coeff_cache_info`` exposes hit/miss/eviction counters next to
``repro.core.switching.profile_cache_info``).
"""

from __future__ import annotations

import hashlib
import os
from collections import OrderedDict

import numpy as np

from repro.layout.geometry import envelope_coeffs, get_layout
from repro.layout.segments import DATA_NETS, SEGMENT_CLASS_SCHEMA, segment_class_coeffs

__all__ = [
    "LoweredCoeffs",
    "lower_layout_coeffs",
    "coeff_cache_info",
    "clear_coeff_cache",
    "set_coeff_cache_capacity",
    "DATA_CLASS_IDX",
    "OVERHEAD_CLASS_IDX",
]

# Schema split: data classes drive the aspect search, overhead classes are
# priced once at the robust aspect.  Static — the schema is the contract.
DATA_CLASS_IDX = tuple(
    i for i, (net, _) in enumerate(SEGMENT_CLASS_SCHEMA) if net in DATA_NETS
)
OVERHEAD_CLASS_IDX = tuple(
    i for i, (net, _) in enumerate(SEGMENT_CLASS_SCHEMA) if net not in DATA_NETS
)
# (n_data,) 1.0 on h-net classes (the rest of the data block is v-net).
DATA_IS_H = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "h" else 0.0 for i in DATA_CLASS_IDX]
)
# (n_over,) net masks for the overhead block.
OVER_IS_PRELOAD = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "preload" else 0.0 for i in OVERHEAD_CLASS_IDX]
)
OVER_IS_DRAIN = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "drain" else 0.0 for i in OVERHEAD_CLASS_IDX]
)
OVER_IS_CLK = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "clk" else 0.0 for i in OVERHEAD_CLASS_IDX]
)

_COEFF_CACHE: OrderedDict[str, "LoweredCoeffs"] = OrderedDict()
_COEFF_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_COEFF_CACHE_CAPACITY = int(os.environ.get("REPRO_COEFF_CACHE_CAPACITY", "16"))

# Device tensors the jitted evaluator consumes, in call order.
DEVICE_FIELDS = (
    "count_d",
    "alpha_d",
    "beta_d",
    "gamma_d",
    "ca",
    "cb",
    "cg",
    "cwidth_d",
    "width_d",
    "lane0_d",
    "count_o",
    "width_o",
    "alpha_o",
    "beta_o",
    "gamma_o",
    "t_lo",
    "t_hi",
)


class LoweredCoeffs:
    """One memoized lowering: host tensors + a lazy device-resident copy.

    Shapes: data block (L, n_data, P), overhead block (L, n_over, P),
    windows (L, P).  ``rep_idx`` indexes the data-class axis.
    """

    __slots__ = ("layouts", "key", "rep_idx", "host", "_device")

    def __init__(self, layouts, key, rep_idx, host):
        self.layouts = tuple(layouts)
        self.key = key
        self.rep_idx = tuple(int(i) for i in rep_idx)
        self.host = host  # dict: DEVICE_FIELDS + feasible/lo/hi
        self._device = None

    def device(self) -> dict:
        """Device-resident copies of the evaluation tensors (created once)."""
        if self._device is None:
            import jax

            self._device = {
                k: jax.device_put(self.host[k]) for k in DEVICE_FIELDS
            }
        return self._device


def _evict_to_capacity() -> None:
    while len(_COEFF_CACHE) > _COEFF_CACHE_CAPACITY:
        _COEFF_CACHE.popitem(last=False)
        _COEFF_CACHE_STATS["evictions"] += 1


def coeff_cache_info() -> dict:
    return {
        "size": len(_COEFF_CACHE),
        "capacity": _COEFF_CACHE_CAPACITY,
        **_COEFF_CACHE_STATS,
    }


def clear_coeff_cache() -> None:
    _COEFF_CACHE.clear()
    for k in _COEFF_CACHE_STATS:
        _COEFF_CACHE_STATS[k] = 0


def set_coeff_cache_capacity(capacity: int) -> int:
    """Set the LRU capacity (entries); returns the previous value."""
    global _COEFF_CACHE_CAPACITY
    if int(capacity) < 1:
        raise ValueError("cache capacity must be >= 1")
    prev = _COEFF_CACHE_CAPACITY
    _COEFF_CACHE_CAPACITY = int(capacity)
    _evict_to_capacity()
    return prev


def _content_key(grid, layout_names, max_envelope_aspect, spacing) -> str:
    h = hashlib.sha256()
    for name in layout_names:
        # the instance repr carries every family parameter (k, gutter, folds)
        h.update(f"{name}={get_layout(name)!r};".encode())
    for tag, arr, dt in (
        ("rows", grid.rows, np.int64),
        ("cols", grid.cols, np.int64),
        ("b_h", grid.b_h, np.int64),
        ("b_v", grid.b_v, np.int64),
        ("os", grid.dataflow_os, np.uint8),
        ("area", grid.pe_area_um2, np.float64),
    ):
        h.update(tag.encode())
        h.update(np.ascontiguousarray(np.asarray(arr, dt)).tobytes())
    h.update(
        f"|{float(grid.aspect_lo)!r}|{float(grid.aspect_hi)!r}"
        f"|{max_envelope_aspect!r}|{float(spacing)!r}".encode()
    )
    return h.hexdigest()


def lower_layout_coeffs(
    grid,
    layouts,
    *,
    max_envelope_aspect: float | None = None,
    repeater_spacing_um: float = 200.0,
) -> LoweredCoeffs:
    """Lower ``layouts`` over ``grid`` into evaluation-ready tensors (memoized)."""
    layout_names = tuple(layouts)
    if max_envelope_aspect is not None and float(max_envelope_aspect) < 1.0:
        raise ValueError("max_envelope_aspect must be >= 1")
    key = _content_key(grid, layout_names, max_envelope_aspect, repeater_spacing_um)
    hit = _COEFF_CACHE.get(key)
    if hit is not None:
        _COEFF_CACHE.move_to_end(key)
        _COEFF_CACHE_STATS["hits"] += 1
        return hit
    _COEFF_CACHE_STATS["misses"] += 1

    p = grid.n_points
    rows = np.asarray(grid.rows, float)
    cols = np.asarray(grid.cols, float)
    b_h = np.asarray(grid.b_h, float)
    b_v = np.asarray(grid.b_v, float)
    os_mask = np.asarray(grid.dataflow_os, bool)
    sqrt_area = np.sqrt(np.asarray(grid.pe_area_um2, float))
    n_l = len(layout_names)
    di = list(DATA_CLASS_IDX)
    oi = list(OVERHEAD_CLASS_IDX)

    count = np.zeros((n_l, len(SEGMENT_CLASS_SCHEMA), p))
    len_w = np.zeros_like(count)
    len_h = np.zeros_like(count)
    len_c = np.zeros_like(count)
    width = np.zeros_like(count)
    lane0 = np.zeros_like(count)
    feasible = np.zeros((n_l, p), bool)
    lo = np.zeros((n_l, p))
    hi = np.zeros((n_l, p))

    for li, name in enumerate(layout_names):
        layout = get_layout(name)
        cc = segment_class_coeffs(layout, rows, cols, b_h, b_v, os_mask)
        count[li] = cc["count"]
        len_w[li] = cc["len_w"]
        len_h[li] = cc["len_h"]
        len_c[li] = cc["len_c"]
        width[li] = cc["width"]
        lane0[li] = cc["lane0"]
        # Aspect window: PE envelope intersected with the die-envelope
        # constraint (gutter constants neglected in the bound — they are
        # small against the array span and only loosen it marginally).
        ew_w, _, eh_h, _ = envelope_coeffs(layout, rows, cols)
        l_lo = np.full(p, float(grid.aspect_lo))
        l_hi = np.full(p, float(grid.aspect_hi))
        if max_envelope_aspect is not None:
            e = float(max_envelope_aspect)
            ratio = ew_w / eh_h
            l_lo = np.maximum(l_lo, 1.0 / (e * ratio))
            l_hi = np.minimum(l_hi, e / ratio)
        ok = np.asarray(cc["feasible"], bool) & (l_lo < l_hi)
        feasible[li] = ok
        lo[li] = np.where(ok, l_lo, 1.0)
        hi[li] = np.where(ok, l_hi, 1.0 + 1e-9)

    alpha = len_w * sqrt_area
    beta = len_h * sqrt_area
    gamma = len_c
    t_lo = np.sqrt(lo)
    t_hi = np.sqrt(hi)

    # Exact repeater prune: len(t) is convex in t, so its window maximum is
    # at an endpoint.  A data class joins the repeater set iff some live
    # (feasible, count > 0) cell can exceed the spacing inside its window.
    rep_idx = []
    for j, ci in enumerate(di):
        ln_ends = np.maximum(
            alpha[:, ci] * t_lo + beta[:, ci] / t_lo + gamma[:, ci],
            alpha[:, ci] * t_hi + beta[:, ci] / t_hi + gamma[:, ci],
        )
        live = feasible & (count[:, ci] > 0)
        if bool((ln_ends[live] > float(repeater_spacing_um)).any()):
            rep_idx.append(j)

    host = {
        "count_d": count[:, di],
        "alpha_d": alpha[:, di],
        "beta_d": beta[:, di],
        "gamma_d": gamma[:, di],
        "ca": count[:, di] * alpha[:, di],
        "cb": count[:, di] * beta[:, di],
        "cg": count[:, di] * gamma[:, di],
        "cwidth_d": count[:, di] * width[:, di],
        "width_d": width[:, di],
        "lane0_d": lane0[:, di].astype(np.int64),
        "count_o": count[:, oi],
        "width_o": width[:, oi],
        "alpha_o": alpha[:, oi],
        "beta_o": beta[:, oi],
        "gamma_o": gamma[:, oi],
        "t_lo": t_lo,
        "t_hi": t_hi,
        "feasible": feasible,
        "lo": lo,
        "hi": hi,
    }
    host = {
        k: np.ascontiguousarray(v) if isinstance(v, np.ndarray) else v
        for k, v in host.items()
    }
    entry = LoweredCoeffs(layout_names, key, rep_idx, host)
    _COEFF_CACHE[key] = entry
    _evict_to_capacity()
    return entry
