"""Memoized lowering of layout families to evaluation-ready coefficients.

``segment_class_coeffs`` renders one family over a grid; this module is the
step between it and the jitted evaluator: every requested family lowers
ONCE into stacked (layout, class, point) tensors pre-arranged for the
coefficient closed form the search runs on —

  * the DATA classes (h/v nets, schema slots 0-4) as per-class length
    polynomials in t = sqrt(aspect): ``len(t) = alpha*t + beta/t + gamma``
    with ``alpha = len_w*sqrt(area)``, ``beta = len_h*sqrt(area)``, plus
    the count-folded products (``count*alpha`` ...) the linear collapse
    consumes and ``count*width`` for the wirelength roll-up;
  * the OVERHEAD classes (preload/drain/clk, slots 5-11) kept whole for
    the single full-schema evaluation at the robust aspect;
  * the per-(layout, point) aspect window — the PE envelope intersected
    with the die-envelope constraint — and the feasibility mask;
  * the REPEATER class set: the (usually 1-2) data classes whose segment
    length can exceed the repeater spacing anywhere inside the aspect
    window.  ``len(t)`` is convex in t, so its maximum over the window
    sits at an endpoint — the prune is exact, not heuristic.  Every other
    class is plain wire (rep == 1) everywhere and folds into three linear
    scalars per cell.

Results are memoized in a small LRU keyed by a sha256 over everything the
tensors depend on (family parameters via their dataclass reprs, the grid's
struct-of-arrays fields, the aspect window, the die-envelope limit, the
repeater spacing), so repeated ``evaluate_layout_design_space`` calls in
examples/benchmarks skip re-enumeration entirely.  Each entry also holds a
lazily-created device-resident copy of its tensors: warm jitted calls reuse
the same device buffers instead of re-transferring ~tens of MB per call
(``coeff_cache_info`` exposes hit/miss/eviction counters next to
``repro.core.switching.profile_cache_info``).
"""

from __future__ import annotations

import hashlib
import os
from collections import OrderedDict

import numpy as np

from repro.core.optimize import bus_invert_activity_arr
from repro.layout.geometry import envelope_coeffs, get_layout
from repro.layout.segments import DATA_NETS, SEGMENT_CLASS_SCHEMA, segment_class_coeffs

__all__ = [
    "LoweredCoeffs",
    "LoweredTensors",
    "lower_layout_coeffs",
    "lower_partition_coeffs",
    "lower_coding_multipliers",
    "grid_coding_effective",
    "coeff_cache_info",
    "clear_coeff_cache",
    "set_coeff_cache_capacity",
    "CODING_SCHEMES",
    "DATA_CLASS_IDX",
    "OVERHEAD_CLASS_IDX",
    "V_HOP_DATA_IDX",
    "V_CROSS_DATA_IDX",
]

# Schema split: data classes drive the aspect search, overhead classes are
# priced once at the robust aspect.  Static — the schema is the contract.
DATA_CLASS_IDX = tuple(
    i for i, (net, _) in enumerate(SEGMENT_CLASS_SCHEMA) if net in DATA_NETS
)
OVERHEAD_CLASS_IDX = tuple(
    i for i, (net, _) in enumerate(SEGMENT_CLASS_SCHEMA) if net not in DATA_NETS
)
# (n_data,) 1.0 on h-net classes (the rest of the data block is v-net).
DATA_IS_H = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "h" else 0.0 for i in DATA_CLASS_IDX]
)
# (n_over,) net masks for the overhead block.
OVER_IS_PRELOAD = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "preload" else 0.0 for i in OVERHEAD_CLASS_IDX]
)
OVER_IS_DRAIN = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "drain" else 0.0 for i in OVERHEAD_CLASS_IDX]
)
OVER_IS_CLK = np.asarray(
    [1.0 if SEGMENT_CLASS_SCHEMA[i][0] == "clk" else 0.0 for i in OVERHEAD_CLASS_IDX]
)
# Positions of the two classes the J/op objective prices word traffic on,
# within the DATA block: spill words re-enter through vertical hops, K-split
# partials cross the gutter trunks.
_DATA_CLASSES = tuple(SEGMENT_CLASS_SCHEMA[i] for i in DATA_CLASS_IDX)
V_HOP_DATA_IDX = _DATA_CLASSES.index(("v", "hop"))
V_CROSS_DATA_IDX = _DATA_CLASSES.index(("v", "cross"))

_COEFF_CACHE: OrderedDict[str, "LoweredCoeffs"] = OrderedDict()
_COEFF_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
_COEFF_CACHE_CAPACITY = int(os.environ.get("REPRO_COEFF_CACHE_CAPACITY", "16"))

# Device tensors the jitted evaluator consumes, in call order.
DEVICE_FIELDS = (
    "count_d",
    "alpha_d",
    "beta_d",
    "gamma_d",
    "ca",
    "cb",
    "cg",
    "cwidth_d",
    "width_d",
    "lane0_d",
    "count_o",
    "width_o",
    "alpha_o",
    "beta_o",
    "gamma_o",
    "t_lo",
    "t_hi",
)


class LoweredCoeffs:
    """One memoized lowering: host tensors + a lazy device-resident copy.

    Shapes: data block (L, n_data, P), overhead block (L, n_over, P),
    windows (L, P).  ``rep_idx`` indexes the data-class axis.
    """

    __slots__ = ("layouts", "key", "rep_idx", "host", "_device")

    def __init__(self, layouts, key, rep_idx, host):
        self.layouts = tuple(layouts)
        self.key = key
        self.rep_idx = tuple(int(i) for i in rep_idx)
        self.host = host  # dict: DEVICE_FIELDS + feasible/lo/hi
        self._device = None

    def device(self) -> dict:
        """Device-resident copies of the evaluation tensors (created once)."""
        if self._device is None:
            import jax

            self._device = {
                k: jax.device_put(self.host[k]) for k in DEVICE_FIELDS
            }
        return self._device


def _evict_to_capacity() -> None:
    while len(_COEFF_CACHE) > _COEFF_CACHE_CAPACITY:
        _COEFF_CACHE.popitem(last=False)
        _COEFF_CACHE_STATS["evictions"] += 1


def coeff_cache_info() -> dict:
    return {
        "size": len(_COEFF_CACHE),
        "capacity": _COEFF_CACHE_CAPACITY,
        **_COEFF_CACHE_STATS,
    }


def clear_coeff_cache() -> None:
    _COEFF_CACHE.clear()
    for k in _COEFF_CACHE_STATS:
        _COEFF_CACHE_STATS[k] = 0


def set_coeff_cache_capacity(capacity: int) -> int:
    """Set the LRU capacity (entries); returns the previous value."""
    global _COEFF_CACHE_CAPACITY
    if int(capacity) < 1:
        raise ValueError("cache capacity must be >= 1")
    prev = _COEFF_CACHE_CAPACITY
    _COEFF_CACHE_CAPACITY = int(capacity)
    _evict_to_capacity()
    return prev


def _content_key(grid, layout_names, max_envelope_aspect, spacing) -> str:
    h = hashlib.sha256()
    for name in layout_names:
        # the instance repr carries every family parameter (k, gutter, folds)
        h.update(f"{name}={get_layout(name)!r};".encode())
    for tag, arr, dt in (
        ("rows", grid.rows, np.int64),
        ("cols", grid.cols, np.int64),
        ("b_h", grid.b_h, np.int64),
        ("b_v", grid.b_v, np.int64),
        ("os", grid.dataflow_os, np.uint8),
        ("area", grid.pe_area_um2, np.float64),
    ):
        h.update(tag.encode())
        h.update(np.ascontiguousarray(np.asarray(arr, dt)).tobytes())
    h.update(
        f"|{float(grid.aspect_lo)!r}|{float(grid.aspect_hi)!r}"
        f"|{max_envelope_aspect!r}|{float(spacing)!r}".encode()
    )
    return h.hexdigest()


def lower_layout_coeffs(
    grid,
    layouts,
    *,
    max_envelope_aspect: float | None = None,
    repeater_spacing_um: float = 200.0,
) -> LoweredCoeffs:
    """Lower ``layouts`` over ``grid`` into evaluation-ready tensors (memoized)."""
    layout_names = tuple(layouts)
    if max_envelope_aspect is not None and float(max_envelope_aspect) < 1.0:
        raise ValueError("max_envelope_aspect must be >= 1")
    key = _content_key(grid, layout_names, max_envelope_aspect, repeater_spacing_um)
    hit = _COEFF_CACHE.get(key)
    if hit is not None:
        _COEFF_CACHE.move_to_end(key)
        _COEFF_CACHE_STATS["hits"] += 1
        return hit
    _COEFF_CACHE_STATS["misses"] += 1

    p = grid.n_points
    rows = np.asarray(grid.rows, float)
    cols = np.asarray(grid.cols, float)
    b_h = np.asarray(grid.b_h, float)
    b_v = np.asarray(grid.b_v, float)
    os_mask = np.asarray(grid.dataflow_os, bool)
    sqrt_area = np.sqrt(np.asarray(grid.pe_area_um2, float))
    n_l = len(layout_names)
    di = list(DATA_CLASS_IDX)
    oi = list(OVERHEAD_CLASS_IDX)

    count = np.zeros((n_l, len(SEGMENT_CLASS_SCHEMA), p))
    len_w = np.zeros_like(count)
    len_h = np.zeros_like(count)
    len_c = np.zeros_like(count)
    width = np.zeros_like(count)
    lane0 = np.zeros_like(count)
    feasible = np.zeros((n_l, p), bool)
    lo = np.zeros((n_l, p))
    hi = np.zeros((n_l, p))

    for li, name in enumerate(layout_names):
        layout = get_layout(name)
        cc = segment_class_coeffs(layout, rows, cols, b_h, b_v, os_mask)
        count[li] = cc["count"]
        len_w[li] = cc["len_w"]
        len_h[li] = cc["len_h"]
        len_c[li] = cc["len_c"]
        width[li] = cc["width"]
        lane0[li] = cc["lane0"]
        # Aspect window: PE envelope intersected with the die-envelope
        # constraint (gutter constants neglected in the bound — they are
        # small against the array span and only loosen it marginally).
        ew_w, _, eh_h, _ = envelope_coeffs(layout, rows, cols)
        l_lo = np.full(p, float(grid.aspect_lo))
        l_hi = np.full(p, float(grid.aspect_hi))
        if max_envelope_aspect is not None:
            e = float(max_envelope_aspect)
            ratio = ew_w / eh_h
            l_lo = np.maximum(l_lo, 1.0 / (e * ratio))
            l_hi = np.minimum(l_hi, e / ratio)
        ok = np.asarray(cc["feasible"], bool) & (l_lo < l_hi)
        feasible[li] = ok
        lo[li] = np.where(ok, l_lo, 1.0)
        hi[li] = np.where(ok, l_hi, 1.0 + 1e-9)

    alpha = len_w * sqrt_area
    beta = len_h * sqrt_area
    gamma = len_c
    t_lo = np.sqrt(lo)
    t_hi = np.sqrt(hi)

    # Exact repeater prune: len(t) is convex in t, so its window maximum is
    # at an endpoint.  A data class joins the repeater set iff some live
    # (feasible, count > 0) cell can exceed the spacing inside its window.
    rep_idx = []
    for j, ci in enumerate(di):
        ln_ends = np.maximum(
            alpha[:, ci] * t_lo + beta[:, ci] / t_lo + gamma[:, ci],
            alpha[:, ci] * t_hi + beta[:, ci] / t_hi + gamma[:, ci],
        )
        live = feasible & (count[:, ci] > 0)
        if bool((ln_ends[live] > float(repeater_spacing_um)).any()):
            rep_idx.append(j)

    host = {
        "count_d": count[:, di],
        "alpha_d": alpha[:, di],
        "beta_d": beta[:, di],
        "gamma_d": gamma[:, di],
        "ca": count[:, di] * alpha[:, di],
        "cb": count[:, di] * beta[:, di],
        "cg": count[:, di] * gamma[:, di],
        "cwidth_d": count[:, di] * width[:, di],
        "width_d": width[:, di],
        "lane0_d": lane0[:, di].astype(np.int64),
        "count_o": count[:, oi],
        "width_o": width[:, oi],
        "alpha_o": alpha[:, oi],
        "beta_o": beta[:, oi],
        "gamma_o": gamma[:, oi],
        "t_lo": t_lo,
        "t_hi": t_hi,
        "feasible": feasible,
        "lo": lo,
        "hi": hi,
    }
    host = {
        k: np.ascontiguousarray(v) if isinstance(v, np.ndarray) else v
        for k, v in host.items()
    }
    entry = LoweredCoeffs(layout_names, key, rep_idx, host)
    _COEFF_CACHE[key] = entry
    _evict_to_capacity()
    return entry


class LoweredTensors:
    """A memoized bundle of host tensors with a lazy device-resident copy.

    Shared by the partition and coding lowerings (``LoweredCoeffs`` keeps
    its own class because its device set is the fixed ``DEVICE_FIELDS``
    contract; here every host array is device-mirrored).
    """

    __slots__ = ("key", "host", "_device")

    def __init__(self, key, host):
        self.key = key
        self.host = host
        self._device = None

    def device(self) -> dict:
        if self._device is None:
            import jax

            self._device = {k: jax.device_put(v) for k, v in self.host.items()}
        return self._device


def _cache_get(key):
    hit = _COEFF_CACHE.get(key)
    if hit is not None:
        _COEFF_CACHE.move_to_end(key)
        _COEFF_CACHE_STATS["hits"] += 1
    return hit


def _cache_put(key, entry):
    _COEFF_CACHE_STATS["misses"] += 1
    _COEFF_CACHE[key] = entry
    _evict_to_capacity()
    return entry


def _partition_key(grid, layout_names, gemms) -> str:
    h = hashlib.sha256()
    h.update(b"partition|")
    for name in layout_names:
        h.update(f"{name}={get_layout(name)!r};".encode())
    for g in gemms:
        h.update(f"({int(g.m)},{int(g.k)},{int(g.n)})".encode())
    for tag, arr, dt in (
        ("rows", grid.rows, np.int64),
        ("cols", grid.cols, np.int64),
        ("os", grid.dataflow_os, np.uint8),
    ):
        h.update(tag.encode())
        h.update(np.ascontiguousarray(np.asarray(arr, dt)).tobytes())
    return h.hexdigest()


def lower_partition_coeffs(grid, layouts, gemms) -> LoweredTensors:
    """Lower the pod-partition model into (gemm, layout, point) arrays.

    One broadcast ``_partition_core`` call replaces the host Python loop of
    ``design_pod_partition``: for every (GEMM, layout family, grid point)
    cell the entry holds

      * ``utilization``        — useful MACs / (rows*cols*cycles), 0 where
        the mapping is degenerate (zero-MAC GEMM) or the family infeasible;
      * ``spill_words_per_mac`` — off-array partial-sum round-trip words;
      * ``trunk_words_per_mac`` — reduction-trunk gutter crossings;
      * ``ksplit``             — 1.0 where the K-split mapping won.

    ``partition_gemm`` remains the scalar oracle (same contract as
    ``SegmentList`` vs. the class coefficients).  Memoized under the
    content-keyed coeff cache; ``.device()`` gives warm jitted objective
    calls transfer-free device buffers.
    """
    from repro.core.workloads import _partition_core
    from repro.layout.geometry import MultiPodLayout, layout_feasible

    layout_names = tuple(layouts)
    gemms = tuple(gemms)
    key = _partition_key(grid, layout_names, gemms)
    hit = _cache_get(key)
    if hit is not None:
        return hit

    p = grid.n_points
    n_l = len(layout_names)
    n_g = len(gemms)
    rows = np.asarray(grid.rows, np.int64)
    cols = np.asarray(grid.cols, np.int64)
    os_mask = np.asarray(grid.dataflow_os, bool)

    # (L, P) pod counts and feasibility; infeasible cells run with k-sized
    # placeholder dims so the integer math stays valid, then get zeroed.
    k_arr = np.ones((n_l, 1), np.int64)
    feas = np.zeros((n_l, p), bool)
    for li, name in enumerate(layout_names):
        layout = get_layout(name)
        k_arr[li, 0] = layout.k if isinstance(layout, MultiPodLayout) else 1
        feas[li] = layout_feasible(layout, rows, cols)
    r_ok = np.where(feas, rows[None, :], k_arr)
    c_ok = np.where(feas, cols[None, :], k_arr)

    m = np.asarray([g.m for g in gemms], np.int64).reshape(n_g, 1, 1)
    kdim = np.asarray([g.k for g in gemms], np.int64).reshape(n_g, 1, 1)
    n = np.asarray([g.n for g in gemms], np.int64).reshape(n_g, 1, 1)
    out = _partition_core(
        m, kdim, n, r_ok[None], c_ok[None], k_arr[None], os_mask[None, None, :]
    )

    macs = (m * kdim * n).astype(np.float64)  # (G, 1, 1)
    live = feas[None] & (macs > 0)
    safe = np.maximum(macs, 1.0)

    def per_mac(words):
        return np.where(live, np.asarray(words, np.float64) / safe, 0.0)

    host = {
        "utilization": np.where(live, out["utilization"], 0.0),
        "spill_words_per_mac": per_mac(out["spill_words"]),
        "trunk_words_per_mac": per_mac(out["trunk_words"]),
        "ksplit": np.where(live, np.asarray(out["ksplit"], np.float64), 0.0),
    }
    host = {k: np.ascontiguousarray(v) for k, v in host.items()}
    return _cache_put(key, LoweredTensors(key, host))


# --- Coding schemes: per-class activity multipliers -------------------------
#
# A coding scheme lowers to a multiplicative factor on the vertical data
# classes' switching activity (the coded bus carries one extra invert line,
# which the grid already folds into b_v).  "none" is the identity;
# "bus_invert" is the exact closed form; "zvcg" is a registered slot for the
# zero-value-clock-gating follow-up (ROADMAP) — it needs measured zero-run
# statistics the profile does not yet carry, so it raises until then.


def _coding_none(a, bits, xp=np):
    return a


def _coding_bus_invert(a, bits, xp=np):
    return bus_invert_activity_arr(a, bits, xp=xp)


def _coding_zvcg(a, bits, xp=np):
    raise NotImplementedError(
        "zero-value clock gating needs measured zero-run statistics; "
        "see ROADMAP 'Low-power signaling stack'"
    )


CODING_SCHEMES = {
    "none": _coding_none,
    "bus_invert": _coding_bus_invert,
    "zvcg": _coding_zvcg,
}


def grid_coding_effective(grid, a_v, xp=np):
    """Effective (coded) vertical activity per (workload, point), host f64.

    Bus-invert points get the exact closed-form coded activity on the
    physical ``b_v_data``-bit payload; everything else passes through.
    This is the single host-side transform both the closed-form design
    engine and the layout/objective engines consume — coding is no longer
    re-derived inside each jitted program.
    """
    a_v = np.asarray(a_v, np.float64)
    bi = np.asarray(grid.bus_invert, bool)
    if not bi.any():
        return a_v + 0.0
    # The closed-form coded activity iterates a fixed point per element —
    # the single most expensive host transform on a warm fleet evaluation —
    # so it is memoized under the same content-keyed cache as the lowerings.
    key = "coded|" + _coding_key(grid, a_v)
    hit = _cache_get(key)
    if hit is not None:
        return hit.host["a_v_eff"]
    bits = np.asarray(grid.b_v_data, np.float64)
    coded = bus_invert_activity_arr(a_v, bits, xp=np)
    out = np.where(bi, coded, a_v)
    out.flags.writeable = False  # cached: callers copy before mutating
    _cache_put(key, LoweredTensors(key, {"a_v_eff": out}))
    return out


def _coding_key(grid, a_v) -> str:
    h = hashlib.sha256()
    h.update(b"coding|")
    for tag, arr, dt in (
        ("bi", grid.bus_invert, np.uint8),
        ("bits", grid.b_v_data, np.int64),
    ):
        h.update(tag.encode())
        h.update(np.ascontiguousarray(np.asarray(arr, dt)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(a_v, np.float64)).tobytes())
    return h.hexdigest()


def lower_coding_multipliers(grid, a_v) -> LoweredTensors:
    """Lower the grid's coding axis to (workload, data-class, point) factors.

    The jitted evaluator multiplies the folded per-class activities by
    ``act_mult`` before collapsing to the closed-form scalars: h-net classes
    are untouched, every v-net class (hop, gutter trunk, OS drain column)
    carries the coded/raw activity ratio where the point's bus-invert flag
    is set.  Exactly 1.0 where coding is off or the activity is zero, so a
    coding-free grid lowers to all-ones.  Memoized like the layout coeffs.
    """
    a_v = np.atleast_2d(np.asarray(a_v, np.float64))
    key = _coding_key(grid, a_v)
    hit = _cache_get(key)
    if hit is not None:
        return hit

    n_w, p = a_v.shape
    coded = grid_coding_effective(grid, a_v)
    ratio = np.where(a_v > 0.0, coded / np.maximum(a_v, 1e-300), 1.0)
    mult = np.ones((n_w, len(DATA_CLASS_IDX), p))
    mult[:, DATA_IS_H == 0.0, :] = ratio[:, None, :]
    host = {"act_mult": np.ascontiguousarray(mult)}
    return _cache_put(key, LoweredTensors(key, host))
