"""Wire-segment enumeration: every hop, trunk, turnaround and spine bar.

Two renderings of the same physical model, tested against each other:

  * ``enumerate_segments`` — the EXPLICIT path: one row per wire-bundle
    segment, struct-of-arrays (``SegmentList``), with endpoints taken from
    the actual cell placement.  Ground truth for validation, reporting and
    plotting; cost O(R*C) per layout.
  * ``segment_class_coeffs`` — the same totals folded into a FIXED schema
    of segment classes whose lengths are linear in the PE dimensions
    (``len = len_w*W + len_h*H + len_c``).  This is what the jitted batched
    evaluator (``repro.layout.power``) runs on: class counts/coefficients
    broadcast over whole design grids, so (design point x layout family)
    spaces evaluate in one program.

Segment taxonomy (``net`` = which activity prices it, ``kind`` = geometry):

  net ``h``       — operand bus hops along logical rows: the West-edge
                    ``feed``, inter-PE ``hop``s, serpentine ``turn``s
                    (fold-crossing, length R*H) and multi-pod gutter
                    ``trunk`` crossings.  Width ``b_h``, lanes [0, b_h).
  net ``v``       — partial-sum (WS) / W-operand-stream (OS) hops down
                    logical columns plus the bottom-edge ``out`` hop.
                    Width ``b_v`` — except WS multi-pod interior hops,
                    which carry only the pod-local accumulator lanes
                    [0, b_v_pod); gutter crossings are full-width trunks.
  net ``preload`` — WS weight-preload chain (same geometry as ``v`` at
                    width ``b_h``).  Off by default in the power model:
                    the paper's steady-state bus model neglects preload.
  net ``drain``   — OS output-drain chain (same geometry as ``v`` at the
                    OS accumulator width).  Also off by default.
  net ``clk``     — the H-tree clock spine over the array envelope (one
                    tree; multi-pod: per-pod subtrees + a top-level tree
                    over the pod centers), 1-bit segments.

On the uniform family the data nets reduce exactly to the closed form:
R*C ``h`` segments of length W and R*C ``v`` segments of length H — Eq. 1/2
with no residual.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.floorplan import pe_dims_arr
from repro.layout.geometry import (
    Layout,
    MultiPodLayout,
    SerpentineLayout,
    clock_tree_coeffs,
    clock_tree_depth,
    envelope,
    envelope_coeffs,
    get_layout,
    htree_segments,
    layout_feasible,
    place_pes,
)

__all__ = [
    "SegmentList",
    "enumerate_segments",
    "segment_class_coeffs",
    "pod_accumulator_bits",
    "os_drain_bits",
    "SEGMENT_CLASS_SCHEMA",
    "DATA_NETS",
]

DATA_NETS = ("h", "v")
OVERHEAD_NETS = ("preload", "drain", "clk")


def _ceil_log2(x) -> np.ndarray:
    x = np.asarray(x, np.int64)
    return np.maximum(np.ceil(np.log2(np.maximum(x, 1) - 0.5)).astype(np.int64), 0)


def pod_accumulator_bits(b_h, b_v, rows, k) -> np.ndarray:
    """Vertical-bus width INSIDE one (rows/k)-deep pod under WS.

    A pod accumulates at most rows/k products of two b_h-bit operands, so
    its partial-sum bus needs 2*b_h + ceil(log2(rows/k)) bits — never more
    than the array-level ``b_v`` (which sizes the full R-deep reduction and
    the inter-pod trunks).  Broadcasts.  (When the power roll-up prices
    these lanes from a measured per-lane profile, the profile describes the
    full R-deep stream — see the fidelity caveat in ``repro.layout.power``.)
    """
    pod_rows = np.maximum(np.asarray(rows, np.int64) // k, 1)
    return np.minimum(
        np.asarray(b_v, np.int64), 2 * np.asarray(b_h, np.int64) + _ceil_log2(pod_rows)
    )


def os_drain_bits(b_h, rows) -> np.ndarray:
    """OS output-drain bus width: the accumulator the drain chain shifts.

    Sized like the WS accumulator of an R-deep reduction (the OS PE holds
    at least one K-chunk of that depth): 2*b_h + ceil(log2 rows).
    """
    return 2 * np.asarray(b_h, np.int64) + _ceil_log2(np.maximum(rows, 2))


@dataclasses.dataclass(frozen=True)
class SegmentList:
    """Struct-of-arrays wire segments (one row per physical bundle segment)."""

    net: np.ndarray  # str: h | v | preload | drain | clk
    kind: np.ndarray  # str: feed | hop | turn | trunk | out | spine
    length: np.ndarray  # um
    width: np.ndarray  # wires in the bundle
    lane0: np.ndarray  # first bus bit-lane carried (lanes [lane0, lane0+width))
    x0: np.ndarray
    y0: np.ndarray
    x1: np.ndarray
    y1: np.ndarray

    @property
    def n_segments(self) -> int:
        return int(self.length.shape[0])

    def select(self, mask) -> "SegmentList":
        return SegmentList(
            *(getattr(self, f.name)[mask] for f in dataclasses.fields(self))
        )

    def for_net(self, net: str) -> "SegmentList":
        return self.select(self.net == net)

    def total_length(self, net: str | None = None) -> float:
        """Sum of segment lengths [um] (bundle routes, not per-wire)."""
        s = self if net is None else self.for_net(net)
        return float(s.length.sum())

    def wire_length(self, net: str | None = None) -> float:
        """Sum of length * width [um of individual wire] — Eq. 1-3's unit."""
        s = self if net is None else self.for_net(net)
        return float((s.length * s.width).sum())


def enumerate_segments(
    layout,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    pe_area_um2: float,
    aspect: float,
    *,
    dataflow: str = "WS",
    nets: Sequence[str] = ("h", "v", "preload", "drain", "clk"),
) -> SegmentList:
    """Enumerate every wire segment of ``layout`` at the given PE aspect.

    Lengths are Manhattan distances between placed cells; ``nets`` filters
    the emitted nets (``preload`` only exists under WS, ``drain`` under OS).
    """
    layout = get_layout(layout)
    if dataflow not in ("WS", "OS"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    w, h = pe_dims_arr(pe_area_um2, aspect, xp=np)
    w, h = float(w), float(h)
    x, y = place_pes(layout, rows, cols, w, h)

    net_l: list[str] = []
    kind_l: list[str] = []
    rows_of: list[tuple[float, float, float, float, float, int, int]] = []

    def emit(net, kind, x0, y0, x1, y1, width, lane0=0):
        net_l.append(net)
        kind_l.append(kind)
        rows_of.append((abs(x1 - x0) + abs(y1 - y0), x0, y0, x1, y1, width, lane0))

    k = layout.k if isinstance(layout, MultiPodLayout) else 1
    pod_rows = rows // k
    # Pod-local accumulator narrowing is a MULTI-POD property (k >= 2): other
    # families — including the degenerate pods1x1 — carry the caller's b_v on
    # every interior hop (the closed-form contract).
    b_v_in = (
        int(pod_accumulator_bits(b_h, b_v, rows, k))
        if dataflow == "WS" and isinstance(layout, MultiPodLayout) and k > 1
        else b_v
    )
    drain_w = int(os_drain_bits(b_h, rows))

    # Boundary hops are classified by LOGICAL index, not geometric length:
    # a zero-width gutter (or fold) still crosses a pod/band boundary and
    # must carry the boundary width (matches segment_class_coeffs exactly).
    if isinstance(layout, SerpentineLayout):
        h_cross = lambda c: c % (cols // layout.folds) == 0
    elif isinstance(layout, MultiPodLayout):
        h_cross = lambda c: c % (cols // layout.k) == 0
    else:
        h_cross = lambda c: False
    v_cross = (lambda r: r % pod_rows == 0) if k > 1 else (lambda r: False)

    if "h" in nets:
        for r in range(rows):
            emit("h", "feed", x[r, 0] - w, y[r, 0], x[r, 0], y[r, 0], b_h)
            for c in range(1, cols):
                if h_cross(c):
                    kind = "turn" if isinstance(layout, SerpentineLayout) else "trunk"
                else:
                    kind = "hop"
                emit("h", kind, x[r, c - 1], y[r, c - 1], x[r, c], y[r, c], b_h)

    def v_geometry(net: str, width_in: int, width_cross: int):
        for c in range(cols):
            for r in range(1, rows):
                cross = v_cross(r)
                emit(
                    net,
                    "trunk" if cross else "hop",
                    x[r - 1, c],
                    y[r - 1, c],
                    x[r, c],
                    y[r, c],
                    width_cross if cross else width_in,
                )
            # bottom-edge output hop (the R-th hop of Eq. 2's R*C count)
            emit(
                net,
                "out",
                x[rows - 1, c],
                y[rows - 1, c],
                x[rows - 1, c],
                y[rows - 1, c] + h,
                width_cross,
            )

    if "v" in nets:
        v_geometry("v", b_v_in, b_v)
    if "preload" in nets and dataflow == "WS":
        v_geometry("preload", b_h, b_h)
    if "drain" in nets and dataflow == "OS":
        v_geometry("drain", drain_w, drain_w)

    if "clk" in nets:
        we, he = envelope(layout, rows, cols, w, h)
        # k == 1 falls through to the single-tree branch: one pod IS the
        # array, and a top-level tree over one center would add a spurious
        # We/2 bar that breaks pods1x1 == uniform.
        if isinstance(layout, MultiPodLayout) and k > 1:
            top = int(clock_tree_depth(k * k))
            for x0, y0, x1, y1 in htree_segments(we / 2, he / 2, we, he, top):
                emit("clk", "spine", x0, y0, x1, y1, 1)
            pod_cols = cols // k
            pw, ph = pod_cols * w, pod_rows * h
            depth = int(clock_tree_depth(pod_rows * pod_cols))
            for pr in range(k):
                for pc in range(k):
                    cx = pc * (pw + layout.gutter_um) + pw / 2
                    cy = pr * (ph + layout.gutter_um) + ph / 2
                    for x0, y0, x1, y1 in htree_segments(cx, cy, pw, ph, depth):
                        emit("clk", "spine", x0, y0, x1, y1, 1)
        else:
            depth = int(clock_tree_depth(rows * cols))
            for x0, y0, x1, y1 in htree_segments(we / 2, he / 2, we, he, depth):
                emit("clk", "spine", x0, y0, x1, y1, 1)

    arr = np.asarray(rows_of, float).reshape(-1, 7)
    return SegmentList(
        net=np.asarray(net_l),
        kind=np.asarray(kind_l),
        length=arr[:, 0],
        x0=arr[:, 1],
        y0=arr[:, 2],
        x1=arr[:, 3],
        y1=arr[:, 4],
        width=arr[:, 5].astype(np.int64),
        lane0=arr[:, 6].astype(np.int64),
    )


# ---------------------------------------------------------------------------
# Segment-class coefficients (the batched evaluator's fixed schema)
# ---------------------------------------------------------------------------

# (net, slot) per class, in schema order.  Every family fills the same 12
# slots (absent classes get count 0), so grids of mixed families stack into
# one (layouts, classes, points) tensor with no padding logic.
SEGMENT_CLASS_SCHEMA = (
    ("h", "hop"),
    ("h", "cross"),
    ("v", "hop"),
    ("v", "cross"),
    ("v", "out"),
    ("preload", "hop"),
    ("preload", "cross"),
    ("preload", "out"),
    ("drain", "hop"),
    ("drain", "cross"),
    ("drain", "out"),
    ("clk", "spine"),
)


def segment_class_coeffs(layout, rows, cols, b_h, b_v, dataflow_os, *_, **__):
    """Fixed-schema class coefficients for one layout family over (P,) grids.

    Returns a dict of (n_classes, P) float arrays — ``count``, ``len_w``,
    ``len_h``, ``len_c`` (segment length = len_w*W + len_h*H + len_c),
    ``width`` (wires) and ``lane0`` — plus ``feasible`` (P,).  Broadcasting
    the family over the whole grid host-side is what lets the jitted
    evaluator treat (point x layout) as one batch axis.  Totals are exact:
    summing ``count * (len, width)`` reproduces ``enumerate_segments`` (the
    parity is tested per family).
    """
    layout = get_layout(layout)
    rows = np.asarray(rows, float)
    cols = np.asarray(cols, float)
    b_h = np.asarray(b_h, float)
    b_v = np.asarray(b_v, float)
    os_mask = np.asarray(dataflow_os, bool)
    p = np.broadcast_shapes(rows.shape, cols.shape, b_h.shape, b_v.shape, os_mask.shape)
    rows, cols, b_h, b_v = (np.broadcast_to(a, p).astype(float) for a in (rows, cols, b_h, b_v))
    os_mask = np.broadcast_to(os_mask, p)
    ws = (~os_mask).astype(float)
    osf = os_mask.astype(float)

    n_cls = len(SEGMENT_CLASS_SCHEMA)
    z = np.zeros((n_cls,) + p)
    out = {k: z.copy() for k in ("count", "len_w", "len_h", "len_c", "width", "lane0")}

    if isinstance(layout, SerpentineLayout):
        nx_h, nx_v, g = float(layout.folds), 1.0, 0.0
    elif isinstance(layout, MultiPodLayout):
        nx_h = nx_v = float(layout.k)
        g = layout.gutter_um if layout.k > 1 else 0.0  # k=1: no gutters exist
    else:
        nx_h = nx_v = 1.0
        g = 0.0

    if isinstance(layout, MultiPodLayout) and layout.k > 1:
        b_v_in = np.where(
            os_mask, b_v, pod_accumulator_bits(b_h, b_v, rows, layout.k).astype(float)
        )
    else:
        b_v_in = b_v
    drain_w = os_drain_bits(b_h, rows).astype(float)

    def put(i, count, lw, lh, lc, width, lane0=0.0):
        out["count"][i] = count
        out["len_w"][i] = lw + 0 * count
        out["len_h"][i] = lh + 0 * count
        out["len_c"][i] = lc + 0 * count
        out["width"][i] = width + 0 * count
        out["lane0"][i] = lane0 + 0 * count

    # h: feed + in-row hops (length W) and the family's cross segments.
    put(0, rows * cols - rows * (nx_h - 1), 1.0, 0.0, 0.0, b_h)
    if isinstance(layout, SerpentineLayout):
        put(1, rows * (nx_h - 1), 0.0, rows, 0.0, b_h)  # turnaround: R*H
    elif isinstance(layout, MultiPodLayout) and layout.k > 1:
        put(1, rows * (nx_h - 1), 1.0, 0.0, g, b_h)  # gutter crossing: W+g

    # v geometry (shared by v / preload / drain): per column, (R - nx_v)
    # interior hops of length H, (nx_v - 1) crossings of length H+g, and one
    # bottom-edge out hop of length H.
    def v_classes(base, width_in, width_cross, gate):
        put(base + 0, gate * cols * (rows - nx_v), 0.0, 1.0, 0.0, width_in)
        put(base + 1, gate * cols * (nx_v - 1), 0.0, 1.0, g, width_cross)
        put(base + 2, gate * cols, 0.0, 1.0, 0.0, width_cross)

    v_classes(2, b_v_in, b_v, 1.0)
    v_classes(5, b_h, b_h, ws)
    v_classes(8, drain_w, drain_w, osf)

    # clk: one class whose "length" is the whole spine.
    ew_w, ew_c, eh_h, eh_c = envelope_coeffs(layout, rows, cols)
    # k == 1: no top-level tree — the single "pod" subtree is the whole
    # array's H-tree, making pods1x1 coefficient-identical to uniform.
    if isinstance(layout, MultiPodLayout) and layout.k > 1:
        kk = layout.k
        cw_t, ch_t = clock_tree_coeffs(np.full(p, int(clock_tree_depth(kk * kk))))
        pod_leaves = np.maximum((rows // kk) * (cols // kk), 1).astype(np.int64)
        cw_p, ch_p = clock_tree_coeffs(clock_tree_depth(pod_leaves))
        lw = cw_t * ew_w + kk * kk * cw_p * (cols / kk)
        lh = ch_t * eh_h + kk * kk * ch_p * (rows / kk)
        lc = cw_t * ew_c + ch_t * eh_c
    else:
        cw, ch = clock_tree_coeffs(clock_tree_depth((rows * cols).astype(np.int64)))
        lw = cw * ew_w
        lh = ch * eh_h
        lc = cw * ew_c + ch * eh_c
    put(11, np.ones(p), lw, lh, lc, 1.0)

    out["feasible"] = np.asarray(layout_feasible(layout, rows.astype(int), cols.astype(int)))
    return out
