"""PE cell placement and the floorplan-family registry.

A *layout family* maps the logical R x C systolic array onto physical cell
positions.  Families are small frozen dataclasses registered in
``LAYOUTS``; every other layer of the engine (segment enumeration,
coefficient builder, batched evaluator) dispatches on them:

  * ``UniformLayout``    — the paper's rectangle: PE (r, c) at (c*W, r*H).
  * ``SerpentineLayout`` — the column axis folded into ``folds`` vertical
    bands in boustrophedon (snake) order: band b holds logical columns
    [b*C/f, (b+1)*C/f), odd bands mirrored so fold-crossing h hops are
    purely vertical turnarounds of length R*H.  Folding rescales the array
    envelope by 1/f horizontally and f vertically, which is the physical
    point: it realizes extreme PE aspect ratios inside a bounded die
    envelope (ArrayFlex-style configurable arrays).
  * ``MultiPodLayout``   — a k x k tiling of (R/k) x (C/k) pods separated
    by ``gutter_um`` routing gutters (SISA-style scale-in organization).
    Pod-internal vertical buses carry only the pod-local partial-sum width
    under WS; full-width trunk wires cross the gutters.

Placements return CELL ORIGINS on the logical (rows, cols) grid; hop
lengths everywhere are Manhattan distances between placed cells, so
family-specific wiring (turnarounds, gutter crossings) emerges from the
placement rather than special cases.

``envelope_coeffs`` expresses each family's bounding box linearly in the
PE dimensions — ``We = ew_w*W + ew_c``, ``He = eh_h*H + eh_c`` — which is
what the batched evaluator's envelope-aspect constraint and the clock-tree
length closed form consume.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = [
    "UniformLayout",
    "SerpentineLayout",
    "MultiPodLayout",
    "Layout",
    "LAYOUTS",
    "register_layout",
    "get_layout",
    "pod_layouts",
    "layout_feasible",
    "envelope_coeffs",
    "envelope",
    "place_pes",
    "clock_tree_depth",
    "clock_tree_coeffs",
    "htree_segments",
]

# Deepest H-tree the closed-form length coefficients cover: 2^30 leaves is
# far beyond any realizable PE grid.
MAX_CLOCK_LEVELS = 30

_PODS_RE = re.compile(r"pods(\d+)x(\d+)")
_SERP_RE = re.compile(r"serpentine(\d+)")


@dataclasses.dataclass(frozen=True)
class UniformLayout:
    """The closed-form R x C rectangle (hop lengths W horizontally, H
    vertically) — the family ``repro.core.floorplan`` Eq. 1-6 describe."""


@dataclasses.dataclass(frozen=True)
class SerpentineLayout:
    """Column axis folded into ``folds`` serpentine bands (see module doc)."""

    folds: int = 2

    def __post_init__(self) -> None:
        if self.folds < 2:
            raise ValueError("serpentine needs folds >= 2 (folds=1 is uniform)")


@dataclasses.dataclass(frozen=True)
class MultiPodLayout:
    """k x k pod tiling with ``gutter_um`` inter-pod routing gutters.

    ``k`` is a free integer axis (SISA-style scale-in): ``k=1`` is the
    degenerate single-pod case and reduces EXACTLY to ``UniformLayout``
    (no gutters, no trunk crossings, no top-level clock tree, no pod
    accumulator narrowing) — which is what lets sweeps treat pod count as
    one more grid dimension instead of a special case.
    """

    k: int = 2
    gutter_um: float = 25.0

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError("multi-pod needs k >= 1")
        if self.gutter_um < 0:
            raise ValueError("gutter_um must be non-negative")


Layout = UniformLayout | SerpentineLayout | MultiPodLayout

LAYOUTS: dict[str, Layout] = {
    "uniform": UniformLayout(),
    "serpentine2": SerpentineLayout(folds=2),
    "serpentine4": SerpentineLayout(folds=4),
    "pods2x2": MultiPodLayout(k=2),
    "pods4x4": MultiPodLayout(k=4),
}


def register_layout(name: str, layout: Layout) -> None:
    """Add a (possibly parameterized) family instance to the registry."""
    if not isinstance(layout, (UniformLayout, SerpentineLayout, MultiPodLayout)):
        raise TypeError(f"unknown layout family {type(layout).__name__}")
    LAYOUTS[name] = layout


def get_layout(name_or_layout) -> Layout:
    """Resolve a layout instance, registered name, or PARAMETRIC name.

    Beyond the ``LAYOUTS`` registry, two parametric spellings resolve
    without registration — they are what promotes the family parameter to
    a free sweep axis:

      * ``"pods{k}x{k}"``   -> ``MultiPodLayout(k=k)``      (k >= 1)
      * ``"serpentine{f}"`` -> ``SerpentineLayout(folds=f)``(f >= 2)

    Registered names win over parsing (so ``register_layout`` can pin a
    non-default ``gutter_um`` under a parametric-looking name).
    """
    if isinstance(name_or_layout, (UniformLayout, SerpentineLayout, MultiPodLayout)):
        return name_or_layout
    try:
        return LAYOUTS[name_or_layout]
    except (KeyError, TypeError):
        pass
    if isinstance(name_or_layout, str):
        m = _PODS_RE.fullmatch(name_or_layout)
        if m and m.group(1) == m.group(2):
            return MultiPodLayout(k=int(m.group(1)))
        m = _SERP_RE.fullmatch(name_or_layout)
        if m:
            return SerpentineLayout(folds=int(m.group(1)))
    raise KeyError(
        f"unknown layout {name_or_layout!r}; registered: {sorted(LAYOUTS)}, "
        "parametric: 'pods{k}x{k}', 'serpentine{f}'"
    )


def pod_layouts(ks) -> tuple[str, ...]:
    """Layout names for a free pod-count axis: ``pod_layouts((1, 2, 4))``
    -> ``("pods1x1", "pods2x2", "pods4x4")`` — every name resolves through
    ``get_layout`` without registration (``pods1x1`` == uniform)."""
    return tuple(f"pods{int(k)}x{int(k)}" for k in ks)


def layout_feasible(layout: Layout, rows, cols):
    """Elementwise feasibility of the family on (rows, cols) grids.

    Serpentine needs the column count divisible by the fold count;
    multi-pod needs both axes divisible by k (ragged pods would break the
    trunk accounting).  Broadcasts over array inputs.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    if isinstance(layout, SerpentineLayout):
        return (cols % layout.folds == 0) & (cols >= layout.folds)
    if isinstance(layout, MultiPodLayout):
        return (rows % layout.k == 0) & (cols % layout.k == 0) & (rows >= layout.k) & (
            cols >= layout.k
        )
    return np.broadcast_to(True, np.broadcast_shapes(rows.shape, cols.shape)).copy()


def envelope_coeffs(layout: Layout, rows, cols):
    """Linear envelope model: ``(ew_w, ew_c, eh_h, eh_c)`` with
    ``We = ew_w*W + ew_c`` and ``He = eh_h*H + eh_c``.  Broadcasts."""
    rows = np.asarray(rows, float)
    cols = np.asarray(cols, float)
    zero = np.zeros(np.broadcast_shapes(rows.shape, cols.shape))
    if isinstance(layout, SerpentineLayout):
        return cols / layout.folds + zero, zero, layout.folds * rows + zero, zero
    if isinstance(layout, MultiPodLayout):
        g = (layout.k - 1) * layout.gutter_um
        return cols + zero, zero + g, rows + zero, zero + g
    return cols + zero, zero, rows + zero, zero


def envelope(layout: Layout, rows: int, cols: int, w_um: float, h_um: float):
    """(We, He) bounding box of the placed array, in um."""
    ew_w, ew_c, eh_h, eh_c = envelope_coeffs(layout, rows, cols)
    return float(ew_w * w_um + ew_c), float(eh_h * h_um + eh_c)


def place_pes(
    layout: Layout, rows: int, cols: int, w_um: float, h_um: float
) -> tuple[np.ndarray, np.ndarray]:
    """Cell origins ``(x, y)`` of every logical PE, each shaped (rows, cols).

    x grows East, y grows South (row 0 at the top edge, where the WS weight
    preload and the partial-sum chains enter).
    """
    if not layout_feasible(layout, rows, cols):
        raise ValueError(f"{layout} infeasible on a {rows}x{cols} grid")
    r = np.arange(rows)[:, None]
    c = np.arange(cols)[None, :]
    if isinstance(layout, SerpentineLayout):
        band_cols = cols // layout.folds
        band = c // band_cols
        cpos = np.where(band % 2 == 0, c % band_cols, band_cols - 1 - (c % band_cols))
        x = cpos * w_um + 0 * r
        y = (band * rows + r) * h_um
        return x.astype(float), y.astype(float)
    if isinstance(layout, MultiPodLayout):
        g = layout.gutter_um
        x = c * w_um + (c // (cols // layout.k)) * g + 0 * r
        y = r * h_um + (r // (rows // layout.k)) * g + 0 * c
        return x.astype(float), y.astype(float)
    return (c * w_um + 0 * r).astype(float), (r * h_um + 0 * c).astype(float)


# ---------------------------------------------------------------------------
# H-tree clock spine
# ---------------------------------------------------------------------------


def clock_tree_depth(n_leaves) -> np.ndarray:
    """H-tree depth serving ``n_leaves`` sinks: ceil(log2 n), at least 1."""
    n = np.asarray(n_leaves, np.int64)
    return np.maximum(np.ceil(np.log2(np.maximum(n, 2) - 0.5)).astype(np.int64), 1)


def clock_tree_coeffs(depth):
    """Closed-form H-tree length: total = cw*We + ch*He for a ``depth``-level
    tree in a (We, He) box.

    Levels alternate horizontal/vertical starting horizontal; level L draws
    2^(L-1) bars of length We/2^ceil(L/2) (odd L) or He/2^(L/2) (even L) —
    exactly what ``htree_segments`` enumerates.  Broadcasts over ``depth``
    arrays (the batched evaluator feeds per-point depths).
    """
    depth = np.asarray(depth, np.int64)
    cw = np.zeros(depth.shape, float)
    ch = np.zeros(depth.shape, float)
    for lvl in range(1, MAX_CLOCK_LEVELS + 1):
        on = depth >= lvl
        if not on.any():
            break
        if lvl % 2:
            cw += np.where(on, 2.0 ** (lvl - 1) / 2.0 ** ((lvl + 1) // 2), 0.0)
        else:
            ch += np.where(on, 2.0 ** (lvl - 1) / 2.0 ** (lvl // 2), 0.0)
    return cw, ch


def htree_segments(
    cx: float, cy: float, we: float, he: float, depth: int
) -> list[tuple[float, float, float, float]]:
    """Explicit H-tree bars ``(x0, y0, x1, y1)`` for a ``depth``-level tree
    centered at (cx, cy) in a (we, he) box.  2^depth - 1 segments; total
    length equals ``clock_tree_coeffs(depth) . (we, he)`` exactly."""
    segs: list[tuple[float, float, float, float]] = []
    pts = [(cx, cy)]
    for lvl in range(1, depth + 1):
        nxt = []
        if lvl % 2:
            ln = we / 2.0 ** ((lvl + 1) // 2)
            for px, py in pts:
                segs.append((px - ln / 2, py, px + ln / 2, py))
                nxt += [(px - ln / 2, py), (px + ln / 2, py)]
        else:
            ln = he / 2.0 ** (lvl // 2)
            for px, py in pts:
                segs.append((px, py - ln / 2, px, py + ln / 2))
                nxt += [(px, py - ln / 2), (px, py + ln / 2)]
        pts = nxt
    return segs
