"""Per-lane x per-segment switched-capacitance roll-up + batched evaluator.

Power model
-----------
Each wire segment of length L carrying an ``act_bits`` expected number of
switching wires per cycle dissipates

    P_seg = 0.5 * c_wire * L * rep(L) * act_bits * Vdd^2 * f

``rep(L) = 1 + repeater_overhead * max(0, L / repeater_spacing - 1)`` is a
simple repeater-aware length scaling: hops shorter than the repeater
spacing (every hop of every family at realistic PE areas) are plain wire,
longer runs (serpentine turnarounds, inter-pod trunks) pay the inserted
repeaters' input capacitance pro-rata.  The clock spine is exempt — clock
trees are explicitly buffered and their buffer power already lives in the
calibrated non-bus fraction of ``repro.core.energy``.

``act_bits`` is where measured per-bit-lane switching enters: a segment
carrying lanes [lane0, lane0+width) of a profiled bus switches
``sum(lane_activity[lane0 : lane0+width])`` wires per transition.  With
only aggregate activities the roll-up falls back to ``a * width`` — the
MEAN-LANE approximation, exact whenever every segment carries the full bus
(the closed-form ``bus_switched_capacitance_arr`` is precisely this case)
and an approximation the moment widths vary per segment (WS multi-pod
interior buses carry only the low pod-accumulator lanes;
``benchmarks/bench_design_space.py``'s ``layout/lane_approx_error`` row
quantifies the gap).  Fidelity caveat: the lane distribution is measured
on the FULL R-deep partial-sum stream; a pod-local bus physically carries
the (R/k)-deep sub-accumulation, whose low lanes toggle similarly but
whose boundary resets the measured stream does not model — the per-lane
roll-up is a better estimate than mean-lane for truncated buses, not
cycle-accurate ground truth.

Closed-form equivalence contract
--------------------------------
With the default config (no envelope limit, duty-cycled overhead nets off)
the uniform family's data-net power equals ``floorplan.bus_power_arr``
exactly and its argmin aspect the envelope-clamped Eq. 6 optimum — the
closed form is a verified special case of the segment model (tested, and
asserted every CI run by ``benchmarks/bench_layout.py``).

Batched evaluation
------------------
``evaluate_layout_space`` broadcasts every registered family's fixed-schema
segment classes over a ``DesignGrid``, then runs ONE jitted program per
call: per-(workload, layout, point) golden-section optimal aspects inside
the intersection of the PE-aspect envelope and the die-envelope constraint
(``max_envelope_aspect`` — the physical reason folded/podded families beat
the uniform rectangle: they realize extreme PE aspects inside a bounded
die), workload-weighted robust aspects, data-net powers, overhead powers
and wirelengths.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from repro.core.design_space import DesignGrid, _norm_activities
from repro.core.floorplan import _xp, golden_section_minimize_arr
from repro.layout.geometry import envelope_coeffs, get_layout
from repro.layout.segments import (
    DATA_NETS,
    SEGMENT_CLASS_SCHEMA,
    SegmentList,
    enumerate_segments,
    segment_class_coeffs,
)

try:  # jax accelerates the evaluator; same code runs in float64 numpy without it
    import jax

    _HAS_JAX = True
except ImportError:  # pragma: no cover - jax baked into the image
    _HAS_JAX = False

__all__ = [
    "LayoutPowerConfig",
    "LayoutSpaceEval",
    "rollup_segments",
    "segment_bus_power",
    "segment_wirelength",
    "evaluate_layout_space",
]


@dataclasses.dataclass(frozen=True)
class LayoutPowerConfig:
    """Knobs of the segment power model (defaults = closed-form-equivalent).

    ``preload_duty``/``drain_duty`` default to 0: the steady-state bus model
    neglects weight preload and output drain exactly as the paper does
    (turn them on to price those chains as duty-cycled overhead nets).
    ``max_envelope_aspect`` bounds the ARRAY bounding box W/H (a die-fitting
    constraint, distinct from the per-PE envelope); ``None`` = unbounded.
    """

    vdd: float = 0.9
    freq_hz: float = 1.0e9
    wire_cap_f_per_um: float = 0.20e-15
    repeater_spacing_um: float = 200.0
    repeater_overhead: float = 0.3
    max_envelope_aspect: float | None = None
    preload_duty: float = 0.0
    preload_activity: float = 0.5
    drain_duty: float = 0.0
    drain_activity: float = 0.5
    clock_toggles_per_cycle: float = 2.0


def _repeater_scale(length, spacing, overhead, xp=np):
    return 1.0 + overhead * xp.maximum(length / spacing - 1.0, 0.0)


def _lane_sum(lanes: np.ndarray | None, lane0, width, agg, _unused=None):
    """Expected switching wires per transition for lanes [lane0, lane0+width).

    ``lanes`` is a per-lane activity array with the lane axis last — (n,)
    for one profile, (W, P, n) for a grid — or None for the aggregate
    mean-lane path (``agg * width``).  ``lane0``/``width`` broadcast over
    the non-lane axes.
    """
    width = np.asarray(width)
    if lanes is None:
        return np.asarray(agg) * width
    lanes = np.asarray(lanes, float)
    cs = np.concatenate(
        [np.zeros(lanes.shape[:-1] + (1,)), np.cumsum(lanes, axis=-1)], axis=-1
    )
    n = lanes.shape[-1]
    lo = np.clip(np.asarray(lane0, np.int64), 0, n)
    hi = np.clip(lo + np.asarray(width, np.int64), 0, n)
    if lanes.ndim == 1:
        return cs[hi] - cs[lo]
    tgt = cs.shape[:-1]
    lo_b = np.broadcast_to(lo, tgt)[..., None]
    hi_b = np.broadcast_to(hi, tgt)[..., None]
    return (
        np.take_along_axis(cs, hi_b, axis=-1) - np.take_along_axis(cs, lo_b, axis=-1)
    )[..., 0]


def _segment_act_bits(
    net: np.ndarray,
    width: np.ndarray,
    lane0: np.ndarray,
    a_h: float,
    a_v: float,
    cfg: LayoutPowerConfig,
    h_lanes: np.ndarray | None,
    v_lanes: np.ndarray | None,
) -> np.ndarray:
    act = np.zeros(width.shape, float)
    for m, lanes, agg in (("h", h_lanes, a_h), ("v", v_lanes, a_v)):
        sel = net == m
        if sel.any():
            act[sel] = _lane_sum(lanes, lane0[sel], width[sel], agg, None)
    act[net == "preload"] = (
        cfg.preload_duty * cfg.preload_activity * width[net == "preload"]
    )
    act[net == "drain"] = cfg.drain_duty * cfg.drain_activity * width[net == "drain"]
    act[net == "clk"] = cfg.clock_toggles_per_cycle * width[net == "clk"]
    return act


def rollup_segments(
    segs: SegmentList,
    a_h: float,
    a_v: float,
    *,
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
) -> dict[str, float]:
    """Explicit per-segment power roll-up [W], by net.

    ``h_lanes``/``v_lanes`` are optional per-lane activity arrays (e.g.
    ``ActivityProfile.a_h_lanes``); without them each net uses its aggregate
    activity (the mean-lane approximation).  Returns per-net watts plus
    ``bus_w`` (the data nets — comparable to ``floorplan.bus_power``),
    ``overhead_w`` and ``total_w``.
    """
    act = _segment_act_bits(
        segs.net, segs.width.astype(float), segs.lane0, a_h, a_v, cfg, h_lanes, v_lanes
    )
    rep = _repeater_scale(
        segs.length, cfg.repeater_spacing_um, cfg.repeater_overhead, np
    )
    rep = np.where(segs.net == "clk", 1.0, rep)
    p_seg = (
        0.5 * cfg.wire_cap_f_per_um * segs.length * rep * act * cfg.vdd**2 * cfg.freq_hz
    )
    out = {net: float(p_seg[segs.net == net].sum()) for net in np.unique(segs.net)}
    bus = sum(out.get(n, 0.0) for n in DATA_NETS)
    overhead = sum(v for k, v in out.items() if k not in DATA_NETS)
    out["bus_w"] = bus
    out["overhead_w"] = overhead
    out["total_w"] = bus + overhead
    return out


def segment_bus_power(
    layout,
    geom,
    act,
    aspect: float,
    *,
    dataflow: str = "WS",
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
) -> float:
    """Data-net (h+v) power [W] of ``layout`` at one aspect — the explicit
    segment model's answer to ``floorplan.bus_power`` (equal on uniform)."""
    segs = enumerate_segments(
        layout,
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        aspect,
        dataflow=dataflow,
        nets=DATA_NETS,
    )
    return rollup_segments(
        segs, act.a_h, act.a_v, h_lanes=h_lanes, v_lanes=v_lanes, cfg=cfg
    )["bus_w"]


def segment_wirelength(layout, geom, aspect: float, *, dataflow: str = "WS") -> float:
    """Total data-net wire length [um] — Eq. 3's unit (equal on uniform)."""
    segs = enumerate_segments(
        layout,
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        aspect,
        dataflow=dataflow,
        nets=DATA_NETS,
    )
    return segs.wire_length()


# ---------------------------------------------------------------------------
# Batched (design point x layout family) evaluator
# ---------------------------------------------------------------------------


def _layout_eval_core(
    count,  # (L, C, P)
    len_w,
    len_h,
    len_c,
    width,
    act_data,  # (W, L, C, P) switching wires per transition, data classes only
    act_over,  # (L, C, P) overhead classes only
    rep_exempt,  # (L, C, P) 1.0 where repeater scaling is exempt (clk)
    data_mask,  # (L, C, P) 1.0 on data-net (h/v) classes
    pe_area,  # (P,)
    log_lo,  # (L, P)
    log_hi,
    weights,  # (W,)
    vdd,
    freq_hz,
    wire_cap,
    spacing,
    overhead,
    *,
    gss_iters: int,
):
    xp = _xp(count, act_data)
    pref = 0.5 * wire_cap * vdd * vdd * freq_hz

    def caps(log_r, act):
        # log_r: (..., L, P) -> per-class lengths at that aspect
        r = xp.exp(log_r)
        w_pe = xp.sqrt(pe_area * r)
        h_pe = xp.sqrt(pe_area / r)
        ln = len_w * w_pe[..., None, :] + len_h * h_pe[..., None, :] + len_c
        rep = 1.0 + (1.0 - rep_exempt) * overhead * xp.maximum(ln / spacing - 1.0, 0.0)
        return xp.sum(count * ln * rep * act, axis=-2)  # reduce the class axis

    # Per-(workload, layout, point) optimum of the data-net power.
    lo_w = log_lo[None] + 0.0 * act_data[:, :, 0]  # (W, L, P)
    hi_w = log_hi[None]
    log_opt = golden_section_minimize_arr(
        lambda lr: caps(lr, act_data), lo_w, hi_w, iters=gss_iters, xp=xp
    )
    aspect_opt = xp.exp(log_opt)
    bus_power_opt = pref * caps(log_opt, act_data)

    # Robust (workload-weighted) aspect per (layout, point).
    w_col = weights[:, None, None]

    def weighted(log_r):
        return xp.sum(w_col * caps(log_r[None], act_data), axis=0)

    log_rob = golden_section_minimize_arr(
        weighted, log_lo, log_hi, iters=gss_iters, xp=xp
    )
    aspect_robust = xp.exp(log_rob)
    bus_power_robust = pref * weighted(log_rob)
    overhead_w = pref * caps(log_rob, act_over)

    # Data-net wirelength (um of wire) at the robust aspect.
    r = xp.exp(log_rob)
    w_pe = xp.sqrt(pe_area * r)
    h_pe = xp.sqrt(pe_area / r)
    ln = len_w * w_pe[..., None, :] + len_h * h_pe[..., None, :] + len_c
    wirelength = xp.sum(data_mask * count * ln * width, axis=-2)

    return {
        "aspect_opt": aspect_opt,
        "bus_power_opt": bus_power_opt,
        "aspect_robust": aspect_robust,
        "bus_power_robust": bus_power_robust,
        "overhead_w": overhead_w,
        "wirelength_um": wirelength,
    }


@functools.lru_cache(maxsize=8)
def _jitted_layout_eval(gss_iters: int):
    return jax.jit(functools.partial(_layout_eval_core, gss_iters=gss_iters))


@dataclasses.dataclass(frozen=True)
class LayoutSpaceEval:
    """(layout L, point P) evaluation of a design grid across families.

    Workload-axis outputs are (W, L, P); per-(layout, point) outputs (L, P).
    Infeasible (layout, point) pairs — family/grid divisibility or an empty
    aspect window under ``max_envelope_aspect`` — carry ``inf`` powers.
    """

    grid: DesignGrid
    layouts: tuple[str, ...]
    feasible: np.ndarray  # (L, P) bool
    aspect_lo: np.ndarray  # (L, P) effective lower aspect bound
    aspect_hi: np.ndarray  # (L, P)
    aspect_opt: np.ndarray  # (W, L, P)
    bus_power_opt: np.ndarray  # (W, L, P) data-net power at aspect_opt [W]
    aspect_robust: np.ndarray  # (L, P)
    bus_power_robust: np.ndarray  # (L, P) workload-weighted at aspect_robust
    overhead_w: np.ndarray  # (L, P) clk (+duty-cycled preload/drain)
    wirelength_um: np.ndarray  # (L, P) data-net wire length at aspect_robust
    sweep_report: object | None = None  # SweepReport when run via ``sweep=``

    @property
    def n_points(self) -> int:
        return self.grid.n_points

    @property
    def total_w(self) -> np.ndarray:
        return self.bus_power_robust + self.overhead_w

    @property
    def best_layout(self) -> np.ndarray:
        """(P,) index into ``layouts`` minimizing robust bus + overhead."""
        return np.argmin(self.total_w, axis=0)

    def best_layout_name(self, i: int) -> str:
        return self.layouts[int(self.best_layout[i])]


def evaluate_layout_space(
    grid: DesignGrid,
    a_h,
    a_v,
    *,
    layouts: Sequence[str] = ("uniform", "serpentine2", "pods2x2"),
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    weights: Sequence[float] | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
    use_jit: bool | None = None,
    gss_iters: int = 64,
    sweep=None,
) -> LayoutSpaceEval:
    """Evaluate every (design point, layout family) pair in one program.

    ``a_h``/``a_v`` are (W, P)-broadcastable aggregate activities (measured:
    ``workloads.measured_design_activities``); ``h_lanes``/``v_lanes`` are
    optional (W, P, n_lanes) per-lane activity arrays (measured:
    ``workloads.measured_design_lane_activities``) — with them, variable-
    width segments (multi-pod pod buses) are priced from the true lane
    distribution instead of the mean-lane approximation.  The grid must be
    bus-invert-free (BI is an activity transform on a coded bus; the
    segment model prices physical lanes).

    ``sweep`` (a ``repro.core.sweep.SweepConfig``) routes evaluation
    through the chunked, checkpointed, guard-validated runner (see
    ``evaluate_design_space``); the returned eval carries ``sweep_report``.
    """
    if np.any(np.asarray(grid.bus_invert)):
        raise ValueError(
            "layout engine prices physical (uncoded) buses; expand the space "
            "with bus_invert=(False,)"
        )
    p = grid.n_points
    a_h, a_v = _norm_activities(a_h, a_v, p)
    n_w = a_h.shape[0]
    w = np.asarray(weights if weights is not None else np.ones(n_w), float)
    if w.shape != (n_w,):
        raise ValueError("weights must match the workload axis")
    if w.sum() <= 0:
        raise ValueError("weights must sum to a positive value")
    w = w / w.sum()
    for lanes, name in ((h_lanes, "h_lanes"), (v_lanes, "v_lanes")):
        if lanes is not None and (lanes.ndim != 3 or lanes.shape[:2] != (n_w, p)):
            raise ValueError(f"{name} must be (workloads, points, n_lanes)")

    layout_names = tuple(layouts)
    if sweep is not None:
        use_jit_r = _HAS_JAX if use_jit is None else use_jit
        if use_jit_r and not _HAS_JAX:
            raise RuntimeError("use_jit=True but jax is not importable")
        from repro.core.sweep import run_layout_sweep

        out, report = run_layout_sweep(
            grid, a_h, a_v, w, layouts=layout_names, h_lanes=h_lanes,
            v_lanes=v_lanes, cfg=cfg, gss_iters=gss_iters, use_jit=use_jit_r,
            sweep=sweep,
        )
        return LayoutSpaceEval(
            grid=grid, layouts=layout_names, sweep_report=report, **out
        )
    rows = np.asarray(grid.rows, float)
    cols = np.asarray(grid.cols, float)
    b_h = np.asarray(grid.b_h, float)
    b_v = np.asarray(grid.b_v, float)
    os_mask = np.asarray(grid.dataflow_os, bool)
    n_cls = len(SEGMENT_CLASS_SCHEMA)
    nets = np.asarray([net for net, _ in SEGMENT_CLASS_SCHEMA])
    n_l = len(layout_names)

    count = np.zeros((n_l, n_cls, p))
    len_w_ = np.zeros_like(count)
    len_h_ = np.zeros_like(count)
    len_c_ = np.zeros_like(count)
    width = np.zeros_like(count)
    rep_exempt = np.zeros_like(count)
    data_mask = np.zeros_like(count)
    act_data = np.zeros((n_w, n_l, n_cls, p))
    act_over = np.zeros((n_l, n_cls, p))
    feasible = np.zeros((n_l, p), bool)
    lo = np.zeros((n_l, p))
    hi = np.zeros((n_l, p))

    for li, name in enumerate(layout_names):
        layout = get_layout(name)
        cc = segment_class_coeffs(layout, rows, cols, b_h, b_v, os_mask)
        count[li] = cc["count"]
        len_w_[li] = cc["len_w"]
        len_h_[li] = cc["len_h"]
        len_c_[li] = cc["len_c"]
        width[li] = cc["width"]
        rep_exempt[li] = (nets == "clk")[:, None].astype(float)
        data_mask[li] = np.isin(nets, DATA_NETS)[:, None].astype(float)
        for ci, (net, _) in enumerate(SEGMENT_CLASS_SCHEMA):
            wdt = cc["width"][ci]
            ln0 = cc["lane0"][ci]
            if net == "h":
                act_data[:, li, ci] = _lane_sum(h_lanes, ln0, wdt, a_h, None)
            elif net == "v":
                act_data[:, li, ci] = _lane_sum(v_lanes, ln0, wdt, a_v, None)
            elif net == "preload":
                act_over[li, ci] = cfg.preload_duty * cfg.preload_activity * wdt
            elif net == "drain":
                act_over[li, ci] = cfg.drain_duty * cfg.drain_activity * wdt
            else:  # clk
                act_over[li, ci] = cfg.clock_toggles_per_cycle * wdt

        # Aspect window: PE envelope intersected with the die-envelope
        # constraint (gutter constants neglected in the bound — they are
        # small against the array span and only loosen it marginally).
        ew_w, _, eh_h, _ = envelope_coeffs(layout, rows, cols)
        l_lo = np.full(p, float(grid.aspect_lo))
        l_hi = np.full(p, float(grid.aspect_hi))
        if cfg.max_envelope_aspect is not None:
            e = float(cfg.max_envelope_aspect)
            if e < 1.0:
                raise ValueError("max_envelope_aspect must be >= 1")
            ratio = ew_w / eh_h
            l_lo = np.maximum(l_lo, 1.0 / (e * ratio))
            l_hi = np.minimum(l_hi, e / ratio)
        ok = np.asarray(cc["feasible"], bool) & (l_lo < l_hi)
        feasible[li] = ok
        lo[li] = np.where(ok, l_lo, 1.0)
        hi[li] = np.where(ok, l_hi, 1.0 + 1e-9)

    use_jit = _HAS_JAX if use_jit is None else use_jit
    if use_jit and not _HAS_JAX:
        raise RuntimeError("use_jit=True but jax is not importable")
    fn = (
        _jitted_layout_eval(gss_iters)
        if use_jit
        else functools.partial(_layout_eval_core, gss_iters=gss_iters)
    )
    out = fn(
        count,
        len_w_,
        len_h_,
        len_c_,
        width,
        act_data,
        act_over,
        rep_exempt,
        data_mask,
        np.asarray(grid.pe_area_um2, float),
        np.log(lo),
        np.log(hi),
        w,
        cfg.vdd,
        cfg.freq_hz,
        cfg.wire_cap_f_per_um,
        cfg.repeater_spacing_um,
        cfg.repeater_overhead,
    )
    out = {k: np.asarray(v, float) for k, v in out.items()}
    bad = ~feasible
    for key in ("bus_power_robust", "overhead_w", "wirelength_um"):
        out[key] = np.where(bad, np.inf, out[key])
    out["bus_power_opt"] = np.where(bad[None], np.inf, out["bus_power_opt"])
    return LayoutSpaceEval(
        grid=grid,
        layouts=layout_names,
        feasible=feasible,
        aspect_lo=lo,
        aspect_hi=hi,
        **out,
    )
