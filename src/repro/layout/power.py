"""Per-lane x per-segment switched-capacitance roll-up + batched evaluator.

Power model
-----------
Each wire segment of length L carrying an ``act_bits`` expected number of
switching wires per cycle dissipates

    P_seg = 0.5 * c_wire * L * rep(L) * act_bits * Vdd^2 * f

``rep(L) = 1 + repeater_overhead * max(0, L / repeater_spacing - 1)`` is a
simple repeater-aware length scaling: hops shorter than the repeater
spacing (every hop of every family at realistic PE areas) are plain wire,
longer runs (serpentine turnarounds, inter-pod trunks) pay the inserted
repeaters' input capacitance pro-rata.  The clock spine is exempt — clock
trees are explicitly buffered and their buffer power already lives in the
calibrated non-bus fraction of ``repro.core.energy``.

``act_bits`` is where measured per-bit-lane switching enters: a segment
carrying lanes [lane0, lane0+width) of a profiled bus switches
``sum(lane_activity[lane0 : lane0+width])`` wires per transition.  With
only aggregate activities the roll-up falls back to ``a * width`` — the
MEAN-LANE approximation, exact whenever every segment carries the full bus
(the closed-form ``bus_switched_capacitance_arr`` is precisely this case)
and an approximation the moment widths vary per segment (WS multi-pod
interior buses carry only the low pod-accumulator lanes;
``benchmarks/bench_design_space.py``'s ``layout/lane_approx_error`` row
quantifies the gap).  Fidelity caveat: the lane distribution is measured
on the FULL R-deep partial-sum stream; a pod-local bus physically carries
the (R/k)-deep sub-accumulation, whose low lanes toggle similarly but
whose boundary resets the measured stream does not model — the per-lane
roll-up is a better estimate than mean-lane for truncated buses, not
cycle-accurate ground truth.

Closed-form equivalence contract
--------------------------------
With the default config (no envelope limit, duty-cycled overhead nets off)
the uniform family's data-net power equals ``floorplan.bus_power_arr``
exactly and its argmin aspect the envelope-clamped Eq. 6 optimum — the
closed form is a verified special case of the segment model (tested, and
asserted every CI run by ``benchmarks/bench_layout.py``).

Batched evaluation
------------------
``evaluate_layout_space`` broadcasts every registered family's fixed-schema
segment classes over a ``DesignGrid``, then runs ONE jitted program per
call: per-(workload, layout, point) golden-section optimal aspects inside
the intersection of the PE-aspect envelope and the die-envelope constraint
(``max_envelope_aspect`` — the physical reason folded/podded families beat
the uniform rectangle: they realize extreme PE aspects inside a bounded
die), workload-weighted robust aspects, data-net powers, overhead powers
and wirelengths.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from repro.core.design_space import DesignGrid, _norm_activities
from repro.core.floorplan import _xp
from repro.layout.coeffs import (
    DATA_IS_H,
    DEVICE_FIELDS,
    OVER_IS_CLK,
    OVER_IS_DRAIN,
    OVER_IS_PRELOAD,
    V_CROSS_DATA_IDX,
    V_HOP_DATA_IDX,
    lower_coding_multipliers,
    lower_layout_coeffs,
)
from repro.layout.segments import DATA_NETS, SegmentList, enumerate_segments

try:  # jax accelerates the evaluator; same code runs in float64 numpy without it
    import jax

    _HAS_JAX = True
except ImportError:  # pragma: no cover - jax baked into the image
    _HAS_JAX = False

__all__ = [
    "LayoutPowerConfig",
    "LayoutSpaceEval",
    "ObjectiveSpec",
    "rollup_segments",
    "segment_bus_power",
    "segment_wirelength",
    "evaluate_layout_space",
]


@dataclasses.dataclass(frozen=True)
class LayoutPowerConfig:
    """Knobs of the segment power model (defaults = closed-form-equivalent).

    ``preload_duty``/``drain_duty`` default to 0: the steady-state bus model
    neglects weight preload and output drain exactly as the paper does
    (turn them on to price those chains as duty-cycled overhead nets).
    ``max_envelope_aspect`` bounds the ARRAY bounding box W/H (a die-fitting
    constraint, distinct from the per-PE envelope); ``None`` = unbounded.
    """

    vdd: float = 0.9
    freq_hz: float = 1.0e9
    wire_cap_f_per_um: float = 0.20e-15
    repeater_spacing_um: float = 200.0
    repeater_overhead: float = 0.3
    max_envelope_aspect: float | None = None
    preload_duty: float = 0.0
    preload_activity: float = 0.5
    drain_duty: float = 0.0
    drain_activity: float = 0.5
    clock_toggles_per_cycle: float = 2.0


def _repeater_scale(length, spacing, overhead, xp=np):
    return 1.0 + overhead * xp.maximum(length / spacing - 1.0, 0.0)


def _lane_sum(lanes: np.ndarray | None, lane0, width, agg, _unused=None):
    """Expected switching wires per transition for lanes [lane0, lane0+width).

    ``lanes`` is a per-lane activity array with the lane axis last — (n,)
    for one profile, (W, P, n) for a grid — or None for the aggregate
    mean-lane path (``agg * width``).  ``lane0``/``width`` broadcast over
    the non-lane axes.
    """
    width = np.asarray(width)
    if lanes is None:
        return np.asarray(agg) * width
    lanes = np.asarray(lanes, float)
    cs = np.concatenate(
        [np.zeros(lanes.shape[:-1] + (1,)), np.cumsum(lanes, axis=-1)], axis=-1
    )
    n = lanes.shape[-1]
    lo = np.clip(np.asarray(lane0, np.int64), 0, n)
    hi = np.clip(lo + np.asarray(width, np.int64), 0, n)
    if lanes.ndim == 1:
        return cs[hi] - cs[lo]
    tgt = cs.shape[:-1]
    lo_b = np.broadcast_to(lo, tgt)[..., None]
    hi_b = np.broadcast_to(hi, tgt)[..., None]
    return (
        np.take_along_axis(cs, hi_b, axis=-1) - np.take_along_axis(cs, lo_b, axis=-1)
    )[..., 0]


def _segment_act_bits(
    net: np.ndarray,
    width: np.ndarray,
    lane0: np.ndarray,
    a_h: float,
    a_v: float,
    cfg: LayoutPowerConfig,
    h_lanes: np.ndarray | None,
    v_lanes: np.ndarray | None,
) -> np.ndarray:
    act = np.zeros(width.shape, float)
    for m, lanes, agg in (("h", h_lanes, a_h), ("v", v_lanes, a_v)):
        sel = net == m
        if sel.any():
            act[sel] = _lane_sum(lanes, lane0[sel], width[sel], agg, None)
    act[net == "preload"] = (
        cfg.preload_duty * cfg.preload_activity * width[net == "preload"]
    )
    act[net == "drain"] = cfg.drain_duty * cfg.drain_activity * width[net == "drain"]
    act[net == "clk"] = cfg.clock_toggles_per_cycle * width[net == "clk"]
    return act


def rollup_segments(
    segs: SegmentList,
    a_h: float,
    a_v: float,
    *,
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
) -> dict[str, float]:
    """Explicit per-segment power roll-up [W], by net.

    ``h_lanes``/``v_lanes`` are optional per-lane activity arrays (e.g.
    ``ActivityProfile.a_h_lanes``); without them each net uses its aggregate
    activity (the mean-lane approximation).  Returns per-net watts plus
    ``bus_w`` (the data nets — comparable to ``floorplan.bus_power``),
    ``overhead_w`` and ``total_w``.
    """
    act = _segment_act_bits(
        segs.net, segs.width.astype(float), segs.lane0, a_h, a_v, cfg, h_lanes, v_lanes
    )
    rep = _repeater_scale(
        segs.length, cfg.repeater_spacing_um, cfg.repeater_overhead, np
    )
    rep = np.where(segs.net == "clk", 1.0, rep)
    p_seg = (
        0.5 * cfg.wire_cap_f_per_um * segs.length * rep * act * cfg.vdd**2 * cfg.freq_hz
    )
    out = {net: float(p_seg[segs.net == net].sum()) for net in np.unique(segs.net)}
    bus = sum(out.get(n, 0.0) for n in DATA_NETS)
    overhead = sum(v for k, v in out.items() if k not in DATA_NETS)
    out["bus_w"] = bus
    out["overhead_w"] = overhead
    out["total_w"] = bus + overhead
    return out


def segment_bus_power(
    layout,
    geom,
    act,
    aspect: float,
    *,
    dataflow: str = "WS",
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
) -> float:
    """Data-net (h+v) power [W] of ``layout`` at one aspect — the explicit
    segment model's answer to ``floorplan.bus_power`` (equal on uniform)."""
    segs = enumerate_segments(
        layout,
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        aspect,
        dataflow=dataflow,
        nets=DATA_NETS,
    )
    return rollup_segments(
        segs, act.a_h, act.a_v, h_lanes=h_lanes, v_lanes=v_lanes, cfg=cfg
    )["bus_w"]


def segment_wirelength(layout, geom, aspect: float, *, dataflow: str = "WS") -> float:
    """Total data-net wire length [um] — Eq. 3's unit (equal on uniform)."""
    segs = enumerate_segments(
        layout,
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        aspect,
        dataflow=dataflow,
        nets=DATA_NETS,
    )
    return segs.wire_length()


# ---------------------------------------------------------------------------
# Batched (design point x layout family) evaluator
# ---------------------------------------------------------------------------
#
# The coefficient protocol: every family's data-net power at PE aspect r
# collapses, per (workload, layout, point) cell, to a closed form in
# t = sqrt(r)
#
#     f(t) = A*t + B/t + C + sum_r c_r * len_r(t) * relu(len_r(t) - s)
#
# where (A, B, C) fold every data class's count * activity * length
# coefficients (alpha = len_w*sqrt(area) multiplies t, beta = len_h*
# sqrt(area) multiplies 1/t), s is the repeater spacing, c_r = (overhead/s)
# * count_r * act_r, and the sum runs over the FEW classes whose segments
# can outgrow s inside the aspect window (``coeffs.rep_idx`` — an exact
# prune, since len(t) is convex with its window maximum at an endpoint).
#
# f is globally convex in t: A*t + B/t + C is (A, B >= 0), and each
# penalty term is x*relu(x - s) — convex nondecreasing — composed with the
# convex positive len_r(t).  So the argmin needs no golden-section scan:
# derivative-sign bisection (carrying just the bracket) plus a few clipped
# Newton polish steps converges faster AND tighter, and the whole search
# touches three scalars per cell per iteration instead of streaming the
# full (layout, class, point) tensors.  That is the ~50x: the per-point
# segment re-enumeration is gone (lowering is memoized + device-resident,
# ``repro.layout.coeffs``) and the inner loop is arithmetic on collapsed
# coefficients.
#
# The search runs over W+1 stacked slots: per-workload optima in slots
# [0, W) and the workload-weighted robust objective in slot W (weighted
# sums of (A, B, C, c_r) — the objective is linear in activity).  Both the
# float64 numpy path and the jitted float32 path run the SAME algorithm.


def _search_iters(gss_iters: int) -> tuple[int, int]:
    """Map the legacy ``gss_iters`` knob onto (bisection, newton) counts.

    Kept as the API/sweep-spec knob for compatibility: 64 "iterations"
    resolve to a 2^-16 bracket plus 3 Newton steps — tighter than GSS-64
    (Newton is quadratic on the convex objective) at a quarter of the
    derivative evaluations.
    """
    return max(8, min(int(gss_iters) // 4, 24)), 3


def _lane_gather(xp, lanes, lane0_d, width_d):
    """Per-class lane-sum: sum(lanes[lane0 : lane0+width]) via one cumsum.

    ``lanes`` (W, P, n); ``lane0_d``/``width_d`` (L, Cd, P).  Returns
    (W, L, Cd, P).
    """
    n = lanes.shape[-1]
    cs = xp.cumsum(lanes, axis=-1)
    cs = xp.concatenate([xp.zeros(lanes.shape[:-1] + (1,), cs.dtype), cs], axis=-1)
    lo = xp.clip(lane0_d, 0, n)
    hi = xp.clip(lo + width_d.astype(lane0_d.dtype), 0, n)
    cs_e = cs[:, None, None, :, :]  # (W, 1, 1, P, n+1)
    take = lambda idx: xp.take_along_axis(cs_e, idx[None, ..., None], axis=-1)[..., 0]
    return take(hi) - take(lo)


def _fold_data_activities(xp, a_h, a_v, h_lanes, v_lanes, width_d, lane0_d):
    """Switching wires per transition for every data class: (W, L, Cd, P).

    Aggregate path: ``a * width`` (the mean-lane approximation); per-lane
    path: the cumsum-gather over the class's lane range — both inside the
    jitted program, so lane profiles ride the same compiled evaluator.
    """
    is_h = DATA_IS_H.reshape(1, 1, -1, 1)
    if h_lanes is None:
        act_h = a_h[:, None, None, :] * width_d[None]
    else:
        act_h = _lane_gather(xp, h_lanes, lane0_d, width_d)
    if v_lanes is None:
        act_v = a_v[:, None, None, :] * width_d[None]
    else:
        act_v = _lane_gather(xp, v_lanes, lane0_d, width_d)
    return is_h * act_h + (1.0 - is_h) * act_v


def _coeff_eval_core(
    count_d,  # (L, Cd, P) data-class counts
    alpha_d,  # (L, Cd, P) len(t) = alpha*t + beta/t + gamma
    beta_d,
    gamma_d,
    ca,  # (L, Cd, P) count * alpha   (linear-collapse products)
    cb,
    cg,
    cwidth_d,  # (L, Cd, P) count * width (wirelength roll-up)
    width_d,  # (L, Cd, P)
    lane0_d,  # (L, Cd, P) int
    count_o,  # (L, Co, P) overhead-class tensors
    width_o,
    alpha_o,
    beta_o,
    gamma_o,
    t_lo,  # (L, P) sqrt-aspect window
    t_hi,
    a_h,  # (W, P) aggregate activities
    a_v,
    h_lanes,  # (W, P, n) or None
    v_lanes,
    weights,  # (W,)
    vdd,
    freq_hz,
    wire_cap,
    spacing,
    overhead,
    preload_coef,  # preload_duty * preload_activity
    drain_coef,
    clk_coef,
    # Coding axis: (W, Cd, P) per-class activity multipliers, or None for
    # the identity (coding-free grids skip the multiply entirely).
    act_mult=None,
    # J/op objective inputs (all None => wire-power-only evaluation):
    util=None,  # (W, L, P) useful-MAC fraction from the partition lowering
    spill_wpm=None,  # (W, L, P) off-array spill words per MAC
    trunk_wpm=None,  # (W, L, P) reduction-trunk gutter crossings per MAC
    rows_arr=None,  # (P,) array rows (spill words traverse 2*rows hops)
    rc_arr=None,  # (P,) rows * cols
    static_w=None,  # (W, P) calibrated fixed-interconnect + compute watts
    *,
    rep_idx: tuple,
    nb: int,
    nn: int,
):
    xp = _xp(ca, a_h)
    pref = 0.5 * wire_cap * vdd * vdd * freq_hz

    act = _fold_data_activities(xp, a_h, a_v, h_lanes, v_lanes, width_d, lane0_d)
    if act_mult is not None:
        act = act * act_mult[:, None, :, :]
    wcol = weights[:, None, None]

    def stack(arr):  # (W, L, P) -> (W+1, L, P): per-workload slots + weighted
        return xp.concatenate([arr, xp.sum(wcol * arr, axis=0, keepdims=True)], 0)

    As = stack(xp.sum(act * ca[None], axis=2))
    Bs = stack(xp.sum(act * cb[None], axis=2))
    Cs = stack(xp.sum(act * cg[None], axis=2))
    kap = overhead / spacing
    reps = [
        (
            alpha_d[:, j],
            beta_d[:, j],
            gamma_d[:, j],
            stack(kap * count_d[:, j][None] * act[:, :, j]),
        )
        for j in rep_idx
    ]

    def grad(t):
        v = 1.0 / t
        v2 = v * v
        v3 = v2 * v
        g = As - Bs * v2
        h = 2.0 * Bs * v3
        for al, be, ga, crs in reps:
            ln = al * t + be * v + ga
            d = al - be * v2
            on = ln > spacing
            g = g + xp.where(on, crs * (2.0 * ln - spacing) * d, 0.0)
            h = h + xp.where(
                on, crs * (2.0 * d * d + (2.0 * ln - spacing) * 2.0 * be * v3), 0.0
            )
        return g, h

    # Derivative-sign bisection: f is convex, so sign(f') brackets the argmin.
    a = t_lo[None] + 0.0 * As
    b = t_hi[None] + 0.0 * As
    for _ in range(nb):
        m = 0.5 * (a + b)
        g, _ = grad(m)
        pos = g > 0.0
        a = xp.where(pos, a, m)
        b = xp.where(pos, m, b)
    x = 0.5 * (a + b)
    # Clipped Newton polish inside the (still-shrinking) bracket.
    for _ in range(nn):
        g, h = grad(x)
        pos = g > 0.0
        a = xp.where(pos, a, x)
        b = xp.where(pos, x, b)
        xn = x - g / xp.maximum(h, 1e-30)
        xn = xp.clip(xn, a, b)
        x = xp.where(xp.isfinite(xn), xn, 0.5 * (a + b))

    f = As * x + Bs / x + Cs
    for al, be, ga, crs in reps:
        ln = al * x + be / x + ga
        f = f + crs * ln * xp.maximum(ln - spacing, 0.0)
    aspect = x * x

    # Overhead nets + wirelength: one full-schema evaluation at the robust
    # aspect (slot W) — outside the search loop, so no collapse needed.
    tr = x[-1][:, None, :]  # (L, 1, P)
    ln_o = alpha_o * tr + beta_o / tr + gamma_o
    exempt = OVER_IS_CLK.reshape(1, -1, 1)  # clk trees are explicitly buffered
    rep_o = 1.0 + (1.0 - exempt) * overhead * xp.maximum(ln_o / spacing - 1.0, 0.0)
    act_o = width_o * (
        OVER_IS_PRELOAD.reshape(1, -1, 1) * preload_coef
        + OVER_IS_DRAIN.reshape(1, -1, 1) * drain_coef
        + exempt * clk_coef
    )
    overhead_w = pref * xp.sum(count_o * ln_o * rep_o * act_o, axis=1)
    ln_d = alpha_d * tr + beta_d / tr + gamma_d
    wirelength = xp.sum(cwidth_d * ln_d, axis=1)

    out = {
        "aspect_opt": aspect[:-1],
        "bus_power_opt": pref * f[:-1],
        "aspect_robust": aspect[-1],
        "bus_power_robust": pref * f[-1],
        "overhead_w": overhead_w,
        "wirelength_um": wirelength,
    }

    if util is not None:
        # Fused J/op objective — everything priced at the ROBUST aspect
        # (the chip is floorplanned once, then serves the whole fleet).
        # Per-workload data-net power re-evaluated at t_robust:
        tr2 = x[-1][None]  # (1, L, P)
        f_r = As * tr2 + Bs / tr2 + Cs
        for al, be, ga, crs in reps:
            ln = al * tr2 + be / tr2 + ga
            f_r = f_r + crs * ln * xp.maximum(ln - spacing, 0.0)
        p_bus_r = pref * f_r[:-1]  # (W, L, P)

        # Word-traffic energies through the same switched-cap roll-up:
        # a spilled partial sum drains + reloads over 2*rows vertical hops,
        # a K-split partial crosses one gutter trunk.  ``act`` rows carry
        # switching-wires-per-word (coding multipliers already applied).
        ln_vh = ln_d[:, V_HOP_DATA_IDX]  # (L, P) hop length at t_robust
        ln_vx = ln_d[:, V_CROSS_DATA_IDX]
        rep_vh = 1.0 + overhead * xp.maximum(ln_vh / spacing - 1.0, 0.0)
        rep_vx = 1.0 + overhead * xp.maximum(ln_vx / spacing - 1.0, 0.0)
        e_len = pref / freq_hz  # J per (um * switching wire * transfer)
        e_spill = 2.0 * rows_arr * e_len * ln_vh * rep_vh * act[:, :, V_HOP_DATA_IDX, :]
        e_trunk = e_len * ln_vx * rep_vx * act[:, :, V_CROSS_DATA_IDX, :]

        # J/op = power x cycles / useful MACs; utilization folds rounds and
        # ragged-tile idling.  util == 0 (zero-MAC GEMM, infeasible mapping)
        # prices inf per-workload and drops out of the MAC-weighted fleet
        # slot (its weight is zero under MAC weighting).
        denom = freq_hz * rc_arr * util  # (W, L, P)
        p_tot = p_bus_r + overhead_w[None] + static_w[:, None, :]
        jpm = (
            p_tot / xp.maximum(denom, 1e-30)
            + spill_wpm * e_spill
            + trunk_wpm * e_trunk
        )
        jpm = xp.where(util > 0.0, jpm, xp.inf)
        live = (wcol > 0.0) & (util > 0.0)
        out["j_per_mac"] = jpm
        out["j_per_mac_robust"] = xp.sum(
            wcol * xp.where(live, jpm, 0.0), axis=0
        )

    return out


@functools.lru_cache(maxsize=32)
def _jitted_coeff_eval(rep_idx: tuple, nb: int, nn: int, donate: bool):
    fn = functools.partial(_coeff_eval_core, rep_idx=rep_idx, nb=nb, nn=nn)
    if donate:
        # Chunked sweeps slice fresh per-chunk coefficient buffers; donating
        # them lets XLA reuse the allocations instead of doubling footprint.
        return jax.jit(fn, donate_argnums=tuple(range(len(DEVICE_FIELDS))))
    return jax.jit(fn)


@dataclasses.dataclass(frozen=True, eq=False)
class ObjectiveSpec:
    """Inputs that turn the wire-power program into a J/op objective.

    ``partition`` is the memoized ``lower_partition_coeffs`` entry — per
    (GEMM workload, layout, point) utilization and spill/trunk words per
    MAC.  ``static_w`` is the (W, P) calibrated non-bus power (fixed
    interconnect + first-order PE/register compute term, see
    ``repro.core.objective``).  Built by ``evaluate_fleet_objective``;
    passing one to ``evaluate_layout_space`` makes the jitted program emit
    ``j_per_mac``/``j_per_mac_robust`` alongside the wire-power outputs.
    """

    partition: object  # LoweredTensors from lower_partition_coeffs
    static_w: np.ndarray  # (W, P)


@dataclasses.dataclass(frozen=True)
class LayoutSpaceEval:
    """(layout L, point P) evaluation of a design grid across families.

    Workload-axis outputs are (W, L, P); per-(layout, point) outputs (L, P).
    Infeasible (layout, point) pairs — family/grid divisibility or an empty
    aspect window under ``max_envelope_aspect`` — carry ``inf`` powers.
    The J/op fields are populated only when an ``ObjectiveSpec`` was priced
    (``evaluate_fleet_objective``), else None.
    """

    grid: DesignGrid
    layouts: tuple[str, ...]
    feasible: np.ndarray  # (L, P) bool
    aspect_lo: np.ndarray  # (L, P) effective lower aspect bound
    aspect_hi: np.ndarray  # (L, P)
    aspect_opt: np.ndarray  # (W, L, P)
    bus_power_opt: np.ndarray  # (W, L, P) data-net power at aspect_opt [W]
    aspect_robust: np.ndarray  # (L, P)
    bus_power_robust: np.ndarray  # (L, P) workload-weighted at aspect_robust
    overhead_w: np.ndarray  # (L, P) clk (+duty-cycled preload/drain)
    wirelength_um: np.ndarray  # (L, P) data-net wire length at aspect_robust
    utilization: np.ndarray | None = None  # (W, L, P) useful-MAC fraction
    j_per_mac: np.ndarray | None = None  # (W, L, P) total J per useful MAC
    j_per_mac_robust: np.ndarray | None = None  # (L, P) MAC-weighted fleet J/op
    # MACs per served token of the workload mix (serving co-design: set by
    # ``evaluate_fleet_objective(..., macs_per_token=)`` from a traffic
    # model's MAC/s over tokens/s) — turns J/op answers into J/token
    macs_per_token: float | None = None
    sweep_report: object | None = None  # SweepReport when run via ``sweep=``

    @property
    def n_points(self) -> int:
        return self.grid.n_points

    @property
    def total_w(self) -> np.ndarray:
        return self.bus_power_robust + self.overhead_w

    @property
    def best_layout(self) -> np.ndarray:
        """(P,) index into ``layouts`` minimizing robust bus + overhead."""
        return np.argmin(self.total_w, axis=0)

    def best_layout_name(self, i: int) -> str:
        return self.layouts[int(self.best_layout[i])]

    @property
    def best_layout_jpo(self) -> np.ndarray:
        """(P,) index into ``layouts`` minimizing fleet J per useful MAC."""
        if self.j_per_mac_robust is None:
            raise ValueError(
                "no J/op objective on this eval; use "
                "repro.core.objective.evaluate_fleet_objective"
            )
        return np.argmin(self.j_per_mac_robust, axis=0)

    @property
    def j_per_token_robust(self) -> np.ndarray:
        """(L, P) joules per served token: J/op x MACs/token.

        Requires both a priced J/op objective and a ``macs_per_token``
        aggregation slot (a serving traffic mix — see
        ``repro.serving.codesign``).
        """
        if self.j_per_mac_robust is None or self.macs_per_token is None:
            raise ValueError(
                "J/token needs a priced J/op objective AND macs_per_token; "
                "use repro.core.objective.evaluate_fleet_objective("
                "..., macs_per_token=jobset.macs_per_token)"
            )
        return np.asarray(self.j_per_mac_robust) * float(self.macs_per_token)


def evaluate_layout_space(
    grid: DesignGrid,
    a_h,
    a_v,
    *,
    layouts: Sequence[str] = ("uniform", "serpentine2", "pods2x2"),
    h_lanes: np.ndarray | None = None,
    v_lanes: np.ndarray | None = None,
    weights: Sequence[float] | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
    use_jit: bool | None = None,
    gss_iters: int = 64,
    sweep=None,
    objective: ObjectiveSpec | None = None,
) -> LayoutSpaceEval:
    """Evaluate every (design point, layout family) pair in one program.

    ``a_h``/``a_v`` are (W, P)-broadcastable aggregate activities (measured:
    ``workloads.measured_design_activities``); ``h_lanes``/``v_lanes`` are
    optional (W, P, n_lanes) per-lane activity arrays (measured:
    ``workloads.measured_design_lane_activities``) — with them, variable-
    width segments (multi-pod pod buses) are priced from the true lane
    distribution instead of the mean-lane approximation.

    Bus-invert points are priced through the lowered coding multipliers
    (``repro.layout.coeffs.lower_coding_multipliers``): the schema's v-net
    classes carry the coded/raw activity ratio inside the same jitted
    program.  Lane arrays describe physical uncoded buses, so lanes and a
    coded grid are mutually exclusive.

    ``objective`` (an ``ObjectiveSpec``) additionally fuses the pod-
    partition model into the program — ``j_per_mac``/``j_per_mac_robust``
    outputs; build it via ``repro.core.objective.evaluate_fleet_objective``.

    ``sweep`` (a ``repro.core.sweep.SweepConfig``) routes evaluation
    through the chunked, checkpointed, guard-validated runner (see
    ``evaluate_design_space``); the returned eval carries ``sweep_report``.
    """
    p = grid.n_points
    a_h, a_v = _norm_activities(a_h, a_v, p)
    n_w = a_h.shape[0]
    w = np.asarray(weights if weights is not None else np.ones(n_w), float)
    if w.shape != (n_w,):
        raise ValueError("weights must match the workload axis")
    if w.sum() <= 0:
        raise ValueError("weights must sum to a positive value")
    w = w / w.sum()
    has_bi = bool(np.any(np.asarray(grid.bus_invert)))
    if has_bi and (h_lanes is not None or v_lanes is not None):
        raise ValueError(
            "per-lane activities describe physical (uncoded) buses; drop the "
            "lane arrays or expand the space with bus_invert=(False,)"
        )
    for lanes, name in ((h_lanes, "h_lanes"), (v_lanes, "v_lanes")):
        if lanes is not None and (lanes.ndim != 3 or lanes.shape[:2] != (n_w, p)):
            raise ValueError(f"{name} must be (workloads, points, n_lanes)")

    layout_names = tuple(layouts)
    if objective is not None:
        part_host = objective.partition.host
        if part_host["utilization"].shape != (n_w, len(layout_names), p):
            raise ValueError(
                "objective.partition does not match (workloads, layouts, "
                "points); lower it with the same grid/layouts/gemms"
            )
        static_w = np.asarray(objective.static_w, float)
        if static_w.shape != (n_w, p):
            raise ValueError("objective.static_w must be (workloads, points)")
    if sweep is not None:
        use_jit_r = _HAS_JAX if use_jit is None else use_jit
        if use_jit_r and not _HAS_JAX:
            raise RuntimeError("use_jit=True but jax is not importable")
        from repro.core.sweep import run_layout_sweep

        out, report = run_layout_sweep(
            grid, a_h, a_v, w, layouts=layout_names, h_lanes=h_lanes,
            v_lanes=v_lanes, cfg=cfg, gss_iters=gss_iters, use_jit=use_jit_r,
            sweep=sweep, objective=objective,
        )
        return LayoutSpaceEval(
            grid=grid, layouts=layout_names, sweep_report=report, **out
        )
    coeffs = lower_layout_coeffs(
        grid,
        layout_names,
        max_envelope_aspect=cfg.max_envelope_aspect,
        repeater_spacing_um=cfg.repeater_spacing_um,
    )
    use_jit = _HAS_JAX if use_jit is None else use_jit
    if use_jit and not _HAS_JAX:
        raise RuntimeError("use_jit=True but jax is not importable")
    nb, nn = _search_iters(gss_iters)
    scalars = (
        cfg.vdd,
        cfg.freq_hz,
        cfg.wire_cap_f_per_um,
        cfg.repeater_spacing_um,
        cfg.repeater_overhead,
        cfg.preload_duty * cfg.preload_activity,
        cfg.drain_duty * cfg.drain_activity,
        cfg.clock_toggles_per_cycle,
    )
    coding = lower_coding_multipliers(grid, a_v) if has_bi else None
    if objective is not None:
        rows_arr = np.asarray(grid.rows, float)
        rc_arr = rows_arr * np.asarray(grid.cols, float)
    if use_jit:
        fn = _jitted_coeff_eval(coeffs.rep_idx, nb, nn, False)
        t = coeffs.device()
        act_mult = coding.device()["act_mult"] if coding is not None else None
        if objective is not None:
            dv = objective.partition.device()
            obj_args = (
                dv["utilization"],
                dv["spill_words_per_mac"],
                dv["trunk_words_per_mac"],
                rows_arr,
                rc_arr,
                static_w,
            )
        else:
            obj_args = (None,) * 6
        out = fn(
            *(t[k] for k in DEVICE_FIELDS), a_h, a_v, h_lanes, v_lanes, w,
            *scalars, act_mult, *obj_args,
        )
    else:
        t = coeffs.host
        act_mult = coding.host["act_mult"] if coding is not None else None
        if objective is not None:
            obj_args = (
                part_host["utilization"],
                part_host["spill_words_per_mac"],
                part_host["trunk_words_per_mac"],
                rows_arr,
                rc_arr,
                static_w,
            )
        else:
            obj_args = (None,) * 6
        out = _coeff_eval_core(
            *(t[k] for k in DEVICE_FIELDS),
            a_h,
            a_v,
            h_lanes,
            v_lanes,
            w,
            *scalars,
            act_mult,
            *obj_args,
            rep_idx=coeffs.rep_idx,
            nb=nb,
            nn=nn,
        )
    out = {k: np.asarray(v, float) for k, v in out.items()}
    feasible = coeffs.host["feasible"]
    bad = ~feasible
    for key in ("bus_power_robust", "overhead_w", "wirelength_um"):
        out[key] = np.where(bad, np.inf, out[key])
    out["bus_power_opt"] = np.where(bad[None], np.inf, out["bus_power_opt"])
    if objective is not None:
        out["j_per_mac"] = np.where(bad[None], np.inf, out["j_per_mac"])
        out["j_per_mac_robust"] = np.where(bad, np.inf, out["j_per_mac_robust"])
        out["utilization"] = part_host["utilization"]
    return LayoutSpaceEval(
        grid=grid,
        layouts=layout_names,
        feasible=feasible,
        aspect_lo=coeffs.host["lo"],
        aspect_hi=coeffs.host["hi"],
        **out,
    )
