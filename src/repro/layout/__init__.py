"""Segment-level physical layout engine.

Where ``repro.core.floorplan`` collapses a floorplan to the paper's
closed-form wirelength model (Eq. 1-6: one aspect scalar, aggregate
activities), this package places every PE cell, enumerates every wire
segment, and rolls interconnect energy up from measured per-bit-lane
switching:

  * ``geometry``  — PE cell dimensions, grid placement, envelopes, and the
    ``LAYOUTS`` registry of floorplan families (uniform rectangle,
    serpentine/folded, k x k multi-pod tilings with inter-pod trunk wires).
  * ``segments``  — struct-of-arrays wire-segment enumeration (h-bus hops,
    v-bus hops + trunks, weight-preload path, OS output-drain path, H-tree
    clock spine) with per-segment length, bit width and lane range, plus
    the fixed-schema segment-class coefficients the batched evaluator runs
    on.
  * ``power``     — per-lane x per-segment switched-capacitance roll-up
    (consuming measured ``ActivityProfile``s), repeater-aware length
    scaling, and the jitted batched layout-space evaluator wired into
    ``repro.core.design_space`` as the layout-family axis.

On the uniform-rectangle family the segment model reduces exactly to
``wirelength_total_arr`` / ``bus_power_arr`` and its argmin to the
envelope-clamped Eq. 6 optimum (tested); serpentine and multi-pod families
express floorplans the closed form cannot.  See DESIGN.md §Layout-engine.
"""

from repro.layout.geometry import (  # noqa: F401
    LAYOUTS,
    MultiPodLayout,
    SerpentineLayout,
    UniformLayout,
    envelope,
    get_layout,
    layout_feasible,
    place_pes,
    pod_layouts,
    register_layout,
)
from repro.layout.segments import (  # noqa: F401
    SegmentList,
    enumerate_segments,
    segment_class_coeffs,
)
from repro.layout.coeffs import (  # noqa: F401
    CODING_SCHEMES,
    LoweredCoeffs,
    LoweredTensors,
    clear_coeff_cache,
    coeff_cache_info,
    grid_coding_effective,
    lower_coding_multipliers,
    lower_layout_coeffs,
    lower_partition_coeffs,
    set_coeff_cache_capacity,
)
from repro.layout.power import (  # noqa: F401
    LayoutPowerConfig,
    LayoutSpaceEval,
    ObjectiveSpec,
    evaluate_layout_space,
    rollup_segments,
    segment_bus_power,
    segment_wirelength,
)
