"""Optimizers: AdamW, schedules, gradient compression."""
