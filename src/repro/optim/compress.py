"""Gradient compression for cross-pod reduction (distributed-opt trick).

Two compressors, both applied to gradients *before* the optimizer:

  * ``bf16``: cast gradients to bfloat16 before the (XLA-inserted) cross-pod
    all-reduce. Since XLA reduces in the tensor's dtype, halving gradient
    width halves DCN collective bytes — directly visible in the dry-run's
    collective-bytes term.
  * ``int8_ef``: per-tensor symmetric int8 quantization with an error-feedback
    residual carried in the optimizer state (1-bit-Adam-style): the
    quantization error of step t is added back into the gradient at step t+1,
    so the compressed-gradient *sum* is unbiased over time and convergence is
    preserved (property-tested in tests/test_optim.py).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant_int8(x: jnp.ndarray) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


def compress_int8_ef(grads: Any, residual: Any) -> tuple[Any, Any]:
    """(compressed grads, new residual). Error feedback: e' = (g+e) - Q(g+e)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        qd = _quant_dequant_int8(gf)
        return qd.astype(g.dtype), gf - qd

    out = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return comp, res
