"""LR schedules (as step -> multiplicative scale, composable with AdamWConfig)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(warmup: int, total: int, final_scale: float = 0.1):
    """Linear warmup to 1.0 over ``warmup`` steps, cosine decay to final_scale."""

    def fn(step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = final_scale + (1.0 - final_scale) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return fn


def constant():
    def fn(step: jnp.ndarray) -> jnp.ndarray:
        return jnp.ones((), jnp.float32)

    return fn
