"""AdamW with decoupled weight decay, global-norm clipping, moment dtypes.

Self-contained (no optax dependency). The optimizer state is a pytree shaped
like the params (same logical axes, so FSDP sharding of moments follows the
parameters for free), plus a replicated step counter.

``moment_dtype`` is a distributed-memory lever: bf16 moments halve optimizer
HBM (the "8/16-bit optimizer" trick) — used by the llama4-400B dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: str = "float32"
    schedule: Callable[[jnp.ndarray], jnp.ndarray] | None = None  # step -> lr scale


def init_state(cfg: AdamWConfig, params: Any) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    cfg: AdamWConfig, params: Any, opt_state: dict, grads: Any
) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1.0 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1.0 - b2)
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            mf.astype(mdt),
            vf.astype(mdt),
        )

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
