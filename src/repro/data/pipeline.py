"""Deterministic sharded synthetic-token data pipeline.

Production posture without external data: an infinite, seekable stream of
language-model batches that is

  * deterministic in (seed, step) — restarts resume bit-identically from a
    checkpointed step with no iterator state to persist beyond the step id;
  * host-sharded — each host generates only its slice of the global batch
    (disjoint by host_id), the standard multi-host input pattern;
  * structurally faithful — zipf-ish token marginals (real vocab usage is
    heavy-tailed, which matters for the SA switching-activity profiler that
    consumes these streams), next-token labels, packed positions.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    num_codebooks: int = 1
    zipf_a: float = 1.2  # heavy-tail exponent for token marginals

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.num_hosts


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int, a: float) -> np.ndarray:
    """Zipf-distributed token ids, clipped to the vocab."""
    z = rng.zipf(a, size=shape)
    return np.minimum(z - 1, vocab - 1).astype(np.int32)


def batch_at_step(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """The (host-local) batch for a given global step. Pure function of
    (seed, step, host_id) — the whole fault-tolerance story for data."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    b, s = cfg.host_batch, cfg.seq_len
    shape = (b, s + 1) if cfg.num_codebooks == 1 else (b, s + 1, cfg.num_codebooks)
    stream = _zipf_tokens(rng, shape, cfg.vocab_size, cfg.zipf_a)
    tokens = stream[:, :-1]
    labels = stream[:, 1:]
    positions = np.broadcast_to(np.arange(s, dtype=np.int32), (b, s)).copy()
    return {"tokens": tokens, "labels": labels, "positions": positions}


class DataIterator:
    """Stateful wrapper: next() -> (step, batch); seekable for restart."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> tuple[int, dict[str, np.ndarray]]:
        batch = batch_at_step(self.cfg, self.step)
        step = self.step
        self.step += 1
        return step, batch

    def state(self) -> dict:
        return {"step": self.step}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict) -> "DataIterator":
        return cls(cfg, start_step=int(state["step"]))
