"""Data pipeline."""
