"""Mesh construction. Importing this module never touches jax device state."""

from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one v5e pod (16x16 = 256 chips) or two pods
    (2x16x16 = 512 chips; the leading 'pod' axis is the DCN-connected
    data-parallel axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """jax.make_mesh, tolerant of a device pool larger than the mesh."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dry-runs must set --xla_force_host_platform_device_count)"
        )
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older signature without devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for unit tests (requires forced host device count)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
