"""Serving driver: batched prefill + decode with the KV/state cache.

CPU-runnable example (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import model


def generate(cfg, params, prompt_tokens, gen_len: int, cache_len: int | None = None):
    """Prefill the prompt (filling the cache), then greedy-decode gen_len."""
    b, s = prompt_tokens.shape[0], prompt_tokens.shape[1]
    total = s + gen_len
    logits_last, cache = model.prefill_with_cache(
        cfg, params, prompt_tokens, cache_seq_len=cache_len or total
    )
    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(cfg, p, c, t, pos), donate_argnums=1
    )
    toks = []
    if cfg.num_codebooks > 1:
        nxt = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None, :]
    else:
        nxt = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None]
    for i in range(gen_len):
        toks.append(nxt)
        logits, cache = decode(params, cache, nxt, jnp.int32(s + i))
        if cfg.num_codebooks > 1:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None, :]
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return jnp.concatenate(toks, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params, _ = model.init_params(cfg, key)
    shape = (
        (args.batch, args.prompt_len, cfg.num_codebooks)
        if cfg.num_codebooks > 1
        else (args.batch, args.prompt_len)
    )
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=jnp.int32)

    t0 = time.time()
    out = generate(cfg, params, prompt, args.gen)
    dt = time.time() - t0
    print(json.dumps({
        "arch": args.arch,
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / dt, 2),
        "finite": bool(jnp.all(out >= 0)),
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
