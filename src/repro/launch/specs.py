"""ShapeDtypeStruct stand-ins (+ logical axes) for every model input.

This is the dry-run's contract: for each (arch, shape) cell we can build the
full argument pytrees — parameters, optimizer state, batches, KV/state caches
— as zero-allocation specs, plus the parallel logical-axes trees the sharding
rules consume.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import model
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def eval_shape_with_axes(fn: Callable[[], tuple[Any, Any]]) -> tuple[Any, Any]:
    """eval_shape over a () -> (arrays, axes) fn; axes via side channel
    (axes trees hold string tuples which eval_shape cannot return)."""
    captured = {}

    def arrays_only():
        arrays, axes = fn()
        captured["axes"] = axes
        return arrays

    shapes = jax.eval_shape(arrays_only)
    return shapes, captured["axes"]


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def token_shape(cfg, batch: int, seq: int) -> tuple[int, ...]:
    """Token-array shape for one step: (B, S) or (B, S, codebooks).

    THE shape authority shared by the dry-run specs below and the serving
    workload expansion (``repro.serving.expand``): decode is ``seq == 1``,
    so ``token_shape(cfg, b, 1)`` is exactly the ``decode_batch_specs``
    token shape — one helper, no duplicated shape math (the historical
    decode-shape drift between ``launch/`` and workload generators is
    regression-tested in tests/test_serving.py).
    """
    if cfg.num_codebooks > 1:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def _token_spec(cfg, batch: int, seq: int) -> tuple[SDS, tuple]:
    shape = token_shape(cfg, batch, seq)
    if len(shape) == 3:
        return SDS(shape, jnp.int32), ("batch", "seq", "codebooks")
    return SDS(shape, jnp.int32), ("batch", "seq")


def _position_spec(cfg, batch: int, seq: int) -> tuple[SDS, tuple]:
    if cfg.rope_kind == "mrope":
        return SDS((3, batch, seq), jnp.int32), (None, "batch", "seq")
    return SDS((batch, seq), jnp.int32), ("batch", "seq")


def train_batch_specs(cfg, shape) -> tuple[dict, dict]:
    b, s = shape.global_batch, shape.seq_len
    tok, tok_ax = _token_spec(cfg, b, s)
    pos, pos_ax = _position_spec(cfg, b, s)
    specs = {"tokens": tok, "labels": tok, "positions": pos}
    axes = {"tokens": tok_ax, "labels": tok_ax, "positions": pos_ax}
    return specs, axes


def prefill_batch_specs(cfg, shape) -> tuple[dict, dict]:
    b, s = shape.global_batch, shape.seq_len
    tok, tok_ax = _token_spec(cfg, b, s)
    pos, pos_ax = _position_spec(cfg, b, s)
    return {"tokens": tok, "positions": pos}, {"tokens": tok_ax, "positions": pos_ax}


def decode_batch_specs(cfg, shape) -> tuple[dict, dict]:
    b = shape.global_batch
    tok, tok_ax = _token_spec(cfg, b, 1)
    return (
        {"tokens": tok, "pos": SDS((), jnp.int32)},
        {"tokens": tok_ax, "pos": ()},
    )


# ---------------------------------------------------------------------------
# State / cache specs
# ---------------------------------------------------------------------------


def param_specs(cfg) -> tuple[Any, Any]:
    return model.shapes_and_axes(cfg)


def train_state_specs(cfg, opt_cfg: adamw.AdamWConfig) -> tuple[dict, dict]:
    """{'params', 'opt_state'} spec + axes trees; moments share param axes."""
    p_shapes, p_axes = param_specs(cfg)
    mdt = jnp.dtype(opt_cfg.moment_dtype)
    mom = jax.tree.map(lambda s: SDS(s.shape, mdt), p_shapes)
    state = {
        "params": p_shapes,
        "opt_state": {"m": mom, "v": mom, "step": SDS((), jnp.int32)},
    }
    axes = {
        "params": p_axes,
        "opt_state": {"m": p_axes, "v": p_axes, "step": ()},
    }
    return state, axes


def cache_specs(cfg, shape, dtype=None) -> tuple[Any, Any]:
    b, s = shape.global_batch, shape.seq_len
    return eval_shape_with_axes(lambda: model.init_cache(cfg, b, s, dtype))


def input_specs(cfg, shape) -> tuple[dict, dict]:
    """All step inputs for one (arch, shape) cell, by shape kind.

    train  -> {'state', 'batch'}
    prefill-> {'params', 'batch'}
    decode -> {'params', 'cache', 'batch'}
    """
    if shape.kind == "train":
        state, state_ax = train_state_specs(cfg, adamw.AdamWConfig())
        batch, batch_ax = train_batch_specs(cfg, shape)
        return {"state": state, "batch": batch}, {"state": state_ax, "batch": batch_ax}
    if shape.kind == "prefill":
        params, p_ax = param_specs(cfg)
        batch, batch_ax = prefill_batch_specs(cfg, shape)
        return {"params": params, "batch": batch}, {"params": p_ax, "batch": batch_ax}
    if shape.kind == "decode":
        params, p_ax = param_specs(cfg)
        cache, c_ax = cache_specs(cfg, shape)
        batch, batch_ax = decode_batch_specs(cfg, shape)
        return (
            {"params": params, "cache": cache, "batch": batch},
            {"params": p_ax, "cache": c_ax, "batch": batch_ax},
        )
    raise ValueError(f"unknown shape kind {shape.kind}")
