"""Launchers: mesh, specs, steps, dryrun, train, serve."""
