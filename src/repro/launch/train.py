"""Training driver: end-to-end fault-tolerant training on any arch config.

CPU-runnable example (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi_6b --reduced \\
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

On a real cluster this same driver runs per-host under the production mesh
(--mesh data,model), with the coordinator handling checkpoints, preemption
and elastic restarts.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig
from repro.launch.steps import make_train_step
from repro.models import model
from repro.optim import adamw
from repro.optim.schedule import linear_warmup_cosine
from repro.runtime.coordinator import CoordinatorConfig, TrainingCoordinator


def build(arch: str, reduced: bool, batch: int, seq: int, steps: int, ckpt_dir: str,
          lr: float = 3e-4, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    opt_cfg = adamw.AdamWConfig(lr=lr, schedule=linear_warmup_cosine(10, steps))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=0)

    def init_state():
        params, _ = model.init_params(cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt_state": adamw.init_state(opt_cfg, params)}

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=seq,
        global_batch=batch,
        num_codebooks=cfg.num_codebooks,
        seed=seed,
    )
    coord = TrainingCoordinator(
        train_step=step_fn,
        init_state=init_state,
        data_cfg=data_cfg,
        ckpt=CheckpointManager(ckpt_dir, keep=3),
        cfg=CoordinatorConfig(checkpoint_every=max(steps // 4, 1), max_steps=steps),
    )
    return coord


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--fail-at", type=int, default=None, help="inject crash (test)")
    args = ap.parse_args()

    coord = build(
        args.arch, args.reduced, args.batch, args.seq, args.steps, args.ckpt_dir,
        lr=args.lr,
    )
    coord.install_preemption_handler()
    step, _ = coord.run(steps=args.steps, fail_at_step=args.fail_at)
    first, last = coord.metrics_log[0], coord.metrics_log[-1]
    print(json.dumps({
        "arch": args.arch,
        "steps_run": len(coord.metrics_log),
        "final_step": step,
        "loss_first": first["loss"],
        "loss_last": last["loss"],
        "improved": last["loss"] < first["loss"],
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
