"""Step functions: training update, serving prefill, serving decode.

These are the functions the dry-run lowers and the drivers execute. They are
pure (state in, state out) so pjit can donate buffers, and they apply the
gradient-compression hook before the optimizer (the cast changes the dtype of
the XLA-inserted cross-pod all-reduce — a measurable collective-bytes lever).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import model
from repro.optim import adamw, compress


def make_train_step(
    cfg,
    opt_cfg: adamw.AdamWConfig,
    grad_compression: str = "none",  # 'none' | 'bf16'
) -> Callable[[dict, dict], tuple[dict, dict]]:
    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        def lf(p):
            return model.loss_fn(cfg, p, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        if grad_compression == "bf16":
            grads = compress.compress_bf16(grads)
        params, opt_state, om = adamw.apply_updates(
            opt_cfg, state["params"], state["opt_state"], grads
        )
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "ce": metrics["ce"].astype(jnp.float32),
            "aux": metrics["aux"].astype(jnp.float32),
            "grad_norm": om["grad_norm"],
            "lr": om["lr"],
        }
        return {"params": params, "opt_state": opt_state}, out_metrics

    return train_step


def make_prefill_step(cfg) -> Callable[[Any, dict], jnp.ndarray]:
    """Serving prefill: next-token logits for the last position (B, V[, K])."""

    def prefill_step(params: Any, batch: dict) -> jnp.ndarray:
        logits, _ = model.forward(
            cfg, params, batch["tokens"], batch.get("positions"), last_only=True
        )
        return logits

    return prefill_step


def make_decode_step(cfg) -> Callable[[Any, Any, dict], tuple[jnp.ndarray, Any]]:
    """Serving decode: one new token against the KV/state cache."""

    def decode_step(params: Any, cache: Any, batch: dict) -> tuple[jnp.ndarray, Any]:
        return model.decode_step(cfg, params, cache, batch["tokens"], batch["pos"])

    return decode_step


def step_for_shape(cfg, shape, opt_cfg: adamw.AdamWConfig | None = None, **kw):
    """(callable, donate_argnums) for one cell's step function."""
    if shape.kind == "train":
        return make_train_step(cfg, opt_cfg or adamw.AdamWConfig(), **kw), (0,)
    if shape.kind == "prefill":
        return make_prefill_step(cfg), ()
    if shape.kind == "decode":
        return make_decode_step(cfg), (1,)
    raise ValueError(shape.kind)
