import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + (" " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")).rstrip()
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first backend init. Everything below is ordinary code.

For each cell this:
  1. builds the production mesh (16x16 single-pod, or 2x16x16 multi-pod),
  2. builds ShapeDtypeStruct inputs + NamedShardings from the logical-axes
     trees (repro.launch.specs + repro.parallel.sharding),
  3. jit(...).lower(...).compile() — compile success IS the test,
  4. records memory_analysis(), cost_analysis(), and the collective-byte
     schedule parsed from the optimized HLO, as one JSON file per cell.

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.hlo import collective_stats
from repro.analysis.roofline import model_flops_for, roofline
from repro.configs.registry import SHAPES, all_cells, get_arch
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import step_for_shape
from repro.optim import adamw
from repro.parallel import sharding as sh


def _memory_dict(mem) -> dict:
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(cost) -> dict:
    if cost is None:
        return {}
    return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}


def _compile_cell(cfg, shape, mesh, opt_cfg, comp, param_rules, act_rules):
    """Lower + compile one step function; returns the compiled executable."""
    in_specs, in_axes = specs_lib.input_specs(cfg, shape)
    if shape.kind == "train":
        step, donate = step_for_shape(cfg, shape, opt_cfg, grad_compression=comp)
        order = ("state", "batch")
    elif shape.kind == "prefill":
        step, donate = step_for_shape(cfg, shape)
        order = ("params", "batch")
    else:
        step, donate = step_for_shape(cfg, shape)
        order = ("params", "cache", "batch")
    args = tuple(in_specs[k] for k in order)
    arg_axes = tuple(in_axes[k] for k in order)

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    in_shardings = jax.tree.map(
        lambda ax, sds: sh.sharding_for(ax, sds.shape, mesh, param_rules),
        arg_axes,
        args,
        is_leaf=is_axes_leaf,
    )
    with sh.activation_sharding(mesh, act_rules):
        jitted = jax.jit(step, in_shardings=in_shardings, donate_argnums=donate)
        return jitted.lower(*args).compile()


def _cost_and_collectives(compiled) -> tuple[float, float, float]:
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(coll.total_bytes),
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    out_dir: str | Path = "results/dryrun",
    grad_compression: str | None = None,
    remat: str | None = None,
    rules_override: dict | None = None,
    cfg_overrides: dict | None = None,
    moment_dtype: str | None = None,
    tag: str = "",
) -> dict:
    """Lower+compile one cell; returns (and writes) the record dict.

    Cost accounting note: XLA's cost_analysis counts a ``while``-loop (scan)
    body ONCE, not trip-count times. We therefore compile two reduced-depth
    variants (n_stages=1 and n_stages=2) of the same cell and extrapolate
    linearly — exact for scan, whose body is iteration-invariant:
        total(n) = c1 + (c2 - c1) * (n - 1).
    The full-depth compile still provides memory_analysis (true HBM residency
    with all stacked params) and proves the production config compiles.
    """
    import dataclasses

    shape = SHAPES[shape_name]
    cfg = get_arch(arch).with_dtypes("bfloat16", "bfloat16")
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    # llama4-400B: bf16 optimizer moments (16-bit optimizer) to fit v5e HBM
    opt_cfg = adamw.AdamWConfig(
        moment_dtype=moment_dtype or ("bfloat16" if "llama4" in arch else "float32")
    )
    comp = grad_compression or ("bf16" if multi_pod else "none")

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    param_rules = dict(sh.DEFAULT_PARAM_RULES)
    act_rules = dict(sh.DEFAULT_ACT_RULES)
    if rules_override:
        param_rules.update(rules_override.get("param", {}))
        act_rules.update(rules_override.get("act", {}))

    t0 = time.time()
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "grad_compression": comp if shape.kind == "train" else None,
        "remat": cfg.remat,
        "tag": tag,
    }
    try:
        compiled = _compile_cell(cfg, shape, mesh, opt_cfg, comp, param_rules, act_rules)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_stats(hlo)

        # depth-extrapolated cost (see docstring)
        pat = len(cfg.stage_pattern)
        n_stages = cfg.n_stages
        if n_stages > 2:
            cfg1 = dataclasses.replace(cfg, n_layers=pat)
            cfg2 = dataclasses.replace(cfg, n_layers=2 * pat)
            f1, b1, c1 = _cost_and_collectives(
                _compile_cell(cfg1, shape, mesh, opt_cfg, comp, param_rules, act_rules)
            )
            f2, b2, c2 = _cost_and_collectives(
                _compile_cell(cfg2, shape, mesh, opt_cfg, comp, param_rules, act_rules)
            )
            flops_dev = f1 + (f2 - f1) * (n_stages - 1)
            bytes_dev = b1 + (b2 - b1) * (n_stages - 1)
            coll_dev = c1 + (c2 - c1) * (n_stages - 1)
        else:
            flops_dev, bytes_dev, coll_dev = _cost_and_collectives(compiled)

        rf = roofline(
            flops_dev, bytes_dev, coll_dev, chips, model_flops_for(cfg, shape)
        )
        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 2),
            memory=_memory_dict(mem),
            cost=_cost_dict(cost),
            cost_extrapolated={
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "coll_bytes_per_device": coll_dev,
            },
            collectives=coll.as_dict(),
            roofline=rf.as_dict(),
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # record failures — they are bugs to fix
        record.update(
            status="error",
            compile_s=round(time.time() - t0, 2),
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )

    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{record['mesh']}" + (f"__{tag}" if tag else "")
    (out_dir / f"{name}.json").write_text(json.dumps(record, indent=1))
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--moment-dtype", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--cfg", default=None,
        help='JSON dict of ArchConfig overrides, e.g. \'{"loss_chunk": 512}\'',
    )
    ap.add_argument(
        "--rules", default=None,
        help='JSON sharding-rule overrides: {"param": {...}, "act": {...}}; '
        "rule values are lists of mesh-axis-name lists, e.g. "
        '\'{"param": {"expert_embed": []}, "act": {"expert_embed": []}}\'',
    )
    args = ap.parse_args()
    cfg_overrides = json.loads(args.cfg) if args.cfg else None
    rules_override = None
    if args.rules:
        raw = json.loads(args.rules)
        rules_override = {
            kind: {ax: tuple(tuple(g) for g in groups) for ax, groups in d.items()}
            for kind, d in raw.items()
        }

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in cells:
        rec = run_cell(
            arch,
            shape,
            multi_pod=args.multi_pod,
            out_dir=args.out,
            grad_compression=args.grad_compression,
            remat=args.remat,
            rules_override=rules_override,
            cfg_overrides=cfg_overrides,
            moment_dtype=args.moment_dtype,
            tag=args.tag,
        )
        if rec["status"] == "ok":
            r = rec["roofline"]
            m = rec["memory"]
            print(
                f"OK   {arch:24s} {shape:12s} {rec['mesh']:8s} "
                f"compile={rec['compile_s']:7.1f}s "
                f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
                f"t_coll={r['t_collective_s']:.3e} dom={r['dominant']:10s} "
                f"frac={r['roofline_fraction']:.3f}",
                flush=True,
            )
            print(
                f"     memory_analysis: args={m.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                f"out={m.get('output_size_in_bytes', 0)/2**30:.2f}GiB "
                f"temp={m.get('temp_size_in_bytes', 0)/2**30:.2f}GiB per device | "
                f"cost_analysis: flops/dev={r['flops_per_device']:.3e} "
                f"bytes/dev={r['bytes_per_device']:.3e} "
                f"coll_bytes/dev={r['coll_bytes_per_device']:.3e}",
                flush=True,
            )
        else:
            failures += 1
            print(f"FAIL {arch:24s} {shape:12s} {rec['mesh']:8s} {rec['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
