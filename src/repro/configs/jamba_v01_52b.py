"""Jamba-v0.1 52B hybrid Mamba+attention MoE [arXiv:2403.19887; hf].

32L in 4 stages of 8 (attn:mamba = 1:7, attention at in-stage index 4 as in
the paper's figure); MoE (16 experts, top-2) every other layer; GQA kv=8.
"""

from repro.configs.registry import ArchConfig

_STAGE = (
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("attn", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba_v01_52b",
    n_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    stage_pattern=_STAGE,
    num_experts=16,
    top_k=2,
    subquadratic=True,  # mamba-dominated: runs long_500k
)
