"""Architecture + shape registry.

Every assigned architecture is a frozen ``ArchConfig``; ``reduced()`` derives
the same-family smoke-test config (small dims, same block pattern). Shapes are
the four assigned input regimes; ``applicable()`` encodes the long_500k
sub-quadratic rule from DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

MixerKind = Literal["attn", "mamba", "mlstm", "slstm"]
MlpKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    stage_pattern: tuple[tuple[MixerKind, MlpKind], ...] = (("attn", "dense"),)

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int | None = None
    rope_kind: Literal["standard", "mrope", "none"] = "standard"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_chunk: int = 1024  # dense attention below this seq, blockwise above

    # MoE
    num_experts: int = 1
    top_k: int = 1
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    moe_d_ff: int | None = None
    renormalize_topk: bool = True
    aux_loss_coef: float = 0.01
    # physical expert shards (>= num_experts, multiple of it): when E < the
    # TP axis, each expert's weights are broadcast over E_phys/E shards and
    # its capacity split among them, so EP still uses the whole 'model' axis
    # (mixtral: 8 experts -> 16 shards). 0 = num_experts.
    expert_shards: int = 0

    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0  # 0 -> d_model // 16

    # xLSTM
    xlstm_proj_factor: float = 2.0
    xlstm_slstm_pf: float = 4.0 / 3.0

    # IO / misc
    num_codebooks: int = 1  # musicgen: 4 EnCodec streams
    gated_mlp: bool = True  # SwiGLU-style; False -> classic 2-matrix FFN
    activation: str = "silu"
    scan_chunk: int = 512  # seq chunk for SSM/linear-attn/blockwise paths
    subquadratic: bool = False  # may run long_500k
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # 'stage' ('full' = alias): checkpoint each scanned stage;
    # 'block': finer per-(mixer|mlp)-block checkpoints (deep stage patterns);
    # 'none': save everything.
    remat: Literal["none", "full", "stage", "block"] = "stage"
    # chunked cross-entropy: compute the LM head + CE over seq chunks of this
    # size (scan + per-chunk remat) so (B, S, V) logits never materialize.
    # 0 = off (full logits). Exactness is dtype-identical to the full path.
    loss_chunk: int = 0
    # dtype of the mamba selective-scan chunk tensors (a/u/h). f32 is exact;
    # bf16 halves the dominant train-time working set (validated in tests).
    mamba_state_dtype: str = "float32"

    def __post_init__(self):
        if self.n_layers % len(self.stage_pattern):
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not a multiple of "
                f"stage pattern length {len(self.stage_pattern)}"
            )

    @property
    def n_stages(self) -> int:
        return self.n_layers // len(self.stage_pattern)

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(self.d_model // 16, 8)

    def with_dtypes(self, param: str, compute: str) -> "ArchConfig":
        return dataclasses.replace(self, param_dtype=param, compute_dtype=compute)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests (one stage)."""
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            n_layers=len(self.stage_pattern),
            d_model=128,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            moe_d_ff=None if self.moe_d_ff is None else 256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            expert_shards=0,
            top_k=min(self.top_k, 2),
            window=min(self.window, 16) if self.window else None,
            attn_chunk=64,
            scan_chunk=16,
            mrope_sections=(4, 6, 6),
            mamba_dt_rank=8,
            param_dtype="float32",
            compute_dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings + stages + head)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = (
    "musicgen_medium",
    "jamba_v01_52b",
    "qwen2_vl_7b",
    "xlstm_1p3b",
    "granite_20b",
    "yi_6b",
    "qwen15_4b",
    "qwen3_8b",
    "llama4_maverick_400b",
    "mixtral_8x7b",
)

# external ids (assignment spelling) -> module ids
ALIASES = {
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "xlstm-1.3b": "xlstm_1p3b",
    "granite-20b": "granite_20b",
    "yi-6b": "yi_6b",
    "qwen1.5-4b": "qwen15_4b",
    "qwen3-8b": "qwen3_8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "mixtral-8x7b": "mixtral_8x7b",
}


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k requires sub-quadratic sequence mixing (DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) dry-run cell."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_arch(a)
        for s in SHAPES.values():
            if applicable(cfg, s):
                cells.append((a, s.name))
    return cells
