"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B]. 40L d=2560 MHA 20/20, QKV bias."""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen15_4b",
    n_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
)
