"""Granite-20B (code) [arXiv:2405.04324; hf].

52L, d=6144, 48 heads with MQA (kv=1 — TP-replicated KV, see sharding
fallback), d_ff=24576 non-gated GELU FFN (GPT-BigCode lineage), vocab=49152.
"""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="granite_20b",
    n_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,
    activation="gelu",
)
