"""Yi-6B llama-arch GQA [arXiv:2403.04652; hf]. 32L d=4096 GQA 32/4."""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="yi_6b",
    n_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5000000.0,
)
