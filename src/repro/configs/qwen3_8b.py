"""Qwen3-8B [hf:Qwen/Qwen3-8B]. 36L d=4096 GQA 32/8, per-head qk RMSNorm."""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_8b",
    n_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
)
