"""Mixtral-8x7B [arXiv:2401.04088]. 32L d=4096 GQA 32/8; 8 experts top-2
every layer; sliding-window attention (4096) => bounded KV cache, runs
long_500k."""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="mixtral_8x7b",
    n_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    stage_pattern=(("attn", "moe"),),
    num_experts=8,
    expert_shards=16,  # 2-way replication groups: fill the 16-wide TP axis
    top_k=2,
    window=4096,
    subquadratic=True,
)
