"""MusicGen-medium decoder backbone over EnCodec tokens [arXiv:2306.05284; hf].

48L, d=1536, 24 MHA heads (kv=24), d_ff=6144 (non-gated GELU FFN), vocab=2048
per codebook, 4 codebooks (embeddings summed; 4 parallel LM heads). The
EnCodec frontend (+ delay-pattern interleaving) is a STUB: input_specs provide
the precomputed codebook token streams directly (DESIGN.md).
"""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium",
    n_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    stage_pattern=(("attn", "dense"),),
    gated_mlp=False,
    activation="gelu",
    num_codebooks=4,
)
