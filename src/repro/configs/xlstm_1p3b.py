"""xLSTM-1.3B [arXiv:2405.04517; unverified].

48 blocks, d=2048, 4 heads, no separate FFN (d_ff=0; projections live inside
the m/sLSTM blocks). Block ratio mLSTM:sLSTM = 7:1 (xLSTM[7:1]).
"""

from repro.configs.registry import ArchConfig

_STAGE = (("slstm", "none"),) + (("mlstm", "none"),) * 7

CONFIG = ArchConfig(
    name="xlstm_1p3b",
    n_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    stage_pattern=_STAGE,
    xlstm_proj_factor=2.0,
    subquadratic=True,  # recurrent state: runs long_500k
)
