"""Llama-4-Maverick 400B-A17B [hf; unverified].

48L, d=5120, GQA 40/8, vocab=202048; MoE every other layer (128 routed
experts top-1 + 1 shared expert, expert d_ff=8192); dense layers d_ff=16384.
Early-fusion multimodal frontend is a STUB (text tokens only in input_specs).
"""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="llama4_maverick_400b",
    n_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=202048,
    stage_pattern=(("attn", "dense"), ("attn", "moe")),
    num_experts=128,
    top_k=1,
    num_shared_experts=1,
    moe_d_ff=8192,
)
