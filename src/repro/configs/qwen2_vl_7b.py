"""Qwen2-VL-7B language backbone [arXiv:2409.12191; hf].

28L, d=3584, GQA 28/4, d_ff=18944, vocab=152064; QKV bias; M-RoPE with
(16, 24, 24) sections over head_dim/2=64. Vision frontend (dynamic-resolution
patch embed) is a STUB: positions arrive precomputed as a (3, B, S) stream.
"""

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_7b",
    n_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
)
