"""Arch configs; see registry.get_arch."""
