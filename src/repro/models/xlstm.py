"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar).

mLSTM is a gated linear-attention recurrence with per-head scalar gates:

    C_t = f_t C_{t-1} + i_t k_t v_t^T        (matrix memory, dh x dh)
    n_t = f_t n_{t-1} + i_t k_t              (normalizer)
    h_t = (C_t^T q_t) / max(|n_t . q_t|, 1)

Training/prefill uses the chunkwise-parallel form (intra-chunk quadratic +
inter-chunk carried state) — O(S) memory, sub-quadratic compute, which is why
xlstm-1.3b runs the long_500k cell. Simplification vs the paper: sigmoid
input/forget gates (bounded, stabilizer-free) instead of exp-input gating with
running max-state; documented in DESIGN.md §Arch-applicability.

sLSTM keeps the paper's exponential gating with the m-state stabilizer and a
per-head block-diagonal recurrent matrix; it is inherently sequential
(lax.scan over time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_param, ones_param, zeros_param
from repro.parallel.sharding import shard_hint


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, stack: int) -> tuple[dict, dict]:
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)  # pre-up-projection inner width
    h = cfg.num_heads
    keys = jax.random.split(key, 8)
    p, a = {}, {}
    dh = di // h
    p["w_up"], a["w_up"] = dense_param(keys[0], (d, 2 * di), ("embed", "inner"), stack=stack)
    # block-diagonal (per-head) q/k/v projections, as in the xLSTM reference
    p["wq"], a["wq"] = dense_param(keys[1], (h, dh, dh), ("heads", None, None), stack=stack, scale=dh ** -0.5)
    p["wk"], a["wk"] = dense_param(keys[2], (h, dh, dh), ("heads", None, None), stack=stack, scale=dh ** -0.5)
    p["wv"], a["wv"] = dense_param(keys[3], (h, dh, dh), ("heads", None, None), stack=stack, scale=dh ** -0.5)
    p["w_igate"], a["w_igate"] = dense_param(keys[4], (di, h), ("inner", "heads"), stack=stack)
    p["w_fgate"], a["w_fgate"] = dense_param(keys[5], (di, h), ("inner", "heads"), stack=stack)
    p["b_fgate"], a["b_fgate"] = ones_param((h,), ("heads",), stack=stack)  # bias>0: long memory
    p["out_norm"], a["out_norm"] = ones_param((di,), ("inner",), stack=stack)
    p["w_down"], a["w_down"] = dense_param(keys[6], (di, d), ("inner", "embed"), stack=stack)
    return p, a


def _mlstm_chunk(q, k, v, li, lf, c0, n0):
    """One chunk of the chunkwise-parallel mLSTM.

    q/k/v: (B, H, c, dh); li/lf: (B, H, c) log input/forget gates.
    c0: (B, H, dh, dh); n0: (B, H, dh). Returns (h, c1, n1).
    """
    cum = jnp.cumsum(lf, axis=-1)  # log decay from chunk start (inclusive)
    # intra-chunk decay matrix: M[t, j] = exp(cum_t - cum_j + li_j), j <= t
    log_m = cum[..., :, None] - cum[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((q.shape[2], q.shape[2]), dtype=bool))
    m = jnp.where(tri[None, None], jnp.exp(log_m), 0.0)

    scale = q.shape[-1] ** -0.5
    qk = jnp.einsum("bhtd,bhjd->bhtj", q, k) * scale  # (B, H, c, c)
    w = qk * m
    intra = jnp.einsum("bhtj,bhjd->bhtd", w, v)
    decay_t = jnp.exp(cum)[..., None]  # (B, H, c, 1)
    inter = decay_t * jnp.einsum("bhtd,bhde->bhte", q * scale, c0)
    # normalizer: q.n_t = decay_t * (q.n0) + row-sum of the gated qk matrix
    qn = decay_t[..., 0] * jnp.einsum("bhtd,bhd->bht", q * scale, n0) + jnp.sum(
        w, axis=-1
    )
    denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    h = (intra + inter) / denom

    # carry updates: decay from t to chunk end
    total = cum[..., -1:]  # (B, H, 1)
    dec_end = jnp.exp(total - cum + li)  # (B, H, c) includes input gate
    c1 = jnp.exp(total)[..., None] * c0 + jnp.einsum(
        "bhtd,bhte,bht->bhde", k, v, dec_end
    )
    n1 = jnp.exp(total) * n0 + jnp.einsum("bhtd,bht->bhd", k, dec_end)
    return h, c1, n1


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def mlstm_apply(p, x, cfg) -> jnp.ndarray:
    """Full-sequence mLSTM. x: (B, S, D)."""
    b, s, d = x.shape
    hh = cfg.num_heads
    di = int(cfg.xlstm_proj_factor * d)
    dh = di // hh
    dtype = x.dtype
    chunk = cfg.scan_chunk if s % cfg.scan_chunk == 0 else s
    nc = s // chunk

    up = x @ p["w_up"].astype(dtype)
    inner, z = jnp.split(up, 2, axis=-1)  # (B, S, di)
    inner = shard_hint(inner, "batch", None, "inner")  # full seq inside block
    inner_h = inner.reshape(b, s, hh, dh).transpose(0, 2, 1, 3)  # (B, H, S, dh)
    q = jnp.einsum("bhsd,hde->bhse", inner_h, p["wq"].astype(dtype))
    k = jnp.einsum("bhsd,hde->bhse", inner_h, p["wk"].astype(dtype))
    v = jnp.einsum("bhsd,hde->bhse", inner_h, p["wv"].astype(dtype))
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    li = jax.nn.log_sigmoid(inner @ p["w_igate"].astype(dtype)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        inner @ p["w_fgate"].astype(dtype) + p["b_fgate"].astype(dtype)
    ).astype(jnp.float32)
    li = li.transpose(0, 2, 1)  # (B, H, S)
    lf = lf.transpose(0, 2, 1)

    def step(carry, idx):
        c0, n0 = carry
        sl = lambda t, ax: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=ax)
        h, c1, n1 = _mlstm_chunk(
            sl(q, 2), sl(k, 2), sl(v, 2), sl(li, 2), sl(lf, 2), c0, n0
        )
        return (c1, n1), h

    c0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hh, dh), jnp.float32)
    if nc == 1:
        h, _, _ = _mlstm_chunk(q, k, v, li, lf, c0, n0)
    else:
        _, hs = jax.lax.scan(jax.checkpoint(step), (c0, n0), jnp.arange(nc))
        # hs: (nc, B, H, chunk, dh) -> (B, H, S, dh)
        h = jnp.moveaxis(hs, 0, 2).reshape(b, hh, s, dh)

    h = h.transpose(0, 2, 1, 3).reshape(b, s, di).astype(dtype)
    h = _rms(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    return h @ p["w_down"].astype(dtype)


def mlstm_cache_init(cfg, batch: int, stack: int, dtype) -> tuple[dict, dict]:
    hh = cfg.num_heads
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    dh = di // hh
    cache = {
        "C": jnp.zeros((stack, batch, hh, dh, dh), jnp.float32),
        "n": jnp.zeros((stack, batch, hh, dh), jnp.float32),
    }
    axes = {
        "C": ("layers", "batch", "heads", None, None),
        "n": ("layers", "batch", "heads", None),
    }
    return cache, axes


def mlstm_decode(p, x, cache, cfg) -> tuple[jnp.ndarray, dict]:
    """One-token mLSTM decode. x: (B, 1, D)."""
    b = x.shape[0]
    d = cfg.d_model
    hh = cfg.num_heads
    di = int(cfg.xlstm_proj_factor * d)
    dh = di // hh
    dtype = x.dtype

    up = x[:, 0] @ p["w_up"].astype(dtype)
    inner, z = jnp.split(up, 2, axis=-1)
    inner_h = inner.reshape(b, hh, dh)
    q = jnp.einsum("bhd,hde->bhe", inner_h, p["wq"].astype(dtype)).astype(jnp.float32)
    k = jnp.einsum("bhd,hde->bhe", inner_h, p["wk"].astype(dtype)).astype(jnp.float32)
    v = jnp.einsum("bhd,hde->bhe", inner_h, p["wv"].astype(dtype)).astype(jnp.float32)
    i_g = jax.nn.sigmoid(inner @ p["w_igate"].astype(dtype)).astype(jnp.float32)  # (B, H)
    f_g = jax.nn.sigmoid(
        inner @ p["w_fgate"].astype(dtype) + p["b_fgate"].astype(dtype)
    ).astype(jnp.float32)

    c1 = f_g[..., None, None] * cache["C"] + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n1 = f_g[..., None] * cache["n"] + i_g[..., None] * k
    scale = dh ** -0.5
    num = jnp.einsum("bhde,bhd->bhe", c1, q * scale)
    qn = jnp.einsum("bhd,bhd->bh", n1, q * scale)
    h = num / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    h = h.reshape(b, di).astype(dtype)
    h = _rms(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    out = (h @ p["w_down"].astype(dtype))[:, None, :]
    return out, {"C": c1, "n": n1}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg, stack: int) -> tuple[dict, dict]:
    d = cfg.d_model
    hh = cfg.num_heads
    dh = d // hh
    keys = jax.random.split(key, 10)
    p, a = {}, {}
    for i, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"], a[f"w_{gate}"] = dense_param(
            keys[i], (d, d), ("embed", "inner"), stack=stack
        )
        p[f"r_{gate}"], a[f"r_{gate}"] = dense_param(
            keys[4 + i], (hh, dh, dh), ("heads", None, None), stack=stack, scale=dh ** -0.5
        )
        p[f"b_{gate}"], a[f"b_{gate}"] = zeros_param((d,), ("inner",), stack=stack)
    p["out_norm"], a["out_norm"] = ones_param((d,), ("embed",), stack=stack)
    # post-recurrence gated MLP (xLSTM block: PF 4/3), rounded to 128
    ff = max(128, int(round(cfg.xlstm_slstm_pf * d / 128)) * 128)
    p["w_ff_gate"], a["w_ff_gate"] = dense_param(keys[8], (d, ff), ("embed", "mlp"), stack=stack)
    p["w_ff_down"], a["w_ff_down"] = dense_param(keys[9], (ff, d), ("mlp", "embed"), stack=stack)
    return p, a


def slstm_apply(p, x, cfg) -> jnp.ndarray:
    """Full-sequence sLSTM (sequential scan over time). x: (B, S, D)."""
    b, s, d = x.shape
    hh = cfg.num_heads
    dh = d // hh
    dtype = x.dtype

    # precompute input contributions for all gates: (B, S, D) each
    pre = {
        g: (x @ p[f"w_{g}"].astype(dtype) + p[f"b_{g}"].astype(dtype)).astype(jnp.float32)
        for g in ("z", "i", "f", "o")
    }
    r = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, t):
        c, n, h, m = carry  # (B, H, dh) x3, (B, H)
        rec = {g: jnp.einsum("bhd,hde->bhe", h, r[g]) for g in r}
        sl = lambda g: jax.lax.dynamic_slice_in_dim(pre[g], t, 1, axis=1)[:, 0].reshape(
            b, hh, dh
        )
        z = jnp.tanh(sl("z") + rec["z"])
        i_t = sl("i") + rec["i"]
        f_t = sl("f") + rec["f"]
        o = jax.nn.sigmoid(sl("o") + rec["o"])
        # exponential gating with per-(B, H, dh) log-stabilizer state m
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    zeros = jnp.zeros((b, hh, dh), jnp.float32)
    init = (zeros, zeros, zeros, zeros)
    _, hs = jax.lax.scan(step, init, jnp.arange(s))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(dtype)
    h = _rms(h, p["out_norm"])
    # gated feed-forward (GELU-gate)
    ffh = jax.nn.gelu(h @ p["w_ff_gate"].astype(dtype))
    return ffh @ p["w_ff_down"].astype(dtype)


def slstm_cache_init(cfg, batch: int, stack: int, dtype) -> tuple[dict, dict]:
    hh = cfg.num_heads
    dh = cfg.d_model // hh
    shape = (stack, batch, hh, dh)
    cache = {k: jnp.zeros(shape, jnp.float32) for k in ("c", "n", "h", "m")}
    axes = {k: ("layers", "batch", "heads", None) for k in cache}
    return cache, axes


def slstm_decode(p, x, cache, cfg) -> tuple[jnp.ndarray, dict]:
    b = x.shape[0]
    d = cfg.d_model
    hh = cfg.num_heads
    dh = d // hh
    dtype = x.dtype
    c, n, h, m = cache["c"], cache["n"], cache["h"], cache["m"]
    rec = {
        g: jnp.einsum("bhd,hde->bhe", h, p[f"r_{g}"].astype(jnp.float32))
        for g in ("z", "i", "f", "o")
    }
    pre = {
        g: (x[:, 0] @ p[f"w_{g}"].astype(dtype) + p[f"b_{g}"].astype(dtype))
        .astype(jnp.float32)
        .reshape(b, hh, dh)
        for g in ("z", "i", "f", "o")
    }
    z = jnp.tanh(pre["z"] + rec["z"])
    i_t = pre["i"] + rec["i"]
    f_t = pre["f"] + rec["f"]
    o = jax.nn.sigmoid(pre["o"] + rec["o"])
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    out = h_new.reshape(b, d).astype(dtype)
    out = _rms(out, p["out_norm"])
    ffh = jax.nn.gelu(out @ p["w_ff_gate"].astype(dtype))
    out = (ffh @ p["w_ff_down"].astype(dtype))[:, None, :]
    return out, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}
