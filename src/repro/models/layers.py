"""Shared model layers: norms, rotary embeddings, chunked attention math.

Everything is pure-functional: params are pytrees of jnp arrays; a parallel
pytree of logical-axis tuples (see ``repro.parallel.sharding``) is built at
init time by the same functions, so sharding rules never have to pattern-match
parameter names.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Axes = dict

_NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Parameter initialization helpers (stacked over a leading 'layers' axis).
# ---------------------------------------------------------------------------


def dense_param(
    key: jax.Array,
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    *,
    stack: int | None = None,
    scale: float | None = None,
    dtype: jnp.dtype = jnp.float32,
) -> tuple[jnp.ndarray, tuple[str | None, ...]]:
    """Fan-in-scaled normal param; optionally stacked over a 'layers' axis."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    full_shape = (stack, *shape) if stack is not None else shape
    full_axes = ("layers", *axes) if stack is not None else axes
    return std * jax.random.normal(key, full_shape, dtype=dtype), full_axes


def ones_param(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    *,
    stack: int | None = None,
    dtype: jnp.dtype = jnp.float32,
) -> tuple[jnp.ndarray, tuple[str | None, ...]]:
    full_shape = (stack, *shape) if stack is not None else shape
    full_axes = ("layers", *axes) if stack is not None else axes
    return jnp.ones(full_shape, dtype=dtype), full_axes


def zeros_param(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    *,
    stack: int | None = None,
    dtype: jnp.dtype = jnp.float32,
) -> tuple[jnp.ndarray, tuple[str | None, ...]]:
    full_shape = (stack, *shape) if stack is not None else shape
    full_axes = ("layers", *axes) if stack is not None else axes
    return jnp.zeros(full_shape, dtype=dtype), full_axes


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jnp.ndarray,  # (..., S) int32
    head_dim: int,
    theta: float = 10000.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables (..., S, head_dim/2) for the given positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    x: jnp.ndarray,  # (B, H, S, D)
    cos: jnp.ndarray,  # (B, S, D/2)
    sin: jnp.ndarray,
) -> jnp.ndarray:
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, None, :, :]
    s = sin[:, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def mrope_angles(
    positions: jnp.ndarray,  # (3, B, S) int32 — temporal / height / width streams
    head_dim: int,
    sections: tuple[int, int, int],
    theta: float = 10000.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL multimodal RoPE: head_dim/2 rotary freqs split into 3 sections,
    each driven by its own position stream. Returns (B, S, D/2) cos/sin."""
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"M-RoPE sections {sections} must sum to head_dim/2 = {half}")
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    section_id = jnp.asarray(np.repeat(np.arange(3), sections))  # (half,)
    pos_per_freq = positions[section_id]  # (half, B, S): stream per freq index
    ang = jnp.moveaxis(pos_per_freq, 0, -1).astype(jnp.float32) * freqs  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# Attention math: chunked (flash-style) prefill/train + cached decode
# ---------------------------------------------------------------------------


def _mask_chunk(
    q_off: jnp.ndarray,
    k_off: jnp.ndarray,
    q_chunk: int,
    k_chunk: int,
    causal: bool,
    window: int | None,
) -> jnp.ndarray:
    q_ids = q_off + jnp.arange(q_chunk)[:, None]
    k_ids = k_off + jnp.arange(k_chunk)[None, :]
    mask = jnp.ones((q_chunk, k_chunk), dtype=bool)
    if causal:
        mask &= q_ids >= k_ids
    if window is not None:
        mask &= (q_ids - k_ids) < window
    return mask


def blockwise_attention(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, S, D)   (kv heads pre-expanded)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    k_chunk: int = 512,
    remat: bool = True,
) -> jnp.ndarray:
    """Online-softmax attention, chunked in both q and kv.

    Never materializes more than (B, H, q_chunk, k_chunk) of logits — the
    pure-XLA analogue of FlashAttention, required for the 32k-seq shapes where
    full (S, S) logits would be terabytes. ``remat`` checkpoints each kv-step
    so the backward pass recomputes chunk logits instead of storing them.
    """
    b, h, s, d = q.shape
    if s % q_chunk or s % k_chunk:
        # fall back to dense for small/ragged sequences (smoke tests)
        return dense_attention(q, k, v, causal=causal, window=window)
    scale = d ** -0.5
    nq, nk = s // q_chunk, s // k_chunk
    qc = q.reshape(b, h, nq, q_chunk, d)
    kc = k.reshape(b, h, nk, k_chunk, d)
    vc = v.reshape(b, h, nk, k_chunk, d)

    def kv_step(carry, kv_idx):
        m_prev, l_prev, acc, q_blk, q_off = carry
        k_blk = jax.lax.dynamic_index_in_dim(kc, kv_idx, axis=2, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vc, kv_idx, axis=2, keepdims=False)
        s_blk = (
            jnp.einsum(
                "bhqd,bhkd->bhqk",
                q_blk.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            )
            * scale
        )
        mask = _mask_chunk(q_off, kv_idx * k_chunk, q_chunk, k_chunk, causal, window)
        s_blk = jnp.where(mask[None, None], s_blk, _NEG_INF)
        m_cur = jnp.max(s_blk, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s_blk - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc_new, q_blk, q_off), None

    kv_step_fn = jax.checkpoint(kv_step) if remat else kv_step

    def q_step(_, q_idx):
        q_blk = jax.lax.dynamic_index_in_dim(qc, q_idx, axis=2, keepdims=False)
        q_off = q_idx * q_chunk
        init = (
            jnp.full((b, h, q_chunk, 1), _NEG_INF, jnp.float32),
            jnp.zeros((b, h, q_chunk, 1), jnp.float32),
            jnp.zeros((b, h, q_chunk, d), jnp.float32),
            q_blk,
            q_off,
        )
        (m_f, l_f, acc_f, _, _), _ = jax.lax.scan(kv_step_fn, init, jnp.arange(nk))
        l_f = jnp.where(l_f == 0.0, 1.0, l_f)
        return None, (acc_f / l_f).astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, jnp.arange(nq))
    # out: (nq, B, H, q_chunk, D) -> (B, H, S, D)
    return jnp.moveaxis(out, 0, 2).reshape(b, h, s, d)


def dense_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    """Reference dense attention (small seqs / smoke tests)."""
    b, h, s, d = q.shape
    logits = (
        jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        * d ** -0.5
    )
    mask = _mask_chunk(jnp.int32(0), jnp.int32(0), s, s, causal, window)
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, H, 1, D)
    k_cache: jnp.ndarray,  # (B, KV, S_max, D)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # () or (B,) current position (the new token's index)
    *,
    window: int | None = None,
) -> jnp.ndarray:
    """Single-token attention against a (possibly sharded) KV cache."""
    b, h, _, d = q.shape
    kv = k_cache.shape[1]
    rep = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, kv, rep, d)
    logits = jnp.einsum(
        "bgrd,bgsd->bgrs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    s_max = k_cache.shape[2]
    k_ids = jnp.arange(s_max)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))[:, None]  # (B, 1)
    valid = k_ids[None, :] <= pos_b
    if window is not None:
        valid &= (pos_b - k_ids[None, :]) < window
    logits = jnp.where(valid[:, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

ACTIVATIONS: dict[str, Callable[[jnp.ndarray], jnp.ndarray]] = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}
