"""Model zoo: transformer/MoE/Mamba/xLSTM blocks + assembly."""
