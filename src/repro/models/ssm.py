"""Mamba-1 selective-SSM block (Jamba's SSM layer), chunked for long seqs.

Training/prefill uses a seq-chunked ``lax.scan`` whose chunk interior is a
``lax.associative_scan`` over the per-step affine maps h -> a*h + b: the
(B, chunk, d_inner, d_state) working set stays VMEM/HBM-friendly at 500k
tokens where the naive (B, S, d_inner, d_state) tensor would be terabytes.
Decode carries (conv window, ssm state) and is O(1) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_param, ones_param, zeros_param
from repro.parallel.sharding import shard_hint


def mamba_init(key, cfg, stack: int) -> tuple[dict, dict]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = cfg.dt_rank
    kk = cfg.mamba_d_conv
    keys = jax.random.split(key, 8)
    p, a = {}, {}
    p["in_proj"], a["in_proj"] = dense_param(
        keys[0], (d, 2 * di), ("embed", "inner"), stack=stack
    )
    p["conv_w"], a["conv_w"] = dense_param(
        keys[1], (kk, di), ("conv", "inner"), stack=stack, scale=kk ** -0.5
    )
    p["conv_b"], a["conv_b"] = zeros_param((di,), ("inner",), stack=stack)
    p["x_proj"], a["x_proj"] = dense_param(
        keys[2], (di, dtr + 2 * n), ("inner", None), stack=stack
    )
    p["dt_proj"], a["dt_proj"] = dense_param(keys[3], (dtr, di), (None, "inner"), stack=stack)
    p["dt_bias"], a["dt_bias"] = zeros_param((di,), ("inner",), stack=stack)
    # A_log init ~ log(arange(1, N+1)): S4D-real init, broadcast over d_inner
    a_log = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
    a_init = jnp.broadcast_to(a_log, (di, n))
    if stack is not None:
        a_init = jnp.broadcast_to(a_init, (stack, di, n))
        p["A_log"], a["A_log"] = a_init, ("layers", "inner", "state")
    else:
        p["A_log"], a["A_log"] = a_init, ("inner", "state")
    p["D"], a["D"] = ones_param((di,), ("inner",), stack=stack)
    p["out_proj"], a["out_proj"] = dense_param(
        keys[4], (di, d), ("inner", "embed"), stack=stack
    )
    return p, a


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, di), w: (K, di) — causal depthwise 1-D convolution."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted-scaled copies: K is tiny (4), unrolled adds beat conv HLO
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssm_chunk(h0, a_c, b_c):
    """Affine-map scan over one chunk. a_c/b_c: (B, c, di, N); h0: (B, di, N)."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_cum, b_cum = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
    h = a_cum * h0[:, None] + b_cum  # h_t for every t in chunk
    return h, h[:, -1]


def mamba_apply(p, x, cfg, chunk: int | None = None) -> jnp.ndarray:
    """Full-sequence selective SSM. x: (B, S, D)."""
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = cfg.dt_rank
    chunk = chunk or cfg.scan_chunk
    dtype = x.dtype

    xz = x @ p["in_proj"].astype(dtype)  # (B, S, 2*di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    # inner-dim TP with FULL seq: the chunked time scan must not see a
    # sharded sequence axis (residual re-shards to SP at the stage boundary)
    x_in = shard_hint(x_in, "batch", None, "inner")
    x_conv = _causal_depthwise_conv(x_in, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype))
    x_act = jax.nn.silu(x_conv)

    dbc = x_act @ p["x_proj"].astype(dtype)  # (B, S, dtr + 2N)
    dt_low = dbc[..., :dtr]
    b_ssm = dbc[..., dtr : dtr + n].astype(jnp.float32)  # (B, S, N)
    c_ssm = dbc[..., dtr + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, di)
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    if s % chunk:
        chunk = s  # smoke-test shapes: single chunk
    nc = s // chunk
    xf = x_act.astype(jnp.float32)
    # the (B, c, di, N) chunk tensors dominate train-time memory; bf16 state
    # halves them (gates/decays still computed in f32 before the cast)
    sdt = jnp.dtype(cfg.mamba_state_dtype)

    def chunk_step(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, axis=1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(b_ssm), sl(c_ssm), sl(xf)
        a_c = jnp.exp(dt_c[..., None] * a_mat[None, None]).astype(sdt)  # (B,c,di,N)
        u_c = ((dt_c * x_c)[..., None] * b_c[:, :, None, :]).astype(sdt)
        h_all, h_last = _ssm_chunk(h.astype(sdt), a_c, u_c)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c.astype(sdt))
        return h_last.astype(jnp.float32), y_c.astype(jnp.float32)

    h0 = jnp.zeros((b, di, n), dtype=jnp.float32)
    if nc == 1:
        _, y = chunk_step(h0, 0)
    else:
        _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, jnp.arange(nc))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)

    y = (y + xf * p["D"].astype(jnp.float32)[None, None]).astype(dtype)
    y = y * jax.nn.silu(z)
    return (y @ p["out_proj"].astype(dtype))


def mamba_cache_init(cfg, batch: int, stack: int, dtype) -> tuple[dict, dict]:
    di = cfg.mamba_expand * cfg.d_model
    n = cfg.mamba_d_state
    kk = cfg.mamba_d_conv
    cache = {
        "conv": jnp.zeros((stack, batch, kk - 1, di), dtype=dtype),
        "ssm": jnp.zeros((stack, batch, di, n), dtype=jnp.float32),
    }
    axes = {
        "conv": ("layers", "batch", "conv", "inner"),
        "ssm": ("layers", "batch", "inner", "state"),
    }
    return cache, axes


def mamba_decode(p, x, cache, cfg) -> tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache: {conv (B,K-1,di), ssm (B,di,N)}."""
    b = x.shape[0]
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = cfg.dt_rank
    dtype = x.dtype

    xz = x[:, 0] @ p["in_proj"].astype(dtype)  # (B, 2di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv_prev = cache["conv"]  # (B, K-1, di)
    window = jnp.concatenate([conv_prev, x_in[:, None, :]], axis=1)  # (B, K, di)
    w = p["conv_w"].astype(dtype)  # (K, di)
    x_conv = jnp.einsum("bkd,kd->bd", window, w) + p["conv_b"].astype(dtype)
    x_act = jax.nn.silu(x_conv)

    dbc = x_act @ p["x_proj"].astype(dtype)
    dt_low = dbc[..., :dtr]
    b_ssm = dbc[..., dtr : dtr + n].astype(jnp.float32)
    c_ssm = dbc[..., dtr + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # (B, di)
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * a_mat[None])  # (B, di, N)
    h = decay * cache["ssm"] + (dt * x_act.astype(jnp.float32))[..., None] * b_ssm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_ssm)
    y = (y + x_act.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dtype))[:, None, :]
    return out, {"conv": window[:, 1:], "ssm": h}
