"""Transformer building blocks: GQA attention, dense MLP, routed MoE.

Each block exposes ``<block>_init(key, cfg, stack)`` returning parallel
(params, axes) pytrees — stacked over a leading 'layers' axis for scan — and
apply functions for full-sequence forward and single-token cached decode.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import (
    ACTIVATIONS,
    apply_rope,
    blockwise_attention,
    decode_attention,
    dense_attention,
    dense_param,
    mrope_angles,
    ones_param,
    rms_norm,
    rope_angles,
    zeros_param,
)
from repro.parallel.sharding import shard_hint


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_init(key, cfg, stack: int) -> tuple[dict, dict]:
    d = cfg.d_model
    hd = cfg.head_dim
    keys = jax.random.split(key, 8)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_param(
        keys[0], (d, cfg.num_heads, hd), ("embed", "heads", None), stack=stack
    )
    p["wk"], a["wk"] = dense_param(
        keys[1], (d, cfg.num_kv_heads, hd), ("embed", "kv_heads", None), stack=stack
    )
    p["wv"], a["wv"] = dense_param(
        keys[2], (d, cfg.num_kv_heads, hd), ("embed", "kv_heads", None), stack=stack
    )
    p["wo"], a["wo"] = dense_param(
        keys[3], (cfg.num_heads, hd, d), ("heads", None, "embed"), stack=stack
    )
    if cfg.qkv_bias:
        p["bq"], a["bq"] = zeros_param((cfg.num_heads, hd), ("heads", None), stack=stack)
        p["bk"], a["bk"] = zeros_param(
            (cfg.num_kv_heads, hd), ("kv_heads", None), stack=stack
        )
        p["bv"], a["bv"] = zeros_param(
            (cfg.num_kv_heads, hd), ("kv_heads", None), stack=stack
        )
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = ones_param((hd,), (None,), stack=stack)
        p["k_norm"], a["k_norm"] = ones_param((hd,), (None,), stack=stack)
    return p, a


def _qkv(p, x, cfg, cos, sin):
    """Project + (bias) + (qk-norm) + rope. x: (B, S, D) -> q/k/v (B, H, S, hd)."""
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
        k = k + p["bk"].astype(x.dtype)[None, :, None, :]
        v = v + p["bv"].astype(x.dtype)[None, :, None, :]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _rope_tables(cfg, positions):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE archs."""
    if positions is None:
        return None, None
    if cfg.rope_kind == "none":
        return None, None
    if cfg.rope_kind == "mrope":
        return mrope_angles(positions, cfg.head_dim, cfg.mrope_sections, cfg.rope_theta)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def attn_apply(p, x, cfg, positions) -> jnp.ndarray:
    """Full-sequence causal attention. x: (B, S, D)."""
    b, s, d = x.shape
    cos, sin = _rope_tables(cfg, positions)
    q, k, v = _qkv(p, x, cfg, cos, sin)
    # Megatron-SP style layout transition: the residual stream is
    # seq-sharded; attention internals run head-sharded over the FULL
    # sequence (explicit hints prevent SPMD from chasing the seq shard
    # through the GQA repeat / chunk reshapes — involuntary remat storms).
    q = shard_hint(q, "batch", "heads", None, None)
    k = shard_hint(k, "batch", "kv_heads", None, None)
    v = shard_hint(v, "batch", "kv_heads", None, None)
    rep = cfg.num_heads // cfg.num_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if s > cfg.attn_chunk:
        o = blockwise_attention(
            q, k, v, causal=True, window=cfg.window,
            q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk,
        )
    else:
        o = dense_attention(q, k, v, causal=True, window=cfg.window)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return shard_hint(out, "batch", "seq", "embed")


def attn_cache_init(cfg, batch: int, cache_len: int, stack: int, dtype) -> tuple[dict, dict]:
    """KV cache (+ per-slot position ring for SWA). Stacked over stages."""
    hd = cfg.head_dim
    shape = (stack, batch, cfg.num_kv_heads, cache_len, hd)
    axes = ("layers", "batch", "kv_heads", "cache_seq", None)
    cache = {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
        "slot_pos": jnp.full((stack, cache_len), -1, dtype=jnp.int32),
    }
    caxes = {"k": axes, "v": axes, "slot_pos": ("layers", "cache_seq")}
    return cache, caxes


def attn_decode(p, x, cache, pos, cfg) -> tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache entries are per-stage slices
    (B, KV, S_cache, hd) / (S_cache,). ``pos`` is the new token's position."""
    b = x.shape[0]
    cache_len = cache["k"].shape[2]
    if cfg.rope_kind == "mrope":
        # decode: all three M-RoPE streams advance with the text position
        pos_arr = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
    else:
        pos_arr = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    cos, sin = _rope_tables(cfg, pos_arr)
    q, k_new, v_new = _qkv(p, x, cfg, cos, sin)

    if cfg.window is not None and cache_len == cfg.window:
        slot = (pos % cache_len).astype(jnp.int32)  # SWA ring buffer
    else:
        slot = jnp.minimum(pos, cache_len - 1).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, 0, slot, 0)
    )
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, 0, slot, 0)
    )
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.asarray(pos, jnp.int32).reshape(1), (slot,)
    )

    rep = cfg.num_heads // cfg.num_kv_heads
    qh = q  # (B, H, 1, hd)
    kv_heads = cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5
    qg = qh.reshape(b, kv_heads, rep, cfg.head_dim)
    logits = (
        jnp.einsum("bgrk,bgsk->bgrs", qg.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    valid = slot_pos >= 0  # ring slots hold only in-window entries
    logits = jnp.where(valid[None, None, None, :], logits, -1.0e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgrs,bgsk->bgrk", probs, v.astype(jnp.float32))
    o = o.reshape(b, cfg.num_heads, 1, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": k, "v": v, "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg, stack: int, d_ff: int | None = None) -> tuple[dict, dict]:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    keys = jax.random.split(key, 3)
    p, a = {}, {}
    p["w_gate"], a["w_gate"] = dense_param(keys[0], (d, ff), ("embed", "mlp"), stack=stack)
    if cfg.gated_mlp:
        p["w_up"], a["w_up"] = dense_param(keys[1], (d, ff), ("embed", "mlp"), stack=stack)
    p["w_down"], a["w_down"] = dense_param(keys[2], (ff, d), ("mlp", "embed"), stack=stack)
    return p, a


def mlp_apply(p, x, cfg) -> jnp.ndarray:
    act = ACTIVATIONS[cfg.activation]
    h = act(x @ p["w_gate"].astype(x.dtype))
    if cfg.gated_mlp:
        h = h * (x @ p["w_up"].astype(x.dtype))
    # d_ff tensor-parallel, full seq (residual re-shards to SP afterwards)
    h = shard_hint(h, *(("batch", None, "mlp") if x.ndim == 3 else ("batch", "mlp")))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Routed MoE (gather/scatter dispatch — no dense one-hot einsum flops)
# ---------------------------------------------------------------------------


def moe_init(key, cfg, stack: int) -> tuple[dict, dict]:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    keys = jax.random.split(key, 5)
    p, a = {}, {}
    p["router"], a["router"] = dense_param(keys[0], (d, e), ("embed", None), stack=stack)
    # dedicated logical axes: expert weights' FSDP/TP assignment is a perf
    # lever independent of the dense layers' (see §Perf — replicating them
    # over 'data' trades ~1.5 GiB HBM for zero per-layer FSDP gathers)
    p["w_gate"], a["w_gate"] = dense_param(
        keys[1], (e, d, ff), ("experts", "expert_embed", "expert_mlp"), stack=stack
    )
    p["w_up"], a["w_up"] = dense_param(
        keys[2], (e, d, ff), ("experts", "expert_embed", "expert_mlp"), stack=stack
    )
    p["w_down"], a["w_down"] = dense_param(
        keys[3], (e, ff, d), ("experts", "expert_mlp", "expert_embed"), stack=stack
    )
    if cfg.num_shared_experts:
        p["shared"], a["shared"] = mlp_init(
            keys[4], cfg, stack=stack, d_ff=ff * cfg.num_shared_experts
        )
    return p, a


def _dispatch_local(x_loc, expert_idx_loc, e: int, k_top: int, capacity: int, shards: int):
    """Per-shard (device-local) capacity dispatch. x_loc: (T_loc, D).

    Sort-based ranking, static local capacity, overflow dropped. Returns the
    local expert buffers reshaped to (shards, E*capacity/shards, D) — the
    PHYSICAL expert layout (replication groups split an expert's capacity
    rows contiguously, which is a free local reshape of the same linear
    buffer) — and the slot->buffer-row map for the combine gather. Runs
    unpartitioned (single device or inside shard_map), so the scatter never
    crosses devices.
    """
    t_loc, d = x_loc.shape
    eids = expert_idx_loc.reshape(-1)  # (T_loc*k,) slot-major
    tok_of_slot = jnp.arange(t_loc * k_top) // k_top
    sort_idx = jnp.argsort(eids)  # stable
    sorted_eids = eids[sort_idx]
    group_start = jnp.searchsorted(sorted_eids, jnp.arange(e))
    rank_sorted = jnp.arange(t_loc * k_top) - group_start[sorted_eids]
    rank = jnp.zeros_like(rank_sorted).at[sort_idx].set(rank_sorted)

    valid = rank < capacity
    dest = jnp.where(valid, eids * capacity + rank, e * capacity)  # overflow row
    gathered = x_loc[tok_of_slot]  # (T_loc*k, D)
    buf = jnp.zeros((e * capacity + 1, d), dtype=x_loc.dtype)
    buf = buf.at[dest].add(gathered * valid[:, None].astype(x_loc.dtype))
    return buf[:-1].reshape(shards, e * capacity // shards, d), dest


def _combine_local(expert_out_loc, dest, gate_vals_loc, k_top: int):
    """Inverse of _dispatch_local: gather slots back to (T_loc, D)."""
    d = expert_out_loc.shape[-1]
    flat = expert_out_loc.reshape(-1, d)  # same linear order dest indexes
    padded = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)])
    valid = (dest < flat.shape[0]).astype(flat.dtype)
    per_slot = padded[dest] * (gate_vals_loc.reshape(-1) * valid)[:, None].astype(
        flat.dtype
    )
    t_loc = gate_vals_loc.shape[0]
    return jnp.sum(per_slot.reshape(t_loc, k_top, d), axis=1)


def _token_partition(mesh, t: int, act_rules) -> tuple[str, ...] | None:
    """Mesh axes the flat token dim is sharded over (from the batch rule)."""
    from repro.parallel.sharding import spec_for_axes

    spec = spec_for_axes(("batch",), (t,), mesh, act_rules)
    entry = spec[0] if len(spec) else None
    if entry is None:
        return None
    return entry if isinstance(entry, tuple) else (entry,)


def moe_apply(p, x, cfg, dropless: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-dispatch MoE. x: (B, S, D) -> (out, aux_loss).

    ``dropless=True`` sizes capacity at the worst case (T*k rows per expert)
    so no token is ever dropped — the serving/decode setting, where dropping
    would make cached decoding diverge from the prefill forward pass.

    Distribution strategy (the part XLA cannot infer): the dispatch scatter
    and combine gather are *device-local* (shard_map over the token shards),
    and only the dense (E, C, D) buffers cross devices — resharded from
    capacity-sharded to expert-sharded, which SPMD lowers to the expert-
    parallel all-to-all. A global scatter would instead be lowered by SPMD as
    a replicated (E*C, D) buffer per device (measured: 197 GiB temp for the
    mixtral train cell — see EXPERIMENTS.md §Perf).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    try:
        shard_map = jax.shard_map
    except AttributeError:  # jax < 0.5 ships it under experimental
        from jax.experimental.shard_map import shard_map

    from repro.parallel.sharding import active_act_rules, active_mesh

    b, s, d = x.shape
    e, k_top = cfg.num_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    router_logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k_top)  # (T, k)
    if cfg.renormalize_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    pe = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(fe * pe)

    mesh = active_mesh()
    tok_axes = _token_partition(mesh, t, active_act_rules()) if mesh else None
    shards = cfg.expert_shards or e
    rep = shards // e

    if tok_axes is None:
        # single-device / tiny-batch path: local == global
        if dropless:
            capacity = t * k_top
        else:
            capacity = max(int(t * k_top * cfg.capacity_factor) // e, 1)
        capacity = -(-capacity // rep) * rep
        expert_in, dest = _dispatch_local(xt, expert_idx, e, k_top, capacity, shards)
        expert_out = _expert_ffn(p, expert_in, cfg)
        out = _combine_local(expert_out, dest, gate_vals, k_top)
    else:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nshards = 1
        for a in tok_axes:
            nshards *= sizes[a]
        t_loc = t // nshards
        if dropless:
            cap_loc = t_loc * k_top
        else:
            cap_loc = max(int(t_loc * k_top * cfg.capacity_factor) // e, 1)
        cap_loc = -(-cap_loc // rep) * rep  # physical split must divide
        disp = shard_map(
            lambda xl, il: _dispatch_local(xl, il, e, k_top, cap_loc, shards),
            mesh=mesh,
            in_specs=(P(tok_axes, None), P(tok_axes, None)),
            out_specs=(P(None, tok_axes, None), P(tok_axes)),
        )
        expert_in, dest = disp(xt, expert_idx)

        # EP all-to-all: capacity-sharded -> expert-sharded (+ cap on DP axes)
        expert_in = shard_hint(expert_in, "experts", "expert_cap", "embed")
        expert_out = _expert_ffn(p, expert_in, cfg)
        # reverse all-to-all back to capacity-sharded for the local combine
        expert_out = jax.lax.with_sharding_constraint(
            expert_out, NamedSharding(mesh, P(None, tok_axes, None))
        )
        comb = shard_map(
            lambda eo, de, gv: _combine_local(eo, de, gv, k_top),
            mesh=mesh,
            in_specs=(P(None, tok_axes, None), P(tok_axes), P(tok_axes, None)),
            out_specs=P(tok_axes, None),
        )
        out = comb(expert_out, dest, gate_vals)

    if cfg.num_shared_experts:
        out = out + mlp_apply(p["shared"], xt, cfg)
    return out.reshape(b, s, d), aux


def _expert_ffn(p, expert_in, cfg):
    """Batched SwiGLU over PHYSICAL expert buffers (shards, C_phys, D).

    When cfg.expert_shards > num_experts, each expert's weights are broadcast
    over rep = shards/E physical shards (the dispatch already split its
    capacity rows between them) — EP then fills the whole 'model' axis even
    when E is smaller than it (mixtral: 8 experts on a 16-wide axis).
    Gradients of the broadcast weights sum over replicas (broadcast
    transpose), so training semantics are exactly those of E logical experts.
    """
    act = ACTIVATIONS[cfg.activation]
    dt = expert_in.dtype
    e = cfg.num_experts
    shards = cfg.expert_shards or e
    rep = shards // e

    def phys(w, axes):
        w = w.astype(dt)
        if rep > 1:
            w = jnp.broadcast_to(w[:, None], (e, rep) + w.shape[1:]).reshape(
                (shards,) + w.shape[1:]
            )
        return shard_hint(w, *axes)

    up_axes = ("experts", "expert_embed", "expert_mlp")  # (E, D, F)
    down_axes = ("experts", "expert_mlp", "expert_embed")  # (E, F, D)
    h = act(jnp.einsum("ecd,edf->ecf", expert_in, phys(p["w_gate"], up_axes)))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, phys(p["w_up"], up_axes))
    h = shard_hint(h, "experts", "expert_cap", "mlp")
    out = jnp.einsum("ecf,efd->ecd", h, phys(p["w_down"], down_axes))
    # pin the output layout: without this SPMD may satisfy the (c from h,
    # d from w) sharding conflict by all-gathering h — measured 140 GiB/dev
    return shard_hint(out, "experts", "expert_cap", "embed")
