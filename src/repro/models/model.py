"""Model assembly: config-driven heterogeneous block stacks, scanned.

A model is ``n_stages`` repetitions of ``cfg.stage_pattern`` (a tuple of
(mixer, mlp) block kinds). All stage parameters are stacked along a leading
'layers' axis and the stack is executed with ``jax.lax.scan`` — HLO size is
O(stage pattern), not O(depth), which keeps 1000-node compiles (and this
container's 1-CPU dry-runs) tractable.

Public entry points:
  init_params / init_cache      -> (pytree, logical-axes pytree)
  forward(cfg, params, batch)   -> logits (full seq, or last position)
  loss_fn                       -> (loss, metrics)
  decode_step                   -> (logits, new cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks, ssm, xlstm
from repro.models.layers import dense_param, ones_param, rms_norm
from repro.parallel.sharding import shard_hint

Params = dict
Axes = dict


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": blocks.attn_init,
    "mamba": ssm.mamba_init,
    "mlstm": xlstm.mlstm_init,
    "slstm": xlstm.slstm_init,
}


def init_params(cfg, key: jax.Array) -> tuple[Params, Axes]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_head, k_stages = jax.random.split(key, 3)
    p: Params = {}
    a: Axes = {}

    if cfg.num_codebooks > 1:
        p["embed"], a["embed"] = dense_param(
            k_embed,
            (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            ("codebooks", "vocab", "embed"),
            scale=1.0,
        )
        p["head"], a["head"] = dense_param(
            k_head,
            (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
            ("codebooks", "embed", "vocab"),
        )
    else:
        p["embed"], a["embed"] = dense_param(
            k_embed, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0
        )
        p["head"], a["head"] = dense_param(
            k_head, (cfg.d_model, cfg.vocab_size), ("embed", "vocab")
        )
    p["final_norm"], a["final_norm"] = ones_param((cfg.d_model,), ("embed",))

    stages_p: dict[str, Any] = {}
    stages_a: dict[str, Any] = {}
    keys = jax.random.split(k_stages, len(cfg.stage_pattern))
    for i, (mixer, mlp) in enumerate(cfg.stage_pattern):
        bk = jax.random.split(keys[i], 4)
        bp: dict[str, Any] = {}
        ba: dict[str, Any] = {}
        bp["ln1"], ba["ln1"] = ones_param((cfg.d_model,), ("embed",), stack=cfg.n_stages)
        bp["mixer"], ba["mixer"] = _MIXER_INIT[mixer](bk[0], cfg, cfg.n_stages)
        if mlp == "dense":
            bp["ln2"], ba["ln2"] = ones_param((cfg.d_model,), ("embed",), stack=cfg.n_stages)
            bp["mlp"], ba["mlp"] = blocks.mlp_init(bk[1], cfg, cfg.n_stages)
        elif mlp == "moe":
            bp["ln2"], ba["ln2"] = ones_param((cfg.d_model,), ("embed",), stack=cfg.n_stages)
            bp["mlp"], ba["mlp"] = blocks.moe_init(bk[1], cfg, cfg.n_stages)
        stages_p[f"block{i}"] = bp
        stages_a[f"block{i}"] = ba
    p["stages"] = stages_p
    a["stages"] = stages_a

    if dtype != jnp.float32:
        p = jax.tree.map(
            lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, p
        )
    return p, a


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, dtype):
    if cfg.num_codebooks > 1:
        # tokens: (B, S, K); sum the K codebook embeddings
        parts = [
            jnp.take(params["embed"][k], tokens[..., k], axis=0)
            for k in range(cfg.num_codebooks)
        ]
        x = sum(parts)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(dtype)


def _head(cfg, params, x):
    if cfg.num_codebooks > 1:
        return jnp.einsum("...d,kdv->...kv", x, params["head"].astype(x.dtype))
    return x @ params["head"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _stage_fn(cfg, x, stage_params, positions):
    aux = jnp.zeros((), jnp.float32)
    block_remat = cfg.remat == "block"

    def mixer_block(x, bp, kind):
        # norms run on the seq-sharded residual; the SP->TP layout transition
        # (all-gather) is pinned HERE, on the bf16 post-norm tensor — without
        # this hint XLA gathers the f32 norm upcast (2x collective bytes)
        h = shard_hint(rms_norm(x, bp["ln1"]), "batch", None, "embed")
        if kind == "attn":
            y = blocks.attn_apply(bp["mixer"], h, cfg, positions)
        elif kind == "mamba":
            y = ssm.mamba_apply(bp["mixer"], h, cfg)
        elif kind == "mlstm":
            y = xlstm.mlstm_apply(bp["mixer"], h, cfg)
        else:
            y = xlstm.slstm_apply(bp["mixer"], h, cfg)
        return x + shard_hint(y, "batch", "seq", "embed")

    def mlp_block(x, bp, kind):
        h = shard_hint(rms_norm(x, bp["ln2"]), "batch", None, "embed")
        if kind == "dense":
            y = blocks.mlp_apply(bp["mlp"], h, cfg)
            a = jnp.zeros((), jnp.float32)
        else:
            y, a = blocks.moe_apply(bp["mlp"], h, cfg)
        return x + shard_hint(y, "batch", "seq", "embed"), a

    if block_remat:
        # per-block checkpoints: backward keeps ONE block's activations live
        # instead of a whole stage's (jamba: 8 blocks/stage — 4x temp cut)
        mixer_block = jax.checkpoint(mixer_block, static_argnums=(2,))
        mlp_block = jax.checkpoint(mlp_block, static_argnums=(2,))

    for i, (mixer, mlp) in enumerate(cfg.stage_pattern):
        bp = stage_params[f"block{i}"]
        x = mixer_block(x, bp, mixer)
        if mlp != "none":
            x, a = mlp_block(x, bp, mlp)
            aux = aux + a
        x = shard_hint(x, "batch", "seq", "embed")
    return x, aux


def default_positions(cfg, batch: int, seq: int) -> jnp.ndarray:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos, (3, batch, seq))
    return pos


def hidden_forward(
    cfg,
    params: Params,
    tokens: jnp.ndarray,
    positions: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Embed + stage stack + final norm. Returns (hidden (B, S, D), aux)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape[0], tokens.shape[1]
    if positions is None:
        positions = default_positions(cfg, b, s)
    x = _embed(cfg, params, tokens, dtype)
    x = shard_hint(x, "batch", "seq", "embed")

    def body(carry, stage_params):
        xc, aux = carry
        xn, a = _stage_fn(cfg, xc, stage_params, positions)
        return (xn, aux + a), None

    # 'stage' (alias 'full'): checkpoint whole stages; 'block': per-block
    # checkpoints inside _stage_fn, stage body saved too (outer checkpoint is
    # then redundant recompute — skip it); 'dots': stage checkpoint that SAVES
    # matmul outputs (no FSDP weight re-gathers in backward, more memory);
    # 'none': save everything.
    if cfg.remat in ("full", "stage"):
        body_fn = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    else:
        body_fn = body
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.n_stages <= 2:
        # unrolled (exact cost_analysis for the dry-run's depth extrapolation)
        for i in range(cfg.n_stages):
            sp = jax.tree.map(lambda t: t[i], params["stages"])
            carry, _ = body_fn(carry, sp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body_fn, carry, params["stages"])
    return rms_norm(x, params["final_norm"]), aux


def forward(
    cfg,
    params: Params,
    tokens: jnp.ndarray,
    positions: jnp.ndarray | None = None,
    *,
    last_only: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss).

    ``last_only`` returns next-token logits for the final position only — the
    serving prefill path (full (B, S, V) logits at 32k x 200k vocab would be
    hundreds of GB and serve no purpose).
    """
    x, aux = hidden_forward(cfg, params, tokens, positions)
    if last_only:
        x = x[:, -1]
        x = shard_hint(x, "batch", "embed")
    else:
        x = shard_hint(x, "batch", None, "embed")  # gather seq (bf16) for head
    logits = _logits_hint(cfg, _head(cfg, params, x))
    return logits, aux


def _logits_hint(cfg, logits):
    """Keep the (huge) logits vocab-sharded: downstream reductions run over
    the sharded axis instead of all-gathering (B, S, V) per device. The seq
    axis is deliberately NOT sharded here so 'model' stays free for vocab."""
    ax = (
        ("batch",)
        + (None,) * (logits.ndim - 2 - (cfg.num_codebooks > 1))
        + (("codebooks",) if cfg.num_codebooks > 1 else ())
        + ("vocab",)
    )
    return shard_hint(logits, *ax)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def _ce_terms(cfg, head, x_chunk, labels_chunk) -> jnp.ndarray:
    """Sum over the chunk of (logsumexp - label_logit). x_chunk: (B, c, D)."""
    if cfg.num_codebooks > 1:
        logits = jnp.einsum("...d,kdv->...kv", x_chunk, head.astype(x_chunk.dtype))
    else:
        logits = x_chunk @ head.astype(x_chunk.dtype)
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - label_logit)


def loss_fn(cfg, params: Params, batch: dict) -> tuple[jnp.ndarray, dict]:
    labels = batch["labels"]
    chunk = cfg.loss_chunk
    seq = labels.shape[1]
    if chunk and seq % chunk == 0 and seq // chunk > 1:
        # chunked CE: the LM head runs per seq-chunk under remat, so the
        # (B, S, V) logits tensor never exists — per-device peak is one
        # (B, c, V) slab (recomputed in backward). Bitwise-same math.
        x, aux = hidden_forward(cfg, params, batch["tokens"], batch.get("positions"))
        nc = seq // chunk
        # hoist ONE replicated copy of the (vocab-sharded) head out of the
        # chunk scan — inside the scan body SPMD would all-gather it per
        # chunk (measured: +25% collective bytes on llama4, §Perf)
        head = shard_hint(params["head"], *(None,) * params["head"].ndim)

        def step(carry, i):
            xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
            return carry + _ce_terms(cfg, head, xc, lc), None

        total_nll, _ = jax.lax.scan(
            jax.checkpoint(step), jnp.zeros((), jnp.float32), jnp.arange(nc)
        )
        ce = total_nll / labels.size
    else:
        logits, aux = forward(cfg, params, batch["tokens"], batch.get("positions"))
        logits = logits.astype(jnp.float32)
        # logsumexp + gather reduce over the (possibly sharded) vocab axis
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - label_logit)
    total = ce + cfg.aux_loss_coef * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches + decode
# ---------------------------------------------------------------------------


def cache_len_for(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window else seq_len


def init_cache(cfg, batch: int, seq_len: int, dtype=None) -> tuple[Params, Axes]:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    clen = cache_len_for(cfg, seq_len)
    cache: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    for i, (mixer, _) in enumerate(cfg.stage_pattern):
        if mixer == "attn":
            c, ax = blocks.attn_cache_init(cfg, batch, clen, cfg.n_stages, dtype)
        elif mixer == "mamba":
            c, ax = ssm.mamba_cache_init(cfg, batch, cfg.n_stages, dtype)
        elif mixer == "mlstm":
            c, ax = xlstm.mlstm_cache_init(cfg, batch, cfg.n_stages, dtype)
        else:
            c, ax = xlstm.slstm_cache_init(cfg, batch, cfg.n_stages, dtype)
        cache[f"block{i}"] = c
        axes[f"block{i}"] = ax
    return cache, axes


def decode_step(
    cfg,
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,  # (B, 1) or (B, 1, K)
    pos: jnp.ndarray,  # scalar int32: position index of this token
) -> tuple[jnp.ndarray, Params]:
    """One decoding step for the whole stack. Returns (logits (B, V[, K]), cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed(cfg, params, tokens, dtype)
    x = shard_hint(x, "batch", "seq", "embed")

    def body(xc, inputs):
        stage_params, stage_cache = inputs
        new_cache = {}
        for i, (mixer, mlp) in enumerate(cfg.stage_pattern):
            bp = stage_params[f"block{i}"]
            c = stage_cache[f"block{i}"]
            h = rms_norm(xc, bp["ln1"])
            if mixer == "attn":
                y, nc = blocks.attn_decode(bp["mixer"], h, c, pos, cfg)
            elif mixer == "mamba":
                y, nc = ssm.mamba_decode(bp["mixer"], h, c, cfg)
            elif mixer == "mlstm":
                y, nc = xlstm.mlstm_decode(bp["mixer"], h, c, cfg)
            else:
                y, nc = xlstm.slstm_decode(bp["mixer"], h, c, cfg)
            new_cache[f"block{i}"] = nc
            xc = xc + y
            if mlp != "none":
                h = rms_norm(xc, bp["ln2"])
                if mlp == "dense":
                    y = blocks.mlp_apply(bp["mlp"], h, cfg)
                else:
                    # dropless at decode: a dropped token would diverge from
                    # the prefill forward pass (and T is tiny here anyway)
                    y, _ = blocks.moe_apply(bp["mlp"], h, cfg, dropless=True)
                xc = xc + y
        return xc, new_cache

    if cfg.n_stages <= 2:
        ncs = []
        for i in range(cfg.n_stages):
            sp = jax.tree.map(lambda t: t[i], params["stages"])
            sc = jax.tree.map(lambda t: t[i], cache)
            x, nc = body(x, (sp, sc))
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["stages"], cache))
    x = rms_norm(x, params["final_norm"])
    logits = _logits_hint(cfg, _head(cfg, params, x[:, 0]))
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill that also fills an attention KV cache (serving path)
# ---------------------------------------------------------------------------


def prefill_with_cache(cfg, params, tokens, cache_seq_len: int | None = None):
    """Run the full forward AND produce a filled decode cache.

    Simple two-pass strategy (forward for logits; per-position decode for the
    cache would be O(S) scans) is wasteful; instead we re-run the mixers'
    cache-filling math directly where cheap. For the framework's serving
    example sizes this uses the straightforward approach: sequential decode
    over positions via lax.scan of decode_step's body on each token, carrying
    the cache. Exact but sequential — fine for example/tests; production
    prefill lowers ``forward(last_only=True)`` + kernelized cache writes.
    """
    b, s = tokens.shape[0], tokens.shape[1]
    clen = cache_len_for(cfg, cache_seq_len or s)
    cache, _ = init_cache(cfg, b, clen)

    def step(carry, t):
        cache = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        logits, cache = decode_step(cfg, params, cache, tok, t)
        return cache, logits

    cache, logits_seq = jax.lax.scan(step, cache, jnp.arange(s))
    logits_last = logits_seq[-1]
    return logits_last, cache


# ---------------------------------------------------------------------------
# Parameter accounting (for MODEL_FLOPS in the roofline)
# ---------------------------------------------------------------------------


def shapes_and_axes(cfg) -> tuple[Any, Any]:
    """(ShapeDtypeStruct pytree, logical-axes pytree) with zero allocation.

    The axes tree contains string tuples which eval_shape cannot return, so
    it is captured through a side channel during the abstract trace.
    """
    captured = {}

    def only_params(key):
        p, a = init_params(cfg, key)
        captured["axes"] = a
        return p

    p_shapes = jax.eval_shape(only_params, jax.random.PRNGKey(0))
    return p_shapes, captured["axes"]


def count_params_analytic(
    cfg, active_only: bool = False, exclude_embed: bool = False
) -> int:
    """Exact param count via eval_shape. ``active_only`` scales expert tables
    by top_k/E (MoE active params); ``exclude_embed`` drops the input
    embedding table (gather, not matmul) for 6ND MODEL_FLOPS accounting —
    the LM head IS counted."""
    p_shapes, axes = shapes_and_axes(cfg)
    total = 0
    for leaf, ax in zip(
        jax.tree.leaves(p_shapes),
        jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )),
    ):
        n = 1
        for d in leaf.shape:
            n *= d
        if exclude_embed and "vocab" in ax and "embed" in ax:
            if ax.index("vocab") < ax.index("embed"):
                continue  # input embedding table
        if active_only and "experts" in ax:
            n = n * cfg.top_k // cfg.num_experts
        total += n
    return total
