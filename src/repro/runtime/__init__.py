"""Fault-tolerance runtime: health, elastic re-mesh, coordinator."""
