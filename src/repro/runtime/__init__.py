"""Fault-tolerance runtime: failure taxonomy + retry/degradation ladder
(``resilience``), deterministic fault injection (``faults``), cluster
health/straggler policies (``health``), elastic re-mesh, coordinator."""
