"""Elastic re-meshing: rebuild the run plan when hosts join/leave.

On failure the coordinator (a) evicts dead hosts, (b) computes the largest
usable host count that keeps the mesh factorizable and the global batch
divisible, (c) restarts every survivor from the last checkpoint with a new
DataConfig — the data pipeline is a pure function of (seed, step, host_id),
so re-sharding data across a different host count is just handing out new
host ids. No training state beyond (checkpoint, step) needs migrating.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RunPlan:
    hosts: tuple[int, ...]  # physical host ids, rank order
    num_hosts: int  # logical hosts in use (<= len(hosts))
    global_batch: int
    mesh_data: int  # data-axis size of the per-run mesh
    mesh_model: int


def largest_usable(n_alive: int, global_batch: int, model_axis: int) -> int:
    """Largest host count <= n_alive such that the batch still divides and
    the data axis stays a positive integer. Prefers powers of two (ICI-ring
    friendly), falls back to the largest divisor of global_batch."""
    best = 0
    n = 1
    while n <= n_alive:
        if global_batch % n == 0:
            best = n
        n *= 2
    if best:
        return best
    for n in range(n_alive, 0, -1):
        if global_batch % n == 0:
            return n
    return 1


def plan_remesh(
    alive_hosts: list[int],
    global_batch: int,
    model_axis: int = 1,
) -> RunPlan:
    """New run plan over the surviving hosts (deterministic: sorted ids)."""
    if not alive_hosts:
        raise RuntimeError("no hosts survive; cannot re-mesh")
    hosts = tuple(sorted(alive_hosts))
    n = largest_usable(len(hosts), global_batch, model_axis)
    return RunPlan(
        hosts=hosts[:n],
        num_hosts=n,
        global_batch=global_batch,
        mesh_data=n,
        mesh_model=model_axis,
    )
