"""Cluster health: heartbeats + straggler detection.

Transport-agnostic (the coordinator feeds observations in; tests drive it
with simulated hosts). Policies:

  * a host is DEAD when its last heartbeat is older than ``timeout_s``;
  * a host is a STRAGGLER when the EMA of its per-step time exceeds the
    cluster median by ``straggler_factor`` for ``patience`` consecutive
    steps — the standard mitigation trigger (re-shard its data, or evict).
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Iterable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float = 0.0
    step_time_ema: float | None = None
    slow_streak: int = 0
    alive: bool = True


class HealthMonitor:
    def __init__(
        self,
        host_ids: Iterable[int],
        timeout_s: float = 60.0,
        straggler_factor: float = 1.5,
        patience: int = 3,
        ema_alpha: float = 0.3,
    ):
        self.hosts = {h: HostState(h) for h in host_ids}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.ema_alpha = ema_alpha

    # -- observations ---------------------------------------------------------

    def heartbeat(self, host_id: int, now: float) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = now
        h.alive = True

    def report_step_time(self, host_id: int, seconds: float) -> None:
        h = self.hosts[host_id]
        if h.step_time_ema is None:
            h.step_time_ema = seconds
        else:
            a = self.ema_alpha
            h.step_time_ema = a * seconds + (1 - a) * h.step_time_ema

    # -- policies ---------------------------------------------------------------

    def dead_hosts(self, now: float) -> list[int]:
        out = []
        for h in self.hosts.values():
            if h.alive and now - h.last_heartbeat > self.timeout_s:
                h.alive = False
            if not h.alive:
                out.append(h.host_id)
        return sorted(out)

    def stragglers(self) -> list[int]:
        emas = [
            h.step_time_ema
            for h in self.hosts.values()
            if h.alive and h.step_time_ema is not None
        ]
        if len(emas) < 2:
            return []
        med = statistics.median(emas)
        out = []
        for h in self.hosts.values():
            if not h.alive or h.step_time_ema is None:
                continue
            if h.step_time_ema > self.straggler_factor * med:
                h.slow_streak += 1
            else:
                h.slow_streak = 0
            if h.slow_streak >= self.patience:
                out.append(h.host_id)
        return sorted(out)

    def alive_hosts(self) -> list[int]:
        return sorted(h.host_id for h in self.hosts.values() if h.alive)

    def evict(self, host_id: int) -> None:
        self.hosts[host_id].alive = False
