"""Failure taxonomy + recovery primitives for the profiling/evaluation stack.

A single Pallas miscompile, device loss, or corrupted cache entry used to
surface as a bare ``Exception`` (or an ad-hoc ``RuntimeWarning``) somewhere
inside ``run_profile_batch`` — aborting, or worse silently poisoning, a
whole workload.  This module gives every failure mode a TYPE, and gives the
pipeline the three recovery primitives it composes them with:

  * the **taxonomy** — ``ProfileError`` subclasses, one per failure class
    (backend-compile, device-dispatch, device-loss, timeout,
    contract-violation, cache-corruption) and the evaluation-layer classes
    (guard-violation, cross-engine-mismatch — see ``core.sweep``), plus
    ``classify_exception`` to
    lift foreign exceptions (jax/XLA errors, ``TimeoutError``, bare
    ``ValueError``) into it;
  * the **retry policy** — exponential backoff with DETERMINISTIC jitter
    (seeded per (site, attempt): reproducible schedules, no thundering
    herd) via ``RetryPolicy`` / ``call_with_retry``;
  * the **degradation ladder** — ``degradation_ladder()`` enumerates the
    per-job backend rungs (pallas kernel -> XLA rendering -> numpy oracle);
    every rung computes identical integer toggle counts (regression-tested
    across the stack), so degrading is bit-exact, never approximate;
  * the **failure report** — ``FailureRecord``/``FailureReport``: a
    machine-readable account of what failed, why (typed), and what recovery
    action was taken, returned in ``BatchStats.failure_report`` instead of
    being lost in a log line.

Nothing here imports jax: the taxonomy must be importable on hosts where
the backend itself is what's broken.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import time
from typing import Callable

__all__ = [
    "ProfileError",
    "BackendCompileError",
    "DeviceDispatchError",
    "DeviceLossError",
    "ProfileTimeoutError",
    "ContractViolationError",
    "CacheCorruptionError",
    "EvaluationError",
    "GuardViolationError",
    "CrossEngineMismatchError",
    "ProfileDegradationWarning",
    "CacheThrashWarning",
    "classify_exception",
    "RetryPolicy",
    "call_with_retry",
    "LADDER_RUNGS",
    "degradation_ladder",
    "EVAL_LADDER_RUNGS",
    "evaluation_ladder",
    "FailureRecord",
    "FailureReport",
]


# --- taxonomy ---------------------------------------------------------------


class ProfileError(RuntimeError):
    """Base of the profiling failure taxonomy.

    ``kind`` is the stable machine-readable class name (what failure
    reports and tests key on); ``job`` names the profiling job (when known)
    and ``stage`` the pipeline stage that observed the failure.
    """

    kind = "profile-error"

    def __init__(self, message: str, *, job: str = "", stage: str = ""):
        super().__init__(message)
        self.job = job
        self.stage = stage

    def describe(self) -> str:
        where = f" [job={self.job}]" if self.job else ""
        return f"{self.kind}{where}: {self}"


class BackendCompileError(ProfileError):
    """The fused engine failed to lower/compile (Pallas miscompile, jax API
    drift, XLA lowering bug) — before any device work ran."""

    kind = "backend-compile"


class DeviceDispatchError(ProfileError):
    """Device execution failed after a successful compile (runtime fault,
    OOM, transfer error)."""

    kind = "device-dispatch"


class DeviceLossError(DeviceDispatchError):
    """A device disappeared mid-workload (preemption, fleet scale-in,
    hardware fault).  Recoverable by eviction + resubmission."""

    kind = "device-loss"


class ProfileTimeoutError(DeviceDispatchError):
    """A dispatched program exceeded its wall-clock budget (hang, runaway
    autotuner, dead interconnect).  Treated like device loss: evict, then
    resubmit the slice elsewhere."""

    kind = "timeout"


class ContractViolationError(ProfileError, ValueError):
    """The request itself is invalid (bad GEMM shapes, unknown engine or
    dataflow, operands beyond an engine contract).  NOT retryable — the
    same request fails on every rung, so the only actions are "raise" or
    "skip and report".  Subclasses ``ValueError`` so pre-taxonomy callers
    (and tests) catching ``ValueError`` keep working."""

    kind = "contract-violation"


class CacheCorruptionError(ProfileError):
    """A cache/store entry failed integrity verification (bit rot, torn
    write from a crashed process, tampering).  The store quarantines the
    entry and the pipeline recomputes — this error is raised only if a
    caller explicitly asks the store to be strict."""

    kind = "cache-corruption"


class EvaluationError(ProfileError):
    """Base of the EVALUATION-layer failure classes (design-space/layout
    sweep chunks), distinct from the profiling classes above: an evaluation
    failure concerns derived physics (powers, optima, savings), not toggle
    measurement.  ``job`` names the chunk, ``stage`` the rung/site."""

    kind = "evaluation-error"


class GuardViolationError(EvaluationError):
    """A chunk's outputs violated a physical-contract guard (non-finite
    value, non-positive power, coded activity above raw, saving above 1,
    argmin outside the aspect envelope...).  ``violations`` lists every
    failed guard.  Recoverable by re-evaluating the chunk down the
    jit -> eager -> scalar ladder; raised only when the last rung still
    violates (a silently wrong cell must never reach the Pareto front)."""

    kind = "guard-violation"

    def __init__(
        self,
        message: str,
        *,
        violations: tuple[str, ...] | list[str] = (),
        job: str = "",
        stage: str = "",
    ):
        super().__init__(message, job=job, stage=stage)
        self.violations = tuple(violations)


class CrossEngineMismatchError(GuardViolationError):
    """A sampled cross-engine agreement check failed: the chunk's batched
    results diverged from an independent reference evaluation (scalar
    closed forms for the design engine, explicit segment enumeration for
    the layout engine) beyond the rung's tolerance."""

    kind = "cross-engine-mismatch"


class ProfileDegradationWarning(RuntimeWarning):
    """A profiling request silently degraded to a slower-but-exact backend
    (the old ad-hoc ``RuntimeWarning``s, now typed so callers can filter)."""


class CacheThrashWarning(RuntimeWarning):
    """A single batch stored more profiles than the in-memory cache can
    hold — later jobs evict entries earlier jobs of the SAME workload still
    need.  Raise ``REPRO_PROFILE_CACHE_CAPACITY`` (or call
    ``set_profile_cache_capacity``) to fit the working set."""


_COMPILE_MARKERS = (
    "compil",  # "compilation", "compile failed"
    "lower",
    "mosaic",
    "unsupported",
    "tracer",
    "pallas",
    "mlir",
)


def classify_exception(
    exc: BaseException, *, job: str = "", stage: str = ""
) -> ProfileError:
    """Lift an arbitrary exception into the taxonomy (idempotent).

    Already-typed errors pass through (annotating job/stage if unset).
    ``TimeoutError`` (incl. ``concurrent.futures.TimeoutError``) maps to
    ``ProfileTimeoutError``; ``ValueError``/``TypeError`` are contract
    violations; jax/XLA errors split on compile-ish message markers; the
    rest default to device-dispatch (the retryable class: misclassifying an
    exotic error as retryable costs a few retries, misclassifying it as
    fatal would abort a recoverable workload).
    """
    if isinstance(exc, ProfileError):
        exc.job = exc.job or job
        exc.stage = exc.stage or stage
        return exc
    msg = f"{type(exc).__name__}: {exc}"
    # concurrent.futures.TimeoutError is a distinct class before py3.11
    if isinstance(exc, (TimeoutError, concurrent.futures.TimeoutError)):
        return ProfileTimeoutError(msg, job=job, stage=stage)
    if isinstance(exc, (ValueError, TypeError, ZeroDivisionError)):
        return ContractViolationError(msg, job=job, stage=stage)
    if isinstance(exc, (ImportError, NotImplementedError)):
        return BackendCompileError(msg, job=job, stage=stage)
    low = msg.lower()
    if any(m in low for m in _COMPILE_MARKERS):
        return BackendCompileError(msg, job=job, stage=stage)
    return DeviceDispatchError(msg, job=job, stage=stage)


# --- retry policy -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic, seeded jitter.

    ``delay(attempt, key)`` for attempt 0, 1, ... is
    ``min(max_delay_s, base_delay_s * multiplier**attempt)`` scaled by a
    jitter factor in ``[1, 1 + jitter]`` drawn from sha256(seed, key,
    attempt) — the schedule is a pure function of its inputs, so tests and
    chaos CI runs reproduce byte-identical behavior, while distinct jobs
    (distinct keys) still decorrelate.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.5
    max_delay_s: float = 2.0
    seed: int = 0

    def delay(self, attempt: int, key: str = "") -> float:
        raw = min(self.max_delay_s, self.base_delay_s * self.multiplier**attempt)
        h = hashlib.sha256(f"{self.seed}|{key}|{attempt}".encode()).digest()
        u = int.from_bytes(h[:8], "big") / float(1 << 64)
        return raw * (1.0 + self.jitter * u)


def call_with_retry(
    fn: Callable[[], object],
    *,
    policy: RetryPolicy,
    key: str = "",
    retry_on: tuple = (BackendCompileError, DeviceDispatchError),
    sleep: Callable[[float], None] = time.sleep,
) -> tuple[object, int, ProfileError | None]:
    """Run ``fn`` under ``policy``; returns ``(result, attempts, last_error)``.

    Exceptions are classified first; only taxonomy classes in ``retry_on``
    are retried (contract violations never are — the same request fails
    identically forever).  On success ``last_error`` is the error of the
    last FAILED attempt (None if the first attempt succeeded); on
    exhaustion the classified error is raised with ``attempts`` recorded on
    it as ``error.attempts``.
    """
    last: ProfileError | None = None
    for attempt in range(max(1, policy.max_attempts)):
        try:
            return fn(), attempt + 1, last
        except BaseException as exc:  # noqa: BLE001 - classified right below
            err = classify_exception(exc, stage="retry")
            last = err
            if not isinstance(err, retry_on) or attempt + 1 >= policy.max_attempts:
                err.attempts = attempt + 1
                raise err from exc
            sleep(policy.delay(attempt, key))
    raise AssertionError("unreachable")  # pragma: no cover


# --- degradation ladder -----------------------------------------------------

# Per-JOB backend rungs, most- to least-accelerated.  Every rung computes
# the same integer toggle counts (bit-exactness across backends is the
# stack's standing regression contract), so stepping down trades speed for
# nothing else.
LADDER_RUNGS: tuple[str, ...] = ("pallas", "xla", "numpy")


def degradation_ladder(engine: str = "auto") -> tuple[str, ...]:
    """The rung sequence for a job that requested device rendering ``engine``.

    ``engine="xla"`` starts below the Pallas rung (there is nothing above
    to degrade from); ``"pallas"``/``"auto"`` walk the full ladder.  The
    numpy oracle is always last — it has no device, no compiler, and no
    contract narrower than "ints fit in 64 bits", so it is the rung that
    cannot fail the way the others do.
    """
    if engine == "xla":
        return ("xla", "numpy")
    return LADDER_RUNGS


# Per-CHUNK evaluation rungs for the design-space/layout sweep runner,
# most- to least-accelerated.  "jit" is the float32 XLA program, "eager"
# the identical code in float64 numpy, "scalar" a per-point float64
# evaluation (the oracle rung: no batching, no fusion, nothing shared
# across points that could smear one bad cell into its neighbors).  Unlike
# the profiling ladder the rungs are NOT bit-identical (float32 vs float64
# rounding) — they agree to the engines' cross-checked tolerances, and a
# chunk recomputed on a lower rung is recorded in the sweep report.
EVAL_LADDER_RUNGS: tuple[str, ...] = ("jit", "eager", "scalar")


def evaluation_ladder(start: str = "jit") -> tuple[str, ...]:
    """The rung sequence for a sweep chunk starting at ``start``.

    ``start="eager"`` (no jax, or ``use_jit=False``) begins below the jit
    rung.  The scalar rung is always last — it exercises none of the
    machinery (batching, jit, broadcasting) that the guards exist to
    distrust, so it is the rung of last resort."""
    if start not in EVAL_LADDER_RUNGS:
        raise ContractViolationError(
            f"unknown evaluation rung {start!r}; know {EVAL_LADDER_RUNGS}"
        )
    return EVAL_LADDER_RUNGS[EVAL_LADDER_RUNGS.index(start):]


# --- failure report ---------------------------------------------------------


@dataclasses.dataclass
class FailureRecord:
    """One observed failure and what was done about it.

    ``error`` is the taxonomy kind; ``action`` the recovery outcome, drawn
    from a small stable vocabulary: ``"retried"``, ``"degraded:<rung>"``,
    ``"device-evicted:resubmitted"``, ``"quarantined:recomputed"``,
    ``"skipped"``, ``"raised"``.
    """

    job: str
    stage: str
    error: str
    message: str
    action: str
    attempts: int = 1

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FailureReport:
    """Machine-readable account of every failure a batch observed."""

    records: list[FailureRecord] = dataclasses.field(default_factory=list)

    def add(
        self,
        error: ProfileError,
        *,
        action: str,
        job: str = "",
        stage: str = "",
        attempts: int = 1,
    ) -> FailureRecord:
        rec = FailureRecord(
            job=job or error.job,
            stage=stage or error.stage,
            error=error.kind,
            message=str(error),
            action=action,
            attempts=attempts,
        )
        self.records.append(rec)
        return rec

    def __bool__(self) -> bool:
        return bool(self.records)

    def __len__(self) -> int:
        return len(self.records)

    def counts(self) -> dict[str, int]:
        """Record count per taxonomy kind."""
        out: dict[str, int] = {}
        for r in self.records:
            out[r.error] = out.get(r.error, 0) + 1
        return out

    def actions(self) -> dict[str, int]:
        """Record count per recovery action."""
        out: dict[str, int] = {}
        for r in self.records:
            out[r.action] = out.get(r.action, 0) + 1
        return out

    def for_job(self, job: str) -> list[FailureRecord]:
        return [r for r in self.records if r.job == job]

    def summary(self) -> str:
        if not self.records:
            return "no failures"
        kinds = ", ".join(f"{k}x{n}" for k, n in sorted(self.counts().items()))
        acts = ", ".join(f"{a}x{n}" for a, n in sorted(self.actions().items()))
        return f"{len(self.records)} failures ({kinds}) -> ({acts})"

    def as_dict(self) -> dict:
        return {
            "records": [r.as_dict() for r in self.records],
            "counts": self.counts(),
            "actions": self.actions(),
        }
