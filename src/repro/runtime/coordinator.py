"""Fault-tolerant training coordinator.

Drives the (jitted) train step with:
  * periodic atomic checkpoints (params, optimizer, step, data-iterator),
  * preemption hook (SIGTERM -> checkpoint -> clean exit),
  * failure injection + restart-from-latest (tested for bit-identical resume),
  * health monitoring + elastic re-mesh planning on host loss.

The coordinator is deliberately synchronous and single-process here (the
container has one CPU); on a real cluster each host runs one coordinator and
the HealthMonitor observations arrive over the cluster transport. All
decision logic (what to save, when to evict, how to re-plan) is host-count
agnostic and unit-tested with simulated hosts.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataIterator, batch_at_step
from repro.runtime.elastic import plan_remesh
from repro.runtime.health import HealthMonitor


@dataclasses.dataclass
class CoordinatorConfig:
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    max_steps: int = 1000
    heartbeat_timeout_s: float = 60.0


class TrainingCoordinator:
    def __init__(
        self,
        train_step: Callable[[dict, dict], tuple[dict, dict]],
        init_state: Callable[[], dict],
        data_cfg: DataConfig,
        ckpt: CheckpointManager,
        cfg: CoordinatorConfig = CoordinatorConfig(),
        host_ids: tuple[int, ...] = (0,),
    ):
        self.train_step = train_step
        self.init_state_fn = init_state
        self.data_cfg = data_cfg
        self.ckpt = ckpt
        self.cfg = cfg
        self.health = HealthMonitor(host_ids, timeout_s=cfg.heartbeat_timeout_s)
        self._preempted = False
        self.metrics_log: list[dict] = []

    # -- lifecycle -------------------------------------------------------------

    def install_preemption_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def _restore_or_init(self) -> tuple[int, dict]:
        latest = self.ckpt.restore_latest(like=jax.eval_shape(self.init_state_fn))
        if latest is None:
            return 0, self.init_state_fn()
        step, state_np, extra = latest
        state = jax.tree.map(lambda x: jax.numpy.asarray(x), state_np)
        data_step = int(extra.get("data_step", step))
        return data_step, state

    # -- main loop ---------------------------------------------------------------

    def run(
        self,
        steps: int | None = None,
        fail_at_step: int | None = None,
    ) -> tuple[int, dict]:
        """Run until ``steps``; optionally inject a crash (for tests).

        Returns (last_step, final_state). Re-entrant: calling run() again
        resumes from the latest checkpoint, replaying nothing (data is a pure
        function of step) and duplicating nothing (checkpoints are atomic).
        """
        total = steps if steps is not None else self.cfg.max_steps
        start_step, state = self._restore_or_init()
        it = DataIterator(self.data_cfg, start_step=start_step)

        step = start_step
        while step < total:
            if self._preempted:
                self._save(step, state)
                raise SystemExit(143)
            t0 = time.time()
            step, batch = next(it)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = self.train_step(state, batch)
            dt = time.time() - t0
            self.health.heartbeat(self.data_cfg.host_id, time.time())
            self.health.report_step_time(self.data_cfg.host_id, dt)
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]), "sec": dt}
            )
            step += 1
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            if step % self.cfg.checkpoint_every == 0 or step == total:
                self._save(step, state)
        return step, state

    def _save(self, step: int, state: dict) -> None:
        host_state = jax.tree.map(np.asarray, state)
        self.ckpt.save(step, host_state, extra={"data_step": step})

    # -- failure handling ---------------------------------------------------------

    def handle_host_failure(self, now: float, global_batch: int, model_axis: int):
        """Evict dead hosts and produce the new run plan (elastic restart)."""
        dead = self.health.dead_hosts(now)
        for h in dead:
            self.health.evict(h)
        return plan_remesh(self.health.alive_hosts(), global_batch, model_axis)
