"""Deterministic fault injection for the profiling pipeline.

The resilience layer (retry ladder, device eviction, store quarantine) is
only trustworthy if every recovery path actually RUNS — so this module
plants seeded, reproducible faults at the pipeline's real failure sites:

  * ``backend``     — raise ``BackendCompileError`` where a fused program
                      would compile/dispatch (bucket, stream bucket, ladder
                      rungs);
  * ``hang``        — sleep at a dispatch site long enough to trip the
                      pipeline's dispatch timeout (drives eviction);
  * ``device_loss`` — raise ``DeviceLossError`` from a device shard
                      (drives eviction + resubmission);
  * ``bitflip``     — flip one bit of an on-disk store entry's payload as
                      it is read (drives integrity quarantine + recompute);
                      fires at every ``ContentStore`` read site, so it
                      covers the profile store AND the sweep chunk store;
  * ``nan``         — overwrite one element of an evaluator result array
                      with NaN/Inf (drives the sweep guard rails + the
                      jit -> eager -> scalar evaluation ladder);
  * ``abort``       — raise ``InjectedAbortError`` (a ``BaseException``, so
                      recovery machinery cannot swallow it) at a sweep
                      commit boundary — models ``kill -9`` mid-sweep for
                      the resume path.

Determinism: each injection site draws from
``sha256(seed | kind | site | key | seq)`` where ``seq`` counts calls to
that exact (kind, site, key) — the Nth retry of the same job redraws, so
``rate < 1`` models transient faults, ``rate = 1`` permanent ones, and the
whole schedule is a pure function of the seed and the call sequence (no
wall clock, no global RNG).  ``FaultSpec.match`` pins a fault to sites/keys
containing a substring — tests aim a fault at one bucket or one device.

Activation: explicitly via ``install``/``injected(...)``, or from the
environment (``REPRO_FAULTS="backend=0.1,hang=0.05,bitflip=1,seed=7"``) so
a chaos CI job can run the whole tier-1 suite under injection with zero
code changes.  ``active()`` is the single lookup the pipeline uses; when
nothing is installed and the env var is unset it costs a None check.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import threading
import time

import numpy as np

from repro.runtime.resilience import (
    BackendCompileError,
    DeviceLossError,
)

__all__ = [
    "FaultSpec",
    "FaultInjector",
    "FireRecord",
    "InjectedAbortError",
    "install",
    "clear",
    "active",
    "injected",
    "from_env",
    "KINDS",
]

KINDS = ("backend", "hang", "bitflip", "device_loss", "nan", "abort")


class InjectedAbortError(BaseException):
    """An injected hard process death (``kill -9`` stand-in).

    Deliberately a ``BaseException``: the sweep runner's recovery paths
    catch ``Exception`` subclasses, so an injected abort tears through them
    exactly like a real SIGKILL would — only the crash-safe store commits
    made BEFORE the abort survive, which is precisely what the resume tests
    need to prove."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injector class: fire with probability ``rate`` per opportunity.

    ``match`` (optional) restricts firing to sites where
    ``match in f"{site}|{key}"``; ``max_fires`` caps total fires (None =
    unlimited).
    """

    kind: str
    rate: float = 1.0
    match: str | None = None
    max_fires: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; know {KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class FireRecord:
    """One fault that actually fired (the failure-report cross-check)."""

    kind: str
    site: str
    key: str
    seq: int


class FaultInjector:
    """Seeded injector evaluated at the pipeline's hook points.

    Thread-safe: dispatch workers draw concurrently.  ``fired`` is the
    append-only log of every fault that fired — benchmarks assert that each
    fired fault is accounted for in ``BatchStats.failure_report``.
    """

    def __init__(
        self,
        specs: list[FaultSpec] | tuple[FaultSpec, ...],
        *,
        seed: int = 0,
        hang_s: float = 0.25,
    ):
        self.specs = tuple(specs)
        self.seed = seed
        self.hang_s = hang_s
        self.fired: list[FireRecord] = []
        self._seq: dict[tuple, int] = {}
        self._fires_per_spec: dict[int, int] = {}
        self._lock = threading.Lock()

    def _draw(self, kind: str, site: str, key: str) -> bool:
        with self._lock:
            hit = False
            for i, spec in enumerate(self.specs):
                if spec.kind != kind:
                    continue
                if spec.match is not None and spec.match not in f"{site}|{key}":
                    continue
                if (
                    spec.max_fires is not None
                    and self._fires_per_spec.get(i, 0) >= spec.max_fires
                ):
                    continue
                sk = (kind, site, key)
                seq = self._seq.get(sk, 0)
                self._seq[sk] = seq + 1
                h = hashlib.sha256(
                    f"{self.seed}|{kind}|{site}|{key}|{seq}".encode()
                ).digest()
                u = int.from_bytes(h[:8], "big") / float(1 << 64)
                if u < spec.rate:
                    self._fires_per_spec[i] = self._fires_per_spec.get(i, 0) + 1
                    self.fired.append(FireRecord(kind, site, key, seq))
                    hit = True
                break  # first matching spec owns this (kind, site, key)
            return hit

    # -- hook points (no-ops unless a matching spec fires) -------------------

    def maybe_fail_backend(self, site: str, key: str = "") -> None:
        """Raise an injected compile/dispatch failure at ``site``."""
        if self._draw("backend", site, key):
            raise BackendCompileError(
                f"injected backend fault at {site} ({key})", stage=site
            )

    def maybe_hang(self, site: str, key: str = "") -> None:
        """Stall ``hang_s`` seconds at ``site`` (models a wedged dispatch)."""
        if self._draw("hang", site, key):
            time.sleep(self.hang_s)

    def maybe_lose_device(self, site: str, key: str = "") -> None:
        """Raise an injected device loss at ``site``."""
        if self._draw("device_loss", site, key):
            raise DeviceLossError(
                f"injected device loss at {site} ({key})", stage=site
            )

    def maybe_corrupt(self, payload: bytes, site: str, key: str = "") -> bytes:
        """Return ``payload`` with one deterministically-chosen bit flipped
        (when the fault fires), else unchanged."""
        if not payload or not self._draw("bitflip", site, key):
            return payload
        h = hashlib.sha256(f"{self.seed}|bit|{site}|{key}".encode()).digest()
        pos = int.from_bytes(h[:8], "big") % len(payload)
        bit = h[8] % 8
        out = bytearray(payload)
        out[pos] ^= 1 << bit
        return bytes(out)

    def maybe_poison(self, value, site: str, key: str = ""):
        """Return ``value`` (a float array) with one deterministically-chosen
        element overwritten by NaN or +Inf when the fault fires, else
        ``value`` unchanged.  The poisoned copy keeps dtype and shape — the
        corruption is indistinguishable from a real silent miscompute, which
        is the point: only a guard can catch it."""
        arr = np.asarray(value)
        if arr.size == 0 or not np.issubdtype(arr.dtype, np.floating):
            return value
        if not self._draw("nan", site, key):
            return value
        h = hashlib.sha256(f"{self.seed}|nan|{site}|{key}".encode()).digest()
        pos = int.from_bytes(h[:8], "big") % arr.size
        out = np.array(arr, copy=True)
        out.flat[pos] = np.nan if h[8] % 2 == 0 else np.inf
        return out

    def maybe_abort(self, site: str, key: str = "") -> None:
        """Raise an injected process abort at ``site`` (kill -9 stand-in)."""
        if self._draw("abort", site, key):
            raise InjectedAbortError(f"injected abort at {site} ({key})")

    def fired_kinds(self) -> set[str]:
        return {f.kind for f in self.fired}


# --- activation -------------------------------------------------------------

_ACTIVE: FaultInjector | None = None
_ENV_CHECKED = False


def install(injector: FaultInjector | None) -> None:
    """Make ``injector`` the process-wide active injector (None disables)."""
    global _ACTIVE, _ENV_CHECKED
    _ACTIVE = injector
    _ENV_CHECKED = True  # explicit install wins over the environment


def clear() -> None:
    """Disable injection (and re-arm env discovery for the next ``active``)."""
    global _ACTIVE, _ENV_CHECKED
    _ACTIVE = None
    _ENV_CHECKED = False


def active() -> FaultInjector | None:
    """The installed injector, else one parsed from ``$REPRO_FAULTS`` (once)."""
    global _ACTIVE, _ENV_CHECKED
    if _ACTIVE is None and not _ENV_CHECKED:
        _ENV_CHECKED = True
        _ACTIVE = from_env()
    return _ACTIVE


@contextlib.contextmanager
def injected(
    specs: list[FaultSpec] | tuple[FaultSpec, ...],
    *,
    seed: int = 0,
    hang_s: float = 0.25,
):
    """Scoped injection: installs a fresh injector, yields it, restores."""
    prev, prev_checked = _ACTIVE, _ENV_CHECKED
    inj = FaultInjector(specs, seed=seed, hang_s=hang_s)
    install(inj)
    try:
        yield inj
    finally:
        install(prev)
        if prev is None and not prev_checked:
            clear()  # restore lazy env discovery, not an explicit None pin


def from_env(env: dict | None = None) -> FaultInjector | None:
    """Parse ``REPRO_FAULTS`` into an injector.

    Format: comma-separated ``kind=rate`` terms plus optional ``seed=N``
    and ``hang_s=F``, e.g. ``"backend=0.1,hang=0.05,bitflip=1,seed=7"``.
    Unset/empty disables injection.  Malformed specs raise loudly —
    silently ignoring a typo'd chaos config would un-test every recovery
    path while claiming coverage.
    """
    env = os.environ if env is None else env
    raw = env.get("REPRO_FAULTS", "").strip()
    if not raw:
        return None
    seed, hang_s = 0, 0.25
    specs: list[FaultSpec] = []
    for term in raw.split(","):
        term = term.strip()
        if not term:
            continue
        name, _, val = term.partition("=")
        name = name.strip()
        if name == "seed":
            seed = int(val)
        elif name == "hang_s":
            hang_s = float(val)
        else:
            specs.append(FaultSpec(kind=name, rate=float(val) if val else 1.0))
    if not specs:
        return None
    return FaultInjector(specs, seed=seed, hang_s=hang_s)
