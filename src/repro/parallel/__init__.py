"""Distribution: logical-axis sharding + collectives helpers."""
