"""Logical-axis sharding: MaxText-style rules with divisibility fallback.

Every parameter / activation / cache tensor in the framework is annotated with
a tuple of *logical* axis names at creation time. This module maps logical
axes onto the physical mesh through an ordered rule table:

  * each logical axis lists candidate mesh-axis groups, in preference order;
  * a candidate is taken only if (a) all its mesh axes exist, (b) none of them
    is already used by another dim of the same tensor, and (c) the product of
    their sizes divides the dim size (GSPMD requires even sharding for inputs).

The fallback behavior is what makes heterogeneous architectures work on one
mesh: granite's single KV head simply ends up replicated, mixtral's 8 experts
fall back from expert-parallel to d_ff tensor-parallel, a batch of 1
(long_500k) leaves 'data' free for the KV-cache sequence axis, etc.

Rules are plain data — swapping them is a first-class perf lever (§Perf).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axes = tuple

# Candidate mesh-axis groups per logical axis, in preference order.
# 'batch' prefers the full DP product (pod x data); 'embed' is the FSDP axis.
DEFAULT_PARAM_RULES: dict[str | None, tuple[tuple[str, ...], ...]] = {
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "experts": (("model",),),
    "embed": (("data",),),
    "expert_embed": (("data",),),  # expert-weight FSDP axis (perf lever)
    "expert_mlp": (("model",),),
    "inner": (("model",),),  # mamba/xlstm inner projection dim
    "batch": (("pod", "data"), ("data",)),
    "layers": (),
    "seq": (),
    # decode KV caches arrive as step *inputs*, so their sequence axis needs a
    # rule here too: prefer 'data' (free when batch=1, e.g. long_500k), else
    # 'model' (decode_32k, where batch already took the DP axes and a
    # replicated 32k cache would not fit HBM).
    "cache_seq": (("data",), ("model",)),
    "state": (),
    "conv": (),
    "codebooks": (),
    None: (),
}

DEFAULT_ACT_RULES: dict[str | None, tuple[tuple[str, ...], ...]] = {
    # 2D batch sharding first: when the global batch divides the full device
    # count, activations are sharded batch-wise over data AND model — the
    # per-device backward stash shrinks by |model| with ZERO per-layer
    # resharding collectives (unlike sequence parallelism, which on current
    # XLA SPMD costs f32 (B,S,D) gathers per block — measured 40x worse; see
    # EXPERIMENTS.md §Perf). Params stay FSDP/TP-sharded; their per-layer
    # all-gathers are unaffected. ORDER MATTERS: every 'pod'-bearing candidate
    # precedes every pod-free one — a pod-free assignment on a multi-pod mesh
    # would replicate the batch across pods (duplicate compute, no DP).
    "batch": (
        ("pod", "data", "model"),
        ("pod", "data"),
        ("data", "model"),
        ("data",),
    ),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "mlp": (("model",),),
    "experts": (("model",),),
    "expert_cap": (("pod", "data"), ("data",)),  # MoE dispatch-buffer capacity
    # compute-time hint for expert weights: REPLICATED over the FSDP axis
    # (one explicit gather per layer); forcing 'data' here instead makes SPMD
    # re-shard around every expert matmul — measured +24% collective bytes
    "expert_embed": (),
    "expert_mlp": (("model",),),
    "inner": (("model",),),
    "vocab": (("model",),),
    "embed": (),
    "seq": (),
    "cache_seq": (("data",), ("model",)),  # batch=1 -> data; else model
    "state": (),
    "codebooks": (),
    "layers": (),
    None: (),
}


def spec_for_axes(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str | None, tuple[tuple[str, ...], ...]],
) -> PartitionSpec:
    """Greedy logical->physical assignment with divisibility fallback."""
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} vs shape {shape} rank mismatch")
    used: set[str] = set()
    entries: list[Any] = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for ax, dim in zip(axes, shape):
        candidates = rules.get(ax, ())
        chosen = None
        for group in candidates:
            if not all(g in mesh_sizes for g in group):
                continue
            if any(g in used for g in group):
                continue
            prod = 1
            for g in group:
                prod *= mesh_sizes[g]
            if prod == 0 or dim % prod:
                continue
            chosen = group
            break
        if chosen is None:
            entries.append(None)
        else:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
    # trailing Nones can be dropped but keeping them is harmless/explicit
    return PartitionSpec(*entries)


def sharding_for(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str | None, tuple[tuple[str, ...], ...]] | None = None,
) -> NamedSharding:
    rules = rules if rules is not None else DEFAULT_PARAM_RULES
    return NamedSharding(mesh, spec_for_axes(axes, shape, mesh, rules))


def tree_shardings(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: dict[str | None, tuple[tuple[str, ...], ...]] | None = None,
) -> Any:
    """NamedSharding pytree for (axes pytree, ShapeDtypeStruct pytree)."""

    def one(axes, sds):
        return sharding_for(axes, sds.shape, mesh, rules)

    return jax.tree.map(
        one, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


# ---------------------------------------------------------------------------
# Activation shard-hint context (used inside model code).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ShardCtx:
    mesh: Mesh | None = None
    act_rules: dict | None = None


_ctx = threading.local()


def _get_ctx() -> _ShardCtx:
    if not hasattr(_ctx, "v"):
        _ctx.v = _ShardCtx()
    return _ctx.v


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, act_rules: dict | None = None):
    """Enable in-model activation sharding constraints (used at trace time)."""
    c = _get_ctx()
    prev = (c.mesh, c.act_rules)
    c.mesh, c.act_rules = mesh, act_rules or DEFAULT_ACT_RULES
    try:
        yield
    finally:
        c.mesh, c.act_rules = prev


def shard_hint(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint under the active mesh; no-op outside it."""
    c = _get_ctx()
    if c.mesh is None:
        return x
    spec = spec_for_axes(axes, x.shape, c.mesh, c.act_rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))


def active_mesh() -> Mesh | None:
    """The mesh of the enclosing activation_sharding context (or None)."""
    return _get_ctx().mesh


def active_act_rules() -> dict | None:
    return _get_ctx().act_rules
