"""Roofline + HLO analysis."""
