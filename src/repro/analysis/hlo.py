"""Post-SPMD HLO analysis: collective-op byte accounting.

``compiled.cost_analysis()`` has no collective information, so the roofline's
collective term is derived by parsing the optimized (per-device) HLO text and
summing the result-buffer bytes of every collective op. Counting result
buffers is the standard approximation (all-gather results count the gathered
size; all-reduce counts the reduced tensor once — a ring all-reduce moves
~2x that, which we fold into the link-bandwidth derate).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = TYPE op-name(` where TYPE is `bf16[1,2]{...}` or a tuple of those.
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def as_dict(self) -> dict:
        return {
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device collective result bytes, by op kind, from optimized HLO."""
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, op = m.group(1), m.group(2)
        bytes_by[op] += _shape_bytes(shape_text)
        count_by[op] += 1
    return CollectiveStats(bytes_by_op=dict(bytes_by), count_by_op=dict(count_by))
