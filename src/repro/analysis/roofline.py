"""Three-term roofline model for TPU v5e (target hardware of the dry-run).

  compute    t = HLO_FLOPs   / (chips * 197e12)   [bf16 peak per chip]
  memory     t = HLO_bytes   / (chips * 819e9)    [HBM BW per chip]
  collective t = coll_bytes  / (chips * 50e9)     [ICI per link]

FLOPs/bytes come from ``compiled.cost_analysis()`` of the *partitioned*
module, i.e. per-device numbers; multiplying by chips gives the global terms
the formulas above expect, so the per-device form used here is equivalent.

MODEL_FLOPS (the useful-work yardstick) is 6*N*D for training and 2*N*D for
inference, with N = active FLOP-bearing params (experts scaled by top_k/E,
input embedding excluded) and D = tokens processed by the step.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link

V5E = HardwareModel()


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    t_compute: float
    t_memory: float
    t_collective: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    model_flops: float
    hlo_flops_global: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — remat/dispatch waste detector."""
        return self.model_flops / self.hlo_flops_global if self.hlo_flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU upper bound: useful-FLOP time / bound time."""
        t_useful = self.model_flops / (self.chips * V5E.peak_flops)
        return t_useful / self.bound_time if self.bound_time else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    chips: int,
    model_flops: float,
    hw: HardwareModel = V5E,
) -> RooflineTerms:
    return RooflineTerms(
        t_compute=flops_per_device / hw.peak_flops,
        t_memory=bytes_per_device / hw.hbm_bw,
        t_collective=coll_bytes_per_device / hw.ici_bw,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        chips=chips,
        model_flops=model_flops,
        hlo_flops_global=flops_per_device * chips,
    )


def model_flops_for(cfg, shape) -> float:
    """6ND (train) / 2ND (inference) with N = active FLOP-bearing params."""
    from repro.models.model import count_params_analytic

    n = count_params_analytic(cfg, active_only=True, exclude_embed=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
