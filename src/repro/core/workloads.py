"""Workload definitions the SA analysis consumes: GEMMs + operand streams.

Two sources:
  1. The paper's own workload — the six ResNet50 conv layers of Table I,
     lowered conv -> im2col GEMM, with synthetic post-ReLU activations
     (density matched to typical ResNet50 layer sparsity) and zero-mean
     weights, quantized to int16 exactly as in Section IV.
  2. Any framework model — ``gemms_for_arch`` extracts the per-layer GEMM set
     (attention projections, FFN/experts, vocab) of an assigned architecture
     so the floorplan optimizer can be run on LLM workloads (beyond-paper).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Sequence

import numpy as np

from repro.core.quant import quantize_symmetric
from repro.core.switching import ActivityProfile, profile_gemm

__all__ = [
    "ConvLayer",
    "Gemm",
    "PodPartition",
    "RESNET50_TABLE1",
    "conv_to_gemm",
    "synth_activations",
    "synth_weights",
    "profile_conv_layer",
    "conv_layer_job",
    "gemm_job",
    "profile_network",
    "measured_design_activities",
    "measured_design_gemm_activities",
    "gemm_profile_seed",
    "measured_design_lane_activities",
    "partition_gemm",
    "design_pod_partition",
    "gemms_for_arch",
]


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """A conv layer in the paper's Table I notation."""

    name: str
    k: int  # kernel size
    h: int  # output height
    w: int  # output width
    c: int  # input channels
    m: int  # output channels
    input_density: float = 0.5  # fraction of non-zero (post-ReLU) inputs


@dataclasses.dataclass(frozen=True)
class Gemm:
    name: str
    m: int
    k: int
    n: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


# Table I of the paper. Input densities: ResNet50 post-ReLU activation
# densities are layer-dependent (~0.4-0.7 early, sparser deep); values below
# are representative of published ResNet50 activation-sparsity profiles and
# give layer-to-layer a_h variation like the paper describes.
RESNET50_TABLE1: tuple[ConvLayer, ...] = (
    ConvLayer("L1", k=1, h=56, w=56, c=256, m=64, input_density=0.55),
    ConvLayer("L2", k=3, h=28, w=28, c=128, m=128, input_density=0.50),
    ConvLayer("L3", k=1, h=28, w=28, c=128, m=512, input_density=0.45),
    ConvLayer("L4", k=1, h=14, w=14, c=512, m=256, input_density=0.40),
    ConvLayer("L5", k=1, h=14, w=14, c=1024, m=256, input_density=0.35),
    ConvLayer("L6", k=3, h=14, w=14, c=256, m=256, input_density=0.40),
)


def conv_to_gemm(layer: ConvLayer) -> Gemm:
    """im2col lowering: M = H*W output pixels, K = k*k*C, N = output channels."""
    return Gemm(
        name=layer.name,
        m=layer.h * layer.w,
        k=layer.k * layer.k * layer.c,
        n=layer.m,
    )


def synth_activations(
    m: int, k: int, density: float, seed: int = 0, scale: float = 1.0
) -> np.ndarray:
    """Synthetic post-ReLU activations: zeros + folded Gaussian magnitudes.

    Non-negative by construction (the paper: "the inputs in the horizontal
    direction are, by construction, positive integers"), with an explicit
    zero fraction of (1 - density) from the preceding ReLU.
    """
    rng = np.random.default_rng(seed)
    mask = rng.random((m, k)) < density
    vals = np.abs(rng.normal(0.0, scale, size=(m, k)))
    return np.where(mask, vals, 0.0)


def synth_weights(k: int, n: int, seed: int = 1, scale: float = 1.0) -> np.ndarray:
    """Zero-mean Gaussian weights (signed — drives sign flips in partial sums)."""
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, scale, size=(k, n))


def _default_b_v(bits: int, rows: int, dataflow: str) -> int:
    """Vertical bus data width per dataflow: the WS accumulator width, or the
    operand width under OS (the W stream; partial sums never move)."""
    from repro.core.floorplan import accumulator_width

    return bits if dataflow == "OS" else accumulator_width(bits, rows)


def profile_conv_layer(
    layer: ConvLayer,
    rows: int = 32,
    cols: int = 32,
    bits: int = 16,
    b_v: int | None = None,
    max_tiles: int | None = None,
    max_stream: int | None = None,
    seed: int = 0,
    backend: str | None = None,
    use_cache: bool = True,
    dataflow: str = "WS",
    lane_detail: bool = False,
) -> ActivityProfile:
    """Quantize a synthetic instance of ``layer`` to int-``bits`` and profile it
    on an R x C array (the paper's Section IV methodology, with synthetic
    ImageNet-statistics inputs) under the given dataflow.

    Exact full-stream profile by default (fused engine); pass
    ``max_tiles``/``max_stream`` to opt into the subsampled estimate (WS
    only — OS profiling is exact by construction).  ``lane_detail=True``
    also measures the exact per-bit-lane toggle totals (for the segment-
    level layout engine).  Repeat calls hit the content-keyed profile
    cache.
    """
    g = conv_to_gemm(layer)
    a_f = synth_activations(g.m, g.k, layer.input_density, seed=seed)
    w_f = synth_weights(g.k, g.n, seed=seed + 1)
    a_q = quantize_symmetric(a_f, bits).values
    w_q = quantize_symmetric(w_f, bits).values
    bv = b_v if b_v is not None else _default_b_v(bits, rows, dataflow)
    return profile_gemm(
        a_q,
        w_q,
        rows=rows,
        cols=cols,
        b_h=bits,
        b_v=bv,
        max_tiles=max_tiles,
        max_stream=max_stream,
        seed=seed,
        dataflow=dataflow,
        backend=backend,
        use_cache=use_cache,
        lane_detail=lane_detail,
    )


def conv_layer_job(
    layer: ConvLayer,
    rows: int = 32,
    cols: int = 32,
    bits: int = 16,
    b_v: int | None = None,
    seed: int = 0,
    dataflow: str = "WS",
):
    """A lazy batch-pipeline job for one Table-I conv layer.

    Operand synthesis (``synth_activations`` + ``quantize_symmetric``) runs
    only when the pipeline materializes the job — i.e. overlapped with the
    device work of the previous shape-class bucket. Operands and quantization
    match ``profile_conv_layer`` exactly, so profiles land on (and hit) the
    same content-keyed cache entries.
    """
    from repro.core.pipeline import ProfileJob

    g = conv_to_gemm(layer)
    bv = b_v if b_v is not None else _default_b_v(bits, rows, dataflow)

    def make():
        a_f = synth_activations(g.m, g.k, layer.input_density, seed=seed)
        w_f = synth_weights(g.k, g.n, seed=seed + 1)
        return quantize_symmetric(a_f, bits).values, quantize_symmetric(w_f, bits).values

    return ProfileJob(
        rows=rows,
        cols=cols,
        b_h=bits,
        b_v=bv,
        make=make,
        shape=(g.m, g.k, g.n),
        name=layer.name,
        dataflow=dataflow,
    )


def gemm_job(
    gemm: Gemm,
    rows: int,
    cols: int,
    bits: int,
    b_v: int | None = None,
    seed: int = 0,
    density: float | None = None,
    clip: tuple[int, int, int] | None = (128, 512, 256),
    dataflow: str = "WS",
):
    """A lazy job for one (LLM-style) GEMM with synthetic int operands.

    Activations are post-activation (non-negative) Gaussians, weights
     1/sqrt(K)-scaled Gaussians, quantized to ``bits`` — the recipe of
    ``examples/sa_power_llm.py``. ``clip`` bounds the profiled slice of
    very large GEMMs (toggle *rates* converge long before full LLM dims).
    """
    from repro.core.pipeline import ProfileJob

    m, k, n = gemm.m, gemm.k, gemm.n
    if clip is not None:
        m, k, n = min(m, clip[0]), min(k, clip[1]), min(n, clip[2])
    bv = b_v if b_v is not None else _default_b_v(bits, rows, dataflow)

    def make():
        rng = np.random.default_rng(seed)
        a_f = np.maximum(rng.normal(0.0, 1.0, size=(m, k)), 0.0)
        if density is not None:
            a_f = np.where(rng.random((m, k)) < density, a_f, 0.0)
        w_f = rng.normal(0.0, 1.0 / np.sqrt(k), size=(k, n))
        return quantize_symmetric(a_f, bits).values, quantize_symmetric(w_f, bits).values

    return ProfileJob(
        rows=rows,
        cols=cols,
        b_h=bits,
        b_v=bv,
        make=make,
        shape=(m, k, n),
        name=gemm.name,
        dataflow=dataflow,
    )


def profile_network(
    layers: Sequence[ConvLayer],
    rows: int = 32,
    cols: int = 32,
    bits: int = 16,
    b_v: int | None = None,
    max_tiles: int | None = None,
    max_stream: int | None = None,
    *,
    dataflow: str = "WS",
    backend: str | None = None,
    use_cache: bool = True,
    return_stats: bool = False,
):
    """Profile a whole network's conv layers through the batched pipeline.

    The batched analogue of looping ``profile_conv_layer`` — same operands,
    same seeds (layer i uses seed i, like every existing consumer), same
    cache keys, bit-exact profiles — but all layers ride a handful of fused
    device programs with operand synthesis overlapped against device work.

    Subsampling (``max_tiles``/``max_stream``, WS only) remains a per-GEMM
    estimate, so requesting it falls back to the serial loop (the batch
    pipeline is exact-only). With ``return_stats=True`` also returns the
    ``repro.core.pipeline.BatchStats`` of the run.
    """
    from repro.core.pipeline import BatchStats, run_profile_batch

    layers = list(layers)
    if max_tiles is not None or max_stream is not None:
        profiles = [
            profile_conv_layer(
                layer,
                rows=rows,
                cols=cols,
                bits=bits,
                b_v=b_v,
                max_tiles=max_tiles,
                max_stream=max_stream,
                seed=i,
                backend=backend,
                use_cache=use_cache,
                dataflow=dataflow,
            )
            for i, layer in enumerate(layers)
        ]
        stats = BatchStats(jobs=len(layers), serial_fallbacks=len(layers))
        return (profiles, stats) if return_stats else profiles

    jobs = [
        conv_layer_job(
            layer, rows=rows, cols=cols, bits=bits, b_v=b_v, seed=i, dataflow=dataflow
        )
        for i, layer in enumerate(layers)
    ]
    profiles, stats = run_profile_batch(jobs, backend=backend, use_cache=use_cache)
    return (profiles, stats) if return_stats else profiles


def _activity_classes(grid) -> tuple[list[tuple], np.ndarray]:
    """The grid's activity classes + the (P,) class index of every point.

    WS classes are ``("WS", rows, b_h, b_v_data)``; OS classes are the
    geometry-free ``("OS", b_h, b_v_data)`` (see
    ``measured_design_activities`` for why these are the invariants).
    """
    os_mask = np.asarray(grid.dataflow_os, bool)
    keys = np.stack(
        [
            np.asarray(grid.rows),
            np.asarray(grid.b_h),
            np.asarray(grid.b_v_data),
            os_mask.astype(np.int64),
        ],
        axis=1,
    )
    uniq, inverse = np.unique(keys, axis=0, return_inverse=True)
    classes: list[tuple] = []
    class_index: dict[tuple, int] = {}
    uniq_class = np.empty(len(uniq), np.int64)
    for u, (r, b_h, b_v, os_flag) in enumerate(uniq):
        # OS activities are geometry-free: rows drops out of the class key.
        key = ("OS", int(b_h), int(b_v)) if os_flag else ("WS", int(r), int(b_h), int(b_v))
        idx = class_index.get(key)
        if idx is None:
            idx = len(classes)
            classes.append(key)
            class_index[key] = idx
        uniq_class[u] = idx
    return classes, uniq_class[inverse]


def measured_design_activities(
    grid,
    layers: Sequence[ConvLayer] = RESNET50_TABLE1,
    *,
    profile_cols: int | None = None,
    backend: str | None = None,
    use_cache: bool = True,
    return_stats: bool = False,
):
    """Measured (W, P) activity arrays for a whole design grid.

    The profile→design-grid adapter: activities depend only on the *activity
    class* of a design point, never on its column count, PE area, or coding
    flag —

      * WS classes are ``(rows, b_h, b_v_data)``: each input lane's stream
        is a column of ``a`` whatever the tiling (h totals scale with
        ``ceil(N/cols)`` exactly as their transition denominators do — PR
        2's geometry-pass reuse), and column tiling regroups, never changes,
        the per-column partial-sum streams, so ``a_v`` depends on ``rows``
        (reduction depth) and the bus width only;
      * OS classes are ``(b_h, b_v_data)`` — fully geometry-free: both
        buses carry operand streams over the K axis (A rows horizontally at
        ``b_h``, W columns vertically at ``b_v``), and both totals scale
        with their tile counts exactly as the denominators do.  OS vertical
        activities are MEASURED from the real W-operand column streams —
        the analytical shortcut ``a_v := a_h`` of earlier revisions is
        retired (it assigned the A-operand's M-axis activity to a bus that
        streams the W operand along K; benchmarks/bench_design_space.py
        quantifies the error and how many design-space winners it flipped);
      * bus-invert is an activity *transform* applied later, inside the
        design-space evaluation, on ``b_v_data`` bits.

    So ONE profiling job per activity class per workload layer feeds every
    point of the grid: a few ``run_profile_batch`` passes (content-deduped
    against the shared sha256 cache, OS stream passes shared across ALL
    geometries) serve thousands-to-millions of design points.

    Returns ``(a_h, a_v)`` of shape (len(layers), grid.n_points) — plus the
    ``BatchStats`` with ``return_stats=True``.  Layer i is profiled with
    ``seed=i`` (the ``profile_network`` convention, so cache entries are
    shared with every other consumer).
    """
    from repro.core.pipeline import run_profile_batch

    layers = list(layers)
    if not layers:
        raise ValueError("no workload layers")
    classes, point_class = _activity_classes(grid)
    cols_fix = int(profile_cols) if profile_cols is not None else int(np.min(grid.cols))
    rows_fix = int(np.min(grid.rows))  # OS activities are rows-invariant
    jobs = [
        conv_layer_job(
            layer,
            rows=cls[1] if cls[0] == "WS" else rows_fix,
            cols=cols_fix,
            bits=cls[-2],
            b_v=cls[-1],
            seed=i,
            dataflow=cls[0],
        )
        for cls in classes
        for i, layer in enumerate(layers)
    ]
    profiles, stats = run_profile_batch(jobs, backend=backend, use_cache=use_cache)
    n_layers = len(layers)
    class_a_h = np.asarray(
        [[profiles[c * n_layers + w].a_h for c in range(len(classes))] for w in range(n_layers)]
    )
    class_a_v = np.asarray(
        [[profiles[c * n_layers + w].a_v for c in range(len(classes))] for w in range(n_layers)]
    )
    a_h = class_a_h[:, point_class]
    a_v = class_a_v[:, point_class]
    return (a_h, a_v, stats) if return_stats else (a_h, a_v)


def gemm_profile_seed(
    gemm: Gemm,
    *,
    clip: tuple[int, int, int] | None = (128, 512, 256),
    density: float | None = None,
) -> int:
    """Content-keyed operand seed for one profiled GEMM shape class.

    Keyed on the CLIPPED dims (+ density) — the quantities that actually
    determine the synthetic operands — so the same shape class reached
    from different models / traffic mixes synthesizes identical operands
    and lands on (and hits) the same content-keyed profile-cache entries.
    """
    m, k, n = gemm.m, gemm.k, gemm.n
    if clip is not None:
        m, k, n = min(m, clip[0]), min(k, clip[1]), min(n, clip[2])
    key = f"{m}|{k}|{n}|{density}".encode()
    return int.from_bytes(hashlib.sha256(key).digest()[:4], "little")


def measured_design_gemm_activities(
    grid,
    gemms: Sequence[Gemm],
    *,
    densities: Sequence[float | None] | None = None,
    seeds: Sequence[int] | None = None,
    clip: tuple[int, int, int] | None = (128, 512, 256),
    profile_cols: int | None = None,
    backend: str | None = None,
    use_cache: bool = True,
    return_stats: bool = False,
):
    """Measured (G, P) activity arrays for a GEMM job set — the serving
    adapter mirroring ``measured_design_activities``.

    One ``gemm_job`` per activity class per GEMM (same class invariance
    arguments: WS classes are (rows, b_h, b_v_data), OS classes the
    geometry-free (b_h, b_v_data)) feeds every point of the grid.
    ``clip`` bounds the profiled slice of LLM-sized GEMMs (toggle RATES
    converge long before full model dims; the J/op objective still prices
    utilization/spill/trunk from the FULL dims).  Seeds default to the
    content-keyed ``gemm_profile_seed`` so shape classes shared across
    models and traffic mixes dedup in the profile cache.
    """
    from repro.core.pipeline import run_profile_batch

    gemms = list(gemms)
    if not gemms:
        raise ValueError("no gemms")
    dens = list(densities) if densities is not None else [None] * len(gemms)
    if len(dens) != len(gemms):
        raise ValueError("densities must match the GEMM axis")
    if seeds is None:
        seeds = [
            gemm_profile_seed(g, clip=clip, density=d) for g, d in zip(gemms, dens)
        ]
    elif len(list(seeds)) != len(gemms):
        raise ValueError("seeds must match the GEMM axis")
    classes, point_class = _activity_classes(grid)
    cols_fix = int(profile_cols) if profile_cols is not None else int(np.min(grid.cols))
    rows_fix = int(np.min(grid.rows))  # OS activities are rows-invariant
    # Serving job sets repeat operand content heavily: after clipping, many
    # distinct full-dim GEMMs synthesize IDENTICAL operands (same clipped
    # dims + density + seed).  Profile each unique operand class once and
    # scatter back over the GEMM axis — a job-set of ~70 GEMMs typically
    # collapses to ~15 profiles per activity class.
    uniq_keys: dict[tuple, int] = {}
    gemm_uniq = np.empty(len(gemms), np.int64)
    uniq_items: list[tuple[Gemm, float | None, int]] = []
    for i, g in enumerate(gemms):
        m, k, n = g.m, g.k, g.n
        if clip is not None:
            m, k, n = min(m, clip[0]), min(k, clip[1]), min(n, clip[2])
        key = (m, k, n, dens[i], int(seeds[i]))
        u = uniq_keys.get(key)
        if u is None:
            u = len(uniq_items)
            uniq_keys[key] = u
            uniq_items.append((g, dens[i], int(seeds[i])))
        gemm_uniq[i] = u
    jobs = [
        gemm_job(
            g,
            rows=cls[1] if cls[0] == "WS" else rows_fix,
            cols=cols_fix,
            bits=cls[-2],
            b_v=cls[-1],
            seed=seed,
            density=density,
            clip=clip,
            dataflow=cls[0],
        )
        for cls in classes
        for g, density, seed in uniq_items
    ]
    profiles, stats = run_profile_batch(jobs, backend=backend, use_cache=use_cache)
    n_u = len(uniq_items)
    class_a_h = np.asarray(
        [[profiles[c * n_u + u].a_h for c in range(len(classes))] for u in range(n_u)]
    )
    class_a_v = np.asarray(
        [[profiles[c * n_u + u].a_v for c in range(len(classes))] for u in range(n_u)]
    )
    a_h = class_a_h[gemm_uniq][:, point_class]
    a_v = class_a_v[gemm_uniq][:, point_class]
    return (a_h, a_v, stats) if return_stats else (a_h, a_v)


def measured_design_lane_activities(
    grid,
    layers: Sequence[ConvLayer] = RESNET50_TABLE1,
    *,
    profile_cols: int | None = None,
    backend: str | None = None,
    use_cache: bool = True,
    n_lanes: int = 64,
):
    """Measured PER-BIT-LANE activities for a whole design grid.

    The lane-resolved sibling of ``measured_design_activities`` for the
    segment-level layout engine: one ``lane_detail=True`` profile per
    activity class per layer (lane-resolved profiling has no batch path, so
    classes run serially through the per-GEMM engine — keep the grid's
    class count small), expanded over the grid by the same cols/geometry
    invariance arguments (they hold per lane: the lane decomposition
    commutes with the tile scaling).

    Returns ``(a_h, a_v, h_lanes, v_lanes)``: the (W, P) aggregates plus
    (W, P, n_lanes) per-lane activity arrays (toggles per transition per
    wire, zero above each point's bus width) ready for
    ``repro.layout.power.evaluate_layout_space``.  The grid must be BI-free
    (lane activities describe physical, uncoded buses).
    """
    layers = list(layers)
    if not layers:
        raise ValueError("no workload layers")
    if np.any(np.asarray(grid.bus_invert)):
        raise ValueError(
            "lane activities describe uncoded buses; expand the space with "
            "bus_invert=(False,)"
        )
    if int(np.max(grid.b_v)) > n_lanes or int(np.max(grid.b_h)) > n_lanes:
        raise ValueError(f"bus wider than n_lanes={n_lanes}")
    classes, point_class = _activity_classes(grid)
    cols_fix = int(profile_cols) if profile_cols is not None else int(np.min(grid.cols))
    rows_fix = int(np.min(grid.rows))
    n_layers = len(layers)
    agg_h = np.zeros((n_layers, len(classes)))
    agg_v = np.zeros((n_layers, len(classes)))
    lane_h = np.zeros((n_layers, len(classes), n_lanes))
    lane_v = np.zeros((n_layers, len(classes), n_lanes))
    for c, cls in enumerate(classes):
        for i, layer in enumerate(layers):
            p = profile_conv_layer(
                layer,
                rows=cls[1] if cls[0] == "WS" else rows_fix,
                cols=cols_fix,
                bits=cls[-2],
                b_v=cls[-1],
                seed=i,
                dataflow=cls[0],
                backend=backend,
                use_cache=use_cache,
                lane_detail=True,
            )
            agg_h[i, c] = p.a_h
            agg_v[i, c] = p.a_v
            lane_h[i, c, : p.b_h] = p.a_h_lanes
            lane_v[i, c, : p.b_v] = p.a_v_lanes
    return (
        agg_h[:, point_class],
        agg_v[:, point_class],
        lane_h[:, point_class, :],
        lane_v[:, point_class, :],
    )


# ---------------------------------------------------------------------------
# GEMM partitioning across pods (the k-axis workload model)
# ---------------------------------------------------------------------------
#
# A k x k multi-pod array can run a GEMM two ways:
#
#   * TILE-PARALLEL — each pod owns independent output tiles of its own
#     (R/k) x (C/k) footprint.  The inter-pod trunks stay idle, but a GEMM
#     deeper than R/k must accumulate across K passes through the memory
#     system (drain + reload of every partial output per extra pass).
#   * K-SPLIT — the k pods of a column cooperate on one output tile,
#     splitting the K axis across pod rows; partial sums reduce in-array
#     over the vertical reduction trunks (the full-width gutter-crossing
#     segments the layout engine already prices), recovering the monolithic
#     array's K capacity at the cost of trunk traffic.
#
# First-order model, one pass per (K window, N window): rounds count how
# many full-array waves the job list needs; spilled words count off-array
# partial-sum accumulation traffic (drain + reload ~ 2*rows hops per word);
# trunk words count gutter crossings (1 hop per word).  The mode decision
# minimizes rounds, then the wire-hop proxy.  Under OS both operands stream
# over K temporally, so there is nothing to reduce across pods: pods only
# ever run tile-parallel.  ``k=1`` degenerates to the monolithic array
# (both modes identical, zero trunk/spill difference) — the same exactness
# contract as ``MultiPodLayout(k=1)`` itself.


@dataclasses.dataclass(frozen=True)
class PodPartition:
    """How one GEMM maps onto a k x k podded array (see module comment)."""

    gemm: Gemm
    rows: int
    cols: int
    k: int
    dataflow: str
    mode: str  # "tile" | "ksplit"
    rounds: int  # full-array waves over the job list
    cycles: int  # rounds * streamed-axis length
    utilization: float  # useful MACs / (rounds * R * C * stream)
    spill_words: int  # off-array partial-sum accumulation traffic [words]
    trunk_words: int  # inter-pod reduction-trunk crossings [words]


def _ceil_div(a, b):
    return -(-np.asarray(a, np.int64) // np.asarray(b, np.int64))


def _partition_core(m, kdim, n, rows, cols, k, os_mask):
    """Vectorized partition model; every argument broadcasts.

    Returns dict of arrays: ksplit (bool), rounds, cycles, utilization,
    spill_words, trunk_words — for the CHOSEN mode per cell.
    """
    m, kdim, n = (np.asarray(v, np.int64) for v in (m, kdim, n))
    rows, cols, k = (np.asarray(v, np.int64) for v in (rows, cols, k))
    os_mask = np.asarray(os_mask, bool)
    pr = rows // k
    pc = cols // k
    stat = np.where(os_mask, m, kdim)  # rows-mapped stationary dim: K (WS), M (OS)
    stream = np.where(os_mask, kdim, m)
    macs = m * kdim * n

    # tile-parallel: k^2 independent pods over ceil(stat/pr)*ceil(N/pc) jobs
    passes_t = _ceil_div(stat, pr)
    rounds_t = _ceil_div(passes_t * _ceil_div(n, pc), k * k)
    spill_t = np.where(os_mask, 0, (_ceil_div(kdim, pr) - 1) * m * n)

    # K-split (WS): K across the k pod rows, N across the k pod columns
    passes_s = _ceil_div(stat, rows)
    rounds_s = _ceil_div(passes_s * _ceil_div(n, pc), k)
    spill_s = (_ceil_div(kdim, rows) - 1) * m * n
    trunk_s = _ceil_div(kdim, rows) * m * n * (k - 1)

    # wire-hop proxy: spilled words traverse the array twice (drain+reload),
    # trunk words cross one gutter
    cost_t = 2 * rows * spill_t
    cost_s = 2 * rows * spill_s + trunk_s
    ksplit = (~os_mask) & (
        (rounds_s < rounds_t) | ((rounds_s == rounds_t) & (cost_s < cost_t))
    )

    rounds = np.where(ksplit, rounds_s, rounds_t)
    cycles = rounds * stream
    denom = rounds * rows * cols * stream
    util = np.where(denom > 0, macs / np.maximum(denom, 1), 0.0)
    return {
        "ksplit": ksplit,
        "rounds": rounds,
        "cycles": cycles,
        "utilization": util,
        "spill_words": np.where(ksplit, spill_s, spill_t),
        "trunk_words": np.where(ksplit, trunk_s, 0),
    }


def partition_gemm(
    gemm: Gemm, rows: int, cols: int, k: int = 1, *, dataflow: str = "WS"
) -> PodPartition:
    """Partition one GEMM onto a k x k podded ``rows x cols`` array.

    Picks tile-parallel vs K-split per the module's first-order cost model
    and reports rounds/cycles/utilization plus the traffic the choice
    implies.  ``utilization`` < 1 exposes ragged tiles and small GEMMs on
    large arrays (the SISA scale-in argument for the free k axis).
    """
    if dataflow not in ("WS", "OS"):
        raise ValueError("dataflow must be WS or OS")
    if k < 1 or rows % k or cols % k:
        raise ValueError(f"k={k} must tile the {rows}x{cols} array")
    out = _partition_core(
        gemm.m, gemm.k, gemm.n, rows, cols, k, dataflow == "OS"
    )
    return PodPartition(
        gemm=gemm,
        rows=int(rows),
        cols=int(cols),
        k=int(k),
        dataflow=dataflow,
        mode="ksplit" if bool(out["ksplit"]) else "tile",
        rounds=int(out["rounds"]),
        cycles=int(out["cycles"]),
        utilization=float(out["utilization"]),
        spill_words=int(out["spill_words"]),
        trunk_words=int(out["trunk_words"]),
    )


def design_pod_partition(grid, layouts, gemms: Sequence[Gemm], weights=None):
    """(L, P) partition statistics of a workload over a layout-axis grid.

    For every (layout family, design point) cell, maps each GEMM (k from
    the family: ``MultiPodLayout.k``, else 1) and aggregates across GEMMs
    with ``weights`` (default: MAC-weighted).  Returns dict of (L, P)
    arrays:

      ``utilization``        weighted mean useful-MAC fraction,
      ``ksplit_frac``        weighted fraction of GEMMs choosing K-split,
      ``trunk_words_per_mac``/``spill_words_per_mac``  traffic intensities.

    Cells where the family does not tile the grid get utilization 0 (the
    layout evaluator already prices them infeasible); zero-MAC GEMMs
    contribute zero everywhere instead of dividing by zero.

    This is a thin aggregation over ``repro.layout.coeffs
    .lower_partition_coeffs`` — the same lowered arrays the fused J/op
    objective consumes — so the two paths cannot silently disagree.  Do
    NOT hand-combine these statistics with ``bus_energy_per_mac_j``:
    ``repro.core.objective.evaluate_fleet_objective`` prices total energy
    per useful MAC (bus + clock + overhead + compute, spill and trunk
    traffic included) in one jitted program.
    """
    from repro.layout.coeffs import lower_partition_coeffs

    gemms = list(gemms)
    if not gemms:
        raise ValueError("no gemms")
    w = np.asarray(
        weights if weights is not None else [g.macs for g in gemms], float
    )
    if w.shape != (len(gemms),) or w.sum() <= 0:
        raise ValueError("weights must be positive per-GEMM values")
    w = w / w.sum()

    h = lower_partition_coeffs(grid, layouts, gemms).host
    w3 = w[:, None, None]
    return {
        "utilization": (w3 * h["utilization"]).sum(axis=0),
        "ksplit_frac": (w3 * h["ksplit"]).sum(axis=0),
        "trunk_words_per_mac": (w3 * h["trunk_words_per_mac"]).sum(axis=0),
        "spill_words_per_mac": (w3 * h["spill_words_per_mac"]).sum(axis=0),
    }


def gemms_for_arch(cfg, seq_len: int, batch: int = 1) -> list[Gemm]:
    """Per-token-batch GEMM set of one transformer layer + vocab projection.

    ``cfg`` is a ``repro.configs.registry.ArchConfig``. M is tokens
    (batch * seq), K/N the weight dims. MoE experts contribute their active
    (top-k) share of tokens. Used by ``examples/sa_power_llm.py`` to run the
    paper's floorplan optimization on LLM inference workloads.
    """
    tokens = seq_len * batch
    d = cfg.d_model
    head_dim = cfg.head_dim
    gemms: list[Gemm] = [
        Gemm("q_proj", tokens, d, cfg.num_heads * head_dim),
        Gemm("k_proj", tokens, d, cfg.num_kv_heads * head_dim),
        Gemm("v_proj", tokens, d, cfg.num_kv_heads * head_dim),
        Gemm("o_proj", tokens, cfg.num_heads * head_dim, d),
    ]
    if cfg.num_experts > 1:
        ff = cfg.d_ff
        active_tokens = tokens * cfg.top_k
        gemms += [
            Gemm("moe_gate", tokens, d, cfg.num_experts),
            Gemm("expert_up", active_tokens, d, ff),
            Gemm("expert_gate", active_tokens, d, ff),
            Gemm("expert_down", active_tokens, ff, d),
        ]
    elif cfg.d_ff > 0:
        gemms += [
            Gemm("ffn_up", tokens, d, cfg.d_ff),
            Gemm("ffn_gate", tokens, d, cfg.d_ff),
            Gemm("ffn_down", tokens, cfg.d_ff, d),
        ]
    gemms.append(Gemm("lm_head", tokens, d, cfg.vocab_size))
    return gemms


def total_macs(gemms: Sequence[Gemm]) -> int:
    return sum(g.macs for g in gemms)
