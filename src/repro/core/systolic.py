"""Systolic array functional + timing models, parameterized by dataflow.

Two dataflows, one ``Dataflow`` abstraction (see ``DATAFLOWS``):

Weight-stationary (WS)
    Weights resident per (K x N) tile; the M input rows stream horizontally
    and partial sums reduce down the columns.  For one R x C tile over a
    T-step stream:

        cycles(tile) = weight_load + fill/drain + stream
                     = R + (R + C - 2) + T

    (rows of weights loaded one per cycle; the wavefront needs R + C - 2
    cycles to fill and drain; one output column per cycle in steady state).
    Tile grid: ceil(K/rows) x ceil(N/cols); stream length T = M.

Output-stationary (OS)
    Accumulators resident per (M x N) output tile; BOTH operands stream —
    A rows West->East on the horizontal buses, W columns North->South on
    the vertical buses — for the K reduction steps, then the finished
    outputs drain.  SCALE-sim-style timing for one R x C tile:

        cycles(tile) = fill/drain skew + stream + output drain
                     = (R + C - 2) + K + R

    (the operand wavefronts need R + C - 2 cycles of skew; K reduction
    steps in steady state; accumulators shift out one per column per cycle,
    R cycles).  Tile grid: ceil(M/rows) x ceil(N/cols); stream length = K.

Functional models (``ws_matmul_reference`` / ``os_matmul_reference``) are
exact tiled executions of ``A @ W`` in the same tile order the hardware
uses, validated against ``jnp.matmul`` in tests.

Utilization = useful MAC-cycles / (R * C * total cycles) for both.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Dataflow",
    "DATAFLOWS",
    "get_dataflow",
    "TileSchedule",
    "ws_tile_cycles",
    "os_tile_cycles",
    "schedule_gemm",
    "ws_matmul_reference",
    "os_matmul_reference",
    "matmul_reference",
    "SAUtilization",
    "schedule_many",
]


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """Static schedule of one GEMM on an R x C systolic array.

    ``m_tiles``/``k_tiles``/``n_tiles`` count the tiling along each GEMM
    axis under the schedule's dataflow; the axis that streams through time
    (M for WS, K for OS) has a tile count of 1 and its extent is
    ``stream_len``.
    """

    m: int
    k: int
    n: int
    rows: int
    cols: int
    k_tiles: int
    n_tiles: int
    total_tiles: int
    cycles_per_tile: int
    total_cycles: int
    useful_macs: int
    peak_macs: int
    dataflow: str = "WS"
    m_tiles: int = 1
    stream_len: int = 0

    @property
    def utilization(self) -> float:
        return self.useful_macs / self.peak_macs if self.peak_macs else 0.0


def ws_tile_cycles(rows: int, cols: int, stream_len: int) -> int:
    """Cycles for one WS tile: weight load + wavefront fill/drain + stream."""
    return rows + (rows + cols - 2) + stream_len


def os_tile_cycles(rows: int, cols: int, k_len: int) -> int:
    """Cycles for one OS tile: wavefront skew + K-reduction stream + output
    drain (accumulators shift out of the array, one per column per cycle)."""
    return (rows + cols - 2) + k_len + rows


def ws_matmul_reference(a: jnp.ndarray, w: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Tiled WS execution of ``a @ w`` (exact, same tile order as hardware).

    Iterates weight tiles (K-major then N), accumulating each tile's column
    reduction into the output — the software analogue of preloading W[k0:k1,
    n0:n1] and streaming all M input rows. Python-level loop over tiles is
    fine: this is a correctness oracle, not the fast path (the fast path is
    ``repro.kernels.ws_matmul``).
    """
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    m, k = a.shape
    _, n = w.shape
    acc_dtype = _acc_dtype(a, w)
    out = jnp.zeros((m, n), dtype=acc_dtype)
    for k0 in range(0, k, rows):
        k1 = min(k0 + rows, k)
        for n0 in range(0, n, cols):
            n1 = min(n0 + cols, n)
            a_tile = a[:, k0:k1].astype(acc_dtype)
            w_tile = w[k0:k1, n0:n1].astype(acc_dtype)
            out = out.at[:, n0:n1].add(a_tile @ w_tile)
    return out


def os_matmul_reference(a: jnp.ndarray, w: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Tiled OS execution of ``a @ w`` (exact, same tile order as hardware).

    Iterates OUTPUT tiles (M-major then N); each tile's accumulators stay
    put while both operands stream through the K reduction in chunks — the
    software analogue of resident C[m0:m1, n0:n1] fed by the A-row and
    W-column streams. Like ``ws_matmul_reference`` this is a correctness
    oracle, not a fast path.
    """
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    m, k = a.shape
    _, n = w.shape
    acc_dtype = _acc_dtype(a, w)
    out = jnp.zeros((m, n), dtype=acc_dtype)
    k_chunk = max(1, rows)
    for m0 in range(0, m, rows):
        m1 = min(m0 + rows, m)
        for n0 in range(0, n, cols):
            n1 = min(n0 + cols, n)
            acc = jnp.zeros((m1 - m0, n1 - n0), dtype=acc_dtype)
            for k0 in range(0, k, k_chunk):
                k1 = min(k0 + k_chunk, k)
                acc = acc + a[m0:m1, k0:k1].astype(acc_dtype) @ w[k0:k1, n0:n1].astype(
                    acc_dtype
                )
            out = out.at[m0:m1, n0:n1].set(acc)
    return out


def _acc_dtype(a: jnp.ndarray, w: jnp.ndarray):
    return (
        jnp.result_type(a.dtype, w.dtype, jnp.int32)
        if jnp.issubdtype(a.dtype, jnp.integer)
        else jnp.float32
    )


@dataclasses.dataclass(frozen=True)
class Dataflow:
    """One systolic dataflow: tiling, timing, and functional semantics.

    ``tile_grid(m, k, n, rows, cols)`` returns (m_tiles, k_tiles, n_tiles);
    ``stream_len(m, k, n)`` is the per-tile time extent;
    ``tile_cycles(rows, cols, stream_len)`` the per-tile cycle count;
    ``matmul_reference`` the exact tiled functional model.
    """

    name: str
    tile_grid: Callable[[int, int, int, int, int], tuple[int, int, int]]
    stream_len: Callable[[int, int, int], int]
    tile_cycles: Callable[[int, int, int], int]
    matmul_reference: Callable[[jnp.ndarray, jnp.ndarray, int, int], jnp.ndarray]


DATAFLOWS: dict[str, Dataflow] = {
    "WS": Dataflow(
        name="WS",
        tile_grid=lambda m, k, n, rows, cols: (
            1,
            math.ceil(k / rows),
            math.ceil(n / cols),
        ),
        stream_len=lambda m, k, n: m,
        tile_cycles=ws_tile_cycles,
        matmul_reference=ws_matmul_reference,
    ),
    "OS": Dataflow(
        name="OS",
        tile_grid=lambda m, k, n, rows, cols: (
            math.ceil(m / rows),
            1,
            math.ceil(n / cols),
        ),
        stream_len=lambda m, k, n: k,
        tile_cycles=os_tile_cycles,
        matmul_reference=os_matmul_reference,
    ),
}


def get_dataflow(dataflow: str | Dataflow) -> Dataflow:
    if isinstance(dataflow, Dataflow):
        return dataflow
    try:
        return DATAFLOWS[dataflow]
    except KeyError:
        raise ValueError(
            f"unknown dataflow {dataflow!r}; expected one of {tuple(DATAFLOWS)}"
        ) from None


def schedule_gemm(
    m: int, k: int, n: int, rows: int, cols: int, dataflow: str | Dataflow = "WS"
) -> TileSchedule:
    """Tile an (M,K)x(K,N) GEMM onto an R x C array and count cycles."""
    if min(m, k, n, rows, cols) <= 0:
        raise ValueError("all dims must be positive")
    df = get_dataflow(dataflow)
    m_tiles, k_tiles, n_tiles = df.tile_grid(m, k, n, rows, cols)
    total_tiles = m_tiles * k_tiles * n_tiles
    stream = df.stream_len(m, k, n)
    cpt = df.tile_cycles(rows, cols, stream)
    total_cycles = total_tiles * cpt
    useful = m * k * n  # one MAC per (m, k, n) triple
    peak = rows * cols * total_cycles
    return TileSchedule(
        m=m,
        k=k,
        n=n,
        rows=rows,
        cols=cols,
        k_tiles=k_tiles,
        n_tiles=n_tiles,
        total_tiles=total_tiles,
        cycles_per_tile=cpt,
        total_cycles=total_cycles,
        useful_macs=useful,
        peak_macs=peak,
        dataflow=df.name,
        m_tiles=m_tiles,
        stream_len=stream,
    )


def matmul_reference(
    a: jnp.ndarray, w: jnp.ndarray, rows: int, cols: int, dataflow: str | Dataflow = "WS"
) -> jnp.ndarray:
    """Exact tiled execution of ``a @ w`` under the given dataflow."""
    return get_dataflow(dataflow).matmul_reference(a, w, rows, cols)


@dataclasses.dataclass(frozen=True)
class SAUtilization:
    """Aggregate timing over a set of GEMMs (e.g. a full network)."""

    total_cycles: int
    useful_macs: int
    peak_macs: int

    @property
    def utilization(self) -> float:
        return self.useful_macs / self.peak_macs if self.peak_macs else 0.0


def schedule_many(
    gemms: Sequence[tuple[int, int, int]],
    rows: int,
    cols: int,
    dataflow: str | Dataflow = "WS",
) -> SAUtilization:
    total_cycles = 0
    useful = 0
    for m, k, n in gemms:
        s = schedule_gemm(m, k, n, rows, cols, dataflow=dataflow)
        total_cycles += s.total_cycles
        useful += s.useful_macs
    return SAUtilization(
        total_cycles=total_cycles,
        useful_macs=useful,
        peak_macs=rows * cols * total_cycles,
    )
