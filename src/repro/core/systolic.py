"""Weight-stationary systolic array functional + timing model.

Functional: an exact tiled execution of ``A @ W`` in the same tile order the
hardware uses (weights preloaded per tile, inputs streamed, partial sums
reduced down columns). Validated against ``jnp.matmul`` in tests.

Timing: the standard SCALE-sim-style WS occupancy model. For one R x C tile
processing a T-step input stream:

    cycles(tile) = weight_load + fill/drain + stream
                 = R + (R + C - 2) + T

(rows of weights loaded one per cycle; the wavefront needs R + C - 2 cycles to
fill and drain; one output column per cycle in steady state).

Utilization = useful MAC-cycles / (R * C * total cycles).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "TileSchedule",
    "ws_tile_cycles",
    "schedule_gemm",
    "ws_matmul_reference",
    "SAUtilization",
]


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """Static schedule of one GEMM on an R x C WS array."""

    m: int
    k: int
    n: int
    rows: int
    cols: int
    k_tiles: int
    n_tiles: int
    total_tiles: int
    cycles_per_tile: int
    total_cycles: int
    useful_macs: int
    peak_macs: int

    @property
    def utilization(self) -> float:
        return self.useful_macs / self.peak_macs if self.peak_macs else 0.0


def ws_tile_cycles(rows: int, cols: int, stream_len: int) -> int:
    """Cycles for one WS tile: weight load + wavefront fill/drain + stream."""
    return rows + (rows + cols - 2) + stream_len


def schedule_gemm(m: int, k: int, n: int, rows: int, cols: int) -> TileSchedule:
    """Tile an (M,K)x(K,N) GEMM onto an R x C WS array and count cycles."""
    if min(m, k, n, rows, cols) <= 0:
        raise ValueError("all dims must be positive")
    k_tiles = math.ceil(k / rows)
    n_tiles = math.ceil(n / cols)
    total_tiles = k_tiles * n_tiles
    cpt = ws_tile_cycles(rows, cols, m)
    total_cycles = total_tiles * cpt
    useful = m * k * n  # one MAC per (m, k, n) triple
    peak = rows * cols * total_cycles
    return TileSchedule(
        m=m,
        k=k,
        n=n,
        rows=rows,
        cols=cols,
        k_tiles=k_tiles,
        n_tiles=n_tiles,
        total_tiles=total_tiles,
        cycles_per_tile=cpt,
        total_cycles=total_cycles,
        useful_macs=useful,
        peak_macs=peak,
    )


def ws_matmul_reference(a: jnp.ndarray, w: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Tiled WS execution of ``a @ w`` (exact, same tile order as hardware).

    Iterates weight tiles (K-major then N), accumulating each tile's column
    reduction into the output — the software analogue of preloading W[k0:k1,
    n0:n1] and streaming all M input rows. Python-level loop over tiles is
    fine: this is a correctness oracle, not the fast path (the fast path is
    ``repro.kernels.ws_matmul``).
    """
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    m, k = a.shape
    _, n = w.shape
    acc_dtype = jnp.result_type(a.dtype, w.dtype, jnp.int32) if jnp.issubdtype(
        a.dtype, jnp.integer
    ) else jnp.float32
    out = jnp.zeros((m, n), dtype=acc_dtype)
    for k0 in range(0, k, rows):
        k1 = min(k0 + rows, k)
        for n0 in range(0, n, cols):
            n1 = min(n0 + cols, n)
            a_tile = a[:, k0:k1].astype(acc_dtype)
            w_tile = w[k0:k1, n0:n1].astype(acc_dtype)
            out = out.at[:, n0:n1].add(a_tile @ w_tile)
    return out


@dataclasses.dataclass(frozen=True)
class SAUtilization:
    """Aggregate timing over a set of GEMMs (e.g. a full network)."""

    total_cycles: int
    useful_macs: int
    peak_macs: int

    @property
    def utilization(self) -> float:
        return self.useful_macs / self.peak_macs if self.peak_macs else 0.0


def schedule_many(
    gemms: Sequence[tuple[int, int, int]], rows: int, cols: int
) -> SAUtilization:
    total_cycles = 0
    useful = 0
    for m, k, n in gemms:
        s = schedule_gemm(m, k, n, rows, cols)
        total_cycles += s.total_cycles
        useful += s.useful_macs
    return SAUtilization(
        total_cycles=total_cycles,
        useful_macs=useful,
        peak_macs=rows * cols * total_cycles,
    )
