"""Crash-safe on-disk content-addressed store for activity profiles.

A profile is a pure function of (operands, geometry, dataflow, plan) — the
in-memory sha256 cache (``core.switching``) already exploits that within a
process.  This store extends the same keys across processes: CI runs,
examples, and benchmarks stop re-profiling workloads any previous run has
measured (cold-start ``benchmarks/run.py --smoke`` against a warm store
does zero profiling compute).

The crash-safety machinery (atomic tmp+fsync+rename writes, per-entry
sha256 verification, quarantine-on-corruption, LRU-by-mtime eviction) lives
in the generic ``core.store.ContentStore`` — shared with the design-space
sweep chunk store (``core.sweep``) — and this module only adds the
``ActivityProfile`` encode/decode on top.  The on-disk format is unchanged
from the pre-refactor store (same ``{"v", "sha256", "payload"}`` entries
under the same ``v4`` version directory), so existing warm stores keep
serving.
"""

from __future__ import annotations

import dataclasses

from repro.core.store import _DEFAULT_MAX_BYTES, ContentStore

__all__ = ["ProfileStore", "STORE_VERSION"]

# Must track the in-memory cache key schema (``switching._cache_key``): the
# store serves the SAME keys, so a schema bump there must orphan disk
# entries here too.
STORE_VERSION = "v4"


class ProfileStore(ContentStore):
    """One on-disk profile store rooted at ``path`` (created on first use).

    Thread-safe; every method is total (no exception escapes a ``get`` or
    ``put`` — the worst outcome is a counted miss or a dropped write).
    """

    def __init__(
        self,
        path,
        *,
        max_bytes: int = _DEFAULT_MAX_BYTES,
        version: str = STORE_VERSION,
    ):
        super().__init__(
            path, version=version, max_bytes=max_bytes, corrupt_site="store-read"
        )

    # -- profile payload codec ----------------------------------------------

    @staticmethod
    def _to_payload(profile) -> dict:
        payload = dataclasses.asdict(profile)
        for lane_field in ("h_lane_toggles", "v_lane_toggles"):
            if payload.get(lane_field) is not None:
                payload[lane_field] = list(payload[lane_field])
        return payload

    @staticmethod
    def _from_payload(payload: dict):
        from repro.core.switching import ActivityProfile

        for lane_field in ("h_lane_toggles", "v_lane_toggles"):
            if payload.get(lane_field) is not None:
                payload[lane_field] = tuple(int(v) for v in payload[lane_field])
        return ActivityProfile(**payload)

    # -- public API ----------------------------------------------------------

    def get(self, key: bytes):
        """Verified profile for ``key``, or None (miss / quarantined)."""
        payload = self.get_payload(key)
        if payload is None:
            return None
        try:
            return self._from_payload(payload)
        except Exception:
            # A sha-valid entry that no longer decodes (schema drift inside
            # the same version) is as unusable as a corrupt one: quarantine
            # semantics without the file move — count and miss.
            self._count("integrity_failures")
            self._count("misses")
            return None

    def put(self, key: bytes, profile) -> bool:
        """Atomically persist ``profile`` under ``key``; True on success."""
        return self.put_payload(key, self._to_payload(profile))
