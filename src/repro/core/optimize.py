"""Beyond-paper design-space extensions of the floorplan optimization.

1. Robust multi-workload design points. The paper fixes ONE aspect ratio from
   average activities and notes: "for a real design, one needs to take into
   account the switching profiles of many applications". This module
   implements that: 'average' (the paper's method, transition-weighted),
   'weighted' (explicit workload mix), and 'minimax-regret' (minimize the
   worst-case power excess vs each workload's private optimum).

2. Output-stationary (OS) dataflow analysis. Under OS the partial sums never
   move — both streamed operands are input-width. The wirelength asymmetry
   (B_v > B_h) vanishes, and with operand streams of similar activity the
   optimal PE is (near-)square: the paper's asymmetry is a *property of the
   weight-stationary dataflow*, not of systolic arrays per se.

3. Bus-invert coding (paper's ref [19]) as an activity transformer: with an
   extra invert line, a b-bit bus toggles min(d, b+1-d) bits for Hamming
   distance d. For i.i.d. per-bit toggle probability a, the expected coded
   activity is computable in closed form from the binomial pmf. Applying BI
   to the vertical bus lowers a_v (and widens B_v by 1), shifting Eq. 6 —
   the two techniques compose, and this module quantifies the joint win.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Sequence

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    golden_section_minimize,
    optimal_aspect_power,
)
from repro.core.switching import ActivityProfile, combine_profiles

__all__ = [
    "robust_design_point",
    "max_regret",
    "os_dataflow_geometry",
    "bus_invert_activity",
    "bus_invert_geometry",
]


# ---------------------------------------------------------------------------
# 1. Robust multi-workload design points
# ---------------------------------------------------------------------------


def _regret(geom, act: BusActivity, aspect: float) -> float:
    """P(aspect) / P(workload's own optimum) - 1 for one workload."""
    own = optimal_aspect_power(geom, act)
    return bus_power(geom, act, aspect) / bus_power(geom, act, own) - 1.0


def max_regret(
    geom: SystolicArrayGeometry, acts: Sequence[BusActivity], aspect: float
) -> float:
    return max(_regret(geom, a, aspect) for a in acts)


def robust_design_point(
    geom: SystolicArrayGeometry,
    profiles: Sequence[ActivityProfile],
    strategy: Literal["average", "weighted", "minimax"] = "average",
    weights: Sequence[float] | None = None,
) -> float:
    """One aspect ratio serving many workloads.

    'average'  — Eq. 6 at the transition-weighted mean activities (paper).
    'weighted' — minimize the weighted mean bus power (explicit app mix).
    'minimax'  — minimize the worst-case regret over workloads.
    """
    if not profiles:
        raise ValueError("no workload profiles")
    acts = [p.as_bus_activity() for p in profiles]
    if strategy == "average":
        return optimal_aspect_power(geom, combine_profiles(profiles).as_bus_activity())
    if strategy == "weighted":
        w = list(weights) if weights is not None else [1.0] * len(acts)
        if len(w) != len(acts):
            raise ValueError("weights/profiles length mismatch")

        def objective(log_a: float) -> float:
            a = math.exp(log_a)
            return sum(wi * bus_power(geom, ai, a) for wi, ai in zip(w, acts))

        return math.exp(golden_section_minimize(objective, math.log(1 / 64), math.log(64)))
    if strategy == "minimax":
        # max-regret is unimodal in log-aspect (max of unimodal functions
        # with a common domain); golden-section suffices in practice and the
        # tests cross-check against a dense grid.
        def objective(log_a: float) -> float:
            return max_regret(geom, acts, math.exp(log_a))

        return math.exp(golden_section_minimize(objective, math.log(1 / 64), math.log(64)))
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# 2. Output-stationary dataflow
# ---------------------------------------------------------------------------


def os_dataflow_geometry(
    input_bits: int, rows: int, cols: int, pe_area_um2: float = 1200.0
) -> SystolicArrayGeometry:
    """Bus geometry of an OUTPUT-stationary array of the same size.

    Under OS, A streams West->East and B streams North->South, both at the
    input width; the (wide) accumulators never cross PE boundaries (results
    drain once at the end, amortized over the whole K-reduction, which the
    steady-state bus model neglects exactly as the paper neglects weight
    preloading for WS). Hence B_h == B_v == input_bits.
    """
    return SystolicArrayGeometry(
        rows=rows, cols=cols, b_h=input_bits, b_v=input_bits, pe_area_um2=pe_area_um2
    )


# ---------------------------------------------------------------------------
# 3. Bus-invert coding
# ---------------------------------------------------------------------------


def bus_invert_activity(a: float, bits: int) -> float:
    """Expected per-bit activity of a b-bit bus under bus-invert coding.

    Model: bit flips are i.i.d. Bernoulli(a) per transition (d ~ Binomial).
    BI transmits inverted data when d > (b+1)/2, so the coded bus (b data
    lines + 1 invert line) toggles min(d, b+1-d) of its b+1 wires. Returns
    expected toggles / (b+1) wires — directly comparable to the uncoded a.
    """
    if not 0.0 <= a <= 1.0:
        raise ValueError("activity must be in [0,1]")
    b = bits
    # E[min(d, b+1-d)] over d ~ Binomial(b, a)
    exp_toggles = 0.0
    pmf = (1.0 - a) ** b  # P(d=0)
    for d in range(0, b + 1):
        if d > 0:
            pmf *= (b - d + 1) / d * (a / (1.0 - a)) if a < 1.0 else 1.0
        if a >= 1.0:
            pmf = 1.0 if d == b else 0.0
        exp_toggles += pmf * min(d, b + 1 - d)
    return exp_toggles / (b + 1)


def bus_invert_geometry(
    geom: SystolicArrayGeometry, act: BusActivity, code_vertical: bool = True
) -> tuple[SystolicArrayGeometry, BusActivity]:
    """Apply BI coding to the vertical (partial-sum) bus: B_v -> B_v + 1 wire,
    a_v -> coded activity. Returns the transformed (geometry, activities) to
    feed back into the aspect-ratio optimization — the techniques compose."""
    if not code_vertical:
        return geom, act
    a_v_coded = bus_invert_activity(act.a_v, geom.b_v)
    geom2 = dataclasses.replace(geom, b_v=geom.b_v + 1)
    return geom2, BusActivity(a_h=act.a_h, a_v=a_v_coded)
