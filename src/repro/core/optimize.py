"""Beyond-paper design-space extensions of the floorplan optimization.

1. Robust multi-workload design points. The paper fixes ONE aspect ratio from
   average activities and notes: "for a real design, one needs to take into
   account the switching profiles of many applications". This module
   implements that: 'average' (the paper's method, transition-weighted),
   'weighted' (explicit workload mix), and 'minimax-regret' (minimize the
   worst-case power excess vs each workload's private optimum).

2. Output-stationary (OS) dataflow analysis. Under OS the partial sums never
   move — both streamed operands are input-width. The wirelength asymmetry
   (B_v > B_h) vanishes, and the remaining aspect lever is the measured
   activity ratio of the two operand streams: ``profile_gemm(...,
   dataflow="OS")`` measures a_h from the A rows and a_v from the W columns
   (both along the K axis), so the WS-vs-OS comparison in
   ``repro.core.design_space`` runs on measured numbers for both dataflows.
   The paper's asymmetry is a *property of the weight-stationary dataflow*,
   not of systolic arrays per se.

3. Bus-invert coding (paper's ref [19]) as an activity transformer: with an
   extra invert line, a b-bit bus toggles min(d, b+1-d) bits for Hamming
   distance d. For i.i.d. per-bit toggle probability a, the expected coded
   activity is computable in closed form from the binomial pmf. Applying BI
   to the vertical bus lowers a_v (and widens B_v by 1), shifting Eq. 6 —
   the two techniques compose, and this module quantifies the joint win.

Array-first layout: the ``*_arr`` kernels (``regret_arr``,
``max_regret_arr``, ``minimax_aspect_arr``, ``bus_invert_activity_arr``)
broadcast over geometry/activity/aspect arrays and are jit-compatible; the
scalar API wraps their float64 numpy path (see ``repro.core.floorplan``).
``repro.core.design_space`` drives them over whole design grids.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

from repro.core.floorplan import (
    ASPECT_MAX,
    ASPECT_MIN,
    BusActivity,
    SystolicArrayGeometry,
    _xp,
    bus_power_arr,
    golden_section_minimize_arr,
    optimal_aspect_power_arr,
)
from repro.core.switching import ActivityProfile, combine_profiles

__all__ = [
    "robust_design_point",
    "max_regret",
    "os_dataflow_geometry",
    "bus_invert_activity",
    "bus_invert_geometry",
    # vectorized kernels
    "regret_arr",
    "max_regret_arr",
    "minimax_aspect_arr",
    "bus_invert_activity_arr",
]

# Widest bus the toggle model supports (``switching._to_bus_repr`` contract);
# bounds the static binomial-support axis of the vectorized BI kernel.
_MAX_BUS_BITS = 64


# ---------------------------------------------------------------------------
# 1. Robust multi-workload design points
# ---------------------------------------------------------------------------


def _power_shape(b_h, b_v, a_h, a_v, aspect, xp):
    """Bus power up to the positive geometry prefactor: x sqrt(r) + y/sqrt(r).

    The prefactor (R C sqrt(A) c_wire V^2 f / 2) is aspect-independent, so
    ratios of this shape function equal ratios of ``bus_power_arr``.
    """
    s = xp.sqrt(aspect)
    return (b_h * a_h) * s + (b_v * a_v) / s


def regret_arr(b_h, b_v, a_h, a_v, aspect, lo=ASPECT_MIN, hi=ASPECT_MAX, xp=None):
    """P(aspect) / P(own envelope-clamped optimum) - 1, elementwise.

    Zero-activity elements (no dynamic power at any aspect) report zero
    regret.
    """
    xp = xp or _xp(b_h, b_v, a_h, a_v, aspect)
    own = optimal_aspect_power_arr(b_h, b_v, a_h, a_v, lo=lo, hi=hi, xp=xp)
    p = _power_shape(b_h, b_v, a_h, a_v, aspect, xp)
    p_own = _power_shape(b_h, b_v, a_h, a_v, own, xp)
    return xp.where(p_own > 0, p / xp.where(p_own > 0, p_own, 1.0) - 1.0, 0.0)


def max_regret_arr(
    b_h, b_v, a_h, a_v, aspect, lo=ASPECT_MIN, hi=ASPECT_MAX, axis=0, xp=None
):
    """Worst-case regret across the workload axis (default: axis 0)."""
    xp = xp or _xp(b_h, b_v, a_h, a_v, aspect)
    return xp.max(regret_arr(b_h, b_v, a_h, a_v, aspect, lo=lo, hi=hi, xp=xp), axis=axis)


def minimax_aspect_arr(
    b_h, b_v, a_h, a_v, lo=ASPECT_MIN, hi=ASPECT_MAX, iters: int = 64, xp=None
):
    """Batched minimax-regret aspect: per design point, the aspect minimizing
    the worst-case regret over the leading workload axis of ``a_h``/``a_v``.

    ``a_h``/``a_v`` have shape (W, ...); the result drops the workload axis.
    Golden-section search over log-aspect (the max of unimodal-in-log
    objectives with a shared minimum basin; cross-checked against dense grids
    in the tests).
    """
    xp = xp or _xp(b_h, b_v, a_h, a_v)
    log_lo = xp.log(xp.asarray(lo) + 0.0 * xp.max(a_h, axis=0))
    log_hi = xp.log(xp.asarray(hi) + 0.0 * xp.max(a_h, axis=0))

    def objective(log_a):
        return max_regret_arr(
            b_h, b_v, a_h, a_v, xp.exp(log_a)[None, ...], lo=lo, hi=hi, axis=0, xp=xp
        )

    return xp.exp(golden_section_minimize_arr(objective, log_lo, log_hi, iters=iters, xp=xp))


def max_regret(
    geom: SystolicArrayGeometry, acts: Sequence[BusActivity], aspect: float
) -> float:
    a_h = np.asarray([a.a_h for a in acts])
    a_v = np.asarray([a.a_v for a in acts])
    return float(max_regret_arr(geom.b_h, geom.b_v, a_h, a_v, aspect, xp=np))


def robust_design_point(
    geom: SystolicArrayGeometry,
    profiles: Sequence[ActivityProfile],
    strategy: Literal["average", "weighted", "minimax"] = "average",
    weights: Sequence[float] | None = None,
) -> float:
    """One aspect ratio serving many workloads.

    'average'  — Eq. 6 at the transition-weighted mean activities (paper).
    'weighted' — minimize the weighted mean bus power (explicit app mix).
    'minimax'  — minimize the worst-case regret over workloads.

    All strategies respect the practical aspect envelope
    ``[ASPECT_MIN, ASPECT_MAX]``.
    """
    if not profiles:
        raise ValueError("no workload profiles")
    a_h = np.asarray([p.a_h for p in profiles])
    a_v = np.asarray([p.a_v for p in profiles])
    if strategy == "average":
        from repro.core.floorplan import optimal_aspect_power

        return optimal_aspect_power(geom, combine_profiles(profiles).as_bus_activity())
    if strategy == "weighted":
        w = np.asarray(weights if weights is not None else np.ones(len(profiles)), float)
        if w.shape != (len(profiles),):
            raise ValueError("weights/profiles length mismatch")

        def objective(log_a):
            p = bus_power_arr(
                geom.rows,
                geom.cols,
                geom.b_h,
                geom.b_v,
                geom.pe_area_um2,
                a_h,
                a_v,
                np.exp(log_a),
                xp=np,
            )
            return np.sum(w * p, axis=0)

        log_opt = golden_section_minimize_arr(
            objective, np.log(ASPECT_MIN), np.log(ASPECT_MAX), iters=80, xp=np
        )
        return float(np.exp(log_opt))
    if strategy == "minimax":
        return float(
            minimax_aspect_arr(geom.b_h, geom.b_v, a_h, a_v, iters=80, xp=np)
        )
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# 2. Output-stationary dataflow
# ---------------------------------------------------------------------------


def os_dataflow_geometry(
    input_bits: int, rows: int, cols: int, pe_area_um2: float = 1200.0
) -> SystolicArrayGeometry:
    """Bus geometry of an OUTPUT-stationary array of the same size.

    Under OS, A streams West->East and W streams North->South, both at the
    input width; the (wide) accumulators never cross PE boundaries (results
    drain once at the end, amortized over the whole K-reduction, which the
    steady-state bus model neglects exactly as the paper neglects weight
    preloading for WS). Hence B_h == B_v == input_bits.  Pair with
    activities measured by ``repro.core.switching.profile_gemm(...,
    dataflow="OS")`` — a_v is the W-column stream activity, not a copy of
    a_h (that approximation is retired).
    """
    return SystolicArrayGeometry(
        rows=rows, cols=cols, b_h=input_bits, b_v=input_bits, pe_area_um2=pe_area_um2
    )


# ---------------------------------------------------------------------------
# 3. Bus-invert coding
# ---------------------------------------------------------------------------


def bus_invert_activity_arr(a, bits, xp=None):
    """Vectorized expected per-bit activity under bus-invert coding.

    Broadcasts over ``a`` (per-bit toggle probabilities in [0, 1]) and
    ``bits`` (data bus widths, <= 64).  The binomial pmf of the Hamming
    distance d ~ Binomial(b, a) is evaluated in LOG space —
    ``logC(b, d) + d log a + (b - d) log(1 - a)`` with the log-binomial
    built by a cumulative-sum recurrence — so activities arbitrarily close
    to 0 or 1 stay finite (the naive pmf recurrence seeds with
    ``(1-a)**b``, which underflows to exactly 0 for a near 1 and poisons
    every term).  The endpoints are exact: a=0 -> 0 coded activity,
    a=1 -> 1/(b+1) (the invert line toggles every cycle, the data lines
    never).
    """
    xp = xp or _xp(a, bits)
    a = xp.asarray(a) + 0.0
    b = xp.asarray(bits) + 0.0
    a, b = xp.broadcast_arrays(a, b)
    eps = xp.finfo(b.dtype).tiny
    a_in = xp.clip(a, eps, 1.0 - xp.finfo(b.dtype).eps)
    log_a = xp.log(a_in)
    log_1ma = xp.log1p(-a_in)

    # Stream the binomial support d = 1.._MAX_BUS_BITS (the widest bus the
    # toggle model takes), carrying the log-binomial recurrence
    # log C(b, d) = log C(b, d-1) + log(b - d + 1) - log(d) — entries beyond
    # each element's own b drop to log-probability -inf.  Streaming keeps the
    # working set at O(broadcast shape) instead of O(shape x 65), so million-
    # point design grids stay cheap.  The d = 0 term has cost min(0, b+1) = 0
    # and never contributes.
    def step(d, log_binom, acc):
        valid = d <= b
        log_binom = xp.where(
            valid, log_binom + xp.log(xp.where(valid, b - d + 1.0, 1.0)) - xp.log(d), -xp.inf
        )
        # BI transmits inverted data when d > (b+1)/2: the coded (b+1)-wire
        # bus toggles min(d, b+1-d) wires.  pmf is exactly 0 beyond d = b,
        # so the clamped cost there contributes nothing.
        pmf = xp.exp(log_binom + d * log_a + (b - d) * log_1ma)
        cost = xp.maximum(xp.minimum(d + 0.0 * b, b + 1.0 - d), 0.0)
        return log_binom, acc + pmf * cost

    log_binom = xp.zeros_like(b)
    acc = xp.zeros_like(b)
    if xp is np:
        for d in range(1, _MAX_BUS_BITS + 1):
            log_binom, acc = step(float(d), log_binom, acc)
    else:
        from jax import lax

        log_binom, acc = lax.fori_loop(
            1,
            _MAX_BUS_BITS + 1,
            lambda d, s: step(d * 1.0, *s),
            (log_binom, acc),
        )
    coded = acc / (b + 1.0)
    return xp.where(a <= 0.0, 0.0, xp.where(a >= 1.0, 1.0 / (b + 1.0), coded))


def bus_invert_activity(a: float, bits: int) -> float:
    """Expected per-bit activity of a b-bit bus under bus-invert coding.

    Model: bit flips are i.i.d. Bernoulli(a) per transition (d ~ Binomial).
    BI transmits inverted data when d > (b+1)/2, so the coded bus (b data
    lines + 1 invert line) toggles min(d, b+1-d) of its b+1 wires. Returns
    expected toggles / (b+1) wires — directly comparable to the uncoded a.
    Evaluated stably in log space (``bus_invert_activity_arr``); the result
    always satisfies ``coded <= a`` and the endpoints are exact.
    """
    if not 0.0 <= a <= 1.0:
        raise ValueError("activity must be in [0,1]")
    if not 1 <= bits <= _MAX_BUS_BITS:
        raise ValueError(f"bits must be in [1, {_MAX_BUS_BITS}]")
    return float(bus_invert_activity_arr(a, bits, xp=np))


def bus_invert_geometry(
    geom: SystolicArrayGeometry, act: BusActivity, code_vertical: bool = True
) -> tuple[SystolicArrayGeometry, BusActivity]:
    """Apply BI coding to the vertical (partial-sum) bus: B_v -> B_v + 1 wire,
    a_v -> coded activity. Returns the transformed (geometry, activities) to
    feed back into the aspect-ratio optimization — the techniques compose."""
    if not code_vertical:
        return geom, act
    a_v_coded = bus_invert_activity(act.a_v, geom.b_v)
    geom2 = dataclasses.replace(geom, b_v=geom.b_v + 1)
    return geom2, BusActivity(a_h=act.a_h, a_v=a_v_coded)
