"""Checkpointed, self-validating execution of design-space/layout sweeps.

The exploration engines (``core.design_space.evaluate_design_space``,
``layout.power.evaluate_layout_space``) evaluate their whole grid in one
program: fast, but a multi-hour sweep that dies at 80% restarts from zero,
and a silently wrong cell (a NaN, a jit/closed-form divergence) corrupts
the Pareto frontier with no error at all.  This module is the resilience
layer between those engines and their callers — both gain a ``sweep=``
keyword that routes evaluation through here.

Chunking & resume
-----------------
The point axis P is split into deterministic fixed-shape chunks of
``SweepConfig.chunk_size`` (the last chunk clamp-pads by repeating the
final point, so every chunk traces to ONE compiled program).  Chunking
along P is mathematically safe: every engine reduction runs along the
workload axis W, never across points.  Each completed chunk is committed to
a crash-safe content-addressed ``core.store.ContentStore`` (atomic
tmp+fsync+rename, per-entry sha256, quarantine-on-corruption — the exact
machinery the profile store uses) under
``sha256(spec | chunk_index)``, where the spec digest covers every input
that determines the chunk's bytes (grid arrays, activities, weights,
config, gss iterations, chunk size, starting rung).  A killed sweep
re-keyed over the same inputs serves completed chunks from the store —
the stored arrays round-trip as raw dtype+shape+base64 bytes, so a
resumed run reproduces the uninterrupted run BIT-identically (JSON float
text could not: it cannot even represent a NaN payload).

Validation & degradation
------------------------
Every chunk (freshly evaluated or resumed) passes a guard harness before
it is accepted:

  * physical contracts — all fields finite; powers positive where activity
    is; coded activity <= raw; savings <= 1; argmin aspects inside the
    envelope; infeasible layout cells priced ``inf`` and only those;
  * cross-engine agreement — the batched golden-section argmin against the
    closed-form Eq. 6 optimum (f64 power-shape comparison), and a seeded
    random sample of cells re-derived through the SCALAR oracles
    (``optimize.bus_invert_activity``, ``floorplan.bus_power``,
    ``layout.power.segment_bus_power``) at rung-appropriate tolerances.

A violated chunk raises a typed ``GuardViolationError`` /
``CrossEngineMismatchError`` (``runtime.resilience`` taxonomy) and is
re-evaluated down the ``jit -> eager -> scalar`` ladder
(``resilience.evaluation_ladder``): same math in float64 numpy, then
per-point scalar evaluation with nothing batched that could smear one bad
cell into its neighbors.  Every event lands in the machine-readable
``SweepReport`` (chunk records + a ``resilience.FailureReport``).

Fault tolerance
---------------
Fresh jit chunks are sharded round-robin across ``jax.local_devices()``;
a dispatch-class failure (timeout, device loss) evicts the device through
``runtime.health.HealthMonitor`` and resubmits the chunk once to a
survivor — the same semantics the profiling pipeline uses.  Evaluator-site
fault hooks (``runtime.faults``: backend raise, hang, device loss, NaN/Inf
poison, chunk-store bitflip, commit-boundary abort) let chaos CI prove
every one of these paths actually runs.
"""

from __future__ import annotations

import base64
import contextlib
import dataclasses
import hashlib
import os
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.store import ContentStore
from repro.runtime import faults
from repro.runtime.health import HealthMonitor
from repro.runtime.resilience import (
    BackendCompileError,
    CacheCorruptionError,
    ContractViolationError,
    CrossEngineMismatchError,
    DeviceDispatchError,
    EvaluationError,
    FailureReport,
    GuardViolationError,
    ProfileError,
    RetryPolicy,
    call_with_retry,
    classify_exception,
    evaluation_ladder,
)

__all__ = [
    "SweepConfig",
    "ChunkRecord",
    "SweepReport",
    "SweepInterrupted",
    "SWEEP_STORE_VERSION",
    "run_design_sweep",
    "run_layout_sweep",
]

# Chunk-store key schema version: a bump orphans old chunks rather than
# mis-serving them (same rule the profile store follows).  v2: the layout
# engine moved to the coefficient-protocol evaluator (bisection+Newton
# aspect search) — numerically tighter optima than the GSS chunks of v1.
# v3: bus-invert coding is lowered host-side in exact float64 (activity
# multipliers / effective activities) instead of recomputed inside the f32
# jitted programs, and the layout engine gained the fused J/op objective
# fields — both change chunk bytes.
SWEEP_STORE_VERSION = "sweep-v3"

# The exact output field sets of the two engines — chunk payloads carry all
# of them, and a stored chunk missing (or growing) a field fails decode.
_DESIGN_FIELDS = (
    "a_v_eff",
    "aspect_opt",
    "aspect_opt_gss",
    "bus_power_opt",
    "bus_power_sym",
    "aspect_robust",
    "max_regret",
    "bus_power_robust",
    "bus_power_square",
    "interconnect_saving",
    "total_saving",
    "area_um2",
    "bus_energy_per_mac_j",
    "neg_macs_per_cycle",
)
_LAYOUT_FIELDS = (
    "feasible",
    "aspect_lo",
    "aspect_hi",
    "aspect_opt",
    "bus_power_opt",
    "aspect_robust",
    "bus_power_robust",
    "overhead_w",
    "wirelength_um",
)
# Objective-mode layout sweeps (an ``ObjectiveSpec`` was priced) carry the
# fused J/op outputs on top of the wire-power schema.
_OBJECTIVE_FIELDS = _LAYOUT_FIELDS + (
    "utilization",
    "j_per_mac",
    "j_per_mac_robust",
)

# Chunks are pure compute (no device queue contention like profiling), so
# the default retry budget is small and fast.
_DEFAULT_RETRY = RetryPolicy(max_attempts=2, base_delay_s=0.01, max_delay_s=0.1)

_ON_VIOLATION = ("degrade", "raise")


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Knobs of the chunked sweep runner (``sweep=`` on the evaluators).

    ``store`` is a directory path or a ``ContentStore``; ``None`` runs
    chunked + validated but unpersisted.  ``max_chunks`` bounds how many
    PENDING chunks this call evaluates (the kill-and-resume test harness:
    a truncated sweep raises ``SweepInterrupted`` after committing them).
    ``on_violation="degrade"`` walks a guard-violating chunk down the
    jit -> eager -> scalar ladder; ``"raise"`` surfaces the first violation.
    ``oracle_cells`` is the per-chunk scalar-oracle sample size (0 keeps
    only the vectorized contract guards).  ``timeout_s`` bounds one chunk's
    device round-trip (default ``$REPRO_SWEEP_TIMEOUT_S``, else unbounded);
    ``devices``/``health`` override device discovery and the eviction
    monitor (tests inject simulated fleets).
    """

    chunk_size: int = 256
    store: object | None = None
    resume: bool = True
    validate: bool = True
    oracle_cells: int = 4
    seed: int = 0
    max_chunks: int | None = None
    on_violation: str = "degrade"
    timeout_s: float | None = None
    retry: RetryPolicy | None = None
    devices: tuple | None = None
    health: object | None = None

    def __post_init__(self):
        if int(self.chunk_size) < 1:
            raise ContractViolationError("chunk_size must be >= 1")
        if self.on_violation not in _ON_VIOLATION:
            raise ContractViolationError(
                f"on_violation must be one of {_ON_VIOLATION}"
            )
        if self.max_chunks is not None and int(self.max_chunks) < 1:
            raise ContractViolationError("max_chunks must be >= 1 (or None)")


@dataclasses.dataclass
class ChunkRecord:
    """Per-chunk outcome: where its points came from and on which rung."""

    index: int
    points: int
    status: str  # "evaluated" | "resumed"
    rung: str  # evaluation rung that produced the accepted result
    guard: str  # "pass" | "skipped"
    attempts: int = 1

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SweepReport:
    """Machine-readable account of one chunked sweep.

    ``records`` has one ``ChunkRecord`` per chunk (in index order);
    ``failures`` is the shared ``resilience.FailureReport`` vocabulary —
    every retry, degradation, eviction, quarantine, and raise is a typed
    record, so chaos CI can assert zero silent corruptions by set-matching
    injected faults against it.
    """

    kind: str
    n_points: int
    chunk_size: int
    chunks_total: int
    chunks_evaluated: int = 0
    chunks_resumed: int = 0
    chunks_quarantined: int = 0
    guard_checks: int = 0
    guard_failures: int = 0
    resubmits: int = 0
    records: list = dataclasses.field(default_factory=list)
    failures: FailureReport = dataclasses.field(default_factory=FailureReport)

    def rung_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            out[r.rung] = out.get(r.rung, 0) + 1
        return out

    def guard_verdicts(self) -> dict[str, int]:
        """{"pass": n, "skipped": n, "fail": n} — fails counted from the
        guard_failures tally (a failed check never yields a chunk record)."""
        out = {"pass": 0, "skipped": 0, "fail": self.guard_failures}
        for r in self.records:
            out[r.guard] = out.get(r.guard, 0) + 1
        return out

    def summary(self) -> str:
        rungs = ", ".join(f"{k}x{n}" for k, n in sorted(self.rung_counts().items()))
        line = (
            f"{self.kind} sweep: {self.n_points} points in {self.chunks_total} "
            f"chunks of {self.chunk_size} — {self.chunks_evaluated} evaluated, "
            f"{self.chunks_resumed} resumed, {self.chunks_quarantined} "
            f"quarantined ({rungs or 'none'}); guards: {self.guard_checks} "
            f"checks, {self.guard_failures} violations"
        )
        if self.resubmits:
            line += f"; {self.resubmits} device resubmissions"
        if self.failures:
            line += f"; {self.failures.summary()}"
        return line

    def as_dict(self) -> dict:
        return {
            "kind": self.kind,
            "n_points": self.n_points,
            "chunk_size": self.chunk_size,
            "chunks_total": self.chunks_total,
            "chunks_evaluated": self.chunks_evaluated,
            "chunks_resumed": self.chunks_resumed,
            "chunks_quarantined": self.chunks_quarantined,
            "guard_checks": self.guard_checks,
            "guard_failures": self.guard_failures,
            "resubmits": self.resubmits,
            "rung_counts": self.rung_counts(),
            "guard_verdicts": self.guard_verdicts(),
            "records": [r.as_dict() for r in self.records],
            "failures": self.failures.as_dict(),
        }


class SweepInterrupted(EvaluationError):
    """A sweep stopped early on purpose (``max_chunks``) — completed chunks
    are committed, the partial ``SweepReport`` rides on ``.report``."""

    kind = "sweep-interrupted"

    def __init__(self, message: str, *, report: SweepReport, job="", stage=""):
        super().__init__(message, job=job, stage=stage)
        self.report = report


# ---------------------------------------------------------------------------
# Chunk payload codec — raw array bytes, NOT JSON floats: base64 of the
# exact buffer round-trips every bit pattern (including a poisoned NaN on
# its way into quarantine), which is what "resume bit-identically" means.
# ---------------------------------------------------------------------------


def _encode_field(arr) -> dict:
    arr = np.ascontiguousarray(arr)
    return {
        "dtype": arr.dtype.str,
        "shape": list(arr.shape),
        "data": base64.b64encode(arr.tobytes()).decode("ascii"),
    }


def _decode_field(doc: dict) -> np.ndarray:
    arr = np.frombuffer(base64.b64decode(doc["data"]), dtype=np.dtype(doc["dtype"]))
    return arr.reshape([int(s) for s in doc["shape"]]).copy()


def _encode_chunk(kind: str, index: int, rung: str, out: dict) -> dict:
    return {
        "kind": kind,
        "chunk": index,
        "rung": rung,
        "fields": {k: _encode_field(v) for k, v in out.items()},
    }


def _decode_chunk(payload: dict, kind: str, index: int, fields) -> tuple[dict, str]:
    if payload.get("kind") != kind or payload.get("chunk") != index:
        raise ValueError(
            f"chunk entry is for {payload.get('kind')}#{payload.get('chunk')}, "
            f"wanted {kind}#{index}"
        )
    docs = payload.get("fields")
    if not isinstance(docs, dict) or set(docs) != set(fields):
        raise ValueError("chunk entry field set does not match the engine schema")
    return {k: _decode_field(docs[k]) for k in fields}, str(payload.get("rung", "?"))


# ---------------------------------------------------------------------------
# Deterministic keying
# ---------------------------------------------------------------------------


def _digest(parts) -> bytes:
    h = hashlib.sha256()
    for tag, val in parts:
        h.update(tag.encode())
        h.update(b"=")
        h.update(val if isinstance(val, bytes) else str(val).encode())
        h.update(b";")
    return h.digest()


def _grid_parts(grid) -> list:
    return [
        ("rows", np.asarray(grid.rows, np.int64).tobytes()),
        ("cols", np.asarray(grid.cols, np.int64).tobytes()),
        ("b_h", np.asarray(grid.b_h, np.int64).tobytes()),
        ("b_v", np.asarray(grid.b_v, np.int64).tobytes()),
        ("b_v_data", np.asarray(grid.b_v_data, np.int64).tobytes()),
        ("bus_invert", np.asarray(grid.bus_invert, np.uint8).tobytes()),
        ("dataflow_os", np.asarray(grid.dataflow_os, np.uint8).tobytes()),
        ("pe_area", np.asarray(grid.pe_area_um2, np.float64).tobytes()),
        ("aspect_lo", repr(float(grid.aspect_lo))),
        ("aspect_hi", repr(float(grid.aspect_hi))),
    ]


def _spec_key(kind, grid, a_h, a_v, weights, extra) -> bytes:
    """Digest over everything that determines a chunk's bytes.  The starting
    rung is included deliberately: jit (f32) and eager (f64) runs must not
    share chunks — they agree to tolerance, not bit-for-bit."""
    parts = [
        ("store", SWEEP_STORE_VERSION),
        ("kind", kind),
        *_grid_parts(grid),
        ("a_h", np.asarray(a_h, np.float64).tobytes()),
        ("a_v", np.asarray(a_v, np.float64).tobytes()),
        ("w", np.asarray(weights, np.float64).tobytes()),
        *extra,
    ]
    return _digest(parts)


def _chunk_key(spec: bytes, index: int) -> bytes:
    return hashlib.sha256(spec + b"|chunk|" + str(index).encode()).digest()


def _chunk_idx(index: int, chunk_size: int, n: int) -> np.ndarray:
    """Point indices of chunk ``index`` — clamp-padded to ``chunk_size`` by
    repeating the last point, so every chunk shares one compiled shape."""
    return np.minimum(np.arange(index * chunk_size, (index + 1) * chunk_size), n - 1)


def _chunk_points(index: int, chunk_size: int, n: int) -> int:
    return min(chunk_size, n - index * chunk_size)


# ---------------------------------------------------------------------------
# Design-space engine adapter (evaluate + validate closures)
# ---------------------------------------------------------------------------


def _design_eval_factory(grid, a_h, a_v_eff, w, cfg, gss_iters, cs, n):
    from repro.core.design_space import _evaluate_core, _jitted_eval

    rows = np.asarray(grid.rows, float)
    cols = np.asarray(grid.cols, float)
    b_h = np.asarray(grid.b_h, float)
    b_v = np.asarray(grid.b_v, float)
    area = np.asarray(grid.pe_area_um2, float)
    lo, hi = float(grid.aspect_lo), float(grid.aspect_hi)

    def args_for(idx):
        return (
            rows[idx], cols[idx], b_h[idx], b_v[idx], area[idx],
            a_h[:, idx], a_v_eff[:, idx], w, lo, hi,
            cfg.vdd, cfg.freq_hz, cfg.wire_cap_f_per_um,
            cfg.non_bus_interconnect_fraction, cfg.interconnect_share_of_total,
        )

    def eval_chunk(rung, index, device=None):
        idx = _chunk_idx(index, cs, n)
        if rung == "jit":
            import jax

            fn = _jitted_eval(gss_iters)
            ctx = (
                jax.default_device(device)
                if device is not None
                else contextlib.nullcontext()
            )
            with ctx:
                return {k: np.asarray(v) for k, v in fn(*args_for(idx)).items()}
        if rung == "eager":
            return {
                k: np.asarray(v)
                for k, v in _evaluate_core(
                    *args_for(idx), gss_iters=gss_iters
                ).items()
            }
        # scalar rung: one point per call — nothing batched that could smear
        # one bad cell into its neighbors.
        parts = [
            _evaluate_core(*args_for(idx[j : j + 1]), gss_iters=gss_iters)
            for j in range(len(idx))
        ]
        return {
            k: np.concatenate([np.asarray(p[k]) for p in parts], axis=-1)
            for k in parts[0]
        }

    return eval_chunk


def _design_validate_factory(
    grid, a_h, a_v, w, cfg, spec, oracle_cells, oracle_seed, cs, n
):
    from repro.core.floorplan import BusActivity, bus_power, optimal_aspect_power_arr
    from repro.core.optimize import _power_shape, bus_invert_activity

    b_h = np.asarray(grid.b_h, float)
    b_v = np.asarray(grid.b_v, float)
    b_v_data = np.asarray(grid.b_v_data, np.int64)
    bi = np.asarray(grid.bus_invert, bool)
    lo, hi = float(grid.aspect_lo), float(grid.aspect_hi)
    has_one = lo <= 1.0 <= hi  # the square layout is inside the envelope

    def validate(out, index, rung):
        idx = _chunk_idx(index, cs, n)
        # "stored" chunks (and fresh jit chunks) are float32 engine output;
        # eager/scalar rungs are float64 and held to much tighter tolerances.
        loose = rung in ("jit", "stored")
        eps = 1e-4 if loose else 1e-8
        eps_a = 1e-5 if loose else 1e-9  # envelope slack (f32 clamp rounding)
        v: list[str] = []

        missing = [f for f in _DESIGN_FIELDS if f not in out]
        if missing:
            return [f"missing fields {missing}"]
        for f in _DESIGN_FIELDS:
            if not np.isfinite(np.asarray(out[f], float)).all():
                v.append(f"non-finite values in {f}")
        if v:
            return v  # every further check is meaningless on NaN/Inf

        ave = np.asarray(out["a_v_eff"], float)
        avs = a_v[:, idx]
        ahs = a_h[:, idx]
        bi_c = bi[idx]
        if (ave < -eps).any() or (ave > 1 + eps).any():
            v.append("a_v_eff outside [0, 1]")
        if bi_c.any() and (ave[:, bi_c] > avs[:, bi_c] + 1e-6 + eps).any():
            v.append("coded activity exceeds raw (a_v_eff > a_v on BI points)")
        unc = ~bi_c
        if unc.any() and (
            np.abs(ave[:, unc] - avs[:, unc]) > 1e-6 + eps * np.abs(avs[:, unc])
        ).any():
            v.append("a_v_eff differs from a_v on uncoded points")

        for f in ("aspect_opt", "aspect_opt_gss"):
            a = np.asarray(out[f], float)
            if (a < lo * (1 - eps_a)).any() or (a > hi * (1 + eps_a)).any():
                v.append(f"{f} outside the aspect envelope [{lo}, {hi}]")
        ar = np.asarray(out["aspect_robust"], float)
        if (ar < lo * (1 - eps_a)).any() or (ar > hi * (1 + eps_a)).any():
            v.append("aspect_robust outside the aspect envelope")

        tiny = 1e-30
        active_wp = ahs + np.maximum(ave, 0.0) > 1e-6  # (W, P)
        active_p = (w[:, None] * (ahs + np.maximum(ave, 0.0))).sum(0) > 1e-6
        for f, active in (
            ("bus_power_opt", active_wp),
            ("bus_power_sym", active_wp),
            ("bus_power_robust", active_p),
            ("bus_power_square", active_p),
        ):
            p = np.asarray(out[f], float)
            if (p < -tiny).any():
                v.append(f"negative power in {f}")
            elif (p[active] <= 0).any():
                v.append(f"zero power in {f} on cells with switching activity")

        if (np.asarray(out["max_regret"], float) < -eps).any():
            v.append("negative worst-case regret")
        for f in ("interconnect_saving", "total_saving"):
            if (np.asarray(out[f], float) > 1 + eps).any():
                v.append(f"{f} exceeds 1")
        if (np.asarray(out["area_um2"], float) <= 0).any():
            v.append("non-positive area")
        if has_one:
            # aspect_opt minimizes per-(workload, point) power over an
            # envelope containing the square layout, so it can never lose
            # to it.  (No analogous bound holds for interconnect_saving:
            # aspect_robust minimizes minimax REGRET, not weighted power.)
            p_opt = np.asarray(out["bus_power_opt"], float)
            p_sym = np.asarray(out["bus_power_sym"], float)
            if (p_opt > p_sym * (1 + 10 * eps) + tiny).any():
                v.append("bus_power_opt exceeds the square layout's power")

        # Cross-engine: the batched golden-section argmin must agree with
        # the closed-form Eq. 6 optimum — compared through the f64 power
        # shape at each aspect (aspect comparison is ill-conditioned: the
        # minimum is flat).
        rtol_gss = 1e-4 if loose else 1e-6
        ao = np.asarray(out["aspect_opt"], float)
        ag = np.asarray(out["aspect_opt_gss"], float)
        bh_c, bv_c = b_h[idx], b_v[idx]
        ave_cl = np.clip(ave, 0.0, 1.0)
        p_cf = _power_shape(bh_c, bv_c, ahs, ave_cl, ao, np)
        p_gs = _power_shape(bh_c, bv_c, ahs, ave_cl, ag, np)
        denom = np.maximum(np.minimum(p_cf, p_gs), tiny)
        if (np.abs(p_cf - p_gs) > rtol_gss * denom + tiny).any():
            v.append(
                "cross-engine:gss-vs-closed-form optimal aspects disagree "
                f"(rtol {rtol_gss})"
            )

        # Cross-engine: seeded random cells re-derived through the scalar
        # API (float64, no batching, no jit) — the oracle of last resort.
        if oracle_cells > 0:
            rtol = 2e-3 if loose else 1e-6
            n_w = a_h.shape[0]
            for t in range(oracle_cells):
                h = hashlib.sha256(
                    spec + f"|oracle|{oracle_seed}|{index}|{t}".encode()
                ).digest()
                wi = int.from_bytes(h[:4], "big") % n_w
                j = int.from_bytes(h[4:8], "big") % len(idx)
                pj = int(idx[j])
                ah_s, av_s = float(a_h[wi, pj]), float(a_v[wi, pj])
                ave_ref = (
                    bus_invert_activity(av_s, int(b_v_data[pj]))
                    if bi[pj]
                    else av_s
                )
                cell = f"[{wi},{pj}]"
                if abs(float(ave[wi, j]) - ave_ref) > rtol * max(ave_ref, 1e-9) + 1e-7:
                    v.append(f"cross-engine:a_v_eff{cell} vs scalar bus_invert_activity")
                opt_ref = float(
                    optimal_aspect_power_arr(
                        b_h[pj], b_v[pj], ah_s, ave_ref, lo=lo, hi=hi, xp=np
                    )
                )
                if abs(float(ao[wi, j]) - opt_ref) > rtol * opt_ref + 1e-7:
                    v.append(f"cross-engine:aspect_opt{cell} vs scalar Eq. 6")
                p_ref = bus_power(
                    grid.geometry(pj),
                    BusActivity(ah_s, min(max(ave_ref, 0.0), 1.0)),
                    opt_ref,
                    vdd=cfg.vdd,
                    freq_hz=cfg.freq_hz,
                    wire_cap_f_per_um=cfg.wire_cap_f_per_um,
                )
                got_p = float(np.asarray(out["bus_power_opt"], float)[wi, j])
                if abs(got_p - p_ref) > rtol * max(p_ref, tiny):
                    v.append(f"cross-engine:bus_power_opt{cell} vs scalar bus_power")
        return v

    return validate


# ---------------------------------------------------------------------------
# Layout engine adapter
# ---------------------------------------------------------------------------


def _slice_objective(objective, sub_idx):
    """Per-chunk view of an ``ObjectiveSpec``: the lowered partition arrays
    and static power sliced along the point axis (all shapes end in P)."""
    from repro.layout.coeffs import LoweredTensors
    from repro.layout.power import ObjectiveSpec

    host = {
        k: np.ascontiguousarray(v[..., sub_idx])
        for k, v in objective.partition.host.items()
    }
    return ObjectiveSpec(
        partition=LoweredTensors(None, host),
        static_w=np.ascontiguousarray(
            np.asarray(objective.static_w, float)[:, sub_idx]
        ),
    )


def _layout_eval_factory(
    grid, a_h, a_v, layouts, h_lanes, v_lanes, w, cfg, gss_iters, cs, n,
    objective=None,
):
    # Per-SWEEP device residency (populated lazily on the first jit chunk):
    # the full grid's coefficient tensors and activities are device-put
    # exactly once, and every chunk slices them on-device.  The per-chunk
    # host->device transfer of v1 was pure overhead at large P.
    state: dict = {}
    fields = _OBJECTIVE_FIELDS if objective is not None else _LAYOUT_FIELDS

    def run(sub_idx, use_jit):
        from repro.layout.power import evaluate_layout_space

        ev = evaluate_layout_space(
            grid.select(sub_idx),
            a_h[:, sub_idx],
            a_v[:, sub_idx],
            layouts=layouts,
            h_lanes=None if h_lanes is None else h_lanes[:, sub_idx, :],
            v_lanes=None if v_lanes is None else v_lanes[:, sub_idx, :],
            weights=w,
            cfg=cfg,
            use_jit=use_jit,
            gss_iters=gss_iters,
            objective=(
                None if objective is None else _slice_objective(objective, sub_idx)
            ),
        )
        return {f: np.asarray(getattr(ev, f)) for f in fields}

    def run_jit(idx, device):
        import jax
        import jax.numpy as jnp

        from repro.layout.coeffs import (
            DEVICE_FIELDS,
            lower_coding_multipliers,
            lower_layout_coeffs,
        )
        from repro.layout.power import _jitted_coeff_eval, _search_iters

        if not state:
            coeffs = lower_layout_coeffs(
                grid,
                layouts,
                max_envelope_aspect=cfg.max_envelope_aspect,
                repeater_spacing_um=cfg.repeater_spacing_um,
            )
            state["coeffs"] = coeffs
            state["dev"] = coeffs.device()
            state["a_h"] = jax.device_put(a_h)
            state["a_v"] = jax.device_put(a_v)
            state["h_lanes"] = None if h_lanes is None else jax.device_put(h_lanes)
            state["v_lanes"] = None if v_lanes is None else jax.device_put(v_lanes)
            state["w"] = jax.device_put(w)
            state["act_mult"] = (
                lower_coding_multipliers(grid, a_v).device()["act_mult"]
                if bool(np.any(np.asarray(grid.bus_invert)))
                else None
            )
            if objective is not None:
                dv = objective.partition.device()
                rows_f = np.asarray(grid.rows, float)
                state["util"] = dv["utilization"]
                state["spill"] = dv["spill_words_per_mac"]
                state["trunk"] = dv["trunk_words_per_mac"]
                state["rows"] = jax.device_put(rows_f)
                state["rc"] = jax.device_put(rows_f * np.asarray(grid.cols, float))
                state["static"] = jax.device_put(
                    np.asarray(objective.static_w, float)
                )
        coeffs = state["coeffs"]
        nb, nn = _search_iters(gss_iters)
        ctx = (
            jax.default_device(device)
            if device is not None
            else contextlib.nullcontext()
        )
        with ctx:
            ji = jnp.asarray(idx)
            # On-device gather makes FRESH per-chunk buffers, so the jitted
            # core can donate them (XLA reuses the chunk allocations instead
            # of doubling the footprint).
            tens = [jnp.take(state["dev"][k], ji, axis=-1) for k in DEVICE_FIELDS]
            ah = jnp.take(state["a_h"], ji, axis=-1)
            av = jnp.take(state["a_v"], ji, axis=-1)
            hl = (
                None
                if state["h_lanes"] is None
                else jnp.take(state["h_lanes"], ji, axis=1)
            )
            vl = (
                None
                if state["v_lanes"] is None
                else jnp.take(state["v_lanes"], ji, axis=1)
            )
            am = (
                None
                if state["act_mult"] is None
                else jnp.take(state["act_mult"], ji, axis=-1)
            )
            if objective is not None:
                obj_args = (
                    jnp.take(state["util"], ji, axis=-1),
                    jnp.take(state["spill"], ji, axis=-1),
                    jnp.take(state["trunk"], ji, axis=-1),
                    jnp.take(state["rows"], ji, axis=-1),
                    jnp.take(state["rc"], ji, axis=-1),
                    jnp.take(state["static"], ji, axis=-1),
                )
            else:
                obj_args = (None,) * 6
            # Donation is only honored (and only matters) off-CPU; the CPU
            # backend warns and keeps the buffers, so skip it there.
            donate = jax.default_backend() != "cpu"
            fn = _jitted_coeff_eval(coeffs.rep_idx, nb, nn, donate)
            out = fn(
                *tens,
                ah,
                av,
                hl,
                vl,
                state["w"],
                cfg.vdd,
                cfg.freq_hz,
                cfg.wire_cap_f_per_um,
                cfg.repeater_spacing_um,
                cfg.repeater_overhead,
                cfg.preload_duty * cfg.preload_activity,
                cfg.drain_duty * cfg.drain_activity,
                cfg.clock_toggles_per_cycle,
                am,
                *obj_args,
            )
        out = {k: np.asarray(v, float) for k, v in out.items()}
        feasible = coeffs.host["feasible"][:, idx]
        bad = ~feasible
        for key in ("bus_power_robust", "overhead_w", "wirelength_um"):
            out[key] = np.where(bad, np.inf, out[key])
        out["bus_power_opt"] = np.where(bad[None], np.inf, out["bus_power_opt"])
        if objective is not None:
            out["j_per_mac"] = np.where(bad[None], np.inf, out["j_per_mac"])
            out["j_per_mac_robust"] = np.where(
                bad, np.inf, out["j_per_mac_robust"]
            )
            # Pure pass-through input, attached host-side (never leaves f64).
            out["utilization"] = np.ascontiguousarray(
                objective.partition.host["utilization"][..., idx]
            )
        out["feasible"] = feasible
        out["aspect_lo"] = coeffs.host["lo"][:, idx]
        out["aspect_hi"] = coeffs.host["hi"][:, idx]
        return out

    def eval_chunk(rung, index, device=None):
        idx = _chunk_idx(index, cs, n)
        if rung == "jit":
            return run_jit(idx, device)
        if rung == "eager":
            return run(idx, False)
        parts = [run(idx[j : j + 1], False) for j in range(len(idx))]
        return {
            f: np.concatenate([p[f] for p in parts], axis=-1) for f in fields
        }

    return eval_chunk


def _layout_validate_factory(
    grid, a_h, a_v, layouts, h_lanes, v_lanes, w, cfg, spec, oracle_cells,
    oracle_seed, cs, n, objective=None,
):
    fields = _OBJECTIVE_FIELDS if objective is not None else _LAYOUT_FIELDS

    def validate(out, index, rung):
        idx = _chunk_idx(index, cs, n)
        loose = rung in ("jit", "stored")
        eps_a = 1e-5 if loose else 1e-9
        tiny = 1e-30
        v: list[str] = []

        missing = [f for f in fields if f not in out]
        if missing:
            return [f"missing fields {missing}"]
        feas = np.asarray(out["feasible"], bool)
        infeas = ~feas
        for f in ("bus_power_robust", "overhead_w", "wirelength_um"):
            arr = np.asarray(out[f], float)
            if np.isnan(arr).any():
                v.append(f"NaN values in {f}")
                continue
            if infeas.any() and not np.isinf(arr[infeas]).all():
                v.append(f"{f} finite on infeasible cells")
            if feas.any() and not np.isfinite(arr[feas]).all():
                v.append(f"{f} non-finite on feasible cells")
        po = np.asarray(out["bus_power_opt"], float)
        if np.isnan(po).any():
            v.append("NaN values in bus_power_opt")
        else:
            if infeas.any() and not np.isinf(po[:, infeas]).all():
                v.append("bus_power_opt finite on infeasible cells")
            if feas.any() and not np.isfinite(po[:, feas]).all():
                v.append("bus_power_opt non-finite on feasible cells")
        for f in ("aspect_lo", "aspect_hi", "aspect_opt", "aspect_robust"):
            if not np.isfinite(np.asarray(out[f], float)).all():
                v.append(f"non-finite values in {f}")
        if v:
            return v

        alo = np.asarray(out["aspect_lo"], float)
        ahi = np.asarray(out["aspect_hi"], float)
        ao = np.asarray(out["aspect_opt"], float)
        ar = np.asarray(out["aspect_robust"], float)
        bad = feas[None] & ((ao < alo[None] * (1 - eps_a)) | (ao > ahi[None] * (1 + eps_a)))
        if bad.any():
            v.append("aspect_opt outside the per-cell aspect window")
        bad = feas & ((ar < alo * (1 - eps_a)) | (ar > ahi * (1 + eps_a)))
        if bad.any():
            v.append("aspect_robust outside the per-cell aspect window")

        pr = np.asarray(out["bus_power_robust"], float)
        ov = np.asarray(out["overhead_w"], float)
        wl = np.asarray(out["wirelength_um"], float)
        active = (w[:, None] * (a_h[:, idx] + a_v[:, idx])).sum(0) > 1e-9  # (P,)
        if (pr[feas] < -tiny).any():
            v.append("negative power in bus_power_robust")
        elif (feas & active[None] & (pr <= 0)).any():
            v.append("zero bus_power_robust on cells with switching activity")
        if (ov[feas] < -tiny).any():
            v.append("negative overhead power")
        if (wl[feas] <= 0).any():
            v.append("non-positive wirelength on feasible cells")

        # J/op contracts (objective mode): utilization is a pure pass-through
        # of the lowered partition arrays (bit-exact), and j_per_mac must be
        # finite and positive exactly on live cells — a NaN anywhere in the
        # objective fields is a poisoned/miscomputed chunk.
        if objective is not None:
            util = np.asarray(out["utilization"], float)
            jpm = np.asarray(out["j_per_mac"], float)
            jpr = np.asarray(out["j_per_mac_robust"], float)
            if np.isnan(util).any():
                v.append("NaN values in utilization")
            elif (util < -tiny).any() or (util > 1.0 + 1e-6).any():
                v.append("utilization outside [0, 1]")
            elif not np.array_equal(
                util, objective.partition.host["utilization"][..., idx]
            ):
                v.append(
                    "utilization differs from the lowered partition arrays"
                )
            if np.isnan(jpm).any():
                v.append("NaN values in j_per_mac")
            else:
                dead = (~feas[None]) | (util <= 0.0)
                if dead.any() and not np.isinf(jpm[dead]).all():
                    v.append("j_per_mac finite on infeasible/zero-MAC cells")
                live = ~dead
                if live.any():
                    if not np.isfinite(jpm[live]).all():
                        v.append("j_per_mac non-finite on live cells")
                    elif (jpm[live] <= 0).any():
                        v.append("non-positive j_per_mac on live cells")
            if np.isnan(jpr).any():
                v.append("NaN values in j_per_mac_robust")
            else:
                if infeas.any() and not np.isinf(jpr[infeas]).all():
                    v.append("j_per_mac_robust finite on infeasible cells")
                if feas.any():
                    if not np.isfinite(jpr[feas]).all():
                        v.append("j_per_mac_robust non-finite on feasible cells")
                    elif (jpr[feas] < -tiny).any():
                        v.append("negative j_per_mac_robust")

        # Cross-engine: seeded feasible cells re-priced through the explicit
        # per-segment enumeration (``segment_bus_power``) — the segment
        # engine's own scalar oracle.  On bus-invert points the engine's
        # coding multipliers scale every v-class activity by coded/raw, which
        # is exactly pricing the segments at the coded activity — so the
        # oracle codes its scalar a_v through the same closed form.
        if oracle_cells > 0:
            from repro.core.floorplan import BusActivity
            from repro.core.optimize import bus_invert_activity
            from repro.layout.geometry import get_layout
            from repro.layout.power import segment_bus_power

            rtol = 5e-3 if loose else 1e-5
            cells = np.argwhere(feas)
            if len(cells):
                n_w = a_h.shape[0]
                bi = np.asarray(grid.bus_invert, bool)
                b_v_data = np.asarray(grid.b_v_data, np.int64)
                for t in range(oracle_cells):
                    h = hashlib.sha256(
                        spec + f"|loracle|{oracle_seed}|{index}|{t}".encode()
                    ).digest()
                    li, j = cells[int.from_bytes(h[:4], "big") % len(cells)]
                    wi = int.from_bytes(h[4:8], "big") % n_w
                    li, j, pj = int(li), int(j), int(idx[int(j)])
                    asp = float(ao[wi, li, j])
                    av_s = float(a_v[wi, pj])
                    if bi[pj]:
                        av_s = bus_invert_activity(av_s, int(b_v_data[pj]))
                    ref = segment_bus_power(
                        get_layout(layouts[li]),
                        grid.geometry(pj),
                        BusActivity(float(a_h[wi, pj]), av_s),
                        asp,
                        dataflow="OS" if grid.dataflow_os[pj] else "WS",
                        h_lanes=None if h_lanes is None else h_lanes[wi, pj],
                        v_lanes=None if v_lanes is None else v_lanes[wi, pj],
                        cfg=cfg,
                    )
                    got = float(po[wi, li, j])
                    if abs(got - ref) > rtol * max(ref, tiny):
                        v.append(
                            f"cross-engine:bus_power_opt[{wi},{li},{pj}] vs "
                            "segment enumeration"
                        )

        # Coefficient-protocol parity: the OVERHEAD side of the schema
        # (preload/drain/clk priced once at the robust aspect) re-priced
        # through the explicit enumeration — the loracle guard above covers
        # the data nets, this one covers everything else the coefficient
        # path folds.
        if oracle_cells > 0:
            from repro.layout.geometry import get_layout
            from repro.layout.power import rollup_segments
            from repro.layout.segments import enumerate_segments

            rtol = 5e-3 if loose else 1e-5
            cells = np.argwhere(feas)
            if len(cells):
                for t in range(oracle_cells):
                    h = hashlib.sha256(
                        spec + f"|coparity|{oracle_seed}|{index}|{t}".encode()
                    ).digest()
                    li, j = cells[int.from_bytes(h[:4], "big") % len(cells)]
                    li, j = int(li), int(j)
                    pj = int(idx[j])
                    geom = grid.geometry(pj)
                    segs = enumerate_segments(
                        get_layout(layouts[li]),
                        geom.rows,
                        geom.cols,
                        geom.b_h,
                        geom.b_v,
                        geom.pe_area_um2,
                        float(ar[li, j]),
                        dataflow="OS" if grid.dataflow_os[pj] else "WS",
                        nets=("preload", "drain", "clk"),
                    )
                    ref = rollup_segments(segs, 0.0, 0.0, cfg=cfg)["overhead_w"]
                    got = float(ov[li, j])
                    if abs(got - ref) > rtol * max(ref, tiny):
                        v.append(
                            f"coeff-parity:overhead_w[{li},{pj}] vs segment "
                            "enumeration"
                        )
        return v

    return validate


# ---------------------------------------------------------------------------
# The chunked runner
# ---------------------------------------------------------------------------


def _resolve_store(sweep: SweepConfig) -> ContentStore | None:
    if sweep.store is None:
        return None
    if isinstance(sweep.store, ContentStore):
        return sweep.store
    return ContentStore(
        sweep.store, version=SWEEP_STORE_VERSION, corrupt_site="chunk-store-read"
    )


def _local_devices() -> list:
    try:
        import jax

        return list(jax.local_devices())
    except Exception:
        return [None]


def _poisoned(out: dict, rung: str, index: int) -> dict:
    """Expose every result field to the NaN/Inf fault hook — the injected
    corruption is indistinguishable from a silent miscompute, so only the
    guards can catch it."""
    inj = faults.active()
    if inj is None:
        return out
    return {
        k: inj.maybe_poison(v, f"sweep-result:{rung}:{k}", f"chunk{index}")
        for k, v in out.items()
    }


def _guard_error(violations, *, job, stage):
    cls = (
        CrossEngineMismatchError
        if any(s.startswith("cross-engine") for s in violations)
        else GuardViolationError
    )
    return cls(
        "; ".join(violations), violations=violations, job=job, stage=stage
    )


def _run_chunked(
    kind, n, sweep, *, start_rung, spec, eval_chunk, validate_chunk, fields
):
    cs = int(sweep.chunk_size)
    chunks_total = -(-n // cs)
    report = SweepReport(
        kind=kind, n_points=n, chunk_size=cs, chunks_total=chunks_total
    )
    store = _resolve_store(sweep)
    policy = sweep.retry if sweep.retry is not None else _DEFAULT_RETRY
    timeout_s = sweep.timeout_s
    if timeout_s is None:
        env = os.environ.get("REPRO_SWEEP_TIMEOUT_S", "").strip()
        timeout_s = float(env) if env else None

    # -- phase 0: resume — serve completed chunks from the store ------------
    results: dict[int, dict] = {}
    to_compute: list[int] = []
    if store is not None and sweep.resume:
        for i in range(chunks_total):
            payload = store.get_payload(_chunk_key(spec, i))
            if payload is None:
                to_compute.append(i)
                continue
            try:
                out, rung = _decode_chunk(payload, kind, i, fields)
            except Exception as exc:
                # sha-valid but schema-invalid (drift inside the version):
                # same semantics as corruption — recompute and overwrite.
                report.failures.add(
                    CacheCorruptionError(
                        f"stored chunk {i} failed decode: {exc}",
                        job=f"chunk{i}",
                        stage="sweep-resume",
                    ),
                    action="quarantined:recomputed",
                )
                report.chunks_quarantined += 1
                to_compute.append(i)
                continue
            if sweep.validate:
                report.guard_checks += 1
                viols = validate_chunk(out, i, "stored")
                if viols:
                    report.guard_failures += 1
                    report.failures.add(
                        _guard_error(viols, job=f"chunk{i}", stage="sweep-resume"),
                        action="quarantined:recomputed",
                    )
                    report.chunks_quarantined += 1
                    to_compute.append(i)
                    continue
            results[i] = out
            report.chunks_resumed += 1
            report.records.append(
                ChunkRecord(
                    i,
                    _chunk_points(i, cs, n),
                    "resumed",
                    rung,
                    "pass" if sweep.validate else "skipped",
                )
            )
        # Entries the store itself quarantined (sha mismatch on read) — the
        # get returned None, so their chunks are already queued to recompute.
        for key_hex in store.drain_quarantine_events():
            report.chunks_quarantined += 1
            report.failures.add(
                CacheCorruptionError(
                    f"chunk entry {key_hex} failed verification; quarantined",
                    stage="sweep-resume",
                ),
                action="quarantined:recomputed",
            )
    else:
        to_compute = list(range(chunks_total))

    # -- phase 1: bound this call's work (the kill-and-resume harness) ------
    interrupted = sweep.max_chunks is not None and len(to_compute) > sweep.max_chunks
    pending_after = 0
    if interrupted:
        pending_after = len(to_compute) - int(sweep.max_chunks)
        to_compute = to_compute[: int(sweep.max_chunks)]

    # -- phase 2: fresh jit chunks, sharded across local devices ------------
    jit_out: dict[int, tuple[dict, int]] = {}
    jit_err: dict[int, ProfileError] = {}
    if start_rung == "jit" and to_compute:
        devices = (
            list(sweep.devices) if sweep.devices is not None else _local_devices()
        )
        health = (
            sweep.health
            if sweep.health is not None
            else HealthMonitor(range(len(devices)))
        )

        def run_on(index, di):
            inj = faults.active()

            def attempt():
                if inj is not None:
                    inj.maybe_fail_backend("sweep-chunk:jit", f"chunk{index}")
                    inj.maybe_hang(f"sweep-chunk:d{di}", f"chunk{index}")
                    inj.maybe_lose_device(f"sweep-chunk:d{di}", f"chunk{index}")
                return _poisoned(
                    eval_chunk("jit", index, devices[di]), "jit", index
                )

            # Only compile-class failures retry here: dispatch-class ones
            # (timeout, device loss) belong to the eviction layer below.
            res, attempts, last = call_with_retry(
                attempt,
                policy=policy,
                key=f"{kind}:chunk{index}:jit",
                retry_on=(BackendCompileError,),
            )
            if last is not None:
                report.failures.add(
                    last,
                    action="retried",
                    job=f"chunk{index}",
                    stage="sweep-jit",
                    attempts=attempts,
                )
            return res, attempts

        alive = health.alive_hosts() or [0]
        if timeout_s is not None or len(devices) > 1:
            with ThreadPoolExecutor(max_workers=max(2, len(devices))) as ex:
                subs = [
                    (i, alive[k % len(alive)], None) for k, i in enumerate(to_compute)
                ]
                subs = [
                    (i, di, ex.submit(run_on, i, di)) for i, di, _ in subs
                ]
                for i, di, fut in subs:
                    t0 = time.monotonic()
                    try:
                        jit_out[i] = fut.result(timeout=timeout_s)
                        health.heartbeat(di, time.monotonic())
                        health.report_step_time(di, time.monotonic() - t0)
                        continue
                    except faults.InjectedAbortError:
                        raise
                    except Exception as exc:
                        err = classify_exception(
                            exc, job=f"chunk{i}", stage="sweep-dispatch"
                        )
                    if isinstance(err, DeviceDispatchError):
                        # PR 6 semantics: evict the device, resubmit the
                        # chunk EXACTLY ONCE to a surviving device.
                        health.evict(di)
                        survivors = health.alive_hosts()
                        if survivors:
                            report.resubmits += 1
                            report.failures.add(
                                err,
                                action="device-evicted:resubmitted",
                                job=f"chunk{i}",
                                stage="sweep-dispatch",
                            )
                            try:
                                jit_out[i] = ex.submit(
                                    run_on, i, survivors[0]
                                ).result(timeout=timeout_s)
                                health.heartbeat(survivors[0], time.monotonic())
                                continue
                            except faults.InjectedAbortError:
                                raise
                            except Exception as exc2:
                                err = classify_exception(
                                    exc2, job=f"chunk{i}", stage="sweep-dispatch"
                                )
                    jit_err[i] = err
        else:
            for i in to_compute:
                try:
                    jit_out[i] = run_on(i, 0)
                except faults.InjectedAbortError:
                    raise
                except Exception as exc:
                    jit_err[i] = classify_exception(
                        exc, job=f"chunk{i}", stage="sweep-jit"
                    )

    # -- phase 3: validate, degrade down the ladder, commit -----------------
    ladder = evaluation_ladder(start_rung)
    for i in to_compute:
        out = None
        used = None
        attempts = 1
        last_err: ProfileError | None = None
        for ri, rung in enumerate(ladder):
            nxt = ladder[ri + 1] if ri + 1 < len(ladder) else None
            if rung == "jit":
                if i in jit_out:
                    cand, attempts = jit_out[i]
                else:
                    last_err = jit_err.get(i) or EvaluationError(
                        "jit chunk evaluation unavailable",
                        job=f"chunk{i}",
                        stage="sweep-jit",
                    )
                    report.failures.add(
                        last_err, action=f"degraded:{nxt}", job=f"chunk{i}"
                    )
                    continue
            else:
                inj = faults.active()

                def attempt(rung=rung, index=i, inj=inj):
                    if inj is not None:
                        inj.maybe_fail_backend(
                            f"sweep-chunk:{rung}", f"chunk{index}"
                        )
                    return _poisoned(eval_chunk(rung, index), rung, index)

                try:
                    cand, attempts, last = call_with_retry(
                        attempt,
                        policy=policy,
                        key=f"{kind}:chunk{i}:{rung}",
                        retry_on=(BackendCompileError, DeviceDispatchError),
                    )
                    if last is not None:
                        report.failures.add(
                            last,
                            action="retried",
                            job=f"chunk{i}",
                            stage=f"sweep-{rung}",
                            attempts=attempts,
                        )
                except faults.InjectedAbortError:
                    raise
                except Exception as exc:
                    last_err = classify_exception(
                        exc, job=f"chunk{i}", stage=f"sweep-{rung}"
                    )
                    if nxt is None:
                        report.failures.add(last_err, action="raised")
                        raise last_err from exc
                    report.failures.add(last_err, action=f"degraded:{nxt}")
                    continue
            if sweep.validate:
                report.guard_checks += 1
                viols = validate_chunk(cand, i, rung)
                if viols:
                    report.guard_failures += 1
                    err = _guard_error(viols, job=f"chunk{i}", stage=f"sweep-{rung}")
                    last_err = err
                    if sweep.on_violation == "raise" or nxt is None:
                        report.failures.add(err, action="raised")
                        raise err
                    report.failures.add(err, action=f"degraded:{nxt}")
                    continue
            out, used = cand, rung
            break
        if out is None:  # pragma: no cover - every exit above raises
            raise last_err
        # Commit BEFORE the abort hook: an injected mid-sweep abort lands at
        # the chunk boundary, so exactly the committed chunks survive —
        # the resume path's contract.
        if store is not None:
            store.put_payload(_chunk_key(spec, i), _encode_chunk(kind, i, used, out))
        inj = faults.active()
        if inj is not None:
            inj.maybe_abort("sweep-commit", f"chunk{i}")
        results[i] = out
        report.chunks_evaluated += 1
        report.records.append(
            ChunkRecord(
                i,
                _chunk_points(i, cs, n),
                "evaluated",
                used,
                "pass" if sweep.validate else "skipped",
                attempts,
            )
        )

    if interrupted:
        raise SweepInterrupted(
            f"sweep stopped after {len(to_compute)} chunks (max_chunks="
            f"{sweep.max_chunks}); {pending_after} chunks remain — rerun with "
            "the same store to resume",
            report=report,
            stage="sweep",
        )

    # -- phase 4: assemble — concatenate chunks, trim the clamp padding -----
    assembled = {
        f: np.ascontiguousarray(
            np.concatenate(
                [np.asarray(results[i][f]) for i in range(chunks_total)], axis=-1
            )[..., :n]
        )
        for f in fields
    }
    return assembled, report


# ---------------------------------------------------------------------------
# Public entry points (called by the engines when ``sweep=`` is passed)
# ---------------------------------------------------------------------------


def run_design_sweep(grid, a_h, a_v, weights, *, cfg, gss_iters, use_jit, sweep):
    """Chunked, validated, resumable ``evaluate_design_space`` execution.

    Inputs arrive pre-normalized from the engine (activities broadcast to
    (W, P), weights normalized, ``use_jit`` resolved); returns
    ``(fields, SweepReport)`` where ``fields`` carries exactly the
    ``DesignSpaceEval`` arrays.
    """
    n = grid.n_points
    if n == 0:
        raise ContractViolationError("cannot sweep an empty design grid")
    start_rung = "jit" if use_jit else "eager"
    # Coding lowered ONCE over the full grid (exact host float64) — chunks
    # slice the effective activities, so the coding flag never reaches the
    # jitted program and cannot change semantics between chunks.
    from repro.core.design_space import _effective_a_v

    a_v_eff = _effective_a_v(grid, a_v)
    cs = int(sweep.chunk_size)
    spec = _spec_key(
        "design",
        grid,
        a_h,
        a_v,
        weights,
        extra=[
            ("cfg", repr(dataclasses.astuple(cfg))),
            ("gss_iters", int(gss_iters)),
            ("chunk_size", cs),
            ("start_rung", start_rung),
        ],
    )
    return _run_chunked(
        "design",
        n,
        sweep,
        start_rung=start_rung,
        spec=spec,
        eval_chunk=_design_eval_factory(
            grid, a_h, a_v_eff, weights, cfg, gss_iters, cs, n
        ),
        validate_chunk=_design_validate_factory(
            grid, a_h, a_v, weights, cfg, spec, int(sweep.oracle_cells),
            int(sweep.seed), cs, n,
        ),
        fields=_DESIGN_FIELDS,
    )


def run_layout_sweep(
    grid,
    a_h,
    a_v,
    weights,
    *,
    layouts,
    h_lanes,
    v_lanes,
    cfg,
    gss_iters,
    use_jit,
    sweep,
    objective=None,
):
    """Chunked, validated, resumable ``evaluate_layout_space`` execution.

    Returns ``(fields, SweepReport)`` with the ``LayoutSpaceEval`` arrays
    (including ``feasible`` and the per-cell aspect window).  With an
    ``ObjectiveSpec`` (``objective=``), chunks carry the fused J/op fields
    too, keyed as a distinct ``"objective"`` sweep kind — the spec digest
    additionally covers the lowered partition arrays (their content key)
    and the calibrated static power, so J/op chunks never alias wire-power
    chunks over the same grid.
    """
    n = grid.n_points
    if n == 0:
        raise ContractViolationError("cannot sweep an empty design grid")
    start_rung = "jit" if use_jit else "eager"
    cs = int(sweep.chunk_size)
    layouts = tuple(layouts)
    kind = "layout" if objective is None else "objective"
    fields = _LAYOUT_FIELDS if objective is None else _OBJECTIVE_FIELDS
    extra = [
        ("layouts", ",".join(layouts)),
        (
            "h_lanes",
            b"none" if h_lanes is None else np.asarray(h_lanes, np.float64).tobytes(),
        ),
        (
            "v_lanes",
            b"none" if v_lanes is None else np.asarray(v_lanes, np.float64).tobytes(),
        ),
        ("cfg", repr(dataclasses.astuple(cfg))),
        ("gss_iters", int(gss_iters)),
        ("chunk_size", cs),
        ("start_rung", start_rung),
    ]
    if objective is not None:
        part = objective.partition
        part_key = part.key
        if part_key is None:  # a sliced/ad-hoc entry: key over content
            part_key = hashlib.sha256(
                b"".join(
                    np.ascontiguousarray(part.host[k]).tobytes()
                    for k in sorted(part.host)
                )
            ).hexdigest()
        extra += [
            ("partition", str(part_key)),
            ("static_w", np.asarray(objective.static_w, np.float64).tobytes()),
        ]
    spec = _spec_key(kind, grid, a_h, a_v, weights, extra=extra)
    return _run_chunked(
        kind,
        n,
        sweep,
        start_rung=start_rung,
        spec=spec,
        eval_chunk=_layout_eval_factory(
            grid, a_h, a_v, layouts, h_lanes, v_lanes, weights, cfg, gss_iters,
            cs, n, objective=objective,
        ),
        validate_chunk=_layout_validate_factory(
            grid, a_h, a_v, layouts, h_lanes, v_lanes, weights, cfg, spec,
            int(sweep.oracle_cells), int(sweep.seed), cs, n,
            objective=objective,
        ),
        fields=fields,
    )
