"""Symmetric integer quantization (the paper evaluates 16-bit-int inference)."""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["QuantizedTensor", "quantize_symmetric", "dequantize"]


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    values: np.ndarray  # int64 container, representable in `bits` bits
    scale: float
    bits: int

    def dequantize(self) -> np.ndarray:
        return dequantize(self)


def quantize_symmetric(x: np.ndarray, bits: int) -> QuantizedTensor:
    """Symmetric per-tensor quantization to signed ``bits``-bit integers."""
    if not 2 <= bits <= 32:
        raise ValueError("bits must be in [2, 32]")
    x = np.asarray(x, dtype=np.float64)
    qmax = float(2 ** (bits - 1) - 1)
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    scale = amax / qmax if amax > 0 else 1.0
    q = np.clip(np.rint(x / scale), -qmax, qmax).astype(np.int64)
    return QuantizedTensor(values=q, scale=scale, bits=bits)


def dequantize(q: QuantizedTensor) -> np.ndarray:
    return q.values.astype(np.float64) * q.scale
