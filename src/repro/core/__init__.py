"""Core: the paper's contribution — asymmetric SA floorplanning + energy model."""

from repro.core.floorplan import (  # noqa: F401
    BusActivity,
    SystolicArrayGeometry,
    accumulator_width,
    bus_power,
    bus_power_ratio_vs_square,
    numeric_optimal_aspect,
    optimal_aspect_power,
    optimal_aspect_wirelength,
    wirelength_total,
)
from repro.core.energy import (  # noqa: F401
    EnergyModelConfig,
    compare_sym_asym,
    power_breakdown,
)
from repro.core.design_space import (  # noqa: F401
    DesignGrid,
    DesignSpace,
    DesignSpaceEval,
    evaluate_design_space,
    evaluate_layout_design_space,
    pareto_mask,
    sweep_bus_power,
)
from repro.core.switching import (  # noqa: F401
    ActivityProfile,
    clear_profile_cache,
    combine_profiles,
    profile_cache_info,
    profile_gemm,
    profile_gemms,
    profile_tile,
    profile_ws_gemm,
    stream_toggle_rate,
)
from repro.core.systolic import (  # noqa: F401
    DATAFLOWS,
    Dataflow,
    matmul_reference,
    os_matmul_reference,
    schedule_gemm,
    ws_matmul_reference,
)
