"""Declarative design-space exploration over the asymmetric-floorplan model.

The paper's headline claim is a *design-space* statement: the optimal
floorplan aspect depends jointly on geometry (R, C, B_h, B_v), dataflow,
coding, and measured switching activity.  This module turns the array-first
analytical core (``repro.core.floorplan`` / ``energy`` / ``optimize``) into
an exploration engine:

  * ``DesignSpace`` — a declarative spec: grids over rows/cols, input bit
    widths, dataflow (WS/OS), bus-invert coding on/off, PE area, plus the
    practical aspect envelope.  ``expand()`` materializes the cross product
    as a ``DesignGrid`` — a struct-of-arrays with one flat point axis P.
  * ``evaluate_design_space`` — evaluates the whole grid against a workload
    axis of activities (shape (W, P)) in ONE program (jitted under jax,
    plain float64 numpy otherwise): envelope-clamped Eq. 6 optima per
    (workload, point), a batched log-space golden-section cross-check of
    those optima, vectorized minimax-regret robust aspects across the
    workload axis, workload-aggregated bus power and calibrated
    interconnect/total savings per point.
  * ``sweep_bus_power`` — the (P, S) bus-power surface over an aspect axis
    (the Fig. 2/3 analog, for every design point at once).
  * ``pareto_mask`` / ``DesignSpaceEval.pareto`` — non-dominated design
    extraction over (bus power, area, worst-case regret) or any objective
    subset.

Broadcasting contract
---------------------
Point axis P is always last; the workload axis W (when present) leads.
Per-point fields are (P,), per-(workload, point) values are (W, P), and the
aspect-sweep surface is (P, S).  Activities may be passed as scalars, (P,)
or (W, P) — they are broadcast to (W, P).

Measured activities come from ``repro.core.workloads.measured_design_activities``,
which profiles one *activity class* per workload layer through
``repro.core.pipeline.run_profile_batch`` — (rows, b_h, b_v) classes for WS
points, geometry-free (b_h, b_v) classes for OS points — and broadcasts the
result across the cols/area/coding axes (toggle activities are column-count
invariant under the WS stream model and fully geometry-invariant under OS),
so a handful of profiling passes feeds arbitrarily many geometry points.
OS vertical activities are MEASURED from the W-operand column streams; the
old ``a_v := a_h`` approximation is retired.

Jit boundaries: ``evaluate_design_space`` and ``sweep_bus_power`` each
compile to a single program (cached per golden-section iteration count);
grid expansion, activity mapping and Pareto extraction are host-side numpy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from repro.core.energy import EnergyModelConfig, calibration_split_arr
from repro.core.floorplan import (
    ASPECT_MAX,
    ASPECT_MIN,
    SystolicArrayGeometry,
    _xp,
    bus_power_arr,
    golden_section_minimize_arr,
    optimal_aspect_power_arr,
)
from repro.core.optimize import _power_shape

try:  # jax accelerates the engine; the same code runs in float64 numpy without it
    import jax

    _HAS_JAX = True
except ImportError:  # pragma: no cover - jax baked into the image
    _HAS_JAX = False

__all__ = [
    "DesignSpace",
    "DesignGrid",
    "DesignSpaceEval",
    "evaluate_design_space",
    "evaluate_layout_design_space",
    "sweep_bus_power",
    "pareto_mask",
]

_DATAFLOWS = ("WS", "OS")


def _as_tuple(x, kind=None) -> tuple:
    if isinstance(x, (str, bytes)) or not isinstance(x, Sequence):
        x = (x,)
    x = tuple(x)
    if kind is not None:
        x = tuple(kind(v) for v in x)
    return x


def _ceil_log2(r: np.ndarray) -> np.ndarray:
    """Elementwise ceil(log2(r)) for positive ints, exact at powers of two
    (evaluated at r - 0.5 so float rounding cannot cross the integer)."""
    return np.maximum(np.ceil(np.log2(r - 0.5)).astype(np.int64), 0)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Declarative spec of a floorplan design space (grids per axis).

    Axes (each a sequence; scalars auto-promote to length-1 tuples):
      rows / cols      PE grid dimensions.
      input_bits       operand quantization width (= B_h).
      dataflows        "WS" (B_v = accumulator width) and/or "OS"
                       (B_v = input_bits; partial sums never move).
      bus_invert       whether the vertical bus is BI-coded (B_v += 1 invert
                       line, a_v -> coded activity at evaluation time).
      pe_area_um2      per-PE area.
      layouts          physical layout families to pair every geometry point
                       with: registered names (``repro.layout.LAYOUTS``) or
                       parametric spellings — ``"pods{k}x{k}"`` promotes pod
                       count k to a free integer axis (``pod_layouts``),
                       ``"serpentine{f}"`` the fold count.  The layout axis
                       is evaluated by the
                       segment-level engine (``evaluate_layout_design_space``
                       / ``repro.layout.power.evaluate_layout_space``), NOT
                       flattened into the point axis: the closed-form
                       ``evaluate_design_space`` only describes the uniform
                       family.
    ``aspect_lo``/``aspect_hi`` bound the practical aspect envelope shared by
    every optimization in the evaluation.
    """

    rows: Sequence[int]
    cols: Sequence[int]
    input_bits: Sequence[int] = (16,)
    dataflows: Sequence[str] = ("WS",)
    bus_invert: Sequence[bool] = (False,)
    pe_area_um2: Sequence[float] = (1200.0,)
    aspect_lo: float = ASPECT_MIN
    aspect_hi: float = ASPECT_MAX
    layouts: Sequence[str] = ("uniform",)

    def __post_init__(self) -> None:
        object.__setattr__(self, "rows", _as_tuple(self.rows, int))
        object.__setattr__(self, "cols", _as_tuple(self.cols, int))
        object.__setattr__(self, "input_bits", _as_tuple(self.input_bits, int))
        object.__setattr__(self, "dataflows", _as_tuple(self.dataflows, str))
        object.__setattr__(self, "bus_invert", _as_tuple(self.bus_invert, bool))
        object.__setattr__(self, "pe_area_um2", _as_tuple(self.pe_area_um2, float))
        object.__setattr__(self, "layouts", _as_tuple(self.layouts, str))
        if not self.layouts:
            raise ValueError("layouts axis must be non-empty")
        # Names resolve through get_layout so PARAMETRIC spellings —
        # "pods{k}x{k}" (the free pod-count axis), "serpentine{f}" — are
        # first-class axis values, not just registry entries.
        from repro.layout.geometry import LAYOUTS as _REGISTRY
        from repro.layout.geometry import get_layout as _get_layout

        unknown = []
        for n in self.layouts:
            try:
                _get_layout(n)
            except (KeyError, ValueError):
                unknown.append(n)
        if unknown:
            raise ValueError(
                f"unknown layout families {unknown}; registered: {sorted(_REGISTRY)}, "
                "parametric: 'pods{k}x{k}', 'serpentine{f}'"
            )
        for name in ("rows", "cols", "input_bits"):
            vals = getattr(self, name)
            if not vals or any(v < 1 for v in vals):
                raise ValueError(f"{name} must be non-empty positive ints")
        if not self.dataflows or any(d not in _DATAFLOWS for d in self.dataflows):
            raise ValueError(f"dataflows must be drawn from {_DATAFLOWS}")
        if not self.pe_area_um2 or any(a <= 0 for a in self.pe_area_um2):
            raise ValueError("pe_area_um2 must be non-empty positive")
        if not self.bus_invert:
            raise ValueError("bus_invert axis must be non-empty")
        if not (0 < self.aspect_lo < self.aspect_hi):
            raise ValueError("need 0 < aspect_lo < aspect_hi")
        widest = 0
        if "WS" in self.dataflows:
            widest = 2 * max(self.input_bits) + int(
                _ceil_log2(np.asarray([max(self.rows)]))[0]
            )
        if "OS" in self.dataflows:
            widest = max(widest, max(self.input_bits))
        if widest + (1 if any(self.bus_invert) else 0) > 64:
            raise ValueError("accumulator (+BI) bus width exceeds the 64-bit toggle model")

    @property
    def n_points(self) -> int:
        return (
            len(self.rows)
            * len(self.cols)
            * len(self.input_bits)
            * len(self.dataflows)
            * len(self.bus_invert)
            * len(self.pe_area_um2)
        )

    def expand(self) -> "DesignGrid":
        """Materialize the cross product as a struct-of-arrays grid.

        Axis nesting is C-order with rows slowest and pe_area fastest:
        (rows, cols, input_bits, dataflows, bus_invert, pe_area_um2).
        """
        df_os = np.asarray([d == "OS" for d in self.dataflows])
        mesh = np.meshgrid(
            np.asarray(self.rows, np.int64),
            np.asarray(self.cols, np.int64),
            np.asarray(self.input_bits, np.int64),
            df_os,
            np.asarray(self.bus_invert, bool),
            np.asarray(self.pe_area_um2, float),
            indexing="ij",
        )
        rows, cols, bits, os_mask, bi, area = (m.ravel() for m in mesh)
        acc = 2 * bits + _ceil_log2(rows)
        b_v_data = np.where(os_mask, bits, acc)
        return DesignGrid(
            rows=rows,
            cols=cols,
            b_h=bits,
            b_v=b_v_data + bi.astype(np.int64),
            b_v_data=b_v_data,
            bus_invert=bi,
            dataflow_os=os_mask,
            pe_area_um2=area,
            aspect_lo=self.aspect_lo,
            aspect_hi=self.aspect_hi,
        )


@dataclasses.dataclass(frozen=True)
class DesignGrid:
    """Struct-of-arrays design grid: every field is a flat (P,) array.

    ``b_v`` is the physical vertical bus width (including the bus-invert
    line when coded); ``b_v_data`` is the data width the BI activity
    transform applies to.
    """

    rows: np.ndarray
    cols: np.ndarray
    b_h: np.ndarray
    b_v: np.ndarray
    b_v_data: np.ndarray
    bus_invert: np.ndarray
    dataflow_os: np.ndarray
    pe_area_um2: np.ndarray
    aspect_lo: float = ASPECT_MIN
    aspect_hi: float = ASPECT_MAX

    @property
    def n_points(self) -> int:
        return int(np.asarray(self.rows).shape[0])

    def geometry(self, i: int) -> SystolicArrayGeometry:
        """Scalar-API geometry of point ``i`` (for cross-checks/reporting)."""
        return SystolicArrayGeometry(
            rows=int(self.rows[i]),
            cols=int(self.cols[i]),
            b_h=int(self.b_h[i]),
            b_v=int(self.b_v[i]),
            pe_area_um2=float(self.pe_area_um2[i]),
        )

    def select(self, idx) -> "DesignGrid":
        """Sub-grid at the given indices/mask (e.g. a Pareto frontier)."""
        return DesignGrid(
            rows=self.rows[idx],
            cols=self.cols[idx],
            b_h=self.b_h[idx],
            b_v=self.b_v[idx],
            b_v_data=self.b_v_data[idx],
            bus_invert=self.bus_invert[idx],
            dataflow_os=self.dataflow_os[idx],
            pe_area_um2=self.pe_area_um2[idx],
            aspect_lo=self.aspect_lo,
            aspect_hi=self.aspect_hi,
        )

    def describe(self, i: int) -> str:
        return (
            f"{int(self.rows[i])}x{int(self.cols[i])} b{int(self.b_h[i])}"
            f"{'/OS' if self.dataflow_os[i] else ''}{'/BI' if self.bus_invert[i] else ''}"
            f" Bv={int(self.b_v[i])}"
        )


# ---------------------------------------------------------------------------
# Evaluation engine
# ---------------------------------------------------------------------------


def _effective_a_v(grid, a_v):
    """Host-side coded vertical activity (see ``layout.coeffs``).

    Coding is lowered BEFORE the jitted program: the exact float64
    bus-invert closed form runs once on the host (``grid_coding_effective``
    — the same transform the layout/objective engines consume as activity
    multipliers), so the coding flag is no longer special-cased inside the
    evaluators.
    """
    from repro.layout.coeffs import grid_coding_effective

    return grid_coding_effective(grid, a_v)


def _evaluate_core(
    rows,
    cols,
    b_h,
    b_v,
    pe_area,
    a_h,
    a_v_eff,  # CODED vertical activity (host-lowered, see _effective_a_v)
    weights,
    lo,
    hi,
    vdd,
    freq_hz,
    wire_cap,
    f_nb,
    share,
    *,
    gss_iters: int,
):
    xp = _xp(rows, a_h)
    a_v_eff = a_v_eff + 0.0

    # Per-(workload, point) envelope-clamped Eq. 6 optimum + its numeric
    # (batched log-space golden-section) cross-check.
    aspect_opt = optimal_aspect_power_arr(b_h, b_v, a_h, a_v_eff, lo=lo, hi=hi, xp=xp)
    log_lo = xp.log(lo + 0.0 * a_h)
    log_hi = xp.log(hi + 0.0 * a_h)
    aspect_opt_gss = xp.exp(
        golden_section_minimize_arr(
            lambda log_r: _power_shape(b_h, b_v, a_h, a_v_eff, xp.exp(log_r), xp),
            log_lo,
            log_hi,
            iters=gss_iters,
            xp=xp,
        )
    )

    pw = functools.partial(
        bus_power_arr,
        rows,
        cols,
        b_h,
        b_v,
        pe_area,
        a_h,
        a_v_eff,
        vdd=vdd,
        freq_hz=freq_hz,
        wire_cap_f_per_um=wire_cap,
        xp=xp,
    )
    p_opt = pw(aspect=aspect_opt)
    p_square = pw(aspect=1.0)

    # Robust (minimax-regret) aspect per point, vectorized across the
    # workload axis: regret reuses the per-workload optimum power shapes.
    shape_own = _power_shape(b_h, b_v, a_h, a_v_eff, aspect_opt, xp)
    safe_own = xp.where(shape_own > 0, shape_own, 1.0)

    def worst_regret(log_a):
        p = _power_shape(b_h, b_v, a_h, a_v_eff, xp.exp(log_a)[None, ...], xp)
        return xp.max(xp.where(shape_own > 0, p / safe_own - 1.0, 0.0), axis=0)

    aspect_robust = xp.exp(
        golden_section_minimize_arr(
            worst_regret, log_lo[0], log_hi[0], iters=gss_iters, xp=xp
        )
    )
    regret_robust = worst_regret(xp.log(aspect_robust))

    p_robust = pw(aspect=aspect_robust[None, ...])
    w_col = weights[:, None]
    bus_power_robust = xp.sum(w_col * p_robust, axis=0)
    bus_power_square = xp.sum(w_col * p_square, axis=0)

    # Calibrated savings at the robust aspect, workload-aggregated the way
    # ``energy.average_comparison`` aggregates Fig. 4/5 (power-weighted sums;
    # the square layout under each workload's own activities is the anchor).
    fixed, compute = calibration_split_arr(p_square, f_nb, share)
    sym_i = xp.sum(w_col * (p_square + fixed), axis=0)
    asym_i = xp.sum(w_col * (p_robust + fixed), axis=0)
    comp_t = xp.sum(w_col * compute, axis=0)
    safe_sym = xp.where(sym_i > 0, sym_i, 1.0)
    safe_tot = xp.where(sym_i + comp_t > 0, sym_i + comp_t, 1.0)

    return {
        "a_v_eff": a_v_eff,
        "aspect_opt": aspect_opt,
        "aspect_opt_gss": aspect_opt_gss,
        "bus_power_opt": p_opt,
        "bus_power_sym": p_square,
        "aspect_robust": aspect_robust,
        "max_regret": regret_robust,
        "bus_power_robust": bus_power_robust,
        "bus_power_square": bus_power_square,
        "interconnect_saving": 1.0 - asym_i / safe_sym,
        "total_saving": 1.0 - (asym_i + comp_t) / safe_tot,
        "area_um2": rows * cols * pe_area,
        # Throughput-aware objectives: each PE retires one MAC per cycle, so
        # J/MAC = P / (R C f).  ``neg_macs_per_cycle`` is negated so the
        # minimize-all Pareto convention maximizes throughput.
        "bus_energy_per_mac_j": bus_power_robust / (rows * cols * freq_hz),
        "neg_macs_per_cycle": -(rows * cols),
    }


def _sweep_core(rows, cols, b_h, b_v, pe_area, a_h, a_v_eff, aspects):
    xp = _xp(rows, a_h, aspects)
    return bus_power_arr(
        rows[:, None],
        cols[:, None],
        b_h[:, None],
        b_v[:, None],
        pe_area[:, None],
        a_h[:, None],
        a_v_eff[:, None],
        aspects[None, :],
        xp=xp,
    )


@functools.lru_cache(maxsize=8)
def _jitted_eval(gss_iters: int):
    return jax.jit(functools.partial(_evaluate_core, gss_iters=gss_iters))


@functools.lru_cache(maxsize=1)
def _jitted_sweep():
    return jax.jit(_sweep_core)


@dataclasses.dataclass(frozen=True)
class DesignSpaceEval:
    """Struct-of-arrays evaluation of a design grid (see field comments).

    Workload-axis outputs are (W, P); per-point outputs are (P,).
    """

    grid: DesignGrid
    a_v_eff: np.ndarray  # (W, P) vertical activity after bus-invert coding
    aspect_opt: np.ndarray  # (W, P) envelope-clamped Eq. 6 optimum
    aspect_opt_gss: np.ndarray  # (W, P) batched golden-section cross-check
    bus_power_opt: np.ndarray  # (W, P) bus power at aspect_opt [W]
    bus_power_sym: np.ndarray  # (W, P) bus power at the square layout [W]
    aspect_robust: np.ndarray  # (P,) minimax-regret aspect over workloads
    max_regret: np.ndarray  # (P,) worst-case regret at aspect_robust
    bus_power_robust: np.ndarray  # (P,) workload-weighted bus power at robust
    bus_power_square: np.ndarray  # (P,) workload-weighted square bus power
    interconnect_saving: np.ndarray  # (P,) calibrated, at aspect_robust
    total_saving: np.ndarray  # (P,) calibrated, at aspect_robust
    area_um2: np.ndarray  # (P,) total PE array area
    bus_energy_per_mac_j: np.ndarray  # (P,) robust bus power / (R C f)
    neg_macs_per_cycle: np.ndarray  # (P,) -(R C): minimize == max throughput
    sweep_report: object | None = None  # SweepReport when run via ``sweep=``

    @property
    def n_points(self) -> int:
        return self.grid.n_points

    def objectives(
        self, names: Sequence[str] = ("bus_power_robust", "area_um2", "max_regret")
    ) -> np.ndarray:
        """(P, len(names)) objective matrix (all minimized)."""
        return np.stack([np.asarray(getattr(self, n), float) for n in names], axis=1)

    def pareto(
        self, names: Sequence[str] = ("bus_power_robust", "area_um2", "max_regret")
    ) -> np.ndarray:
        """Boolean (P,) mask of Pareto-optimal points for the objectives."""
        return pareto_mask(self.objectives(names))


def _norm_activities(a_h, a_v, n_points: int) -> tuple[np.ndarray, np.ndarray]:
    a_h = np.atleast_1d(np.asarray(a_h, float))
    a_v = np.atleast_1d(np.asarray(a_v, float))
    if a_h.ndim == 1:
        a_h = a_h[None, :]
    if a_v.ndim == 1:
        a_v = a_v[None, :]
    w = max(a_h.shape[0], a_v.shape[0])
    a_h = np.broadcast_to(a_h, (w, n_points))
    a_v = np.broadcast_to(a_v, (w, n_points))
    if not (0.0 <= a_h.min() and a_h.max() <= 1.0 and 0.0 <= a_v.min() and a_v.max() <= 1.0):
        raise ValueError("activities must lie in [0, 1]")
    return np.ascontiguousarray(a_h), np.ascontiguousarray(a_v)


def evaluate_design_space(
    grid: DesignGrid,
    a_h,
    a_v,
    *,
    weights: Sequence[float] | None = None,
    cfg: EnergyModelConfig = EnergyModelConfig(),
    use_jit: bool | None = None,
    gss_iters: int = 64,
    sweep=None,
) -> DesignSpaceEval:
    """Evaluate every design point of ``grid`` against a workload axis.

    ``a_h``/``a_v`` are activities of shape scalar, (P,), or (W, P) —
    measured (``workloads.measured_design_activities``) or analytical.
    ``weights`` (W,) mixes workloads for the aggregate power/saving outputs
    (default: uniform).  Runs as one jitted jax program when jax is
    available (float32; pass ``use_jit=False`` for the float64 numpy path —
    same code, same results up to float32 rounding).

    ``sweep`` (a ``repro.core.sweep.SweepConfig``) routes evaluation
    through the chunked, checkpointed, guard-validated runner: the point
    axis is split into fixed-shape chunks, each committed to a crash-safe
    content-addressed store and validated against physical contracts and
    scalar-oracle cross-checks; a killed sweep resumes bit-identically.
    The returned eval carries the machine-readable ``sweep_report``.
    """
    p = grid.n_points
    a_h, a_v = _norm_activities(a_h, a_v, p)
    w = np.asarray(
        weights if weights is not None else np.ones(a_h.shape[0]), float
    )
    if w.shape != (a_h.shape[0],):
        raise ValueError("weights must match the workload axis")
    if w.sum() <= 0:
        raise ValueError("weights must sum to a positive value")
    w = w / w.sum()

    use_jit = _HAS_JAX if use_jit is None else use_jit
    if use_jit and not _HAS_JAX:
        raise RuntimeError("use_jit=True but jax is not importable")
    if sweep is not None:
        from repro.core.sweep import run_design_sweep

        out, report = run_design_sweep(
            grid, a_h, a_v, w, cfg=cfg, gss_iters=gss_iters, use_jit=use_jit,
            sweep=sweep,
        )
        return DesignSpaceEval(grid=grid, sweep_report=report, **out)
    fn = (
        _jitted_eval(gss_iters)
        if use_jit
        else functools.partial(_evaluate_core, gss_iters=gss_iters)
    )
    args = (
        np.asarray(grid.rows, float),
        np.asarray(grid.cols, float),
        np.asarray(grid.b_h, float),
        np.asarray(grid.b_v, float),
        np.asarray(grid.pe_area_um2, float),
        a_h,
        _effective_a_v(grid, a_v),
        w,
        float(grid.aspect_lo),
        float(grid.aspect_hi),
        cfg.vdd,
        cfg.freq_hz,
        cfg.wire_cap_f_per_um,
        cfg.non_bus_interconnect_fraction,
        cfg.interconnect_share_of_total,
    )
    if use_jit:
        out = {k: np.asarray(v) for k, v in fn(*args).items()}
    else:
        out = fn(*args)
    return DesignSpaceEval(grid=grid, **out)


def sweep_bus_power(
    grid: DesignGrid, a_h, a_v, aspects, *, use_jit: bool | None = None
) -> np.ndarray:
    """(P, S) bus power surface over an aspect axis — the Fig. 2/3 analog
    for every design point at once.  ``a_h``/``a_v`` are per-point (P,) or
    scalar activities (combine the workload axis first, e.g. with
    transition-weighted means)."""
    p = grid.n_points
    a_h = np.ascontiguousarray(np.broadcast_to(np.asarray(a_h, float), (p,)))
    a_v = np.ascontiguousarray(np.broadcast_to(np.asarray(a_v, float), (p,)))
    aspects = np.asarray(aspects, float)
    use_jit = _HAS_JAX if use_jit is None else use_jit
    if use_jit and not _HAS_JAX:
        raise RuntimeError("use_jit=True but jax is not importable")
    fn = _jitted_sweep() if use_jit else _sweep_core
    out = fn(
        np.asarray(grid.rows, float),
        np.asarray(grid.cols, float),
        np.asarray(grid.b_h, float),
        np.asarray(grid.b_v, float),
        np.asarray(grid.pe_area_um2, float),
        a_h,
        _effective_a_v(grid, a_v),
        aspects,
    )
    return np.asarray(out)


def evaluate_layout_design_space(
    space_or_grid,
    a_h,
    a_v,
    *,
    layouts: Sequence[str] | None = None,
    **kwargs,
):
    """Evaluate the design grid across its LAYOUT-FAMILY axis.

    The segment-level entry point of the exploration engine: where
    ``evaluate_design_space`` collapses every point to the closed-form
    uniform rectangle, this pairs each point with every family of the
    layout axis (``DesignSpace.layouts``, or an explicit ``layouts=``) and
    runs the jitted segment-class evaluator —
    ``repro.layout.power.evaluate_layout_space`` — over the (point x
    layout) batch: envelope-constrained optimal aspects, data-net powers,
    overheads, and the best family per point.  Accepts a ``DesignSpace``
    (expanded here) or a ``DesignGrid``; see ``evaluate_layout_space`` for
    the remaining keyword arguments (per-lane activities, weights,
    ``LayoutPowerConfig``...).
    """
    from repro.layout.power import evaluate_layout_space

    if isinstance(space_or_grid, DesignSpace):
        if layouts is None:
            layouts = space_or_grid.layouts
        grid = space_or_grid.expand()
    else:
        grid = space_or_grid
        if layouts is None:
            # A bare grid does not carry the layout axis (expand() keeps the
            # point axis geometry-only); silently defaulting would drop
            # whatever the user configured on the space.
            raise ValueError(
                "pass layouts= explicitly when evaluating a DesignGrid "
                "(or pass the DesignSpace, whose layouts axis is used)"
            )
    return evaluate_layout_space(grid, a_h, a_v, layouts=layouts, **kwargs)


# ---------------------------------------------------------------------------
# Pareto extraction
# ---------------------------------------------------------------------------


def pareto_mask(objectives: np.ndarray, chunk: int = 1024) -> np.ndarray:
    """Boolean mask of Pareto-optimal rows (all objectives minimized).

    A row p dominates q iff p <= q on every objective and p < q on at least
    one; the mask keeps exactly the non-dominated rows (duplicates of a
    non-dominated row are all kept — neither dominates the other).

    Non-finite rows (any NaN or +/-Inf objective) are EXCLUDED: they never
    join the frontier and never dominate anyone.  A poisoned cell (a NaN
    leaking out of an evaluator) must not be able to corrupt — or crash —
    the frontier extraction; NaN comparisons are False-poison under the
    dominance tests, so exclusion is the only safe semantics.

    O(n * frontier) rather than O(n^2): rows are processed in lexicographic
    order (a dominator always sorts no later than its victim), compared in
    vectorized chunks against the accumulated frontier, and only surviving
    rows join the frontier (dominance is transitive, so dominated rows never
    need to serve as dominators).  Verified against the O(n^2) oracle in the
    tests.
    """
    obj = np.asarray(objectives, float)
    if obj.ndim != 2:
        raise ValueError("objectives must be (n_points, n_objectives)")
    n = obj.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    finite = np.isfinite(obj).all(axis=1)
    if not finite.all():
        mask = np.zeros(n, bool)
        if finite.any():
            mask[finite] = pareto_mask(obj[finite], chunk)
        return mask
    order = np.lexsort(obj.T[::-1])  # sort by column 0, then 1, ...
    srt = obj[order]
    keep = np.ones(n, bool)
    front = np.empty((0, obj.shape[1]))
    for lo in range(0, n, chunk):
        blk = srt[lo : lo + chunk]
        k = np.ones(len(blk), bool)
        for flo in range(0, len(front), 4096):  # bound the comparison matrix
            fr = front[flo : flo + 4096]
            le = (fr[:, None, :] <= blk[None, :, :]).all(-1)
            lt = (fr[:, None, :] < blk[None, :, :]).any(-1)
            k &= ~(le & lt).any(axis=0)
        le = (blk[:, None, :] <= blk[None, :, :]).all(-1)
        lt = (blk[:, None, :] < blk[None, :, :]).any(-1)
        k &= ~np.triu(le & lt, 1).any(axis=0)  # dominators sort earlier
        keep[lo : lo + len(blk)] = k
        front = np.concatenate([front, blk[k]])
    mask = np.empty(n, bool)
    mask[order] = keep
    return mask
