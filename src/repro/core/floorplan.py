"""Analytical floorplan model for weight-stationary systolic arrays.

Implements the paper's core contribution (Peltekis et al., "The Case for
Asymmetric Systolic Array Floorplanning", 2023):

  * Eq. 1-3: total horizontal/vertical bus wirelength of an R x C array of
    PEs with a fixed per-PE area ``A = H * W``.
  * Eq. 5:   wirelength-optimal PE aspect ratio ``W/H = B_v / B_h``.
  * Eq. 6:   power-optimal PE aspect ratio   ``W/H = (B_v a_v) / (B_h a_h)``.

All lengths are in micrometers, areas in um^2, powers in watts unless noted.
The model is closed-form; a numeric golden-section optimizer is provided so
property tests can cross-check the closed form against brute-force search.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

__all__ = [
    "SystolicArrayGeometry",
    "BusActivity",
    "pe_dims_from_aspect",
    "wirelength_h",
    "wirelength_v",
    "wirelength_total",
    "optimal_aspect_wirelength",
    "optimal_aspect_power",
    "bus_switched_capacitance_per_cycle",
    "bus_power",
    "bus_power_ratio_vs_square",
    "golden_section_minimize",
    "numeric_optimal_aspect",
    "sweep_aspects",
    "accumulator_width",
]


def accumulator_width(input_bits: int, rows: int) -> int:
    """Bit width needed to accumulate ``rows`` products of two ``input_bits`` ints.

    A product of two signed B-bit integers needs 2B bits; adding R of them
    grows the dynamic range by ceil(log2 R) bits.  The paper's operating point
    (B=16, R=32) yields 32 + ceil(log2 32) = 37 bits, matching Section IV.
    """
    if input_bits <= 0 or rows <= 0:
        raise ValueError("input_bits and rows must be positive")
    return 2 * input_bits + math.ceil(math.log2(rows))


@dataclasses.dataclass(frozen=True)
class SystolicArrayGeometry:
    """Static geometry of an R x C weight-stationary systolic array.

    Attributes:
      rows / cols:  PE grid dimensions (R, C in the paper).
      b_h:          horizontal (input) bus width in bits, per row.
      b_v:          vertical (partial-sum) bus width in bits, per column.
      pe_area_um2:  fixed per-PE area A; H * W == A for any aspect ratio.
    """

    rows: int
    cols: int
    b_h: int
    b_v: int
    pe_area_um2: float = 1200.0  # 16-bit MAC + pipeline regs @ 28nm (typical)

    def __post_init__(self) -> None:
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError("rows/cols must be positive")
        if self.b_h <= 0 or self.b_v <= 0:
            raise ValueError("bus widths must be positive")
        if self.pe_area_um2 <= 0:
            raise ValueError("pe_area_um2 must be positive")

    @classmethod
    def paper_32x32(cls) -> "SystolicArrayGeometry":
        """The paper's experimental configuration: 32x32, int16, 37-bit sums."""
        return cls(rows=32, cols=32, b_h=16, b_v=accumulator_width(16, 32))


@dataclasses.dataclass(frozen=True)
class BusActivity:
    """Average switching activity (toggles per bit per cycle) per direction."""

    a_h: float
    a_v: float

    def __post_init__(self) -> None:
        if not (0.0 <= self.a_h <= 1.0 and 0.0 <= self.a_v <= 1.0):
            raise ValueError("activities must lie in [0, 1]")

    @classmethod
    def paper_resnet50(cls) -> "BusActivity":
        """Activities measured by the paper on ResNet50/ImageNet (Section IV)."""
        return cls(a_h=0.22, a_v=0.36)


def pe_dims_from_aspect(geom: SystolicArrayGeometry, aspect: float) -> tuple[float, float]:
    """Return (W, H) in um for a PE of area A with aspect ratio ``W/H = aspect``."""
    if aspect <= 0:
        raise ValueError("aspect ratio must be positive")
    h = math.sqrt(geom.pe_area_um2 / aspect)
    w = geom.pe_area_um2 / h
    return w, h


def wirelength_h(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 1: WL_h = R * C * (W * B_h)  [um of wire]."""
    w, _ = pe_dims_from_aspect(geom, aspect)
    return geom.rows * geom.cols * w * geom.b_h


def wirelength_v(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 2: WL_v = R * C * (H * B_v)  [um of wire]."""
    _, h = pe_dims_from_aspect(geom, aspect)
    return geom.rows * geom.cols * h * geom.b_v


def wirelength_total(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 3/4: WL = R*C*(W*B_h + H*B_v)."""
    return wirelength_h(geom, aspect) + wirelength_v(geom, aspect)


def optimal_aspect_wirelength(geom: SystolicArrayGeometry) -> float:
    """Eq. 5: the wirelength-optimal aspect ratio W/H = B_v / B_h."""
    return geom.b_v / geom.b_h


def optimal_aspect_power(geom: SystolicArrayGeometry, act: BusActivity) -> float:
    """Eq. 6: the power-optimal aspect ratio W/H = (B_v a_v) / (B_h a_h).

    Falls back to the wirelength optimum when either activity is zero (a
    direction with no toggling contributes no dynamic power, so only the
    toggling direction's wirelength matters; the limit of Eq. 6 is then
    unbounded — we clamp to the pure-wirelength optimum scaled by the active
    direction, which is the paper's Eq. 5 behavior for a_h == a_v).
    """
    if act.a_h == 0.0 and act.a_v == 0.0:
        return optimal_aspect_wirelength(geom)
    if act.a_h == 0.0 or act.a_v == 0.0:
        # Degenerate: one direction never toggles. Dynamic bus power is then
        # monotonic in the other direction's span; physical floorplans bound
        # the aspect ratio, so clamp to a practical envelope.
        return _ASPECT_MAX if act.a_h == 0.0 else _ASPECT_MIN
    return (geom.b_v * act.a_v) / (geom.b_h * act.a_h)


# Practical envelope for physically realizable standard-cell placements.
_ASPECT_MIN = 1.0 / 16.0
_ASPECT_MAX = 16.0


def bus_switched_capacitance_per_cycle(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    wire_cap_f_per_um: float = 0.20e-15,
) -> float:
    """Average switched wire capacitance per cycle [F].

    C_sw = a_h * WL_h * c_wire + a_v * WL_v * c_wire.  This is the quantity the
    aspect ratio actually optimizes; power is 1/2 * C_sw * V^2 * f.
    """
    return wire_cap_f_per_um * (
        act.a_h * wirelength_h(geom, aspect) + act.a_v * wirelength_v(geom, aspect)
    )


def bus_power(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    vdd: float = 0.9,
    freq_hz: float = 1.0e9,
    wire_cap_f_per_um: float = 0.20e-15,
) -> float:
    """Dynamic power dissipated on the H/V data buses [W] at a given aspect."""
    c_sw = bus_switched_capacitance_per_cycle(geom, act, aspect, wire_cap_f_per_um)
    return 0.5 * c_sw * vdd * vdd * freq_hz


def bus_power_ratio_vs_square(geom: SystolicArrayGeometry, act: BusActivity) -> float:
    """P_bus(optimal aspect) / P_bus(square).

    Closed form: with x = B_h a_h, y = B_v a_v, the square layout dissipates
    ∝ (x + y) while the optimal rectangle dissipates ∝ 2 sqrt(x y); the ratio
    is the AM-GM gap 2 sqrt(xy)/(x+y) ≤ 1 (equality iff x == y, i.e. the array
    is already balanced and square IS optimal).
    """
    x = geom.b_h * act.a_h
    y = geom.b_v * act.a_v
    if x == 0.0 and y == 0.0:
        return 1.0
    if x == 0.0 or y == 0.0:
        # Unbounded improvement in theory; report the envelope-clamped ratio.
        opt = optimal_aspect_power(geom, act)
        return bus_power(geom, act, opt) / bus_power(geom, act, 1.0)
    return 2.0 * math.sqrt(x * y) / (x + y)


def golden_section_minimize(
    fn: Callable[[float], float],
    lo: float,
    hi: float,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> float:
    """Golden-section search for the minimizer of a unimodal ``fn`` on [lo, hi]."""
    if not (lo < hi):
        raise ValueError("need lo < hi")
    invphi = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - invphi * (b - a)
    d = a + invphi * (b - a)
    fc, fd = fn(c), fn(d)
    for _ in range(max_iter):
        if abs(b - a) < tol * (abs(a) + abs(b) + 1e-30):
            break
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - invphi * (b - a)
            fc = fn(c)
        else:
            a, c, fc = c, d, fd
            d = a + invphi * (b - a)
            fd = fn(d)
    return 0.5 * (a + b)


def numeric_optimal_aspect(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    lo: float = 1.0 / 64.0,
    hi: float = 64.0,
) -> float:
    """Brute-force (golden-section, in log-space) power-optimal aspect ratio.

    Used by property tests to validate the closed-form Eq. 6. The objective
    P(aspect) = k1 * sqrt(aspect) + k2 / sqrt(aspect) is unimodal in
    log(aspect), so golden-section search is exact up to tolerance.
    """

    def objective(log_aspect: float) -> float:
        return bus_power(geom, act, math.exp(log_aspect))

    log_opt = golden_section_minimize(objective, math.log(lo), math.log(hi))
    return math.exp(log_opt)


def sweep_aspects(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspects: Sequence[float],
) -> list[dict[str, float]]:
    """Evaluate wirelength and bus power across a sweep of aspect ratios."""
    rows = []
    for ar in aspects:
        w, h = pe_dims_from_aspect(geom, ar)
        rows.append(
            {
                "aspect": ar,
                "pe_w_um": w,
                "pe_h_um": h,
                "wl_h_um": wirelength_h(geom, ar),
                "wl_v_um": wirelength_v(geom, ar),
                "wl_total_um": wirelength_total(geom, ar),
                "bus_power_w": bus_power(geom, act, ar),
            }
        )
    return rows
