"""Analytical floorplan model for weight-stationary systolic arrays.

Implements the paper's core contribution (Peltekis et al., "The Case for
Asymmetric Systolic Array Floorplanning", 2023):

  * Eq. 1-3: total horizontal/vertical bus wirelength of an R x C array of
    PEs with a fixed per-PE area ``A = H * W``.
  * Eq. 5:   wirelength-optimal PE aspect ratio ``W/H = B_v / B_h``.
  * Eq. 6:   power-optimal PE aspect ratio   ``W/H = (B_v a_v) / (B_h a_h)``.

All lengths are in micrometers, areas in um^2, powers in watts unless noted.

Array-first layout
------------------
The analytical core is a set of ``*_arr`` kernels: pure functions over
broadcastable arrays of the geometry fields (rows, cols, b_h, b_v,
pe_area), activities (a_h, a_v) and aspect ratios. They are
backend-agnostic — given numpy inputs they compute in float64 numpy; given
jax arrays (or tracers, i.e. under ``jax.jit``) they compute with
``jax.numpy`` and are fully jit/vmap-compatible (no Python branching on
values). ``repro.core.design_space`` evaluates whole design grids through
them in a handful of jitted programs.

The original scalar API (``SystolicArrayGeometry``/``BusActivity``
dataclasses + float-returning functions) is preserved as thin wrappers over
the same kernels, so results are bit-for-bit the kernels' float64 numpy
path.

Practical aspect envelope
-------------------------
Physically realizable standard-cell floorplans bound the PE aspect ratio;
``optimal_aspect_power`` clamps every branch (including the general Eq. 6
form) to ``[ASPECT_MIN, ASPECT_MAX] = [1/16, 16]``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "ASPECT_MIN",
    "ASPECT_MAX",
    "SystolicArrayGeometry",
    "BusActivity",
    "pe_dims_from_aspect",
    "wirelength_h",
    "wirelength_v",
    "wirelength_total",
    "optimal_aspect_wirelength",
    "optimal_aspect_power",
    "bus_switched_capacitance_per_cycle",
    "bus_power",
    "bus_power_ratio_vs_square",
    "golden_section_minimize",
    "numeric_optimal_aspect",
    "sweep_aspects",
    "accumulator_width",
    # vectorized kernels
    "pe_dims_arr",
    "wirelength_h_arr",
    "wirelength_v_arr",
    "wirelength_total_arr",
    "optimal_aspect_wirelength_arr",
    "optimal_aspect_power_arr",
    "bus_switched_capacitance_arr",
    "bus_power_arr",
    "bus_power_ratio_vs_square_arr",
    "golden_section_minimize_arr",
]

# Practical envelope for physically realizable standard-cell placements.
ASPECT_MIN = 1.0 / 16.0
ASPECT_MAX = 16.0
# Backwards-compatible aliases (pre-refactor private names).
_ASPECT_MIN = ASPECT_MIN
_ASPECT_MAX = ASPECT_MAX


def _xp(*xs):
    """Array namespace for the given operands: ``jax.numpy`` if any operand
    is a jax array or tracer (so kernels trace cleanly under ``jax.jit``),
    plain ``numpy`` otherwise (so the scalar wrappers stay float64-exact and
    jax-free)."""
    for x in xs:
        mod = type(x).__module__
        if mod.startswith("jax") or mod.startswith("jaxlib"):
            import jax.numpy as jnp

            return jnp
    return np


def accumulator_width(input_bits: int, rows: int) -> int:
    """Bit width needed to accumulate ``rows`` products of two ``input_bits`` ints.

    A product of two signed B-bit integers needs 2B bits; adding R of them
    grows the dynamic range by ceil(log2 R) bits.  The paper's operating point
    (B=16, R=32) yields 32 + ceil(log2 32) = 37 bits, matching Section IV.
    """
    if input_bits <= 0 or rows <= 0:
        raise ValueError("input_bits and rows must be positive")
    return 2 * input_bits + math.ceil(math.log2(rows))


@dataclasses.dataclass(frozen=True)
class SystolicArrayGeometry:
    """Static geometry of an R x C weight-stationary systolic array.

    Attributes:
      rows / cols:  PE grid dimensions (R, C in the paper).
      b_h:          horizontal (input) bus width in bits, per row.
      b_v:          vertical (partial-sum) bus width in bits, per column.
      pe_area_um2:  fixed per-PE area A; H * W == A for any aspect ratio.
    """

    rows: int
    cols: int
    b_h: int
    b_v: int
    pe_area_um2: float = 1200.0  # 16-bit MAC + pipeline regs @ 28nm (typical)

    def __post_init__(self) -> None:
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError("rows/cols must be positive")
        if self.b_h <= 0 or self.b_v <= 0:
            raise ValueError("bus widths must be positive")
        if self.pe_area_um2 <= 0:
            raise ValueError("pe_area_um2 must be positive")

    @classmethod
    def paper_32x32(cls) -> "SystolicArrayGeometry":
        """The paper's experimental configuration: 32x32, int16, 37-bit sums."""
        return cls(rows=32, cols=32, b_h=16, b_v=accumulator_width(16, 32))


@dataclasses.dataclass(frozen=True)
class BusActivity:
    """Average switching activity (toggles per bit per cycle) per direction."""

    a_h: float
    a_v: float

    def __post_init__(self) -> None:
        if not (0.0 <= self.a_h <= 1.0 and 0.0 <= self.a_v <= 1.0):
            raise ValueError("activities must lie in [0, 1]")

    @classmethod
    def paper_resnet50(cls) -> "BusActivity":
        """Activities measured by the paper on ResNet50/ImageNet (Section IV)."""
        return cls(a_h=0.22, a_v=0.36)


# ---------------------------------------------------------------------------
# Vectorized kernels (broadcastable arrays; numpy or jax.numpy)
# ---------------------------------------------------------------------------


def pe_dims_arr(pe_area, aspect, xp=None):
    """(W, H) for PEs of area ``pe_area`` and aspect ratio ``W/H = aspect``."""
    xp = xp or _xp(pe_area, aspect)
    h = xp.sqrt(pe_area / aspect)
    w = pe_area / h
    return w, h


def wirelength_h_arr(rows, cols, b_h, pe_area, aspect, xp=None):
    """Eq. 1: WL_h = R * C * (W * B_h)  [um of wire]."""
    xp = xp or _xp(rows, pe_area, aspect)
    w, _ = pe_dims_arr(pe_area, aspect, xp=xp)
    return rows * cols * w * b_h


def wirelength_v_arr(rows, cols, b_v, pe_area, aspect, xp=None):
    """Eq. 2: WL_v = R * C * (H * B_v)  [um of wire]."""
    xp = xp or _xp(rows, pe_area, aspect)
    _, h = pe_dims_arr(pe_area, aspect, xp=xp)
    return rows * cols * h * b_v


def wirelength_total_arr(rows, cols, b_h, b_v, pe_area, aspect, xp=None):
    """Eq. 3/4: WL = R*C*(W*B_h + H*B_v)."""
    xp = xp or _xp(rows, pe_area, aspect)
    return wirelength_h_arr(rows, cols, b_h, pe_area, aspect, xp=xp) + wirelength_v_arr(
        rows, cols, b_v, pe_area, aspect, xp=xp
    )


def optimal_aspect_wirelength_arr(b_h, b_v, xp=None):
    """Eq. 5: the wirelength-optimal aspect ratio W/H = B_v / B_h."""
    xp = xp or _xp(b_h, b_v)
    return b_v / xp.asarray(b_h)


def optimal_aspect_power_arr(
    b_h, b_v, a_h, a_v, lo: float = ASPECT_MIN, hi: float = ASPECT_MAX, xp=None
):
    """Eq. 6, envelope-clamped and branchless over arrays.

    With x = B_h a_h and y = B_v a_v the power-optimal aspect is y/x; the
    degenerate limits (one or both directions never toggle) resolve to the
    envelope bound on the still-toggling side, or to the Eq. 5 wirelength
    optimum when nothing toggles.  Every branch is clamped to the practical
    envelope ``[lo, hi]`` (default ``[ASPECT_MIN, ASPECT_MAX]``).
    """
    xp = xp or _xp(b_h, b_v, a_h, a_v)
    x = b_h * a_h
    y = b_v * a_v
    x_pos = x > 0
    raw = xp.where(
        x_pos,
        y / xp.where(x_pos, x, 1.0),
        xp.where(y > 0, hi, b_v / xp.asarray(b_h)),
    )
    return xp.clip(raw, lo, hi)


def bus_switched_capacitance_arr(
    rows, cols, b_h, b_v, pe_area, a_h, a_v, aspect, wire_cap_f_per_um=0.20e-15, xp=None
):
    """Average switched wire capacitance per cycle [F] (see ``bus_power``).

    Uniform-activity assumption: every wire of a bus is priced at the
    aggregate activity ``a`` — i.e. ``a * bits`` switching wires per
    transition.  This is exactly the MEAN-LANE approximation of the
    per-bit-lane roll-up (``sum(lane_activities) == a * bits`` by
    construction, so the two agree bit-for-bit whenever every segment
    carries the full bus — the case this closed form describes).  It stops
    being exact once segment widths vary per lane (e.g. multi-pod
    pod-local accumulator buses); ``repro.layout.power`` prices those from
    measured ``ActivityProfile.h_lane_toggles``/``v_lane_toggles``, and
    ``benchmarks/bench_design_space.py``'s ``layout/lane_approx_error``
    row tracks the gap.
    """
    xp = xp or _xp(rows, pe_area, a_h, aspect)
    return wire_cap_f_per_um * (
        a_h * wirelength_h_arr(rows, cols, b_h, pe_area, aspect, xp=xp)
        + a_v * wirelength_v_arr(rows, cols, b_v, pe_area, aspect, xp=xp)
    )


def bus_power_arr(
    rows,
    cols,
    b_h,
    b_v,
    pe_area,
    a_h,
    a_v,
    aspect,
    vdd=0.9,
    freq_hz=1.0e9,
    wire_cap_f_per_um=0.20e-15,
    xp=None,
):
    """Dynamic H/V data-bus power [W]; broadcastable over every argument."""
    xp = xp or _xp(rows, pe_area, a_h, aspect)
    c_sw = bus_switched_capacitance_arr(
        rows, cols, b_h, b_v, pe_area, a_h, a_v, aspect, wire_cap_f_per_um, xp=xp
    )
    return 0.5 * c_sw * vdd * vdd * freq_hz


def bus_power_ratio_vs_square_arr(b_h, b_v, a_h, a_v, xp=None):
    """P_bus(envelope-clamped optimal aspect) / P_bus(square).

    With x = B_h a_h, y = B_v a_v the bus power at aspect r is proportional
    to ``x sqrt(r) + y / sqrt(r)`` (the geometry prefactor cancels in the
    ratio).  When the Eq. 6 optimum y/x lies inside the envelope this equals
    the AM-GM gap ``2 sqrt(xy) / (x + y) <= 1``; outside, the ratio is
    evaluated at the clamped boundary aspect.  Zero-activity designs report
    1.0 (no dynamic power to save).
    """
    xp = xp or _xp(b_h, b_v, a_h, a_v)
    x = b_h * a_h
    y = b_v * a_v
    opt = optimal_aspect_power_arr(b_h, b_v, a_h, a_v, xp=xp)
    s = xp.sqrt(opt)
    denom = x + y
    safe = xp.where(denom > 0, denom, 1.0)
    return xp.where(denom > 0, (x * s + y / s) / safe, 1.0)


def golden_section_minimize_arr(fn, lo, hi, iters: int = 64, xp=None):
    """Elementwise golden-section minimizer over an array of intervals.

    ``fn`` maps an array of probe points (broadcast of ``lo``/``hi``) to
    objective values of the same shape; each element's objective must be
    unimodal on its [lo, hi].  Runs a fixed ``iters`` iterations — the
    surviving interior probe is carried so each iteration costs ONE ``fn``
    evaluation; the interval shrinks by phi^-1 per step (64 iterations
    reach ~1e-13 of the initial interval) — so the loop is branch-free and
    traces once under ``jax.jit``.
    """
    xp = xp or _xp(lo, hi)
    a = xp.asarray(lo) + 0.0
    b = xp.asarray(hi) + 0.0
    a, b = xp.broadcast_arrays(a, b)
    invphi = (math.sqrt(5.0) - 1.0) / 2.0
    c = b - invphi * (b - a)
    d = a + invphi * (b - a)
    fc, fd = fn(c), fn(d)

    def step(a, b, c, d, fc, fd):
        take_left = fc < fd
        a2 = xp.where(take_left, a, c)
        b2 = xp.where(take_left, d, b)
        # keep-left reuses c as the new d; keep-right reuses d as the new c
        c2 = xp.where(take_left, b2 - invphi * (b2 - a2), d)
        d2 = xp.where(take_left, c, a2 + invphi * (b2 - a2))
        f_new = fn(xp.where(take_left, c2, d2))
        fc2 = xp.where(take_left, f_new, fd)
        fd2 = xp.where(take_left, fc, f_new)
        return a2, b2, c2, d2, fc2, fd2

    if xp is np:
        for _ in range(iters):
            a, b, c, d, fc, fd = step(a, b, c, d, fc, fd)
    else:
        # Trace the contraction once instead of unrolling ``iters`` copies —
        # keeps jit compile time flat in the iteration count.
        from jax import lax

        a, b, c, d, fc, fd = lax.fori_loop(
            0, iters, lambda _, s: step(*s), (a, b, c, d, fc, fd)
        )
    return 0.5 * (a + b)


# ---------------------------------------------------------------------------
# Scalar API — thin wrappers over the kernels (numpy float64 path)
# ---------------------------------------------------------------------------


def pe_dims_from_aspect(geom: SystolicArrayGeometry, aspect: float) -> tuple[float, float]:
    """Return (W, H) in um for a PE of area A with aspect ratio ``W/H = aspect``."""
    if aspect <= 0:
        raise ValueError("aspect ratio must be positive")
    w, h = pe_dims_arr(geom.pe_area_um2, aspect, xp=np)
    return float(w), float(h)


def wirelength_h(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 1: WL_h = R * C * (W * B_h)  [um of wire]."""
    return float(
        wirelength_h_arr(geom.rows, geom.cols, geom.b_h, geom.pe_area_um2, aspect, xp=np)
    )


def wirelength_v(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 2: WL_v = R * C * (H * B_v)  [um of wire]."""
    return float(
        wirelength_v_arr(geom.rows, geom.cols, geom.b_v, geom.pe_area_um2, aspect, xp=np)
    )


def wirelength_total(geom: SystolicArrayGeometry, aspect: float) -> float:
    """Eq. 3/4: WL = R*C*(W*B_h + H*B_v)."""
    return wirelength_h(geom, aspect) + wirelength_v(geom, aspect)


def optimal_aspect_wirelength(geom: SystolicArrayGeometry) -> float:
    """Eq. 5: the wirelength-optimal aspect ratio W/H = B_v / B_h."""
    return float(optimal_aspect_wirelength_arr(geom.b_h, geom.b_v, xp=np))


def optimal_aspect_power(geom: SystolicArrayGeometry, act: BusActivity) -> float:
    """Eq. 6: the power-optimal aspect ratio W/H = (B_v a_v) / (B_h a_h),
    clamped to the practical envelope ``[ASPECT_MIN, ASPECT_MAX]``.

    Degenerate activities fall back gracefully: if only one direction
    toggles, dynamic bus power is monotonic in the other direction's span
    and the result clamps to the envelope bound (``ASPECT_MAX`` when only
    the vertical bus toggles, ``ASPECT_MIN`` when only the horizontal one
    does); if neither toggles, the Eq. 5 wirelength optimum (clamped) is
    returned.  The general Eq. 6 branch is clamped to the same envelope —
    extreme ``B_v a_v / (B_h a_h)`` ratios otherwise prescribe physically
    unrealizable standard-cell placements.
    """
    return float(optimal_aspect_power_arr(geom.b_h, geom.b_v, act.a_h, act.a_v, xp=np))


def bus_switched_capacitance_per_cycle(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    wire_cap_f_per_um: float = 0.20e-15,
) -> float:
    """Average switched wire capacitance per cycle [F].

    C_sw = a_h * WL_h * c_wire + a_v * WL_v * c_wire.  This is the quantity the
    aspect ratio actually optimizes; power is 1/2 * C_sw * V^2 * f.
    """
    return float(
        bus_switched_capacitance_arr(
            geom.rows,
            geom.cols,
            geom.b_h,
            geom.b_v,
            geom.pe_area_um2,
            act.a_h,
            act.a_v,
            aspect,
            wire_cap_f_per_um,
            xp=np,
        )
    )


def bus_power(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    vdd: float = 0.9,
    freq_hz: float = 1.0e9,
    wire_cap_f_per_um: float = 0.20e-15,
) -> float:
    """Dynamic power dissipated on the H/V data buses [W] at a given aspect."""
    return float(
        bus_power_arr(
            geom.rows,
            geom.cols,
            geom.b_h,
            geom.b_v,
            geom.pe_area_um2,
            act.a_h,
            act.a_v,
            aspect,
            vdd,
            freq_hz,
            wire_cap_f_per_um,
            xp=np,
        )
    )


def bus_power_ratio_vs_square(geom: SystolicArrayGeometry, act: BusActivity) -> float:
    """P_bus(envelope-clamped optimal aspect) / P_bus(square).

    Equals the AM-GM gap ``2 sqrt(xy)/(x+y)`` (x = B_h a_h, y = B_v a_v)
    whenever the Eq. 6 optimum lies inside the practical envelope; see
    ``bus_power_ratio_vs_square_arr``.
    """
    return float(
        bus_power_ratio_vs_square_arr(geom.b_h, geom.b_v, act.a_h, act.a_v, xp=np)
    )


def golden_section_minimize(
    fn: Callable[[float], float],
    lo: float,
    hi: float,
    tol: float = 1e-10,
    max_iter: int = 200,
) -> float:
    """Golden-section search for the minimizer of a unimodal ``fn`` on [lo, hi].

    Scalar tolerance-based variant (the batched fixed-iteration form is
    ``golden_section_minimize_arr``)."""
    if not (lo < hi):
        raise ValueError("need lo < hi")
    invphi = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - invphi * (b - a)
    d = a + invphi * (b - a)
    fc, fd = fn(c), fn(d)
    for _ in range(max_iter):
        if abs(b - a) < tol * (abs(a) + abs(b) + 1e-30):
            break
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - invphi * (b - a)
            fc = fn(c)
        else:
            a, c, fc = c, d, fd
            d = a + invphi * (b - a)
            fd = fn(d)
    return 0.5 * (a + b)


def numeric_optimal_aspect(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    lo: float = ASPECT_MIN,
    hi: float = ASPECT_MAX,
) -> float:
    """Brute-force (golden-section, in log-space) power-optimal aspect ratio.

    Used by property tests to validate the closed-form Eq. 6. The objective
    P(aspect) = k1 * sqrt(aspect) + k2 / sqrt(aspect) is unimodal in
    log(aspect), so golden-section search is exact up to tolerance.  The
    default search window is the practical envelope — matching the clamped
    closed form (an out-of-envelope optimum converges to the boundary).
    """

    def objective(log_aspect: float) -> float:
        return bus_power(geom, act, math.exp(log_aspect))

    log_opt = golden_section_minimize(objective, math.log(lo), math.log(hi))
    return math.exp(log_opt)


def sweep_aspects(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspects: Sequence[float],
) -> list[dict[str, float]]:
    """Evaluate wirelength and bus power across a sweep of aspect ratios."""
    rows = []
    for ar in aspects:
        w, h = pe_dims_from_aspect(geom, ar)
        rows.append(
            {
                "aspect": ar,
                "pe_w_um": w,
                "pe_h_um": h,
                "wl_h_um": wirelength_h(geom, ar),
                "wl_v_um": wirelength_v(geom, ar),
                "wl_total_um": wirelength_total(geom, ar),
                "bus_power_w": bus_power(geom, act, ar),
            }
        )
    return rows
