"""Generic crash-safe on-disk content-addressed store.

Factored out of the profile store (PR 6) so every persisted artifact of the
stack — activity profiles (``core.profile_store``) and design-space sweep
chunks (``core.sweep``) — shares ONE audited implementation of the
crash-safety machinery instead of re-growing it per subsystem.

Design constraints, in priority order:

  1. **Never corrupt, never crash.**  Writes are atomic (temp file in the
     same directory + ``os.replace``); a process killed mid-write leaves
     only a temp file the next writer ignores, never a torn entry.  Reads
     verify a per-entry sha256 over the payload bytes; entries that fail
     verification (bit rot, torn bytes from pre-atomic tooling, tampering)
     are QUARANTINED — moved aside for forensics, counted, and reported as
     a miss so the caller recomputes and overwrites.  No store failure mode
     propagates: a broken disk degrades to compute, exactly like a cold
     cache.
  2. **Versioned keys.**  Entries live under a schema-version directory;
     a key-schema bump orphans old entries rather than mis-serving them.
  3. **Bounded size.**  ``max_bytes`` caps the store; eviction is
     LRU-by-mtime (reads touch their entry), oldest first.

Layout::

    <root>/<version>/<kk>/<keyhex>.json      kk = first key byte (fan-out)
    <root>/<version>/quarantine/<keyhex>.json
    <root>/<version>/.tmp-<pid>-<nonce>      in-flight writes

Entry format: JSON ``{"v", "sha256", "payload"}`` where ``sha256`` is over
the canonical (sorted-keys) JSON encoding of ``payload``.  JSON keeps
entries inspectable with a text editor during an incident; bulk array data
(sweep chunks) rides inside the payload as base64 fields.

``corrupt_site`` names the fault-injection site the read path exposes
(``runtime.faults`` bitflips): ``"store-read"`` for profiles,
``"chunk-store-read"`` for sweep chunks — chaos CI can aim at either store
independently.
"""

from __future__ import annotations

import hashlib
import json
import os
import secrets
import threading

__all__ = ["ContentStore", "atomic_write_bytes"]

_DEFAULT_MAX_BYTES = 256 << 20  # 256 MiB ~ hundreds of thousands of entries


def canonical_payload(payload: dict) -> bytes:
    """Canonical (sorted-keys, no-whitespace) JSON bytes of ``payload`` —
    the digest input shared by every store entry."""
    return json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()


def atomic_write_bytes(
    path: str | os.PathLike, raw: bytes, *, tmp_dir: str | os.PathLike | None = None
) -> None:
    """Write ``raw`` to ``path`` atomically (tmp file + fsync +
    ``os.replace``).  ``tmp_dir`` (default: ``path``'s directory) must be on
    the same filesystem for the replace to stay atomic.  Raises ``OSError``
    on failure — callers decide whether a dropped write is fatal (checkpoint
    manifests) or degradable (store entries)."""
    path = os.fspath(path)
    d = os.fspath(tmp_dir) if tmp_dir is not None else (os.path.dirname(path) or ".")
    tmp = os.path.join(d, f".tmp-{os.getpid()}-{secrets.token_hex(8)}")
    try:
        with open(tmp, "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ContentStore:
    """One on-disk store rooted at ``path`` (created on first use).

    Payloads are JSON dicts addressed by an opaque ``bytes`` key; subclasses
    add typed encode/decode on top of ``get_payload``/``put_payload``.
    Thread-safe; every method is total (no exception escapes a get or put —
    the worst outcome is a counted miss or a dropped write).
    """

    def __init__(
        self,
        path: str | os.PathLike,
        *,
        version: str,
        max_bytes: int = _DEFAULT_MAX_BYTES,
        corrupt_site: str = "store-read",
    ):
        self.root = os.fspath(path)
        self.version = version
        self.max_bytes = int(max_bytes)
        self.corrupt_site = corrupt_site
        self.stats = {
            "hits": 0,
            "misses": 0,
            "puts": 0,
            "evictions": 0,
            "integrity_failures": 0,
            "io_errors": 0,
        }
        self._lock = threading.Lock()
        self._approx_bytes: int | None = None  # lazily scanned
        self._quarantine_events: list[str] = []  # key hexes, drained by readers

    # -- paths ---------------------------------------------------------------

    @property
    def _vdir(self) -> str:
        return os.path.join(self.root, self.version)

    @property
    def quarantine_dir(self) -> str:
        return os.path.join(self._vdir, "quarantine")

    def entry_path(self, key: bytes) -> str:
        hexkey = key.hex()
        return os.path.join(self._vdir, hexkey[:2], hexkey + ".json")

    def _count(self, stat: str, n: int = 1) -> None:
        with self._lock:
            self.stats[stat] += n

    # -- encode / decode -----------------------------------------------------

    def encode_payload(self, payload: dict) -> bytes:
        body = canonical_payload(payload)
        doc = {
            "v": self.version,
            "sha256": hashlib.sha256(body).hexdigest(),
            "payload": payload,
        }
        return json.dumps(doc, sort_keys=True).encode()

    def decode_payload(self, raw: bytes) -> dict:
        """Verified payload dict, or raise (caller quarantines)."""
        doc = json.loads(raw)
        if doc["v"] != self.version:
            raise ValueError(f"entry version {doc['v']!r} != {self.version!r}")
        payload = doc["payload"]
        digest = hashlib.sha256(canonical_payload(payload)).hexdigest()
        if digest != doc["sha256"]:
            raise ValueError("payload sha256 mismatch")
        return payload

    # -- public API ----------------------------------------------------------

    def get_payload(self, key: bytes) -> dict | None:
        """Verified payload for ``key``, or None (miss / quarantined)."""
        path = self.entry_path(key)
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except FileNotFoundError:
            self._count("misses")
            return None
        except OSError:
            self._count("io_errors")
            self._count("misses")
            return None

        from repro.runtime import faults

        inj = faults.active()
        if inj is not None:
            raw = inj.maybe_corrupt(raw, self.corrupt_site, key.hex()[:16])

        try:
            payload = self.decode_payload(raw)
        except Exception:
            self._quarantine(key, path, raw)
            self._count("integrity_failures")
            self._count("misses")
            return None
        try:
            os.utime(path)  # LRU recency
        except OSError:
            pass
        self._count("hits")
        return payload

    def put_payload(self, key: bytes, payload: dict) -> bool:
        """Atomically persist ``payload`` under ``key``; True on success.

        Crash-safe by construction: the entry becomes visible only via the
        final ``os.replace`` — a writer killed at ANY earlier point leaves
        the previous entry (if any) untouched and at most a stray temp
        file.  I/O failures are counted and swallowed (a full disk must
        degrade to compute-only, not abort a workload).
        """
        path = self.entry_path(key)
        try:
            raw = self.encode_payload(payload)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            atomic_write_bytes(path, raw, tmp_dir=self._vdir)
        except OSError:
            self._count("io_errors")
            return False
        self._count("puts")
        with self._lock:
            if self._approx_bytes is not None:
                self._approx_bytes += len(raw)
        self._evict_if_needed()
        return True

    def drain_quarantine_events(self) -> list[str]:
        """Key hexes quarantined since the last drain (failure reporting)."""
        with self._lock:
            out, self._quarantine_events = self._quarantine_events, []
        return out

    def _quarantine(self, key: bytes, path: str, raw: bytes) -> None:
        """Move a failed-verification entry aside; never raise."""
        with self._lock:
            self._quarantine_events.append(key.hex())
        try:
            os.makedirs(self.quarantine_dir, exist_ok=True)
            os.replace(
                path, os.path.join(self.quarantine_dir, os.path.basename(path))
            )
        except OSError:
            try:
                os.unlink(path)
            except OSError:
                pass

    # -- size bound ----------------------------------------------------------

    def _scan(self) -> list[tuple[float, int, str]]:
        """(mtime, size, path) for every live entry; also refreshes the
        approximate byte total and sweeps stale temp files."""
        out = []
        total = 0
        try:
            shards = os.listdir(self._vdir)
        except OSError:
            shards = []
        for shard in shards:
            sdir = os.path.join(self._vdir, shard)
            if shard.startswith(".tmp-"):
                try:  # stray temp from a crashed writer: sweep
                    os.unlink(sdir)
                except OSError:
                    pass
                continue
            if shard == "quarantine" or not os.path.isdir(sdir):
                continue
            try:
                names = os.listdir(sdir)
            except OSError:
                continue
            for name in names:
                p = os.path.join(sdir, name)
                if name.startswith(".tmp-"):
                    try:  # defensive: a temp that strayed into a shard dir
                        os.unlink(p)
                    except OSError:
                        pass
                    continue
                try:
                    st = os.stat(p)
                except OSError:
                    continue
                out.append((st.st_mtime, st.st_size, p))
                total += st.st_size
        with self._lock:
            self._approx_bytes = total
        return out

    def _evict_if_needed(self) -> None:
        with self._lock:
            approx = self._approx_bytes
        if approx is not None and approx <= self.max_bytes:
            return
        entries = self._scan()
        total = sum(size for _, size, _ in entries)
        if total <= self.max_bytes:
            return
        evicted = 0
        for _, size, p in sorted(entries):  # oldest mtime first
            if total <= self.max_bytes:
                break
            try:
                os.unlink(p)
            except OSError:
                continue
            total -= size
            evicted += 1
        with self._lock:
            self._approx_bytes = total
            self.stats["evictions"] += evicted

    # -- introspection -------------------------------------------------------

    def entries(self) -> list[str]:
        """Paths of every live entry (tests / incident tooling)."""
        return sorted(p for _, _, p in self._scan())

    def quarantined(self) -> list[str]:
        try:
            return sorted(
                os.path.join(self.quarantine_dir, n)
                for n in os.listdir(self.quarantine_dir)
            )
        except OSError:
            return []

    def info(self) -> dict:
        with self._lock:
            stats = dict(self.stats)
        return {
            "path": self.root,
            "version": self.version,
            "max_bytes": self.max_bytes,
            "entries": len(self.entries()),
            **stats,
        }

    def clear(self) -> None:
        """Delete every entry (incl. quarantine); keep the directories."""
        for p in self.entries() + self.quarantined():
            try:
                os.unlink(p)
            except OSError:
                pass
        with self._lock:
            self._approx_bytes = 0
