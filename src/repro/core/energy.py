"""Calibrated SA power model: reproduces the paper's Fig. 4 / Fig. 5 split.

Decomposition (per Section I of the paper):

  P_total = P_interconnect + P_compute_and_regs
  P_interconnect = P_bus(aspect) + P_fixed_interconnect

``P_bus`` is the aspect-ratio-dependent H/V data-bus power computed from first
principles (``repro.core.floorplan.bus_power``). The two calibration fractions
below fold in what a 28 nm physical flow measures but an analytical model
cannot (clock tree, PE-local nets, cell-internal power); they are FITTED to the
paper's aggregate claims and documented in DESIGN.md §2:

  * NON_BUS_INTERCONNECT_FRACTION: share of interconnect power that does NOT
    scale with PE aspect ratio. At the paper's operating point the optimal
    rectangle cuts bus power by 18.7%; the paper measures a 9.1% cut in total
    interconnect power, hence 1 - 0.091/0.187 ≈ 0.513 of interconnect power is
    aspect-invariant.
  * INTERCONNECT_SHARE_OF_TOTAL: interconnect share of total SA power; the
    paper's 9.1% interconnect cut shows up as a 2.1% total cut, hence
    0.021/0.091 ≈ 0.231.

Everything *relative* across layers/aspects is computed, not fitted.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    optimal_aspect_power,
)

__all__ = [
    "EnergyModelConfig",
    "PowerBreakdown",
    "power_breakdown",
    "compare_sym_asym",
    "average_comparison",
    "SymAsymComparison",
]

NON_BUS_INTERCONNECT_FRACTION = 0.513
INTERCONNECT_SHARE_OF_TOTAL = 0.231


@dataclasses.dataclass(frozen=True)
class EnergyModelConfig:
    vdd: float = 0.9
    freq_hz: float = 1.0e9
    wire_cap_f_per_um: float = 0.20e-15
    non_bus_interconnect_fraction: float = NON_BUS_INTERCONNECT_FRACTION
    interconnect_share_of_total: float = INTERCONNECT_SHARE_OF_TOTAL


@dataclasses.dataclass(frozen=True)
class PowerBreakdown:
    """Absolute power [W] of one SA configuration on one workload."""

    aspect: float
    bus_w: float
    fixed_interconnect_w: float
    compute_w: float

    @property
    def interconnect_w(self) -> float:
        return self.bus_w + self.fixed_interconnect_w

    @property
    def total_w(self) -> float:
        return self.interconnect_w + self.compute_w


def power_breakdown(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    cfg: EnergyModelConfig = EnergyModelConfig(),
    reference_act: BusActivity | None = None,
) -> PowerBreakdown:
    """Power breakdown at a given aspect ratio.

    The fixed (non-bus) interconnect power and the compute power are anchored
    to the *square* layout under ``reference_act`` (defaults to ``act``): the
    calibration fractions describe the square design's power split, and those
    absolute watts do not change when only the floorplan aspect changes
    (clock tree + cell-internal power are aspect-invariant to first order).
    """
    ref = reference_act if reference_act is not None else act
    bus_ref_sq = bus_power(geom, ref, 1.0, cfg.vdd, cfg.freq_hz, cfg.wire_cap_f_per_um)
    f_nb = cfg.non_bus_interconnect_fraction
    interconnect_ref_sq = bus_ref_sq / (1.0 - f_nb)
    fixed = interconnect_ref_sq * f_nb
    total_ref_sq = interconnect_ref_sq / cfg.interconnect_share_of_total
    compute = total_ref_sq - interconnect_ref_sq

    bus = bus_power(geom, act, aspect, cfg.vdd, cfg.freq_hz, cfg.wire_cap_f_per_um)
    return PowerBreakdown(aspect=aspect, bus_w=bus, fixed_interconnect_w=fixed, compute_w=compute)


@dataclasses.dataclass(frozen=True)
class SymAsymComparison:
    aspect_opt: float
    sym: PowerBreakdown
    asym: PowerBreakdown

    @property
    def interconnect_saving(self) -> float:
        return 1.0 - self.asym.interconnect_w / self.sym.interconnect_w

    @property
    def total_saving(self) -> float:
        return 1.0 - self.asym.total_w / self.sym.total_w

    @property
    def bus_saving(self) -> float:
        return 1.0 - self.asym.bus_w / self.sym.bus_w


def compare_sym_asym(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    cfg: EnergyModelConfig = EnergyModelConfig(),
    design_act: BusActivity | None = None,
    reference_act: BusActivity | None = None,
) -> SymAsymComparison:
    """Square vs power-optimal-rectangular floorplan on one workload.

    ``design_act`` (default: ``act``) picks the aspect ratio — a real chip
    fixes its floorplan at design time from *average* activities, then runs
    many workloads; pass the averaged profile here and the per-layer profile
    as ``act`` to reproduce the paper's per-layer Fig. 4 bars.
    """
    d_act = design_act if design_act is not None else act
    aspect = optimal_aspect_power(geom, d_act)
    sym = power_breakdown(geom, act, 1.0, cfg, reference_act=reference_act)
    asym = power_breakdown(geom, act, aspect, cfg, reference_act=reference_act)
    return SymAsymComparison(aspect_opt=aspect, sym=sym, asym=asym)


def average_comparison(comparisons: Sequence[SymAsymComparison]) -> dict[str, float]:
    """Workload-average savings (the paper's 'Average' bars in Fig. 4/5)."""
    if not comparisons:
        raise ValueError("no comparisons")
    sym_i = sum(c.sym.interconnect_w for c in comparisons)
    asym_i = sum(c.asym.interconnect_w for c in comparisons)
    sym_t = sum(c.sym.total_w for c in comparisons)
    asym_t = sum(c.asym.total_w for c in comparisons)
    return {
        "interconnect_saving": 1.0 - asym_i / sym_i,
        "total_saving": 1.0 - asym_t / sym_t,
        "sym_interconnect_w": sym_i / len(comparisons),
        "asym_interconnect_w": asym_i / len(comparisons),
        "sym_total_w": sym_t / len(comparisons),
        "asym_total_w": asym_t / len(comparisons),
    }
