"""Calibrated SA power model: reproduces the paper's Fig. 4 / Fig. 5 split.

Decomposition (per Section I of the paper):

  P_total = P_interconnect + P_compute_and_regs
  P_interconnect = P_bus(aspect) + P_fixed_interconnect

``P_bus`` is the aspect-ratio-dependent H/V data-bus power computed from first
principles (``repro.core.floorplan.bus_power``). The two calibration fractions
below fold in what a 28 nm physical flow measures but an analytical model
cannot (clock tree, PE-local nets, cell-internal power); they are FITTED to the
paper's aggregate claims and documented in DESIGN.md §2:

  * NON_BUS_INTERCONNECT_FRACTION: share of interconnect power that does NOT
    scale with PE aspect ratio. At the paper's operating point the optimal
    rectangle cuts bus power by 18.7%; the paper measures a 9.1% cut in total
    interconnect power, hence 1 - 0.091/0.187 ≈ 0.513 of interconnect power is
    aspect-invariant.
  * INTERCONNECT_SHARE_OF_TOTAL: interconnect share of total SA power; the
    paper's 9.1% interconnect cut shows up as a 2.1% total cut, hence
    0.021/0.091 ≈ 0.231.

Everything *relative* across layers/aspects is computed, not fitted.

Array-first layout: ``power_breakdown_arr`` / ``compare_sym_asym_arr`` are
broadcastable (and jit-compatible) kernels over geometry/activity/aspect
arrays; the scalar dataclass API wraps their float64 numpy path (see
``repro.core.floorplan``).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    _xp,
    bus_power,
    bus_power_arr,
    optimal_aspect_power,
    optimal_aspect_power_arr,
)

__all__ = [
    "EnergyModelConfig",
    "PowerBreakdown",
    "calibration_split_arr",
    "power_breakdown",
    "power_breakdown_arr",
    "compare_sym_asym",
    "compare_sym_asym_arr",
    "average_comparison",
    "SymAsymComparison",
]

NON_BUS_INTERCONNECT_FRACTION = 0.513
INTERCONNECT_SHARE_OF_TOTAL = 0.231


@dataclasses.dataclass(frozen=True)
class EnergyModelConfig:
    vdd: float = 0.9
    freq_hz: float = 1.0e9
    wire_cap_f_per_um: float = 0.20e-15
    non_bus_interconnect_fraction: float = NON_BUS_INTERCONNECT_FRACTION
    interconnect_share_of_total: float = INTERCONNECT_SHARE_OF_TOTAL


@dataclasses.dataclass(frozen=True)
class PowerBreakdown:
    """Absolute power [W] of one SA configuration on one workload."""

    aspect: float
    bus_w: float
    fixed_interconnect_w: float
    compute_w: float

    @property
    def interconnect_w(self) -> float:
        return self.bus_w + self.fixed_interconnect_w

    @property
    def total_w(self) -> float:
        return self.interconnect_w + self.compute_w


def calibration_split_arr(
    bus_ref_sq,
    non_bus_interconnect_fraction=NON_BUS_INTERCONNECT_FRACTION,
    interconnect_share_of_total=INTERCONNECT_SHARE_OF_TOTAL,
):
    """(fixed_interconnect, compute) watts anchored to a square-layout
    reference bus power — the ONE home of the DESIGN.md §2 calibration
    anchoring, shared by the scalar breakdown and the design-space engine."""
    f_nb = non_bus_interconnect_fraction
    interconnect_ref_sq = bus_ref_sq / (1.0 - f_nb)
    fixed = interconnect_ref_sq * f_nb
    total_ref_sq = interconnect_ref_sq / interconnect_share_of_total
    compute = total_ref_sq - interconnect_ref_sq
    return fixed, compute


def power_breakdown_arr(
    rows,
    cols,
    b_h,
    b_v,
    pe_area,
    a_h,
    a_v,
    aspect,
    *,
    vdd=0.9,
    freq_hz=1.0e9,
    wire_cap_f_per_um=0.20e-15,
    non_bus_interconnect_fraction=NON_BUS_INTERCONNECT_FRACTION,
    interconnect_share_of_total=INTERCONNECT_SHARE_OF_TOTAL,
    ref_a_h=None,
    ref_a_v=None,
    xp=None,
) -> dict:
    """Vectorized power breakdown: ``{"bus_w", "fixed_interconnect_w",
    "compute_w"}`` arrays broadcast over every input.

    The fixed (non-bus) interconnect power and the compute power are anchored
    to the *square* layout under the reference activities (default: the
    workload activities themselves) — see ``power_breakdown``.
    """
    xp = xp or _xp(rows, pe_area, a_h, aspect)
    r_h = a_h if ref_a_h is None else ref_a_h
    r_v = a_v if ref_a_v is None else ref_a_v
    bus_ref_sq = bus_power_arr(
        rows, cols, b_h, b_v, pe_area, r_h, r_v, 1.0, vdd, freq_hz, wire_cap_f_per_um, xp=xp
    )
    fixed, compute = calibration_split_arr(
        bus_ref_sq, non_bus_interconnect_fraction, interconnect_share_of_total
    )
    bus = bus_power_arr(
        rows, cols, b_h, b_v, pe_area, a_h, a_v, aspect, vdd, freq_hz, wire_cap_f_per_um, xp=xp
    )
    return {"bus_w": bus, "fixed_interconnect_w": fixed + 0 * bus, "compute_w": compute + 0 * bus}


def power_breakdown(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    aspect: float,
    cfg: EnergyModelConfig = EnergyModelConfig(),
    reference_act: BusActivity | None = None,
) -> PowerBreakdown:
    """Power breakdown at a given aspect ratio.

    The fixed (non-bus) interconnect power and the compute power are anchored
    to the *square* layout under ``reference_act`` (defaults to ``act``): the
    calibration fractions describe the square design's power split, and those
    absolute watts do not change when only the floorplan aspect changes
    (clock tree + cell-internal power are aspect-invariant to first order).
    """
    ref = reference_act if reference_act is not None else act
    parts = power_breakdown_arr(
        geom.rows,
        geom.cols,
        geom.b_h,
        geom.b_v,
        geom.pe_area_um2,
        act.a_h,
        act.a_v,
        aspect,
        vdd=cfg.vdd,
        freq_hz=cfg.freq_hz,
        wire_cap_f_per_um=cfg.wire_cap_f_per_um,
        non_bus_interconnect_fraction=cfg.non_bus_interconnect_fraction,
        interconnect_share_of_total=cfg.interconnect_share_of_total,
        ref_a_h=ref.a_h,
        ref_a_v=ref.a_v,
        xp=np,
    )
    return PowerBreakdown(
        aspect=aspect,
        bus_w=float(parts["bus_w"]),
        fixed_interconnect_w=float(parts["fixed_interconnect_w"]),
        compute_w=float(parts["compute_w"]),
    )


@dataclasses.dataclass(frozen=True)
class SymAsymComparison:
    aspect_opt: float
    sym: PowerBreakdown
    asym: PowerBreakdown

    @property
    def interconnect_saving(self) -> float:
        return 1.0 - self.asym.interconnect_w / self.sym.interconnect_w

    @property
    def total_saving(self) -> float:
        return 1.0 - self.asym.total_w / self.sym.total_w

    @property
    def bus_saving(self) -> float:
        return 1.0 - self.asym.bus_w / self.sym.bus_w


def compare_sym_asym_arr(
    rows,
    cols,
    b_h,
    b_v,
    pe_area,
    a_h,
    a_v,
    *,
    design_a_h=None,
    design_a_v=None,
    ref_a_h=None,
    ref_a_v=None,
    aspect=None,
    vdd=0.9,
    freq_hz=1.0e9,
    wire_cap_f_per_um=0.20e-15,
    non_bus_interconnect_fraction=NON_BUS_INTERCONNECT_FRACTION,
    interconnect_share_of_total=INTERCONNECT_SHARE_OF_TOTAL,
    xp=None,
) -> dict:
    """Vectorized square-vs-rectangle comparison.

    The asymmetric aspect is ``aspect`` when given, else the Eq. 6 optimum of
    the design activities (``design_a_h/v``, defaulting to ``a_h/v``).
    Returns arrays: ``aspect_opt``, the sym/asym bus powers, the shared
    ``fixed_interconnect_w``/``compute_w``, and the three relative savings.
    """
    xp = xp or _xp(rows, pe_area, a_h)
    d_h = a_h if design_a_h is None else design_a_h
    d_v = a_v if design_a_v is None else design_a_v
    aspect_opt = (
        optimal_aspect_power_arr(b_h, b_v, d_h, d_v, xp=xp) if aspect is None else aspect
    )
    kw = dict(
        vdd=vdd,
        freq_hz=freq_hz,
        wire_cap_f_per_um=wire_cap_f_per_um,
        non_bus_interconnect_fraction=non_bus_interconnect_fraction,
        interconnect_share_of_total=interconnect_share_of_total,
        ref_a_h=ref_a_h,
        ref_a_v=ref_a_v,
        xp=xp,
    )
    sym = power_breakdown_arr(rows, cols, b_h, b_v, pe_area, a_h, a_v, 1.0, **kw)
    asym = power_breakdown_arr(rows, cols, b_h, b_v, pe_area, a_h, a_v, aspect_opt, **kw)
    fixed = sym["fixed_interconnect_w"]
    compute = sym["compute_w"]
    sym_i = sym["bus_w"] + fixed
    asym_i = asym["bus_w"] + fixed
    return {
        "aspect_opt": aspect_opt + 0 * sym["bus_w"],
        "sym_bus_w": sym["bus_w"],
        "asym_bus_w": asym["bus_w"],
        "fixed_interconnect_w": fixed,
        "compute_w": compute,
        "bus_saving": 1.0 - asym["bus_w"] / sym["bus_w"],
        "interconnect_saving": 1.0 - asym_i / sym_i,
        "total_saving": 1.0 - (asym_i + compute) / (sym_i + compute),
    }


def compare_sym_asym(
    geom: SystolicArrayGeometry,
    act: BusActivity,
    cfg: EnergyModelConfig = EnergyModelConfig(),
    design_act: BusActivity | None = None,
    reference_act: BusActivity | None = None,
) -> SymAsymComparison:
    """Square vs power-optimal-rectangular floorplan on one workload.

    ``design_act`` (default: ``act``) picks the aspect ratio — a real chip
    fixes its floorplan at design time from *average* activities, then runs
    many workloads; pass the averaged profile here and the per-layer profile
    as ``act`` to reproduce the paper's per-layer Fig. 4 bars.
    """
    d_act = design_act if design_act is not None else act
    aspect = optimal_aspect_power(geom, d_act)
    sym = power_breakdown(geom, act, 1.0, cfg, reference_act=reference_act)
    asym = power_breakdown(geom, act, aspect, cfg, reference_act=reference_act)
    return SymAsymComparison(aspect_opt=aspect, sym=sym, asym=asym)


def average_comparison(comparisons: Sequence[SymAsymComparison]) -> dict[str, float]:
    """Workload-average savings (the paper's 'Average' bars in Fig. 4/5)."""
    if not comparisons:
        raise ValueError("no comparisons")
    sym_i = sum(c.sym.interconnect_w for c in comparisons)
    asym_i = sum(c.asym.interconnect_w for c in comparisons)
    sym_t = sum(c.sym.total_w for c in comparisons)
    asym_t = sum(c.asym.total_w for c in comparisons)
    return {
        "interconnect_saving": 1.0 - asym_i / sym_i,
        "total_saving": 1.0 - asym_t / sym_t,
        "sym_interconnect_w": sym_i / len(comparisons),
        "asym_interconnect_w": asym_i / len(comparisons),
        "sym_total_w": sym_t / len(comparisons),
        "asym_total_w": asym_t / len(comparisons),
    }
