"""Batched network-level profiling pipeline: jobs in, a few device programs out.

The per-GEMM entry point (``profile_gemm``) is fast *per call* but every
network-scale consumer used to drive it one GEMM at a time — paying a
host-side operand synthesis, a fresh pad, a host→device copy, a
shape-specialized recompile (~2s on CPU, twice per distinct shape) and a
blocking device round-trip per layer. This module turns a LIST of profiling
jobs into a handful of fused device programs:

  1. **Dedup** — each job is checked against the content-keyed profile cache
     first; identical (operands, geometry) pairs inside one batch, and the
     same operands profiled across several (rows, cols) geometries, share a
     single device pass (``a``'s horizontal toggles are geometry-independent
     up to ceil(N/cols) scaling, and the vertical totals depend on ``rows``
     but not ``cols`` — tiling the columns differently regroups, never
     changes, the per-column partial-sum streams).
  2. **Bucketing** — schedulable jobs are grouped into a small set of padded
     shape classes: same (rows, cols, b_h, b_v) and time extents rounded up
     to a shared power-of-two block count (≤2x T padding, count-neutral).
     Each bucket is ONE stacked-tile device program regardless of how many
     GEMMs or how ragged their K/N are (tiles, not jobs, are the batch
     axis — see ``repro.kernels.activity_profile.batch``).
  3. **Async dispatch** — bucket i's program is dispatched without blocking
     (jax async dispatch), so the device crunches while the host synthesizes
     and quantizes bucket i+1's operands; results are pulled only in the
     final collection phase.

Dataflow is a first-class job axis: ``ProfileJob.dataflow`` selects the
stream model.  WS jobs run the partial-sum task machinery above; OS jobs
need none of it — both OS buses carry raw operand streams over the K axis,
so each OS job schedules two GEOMETRY-FREE operand-stream passes (the A
rows as (K, M) lane streams at width b_h, the W columns as (K, N) at b_v)
into strips-only *stream buckets*, and the totals are scaled by the
output-tile counts at collection (h by ceil(N/cols), v by ceil(M/rows) —
matching their transition denominators, so OS activities are geometry-
invariant and a layer profiled at ANY (rows, cols) shares the same passes).

Counts are bit-exact vs per-job ``profile_gemm`` (and the numpy oracle);
jobs the fused engine cannot take (operands beyond int16 range, degenerate
shapes, K/rows beyond the engine bounds, or an explicit numpy backend) fall
back to the serial path per job and are reported in ``BatchStats``.

Resilience
----------
Partial failure is a first-class outcome, not an abort.  Every failure is
classified into the typed taxonomy of ``repro.runtime.resilience`` and the
``on_error`` knob picks the policy:

  * ``"raise"``   (default) — fail fast with a TYPED error;
  * ``"degrade"`` — recover each affected job individually down the backend
    ladder (pallas kernel → XLA rendering → numpy oracle; every rung
    computes identical integer counts, so degradation is bit-exact), with
    per-rung retry + deterministic-jitter backoff for transient
    dispatch-class faults;
  * ``"skip"``    — failed jobs yield ``None`` in the profile list; every
    successful job's profile is still returned.

Contract violations (malformed jobs, out-of-contract explicit requests)
raise in EVERY mode — they are programming errors that recur identically on
each rung, and silently skipping them would hide bugs.

Dispatch is bounded by ``timeout_s``: a device shard that hangs past it is
treated as lost — the device is evicted through a ``HealthMonitor`` and the
shard's task slice is resubmitted ONCE to a surviving device before the
per-job ladder takes over.  Whatever happened, ``BatchStats.failure_report``
enumerates each failure with its typed cause and the recovery action taken,
and layered cache lookups (memory → on-disk store → compute) record
quarantined-and-recomputed corrupt store entries there too.
"""

from __future__ import annotations

import dataclasses
import os
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import numpy as np

from repro.core.switching import (
    ActivityProfile,
    _cache_get,
    _cache_key,
    _cache_put,
    _note_batch_stores,
    _operand_digest,
    _resolve_backend,
    DEFAULT_BACKEND,
    os_stream_counts,
    profile_gemm,
    profile_store,
)
from repro.runtime import faults
from repro.runtime.health import HealthMonitor
from repro.runtime.resilience import (
    CacheCorruptionError,
    ContractViolationError,
    DeviceDispatchError,
    FailureReport,
    ProfileDegradationWarning,
    ProfileError,
    RetryPolicy,
    call_with_retry,
    classify_exception,
    degradation_ladder,
)

__all__ = [
    "ProfileJob",
    "BatchStats",
    "run_profile_batch",
    "ON_ERROR_MODES",
]

ON_ERROR_MODES = ("raise", "degrade", "skip")

# Environment defaults: a chaos CI run (fault injection over the whole
# tier-1 suite) flips the fleet-wide policy to "degrade" without touching
# call sites; a serving deployment pins a dispatch budget the same way.
DEFAULT_ON_ERROR = os.environ.get("REPRO_ON_ERROR", "raise")
_env_timeout = os.environ.get("REPRO_PROFILE_TIMEOUT_S", "").strip()
DEFAULT_TIMEOUT_S: float | None = float(_env_timeout) if _env_timeout else None


@dataclasses.dataclass
class ProfileJob:
    """One GEMM-on-array profiling request.

    Operands come either eagerly (``a``/``w``) or lazily (``make`` returning
    ``(a, w)`` plus the declared ``shape=(m, k, n)``) — lazy jobs let the
    pipeline overlap operand synthesis with device work, and let bucket
    planning see shapes without materializing anything.  ``dataflow``
    selects the stream model ("WS" partial sums / "OS" operand streams).
    """

    rows: int
    cols: int
    b_h: int
    b_v: int
    a: np.ndarray | None = None
    w: np.ndarray | None = None
    make: Callable[[], tuple[np.ndarray, np.ndarray]] | None = None
    shape: tuple[int, int, int] | None = None
    name: str = ""
    dataflow: str = "WS"

    def label(self, index: int) -> str:
        return self.name or f"job{index}"

    def gemm_shape(self) -> tuple[int, int, int]:
        """(M, K, N) without materializing lazy operands."""
        if self.a is not None and self.w is not None:
            return (self.a.shape[0], self.a.shape[1], self.w.shape[1])
        if self.shape is None:
            raise ContractViolationError(
                f"lazy job {self.name!r} needs shape=(m, k, n)", job=self.name
            )
        return tuple(self.shape)

    def operands(self) -> tuple[np.ndarray, np.ndarray]:
        """Materialize (and keep) int64 operands, validated against shape."""
        if self.a is None or self.w is None:
            if self.make is None:
                raise ContractViolationError(
                    f"job {self.name!r} has neither operands nor make",
                    job=self.name,
                )
            a, w = self.make()
            self.a, self.w = np.asarray(a), np.asarray(w)
        a = np.asarray(self.a, dtype=np.int64)
        w = np.asarray(self.w, dtype=np.int64)
        if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
            raise ContractViolationError(
                f"bad GEMM shapes {a.shape} x {w.shape}", job=self.name
            )
        declared = (a.shape[0], a.shape[1], w.shape[1])
        if self.shape is not None and tuple(self.shape) != declared:
            raise ContractViolationError(
                f"job {self.name!r}: declared shape {tuple(self.shape)} != "
                f"materialized {declared}",
                job=self.name,
            )
        self.a, self.w = a, w
        return a, w


@dataclasses.dataclass
class BatchStats:
    """What the scheduler actually did (regression-tested invariants)."""

    jobs: int = 0
    cache_hits: int = 0
    store_hits: int = 0  # cache_hits served by the on-disk store layer
    passes: int = 0  # device operand-passes scheduled (strips + tiles)
    pass_reuse: int = 0  # jobs served by an already-scheduled pass
    buckets: int = 0  # padded shape classes == fused programs dispatched
    serial_fallbacks: int = 0
    tasks: int = 0  # stacked (tile, segment) device tasks across all buckets
    strips: int = 0  # stacked seeded stream windows across all buckets
    retries: int = 0  # extra attempts spent inside recovery ladders
    degraded: int = 0  # jobs recovered per-job after a batched-path failure
    skipped: int = 0  # jobs returned as None under on_error="skip"
    resubmits: int = 0  # device shards resubmitted after eviction
    failure_report: FailureReport = dataclasses.field(default_factory=FailureReport)

    def as_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["failure_report"] = self.failure_report.as_dict()
        return out


@dataclasses.dataclass
class _Pass:
    """One scheduled (a, w, rows) device pass inside a bucket."""

    bucket: int
    strip_lo: int
    strip_hi: int
    tile_lo: int
    tile_hi: int
    h_total: int | None = None
    v_total: int | None = None


@dataclasses.dataclass
class _Shard:
    """One dispatched slice of a bucket's task axis (resubmittable)."""

    label: str
    args: tuple  # (strips, w_tiles, ids, wids, vr)
    kwargs: dict
    device_index: int
    future: object
    resubmits: int = 0


@dataclasses.dataclass
class _Bucket:
    rows: int
    cols: int
    b_h: int
    b_v: int
    t_seg: int
    strips: list = dataclasses.field(default_factory=list)
    w_tiles: list = dataclasses.field(default_factory=list)
    strip_ids: list = dataclasses.field(default_factory=list)
    w_ids: list = dataclasses.field(default_factory=list)
    valid_r: list = dataclasses.field(default_factory=list)
    shards: list = dataclasses.field(default_factory=list)  # [_Shard]
    error: ProfileError | None = None


@dataclasses.dataclass
class _StreamPass:
    """One scheduled geometry-free operand-stream pass (OS jobs)."""

    bucket: int
    strip_lo: int
    strip_hi: int
    total: int | None = None


@dataclasses.dataclass
class _StreamBucket:
    """Strips-only shape class for OS operand streams: (bits, t_seg)."""

    bits: int
    t_seg: int
    strips: list = dataclasses.field(default_factory=list)
    future: object | None = None  # -> per-strip int64 totals
    error: ProfileError | None = None


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


# Segment-length ceiling. 128 keeps the per-lane scan state (t_seg + 1,
# cols) cache-resident AND collapses every stream longer than one segment
# into the same shape class — short and long layers of one geometry share a
# single compiled program (tail rounding stays <= 2x and count-neutral).
MAX_SEG_T = 128


# Lane width of OS operand-stream strips.  Purely a batching shape — OS lane
# streams are independent, so the chop never has to match the array geometry
# (zero-padded lanes toggle nothing) and one constant collapses every OS job
# of a given (bits, t_seg) onto one program shape.
OS_LANE_CHUNK = 64


def _os_t_seg(k: int) -> int:
    """Stream-bucket segment length for a K-step OS operand stream."""
    return min(MAX_SEG_T, _next_pow2(max(1, -(-k // 8))) * 8)


def _bucket_key(job: ProfileJob) -> tuple:
    """Padded shape class: geometry + bus widths + pow2 segment length.

    ``t_seg`` is the segment ceiling (bounded further by the VMEM block
    budget for huge geometries) capped to the job's own stream length
    rounded up to a power of two — so short-stream jobs don't pad to the
    long-stream class and a whole workload collapses into a couple of
    program shapes.  OS jobs class by bus widths + their K-axis segment
    length only: their stream passes are geometry-free.
    """
    from repro.kernels.activity_profile.kernel import choose_block_t

    m, k, _ = job.gemm_shape()
    if job.dataflow == "OS":
        return ("OS", job.b_h, job.b_v, _os_t_seg(k))
    t_seg = min(
        MAX_SEG_T,
        choose_block_t(job.rows, job.cols),
        _next_pow2(max(1, -(-m // 8))) * 8,
    )
    return (job.rows, job.cols, job.b_h, job.b_v, t_seg)


def _fused_eligible(job: ProfileJob, a: np.ndarray, w: np.ndarray) -> bool:
    """Mirror of profile_gemm_toggles' contract checks (raise-free)."""
    from repro.kernels.activity_profile.ops import (
        MAX_FUSED_K,
        MAX_FUSED_LANES,
        MAX_FUSED_ROWS,
        operands_fit_fused,
    )

    m, k, n = job.gemm_shape()
    if job.dataflow == "OS":
        if k < 2 or m == 0 or n == 0:
            return False  # zero transitions: serial path returns zeros instantly
        if max(m, n) >= MAX_FUSED_LANES:
            return False
        return operands_fit_fused(a, w)
    if m < 2 or k == 0 or n == 0:
        return False  # zero transitions: serial path returns zeros instantly
    if k + job.rows >= MAX_FUSED_K or job.rows >= MAX_FUSED_ROWS:
        return False
    return operands_fit_fused(a, w)


def _schedule_job(job, a, w, t_trim, bucket_map, buckets, pass_map, stats):
    """Attach one job to a (possibly shared) device pass, creating buckets
    and stacking segment strips / weight tiles / tasks as needed. Returns
    the job's pass key. ``t_trim`` caps the bucket's segment length at the
    class's actual longest stream (8-aligned) so short-stream classes don't
    compute their pow2 rounding."""
    from repro.kernels.activity_profile.batch import segment_strips

    m, k, n = job.gemm_shape()
    # Shapes are part of the key: digests hash raw bytes, and the same bytes
    # reshaped to a different (M, K)/(K, N) are a different stream.
    pass_key = (
        _operand_digest(a), _operand_digest(w), (m, k, n),
        job.rows, job.b_h, job.b_v,
    )
    if pass_key in pass_map:
        stats.pass_reuse += 1
        return pass_key

    bkey = _bucket_key(job)
    if bkey not in bucket_map:
        bucket_map[bkey] = len(buckets)
        buckets.append(
            _Bucket(job.rows, job.cols, job.b_h, job.b_v, min(bkey[-1], t_trim))
        )
    bidx = bucket_map[bkey]
    bucket = buckets[bidx]
    rows, cols = job.rows, job.cols

    strip_lo = len(bucket.strips)
    bucket.strips.extend(segment_strips(a, rows, bucket.t_seg))
    n_seg = (len(bucket.strips) - strip_lo) // (-(-k // rows))

    pk = (-k) % rows
    pn = (-n) % cols
    w_pad = np.pad(w.astype(np.int32), ((0, pk), (0, pn)))
    k_tiles = -(-k // rows)
    n_tiles = -(-n // cols)
    w_lo = len(bucket.w_tiles)
    for kt in range(k_tiles):
        for nt in range(n_tiles):
            bucket.w_tiles.append(
                np.ascontiguousarray(
                    w_pad[kt * rows : (kt + 1) * rows, nt * cols : (nt + 1) * cols]
                )
            )
    task_lo = len(bucket.strip_ids)
    for kt in range(k_tiles):
        vr = min(rows, k - kt * rows)
        for nt in range(n_tiles):
            for s in range(n_seg):
                bucket.strip_ids.append(strip_lo + kt * n_seg + s)
                bucket.w_ids.append(w_lo + kt * n_tiles + nt)
                bucket.valid_r.append(vr)
    pass_map[pass_key] = _Pass(
        bidx, strip_lo, len(bucket.strips), task_lo, len(bucket.strip_ids)
    )
    stats.passes += 1
    return pass_key


def _schedule_os_job(
    job, a, w, stream_bucket_map, stream_buckets, stream_pass_map, stats
):
    """Attach one OS job to its two operand-stream passes (A rows at b_h,
    W columns at b_v), creating stream buckets as needed.  Pass keys carry
    NO geometry — OS per-lane stream totals are (rows, cols)-free; the
    collection phase scales them by each job's own tile counts.  Returns
    the (A-pass key, W-pass key) pair."""
    from repro.kernels.activity_profile.batch import segment_strips

    m, k, n = job.gemm_shape()
    keys = []
    for tag, arr, shape, bits in (
        ("A", a, (m, k), job.b_h),
        ("W", w, (k, n), job.b_v),
    ):
        key = ("os", tag, _operand_digest(arr), shape, bits)
        keys.append(key)
        if key in stream_pass_map:
            stats.pass_reuse += 1
            continue
        # Stream matrices are time(K)-major: A rows transpose, W is already.
        stream = np.ascontiguousarray(arr.T) if tag == "A" else arr
        t_seg = _os_t_seg(k)
        bkey = (bits, t_seg)
        if bkey not in stream_bucket_map:
            stream_bucket_map[bkey] = len(stream_buckets)
            stream_buckets.append(_StreamBucket(bits, t_seg))
        bidx = stream_bucket_map[bkey]
        bucket = stream_buckets[bidx]
        strip_lo = len(bucket.strips)
        bucket.strips.extend(segment_strips(stream, OS_LANE_CHUNK, bucket.t_seg))
        stream_pass_map[key] = _StreamPass(bidx, strip_lo, len(bucket.strips))
        stats.passes += 1
    return tuple(keys)


def _ladder_recover(
    job: ProfileJob,
    label: str,
    cause: ProfileError,
    *,
    engine: str,
    interpret: bool,
    use_cache: bool,
    store_key: bytes | None,
    policy: RetryPolicy,
    stats: BatchStats,
    report: FailureReport,
):
    """Recover ONE job down the backend ladder after a batched-path failure.

    Walks ``degradation_ladder(engine)`` rung by rung.  Dispatch-class
    faults (device loss, timeouts, runtime errors) are retried within a
    rung under ``policy``'s backoff; compile-class and contract faults
    descend immediately — they recur deterministically.  Every rung
    computes identical integer toggle counts, so whichever rung lands
    first yields the bit-exact profile.  Returns ``(profile, None)`` or
    ``(None, last_error)`` if even the numpy oracle failed.
    """
    from repro.kernels.activity_profile.ops import profile_gemm_toggles

    try:
        a, w = job.operands()
    except Exception as exc:  # malformed job: nothing to degrade to
        return None, classify_exception(exc, job=label, stage="recover")

    inj = faults.active()
    last = cause
    for rung in degradation_ladder(engine):

        def attempt(rung=rung):
            if inj is not None:
                inj.maybe_fail_backend(f"ladder:{rung}", label)
                inj.maybe_lose_device(f"ladder:{rung}", label)
            if rung == "numpy":
                return profile_gemm(
                    a, w, job.rows, job.cols, job.b_h, job.b_v,
                    dataflow=job.dataflow, backend="numpy", use_cache=False,
                )
            counts = profile_gemm_toggles(
                a, w, job.rows, job.cols, job.b_h, job.b_v,
                dataflow=job.dataflow, engine=rung, interpret=interpret,
            )
            a_h, a_v = counts.activities(job.b_h, job.b_v)
            return ActivityProfile(
                a_h=a_h,
                a_v=a_v,
                b_h=job.b_h,
                b_v=job.b_v,
                h_transitions=counts.h_transitions,
                v_transitions=counts.v_transitions,
                input_zero_fraction=float(np.mean(a == 0)),
                input_elements=int(a.size),
            )

        try:
            profile, attempts, _ = call_with_retry(
                attempt,
                policy=policy,
                key=f"{label}:{rung}",
                retry_on=(DeviceDispatchError,),
            )
        except ProfileError as err:
            stats.retries += getattr(err, "attempts", 1) - 1
            last = err
            continue
        stats.retries += attempts - 1
        stats.degraded += 1
        # Record the ORIGINAL cause, not the last rung's failure: the report
        # answers "what fault made this job degrade", and intermediate rung
        # descents are bookkept in stats.retries.
        report.add(
            cause,
            action=f"degraded:{rung}",
            job=label,
            stage="recover",
            attempts=attempts,
        )
        if use_cache and store_key is not None:
            # Counts are rung-invariant, so the recovered profile is stored
            # under the job's ORIGINAL batched-path key: the next run hits
            # the cache instead of re-dispatching the fused program.
            _cache_put(store_key, profile)
        return profile, None
    return None, last


def run_profile_batch(
    jobs: Sequence[ProfileJob],
    *,
    backend: str | None = None,
    engine: str = "auto",
    interpret: bool = False,
    use_cache: bool = True,
    on_error: str | None = None,
    timeout_s: float | None = None,
    retry: RetryPolicy | None = None,
    health: HealthMonitor | None = None,
) -> tuple[list[ActivityProfile | None], BatchStats]:
    """Profile every job; returns (profiles in input order, scheduler stats).

    ``backend`` follows ``profile_gemm``: ``"numpy"`` runs the serial
    oracle per job (no device work at all); ``"pallas"``/``"auto"`` run the
    batched fused pipeline with per-job fallback to serial for operands the
    engine cannot take. ``engine``/``interpret`` pick the device rendering
    (Pallas kernel on TPU, XLA elsewhere) exactly like the per-GEMM engine.

    ``on_error`` selects the failure policy (default ``$REPRO_ON_ERROR`` or
    ``"raise"``): ``"raise"`` fails fast with a typed
    ``repro.runtime.resilience.ProfileError``; ``"degrade"`` recovers each
    affected job individually down the backend ladder (bit-exact — every
    rung computes the same integer counts); ``"skip"`` returns ``None`` for
    failed jobs and every successful profile.  Contract violations
    (malformed jobs) raise in all modes.  ``timeout_s`` (default
    ``$REPRO_PROFILE_TIMEOUT_S`` or unbounded) bounds each dispatched
    shard; a shard that exceeds it has its device evicted via ``health``
    (a ``HealthMonitor``, created internally when not passed) and its task
    slice resubmitted once to a surviving device.  ``retry`` is the
    ``RetryPolicy`` for transient faults inside recovery ladders.
    ``BatchStats.failure_report`` enumerates every failure with its typed
    cause and the recovery action taken.
    """
    from repro.kernels.activity_profile.batch import (
        bucket_toggle_parts,
        reduce_bucket_parts,
        reduce_stream_parts,
        stream_bucket_parts,
    )
    from repro.kernels.activity_profile.ops import ToggleCounts

    jobs = list(jobs)
    stats = BatchStats(jobs=len(jobs))
    report = stats.failure_report
    requested = backend if backend is not None else DEFAULT_BACKEND
    mode = on_error if on_error is not None else DEFAULT_ON_ERROR
    if mode not in ON_ERROR_MODES:
        raise ContractViolationError(
            f"unknown on_error mode {mode!r}; know {ON_ERROR_MODES}"
        )
    if timeout_s is None:
        timeout_s = DEFAULT_TIMEOUT_S
    policy = retry if retry is not None else RetryPolicy()
    store = profile_store()
    store_hits0 = store.stats["hits"] if store is not None else 0

    def _finish(profiles):
        if store is not None:
            stats.store_hits = store.stats["hits"] - store_hits0
            for hexkey in store.drain_quarantine_events():
                report.add(
                    CacheCorruptionError(
                        f"store entry {hexkey[:16]}… failed integrity "
                        "verification",
                        stage="store",
                    ),
                    action="quarantined:recomputed",
                    job=hexkey[:16],
                )
        if use_cache:
            _note_batch_stores(stats.jobs - stats.cache_hits)
        return profiles, stats

    def _serial_job(job, i, resolved_backend):
        """One serial-path profile under the active failure policy."""
        label = job.label(i)
        try:
            a, w = job.operands()
            inj = faults.active()
            if inj is not None and resolved_backend != "numpy":
                inj.maybe_fail_backend("serial", label)
            return profile_gemm(
                a, w, job.rows, job.cols, job.b_h, job.b_v,
                dataflow=job.dataflow, backend=resolved_backend,
                use_cache=use_cache,
            )
        except Exception as exc:
            err = classify_exception(exc, job=label, stage="serial")
            if mode == "raise" or isinstance(err, ContractViolationError):
                raise err from exc
            if mode == "degrade" and resolved_backend != "numpy":
                profile, ladder_err = _ladder_recover(
                    job, label, err,
                    engine=engine, interpret=interpret, use_cache=use_cache,
                    store_key=None, policy=policy, stats=stats, report=report,
                )
                if profile is not None:
                    return profile
                err = ladder_err
            stats.skipped += 1
            report.add(err, action="skipped", job=label, stage="serial")
            return None

    if requested == "numpy":
        # Serial oracle per job: no jax import, no device or thread work at
        # all (the docstring's contract for numpy-only callers).
        stats.serial_fallbacks = len(jobs)
        profiles = [_serial_job(job, i, "numpy") for i, job in enumerate(jobs)]
        return _finish(profiles)

    # resolution[i]: ("cache", profile) | ("pass", key) | ("os_pass", keys)
    #             | ("serial", backend) | ("failed", typed error)
    resolution: list[tuple] = [None] * len(jobs)
    bucket_map: dict[tuple, int] = {}
    buckets: list[_Bucket] = []
    pass_map: dict[tuple, _Pass] = {}
    stream_bucket_map: dict[tuple, int] = {}
    stream_buckets: list[_StreamBucket] = []
    stream_pass_map: dict[tuple, _StreamPass] = {}

    # Group by shape class first (shapes are declared, operands still lazy),
    # then materialize + dispatch bucket by bucket: while bucket i compiles
    # (worker thread) and computes on-device, the main thread synthesizes
    # bucket i+1's operands.
    order: dict[tuple, list[int]] = {}
    for i, job in enumerate(jobs):
        order.setdefault(_bucket_key(job), []).append(i)

    # Device fan-out: each bucket's TASK axis is sharded across the local
    # devices (contiguous slices, padded to one shared shape class so every
    # shard reuses the same compiled program) and the shards execute
    # genuinely in parallel — on TPU pods, or on CPU hosts running with
    # ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. The serial
    # per-GEMM path cannot do this: it blocks on every layer's result.
    try:
        import jax

        devices = jax.local_devices()
    except (ImportError, RuntimeError) as exc:  # pragma: no cover - no jax
        # Narrow on purpose: ImportError = jax genuinely absent,
        # RuntimeError = jax present but backend init failed.  Anything else
        # is a real bug that must NOT masquerade as "jax unavailable".
        warnings.warn(
            f"batched pipeline: jax unavailable for device dispatch "
            f"({type(exc).__name__}: {exc}); falling back to a single "
            "anonymous device slot",
            ProfileDegradationWarning,
            stacklevel=2,
        )
        devices = [None]

    if health is None:
        health = HealthMonitor(range(len(devices)))

    executor = ThreadPoolExecutor(max_workers=max(2, len(devices)))

    def _run_shard(args, kw, device_index, site):
        """Executor task for one shard: fault hooks, compile + dispatch,
        BLOCKING reduce — so ``future.result(timeout=...)`` bounds the whole
        device round-trip, not just program construction."""
        inj = faults.active()
        if inj is not None:
            inj.maybe_fail_backend("bucket-dispatch", site)
            inj.maybe_hang("bucket-exec", site)
            inj.maybe_lose_device("bucket-shard", site)
        parts = bucket_toggle_parts(*args, device=devices[device_index], **kw)
        return reduce_bucket_parts(*parts)

    def _submit_bucket(bidx: int, b: _Bucket) -> list[_Shard]:
        """One executor task per shard: shard compiles (each device binding
        compiles its own executable) and executions all run concurrently."""
        strips = np.stack(b.strips)
        w_tiles = np.stack(b.w_tiles)
        ids = np.asarray(b.strip_ids, np.int32)
        wids = np.asarray(b.w_ids, np.int32)
        vr = np.asarray(b.valid_r, np.int32)
        n_shards = min(len(devices), max(1, len(ids) // 64))
        kw = dict(
            rows=b.rows, cols=b.cols, b_h=b.b_h, b_v=b.b_v,
            engine=engine, interpret=interpret,
        )
        if n_shards == 1:
            args = (strips, w_tiles, ids, wids, vr)
            site = f"b{bidx}s0d0"
            return [
                _Shard(site, args, kw, 0,
                       executor.submit(_run_shard, args, kw, 0, site))
            ]
        # Equal-length slices (tail padded with valid_r=0 dummies that count
        # zero) so every shard lowers the same program shape. Only shard 0's
        # h_parts are used at collection — h is per-strip and every shard
        # sees the full strips array.
        per = -(-len(ids) // n_shards)
        pad = n_shards * per - len(ids)
        if pad:
            zeros = np.zeros(pad, np.int32)
            ids = np.concatenate([ids, zeros])
            wids = np.concatenate([wids, zeros])
            vr = np.concatenate([vr, zeros])
        shards = []
        for s in range(n_shards):
            args = (
                strips, w_tiles,
                ids[s * per : (s + 1) * per],
                wids[s * per : (s + 1) * per],
                vr[s * per : (s + 1) * per],
            )
            didx = s % len(devices)
            site = f"b{bidx}s{s}d{didx}"
            shards.append(
                _Shard(site, args, kw, didx,
                       executor.submit(_run_shard, args, kw, didx, site))
            )
        return shards

    def _run_stream(strips, bits, site):
        inj = faults.active()
        if inj is not None:
            inj.maybe_fail_backend("stream-dispatch", site)
            inj.maybe_hang("stream-exec", site)
        parts = stream_bucket_parts(
            strips, bits=bits, engine=engine, interpret=interpret
        )
        return reduce_stream_parts(parts)

    def _await_shard(shard: _Shard):
        """Block on one shard (bounded by ``timeout_s``); returns
        ``(h, v, error)``.  A dispatch-class failure evicts the shard's
        device through the health monitor and resubmits the task slice
        EXACTLY ONCE to a surviving device before giving up on the shard."""
        while True:
            t0 = time.monotonic()
            try:
                h, v = shard.future.result(timeout=timeout_s)
                health.heartbeat(shard.device_index, time.monotonic())
                health.report_step_time(
                    shard.device_index, time.monotonic() - t0
                )
                return h, v, None
            except Exception as exc:
                err = classify_exception(exc, stage="dispatch", job=shard.label)
                if mode == "raise":
                    raise err from exc
                if (
                    shard.resubmits == 0
                    and isinstance(err, DeviceDispatchError)
                    and len(devices) > 1
                ):
                    health.evict(shard.device_index)
                    alive = health.alive_hosts()
                    if alive:
                        new_idx = alive[shard.resubmits % len(alive)]
                        report.add(
                            err,
                            action="device-evicted:resubmitted",
                            job=shard.label,
                            stage="dispatch",
                        )
                        shard.resubmits += 1
                        shard.device_index = new_idx
                        stats.resubmits += 1
                        shard.future = executor.submit(
                            _run_shard, shard.args, shard.kwargs, new_idx,
                            shard.label,
                        )
                        continue
                return None, None, err

    prefetch_pool = ThreadPoolExecutor(max_workers=1)
    try:
        if devices != [None]:
            # Pay the one-time XLA/LLVM backend spin-up concurrently with
            # the first bucket's operand synthesis instead of inside its
            # (timed) first compile.
            import jax.numpy as jnp

            executor.submit(jax.jit(lambda x: x + 1), jnp.zeros(8, jnp.int32))

        # Materialize lazy operands a bounded window ahead on a side thread
        # (numpy synthesis releases the GIL), in the same order the group
        # loop consumes them — the window keeps host memory at a few jobs'
        # operands, not the whole workload's.
        consume_order = [i for members in order.values() for i in members]
        prefetched: dict[int, object] = {}
        window = 3

        def _advance_prefetch():
            while consume_order and len(prefetched) < window:
                nxt = consume_order.pop(0)
                prefetched[nxt] = prefetch_pool.submit(jobs[nxt].operands)

        _advance_prefetch()

        for bkey, members in order.items():
            t_trim = max(
                -(-jobs[i].gemm_shape()[0] // 8) * 8 for i in members
            )
            for i in members:
                job = jobs[i]
                try:
                    a, w = prefetched.pop(i).result()
                except Exception as exc:
                    # Malformed jobs are programming errors: typed, and
                    # raised in EVERY mode (skipping them would hide bugs).
                    raise classify_exception(
                        exc, job=job.label(i), stage="schedule"
                    ) from exc
                _advance_prefetch()
                resolved = _resolve_backend(backend, a, w, job.rows, job.dataflow)
                if use_cache:
                    key = _cache_key(
                        a, w, job.rows, job.cols, job.b_h, job.b_v,
                        (resolved, job.dataflow, "exact"),
                    )
                    hit, _source = _cache_get(key)
                    if hit is not None:
                        resolution[i] = ("cache", hit)
                        stats.cache_hits += 1
                        continue
                if resolved == "numpy" or not _fused_eligible(job, a, w):
                    if requested == "pallas" and resolved != "numpy":
                        # match profile_gemm(backend="pallas"): loud
                        # contract failure instead of a silent oracle detour
                        from repro.kernels.activity_profile.ops import (
                            profile_gemm_toggles,
                        )

                        profile_gemm_toggles(
                            a, w, job.rows, job.cols, job.b_h, job.b_v,
                            dataflow=job.dataflow,
                        )
                    resolution[i] = ("serial", resolved)
                    stats.serial_fallbacks += 1
                    continue
                if job.dataflow == "OS":
                    keys = _schedule_os_job(
                        job, a, w, stream_bucket_map, stream_buckets,
                        stream_pass_map, stats,
                    )
                    kind = "os_pass"
                else:
                    keys = _schedule_job(
                        job, a, w, t_trim, bucket_map, buckets, pass_map, stats
                    )
                    kind = "pass"
                # Record the operand statistics (and the content-cache store
                # key) now and release lazy jobs' operands: the device holds
                # the (int32) strip copies, so keeping every job's int64
                # operands alive until collection would scale host memory
                # with the whole workload.
                store_key = (
                    _cache_key(
                        a, w, job.rows, job.cols, job.b_h, job.b_v,
                        ("pallas", job.dataflow, "exact"),
                    )
                    if use_cache
                    else None
                )
                resolution[i] = (
                    kind,
                    (keys, float(np.mean(a == 0)), int(a.size), store_key),
                )
                if job.make is not None:
                    job.a = job.w = None
            # Hand every program this shape class produced to a worker:
            # stacking + compile + async device dispatch happen off-thread.
            for bidx in {pass_map[r[1][0]].bucket for j in members
                         if (r := resolution[j])[0] == "pass"}:
                b = buckets[bidx]
                if not b.shards and b.strip_ids:
                    b.shards = _submit_bucket(bidx, b)
        # Stream buckets are submitted only after ALL groups are scheduled:
        # unlike WS buckets (whose bucket key IS the group key), one
        # (bits, t_seg) stream bucket can collect strips from several
        # (b_h, b_v) job groups, so an early submit would freeze it before
        # later groups append.  They are strips-only programs — a trivial
        # fraction of the device work — so the lost overlap is nil.
        for sidx, b in enumerate(stream_buckets):
            if b.future is None and b.strips:
                b.future = executor.submit(
                    _run_stream, np.stack(b.strips), b.bits, f"sb{sidx}"
                )

        stats.buckets = len(buckets) + len(stream_buckets)
        stats.tasks = sum(len(b.strip_ids) for b in buckets)
        stats.strips = sum(len(b.strips) for b in buckets) + sum(
            len(b.strips) for b in stream_buckets
        )

        # Collection: block on each bucket once (each shard bounded by
        # timeout_s), fold per-pass totals.  Sharded buckets: h comes from
        # shard 0 (identical in all shards), v concatenates the contiguous
        # task slices back together.  A bucket whose shards cannot be
        # recovered records its typed error; its jobs are degraded or
        # skipped per job below.
        reduced = []
        for b in buckets:
            if not b.shards:
                reduced.append(None)
                continue
            h_tot = None
            v_chunks = []
            for si, shard in enumerate(b.shards):
                h, v, err = _await_shard(shard)
                if err is not None:
                    b.error = err
                    break
                if si == 0:
                    h_tot = h
                v_chunks.append(v)
            if b.error is not None:
                reduced.append(None)
                continue
            reduced.append(
                (h_tot, np.concatenate(v_chunks)[: len(b.strip_ids)])
            )
        stream_reduced = []
        for b in stream_buckets:
            if b.future is None:
                stream_reduced.append(None)
                continue
            try:
                stream_reduced.append(b.future.result(timeout=timeout_s))
            except Exception as exc:
                err = classify_exception(exc, stage="dispatch")
                if mode == "raise":
                    raise err from exc
                b.error = err
                stream_reduced.append(None)
    finally:
        executor.shutdown(wait=True)
        prefetch_pool.shutdown(wait=True)
    for p in pass_map.values():
        if reduced[p.bucket] is None:
            continue  # failed bucket: totals stay None, jobs recover below
        h_tot, v_tot = reduced[p.bucket]
        p.h_total = int(h_tot[p.strip_lo : p.strip_hi].sum())
        p.v_total = int(v_tot[p.tile_lo : p.tile_hi].sum())
    for sp in stream_pass_map.values():
        if stream_reduced[sp.bucket] is None:
            continue
        sp.total = int(stream_reduced[sp.bucket][sp.strip_lo : sp.strip_hi].sum())

    def _recover_or_skip(i, job, cause, store_key):
        """Per-job policy application after a batched-path failure."""
        label = job.label(i)
        if mode == "degrade":
            profile, err = _ladder_recover(
                job, label, cause,
                engine=engine, interpret=interpret, use_cache=use_cache,
                store_key=store_key, policy=policy, stats=stats, report=report,
            )
            if profile is not None:
                return profile
            cause = err
        stats.skipped += 1
        report.add(cause, action="skipped", job=label, stage="collect")
        return None

    profiles: list[ActivityProfile | None] = []
    for i, job in enumerate(jobs):
        kind, payload = resolution[i]
        if kind == "cache":
            profiles.append(payload)
            continue
        if kind == "serial":
            profiles.append(_serial_job(job, i, payload))
            continue
        key, zero_fraction, elements, store_key = payload
        m, k, n = job.gemm_shape()
        n_tiles = -(-n // job.cols)
        if kind == "os_pass":
            key_a, key_w = key
            sps = (stream_pass_map[key_a], stream_pass_map[key_w])
            if any(sp.total is None for sp in sps):
                cause = next(
                    stream_buckets[sp.bucket].error
                    for sp in sps
                    if sp.total is None
                )
                profiles.append(_recover_or_skip(i, job, cause, store_key))
                continue
            # Geometry-free stream totals fold through the shared OS
            # accounting identity with each job's own output tiling.
            counts = ToggleCounts(
                *os_stream_counts(
                    sps[0].total, sps[1].total, m, k, n, job.rows, job.cols
                )
            )
            a_h, a_v = counts.activities(job.b_h, job.b_v)
            profiles.append(
                _store_profile(
                    job, counts, a_h, a_v, zero_fraction, elements, store_key
                )
            )
            continue
        p = pass_map[key]
        if p.h_total is None:
            profiles.append(
                _recover_or_skip(i, job, buckets[p.bucket].error, store_key)
            )
            continue
        counts = ToggleCounts(
            n_tiles * p.h_total,
            p.v_total,
            max(m - 1, 0) * k * n_tiles,
            max(m - 1, 0) * k * n,
        )
        a_h, a_v = counts.activities(job.b_h, job.b_v)
        profiles.append(
            _store_profile(job, counts, a_h, a_v, zero_fraction, elements, store_key)
        )
    return _finish(profiles)


def _store_profile(
    job: ProfileJob, counts, a_h, a_v, zero_fraction, elements, store_key
) -> ActivityProfile:
    """Build one job's profile from folded counts; memoize if keyed."""
    profile = ActivityProfile(
        a_h=a_h,
        a_v=a_v,
        b_h=job.b_h,
        b_v=job.b_v,
        h_transitions=counts.h_transitions,
        v_transitions=counts.v_transitions,
        input_zero_fraction=zero_fraction,
        input_elements=elements,
    )
    if store_key is not None:
        _cache_put(store_key, profile)
    return profile
