"""Bit-level switching-activity profiling of systolic-array data streams.

The paper's Eq. 6 needs the *average switching activity per bit* of every
bus, and what each bus carries is a property of the DATAFLOW
(``profile_gemm(..., dataflow=...)``):

Weight-stationary (``"WS"``, the paper's array):
  * horizontal buses (a_h): the input operands A[t, r] streamed into each
    row r of the array over the M axis;
  * vertical buses (a_v): the partial sums
    S[t, r, c] = sum_{r' <= r} A[t, r'] * W[r', c] flowing South out of
    each PE (r, c).

Output-stationary (``"OS"``): the accumulators never move — BOTH buses are
operand streams over the K (reduction) axis:
  * horizontal buses (a_h): each array row streams one A row, A[m, t];
  * vertical buses (a_v): each array column streams one W column, W[t, n].

Toggle statistics between *consecutive values on the same wire* are invariant
to the systolic pipeline skew (skew delays whole sequences; it does not
reorder them), so we profile the unskewed streams directly.

WS partial sums need up to ``2*B + ceil(log2 R)`` bits (37 for the paper's
config), so this module carries them as int64 and counts toggles on the
two's-complement representation truncated to the bus width.

Backends
--------
``profile_gemm`` dispatches between two implementations of the same
counts (verified bit-exact against each other in tests):

  * ``backend="numpy"`` — the host-side oracle below: per-tile Python loop,
    materialized (T, R, C) int64 cumsum. Exact int64 bit manipulation;
    slow, memory-heavy, kept as the verification reference.
  * ``backend="pallas"`` — the fused single-pass engine in
    ``repro.kernels.activity_profile``: one kernel grid over (weight tile,
    time block) computes the partial-sum cumsum in lo/hi int32 planes and
    toggle totals without ever materializing (T, R, C). Runs the Pallas TPU
    kernel on TPU hosts and an identical-math jitted XLA program elsewhere.
  * ``backend="auto"`` (default) — "pallas" whenever jax is importable and
    operands are int16-range (the engine's exactness contract), else numpy.

Exact full-stream profiling is the DEFAULT: every weight tile, every stream
step. Subsampling (``max_tiles``/``max_stream``) is an explicit opt-in and
both backends draw the identical subsample plan from the seed.

Results are memoized in a content-keyed cache (sha256 over operand bytes +
geometry + dataflow), so re-profiling an identical layer is free; see
``clear_profile_cache`` / ``profile_cache_info``.

``profile_ws_gemm`` / ``profile_ws_gemms`` / ``profile_ws_tile`` survive as
deprecated aliases of the dataflow-generic API (they forward to
``dataflow="WS"`` with a DeprecationWarning).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import warnings
from collections import OrderedDict
from typing import Iterable, Sequence

import numpy as np

from repro.runtime.resilience import (
    CacheThrashWarning,
    ContractViolationError,
    ProfileDegradationWarning,
)

__all__ = [
    "popcount",
    "toggles_between",
    "stream_toggle_rate",
    "stream_lane_toggles",
    "horizontal_stream",
    "vertical_partial_sums",
    "os_operand_streams",
    "os_stream_counts",
    "ActivityProfile",
    "profile_tile",
    "profile_gemm",
    "profile_gemms",
    "profile_ws_tile",
    "profile_ws_gemm",
    "profile_ws_gemms",
    "combine_profiles",
    "clear_profile_cache",
    "profile_cache_info",
    "set_profile_cache_capacity",
    "configure_profile_store",
    "profile_store",
    "profile_store_info",
]

DEFAULT_BACKEND = os.environ.get("REPRO_ACTIVITY_BACKEND", "auto")

_M1 = np.uint64(0x5555555555555555)
_M2 = np.uint64(0x3333333333333333)
_M4 = np.uint64(0x0F0F0F0F0F0F0F0F)
_H01 = np.uint64(0x0101010101010101)


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorized 64-bit population count (Hamming weight).

    Classic SWAR bit-twiddling; exact for any uint64 input.
    """
    x = np.asarray(x, dtype=np.uint64)
    x = x - ((x >> np.uint64(1)) & _M1)
    x = (x & _M2) + ((x >> np.uint64(2)) & _M2)
    x = (x + (x >> np.uint64(4))) & _M4
    return ((x * _H01) >> np.uint64(56)).astype(np.int64)


def _to_bus_repr(values: np.ndarray, bits: int) -> np.ndarray:
    """Two's-complement representation of ``values`` on a ``bits``-wide bus."""
    if not 1 <= bits <= 64:
        raise ValueError("bus width must be in [1, 64]")
    v = np.asarray(values).astype(np.int64)
    if bits == 64:
        return v.view(np.uint64)
    mask = np.uint64((1 << bits) - 1)
    return v.view(np.uint64) & mask


def toggles_between(a: np.ndarray, b: np.ndarray, bits: int) -> np.ndarray:
    """Number of bit flips when a ``bits``-wide bus goes from value a to b."""
    ua = _to_bus_repr(a, bits)
    ub = _to_bus_repr(b, bits)
    return popcount(ua ^ ub)


def stream_toggle_rate(stream: np.ndarray, bits: int, axis: int = 0) -> float:
    """Average toggles per bit per transition along ``axis`` of a value stream.

    For a stream of T values on one wire bundle, there are T-1 transitions;
    the rate is  mean_t popcount(x_t XOR x_{t+1}) / bits, averaged over every
    other axis (i.e. over all wires in the bundle).
    """
    s = np.asarray(stream)
    if s.shape[axis] < 2:
        return 0.0
    cur = np.take(s, range(0, s.shape[axis] - 1), axis=axis)
    nxt = np.take(s, range(1, s.shape[axis]), axis=axis)
    return float(np.mean(toggles_between(cur, nxt, bits))) / float(bits)


def stream_lane_toggles(stream: np.ndarray, bits: int, axis: int = 0) -> np.ndarray:
    """Per-bit-lane toggle totals along ``axis`` of a value stream: (bits,) int64.

    Entry b counts the flips of bus bit-lane b (LSB first) summed over every
    transition and every wire bundle in the stream; ``result.sum() ==
    bits * stream_toggle_rate(...) * transitions`` holds bit-exactly.  The
    numpy lane oracle behind ``profile_gemm(..., lane_detail=True)``.
    """
    s = np.asarray(stream)
    out = np.zeros(bits, np.int64)
    if s.shape[axis] < 2:
        return out
    cur = np.take(s, range(0, s.shape[axis] - 1), axis=axis)
    nxt = np.take(s, range(1, s.shape[axis]), axis=axis)
    x = _to_bus_repr(cur, bits) ^ _to_bus_repr(nxt, bits)
    one = np.uint64(1)
    for b in range(bits):
        out[b] = int(((x >> np.uint64(b)) & one).sum())
    return out


def horizontal_stream(a_tile: np.ndarray) -> np.ndarray:
    """The per-row horizontal bus streams for one WS tile.

    ``a_tile`` has shape (T, R): T time steps (one output row of the GEMM per
    step, in steady state) of R input operands. Row r's horizontal bus sees
    the sequence a_tile[:, r]. Returned unchanged (shape (T, R)); the stream
    axis is axis 0.
    """
    a = np.asarray(a_tile)
    if a.ndim != 2:
        raise ValueError("a_tile must be (T, R)")
    return a


def vertical_partial_sums(a_tile: np.ndarray, w_tile: np.ndarray) -> np.ndarray:
    """Partial-sum sequences on every vertical bus segment of one WS tile.

    Under weight-stationary dataflow, PE (r, c) emits
    S[t, r, c] = sum_{r' <= r} a_tile[t, r'] * w_tile[r', c] on its South bus.
    Shape: (T, R, C), int64 (exact for bus widths <= 63 bits).
    """
    a = np.asarray(a_tile, dtype=np.int64)
    w = np.asarray(w_tile, dtype=np.int64)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    # products[t, r, c] then prefix-sum down the rows (the reduction axis).
    products = a[:, :, None] * w[None, :, :]
    return np.cumsum(products, axis=1)


def os_operand_streams(
    a_tile: np.ndarray, w_tile: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """The per-lane bus streams of one OS output tile.

    ``a_tile`` is (Mt, K) — the A rows resident on the tile's array rows —
    and ``w_tile`` is (K, Nt).  Under output-stationary dataflow the
    horizontal bus of array row r carries a_tile[r, t] over the K reduction
    steps and the vertical bus of array column c carries w_tile[t, c]; no
    partial sum ever crosses a PE boundary.  Returns ``(h_streams (K, Mt),
    v_streams (K, Nt))`` with the stream axis leading, ready for
    ``stream_toggle_rate``.
    """
    a = np.asarray(a_tile)
    w = np.asarray(w_tile)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    return a.T, w


@dataclasses.dataclass(frozen=True)
class ActivityProfile:
    """Measured switching activities + supporting statistics for one workload.

    ``input_elements`` is the number of operand elements behind
    ``input_zero_fraction`` (0 for hand-built profiles — ``combine_profiles``
    then falls back to an unweighted mean for the zero fraction).

    ``h_lane_toggles`` / ``v_lane_toggles`` (present when profiled with
    ``lane_detail=True``) are the exact per-bit-lane toggle totals, LSB
    first: lane b of the ``b_h``/``b_v``-wide bus toggled that many times
    over ``h_transitions``/``v_transitions`` bundle transitions.  The lane
    sums reproduce the aggregate counts bit-exactly
    (``sum(h_lane_toggles) == round(a_h * h_transitions * b_h)``), and the
    mean of ``a_h_lanes`` is ``a_h`` — the aggregate activity IS the
    mean-lane approximation of the per-lane profile.  The segment-level
    layout engine (``repro.layout``) consumes the per-lane arrays to price
    buses that carry only a lane subset (e.g. multi-pod partial-sum buses).
    """

    a_h: float
    a_v: float
    b_h: int
    b_v: int
    h_transitions: int
    v_transitions: int
    input_zero_fraction: float
    input_elements: int = 0
    h_lane_toggles: tuple[int, ...] | None = None
    v_lane_toggles: tuple[int, ...] | None = None

    @property
    def a_h_lanes(self) -> np.ndarray | None:
        """(b_h,) per-lane horizontal activities (toggles per transition)."""
        if self.h_lane_toggles is None:
            return None
        return np.asarray(self.h_lane_toggles, float) / max(self.h_transitions, 1)

    @property
    def a_v_lanes(self) -> np.ndarray | None:
        """(b_v,) per-lane vertical activities (toggles per transition)."""
        if self.v_lane_toggles is None:
            return None
        return np.asarray(self.v_lane_toggles, float) / max(self.v_transitions, 1)

    def as_bus_activity(self):
        from repro.core.floorplan import BusActivity

        return BusActivity(a_h=self.a_h, a_v=self.a_v)


def profile_tile(
    a_tile: np.ndarray,
    w_tile: np.ndarray,
    b_h: int,
    b_v: int,
    dataflow: str = "WS",
) -> tuple[float, float, int, int]:
    """(a_h, a_v, #h transitions, #v transitions) for one R x C array tile.

    WS: ``a_tile`` is the (T, R) input stream of one weight tile,
    ``w_tile`` the resident (R, C) weights.  OS: ``a_tile`` is the (Mt, K)
    A rows of one output tile, ``w_tile`` the (K, Nt) W columns; both buses
    carry operand streams over K.
    """
    if dataflow == "OS":
        h, v = os_operand_streams(a_tile, w_tile)
        t = h.shape[0]
        a_h = stream_toggle_rate(h, b_h, axis=0)
        a_v = stream_toggle_rate(v, b_v, axis=0)
        h_trans = max(t - 1, 0) * h.shape[1]
        v_trans = max(t - 1, 0) * v.shape[1]
        return a_h, a_v, h_trans, v_trans
    if dataflow != "WS":
        raise ValueError(f"unknown dataflow {dataflow!r}")
    h = horizontal_stream(a_tile)
    v = vertical_partial_sums(a_tile, w_tile)
    t = a_tile.shape[0]
    a_h = stream_toggle_rate(h, b_h, axis=0)
    a_v = stream_toggle_rate(v, b_v, axis=0)
    h_trans = max(t - 1, 0) * h.shape[1]
    v_trans = max(t - 1, 0) * v.shape[1] * v.shape[2]
    return a_h, a_v, h_trans, v_trans


def _tile_plan(
    m: int,
    k: int,
    n: int,
    rows: int,
    cols: int,
    max_tiles: int | None,
    max_stream: int | None,
    seed: int,
) -> list[tuple[int, int, int, int, int, int]]:
    """Subsample plan: (k0, k1, n0, n1, t0, t1) per profiled tile.

    One function shared by BOTH backends so the numpy oracle and the fused
    engine see byte-identical subsamples (same rng draw order as the seed
    implementation: one tile choice, then one stream start per tile).
    Stream windows are consecutive — toggle statistics need adjacency.
    """
    rng = np.random.default_rng(seed)
    k_tiles = -(-k // rows)
    n_tiles = -(-n // cols)
    tile_ids = [(kt, nt) for kt in range(k_tiles) for nt in range(n_tiles)]
    if max_tiles is not None and len(tile_ids) > max_tiles:
        idx = rng.choice(len(tile_ids), size=max_tiles, replace=False)
        tile_ids = [tile_ids[i] for i in sorted(idx)]
    plan = []
    for kt, nt in tile_ids:
        t0, t1 = 0, m
        if max_stream is not None and m > max_stream:
            t0 = int(rng.integers(0, m - max_stream + 1))
            t1 = t0 + max_stream
        plan.append(
            (kt * rows, min((kt + 1) * rows, k), nt * cols, min((nt + 1) * cols, n), t0, t1)
        )
    return plan


def _fused_importable() -> bool:
    # ImportError only: a genuinely broken kernel package (bad refactor, jax
    # API drift) must raise loudly, not silently degrade every profile to
    # the slow numpy path.
    try:
        import repro.kernels.activity_profile.ops  # noqa: F401

        return True
    except ImportError:  # pragma: no cover - jax missing
        return False


def _warn_numpy_fallback(reason: str) -> None:
    # warnings dedups by (message, location), so this surfaces once per run.
    # Typed (ProfileDegradationWarning subclasses RuntimeWarning) so callers
    # can filter degradations from generic runtime noise.
    warnings.warn(
        f"profile_gemm: fused engine unavailable ({reason}); using the "
        "slow numpy oracle. Exact full-stream profiling is the default — "
        "pass max_tiles/max_stream to bound large workloads.",
        ProfileDegradationWarning,
        stacklevel=4,
    )


def _resolve_backend(
    backend: str | None,
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    dataflow: str = "WS",
) -> str:
    backend = backend if backend is not None else DEFAULT_BACKEND
    if backend == "auto":
        if not _fused_importable():
            _warn_numpy_fallback("jax not importable")
            return "numpy"
        from repro.kernels.activity_profile.ops import (
            MAX_FUSED_K,
            MAX_FUSED_LANES,
            MAX_FUSED_ROWS,
            operands_fit_fused,
        )

        if dataflow == "OS":
            dims_ok = max(a.shape[0], w.shape[1]) < MAX_FUSED_LANES
        else:
            dims_ok = a.shape[1] + rows < MAX_FUSED_K and rows < MAX_FUSED_ROWS
        if not dims_ok:
            _warn_numpy_fallback("GEMM/array dims beyond fused-engine bounds")
            return "numpy"
        if not operands_fit_fused(a, w):
            _warn_numpy_fallback("operands wider than int16")
            return "numpy"
        return "pallas"
    if backend not in ("numpy", "pallas"):
        raise ContractViolationError(f"unknown backend {backend!r}")
    return backend


# --- content-keyed profile cache -------------------------------------------
# Benchmarks and examples repeatedly profile the same synthetic layers; a
# profile is a pure function of (operands, geometry, plan), so memoize on
# content. Exact-mode keys ignore the seed (it only feeds the subsampler).
#
# Lookup is LAYERED: memory -> on-disk store -> compute.  The store
# (``repro.core.profile_store``) shares the same keys across processes; it
# is enabled by ``configure_profile_store(path)`` or ``$REPRO_PROFILE_STORE``
# and stays off otherwise (in-process behavior is then exactly the old
# memory-only cache).

_KEY_VERSION = "v4"  # also the on-disk store's schema-version directory

_PROFILE_CACHE: OrderedDict[bytes, ActivityProfile] = OrderedDict()
_PROFILE_CACHE_CAPACITY = max(
    1, int(os.environ.get("REPRO_PROFILE_CACHE_CAPACITY", "128"))
)
_PROFILE_CACHE_STATS = {"hits": 0, "misses": 0, "store_hits": 0, "evictions": 0}
_THRASH_WARNED = False

_PROFILE_STORE = None
_PROFILE_STORE_RESOLVED = False


def clear_profile_cache() -> None:
    """Drop the in-memory cache + reset its counters (the on-disk store, if
    configured, is NOT touched — it exists to outlive process state)."""
    global _THRASH_WARNED
    _PROFILE_CACHE.clear()
    for k in _PROFILE_CACHE_STATS:
        _PROFILE_CACHE_STATS[k] = 0
    _THRASH_WARNED = False


def profile_cache_info() -> dict:
    return {
        "size": len(_PROFILE_CACHE),
        "capacity": _PROFILE_CACHE_CAPACITY,
        **_PROFILE_CACHE_STATS,
    }


def set_profile_cache_capacity(capacity: int) -> int:
    """Set the in-memory LRU capacity (entries); returns the previous value.

    The default comes from ``$REPRO_PROFILE_CACHE_CAPACITY`` (128 when
    unset).  A single network-scale batch that stores more profiles than
    this thrashes mid-workload (see ``CacheThrashWarning``)."""
    global _PROFILE_CACHE_CAPACITY
    if capacity < 1:
        raise ContractViolationError("cache capacity must be >= 1")
    prev = _PROFILE_CACHE_CAPACITY
    _PROFILE_CACHE_CAPACITY = int(capacity)
    while len(_PROFILE_CACHE) > _PROFILE_CACHE_CAPACITY:
        _PROFILE_CACHE.popitem(last=False)
        _PROFILE_CACHE_STATS["evictions"] += 1
    return prev


def configure_profile_store(path=None, *, max_bytes=None):
    """Enable (or with ``path=None`` disable) the on-disk profile store.

    ``path`` may also be an existing ``ProfileStore`` instance, installed
    as-is with its statistics intact (callers that temporarily swap stores
    restore the previous one this way).  Returns the active ``ProfileStore``
    (or None).  Overrides any ``$REPRO_PROFILE_STORE`` environment
    configuration for this process."""
    global _PROFILE_STORE, _PROFILE_STORE_RESOLVED
    from repro.core.profile_store import ProfileStore, _DEFAULT_MAX_BYTES

    _PROFILE_STORE_RESOLVED = True
    if path is None:
        _PROFILE_STORE = None
        return None
    if isinstance(path, ProfileStore):
        _PROFILE_STORE = path
        return _PROFILE_STORE
    _PROFILE_STORE = ProfileStore(
        path,
        max_bytes=_DEFAULT_MAX_BYTES if max_bytes is None else max_bytes,
        version=_KEY_VERSION,
    )
    return _PROFILE_STORE


def profile_store():
    """The active on-disk store: explicit configuration first, else lazily
    from ``$REPRO_PROFILE_STORE`` (+ ``$REPRO_PROFILE_STORE_MAX_BYTES``),
    else None."""
    global _PROFILE_STORE, _PROFILE_STORE_RESOLVED
    if not _PROFILE_STORE_RESOLVED:
        _PROFILE_STORE_RESOLVED = True
        path = os.environ.get("REPRO_PROFILE_STORE", "").strip()
        if path:
            max_bytes = os.environ.get("REPRO_PROFILE_STORE_MAX_BYTES")
            configure_profile_store(
                path, max_bytes=int(max_bytes) if max_bytes else None
            )
    return _PROFILE_STORE


def profile_store_info() -> dict | None:
    store = profile_store()
    return None if store is None else store.info()


def _note_batch_stores(n_stored: int) -> None:
    """One-shot mid-workload thrash warning: a single batch stored more
    profiles than the memory cache holds, so jobs at the batch's end
    evicted entries its consumers (e.g. a design-space sweep re-reading
    every layer) still need."""
    global _THRASH_WARNED
    if _THRASH_WARNED or n_stored <= _PROFILE_CACHE_CAPACITY:
        return
    _THRASH_WARNED = True
    warnings.warn(
        f"one profiling batch stored {n_stored} profiles but the in-memory "
        f"cache holds only {_PROFILE_CACHE_CAPACITY}; mid-workload eviction "
        "will thrash re-reads. Raise REPRO_PROFILE_CACHE_CAPACITY or call "
        "set_profile_cache_capacity() to fit the working set.",
        CacheThrashWarning,
        stacklevel=3,
    )


def _operand_digest(arr: np.ndarray) -> bytes:
    """Value-canonical sha256 of one operand matrix.

    int16-range data (the common case) hashes at 2 bytes/element instead of
    the upcast 8, and equal values hit the same digest regardless of input
    dtype. Also used by the batch pipeline's cross-geometry pass reuse.
    """
    h = hashlib.sha256()
    if arr.size and -32768 <= int(arr.min()) and int(arr.max()) <= 32767:
        arr = arr.astype(np.int16)
    h.update(arr.dtype.str.encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.digest()


def _cache_key(
    a: np.ndarray, w: np.ndarray, rows, cols, b_h, b_v, mode: tuple
) -> bytes:
    """Content cache key.  ``mode`` is ``(backend, dataflow, *plan)`` — the
    dataflow MUST be encoded: WS and OS profiles of identical operands and
    geometry measure different streams and must never alias.  The "v4" bump
    adds the lane-detail flag to the plan (lane-resolved profiles carry
    strictly more data than aggregate ones and must not alias them; it also
    retires any pre-lane "v3" entry shape)."""
    h = hashlib.sha256()
    h.update(
        repr((_KEY_VERSION, a.shape, w.shape, rows, cols, b_h, b_v, mode)).encode()
    )
    for arr in (a, w):
        h.update(_operand_digest(arr))
    return h.digest()


def _cache_get(key: bytes) -> tuple[ActivityProfile | None, str | None]:
    """Layered lookup (memory -> disk store); returns ``(profile, source)``
    with ``source`` in ``("memory", "store", None)``.  Hit/miss accounting
    is shared with the batch pipeline; a store hit is promoted into the
    memory LRU (without a write-back to disk)."""
    hit = _PROFILE_CACHE.get(key)
    if hit is not None:
        _PROFILE_CACHE_STATS["hits"] += 1
        _PROFILE_CACHE.move_to_end(key)
        return hit, "memory"
    store = profile_store()
    if store is not None:
        hit = store.get(key)
        if hit is not None:
            _PROFILE_CACHE_STATS["store_hits"] += 1
            _cache_put(key, hit, write_store=False)
            return hit, "store"
    _PROFILE_CACHE_STATS["misses"] += 1
    return None, None


def _cache_put(
    key: bytes, profile: ActivityProfile, *, write_store: bool = True
) -> None:
    _PROFILE_CACHE[key] = profile
    while len(_PROFILE_CACHE) > _PROFILE_CACHE_CAPACITY:
        _PROFILE_CACHE.popitem(last=False)
        _PROFILE_CACHE_STATS["evictions"] += 1
    if write_store:
        store = profile_store()
        if store is not None:
            store.put(key, profile)


def _profile_numpy(a, w, b_h, b_v, plan) -> tuple[float, float, int, int]:
    """The seed per-tile oracle loop (materializes (T, R, C) per tile)."""
    h_num = v_num = 0.0
    h_den = v_den = 0
    for k0, k1, n0, n1, t0, t1 in plan:
        ah, av, ht, vt = profile_tile(a[t0:t1, k0:k1], w[k0:k1, n0:n1], b_h, b_v)
        h_num += ah * ht
        v_num += av * vt
        h_den += ht
        v_den += vt
    a_h = h_num / h_den if h_den else 0.0
    a_v = v_num / v_den if v_den else 0.0
    return a_h, a_v, h_den, v_den


def _lane_profile_numpy(
    a: np.ndarray, w: np.ndarray, rows: int, cols: int, b_h: int, b_v: int
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side WS per-lane oracle: exact (b_h,)/(b_v,) lane toggle totals.

    Materializes the per-tile (T, R, C) partial-sum tensor like the
    aggregate oracle — slow, kept as the verification reference for the
    lane-resolved XLA pass.
    """
    m, k = a.shape
    n = w.shape[1]
    n_tiles = -(-n // cols) if n else 0
    h_lanes = stream_lane_toggles(a, b_h) * n_tiles
    v_lanes = np.zeros(b_v, np.int64)
    for k0 in range(0, k, rows):
        for n0 in range(0, n, cols):
            ps = vertical_partial_sums(a[:, k0 : k0 + rows], w[k0 : k0 + rows, n0 : n0 + cols])
            v_lanes += stream_lane_toggles(ps.reshape(m, -1), b_v)
    return h_lanes, v_lanes


def _lane_profile_numpy_os(
    a: np.ndarray, w: np.ndarray, rows: int, cols: int, b_h: int, b_v: int
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side OS per-lane oracle (the lane form of ``_profile_numpy_os``)."""
    m, k = a.shape
    n = w.shape[1]
    if k < 2 or m == 0 or n == 0:
        return np.zeros(b_h, np.int64), np.zeros(b_v, np.int64)
    h_streams, v_streams = os_operand_streams(a, w)
    n_tiles = -(-n // cols)
    m_tiles = -(-m // rows)
    return (
        stream_lane_toggles(h_streams, b_h) * n_tiles,
        stream_lane_toggles(v_streams, b_v) * m_tiles,
    )


def os_stream_counts(
    base_h: int, base_v: int, m: int, k: int, n: int, rows: int, cols: int
) -> tuple[int, int, int, int]:
    """Fold per-lane OS stream totals into full-GEMM (h_tog, v_tog, h_trans,
    v_trans).

    Each output tile streams its A rows and W columns over the K axis, so
    the full-GEMM totals are the per-lane totals scaled by the orthogonal
    tile count (every nt repeats the A streams of its mt, and vice versa) —
    the scaling matches the transition denominators, so OS activities are
    geometry-invariant.  This is THE OS accounting identity; the numpy
    oracle, the fused engine, and the batch pipeline all fold through it
    (only ``ref.py`` recounts tile by tile, on purpose).
    """
    m_tiles = -(-m // rows) if m else 0
    n_tiles = -(-n // cols) if n else 0
    return (
        n_tiles * base_h,
        m_tiles * base_v,
        max(k - 1, 0) * m * n_tiles,
        max(k - 1, 0) * n * m_tiles,
    )


def _profile_numpy_os(a, w, rows, cols, b_h, b_v) -> tuple[float, float, int, int]:
    """Host-side OS oracle: per-lane operand-stream toggles, exact."""
    m, k = a.shape
    n = w.shape[1]
    if k < 2 or m == 0 or n == 0:
        _, _, h_trans, v_trans = os_stream_counts(0, 0, m, k, n, rows, cols)
        return 0.0, 0.0, h_trans, v_trans
    h_streams, v_streams = os_operand_streams(a, w)
    base_h = int(toggles_between(h_streams[:-1], h_streams[1:], b_h).sum())
    base_v = int(toggles_between(v_streams[:-1], v_streams[1:], b_v).sum())
    h_tog, v_tog, h_trans, v_trans = os_stream_counts(
        base_h, base_v, m, k, n, rows, cols
    )
    a_h = h_tog / (h_trans * b_h) if h_trans else 0.0
    a_v = v_tog / (v_trans * b_v) if v_trans else 0.0
    return a_h, a_v, h_trans, v_trans


def _profile_fused(
    a, w, rows, cols, b_h, b_v, plan, exact: bool, dataflow: str = "WS"
) -> tuple[float, float, int, int]:
    """The fused engine: exact whole-GEMM grid, or per-plan-entry for opt-in
    subsampling (each entry is a single-tile GEMM for the engine)."""
    from repro.kernels.activity_profile.ops import ToggleCounts, profile_gemm_toggles

    if exact:
        counts = profile_gemm_toggles(a, w, rows, cols, b_h, b_v, dataflow=dataflow)
    else:
        counts = ToggleCounts(0, 0, 0, 0)
        for k0, k1, n0, n1, t0, t1 in plan:
            counts = counts + profile_gemm_toggles(
                a[t0:t1, k0:k1], w[k0:k1, n0:n1], k1 - k0, n1 - n0, b_h, b_v
            )
    a_h, a_v = counts.activities(b_h, b_v)
    return a_h, a_v, counts.h_transitions, counts.v_transitions


def profile_gemm(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    max_tiles: int | None = None,
    max_stream: int | None = None,
    seed: int = 0,
    *,
    dataflow: str = "WS",
    backend: str | None = None,
    use_cache: bool = True,
    lane_detail: bool = False,
) -> ActivityProfile:
    """Profile the full GEMM ``a @ w`` tiled onto an R x C systolic array.

    Under ``dataflow="WS"`` the GEMM (M, K) x (K, N) is tiled into
    ceil(K/rows) * ceil(N/cols) weight tiles, each streaming all M input
    rows; under ``dataflow="OS"`` it is tiled into ceil(M/rows) *
    ceil(N/cols) output tiles, each streaming both operands over the K
    reduction axis (see the module docstring for what each bus carries).

    By default the profile is EXACT — every tile, every stream step (the
    fused engine makes this cheap). Pass ``max_tiles``/``max_stream`` to opt
    into the legacy WS subsampled estimate (consecutive stream windows —
    toggle statistics need adjacency); both backends then draw the identical
    subsample from ``seed``.  OS profiling is exact-only: its work is
    O(M*K + K*N) with no partial-sum tensor anywhere, so there is nothing
    worth subsampling (passing the limits with OS raises).

    ``lane_detail=True`` additionally measures the exact per-bit-lane toggle
    totals (``ActivityProfile.h_lane_toggles``/``v_lane_toggles``; the
    aggregate activities are then derived from the lane sums, so aggregate
    and lanes can never disagree).  Lane-resolved profiling is exact-only
    (combining it with the subsample limits raises) and costs a lane-fan-out
    pass — roughly ``bus_width`` reductions where the aggregate engine runs
    one popcount — so it is an explicit opt-in.
    """
    a = np.asarray(a, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {w.shape}")
    if dataflow not in ("WS", "OS"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    if dataflow == "OS" and (max_tiles is not None or max_stream is not None):
        raise ValueError("OS profiling is exact-only; max_tiles/max_stream apply to WS")
    m, k = a.shape
    _, n = w.shape

    # "Effective" mode: subsampling limits that don't bind are exact.
    total_tiles = (-(-k // rows)) * (-(-n // cols))
    exact = not (
        (max_tiles is not None and total_tiles > max_tiles)
        or (max_stream is not None and m > max_stream)
    )
    if lane_detail and not exact:
        raise ValueError(
            "lane_detail requires exact profiling; drop max_tiles/max_stream"
        )
    mode: tuple = ("exact",) if exact else ("sub", max_tiles, max_stream, seed)
    if lane_detail:
        mode = (*mode, "lanes")

    # Resolve the backend BEFORE the cache lookup and key on it: the two
    # backends agree to float rounding, but an explicit backend= request
    # (oracle cross-checks, timing) must never be served the other
    # backend's result.
    resolved = _resolve_backend(backend, a, w, rows, dataflow)

    key = None
    if use_cache:
        key = _cache_key(a, w, rows, cols, b_h, b_v, (resolved, dataflow, *mode))
        hit, _ = _cache_get(key)
        if hit is not None:
            return hit

    h_lanes = v_lanes = None
    if lane_detail:
        if resolved == "pallas":
            from repro.kernels.activity_profile.ops import profile_gemm_lane_toggles

            lc = profile_gemm_lane_toggles(a, w, rows, cols, b_h, b_v, dataflow=dataflow)
            h_lanes = np.asarray(lc.h_lanes, np.int64)
            v_lanes = np.asarray(lc.v_lanes, np.int64)
            h_den, v_den = lc.h_transitions, lc.v_transitions
        else:
            lane_fn = _lane_profile_numpy_os if dataflow == "OS" else _lane_profile_numpy
            h_lanes, v_lanes = lane_fn(a, w, rows, cols, b_h, b_v)
            if dataflow == "OS":
                _, _, h_den, v_den = os_stream_counts(0, 0, m, k, n, rows, cols)
            else:
                n_tiles = -(-n // cols) if n else 0
                h_den = max(m - 1, 0) * k * n_tiles
                v_den = max(m - 1, 0) * k * n
        a_h = int(h_lanes.sum()) / (h_den * b_h) if h_den else 0.0
        a_v = int(v_lanes.sum()) / (v_den * b_v) if v_den else 0.0
    elif dataflow == "OS":
        if resolved == "pallas":
            a_h, a_v, h_den, v_den = _profile_fused(
                a, w, rows, cols, b_h, b_v, None, True, dataflow="OS"
            )
        else:
            a_h, a_v, h_den, v_den = _profile_numpy_os(a, w, rows, cols, b_h, b_v)
    else:
        plan = None
        if not exact or resolved == "numpy":
            plan = _tile_plan(m, k, n, rows, cols, max_tiles, max_stream, seed)
        if resolved == "pallas":
            a_h, a_v, h_den, v_den = _profile_fused(
                a, w, rows, cols, b_h, b_v, plan, exact
            )
        else:
            a_h, a_v, h_den, v_den = _profile_numpy(a, w, b_h, b_v, plan)

    profile = ActivityProfile(
        a_h=a_h,
        a_v=a_v,
        b_h=b_h,
        b_v=b_v,
        h_transitions=h_den,
        v_transitions=v_den,
        input_zero_fraction=float(np.mean(a == 0)),
        input_elements=int(a.size),
        h_lane_toggles=None if h_lanes is None else tuple(int(v) for v in h_lanes),
        v_lane_toggles=None if v_lanes is None else tuple(int(v) for v in v_lanes),
    )
    if key is not None:
        _cache_put(key, profile)
    return profile


def profile_gemms(jobs, **kwargs):
    """Batch API: profile MANY GEMMs as a handful of device programs.

    ``jobs`` is a sequence of ``repro.core.pipeline.ProfileJob`` (each
    carrying its own dataflow); returns the profiles in input order. Jobs
    are deduped against the content-keyed cache, bucketed into shared padded
    shape classes to bound recompiles, dispatched asynchronously (device
    work overlaps the next bucket's host-side operand synthesis), and
    identical operands profiled across several (rows, cols) geometries share
    one device pass (OS jobs share geometry-FREE operand-stream passes).
    Counts are bit-exact vs per-job ``profile_gemm``. See
    ``repro.core.pipeline`` (``run_profile_batch`` returns scheduling
    statistics as well).
    """
    from repro.core.pipeline import run_profile_batch

    profiles, _ = run_profile_batch(jobs, **kwargs)
    return profiles


def _deprecated_ws_alias(name: str, generic: str) -> None:
    warnings.warn(
        f"{name} is deprecated; use repro.core.switching.{generic} "
        f"(dataflow-generic, defaults to dataflow='WS')",
        DeprecationWarning,
        stacklevel=3,
    )


def profile_ws_gemm(*args, **kwargs) -> ActivityProfile:
    """Deprecated alias of ``profile_gemm`` (weight-stationary)."""
    _deprecated_ws_alias("profile_ws_gemm", "profile_gemm")
    kwargs.setdefault("dataflow", "WS")
    return profile_gemm(*args, **kwargs)


def profile_ws_gemms(jobs, **kwargs):
    """Deprecated alias of ``profile_gemms`` (jobs default to WS)."""
    _deprecated_ws_alias("profile_ws_gemms", "profile_gemms")
    return profile_gemms(jobs, **kwargs)


def profile_ws_tile(
    a_tile: np.ndarray, w_tile: np.ndarray, b_h: int, b_v: int
) -> tuple[float, float, int, int]:
    """Deprecated alias of ``profile_tile`` (weight-stationary)."""
    _deprecated_ws_alias("profile_ws_tile", "profile_tile")
    return profile_tile(a_tile, w_tile, b_h, b_v, dataflow="WS")


def combine_profiles(profiles: Iterable[ActivityProfile]) -> ActivityProfile:
    """Weighted average of several per-layer profiles.

    Activities are transition-count-weighted; ``input_zero_fraction`` is
    element-count-weighted (a 10-element layer must not count as much as a
    10M-element one). If ANY profile lacks an element count
    (``input_elements == 0``, e.g. hand-built), the zero fraction falls back
    to an unweighted mean over all profiles — no profile is silently
    dropped from it.  Per-bit-lane toggle totals combine by elementwise sum
    (lane counts are additive) when EVERY profile carries them at matching
    widths, else the combined profile drops them.
    """
    profiles = list(profiles)
    if not profiles:
        raise ValueError("no profiles to combine")
    b_h, b_v = profiles[0].b_h, profiles[0].b_v

    def _sum_lanes(attr):
        vals = [getattr(p, attr) for p in profiles]
        if any(v is None for v in vals) or len({len(v) for v in vals}) != 1:
            return None
        total = np.sum([np.asarray(v, np.int64) for v in vals], axis=0)
        return tuple(int(v) for v in total)

    h_den = sum(p.h_transitions for p in profiles)
    v_den = sum(p.v_transitions for p in profiles)
    a_h = sum(p.a_h * p.h_transitions for p in profiles) / max(h_den, 1)
    a_v = sum(p.a_v * p.v_transitions for p in profiles) / max(v_den, 1)
    if all(p.input_elements > 0 for p in profiles):
        elems = sum(p.input_elements for p in profiles)
        zf = sum(p.input_zero_fraction * p.input_elements for p in profiles) / elems
    else:
        # Unweighted fallback: report elements as unknown (0) so a nested
        # combine doesn't element-weight a fraction that never was.
        elems = 0
        zf = float(np.mean([p.input_zero_fraction for p in profiles]))
    return ActivityProfile(
        a_h=a_h,
        a_v=a_v,
        b_h=b_h,
        b_v=b_v,
        h_transitions=h_den,
        v_transitions=v_den,
        input_zero_fraction=float(zf),
        input_elements=elems,
        h_lane_toggles=_sum_lanes("h_lane_toggles"),
        v_lane_toggles=_sum_lanes("v_lane_toggles"),
    )
