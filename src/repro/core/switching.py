"""Bit-level switching-activity profiling of weight-stationary SA data streams.

The paper's Eq. 6 needs the *average switching activity per bit* of

  * the horizontal input buses (a_h): the sequence of input operands A[t, r]
    streamed into each row r of the array, and
  * the vertical partial-sum buses (a_v): the sequence of partial sums
    S[t, r, c] = sum_{r' <= r} A[t, r'] * W[r', c] flowing South out of each
    PE (r, c).

Toggle statistics between *consecutive values on the same wire* are invariant
to the systolic pipeline skew (skew delays whole sequences; it does not
reorder them), so we profile the unskewed streams directly.

Partial sums need up to ``2*B + ceil(log2 R)`` bits (37 for the paper's
config), so this module carries them as int64 and counts toggles on the
two's-complement representation truncated to the bus width.

numpy is used for the host-side oracle (exact int64 bit manipulation); the
TPU-accelerated path lives in ``repro.kernels.toggle_count`` and is verified
against this module.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "popcount",
    "toggles_between",
    "stream_toggle_rate",
    "horizontal_stream",
    "vertical_partial_sums",
    "ActivityProfile",
    "profile_ws_tile",
    "profile_ws_gemm",
]

_M1 = np.uint64(0x5555555555555555)
_M2 = np.uint64(0x3333333333333333)
_M4 = np.uint64(0x0F0F0F0F0F0F0F0F)
_H01 = np.uint64(0x0101010101010101)


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorized 64-bit population count (Hamming weight).

    Classic SWAR bit-twiddling; exact for any uint64 input.
    """
    x = np.asarray(x, dtype=np.uint64)
    x = x - ((x >> np.uint64(1)) & _M1)
    x = (x & _M2) + ((x >> np.uint64(2)) & _M2)
    x = (x + (x >> np.uint64(4))) & _M4
    return ((x * _H01) >> np.uint64(56)).astype(np.int64)


def _to_bus_repr(values: np.ndarray, bits: int) -> np.ndarray:
    """Two's-complement representation of ``values`` on a ``bits``-wide bus."""
    if not 1 <= bits <= 64:
        raise ValueError("bus width must be in [1, 64]")
    v = np.asarray(values).astype(np.int64)
    if bits == 64:
        return v.view(np.uint64)
    mask = np.uint64((1 << bits) - 1)
    return v.view(np.uint64) & mask


def toggles_between(a: np.ndarray, b: np.ndarray, bits: int) -> np.ndarray:
    """Number of bit flips when a ``bits``-wide bus goes from value a to b."""
    ua = _to_bus_repr(a, bits)
    ub = _to_bus_repr(b, bits)
    return popcount(ua ^ ub)


def stream_toggle_rate(stream: np.ndarray, bits: int, axis: int = 0) -> float:
    """Average toggles per bit per transition along ``axis`` of a value stream.

    For a stream of T values on one wire bundle, there are T-1 transitions;
    the rate is  mean_t popcount(x_t XOR x_{t+1}) / bits, averaged over every
    other axis (i.e. over all wires in the bundle).
    """
    s = np.asarray(stream)
    if s.shape[axis] < 2:
        return 0.0
    cur = np.take(s, range(0, s.shape[axis] - 1), axis=axis)
    nxt = np.take(s, range(1, s.shape[axis]), axis=axis)
    return float(np.mean(toggles_between(cur, nxt, bits))) / float(bits)


def horizontal_stream(a_tile: np.ndarray) -> np.ndarray:
    """The per-row horizontal bus streams for one WS tile.

    ``a_tile`` has shape (T, R): T time steps (one output row of the GEMM per
    step, in steady state) of R input operands. Row r's horizontal bus sees
    the sequence a_tile[:, r]. Returned unchanged (shape (T, R)); the stream
    axis is axis 0.
    """
    a = np.asarray(a_tile)
    if a.ndim != 2:
        raise ValueError("a_tile must be (T, R)")
    return a


def vertical_partial_sums(a_tile: np.ndarray, w_tile: np.ndarray) -> np.ndarray:
    """Partial-sum sequences on every vertical bus segment of one WS tile.

    Under weight-stationary dataflow, PE (r, c) emits
    S[t, r, c] = sum_{r' <= r} a_tile[t, r'] * w_tile[r', c] on its South bus.
    Shape: (T, R, C), int64 (exact for bus widths <= 63 bits).
    """
    a = np.asarray(a_tile, dtype=np.int64)
    w = np.asarray(w_tile, dtype=np.int64)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    # products[t, r, c] then prefix-sum down the rows (the reduction axis).
    products = a[:, :, None] * w[None, :, :]
    return np.cumsum(products, axis=1)


@dataclasses.dataclass(frozen=True)
class ActivityProfile:
    """Measured switching activities + supporting statistics for one workload."""

    a_h: float
    a_v: float
    b_h: int
    b_v: int
    h_transitions: int
    v_transitions: int
    input_zero_fraction: float

    def as_bus_activity(self):
        from repro.core.floorplan import BusActivity

        return BusActivity(a_h=self.a_h, a_v=self.a_v)


def profile_ws_tile(
    a_tile: np.ndarray,
    w_tile: np.ndarray,
    b_h: int,
    b_v: int,
) -> tuple[float, float, int, int]:
    """(a_h, a_v, #h transitions, #v transitions) for one R x C WS tile."""
    h = horizontal_stream(a_tile)
    v = vertical_partial_sums(a_tile, w_tile)
    t = a_tile.shape[0]
    a_h = stream_toggle_rate(h, b_h, axis=0)
    a_v = stream_toggle_rate(v, b_v, axis=0)
    h_trans = max(t - 1, 0) * h.shape[1]
    v_trans = max(t - 1, 0) * v.shape[1] * v.shape[2]
    return a_h, a_v, h_trans, v_trans


def profile_ws_gemm(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    max_tiles: int | None = 16,
    max_stream: int | None = 1024,
    seed: int = 0,
) -> ActivityProfile:
    """Profile the full GEMM ``a @ w`` tiled onto an R x C WS systolic array.

    The GEMM (M, K) x (K, N) is tiled into ceil(K/rows) * ceil(N/cols) weight
    tiles; each tile streams all M input rows. For tractability the profiler
    subsamples ``max_tiles`` tiles and ``max_stream`` consecutive stream steps
    per tile (consecutive — toggle statistics need adjacency), then averages
    activities weighted by transition counts.
    """
    a = np.asarray(a, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {w.shape}")
    m, k = a.shape
    _, n = w.shape
    rng = np.random.default_rng(seed)

    k_tiles = -(-k // rows)
    n_tiles = -(-n // cols)
    tile_ids = [(kt, nt) for kt in range(k_tiles) for nt in range(n_tiles)]
    if max_tiles is not None and len(tile_ids) > max_tiles:
        idx = rng.choice(len(tile_ids), size=max_tiles, replace=False)
        tile_ids = [tile_ids[i] for i in sorted(idx)]

    h_num = v_num = 0.0
    h_den = v_den = 0
    for kt, nt in tile_ids:
        k0, k1 = kt * rows, min((kt + 1) * rows, k)
        n0, n1 = nt * cols, min((nt + 1) * cols, n)
        a_tile = a[:, k0:k1]
        w_tile = w[k0:k1, n0:n1]
        if max_stream is not None and m > max_stream:
            start = int(rng.integers(0, m - max_stream + 1))
            a_tile = a_tile[start : start + max_stream]
        ah, av, ht, vt = profile_ws_tile(a_tile, w_tile, b_h, b_v)
        h_num += ah * ht
        v_num += av * vt
        h_den += ht
        v_den += vt

    return ActivityProfile(
        a_h=h_num / h_den if h_den else 0.0,
        a_v=v_num / v_den if v_den else 0.0,
        b_h=b_h,
        b_v=b_v,
        h_transitions=h_den,
        v_transitions=v_den,
        input_zero_fraction=float(np.mean(a == 0)),
    )


def combine_profiles(profiles: Iterable[ActivityProfile]) -> ActivityProfile:
    """Transition-count-weighted average of several per-layer profiles."""
    profiles = list(profiles)
    if not profiles:
        raise ValueError("no profiles to combine")
    b_h, b_v = profiles[0].b_h, profiles[0].b_v
    h_den = sum(p.h_transitions for p in profiles)
    v_den = sum(p.v_transitions for p in profiles)
    a_h = sum(p.a_h * p.h_transitions for p in profiles) / max(h_den, 1)
    a_v = sum(p.a_v * p.v_transitions for p in profiles) / max(v_den, 1)
    zf = float(np.mean([p.input_zero_fraction for p in profiles]))
    return ActivityProfile(
        a_h=a_h,
        a_v=a_v,
        b_h=b_h,
        b_v=b_v,
        h_transitions=h_den,
        v_transitions=v_den,
        input_zero_fraction=zf,
    )
