"""Fleet-level J/op objective: one jitted program over (GEMM, layout, point).

SISA's scale-in claim — fleets of small pods beating a monolithic array —
is an *energy per operation* claim, not a wire-power claim: an
under-utilized monolith amortizes its (lower) wire power over fewer useful
MACs, while a pod fleet pays reduction-trunk and spill traffic for its
(higher) utilization.  This module closes that loop by fusing three
previously separate answers into the one broadcast coefficient program:

  * wire power per (workload, layout, point) at the robust aspect — the
    existing ``evaluate_layout_space`` coefficient engine;
  * the pod-partition model (utilization, tile-parallel vs K-split, spill
    and trunk words per MAC) — lowered once to (GEMM, layout, point)
    arrays by ``repro.layout.coeffs.lower_partition_coeffs`` (the host
    ``partition_gemm`` loop stays as the scalar oracle);
  * the calibrated non-bus power split of ``repro.core.energy`` — a fixed
    interconnect term plus a first-order PE/register compute term, both
    anchored to the square-layout reference bus power per workload/point.

The fused objective per cell is

    j_per_mac = (P_bus + P_overhead + P_fixed + P_compute)
                  / (freq * rows * cols * utilization)
                + spill_words_per_mac * E_spill_word
                + trunk_words_per_mac * E_trunk_word

with the word energies priced through the same switched-capacitance
roll-up as every other segment (spilled partials traverse 2*rows vertical
hops, trunk words cross one gutter), coding multipliers included.  The
MAC-weighted fleet slot ``j_per_mac_robust`` is exactly total joules over
total useful MACs for the workload mix.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.energy import EnergyModelConfig, calibration_split_arr
from repro.core.floorplan import bus_power_arr
from repro.core.workloads import Gemm
from repro.layout.coeffs import grid_coding_effective, lower_partition_coeffs
from repro.layout.power import (
    LayoutPowerConfig,
    LayoutSpaceEval,
    ObjectiveSpec,
    evaluate_layout_space,
)

__all__ = ["evaluate_fleet_objective", "fleet_static_power"]


def fleet_static_power(
    grid, a_h, a_v, *, energy_cfg: EnergyModelConfig = EnergyModelConfig()
) -> np.ndarray:
    """(W, P) calibrated non-bus watts: fixed interconnect + compute term.

    Anchored per workload/point to the square-layout reference bus power
    (coded activities where the point's bus-invert flag is set), exactly
    the DESIGN.md §2 calibration split.  This is the ``static_w`` term of
    the J/op objective — first-order in the sense that it scales with the
    reference bus power, not with pipeline depth or utilization.
    """
    a_h = np.atleast_2d(np.asarray(a_h, float))
    a_v_eff = grid_coding_effective(grid, np.atleast_2d(np.asarray(a_v, float)))
    bus_ref_sq = bus_power_arr(
        np.asarray(grid.rows, float),
        np.asarray(grid.cols, float),
        np.asarray(grid.b_h, float),
        np.asarray(grid.b_v, float),
        np.asarray(grid.pe_area_um2, float),
        a_h,
        a_v_eff,
        1.0,
        energy_cfg.vdd,
        energy_cfg.freq_hz,
        energy_cfg.wire_cap_f_per_um,
        xp=np,
    )
    fixed, compute = calibration_split_arr(
        bus_ref_sq,
        energy_cfg.non_bus_interconnect_fraction,
        energy_cfg.interconnect_share_of_total,
    )
    return np.asarray(fixed + compute, float)


def evaluate_fleet_objective(
    grid,
    a_h,
    a_v,
    gemms: Sequence[Gemm],
    *,
    layouts: Sequence[str] = ("uniform", "serpentine2", "pods2x2"),
    weights: Sequence[float] | None = None,
    cfg: LayoutPowerConfig = LayoutPowerConfig(),
    energy_cfg: EnergyModelConfig = EnergyModelConfig(),
    use_jit: bool | None = None,
    gss_iters: int = 64,
    sweep=None,
    macs_per_token: float | None = None,
) -> LayoutSpaceEval:
    """Rank layout families on total J per useful MAC in one jitted program.

    The workload axis IS the GEMM axis: ``a_h``/``a_v`` are (G, P)
    activities, one row per GEMM in ``gemms`` (broadcast from (P,) for a
    single shared profile).  ``weights`` default to MAC weighting, which
    makes the returned ``j_per_mac_robust`` exactly total fleet joules
    over total useful MACs.  Returns a ``LayoutSpaceEval`` whose
    ``j_per_mac``/``j_per_mac_robust``/``utilization``/``best_layout_jpo``
    fields are populated next to the wire-power outputs — compare
    ``best_layout`` (bus power only) against ``best_layout_jpo`` to find
    the cells where utilization and traffic flip the winner.

    ``macs_per_token`` is the serving-traffic aggregation slot (J/token =
    J/op x MACs/token): pass a job set's MAC/s-over-tokens/s (e.g.
    ``repro.serving.traffic.ServingJobSet.macs_per_token``, with
    ``weights`` set to its MAC-rate shares so the robust slot is the
    traffic mix's fleet J/op) and the eval's ``j_per_token_robust``
    property prices joules per served token per (layout, point) cell.
    """
    gemms = list(gemms)
    if not gemms:
        raise ValueError("no gemms")
    p = grid.n_points
    a_h = np.atleast_2d(np.asarray(a_h, float))
    a_v = np.atleast_2d(np.asarray(a_v, float))
    if a_h.size == 1:  # scalar activity: one shared profile for every point
        a_h = np.broadcast_to(a_h.reshape(1, 1), (1, p)).copy()
    if a_v.size == 1:
        a_v = np.broadcast_to(a_v.reshape(1, 1), (1, p)).copy()
    if a_h.shape[0] == 1 and len(gemms) > 1:
        a_h = np.broadcast_to(a_h, (len(gemms), a_h.shape[1])).copy()
        a_v = np.broadcast_to(a_v, (len(gemms), a_v.shape[1])).copy()
    if a_h.shape[0] != len(gemms):
        raise ValueError(
            f"activity workload axis ({a_h.shape[0]}) must match the GEMM "
            f"axis ({len(gemms)}): the J/op objective prices one GEMM per "
            "workload slot"
        )
    macs = np.asarray([g.macs for g in gemms], float)
    w = np.asarray(weights if weights is not None else macs, float)
    partition = lower_partition_coeffs(grid, tuple(layouts), gemms)
    static_w = np.broadcast_to(
        fleet_static_power(grid, a_h, a_v, energy_cfg=energy_cfg), (len(gemms), p)
    ).copy()
    ev = evaluate_layout_space(
        grid,
        a_h,
        a_v,
        layouts=tuple(layouts),
        weights=w,
        cfg=cfg,
        use_jit=use_jit,
        gss_iters=gss_iters,
        sweep=sweep,
        objective=ObjectiveSpec(partition=partition, static_w=static_w),
    )
    if macs_per_token is not None:
        if macs_per_token <= 0:
            raise ValueError("macs_per_token must be positive")
        ev = dataclasses.replace(ev, macs_per_token=float(macs_per_token))
    return ev
