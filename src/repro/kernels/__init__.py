"""Pallas kernels (TPU target, interpret-validated)."""
