"""Pure-jnp oracle for the toggle_count kernel."""

from __future__ import annotations

import jax.numpy as jnp


def popcount_u32_ref(v: jnp.ndarray) -> jnp.ndarray:
    v = v.astype(jnp.uint32)
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def toggle_count_ref(cur: jnp.ndarray, nxt: jnp.ndarray) -> jnp.ndarray:
    """Total bit flips between aligned int32 arrays.

    Summed in int32 (jnp int64 needs the global x64 flag): exact for streams
    up to 2^31 total toggles = 64M+ int32 values, far beyond oracle sizes;
    the production path (ops.stream_toggle_count) reduces in numpy int64.
    """
    x = cur.astype(jnp.uint32) ^ nxt.astype(jnp.uint32)
    return jnp.sum(popcount_u32_ref(x).astype(jnp.int32))


def stream_toggle_count_ref(stream: jnp.ndarray) -> jnp.ndarray:
    """Total bit flips along axis 0 of an int32 value stream (T, L)."""
    return toggle_count_ref(stream[:-1], stream[1:])
