"""Jitted public API for stream toggle counting (switching-activity profiling).

Handles padding to TPU-friendly block multiples, int64 streams (split into
hi/lo int32 planes — exact for bus widths up to 64 bits), and converts raw
toggle counts to per-bit switching activities compatible with
``repro.core.switching``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.toggle_count.kernel import (
    DEFAULT_BLOCK_L,
    DEFAULT_BLOCK_T,
    toggle_count_pallas,
)


def _pad_to_blocks(x: jnp.ndarray, bt: int, bl: int) -> jnp.ndarray:
    t, l = x.shape
    pt = (-t) % bt
    pll = (-l) % bl
    if pt or pll:
        # zero-pad BOTH cur and nxt: padded lanes see 0 XOR 0 = no toggles
        x = jnp.pad(x, ((0, pt), (0, pll)))
    return x


def stream_toggle_count(
    stream: jnp.ndarray,
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = False,
) -> int:
    """Total bit flips along axis 0 of an int32 (T, L) stream, via Pallas.

    The per-block partial sums come back as int32 (safe: <= bt*bl*32 per
    block); the cross-block reduction happens here in numpy int64 so totals
    never overflow regardless of stream size.
    """
    if stream.ndim == 1:
        stream = stream[:, None]
    if stream.shape[0] < 2:
        return 0
    cur = _pad_to_blocks(stream[:-1].astype(jnp.int32), block_t, block_l)
    nxt = _pad_to_blocks(stream[1:].astype(jnp.int32), block_t, block_l)
    partials = toggle_count_pallas(
        cur, nxt, block_t=block_t, block_l=block_l, interpret=interpret
    )
    return int(np.asarray(partials).astype(np.int64).sum())


def stream_toggle_count_i64(
    stream_np: np.ndarray,
    *,
    interpret: bool = False,
) -> int:
    """Toggle count for an int64-valued stream (e.g. 37-bit partial sums).

    Splits each value into lo/hi uint32 planes; popcount(a XOR b) over 64 bits
    equals the sum of the 32-bit plane popcounts, so this is exact.
    """
    s = np.asarray(stream_np)
    if s.ndim == 1:
        s = s[:, None]
    u = s.astype(np.int64).view(np.uint64)
    lo = (u & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
    hi = (u >> np.uint64(32)).astype(np.uint32).view(np.int32)
    total = stream_toggle_count(jnp.asarray(lo), interpret=interpret)
    total += stream_toggle_count(jnp.asarray(hi), interpret=interpret)
    return total


def stream_activity(
    stream_np: np.ndarray,
    bits: int,
    *,
    interpret: bool = False,
) -> float:
    """Per-bit, per-transition switching activity of a (T, L) value stream.

    Values are first truncated to the ``bits``-wide two's-complement bus
    representation (matching ``repro.core.switching.stream_toggle_rate``).
    """
    s = np.asarray(stream_np).astype(np.int64)
    if s.ndim == 1:
        s = s[:, None]
    if s.shape[0] < 2:
        return 0.0
    if bits < 64:
        mask = np.int64((1 << bits) - 1)
        s = s & mask
    toggles = stream_toggle_count_i64(s, interpret=interpret)
    transitions = (s.shape[0] - 1) * s.shape[1]
    return toggles / (transitions * bits)
