"""Pallas TPU kernel: bit-toggle counting over int32 value streams.

The switching-activity profiler's hot loop is popcount(x[t] XOR x[t+1])
summed over an entire activation/partial-sum stream. On TPU this is a pure
VPU workload: int32 XOR + SWAR popcount over (8, 128)-aligned VMEM tiles.

The wrapper (ops.py) passes the stream twice — ``x[:-1]`` and ``x[1:]`` — so
each grid cell sees aligned (cur, nxt) blocks and no cross-block halo is
needed. Each grid cell writes one partial sum; the wrapper reduces them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitops import popcount_u32 as _popcount_u32

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_L = 128


def _toggle_kernel(cur_ref, nxt_ref, out_ref):
    x = cur_ref[...].astype(jnp.uint32)
    y = nxt_ref[...].astype(jnp.uint32)
    cnt = _popcount_u32(x ^ y).astype(jnp.int32)
    out_ref[0, 0] = jnp.sum(cnt)


@functools.partial(jax.jit, static_argnames=("block_t", "block_l", "interpret"))
def toggle_count_pallas(
    cur: jnp.ndarray,
    nxt: jnp.ndarray,
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = False,
) -> jnp.ndarray:
    """Total bit toggles between aligned int32 arrays ``cur`` and ``nxt``.

    Both inputs are (T, L) int32; T and L are padded to block multiples by the
    caller (ops.py) with identical padding values so padding contributes zero
    toggles. Returns a scalar int64-safe int32 count per (T//bt, L//bl) grid
    cell, summed here to a scalar int64.
    """
    if cur.shape != nxt.shape or cur.ndim != 2:
        raise ValueError(f"cur/nxt must be equal-shape rank-2, got {cur.shape} {nxt.shape}")
    t, l = cur.shape
    if t % block_t or l % block_l:
        raise ValueError(f"shape {(t, l)} not padded to block {(block_t, block_l)}")
    grid = (t // block_t, l // block_l)
    # Per-block partials: a (block_t, block_l) int32 block toggles at most
    # bt*bl*32 = 2^20-ish bits — far below int32 overflow. The cross-block
    # reduction is done by the caller in int64 (host-side numpy; jnp int64
    # needs the global x64 flag which this library never sets).
    return pl.pallas_call(
        _toggle_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_l), lambda i, j: (i, j)),
            pl.BlockSpec((block_t, block_l), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(cur, nxt)
