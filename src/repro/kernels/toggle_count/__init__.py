"""toggle_count kernel package."""
from repro.kernels.toggle_count.ops import *  # noqa: F401,F403
