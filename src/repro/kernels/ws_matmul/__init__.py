"""ws_matmul kernel package."""
from repro.kernels.ws_matmul.ops import *  # noqa: F401,F403
