"""Jitted public API for the weight-stationary Pallas GEMM (auto-padding)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ws_matmul.kernel import ws_matmul_pallas


def _pad_dim(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def ws_matmul(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """``a @ w`` on the weight-stationary Pallas kernel, any 2-D shapes.

    Zero-pads every dim to its block multiple (zeros contribute nothing to the
    accumulation) and slices the result back.
    """
    m, _ = a.shape
    _, n = w.shape
    a_p = _pad_dim(_pad_dim(a, 0, block_m), 1, block_k)
    w_p = _pad_dim(_pad_dim(w, 0, block_k), 1, block_n)
    out = ws_matmul_pallas(
        a_p, w_p, block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret
    )
    return out[:m, :n]
