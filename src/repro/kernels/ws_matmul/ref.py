"""Pure-jnp oracle for the ws_matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def ws_matmul_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul at the kernel's accumulation precision."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return jnp.dot(
            a.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
        )
    return jnp.dot(a, w, preferred_element_type=jnp.float32)
