"""Pallas TPU kernel: weight-stationary tiled matmul (the SA compute pattern).

This is the TPU-native expression of the paper's workload: an R x C
weight-stationary systolic GEMM. The MXU *is* a 128x128 systolic array, so the
kernel tiles (M, K) x (K, N) into MXU-aligned VMEM blocks with K innermost —
exactly the WS schedule (weights of one (bk, bn) tile stay resident while the
input stream flows through), accumulating into a VMEM scratch accumulator at
the wide "vertical-bus" precision (int32 for int8/int16 inputs, f32 for bf16),
mirroring the B_v > B_h asymmetry the paper optimizes.

Supports: int8/int16 -> int32 (quantized inference) and bf16/f32 -> f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _acc_dtype(dtype: jnp.dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _ws_matmul_kernel(a_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid is (nm, nn, nk) with K innermost."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    w = w_ref[...]
    acc = acc_ref[...]
    prec = _acc_dtype(a.dtype)
    # The MXU consumes the narrow operands and accumulates wide — the
    # hardware analogue of B_h-wide H buses feeding B_v-wide V buses.
    acc_ref[...] = acc + jnp.dot(
        a.astype(prec) if prec == jnp.int32 else a,
        w.astype(prec) if prec == jnp.int32 else w,
        preferred_element_type=prec,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def ws_matmul_pallas(
    a: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Weight-stationary tiled ``a @ w``; dims must be block multiples.

    (Use ops.ws_matmul for automatic padding of arbitrary shapes.)
    """
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {w.shape}")
    m, k = a.shape
    _, n = w.shape
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"{(m, k, n)} not multiples of {(block_m, block_k, block_n)}")
    n_k = k // block_k
    out_dtype = _acc_dtype(a.dtype)
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_ws_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), out_dtype)],
        interpret=interpret,
    )(a, w)
