"""Pure-jnp oracle for the flash_attention kernel."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Dense masked softmax attention. q, k, v: (BH, S, D)."""
    bh, s, d = q.shape
    scale = sm_scale if sm_scale is not None else d ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    q_ids = jnp.arange(s)[:, None]
    k_ids = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask = mask & (q_ids >= k_ids)
    if window is not None:
        mask = mask & (q_ids - k_ids < window)
    logits = jnp.where(mask[None], logits, -1.0e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = jnp.where(mask[None], probs, 0.0)
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = probs / jnp.where(denom == 0.0, 1.0, denom)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
