"""flash_attention kernel package."""
from repro.kernels.flash_attention.ops import *  # noqa: F401,F403
