"""Pallas TPU kernel: FlashAttention-style fused attention (fwd).

Online-softmax attention with causal and sliding-window (Mixtral SWA) masks
and GQA (query-group) support handled by the ops.py wrapper. This is the
perf-critical prefill kernel of the framework's serving path; the dry-run
itself lowers pure-XLA attention (Pallas lowers only for TPU targets), with
this kernel enabled by ``ModelConfig.use_pallas_attention`` on real hardware.

Blocking: grid = (batch*heads, q_blocks, kv_blocks), kv innermost. Running
max / sum / accumulator live in VMEM scratch at f32 ("vertical-bus" wide
precision; operands stream at bf16 — the same H/V width asymmetry the paper
exploits).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1.0e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    n_kv: int,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int | None,
    sm_scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def body():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, dtype=jnp.bool_)
        if causal:
            mask = mask & (q_ids >= k_ids)
        if window is not None:
            mask = mask & (q_ids - k_ids < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        # fully-masked rows: exp(-inf - -inf) guard
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    # Whole kv blocks above the causal diagonal / outside the window carry no
    # unmasked entries — skip their compute AND their softmax-state update.
    if causal or window is not None:
        q_end = q_start + block_q - 1
        k_end = k_start + block_k - 1
        needed = jnp.asarray(True)
        if causal:
            needed = needed & (k_start <= q_end)
        if window is not None:
            needed = needed & (k_end > q_start - window)
        pl.when(needed)(body)
    else:
        body()

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked query rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "sm_scale", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention. q, k, v: (BH, S, D) with S a block multiple.

    GQA/padding handled by ops.flash_attention.
    """
    bh, s, d = q.shape
    if k.shape != (bh, s, d) or v.shape != (bh, s, d):
        raise ValueError(f"q/k/v mismatch: {q.shape} {k.shape} {v.shape}")
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} not a multiple of blocks {(block_q, block_k)}")
    scale = sm_scale if sm_scale is not None else d ** -0.5
    n_kv = s // block_k
    grid = (bh, s // block_q, n_kv)
    kernel = functools.partial(
        _flash_kernel,
        n_kv=n_kv,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        sm_scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
