"""Jitted public API for fused attention: GQA expansion + seq padding."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_pallas,
)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention over (B, H, S, D) queries with (B, KV, S, D) keys/values.

    KV heads are repeated to match H (GQA); sequence is zero-padded to a block
    multiple (padded keys sit above the causal diagonal for padded queries
    only, and padded query rows are sliced away).
    """
    b, h, s, d = q.shape
    _, kv, _, _ = k.shape
    if h % kv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {kv}")
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    block = max(block_q, block_k)
    pad = (-s) % block
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    sp = s + pad

    q3 = q.reshape(b * h, sp, d)
    k3 = k.reshape(b * h, sp, d)
    v3 = v.reshape(b * h, sp, d)
    # Padding note: with causal=True padded kv positions are only visible to
    # padded query rows, which are sliced off below. For non-causal use the
    # caller must pass an exact block-multiple seq (asserted in the kernel).
    if not causal and pad:
        raise ValueError("non-causal flash attention requires block-multiple seq")
    out = flash_attention_pallas(
        q3,
        k3,
        v3,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.reshape(b, h, sp, d)[:, :, :s, :]
