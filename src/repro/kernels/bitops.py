"""Shared bit-manipulation primitives for the kernel packages."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["popcount_u32"]


def popcount_u32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount for uint32 lanes (no popc instruction on the TPU VPU).

    Used inside Pallas kernels and jitted XLA programs alike; exact for any
    uint32 input.
    """
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)
