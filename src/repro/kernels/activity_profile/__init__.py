"""activity_profile kernel package: fused single-pass WS switching profiler."""
from repro.kernels.activity_profile.ops import *  # noqa: F401,F403
