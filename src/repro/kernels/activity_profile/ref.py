"""Numpy oracle for the fused activity engine: exact integer toggle counts.

Deliberately does the work the fused engines avoid, so the two
implementations share no code and a match is meaningful:

  * WS — materializes the (T, R, C) partial-sum tensor per tile via
    ``repro.core.switching.vertical_partial_sums`` + XOR-popcount.
  * OS — loops every ceil(M/rows) * ceil(N/cols) OUTPUT tile and counts its
    operand-stream toggles tile by tile (the fused engine instead counts
    each lane once and scales by the orthogonal tile count).

Used by tests (bit-exact comparison) and as the timed "seed numpy path"
baseline in benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.core.switching import toggles_between, vertical_partial_sums

__all__ = ["profile_gemm_toggles_ref"]


def profile_gemm_toggles_ref(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    dataflow: str = "WS",
) -> tuple[int, int, int, int]:
    """(h_toggles, v_toggles, h_transitions, v_transitions) for a full GEMM."""
    a = np.asarray(a, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {w.shape}")
    if dataflow == "OS":
        return _profile_os_ref(a, w, rows, cols, b_h, b_v)
    if dataflow != "WS":
        raise ValueError(f"unknown dataflow {dataflow!r}")
    m, k = a.shape
    n = w.shape[1]
    k_tiles = -(-k // rows) if k else 0
    n_tiles = -(-n // cols) if n else 0
    h_tog = v_tog = 0
    for kt in range(k_tiles):
        k0, k1 = kt * rows, min((kt + 1) * rows, k)
        a_tile = a[:, k0:k1]
        h_tile = int(toggles_between(a_tile[:-1], a_tile[1:], b_h).sum()) if m > 1 else 0
        for nt in range(n_tiles):
            n0, n1 = nt * cols, min((nt + 1) * cols, n)
            v = vertical_partial_sums(a_tile, w[k0:k1, n0:n1])
            if m > 1:
                v_tog += int(toggles_between(v[:-1], v[1:], b_v).sum())
            h_tog += h_tile
    h_trans = max(m - 1, 0) * k * n_tiles
    v_trans = max(m - 1, 0) * k * n
    return h_tog, v_tog, h_trans, v_trans


def _profile_os_ref(
    a: np.ndarray, w: np.ndarray, rows: int, cols: int, b_h: int, b_v: int
) -> tuple[int, int, int, int]:
    """OS oracle: walk every output tile, toggle its own operand streams."""
    m, k = a.shape
    n = w.shape[1]
    m_tiles = -(-m // rows) if m else 0
    n_tiles = -(-n // cols) if n else 0
    h_tog = v_tog = 0
    for mt in range(m_tiles):
        m0, m1 = mt * rows, min((mt + 1) * rows, m)
        # horizontal: each array row streams one A row over the K axis
        h_stream = a[m0:m1, :].T  # (K, rows_valid)
        h_tile = (
            int(toggles_between(h_stream[:-1], h_stream[1:], b_h).sum()) if k > 1 else 0
        )
        for nt in range(n_tiles):
            n0, n1 = nt * cols, min((nt + 1) * cols, n)
            # vertical: each array column streams one W column over K
            v_stream = w[:, n0:n1]  # (K, cols_valid)
            if k > 1:
                v_tog += int(toggles_between(v_stream[:-1], v_stream[1:], b_v).sum())
            h_tog += h_tile
    h_trans = max(k - 1, 0) * m * n_tiles
    v_trans = max(k - 1, 0) * n * m_tiles
    return h_tog, v_tog, h_trans, v_trans
