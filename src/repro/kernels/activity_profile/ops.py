"""Public API for the fused switching-activity engine.

``profile_gemm_toggles`` returns EXACT integer toggle totals for the
horizontal and vertical buses of a full GEMM under either systolic dataflow:

  * ``dataflow="WS"`` — weight-stationary: horizontal buses stream the A
    operand over the M axis, vertical buses carry the partial-sum cumsum
    down the reduction rows.  Every weight tile, every stream step, without
    ever materializing the (T, R, C) partial-sum tensor.
  * ``dataflow="OS"`` — output-stationary: BOTH buses are operand streams
    over the K axis (A rows horizontally, W columns vertically; the
    accumulators never move).  Per-lane toggle totals are geometry-free and
    scale with the output-tile counts — ceil(N/cols) horizontally,
    ceil(M/rows) vertically — exactly as their transition denominators do,
    so no partial-sum machinery runs at all.

Two engines run the identical algorithm (shared jnp helpers in kernel.py):

  * ``"pallas"`` — the fused TPU kernel (one grid cell per (tile, t-block),
    carry in VMEM scratch). Also runs under ``interpret=True`` for CPU CI.
  * ``"xla"``    — a jitted lax.map-over-tiles / lax.scan-over-time rendering
    of the same grid, for hosts without a TPU. Peak live memory is one
    (block_t, R, C) block, exactly like the kernel.

``engine="auto"`` picks "pallas" on TPU backends and "xla" elsewhere.

Operand contract: values must be int16-range (|x| < 2^15) so products fit
int32 — the paper's quantization (Section IV) and everything
``repro.core.quant`` emits satisfies this. ``repro.core.switching`` falls
back to the numpy oracle for anything wider.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.activity_profile.kernel import (
    activity_profile_pallas,
    choose_block_t,
    operand_stream_toggles_pallas,
    partial_sum_planes,
    planes_toggles,
    value32_toggles,
)

__all__ = [
    "ToggleCounts",
    "LaneToggleCounts",
    "INT16_SAFE_MAX",
    "MAX_FUSED_K",
    "MAX_FUSED_LANES",
    "operands_fit_fused",
    "profile_gemm_toggles",
    "profile_gemm_lane_toggles",
    "stream_toggle_total",
    "stream_lane_toggle_totals",
]

INT16_SAFE_MAX = (1 << 15) - 1
# K_pad (= K + up to rows-1 of zero padding) must stay below this so the
# per-row int32 h-toggle partials (<= K_pad * 64) cannot overflow.
# backend="auto" in repro.core.switching falls back to numpy beyond it.
MAX_FUSED_K = 1 << 25
# The lo/hi int32 cumsum planes are exact only while R * 0xffff fits int32.
MAX_FUSED_ROWS = 1 << 15
# OS streams reduce per-time-row toggle partials over their lane axis (M for
# the A stream, N for the W stream) in int32: lanes * 64 must stay < 2^31.
MAX_FUSED_LANES = 1 << 25


@dataclasses.dataclass(frozen=True)
class ToggleCounts:
    """Exact integer toggle totals + transition denominators for one GEMM."""

    h_toggles: int
    v_toggles: int
    h_transitions: int
    v_transitions: int

    def activities(self, b_h: int, b_v: int) -> tuple[float, float]:
        a_h = self.h_toggles / (self.h_transitions * b_h) if self.h_transitions else 0.0
        a_v = self.v_toggles / (self.v_transitions * b_v) if self.v_transitions else 0.0
        return a_h, a_v

    def __add__(self, other: "ToggleCounts") -> "ToggleCounts":
        return ToggleCounts(
            self.h_toggles + other.h_toggles,
            self.v_toggles + other.v_toggles,
            self.h_transitions + other.h_transitions,
            self.v_transitions + other.v_transitions,
        )


@dataclasses.dataclass(frozen=True)
class LaneToggleCounts:
    """Exact per-bit-lane toggle totals for one GEMM.

    ``h_lanes[b]`` / ``v_lanes[b]`` count the toggles of bus bit-lane ``b``
    (LSB first) summed over every wire bundle and transition of the
    respective direction; every lane shares the bundle's transition
    denominator, so lane activities are ``lanes / transitions`` and the
    lane sums reproduce the aggregate ``ToggleCounts`` bit-exactly
    (``sum(h_lanes) == h_toggles`` etc. — regression-tested).
    """

    h_lanes: tuple[int, ...]
    v_lanes: tuple[int, ...]
    h_transitions: int
    v_transitions: int

    def totals(self) -> ToggleCounts:
        return ToggleCounts(
            sum(self.h_lanes), sum(self.v_lanes), self.h_transitions, self.v_transitions
        )

    def activities(self, b_h: int, b_v: int) -> tuple[float, float]:
        return self.totals().activities(b_h, b_v)


def _fits_int16(arr: np.ndarray) -> bool:
    # Bounds are checked via min/max, NOT np.abs: abs(int64 min) wraps
    # negative and would silently admit an out-of-contract value.
    return not arr.size or (
        -INT16_SAFE_MAX <= int(arr.min()) and int(arr.max()) <= INT16_SAFE_MAX
    )


def operands_fit_fused(a: np.ndarray, w: np.ndarray) -> bool:
    """True iff products fit int32 (int16-range operands) — the engine's contract."""
    return _fits_int16(a) and _fits_int16(w)


@functools.partial(jax.jit, static_argnames=("b_h", "block_t"))
def _h_toggles_xla(a_pad: jnp.ndarray, *, b_h: int, block_t: int) -> jnp.ndarray:
    """Horizontal-bus toggle partials over the whole (T_pad, K_pad) stream.

    One k-strip's horizontal count is identical for every n-tile it pairs
    with, and the strips concatenate to the full matrix — so the total over
    all tiles is ``n_tiles *`` one vectorized pass over ``a``. K zero-padding
    toggles nothing (0 XOR 0). Returns (num_t_blocks, block_t) int32
    partials — reduced per ROW, not per block, so each partial is bounded by
    K_pad * 64 regardless of block_t (< 2^31 for any K_pad < 2^25, enforced
    by the caller).
    """
    t_pad, k_pad = a_pad.shape
    blocks = a_pad.reshape(t_pad // block_t, block_t, k_pad)

    def step(prev_row, blk):
        lag = jnp.concatenate([prev_row, blk[:-1]], axis=0)
        cnt = jnp.sum(value32_toggles(blk, lag, b_h), axis=1)
        return blk[-1:], cnt

    # Seed with t=0 so the first transition contributes zero toggles.
    _, cnts = jax.lax.scan(step, blocks[0, :1], blocks)
    return cnts


@functools.partial(
    jax.jit,
    static_argnames=("rows", "cols", "k", "n", "b_v", "block_t", "tile_chunk"),
)
def _v_toggles_xla(
    a_pad: jnp.ndarray,
    w_pad: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    k: int,
    n: int,
    b_v: int,
    block_t: int,
    tile_chunk: int,
) -> jnp.ndarray:
    """Vertical-bus toggle partials: XLA rendering of the fused kernel grid.

    Sequential over tiles (lax.map) and time blocks (outer lax.scan), with an
    inner lax.scan down R that carries the running partial-sum lo/hi planes —
    S[t, r] is produced as a (block_t, C) slice, toggled against its time
    predecessor, and immediately overwritten. Live memory is O(block_t * C +
    R * C) per tile regardless of T, K, N; the (T, R, C) tensor never exists.

    The running sum adds each raw int32 product with one unsigned-compare
    carry into the hi plane — exact mod 2^64, same invariant as the Pallas
    kernel's plane reconstruction.

    Tiles run ``tile_chunk`` at a time under vmap (one lax.map step per
    chunk): wider vectors amortize scan-step overhead and let XLA:CPU's
    intra-op threads engage, ~4x over strictly-sequential tiles at bounded
    memory (tile_chunk * block_t * cols elements live). Tile ids are padded
    to a chunk multiple by repeating id 0; the caller drops the duplicates.
    Returns (padded_tiles // tile_chunk, tile_chunk, num_t_blocks) int32.
    """
    t_pad, k_pad = a_pad.shape
    k_tiles = k_pad // rows
    n_tiles = w_pad.shape[1] // cols
    num_tb = t_pad // block_t
    a_blocks = a_pad.reshape(num_tb, block_t, k_tiles, rows)
    w_tiles = w_pad.reshape(k_tiles, rows, n_tiles, cols).transpose(0, 2, 1, 3)
    cix = jnp.arange(cols, dtype=jnp.int32)
    rix = jnp.arange(rows, dtype=jnp.int32)

    def per_tile(p):
        kt = p // n_tiles
        nt = p % n_tiles
        w_t = w_tiles[kt, nt]  # (rows, cols)
        a_t = a_blocks[:, :, kt, :]  # (num_tb, block_t, rows)
        valid_r = jnp.minimum(rows, k - kt * rows)
        valid_c = jnp.minimum(cols, n - nt * cols)
        colmask = cix < valid_c  # (cols,)

        def block_step(bcarry, a_blk):
            bound_lo, bound_hi = bcarry  # (rows, cols): S[t_prev_last, r, :]

            def rstep(rcarry, xs):
                run_lo, run_hi = rcarry  # (block_t, cols): S[t, r-1, :]
                a_col, w_row, b_lo, b_hi, r = xs
                prod = a_col[:, None] * w_row[None, :]
                new_lo = run_lo + prod
                carry = (
                    new_lo.astype(jnp.uint32) < run_lo.astype(jnp.uint32)
                ).astype(jnp.int32)
                new_hi = run_hi + (prod >> jnp.int32(31)) + carry
                lag_lo = jnp.concatenate([b_lo[None], new_lo[:-1]], axis=0)
                lag_hi = jnp.concatenate([b_hi[None], new_hi[:-1]], axis=0)
                cnt = planes_toggles(new_lo, new_hi, lag_lo, lag_hi, b_v)
                cnt = jnp.sum(jnp.where((r < valid_r) & colmask[None, :], cnt, 0))
                return (new_lo, new_hi), (cnt, new_lo[-1], new_hi[-1])

            zero = jnp.zeros((a_blk.shape[0], cols), jnp.int32)
            (_, _), (cnts, nb_lo, nb_hi) = jax.lax.scan(
                rstep, (zero, zero), (a_blk.T, w_t, bound_lo, bound_hi, rix)
            )
            return (nb_lo, nb_hi), jnp.sum(cnts)

        # Seed the time-boundary planes with t=0 (zero first-transition).
        s0_lo, s0_hi = partial_sum_planes(a_t[0, :1, :], w_t)
        (_, _), v_b = jax.lax.scan(block_step, (s0_lo[0], s0_hi[0]), a_t)
        return v_b

    num_tiles = k_tiles * n_tiles
    padded = -(-num_tiles // tile_chunk) * tile_chunk
    ids = jnp.where(
        jnp.arange(padded, dtype=jnp.int32) < num_tiles,
        jnp.arange(padded, dtype=jnp.int32),
        0,
    ).reshape(padded // tile_chunk, tile_chunk)
    return jax.lax.map(jax.vmap(per_tile), ids)


def _pad_operands(
    a: np.ndarray, w: np.ndarray, rows: int, cols: int, block_t: int
) -> tuple[np.ndarray, np.ndarray]:
    m, k = a.shape
    n = w.shape[1]
    pt = (-m) % block_t
    pk = (-k) % rows
    pn = (-n) % cols
    # T: replicate the last row — repeated values toggle zero bits, so the
    # padding is count-neutral. K/N: zero-pad; edge-tile masks drop them.
    a_pad = np.pad(a, ((0, pt), (0, pk)), mode="edge" if m else "constant")
    if pk:
        a_pad[:, k:] = 0
    w_pad = np.pad(w, ((0, pk), (0, pn)))
    return a_pad, w_pad


def stream_toggle_total(
    x: np.ndarray,
    bits: int,
    *,
    engine: str = "auto",
    block_t: int | None = None,
    interpret: bool = False,
) -> int:
    """Exact toggle total of a bundle of independent value streams.

    ``x`` is (T, L): L lanes, each a T-step stream of int16-range values
    toggling on a ``bits``-wide two's-complement bus.  This is the whole
    per-operand computation of the OS dataflow (and the h pass of WS, up to
    tiling).  Runs the operand-stream Pallas kernel on TPU hosts and the
    shared scan-free XLA h pass elsewhere; both reuse the WS horizontal
    machinery so the engines stay one algorithm.
    """
    x = np.asarray(x)
    t, lanes = x.shape
    if t < 2 or lanes == 0:
        return 0
    if not _fits_int16(x):
        # validate-or-raise, like profile_gemm_toggles: a silent int32 cast
        # would wrap out-of-contract values into wrong totals
        raise ValueError(
            "fused engine needs int16-range stream values; "
            "use the numpy backend for wider values"
        )
    if lanes >= MAX_FUSED_LANES:
        raise ValueError("fused engine supports < 2^25 stream lanes")
    if block_t is None:
        block_t = min(choose_block_t(1, lanes), -(-t // 8) * 8)
    pt = (-t) % block_t
    # Edge-replicate the stream tail: repeated values toggle zero bits.
    x_pad = np.pad(x.astype(np.int32), ((0, pt), (0, 0)), mode="edge")
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" else "xla"
    if engine == "pallas":
        parts = operand_stream_toggles_pallas(
            jnp.asarray(x_pad), bits=bits, block_t=block_t, interpret=interpret
        )
    elif engine == "xla":
        parts = _h_toggles_xla(jnp.asarray(x_pad), b_h=bits, block_t=block_t)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    return int(np.asarray(parts).astype(np.int64).sum())


def _profile_os_toggles(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    engine: str,
    block_t: int | None,
    interpret: bool,
) -> ToggleCounts:
    """OS totals: per-lane operand-stream toggles scaled by the tile grid.

    Every (mt, nt) output tile streams the SAME A rows (for its mt) and the
    same W columns (for its nt) over the K axis; the fold into full-GEMM
    totals is the shared ``switching.os_stream_counts`` identity.  Edge
    tiles need no masking: summing over the true lanes of ``a``/``w``
    already covers exactly the valid PEs.
    """
    from repro.core.switching import os_stream_counts

    m, k = a.shape
    n = w.shape[1]
    if k < 2 or m == 0 or n == 0:
        return ToggleCounts(*os_stream_counts(0, 0, m, k, n, rows, cols))
    kw = dict(engine=engine, block_t=block_t, interpret=interpret)
    base_h = stream_toggle_total(np.ascontiguousarray(a.T), b_h, **kw)
    base_v = stream_toggle_total(w, b_v, **kw)
    return ToggleCounts(*os_stream_counts(base_h, base_v, m, k, n, rows, cols))


def profile_gemm_toggles(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    *,
    dataflow: str = "WS",
    engine: str = "auto",
    block_t: int | None = None,
    interpret: bool = False,
) -> ToggleCounts:
    """Exact toggle totals for GEMM ``a @ w`` tiled on an R x C array.

    ``a`` is (M, K), ``w`` is (K, N), integer-valued with int16-range
    magnitudes. Counts match ``repro.core.switching``'s numpy oracle
    bit-for-bit under both dataflows: for WS every ceil(K/rows)*ceil(N/cols)
    weight tile and all M stream steps; for OS every ceil(M/rows)*ceil(N/cols)
    output tile and all K reduction steps. Bus widths ``b_h``/``b_v`` in
    [1, 64].
    """
    a = np.asarray(a)
    w = np.asarray(w)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {w.shape}")
    if not 1 <= b_h <= 64 or not 1 <= b_v <= 64:
        raise ValueError("bus widths must be in [1, 64]")
    if dataflow not in ("WS", "OS"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    if not operands_fit_fused(a, w):
        raise ValueError(
            "fused engine needs int16-range operands (products must fit int32); "
            "use the numpy backend for wider values"
        )
    if dataflow == "OS":
        if max(a.shape[0], w.shape[1]) >= MAX_FUSED_LANES:
            # per-time-row stream partials are bounded by lanes * 64
            raise ValueError(
                "fused OS engine supports M, N < 2^25; use the numpy backend"
            )
        if engine == "auto":
            engine = "pallas" if jax.default_backend() == "tpu" else "xla"
        return _profile_os_toggles(
            a, w, rows, cols, b_h, b_v, engine, block_t, interpret
        )
    if a.shape[1] + rows >= MAX_FUSED_K:
        # per-row int32 h-toggle partials are bounded by K_pad * 64
        raise ValueError("fused engine supports K < 2^25; use the numpy backend")
    if rows >= MAX_FUSED_ROWS:
        raise ValueError("fused engine supports rows < 2^15; use the numpy backend")
    m, k = a.shape
    n = w.shape[1]
    k_tiles = -(-k // rows) if k else 0
    n_tiles = -(-n // cols) if n else 0
    h_trans = max(m - 1, 0) * k * n_tiles
    v_trans = max(m - 1, 0) * k * n
    if m < 2 or k == 0 or n == 0:
        return ToggleCounts(0, 0, h_trans, v_trans)

    if block_t is None:
        # Don't pad T beyond the next 8-multiple of the true stream length.
        block_t = min(choose_block_t(rows, cols), -(-m // 8) * 8)
    a_pad, w_pad = _pad_operands(a.astype(np.int32), w.astype(np.int32), rows, cols, block_t)

    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" else "xla"
    if engine == "pallas":
        h_parts, v_parts = activity_profile_pallas(
            jnp.asarray(a_pad),
            jnp.asarray(w_pad),
            rows=rows,
            cols=cols,
            k=k,
            n=n,
            b_h=b_h,
            b_v=b_v,
            block_t=block_t,
            interpret=interpret,
        )
        h_tog = int(np.asarray(h_parts).astype(np.int64).sum())
    elif engine == "xla":
        num_tiles = k_tiles * n_tiles
        tile_chunk = int(min(16, max(1, num_tiles)))
        h_strip = _h_toggles_xla(jnp.asarray(a_pad), b_h=b_h, block_t=block_t)
        v_parts = _v_toggles_xla(
            jnp.asarray(a_pad),
            jnp.asarray(w_pad),
            rows=rows,
            cols=cols,
            k=k,
            n=n,
            b_v=b_v,
            block_t=block_t,
            tile_chunk=tile_chunk,
        )
        # Drop the chunk-padding duplicates before reducing.
        v_parts = np.asarray(v_parts).reshape(-1, v_parts.shape[-1])[:num_tiles]
        h_tog = n_tiles * int(np.asarray(h_strip).astype(np.int64).sum())
    else:
        raise ValueError(f"unknown engine {engine!r}")

    v_tog = int(np.asarray(v_parts).astype(np.int64).sum())
    return ToggleCounts(h_tog, v_tog, h_trans, v_trans)


# ---------------------------------------------------------------------------
# Per-bit-lane toggle totals (lane-resolved rendering of the same passes)
# ---------------------------------------------------------------------------
#
# The aggregate engines popcount the XORed lo/hi planes; the lane-resolved
# variants extract each bus bit instead and accumulate a (lanes,) vector.
# Bus semantics match ``kernel.value32_toggles`` / ``kernel.planes_toggles``
# exactly: for a bus wider than the 32-bit operand plane, lanes >= 32 of an
# operand stream are sign-extension copies (they all flip with the sign
# bit), while the WS partial-sum lanes >= 32 come from the true hi plane.
# The lane passes always run the XLA engine (lane extraction is a reduction
# fan-out, not a kernel-shaped inner loop); counts are bit-exact vs the
# aggregate engines and the numpy oracle.


def _compact_lanes(bits: int) -> int:
    """Lanes materialized on-device: 32 value lanes + one shared sign lane."""
    return min(bits, 32) + (1 if bits > 32 else 0)


def _expand_sign_lanes(cnt: np.ndarray, bits: int) -> np.ndarray:
    """(compact,) device counts -> (bits,) int64 per-lane totals."""
    cnt = np.asarray(cnt, np.int64)
    if bits <= 32:
        return cnt
    return np.concatenate([cnt[:32], np.repeat(cnt[32:33], bits - 32)])


@functools.partial(jax.jit, static_argnames=("bits", "block_t"))
def _h_lane_toggles_xla(a_pad: jnp.ndarray, *, bits: int, block_t: int) -> jnp.ndarray:
    """Per-bit-lane horizontal toggle partials over the whole stream.

    Returns (num_t_blocks, block_t, compact_lanes) int32 — reduced per time
    ROW, so each partial is bounded by K_pad (< 2^25, caller-enforced).
    """
    t_pad, k_pad = a_pad.shape
    blocks = a_pad.reshape(t_pad // block_t, block_t, k_pad)

    def lane_counts(x):  # (block_t, k_pad) int32 XOR -> (block_t, compact)
        cols_ = [((x >> jnp.int32(b)) & 1).sum(axis=1) for b in range(min(bits, 32))]
        if bits > 32:
            cols_.append(((x >> jnp.int32(31)) & 1).sum(axis=1))
        return jnp.stack(cols_, axis=-1).astype(jnp.int32)

    def step(prev_row, blk):
        lag = jnp.concatenate([prev_row, blk[:-1]], axis=0)
        return blk[-1:], lane_counts(blk ^ lag)

    _, cnts = jax.lax.scan(step, blocks[0, :1], blocks)
    return cnts


@functools.partial(
    jax.jit,
    static_argnames=("rows", "cols", "k", "n", "b_v", "block_t", "tile_chunk"),
)
def _v_lane_toggles_xla(
    a_pad: jnp.ndarray,
    w_pad: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    k: int,
    n: int,
    b_v: int,
    block_t: int,
    tile_chunk: int,
) -> jnp.ndarray:
    """Per-bit-lane vertical toggle partials: lane-resolved ``_v_toggles_xla``.

    Same grid, same lo/hi-plane carries; each (tile, t-block) cell reduces a
    (b_v,) lane vector instead of a popcount scalar (every entry is bounded
    by block_t * rows * cols < 2^31).  Returns
    (padded_tiles // tile_chunk, tile_chunk, num_t_blocks, b_v) int32.
    """
    t_pad, k_pad = a_pad.shape
    k_tiles = k_pad // rows
    n_tiles = w_pad.shape[1] // cols
    num_tb = t_pad // block_t
    a_blocks = a_pad.reshape(num_tb, block_t, k_tiles, rows)
    w_tiles = w_pad.reshape(k_tiles, rows, n_tiles, cols).transpose(0, 2, 1, 3)
    cix = jnp.arange(cols, dtype=jnp.int32)
    rix = jnp.arange(rows, dtype=jnp.int32)

    def per_tile(p):
        kt = p // n_tiles
        nt = p % n_tiles
        w_t = w_tiles[kt, nt]
        a_t = a_blocks[:, :, kt, :]
        valid_r = jnp.minimum(rows, k - kt * rows)
        valid_c = jnp.minimum(cols, n - nt * cols)
        colmask = cix < valid_c

        def block_step(bcarry, a_blk):
            bound_lo, bound_hi = bcarry

            def rstep(rcarry, xs):
                run_lo, run_hi = rcarry
                a_col, w_row, b_lo, b_hi, r = xs
                prod = a_col[:, None] * w_row[None, :]
                new_lo = run_lo + prod
                carry = (
                    new_lo.astype(jnp.uint32) < run_lo.astype(jnp.uint32)
                ).astype(jnp.int32)
                new_hi = run_hi + (prod >> jnp.int32(31)) + carry
                lag_lo = jnp.concatenate([b_lo[None], new_lo[:-1]], axis=0)
                lag_hi = jnp.concatenate([b_hi[None], new_hi[:-1]], axis=0)
                x_lo = new_lo ^ lag_lo
                x_hi = new_hi ^ lag_hi
                ok = (r < valid_r) & colmask[None, :]
                lanes = [
                    jnp.sum(jnp.where(ok, (x_lo >> jnp.int32(b)) & 1, 0))
                    for b in range(min(b_v, 32))
                ] + [
                    jnp.sum(jnp.where(ok, (x_hi >> jnp.int32(b - 32)) & 1, 0))
                    for b in range(32, b_v)
                ]
                cnt = jnp.stack(lanes).astype(jnp.int32)
                return (new_lo, new_hi), (cnt, new_lo[-1], new_hi[-1])

            zero = jnp.zeros((a_blk.shape[0], cols), jnp.int32)
            (_, _), (cnts, nb_lo, nb_hi) = jax.lax.scan(
                rstep, (zero, zero), (a_blk.T, w_t, bound_lo, bound_hi, rix)
            )
            return (nb_lo, nb_hi), jnp.sum(cnts, axis=0)

        s0_lo, s0_hi = partial_sum_planes(a_t[0, :1, :], w_t)
        (_, _), v_b = jax.lax.scan(block_step, (s0_lo[0], s0_hi[0]), a_t)
        return v_b  # (num_tb, b_v)

    num_tiles = k_tiles * n_tiles
    padded = -(-num_tiles // tile_chunk) * tile_chunk
    ids = jnp.where(
        jnp.arange(padded, dtype=jnp.int32) < num_tiles,
        jnp.arange(padded, dtype=jnp.int32),
        0,
    ).reshape(padded // tile_chunk, tile_chunk)
    return jax.lax.map(jax.vmap(per_tile), ids)


def stream_lane_toggle_totals(
    x: np.ndarray, bits: int, *, block_t: int | None = None
) -> np.ndarray:
    """Per-bit-lane totals of ``stream_toggle_total``: (bits,) int64.

    ``x`` is (T, L) int16-range stream lanes on a ``bits``-wide bus; entry b
    counts the toggles of bus bit b summed over all L wires and T-1
    transitions (``sum(result) == stream_toggle_total(x, bits)``,
    bit-exactly).
    """
    x = np.asarray(x)
    t, lanes = x.shape
    if t < 2 or lanes == 0:
        return np.zeros(bits, np.int64)
    if not _fits_int16(x):
        raise ValueError(
            "fused engine needs int16-range stream values; "
            "use the numpy backend for wider values"
        )
    if lanes >= MAX_FUSED_LANES:
        raise ValueError("fused engine supports < 2^25 stream lanes")
    if block_t is None:
        block_t = min(choose_block_t(1, lanes), -(-t // 8) * 8)
    pt = (-t) % block_t
    x_pad = np.pad(x.astype(np.int32), ((0, pt), (0, 0)), mode="edge")
    parts = _h_lane_toggles_xla(jnp.asarray(x_pad), bits=bits, block_t=block_t)
    compact = np.asarray(parts).astype(np.int64).sum(axis=(0, 1))
    return _expand_sign_lanes(compact, bits)


def profile_gemm_lane_toggles(
    a: np.ndarray,
    w: np.ndarray,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    *,
    dataflow: str = "WS",
    block_t: int | None = None,
) -> LaneToggleCounts:
    """Exact per-bit-lane toggle totals for GEMM ``a @ w`` on an R x C array.

    The lane-resolved sibling of ``profile_gemm_toggles`` (same operand and
    dimension contracts, same tiling semantics under both dataflows); the
    lane sums equal the aggregate totals bit-for-bit.  Always runs the XLA
    engine.
    """
    a = np.asarray(a)
    w = np.asarray(w)
    if a.ndim != 2 or w.ndim != 2 or a.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} x {w.shape}")
    if not 1 <= b_h <= 64 or not 1 <= b_v <= 64:
        raise ValueError("bus widths must be in [1, 64]")
    if dataflow not in ("WS", "OS"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    if not operands_fit_fused(a, w):
        raise ValueError(
            "fused engine needs int16-range operands (products must fit int32); "
            "use the numpy backend for wider values"
        )
    m, k = a.shape
    n = w.shape[1]

    if dataflow == "OS":
        if max(m, n) >= MAX_FUSED_LANES:
            raise ValueError(
                "fused OS engine supports M, N < 2^25; use the numpy backend"
            )
        from repro.core.switching import os_stream_counts

        _, _, h_trans, v_trans = os_stream_counts(0, 0, m, k, n, rows, cols)
        if k < 2 or m == 0 or n == 0:
            return LaneToggleCounts((0,) * b_h, (0,) * b_v, h_trans, v_trans)
        base_h = stream_lane_toggle_totals(
            np.ascontiguousarray(a.T), b_h, block_t=block_t
        )
        base_v = stream_lane_toggle_totals(w, b_v, block_t=block_t)
        n_tiles = -(-n // cols)
        m_tiles = -(-m // rows)
        return LaneToggleCounts(
            tuple(int(v) for v in n_tiles * base_h),
            tuple(int(v) for v in m_tiles * base_v),
            h_trans,
            v_trans,
        )

    if k + rows >= MAX_FUSED_K:
        raise ValueError("fused engine supports K < 2^25; use the numpy backend")
    if rows >= MAX_FUSED_ROWS:
        raise ValueError("fused engine supports rows < 2^15; use the numpy backend")
    k_tiles = -(-k // rows) if k else 0
    n_tiles = -(-n // cols) if n else 0
    h_trans = max(m - 1, 0) * k * n_tiles
    v_trans = max(m - 1, 0) * k * n
    if m < 2 or k == 0 or n == 0:
        return LaneToggleCounts((0,) * b_h, (0,) * b_v, h_trans, v_trans)

    if block_t is None:
        block_t = min(choose_block_t(rows, cols), -(-m // 8) * 8)
    a_pad, w_pad = _pad_operands(
        a.astype(np.int32), w.astype(np.int32), rows, cols, block_t
    )
    h_parts = _h_lane_toggles_xla(jnp.asarray(a_pad), bits=b_h, block_t=block_t)
    h_lanes = n_tiles * _expand_sign_lanes(
        np.asarray(h_parts).astype(np.int64).sum(axis=(0, 1)), b_h
    )
    num_tiles = k_tiles * n_tiles
    tile_chunk = int(min(16, max(1, num_tiles)))
    v_parts = _v_lane_toggles_xla(
        jnp.asarray(a_pad),
        jnp.asarray(w_pad),
        rows=rows,
        cols=cols,
        k=k,
        n=n,
        b_v=b_v,
        block_t=block_t,
        tile_chunk=tile_chunk,
    )
    v_parts = np.asarray(v_parts).reshape(-1, v_parts.shape[-2], b_v)[:num_tiles]
    v_lanes = v_parts.astype(np.int64).sum(axis=(0, 1))
    return LaneToggleCounts(
        tuple(int(v) for v in h_lanes),
        tuple(int(v) for v in v_lanes),
        h_trans,
        v_trans,
    )
