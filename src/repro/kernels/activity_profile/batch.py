"""Stacked segment-window batched engine: one device program per shape class.

The per-GEMM engine (`ops.profile_gemm_toggles`) compiles and dispatches TWO
jitted programs per distinct GEMM shape — for a whole network that is a
recompile and a blocking round-trip per layer, and compile time dominates
(measured ~2s/shape vs ~0.5s of compute on CPU). This module profiles MANY
GEMMs with a handful of fused programs by flattening every job into
fixed-shape *segment tasks*:

  * Each job's activation stream is chopped into windows of ``t_seg`` steps
    **plus one seed row** — the stream value right before the window (the
    window's own first row for the first segment, so the nonexistent first
    transition counts zero). Toggle counts only ever compare consecutive
    stream values, so with the seed row included every window's count is
    independent: no carry between segments, no time-axis scan in the
    program, and a job of ANY stream length becomes an integer number of
    identical (t_seg + 1, rows) strips. Tail padding replicates the last
    row (repeated values toggle zero bits: count-neutral).
  * ``strips``  (S, t_seg + 1, rows) int32 — every (job, k-strip, segment)
    window, K zero-padded.
  * ``w_tiles`` (W, rows, cols) int32 — every job's distinct weight tiles
    (segments of one tile share a single copy).
  * per-task metadata (P,) int32 — ``strip_ids``/``w_ids`` route each task
    to its operands; ``valid_r`` is the true K extent of each task's tile
    (K-padding rows would duplicate the previous row's count, so they are
    gated out; zero-padded w COLUMNS hold their partial sums at zero and
    toggle nothing, needing no mask; ``valid_r == 0`` turns a whole dummy
    task off). Totals stay bit-exact vs the unpadded numpy oracle.

Tasks — not jobs — are the batch axis, so jobs of different M/K/N never pad
each other beyond the ≤2x segment rounding, and the program shape depends
only on (S, W, P, t_seg, rows, cols, b_h, b_v): a couple of shape classes
serve an entire network (see ``repro.core.pipeline`` for the bucketing).

Two engines, same counts (verified bit-exact in tests):

  * ``engine="xla"``    — ``bucket_toggle_parts``: ONE jitted program; h is
    a scan-free vectorized pass over strips, v runs lax.map over task
    chunks of a vmapped scan down R that carries (t_seg + 1, cols)
    partial-sum planes — the same cache-friendly inner loop as the
    per-GEMM engine, minus its outer time-block machinery.
  * ``engine="pallas"`` — the scalar-prefetch TPU kernel
    ``kernel.activity_profile_pallas_tasks`` for v plus the XLA h pass
    (h is O(T*K): a trivial fraction of the v work).

Both return *unconverted* device arrays so callers can overlap bucket i+1's
host-side operand synthesis with bucket i's device work (async dispatch);
block with ``reduce_bucket_parts`` when the totals are actually needed.

Output-stationary jobs need none of the partial-sum machinery: both OS
buses carry raw operand streams over the K axis, so an OS job contributes
two strips-only passes (the A rows as (K, M) lane streams, the W columns as
(K, N)) to *stream buckets* dispatched by ``stream_bucket_parts`` — the
same ``segment_strips`` windows, counted at the bus width, geometry-free
(the pipeline scales totals by the output-tile counts at collection).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.activity_profile.kernel import (
    activity_profile_pallas_tasks,
    bus_masks,
    stream_strips_toggles_pallas,
    value32_toggles,
)
from repro.kernels.bitops import popcount_u32
from repro.runtime.resilience import ContractViolationError

__all__ = [
    "TASK_CHUNK_BUDGET",
    "choose_task_chunk",
    "popcount_sum",
    "segment_strips",
    "bucket_toggle_parts",
    "stream_bucket_parts",
    "reduce_bucket_parts",
    "reduce_stream_parts",
]

# Vectorization width of the v pass: tasks per lax.map step, sized so one
# step's (chunk, t_seg + 1, cols) scan state is ~2^20 elements — big enough
# for XLA:CPU's intra-op threads to engage (measured ~30% faster than
# 32-lane steps), small enough that the ~6 live temporaries stay in tens
# of MB.
TASK_CHUNK_BUDGET = 1 << 20


def choose_task_chunk(num_tasks: int, t_seg1: int, cols: int) -> int:
    chunk = max(8, TASK_CHUNK_BUDGET // max(t_seg1 * cols, 1))
    if num_tasks <= chunk:
        return max(1, num_tasks)
    # Balance the final lax.map steps so chunk-rounding wastes < one step.
    steps = -(-num_tasks // chunk)
    return -(-num_tasks // steps)


def popcount_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Total popcount over ALL elements of a uint32 array.

    Plain SWAR-then-reduce: XLA:CPU fuses the whole per-word chain into the
    surrounding loop, which measures FASTER than a Harley–Seal carry-save
    tree here (the CSA group reshape/slicing defeats loop fusion).
    """
    return jnp.sum(popcount_u32(x))


def _toggles_sum_planes(xl: jnp.ndarray, xh: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Sum of ``bits``-bus toggles from lo/hi XOR planes (bits > 32)."""
    lo_m, hi_m = bus_masks(bits)
    cnt = popcount_sum(xl.astype(jnp.uint32) & jnp.uint32(lo_m))
    if hi_m:
        cnt = cnt + popcount_sum(xh.astype(jnp.uint32) & jnp.uint32(hi_m))
    return cnt.astype(jnp.int32)


def _toggles_sum_value32(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Sum of ``bits``-bus toggles from int32 XOR words (bits <= 32)."""
    lo_m, _ = bus_masks(min(bits, 32))
    return popcount_sum(x.astype(jnp.uint32) & jnp.uint32(lo_m)).astype(jnp.int32)


def segment_strips(a: np.ndarray, rows: int, t_seg: int) -> list[np.ndarray]:
    """Chop one job's (M, K) stream into seeded (t_seg + 1, rows) windows.

    Returns k-strip-major windows: ``[strip0_seg0, strip0_seg1, ...,
    strip1_seg0, ...]`` — ceil(K/rows) * ceil(M/t_seg) arrays. K zero-pads
    to a strip multiple; M tail-pads by edge replication; each window's row
    0 is the stream value preceding the window (its own first row for
    segment 0). All padding is count-neutral by construction.
    """
    m, k = a.shape
    if m < 1:
        raise ValueError("need at least one stream step")
    n_seg = max(1, -(-m // t_seg))
    pk = (-k) % rows
    a_pad = np.pad(a.astype(np.int32), ((0, n_seg * t_seg - m), (0, pk)), mode="edge")
    if pk:
        a_pad[:, k:] = 0
    out = []
    for kt in range(a_pad.shape[1] // rows):
        strip = a_pad[:, kt * rows : (kt + 1) * rows]
        for s in range(n_seg):
            t0 = s * t_seg
            seed = strip[t0 - 1 if s else t0]
            out.append(
                np.concatenate([seed[None], strip[t0 : t0 + t_seg]], axis=0)
            )
    return out


@functools.partial(
    jax.jit,
    static_argnames=("rows", "cols", "b_h", "b_v", "task_chunk"),
)
def _bucket_counts_xla(
    strips: jnp.ndarray,
    w_tiles: jnp.ndarray,
    strip_ids: jnp.ndarray,
    w_ids: jnp.ndarray,
    valid_r: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    task_chunk: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused program: h totals per strip window + v totals per task.

    Every count is int32-safe: a window holds t_seg * rows * cols <=
    2^20 plane elements (choose_block_t's budget), so per-strip h <=
    t_seg * rows * 64 and per-task v <= t_seg * rows * cols * 64 < 2^27.
    The caller reduces across strips/tasks in int64.
    """
    # --- horizontal: consecutive-row toggles, no scan at all ----------------
    h_parts = jax.vmap(
        lambda s: jnp.sum(value32_toggles(s[1:], s[:-1], b_h))
    )(strips)

    # --- vertical: lax.map over task chunks, scan down R per task -----------
    # Masking is cheap by construction: zero-padded w COLUMNS keep their
    # partial sums identically zero (no toggles, no mask needed), and a-pad
    # ROWS only ever duplicate the previous row's count, so validity is a
    # scalar gate on the per-row sum rather than an elementwise mask.
    t_seg1 = strips.shape[1]
    rix = jnp.arange(rows, dtype=jnp.int32)

    def per_task(p):
        aw = strips[strip_ids[p]]  # (t_seg + 1, rows)
        w_t = w_tiles[w_ids[p]]  # (rows, cols)
        vr = valid_r[p]

        if b_v <= 32:
            # Fast path: the bus sees only the low 32 bits of the sum, and
            # the lo plane evolves independently (mod-2^32 addition) — no
            # carry chain, no hi plane, one popcount per transition.
            def rstep(run_lo, xs):
                a_col, w_row, r = xs
                new_lo = run_lo + a_col[:, None] * w_row[None, :]
                cnt = _toggles_sum_value32(new_lo[1:] ^ new_lo[:-1], b_v)
                return new_lo, jnp.where(r < vr, cnt, 0)

            zero = jnp.zeros((t_seg1, cols), jnp.int32)
            _, cnts = jax.lax.scan(rstep, zero, (aw.T, w_t, rix))
            return jnp.sum(cnts)

        def rstep(carry, xs):
            run_lo, run_hi = carry  # (t_seg + 1, cols): S[., r-1, :] planes
            a_col, w_row, r = xs
            prod = a_col[:, None] * w_row[None, :]
            new_lo = run_lo + prod
            c = (new_lo.astype(jnp.uint32) < run_lo.astype(jnp.uint32)).astype(
                jnp.int32
            )
            new_hi = run_hi + (prod >> jnp.int32(31)) + c
            cnt = _toggles_sum_planes(
                new_lo[1:] ^ new_lo[:-1], new_hi[1:] ^ new_hi[:-1], b_v
            )
            return (new_lo, new_hi), jnp.where(r < vr, cnt, 0)

        zero = jnp.zeros((t_seg1, cols), jnp.int32)
        _, cnts = jax.lax.scan(rstep, (zero, zero), (aw.T, w_t, rix))
        return jnp.sum(cnts)

    ids = jnp.arange(strip_ids.shape[0], dtype=jnp.int32)
    v_parts = jax.lax.map(jax.vmap(per_task), ids.reshape(-1, task_chunk))
    return h_parts, v_parts.reshape(-1)


@functools.partial(jax.jit, static_argnames=("b_h",))
def _h_strips_xla(strips: jnp.ndarray, *, b_h: int) -> jnp.ndarray:
    """Standalone h pass for the Pallas engine (same math as above)."""
    return jax.vmap(lambda s: jnp.sum(value32_toggles(s[1:], s[:-1], b_h)))(strips)


def bucket_toggle_parts(
    strips: np.ndarray,
    w_tiles: np.ndarray,
    strip_ids: np.ndarray,
    w_ids: np.ndarray,
    valid_r: np.ndarray,
    *,
    rows: int,
    cols: int,
    b_h: int,
    b_v: int,
    engine: str = "auto",
    interpret: bool = False,
    device=None,
) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Dispatch one bucket's fused program; do NOT block on the result.

    Returns ``(h_parts, v_parts, num_tasks)``: per-strip and per-task int32
    totals, still computing when this returns (jax async dispatch) so the
    caller can overlap the next bucket's host-side work. Rows of
    ``v_parts`` past ``num_tasks`` are chunk-padding dummies.

    ``device`` places the bucket on a specific jax device — the pipeline
    round-robins buckets over ``jax.local_devices()`` so multi-device hosts
    (including CPU hosts running with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) crunch buckets
    genuinely in parallel.
    """
    num_tasks = int(strip_ids.shape[0])
    task_chunk = choose_task_chunk(num_tasks, int(strips.shape[1]), cols)
    pad = (-num_tasks) % task_chunk
    if pad:
        zeros = np.zeros(pad, np.int32)
        strip_ids = np.concatenate([strip_ids, zeros])
        w_ids = np.concatenate([w_ids, zeros])
        valid_r = np.concatenate([valid_r, zeros])  # vr=0 gates dummies off
    put = (lambda x: jax.device_put(x, device)) if device is not None else jnp.asarray
    args = (
        put(strips),
        put(w_tiles),
        put(strip_ids.astype(np.int32)),
        put(w_ids.astype(np.int32)),
        put(valid_r.astype(np.int32)),
    )
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" else "xla"
    if engine == "xla":
        h_parts, v_parts = _bucket_counts_xla(
            *args, rows=rows, cols=cols, b_h=b_h, b_v=b_v, task_chunk=task_chunk
        )
    elif engine == "pallas":
        h_parts = _h_strips_xla(args[0], b_h=b_h)
        v_parts = activity_profile_pallas_tasks(
            *args, rows=rows, cols=cols, b_v=b_v, interpret=interpret
        )
    else:
        # typed (still a ValueError subclass): an unknown engine is a
        # caller bug, not a retryable fault — it must raise in every
        # on_error mode rather than walk the degradation ladder
        raise ContractViolationError(f"unknown engine {engine!r}")
    return h_parts, v_parts, num_tasks


def stream_bucket_parts(
    strips: np.ndarray,
    *,
    bits: int,
    engine: str = "auto",
    interpret: bool = False,
    device=None,
) -> jnp.ndarray:
    """Dispatch one OPERAND-STREAM bucket's program; do NOT block.

    OS-dataflow jobs flatten each operand's per-lane streams into the same
    seeded (t_seg + 1, lane_chunk) windows as WS horizontal streams
    (``segment_strips`` on the time-major stream matrix) — there is no
    partial-sum arithmetic at all, so a bucket is ONE strips-only pass:
    per-strip toggle totals at the bus width ``bits``.  Returns the
    still-computing (S,) int32 device array (jax async dispatch).
    """
    put = (lambda x: jax.device_put(x, device)) if device is not None else jnp.asarray
    strips = put(strips)
    if engine == "auto":
        engine = "pallas" if jax.default_backend() == "tpu" else "xla"
    if engine == "xla":
        return _h_strips_xla(strips, b_h=bits)
    if engine == "pallas":
        return stream_strips_toggles_pallas(strips, bits=bits, interpret=interpret)
    raise ContractViolationError(f"unknown engine {engine!r}")


def reduce_bucket_parts(
    h_parts, v_parts, num_tasks: int
) -> tuple[np.ndarray, np.ndarray]:
    """Block on a bucket's device arrays; int64 per-strip / per-task totals."""
    h = np.asarray(h_parts).astype(np.int64)
    v = np.asarray(v_parts).astype(np.int64)[:num_tasks]
    return h, v


def reduce_stream_parts(parts) -> np.ndarray:
    """Block on a stream bucket's device array; int64 per-strip totals."""
    return np.asarray(parts).astype(np.int64)
