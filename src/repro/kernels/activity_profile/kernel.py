"""Fused Pallas TPU kernel: single-pass WS switching-activity profiling.

Replaces the host-side pipeline ``vertical_partial_sums`` (a materialized
(T, R, C) int64 cumsum) + XOR-popcount with ONE kernel that, per
``(weight_tile, t_block)`` grid cell:

  1. streams a ``(block_t, R)`` activation block through the resident
     ``(R, C)`` weight tile,
  2. forms the running partial-sum cumsum down R **in-kernel** — carried as
     lo/hi int32 planes so the paper's 37-bit accumulations stay exact
     without 64-bit arithmetic (the VPU has none),
  3. XORs each time step against its predecessor (the cross-block
     predecessor lives in VMEM scratch, persistent across the sequential
     grid), popcounts under the bus-width mask, and
  4. accumulates toggle totals for BOTH the horizontal input buses and the
     vertical partial-sum buses.

The (T, R, C) partial-sum tensor therefore never exists anywhere — not in
host memory, not in HBM; each element is produced, toggled against, and
discarded inside one VMEM-resident block.

Exact 64-bit partial sums from int32 lanes
------------------------------------------
For int16 operands every product fits int32. Split ``p = p_hi * 2^16 + p_lo``
with ``p_lo = p & 0xffff`` (in [0, 2^16)) and ``p_hi = p >> 16`` (arithmetic,
in [-2^15, 2^15)). Both planes cumsum exactly in int32 for any realistic R
(R < 2^15), and ``S = Hc * 2^16 + Lc`` is reconstructed mod 2^64 as
``(s_lo, s_hi)`` int32 planes with one unsigned-compare carry. Bus toggles on
a ``bits``-wide two's-complement bus are then popcounts of the XORed planes
under a static (lo_mask, hi_mask) split — exact for bits in [1, 64].

The same jnp helpers below are shared by the jitted XLA fallback in ops.py
(used when no TPU is attached), so both engines are one algorithm.

Output-stationary profiling needs no partial-sum machinery at all — both OS
buses carry raw operand streams — so its kernels are the lighter
``operand_stream_toggles_pallas`` (per-GEMM, time-blocked with a VMEM seed
carry) and ``stream_strips_toggles_pallas`` (batched seeded windows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitops import popcount_u32 as _popcount_u32

# Upper bound on block_t * rows * cols (elements of one in-flight plane).
# Keeps every temporary comfortably inside VMEM and bounds each grid cell's
# toggle partial at ~2^26 * 96 bits, far below int32 overflow.
DEFAULT_BLOCK_BUDGET = 1 << 20
MAX_BLOCK_T = 512
MIN_BLOCK_T = 8

__all__ = [
    "DEFAULT_BLOCK_BUDGET",
    "choose_block_t",
    "bus_masks",
    "partial_sum_planes",
    "planes_toggles",
    "value32_toggles",
    "activity_profile_pallas",
    "activity_profile_pallas_tasks",
    "operand_stream_toggles_pallas",
    "stream_strips_toggles_pallas",
]


def choose_block_t(rows: int, cols: int, budget: int = DEFAULT_BLOCK_BUDGET) -> int:
    """Time-block size: as many steps as the element budget allows, 8-aligned."""
    bt = budget // max(rows * cols, 1)
    bt = max(MIN_BLOCK_T, min(MAX_BLOCK_T, bt))
    return bt - (bt % MIN_BLOCK_T)


def bus_masks(bits: int) -> tuple[int, int]:
    """(lo_mask, hi_mask) selecting the low ``bits`` of a 64-bit lo/hi pair."""
    if not 1 <= bits <= 64:
        raise ValueError("bus width must be in [1, 64]")
    if bits >= 64:
        return 0xFFFFFFFF, 0xFFFFFFFF
    if bits >= 32:
        return 0xFFFFFFFF, (1 << (bits - 32)) - 1
    return (1 << bits) - 1, 0


def partial_sum_planes(
    a_block: jnp.ndarray, w_tile: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact 64-bit WS partial sums S[t, r, c] = sum_{r'<=r} a[t,r']*w[r',c].

    ``a_block`` is (BT, R) int32, ``w_tile`` is (R, C) int32; products must
    fit int32 (guaranteed for int16-range operands). Returns (s_lo, s_hi)
    int32 planes holding S mod 2^64.
    """
    p = a_block[:, :, None] * w_tile[None, :, :]
    p_lo = p & jnp.int32(0xFFFF)
    p_hi = p >> jnp.int32(16)  # arithmetic: p == p_hi * 2^16 + p_lo exactly
    acc_lo = jnp.cumsum(p_lo, axis=1)  # <= R * 0xffff, exact in int32
    acc_hi = jnp.cumsum(p_hi, axis=1)  # |.| <= R * 2^15, exact in int32
    # Reconstruct acc_hi * 2^16 + acc_lo as 64-bit lo/hi planes (mod 2^64).
    shifted = acc_hi << jnp.int32(16)
    s_lo = shifted + acc_lo
    carry = (s_lo.astype(jnp.uint32) < shifted.astype(jnp.uint32)).astype(jnp.int32)
    s_hi = (acc_hi >> jnp.int32(16)) + carry
    return s_lo, s_hi


def planes_toggles(
    s_lo: jnp.ndarray,
    s_hi: jnp.ndarray,
    p_lo: jnp.ndarray,
    p_hi: jnp.ndarray,
    bits: int,
) -> jnp.ndarray:
    """Per-element bit flips between two lo/hi-plane values on a ``bits`` bus."""
    lo_m, hi_m = bus_masks(bits)
    cnt = _popcount_u32((s_lo ^ p_lo).astype(jnp.uint32) & jnp.uint32(lo_m))
    if hi_m:
        cnt = cnt + _popcount_u32((s_hi ^ p_hi).astype(jnp.uint32) & jnp.uint32(hi_m))
    return cnt.astype(jnp.int32)


def value32_toggles(cur: jnp.ndarray, prev: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Bit flips between int32 values on a ``bits``-wide two's-complement bus.

    For bits > 32 the bus bits above 31 are sign-extension copies: they all
    flip together iff the sign bit flips.
    """
    x = cur ^ prev
    if bits <= 32:
        lo_m, _ = bus_masks(bits)
        return _popcount_u32(x.astype(jnp.uint32) & jnp.uint32(lo_m)).astype(jnp.int32)
    base = _popcount_u32(x.astype(jnp.uint32)).astype(jnp.int32)
    sign_flip = (x >> jnp.int32(31)) & jnp.int32(1)
    return base + sign_flip * jnp.int32(bits - 32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "rows", "cols", "k", "n", "b_h", "b_v", "block_t", "interpret",
    ),
)
def activity_profile_pallas(
    a_pad: jnp.ndarray,
    w_pad: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    k: int,
    n: int,
    b_h: int,
    b_v: int,
    block_t: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused toggle totals for every weight tile of a WS GEMM, in one pass.

    ``a_pad`` is (T_pad, K_pad) int32 — T edge-padded (replicated last row:
    zero extra toggles), K zero-padded to a multiple of ``rows``. ``w_pad``
    is (K_pad, N_pad) int32, zero-padded. ``k``/``n`` are the true (unpadded)
    GEMM dims; edge tiles mask their padding lanes out of the counts, so
    totals are bit-exact vs. the unpadded numpy oracle.

    Returns per-grid-cell int32 partials ``(h_out, v_out)`` of shape
    (num_tiles, num_t_blocks); the caller reduces them in int64. Each cell's
    count is bounded by block_t*rows*cols*(64+b_h) < 2^31 via choose_block_t.
    """
    t_pad, k_pad = a_pad.shape
    n_pad = w_pad.shape[1]
    if t_pad % block_t or k_pad % rows or n_pad % cols:
        raise ValueError(
            f"padded shapes {(t_pad, k_pad, n_pad)} not multiples of "
            f"{(block_t, rows, cols)}"
        )
    k_tiles = k_pad // rows
    n_tiles = n_pad // cols
    num_tiles = k_tiles * n_tiles
    num_tb = t_pad // block_t

    def kernel(a_ref, w_ref, h_ref, v_ref, prev_lo, prev_hi, prev_a):
        p = pl.program_id(0)
        j = pl.program_id(1)
        a = a_ref[...]  # (block_t, rows)
        w = w_ref[...]  # (rows, cols)
        s_lo, s_hi = partial_sum_planes(a, w)

        # First t-block of a tile: seed the carry with t=0 so the (nonexistent)
        # transition into the first time step contributes zero toggles.
        @pl.when(j == 0)
        def _():
            prev_lo[...] = s_lo[0]
            prev_hi[...] = s_hi[0]
            prev_a[...] = a[:1]

        lag_lo = jnp.concatenate([prev_lo[...][None], s_lo[:-1]], axis=0)
        lag_hi = jnp.concatenate([prev_hi[...][None], s_hi[:-1]], axis=0)
        lag_a = jnp.concatenate([prev_a[...], a[:-1]], axis=0)

        # Edge tiles: mask PEs beyond the true K/N extent out of the counts.
        kt = p // n_tiles
        nt = p % n_tiles
        valid_r = jnp.minimum(rows, k - kt * rows)
        valid_c = jnp.minimum(cols, n - nt * cols)
        rid = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0)
        cid = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
        vmask = (rid < valid_r) & (cid < valid_c)
        hmask = jax.lax.broadcasted_iota(jnp.int32, (1, rows), 1) < valid_r

        v_cnt = planes_toggles(s_lo, s_hi, lag_lo, lag_hi, b_v)
        h_cnt = value32_toggles(a, lag_a, b_h)
        v_ref[0, 0] = jnp.sum(jnp.where(vmask[None, :, :], v_cnt, 0))
        h_ref[0, 0] = jnp.sum(jnp.where(hmask, h_cnt, 0))

        prev_lo[...] = s_lo[-1]
        prev_hi[...] = s_hi[-1]
        prev_a[...] = a[-1:]

    return pl.pallas_call(
        kernel,
        grid=(num_tiles, num_tb),
        in_specs=[
            pl.BlockSpec((block_t, rows), lambda p, j: (j, p // n_tiles)),
            pl.BlockSpec((rows, cols), lambda p, j: (p // n_tiles, p % n_tiles)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda p, j: (p, j)),
            pl.BlockSpec((1, 1), lambda p, j: (p, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, num_tb), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, num_tb), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, cols), jnp.int32),
            pltpu.VMEM((rows, cols), jnp.int32),
            pltpu.VMEM((1, rows), jnp.int32),
        ],
        interpret=interpret,
    )(a_pad, w_pad)


@functools.partial(jax.jit, static_argnames=("bits", "block_t", "interpret"))
def operand_stream_toggles_pallas(
    x_pad: jnp.ndarray,
    *,
    bits: int,
    block_t: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Toggle partials for a bundle of independent operand lane streams.

    The OS dataflow streams OPERANDS on both array axes — per-lane value
    sequences with no cross-lane arithmetic — so its per-GEMM profile needs
    only this kernel: ``x_pad`` is (T_pad, L) int32, one stream per column,
    T edge-padded to a ``block_t`` multiple (replicated values toggle zero
    bits).  One grid cell per time block; the previous block's last row is
    carried in VMEM scratch so cross-block transitions count exactly once.
    Returns (num_t_blocks, block_t) int32 partials reduced per TIME ROW,
    not per block — each bounded by L * 64 regardless of ``block_t``
    (< 2^31 for any L < 2^25, the ``MAX_FUSED_LANES`` contract), exactly
    like the XLA h pass; the caller reduces in int64.
    """
    t_pad, lanes = x_pad.shape
    if t_pad % block_t:
        raise ValueError(f"padded stream length {t_pad} not a multiple of {block_t}")
    num_tb = t_pad // block_t

    def kernel(x_ref, o_ref, prev_x):
        j = pl.program_id(0)
        x = x_ref[...]  # (block_t, lanes)

        @pl.when(j == 0)
        def _():
            prev_x[...] = x[:1]

        lag = jnp.concatenate([prev_x[...], x[:-1]], axis=0)
        o_ref[0, :] = jnp.sum(value32_toggles(x, lag, bits), axis=1)
        prev_x[...] = x[-1:]

    return pl.pallas_call(
        kernel,
        grid=(num_tb,),
        in_specs=[pl.BlockSpec((block_t, lanes), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((1, block_t), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tb, block_t), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, lanes), jnp.int32)],
        interpret=interpret,
    )(x_pad)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def stream_strips_toggles_pallas(
    strips: jnp.ndarray,
    *,
    bits: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-strip toggle totals for STACKED seeded stream windows.

    The batch pipeline flattens OS operand streams (and WS horizontal
    streams) into independent (t_seg + 1, lanes) windows whose row 0 seeds
    the cross-window transition (see ``batch.segment_strips``); each grid
    cell toggles one window.  Returns (S,) int32 totals, each bounded by
    t_seg * lanes * 64 < 2^31 by the segment budget; callers reduce int64.
    """
    num_strips, t_seg1, lanes = strips.shape

    def kernel(s_ref, o_ref):
        s = s_ref[0]  # (t_seg + 1, lanes)
        o_ref[0] = jnp.sum(value32_toggles(s[1:], s[:-1], bits))

    return pl.pallas_call(
        kernel,
        grid=(num_strips,),
        in_specs=[pl.BlockSpec((1, t_seg1, lanes), lambda p: (p, 0, 0))],
        out_specs=pl.BlockSpec((1,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((num_strips,), jnp.int32),
        interpret=interpret,
    )(strips)


@functools.partial(
    jax.jit,
    static_argnames=("rows", "cols", "b_v", "interpret"),
)
def activity_profile_pallas_tasks(
    strips: jnp.ndarray,
    w_tiles: jnp.ndarray,
    strip_ids: jnp.ndarray,
    w_ids: jnp.ndarray,
    valid_r: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    b_v: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Vertical-bus toggles for a STACKED segment-task batch (multi-GEMM).

    The batch pipeline (`repro.kernels.activity_profile.batch`) flattens
    many GEMMs into fixed-shape segment tasks; this kernel runs one task
    per grid cell. Task metadata rides in scalar-prefetch arrays so the
    BlockSpec index maps can route each cell to its operands: ``strips`` is
    (S, t_seg + 1, rows) seeded stream windows, ``w_tiles`` (W, rows, cols),
    ``strip_ids``/``w_ids``/``valid_r`` (P,) int32. Each cell walks the
    reduction rows with a fori_loop carrying the (t_seg + 1, cols)
    partial-sum lo/hi planes — the (T, R, C) tensor never exists, VMEM holds
    one strip window + one weight tile + two plane carries. K-padding rows
    (r >= valid_r) would duplicate the previous row's count and are gated
    out of the scalar sum; zero-padded w columns toggle nothing by
    construction; valid_r == 0 turns dummy chunk-padding tasks off.
    Returns (P,) int32 totals; the caller reduces in int64 (each total <=
    t_seg*rows*cols*64 < 2^27 by the choose_block_t budget). Horizontal
    counts are per-strip, not per-task, and run in the sibling XLA strips
    pass (a trivial fraction of the work).
    """
    num_tasks = strip_ids.shape[0]
    t_seg1 = strips.shape[1]

    def kernel(sid_ref, wid_ref, vr_ref, a_ref, w_ref, v_ref):
        p = pl.program_id(0)
        aw = a_ref[0]  # (t_seg + 1, rows)
        w = w_ref[0]  # (rows, cols)
        vr = vr_ref[p]

        def body(r, carry):
            run_lo, run_hi, acc = carry  # planes: (t_seg + 1, cols)
            a_col = jax.lax.dynamic_index_in_dim(aw, r, axis=1, keepdims=False)
            w_row = jax.lax.dynamic_index_in_dim(w, r, axis=0, keepdims=False)
            prod = a_col[:, None] * w_row[None, :]
            new_lo = run_lo + prod
            if b_v <= 32:
                # lo plane alone is exact for buses <= 32 bits (mod-2^32
                # addition); skip the carry chain and the hi popcount
                new_hi = run_hi
                cnt = jnp.sum(value32_toggles(new_lo[1:], new_lo[:-1], b_v))
            else:
                c = (new_lo.astype(jnp.uint32) < run_lo.astype(jnp.uint32)).astype(
                    jnp.int32
                )
                new_hi = run_hi + (prod >> jnp.int32(31)) + c
                cnt = jnp.sum(
                    planes_toggles(
                        new_lo[1:], new_hi[1:], new_lo[:-1], new_hi[:-1], b_v
                    )
                )
            return new_lo, new_hi, acc + jnp.where(r < vr, cnt, 0)

        zero = jnp.zeros((t_seg1, cols), jnp.int32)
        _, _, acc = jax.lax.fori_loop(0, rows, body, (zero, zero, jnp.int32(0)))
        v_ref[0] = acc

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_tasks,),
        in_specs=[
            pl.BlockSpec((1, t_seg1, rows), lambda p, sid, wid, vr: (sid[p], 0, 0)),
            pl.BlockSpec((1, rows, cols), lambda p, sid, wid, vr: (wid[p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda p, sid, wid, vr: (p,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_tasks,), jnp.int32),
        interpret=interpret,
    )(strip_ids, w_ids, valid_r, strips, w_tiles)
