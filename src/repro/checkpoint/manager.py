"""Atomic, keep-K checkpointing of full train state (params/opt/step/data).

Design points for the 1000-node posture:
  * atomic directory commit (write to ``<step>.tmp``, fsync, rename) — a
    preempted save never corrupts the latest checkpoint;
  * per-leaf .npy files + a JSON manifest with the pytree structure — each
    host can save/restore only its FSDP shard slice (``shard_info`` hook);
  * keep-last-K garbage collection;
  * restore() is pure: (dir) -> train_state pytree + step + data state.

numpy .npy is the storage format (no orbax in this container); the manager's
API mirrors orbax's CheckpointManager so swapping backends is mechanical.

Scope note: this manager checkpoints STEP-INDEXED train state (a mutable
pytree evolving through time, restored by recency).  The design-space sweep
runner (``core.sweep``) deliberately does NOT reuse it: sweep chunks are
idempotent pure functions of their key, so they live in the
content-addressed ``core.store.ContentStore`` (resume = key lookup, no
step ordering, no keep-K).  The two share the atomic-write primitive —
``core.store.atomic_write_bytes`` below — which is the piece of this
module's seed machinery that generalized.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.core.store import atomic_write_bytes


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, extra: dict | None = None) -> Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves = _flatten_with_paths(state)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for key, leaf in leaves:
            arr = np.asarray(leaf)
            fname = key.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            )
        # manifest lands via tmp+fsync+replace (shared crash-safe primitive),
        # then the whole directory commits atomically via rename
        atomic_write_bytes(tmp / "manifest.json", json.dumps(manifest).encode())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays/specs)."""
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_key = {e["key"]: e for e in manifest["leaves"]}
        paths = _flatten_with_paths(like)
        leaves = []
        for key, leaf in paths:
            e = by_key[key]
            arr = np.load(d / e["file"])
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, manifest.get("extra", {})

    def restore_latest(self, like: Any) -> tuple[int, Any, dict] | None:
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, like)
        return step, state, extra

    # -- gc -------------------------------------------------------------------

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
