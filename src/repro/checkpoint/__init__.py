"""Checkpointing."""
