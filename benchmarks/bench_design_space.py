"""Design-space exploration through the jitted array-first engine.

Builds a declarative ``DesignSpace`` (rows x cols x input bits x WS/OS
dataflow x bus-invert x PE area), couples it to MEASURED network activity
profiles (one ``run_profile_batch`` pass per activity class feeds the whole
cols/area/coding cross product; OS classes are geometry-free), evaluates
the full grid — per-point Eq. 6 optima, batched log-space golden-section
cross-checks, vectorized minimax-regret across the workload axis,
calibrated savings, plus the (P, S) aspect-sweep surface — and extracts the
Pareto frontier over (bus power, area, worst-case regret).

The ``design_space/os_approx_error`` row quantifies the retired
``a_v := a_h`` OS approximation: the measured-vs-approximated OS vertical
activity delta and how many design-space winners (Pareto members, best
points) flip once OS activities are measured from the real W-operand
streams.

Reported throughput counts *design points* — (geometry config, aspect)
cells, the aspect being the design variable the paper is about, with the
per-geometry statistics (W workload optima + robust minimax + savings)
folded into each geometry's S cells; the grid row spells the accounting
out ("P geometry configs x S aspect choices").  The baseline loops the
scalar dataclass API over a sampled subset doing identical math.
Vectorized results are verified ``allclose`` against the scalar closed
forms on that subset; the run fails loudly on divergence, an empty
frontier, or a sub-floor speedup.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.design_space import (
    _HAS_JAX,
    DesignSpace,
    evaluate_design_space,
    sweep_bus_power,
)
from repro.core.energy import power_breakdown
from repro.core.floorplan import (
    ASPECT_MAX,
    ASPECT_MIN,
    BusActivity,
    SystolicArrayGeometry,
    bus_power,
    optimal_aspect_power,
)
from repro.core.optimize import (
    bus_invert_activity,
    bus_invert_geometry,
    max_regret,
    robust_design_point,
)
from repro.core.switching import ActivityProfile
from repro.core.workloads import (
    RESNET50_TABLE1,
    ConvLayer,
    measured_design_activities,
)

# Small synthetic conv layers for the CI smoke configuration: the measured
# coupling is exercised end to end, but each profiling pass is milliseconds.
SMOKE_LAYERS = (
    ConvLayer("S1", k=1, h=10, w=10, c=64, m=48, input_density=0.55),
    ConvLayer("S2", k=1, h=8, w=8, c=96, m=64, input_density=0.40),
    ConvLayer("S3", k=1, h=8, w=8, c=48, m=96, input_density=0.30),
)

SPEEDUP_TARGET = 50.0  # acceptance: full grid, jitted engine vs scalar loop
SPEEDUP_FLOOR_SMOKE = 5.0


def _space(smoke: bool) -> DesignSpace:
    if smoke:
        return DesignSpace(
            rows=(4, 8),
            cols=(4, 6, 8, 12, 16, 24, 32, 48),
            input_bits=(8,),
            dataflows=("WS", "OS"),
            bus_invert=(False, True),
            pe_area_um2=(900.0, 1200.0),
        )
    return DesignSpace(
        rows=(8, 16, 32, 64),
        cols=(4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 160, 192, 224, 256, 320),
        input_bits=(8, 16),
        dataflows=("WS", "OS"),
        bus_invert=(False, True),
        pe_area_um2=(800.0, 1000.0, 1200.0, 1600.0),
    )


def _scalar_point_eval(grid, i, a_h, a_v, comb_h, comb_v, aspects):
    """Everything the engine computes for geometry point i, via the scalar
    dataclass API: per-workload BI transform + Eq. 6 optimum + powers,
    minimax-regret robust aspect, calibrated breakdowns, and the point's
    aspect-sweep row."""
    geom = grid.geometry(i)
    coded = bool(grid.bus_invert[i])
    bits = int(grid.b_v_data[i])
    acts, profs = [], []
    for w in range(a_h.shape[0]):
        av = float(a_v[w, i])
        if coded:
            av = bus_invert_activity(av, bits)
        act = BusActivity(float(a_h[w, i]), av)
        acts.append(act)
        profs.append(ActivityProfile(act.a_h, act.a_v, geom.b_h, geom.b_v, 1, 1, 0.0))
        opt = optimal_aspect_power(geom, act)
        bus_power(geom, act, opt)
        bus_power(geom, act, 1.0)
    robust = robust_design_point(geom, profs, "minimax")
    mr = max_regret(geom, acts, robust)
    for act in acts:
        power_breakdown(geom, act, robust)
        power_breakdown(geom, act, 1.0)
    cv = float(comb_v[i])
    if coded:
        cv = bus_invert_activity(cv, bits)
    c_act = BusActivity(float(comb_h[i]), cv)
    sweep_row = [bus_power(geom, c_act, float(a)) for a in aspects]
    return robust, mr, np.asarray(sweep_row)


def run(smoke: bool = False) -> list[dict]:
    out = []
    space = _space(smoke)
    grid = space.expand()
    layers = SMOKE_LAYERS if smoke else RESNET50_TABLE1
    aspects = np.exp(
        np.linspace(np.log(ASPECT_MIN), np.log(ASPECT_MAX), 64 if smoke else 128)
    )
    p, s = grid.n_points, len(aspects)
    n_cells = p * s

    # --- measured activity coupling (profiling passes shared per class) ----
    t0 = time.perf_counter()
    a_h, a_v, stats = measured_design_activities(grid, layers, return_stats=True)
    t_profile = time.perf_counter() - t0
    comb_h, comb_v = a_h.mean(axis=0), a_v.mean(axis=0)
    out.append(
        {
            "name": "design_space/grid",
            "us_per_call": t_profile * 1e6 / max(stats.jobs, 1),
            "dataflow": "WS+OS",
            "derived": (
                f"{p} geometry configs x {s} aspect choices = {n_cells} design points "
                f"(workloads={a_h.shape[0]} profile_jobs={stats.jobs} "
                f"cache_hits={stats.cache_hits} passes={stats.passes} "
                f"profile_s={t_profile:.2f})"
            ),
        }
    )

    # --- jitted engine: full grid ------------------------------------------
    use_jit = _HAS_JAX
    evaluate_design_space(grid, a_h, a_v, use_jit=use_jit)  # compile
    sweep_bus_power(grid, comb_h, comb_v, aspects, use_jit=use_jit)
    t_eval = min(
        _timed(lambda: evaluate_design_space(grid, a_h, a_v, use_jit=use_jit))
        for _ in range(3)
    )
    t_sweep = min(
        _timed(lambda: sweep_bus_power(grid, comb_h, comb_v, aspects, use_jit=use_jit))
        for _ in range(3)
    )
    ev = evaluate_design_space(grid, a_h, a_v, use_jit=use_jit)
    surf = sweep_bus_power(grid, comb_h, comb_v, aspects, use_jit=use_jit)
    t_vec = t_eval + t_sweep
    vec_rate = n_cells / t_vec
    out.append(
        {
            "name": "design_space/engine",
            "us_per_call": t_vec * 1e6 / n_cells,
            "dataflow": "WS+OS",
            "derived": (
                f"jit={use_jit} {vec_rate:,.0f} points/s "
                f"(eval {t_eval*1e3:.1f}ms + sweep {t_sweep*1e3:.1f}ms for {n_cells} cells)"
            ),
        }
    )

    # --- scalar-API baseline on a sampled subset ---------------------------
    rng = np.random.default_rng(0)
    sample = rng.choice(p, size=min(p, 8), replace=False)
    t0 = time.perf_counter()
    scalar_results = {
        int(i): _scalar_point_eval(grid, int(i), a_h, a_v, comb_h, comb_v, aspects)
        for i in sample
    }
    t_scalar = time.perf_counter() - t0
    scalar_rate = len(sample) * s / t_scalar
    speedup = vec_rate / scalar_rate
    out.append(
        {
            "name": "design_space/scalar_baseline",
            "us_per_call": t_scalar * 1e6 / (len(sample) * s),
            "derived": f"{scalar_rate:,.0f} points/s over {len(sample)} sampled configs",
        }
    )
    out.append(
        {
            "name": "design_space/speedup",
            "us_per_call": 0.0,
            "derived": f"{speedup:.1f}x vs scalar loop (target >={SPEEDUP_TARGET:.0f}x full)",
        }
    )

    # --- verify the engine against the scalar closed forms -----------------
    max_rel = 0.0
    for i, (robust_s, mr_s, sweep_s) in scalar_results.items():
        for w in range(a_h.shape[0]):
            av = float(a_v[w, i])
            if grid.bus_invert[i]:
                av = bus_invert_activity(av, int(grid.b_v_data[i]))
            act = BusActivity(float(a_h[w, i]), av)
            geom = grid.geometry(i)
            opt_s = optimal_aspect_power(geom, act)
            p_s = bus_power(geom, act, opt_s)
            max_rel = max(
                max_rel,
                abs(float(ev.aspect_opt[w, i]) - opt_s) / opt_s,
                abs(float(ev.bus_power_opt[w, i]) - p_s) / p_s,
            )
        np.testing.assert_allclose(surf[i], sweep_s, rtol=2e-4)
        # regret curves are flat near the optimum: compare achieved regret
        assert float(ev.max_regret[i]) <= mr_s * (1 + 5e-3) + 1e-6, (
            f"engine robust point worse than scalar at {i}: "
            f"{float(ev.max_regret[i]):.6f} vs {mr_s:.6f}"
        )
    assert max_rel < 2e-4, f"scalar/vector divergence {max_rel:.2e}"
    out.append(
        {
            "name": "design_space/parity",
            "us_per_call": 0.0,
            "derived": f"max rel err vs scalar closed forms {max_rel:.1e} (n={len(sample)})",
        }
    )
    if smoke:
        assert speedup >= SPEEDUP_FLOOR_SMOKE, (
            f"smoke speedup {speedup:.1f}x below floor {SPEEDUP_FLOOR_SMOKE}x"
        )
    else:
        assert n_cells >= 100_000, f"full grid too small: {n_cells}"
        if use_jit:
            assert speedup >= SPEEDUP_TARGET, (
                f"speedup {speedup:.1f}x below target {SPEEDUP_TARGET}x"
            )

    # --- Pareto frontier over (bus power, area, worst-case regret) ---------
    mask = ev.pareto()
    assert mask.any(), "empty Pareto frontier"
    idx = np.flatnonzero(mask)
    best_p = idx[np.argmin(ev.bus_power_robust[idx])]
    best_r = idx[np.argmin(ev.max_regret[idx])]
    os_mask = np.asarray(grid.dataflow_os, bool)
    out.append(
        {
            "name": "design_space/pareto",
            "us_per_call": 0.0,
            "dataflow": "WS+OS",
            "derived": (
                f"frontier {mask.sum()}/{p} (WS {int((mask & ~os_mask).sum())} / "
                f"OS {int((mask & os_mask).sum())}); "
                f"min-power {grid.describe(int(best_p))} "
                f"W/H*={float(ev.aspect_robust[best_p]):.2f}; "
                f"min-regret {grid.describe(int(best_r))} "
                f"regret={float(ev.max_regret[best_r])*100:.2f}%"
            ),
        }
    )

    # --- retired OS approximation: measured vs a_v := a_h ------------------
    # Re-evaluate the identical grid with OS vertical activities overwritten
    # by the old convention (the A-operand's activity) and count how many
    # design-space winners the measurement flips.
    assert os_mask.any(), "space must contain OS points"
    a_v_approx = np.where(os_mask[None, :], a_h, a_v)
    delta = np.abs(a_v - a_v_approx)[:, os_mask]
    ev_apx = evaluate_design_space(grid, a_h, a_v_approx, use_jit=use_jit)
    mask_apx = ev_apx.pareto()
    pareto_flips = int((mask != mask_apx).sum())
    rank = np.argsort(np.argsort(ev.bus_power_robust))
    rank_apx = np.argsort(np.argsort(ev_apx.bus_power_robust))
    moved = int((rank != rank_apx).sum())
    winner = int(np.argmin(ev.bus_power_robust))
    winner_apx = int(np.argmin(ev_apx.bus_power_robust))
    assert float(delta.max()) > 0.0, "measured OS a_v identical to a_h?"
    out.append(
        {
            "name": "design_space/os_approx_error",
            "us_per_call": 0.0,
            "dataflow": "OS",
            "derived": (
                f"OS a_v delta mean={float(delta.mean()):.4f} "
                f"max={float(delta.max()):.4f} over {int(os_mask.sum())} points; "
                f"pareto_flips={pareto_flips} rank_moves={moved}/{p} "
                f"min_power_winner {grid.describe(winner_apx)} -> "
                f"{grid.describe(winner)}"
                f"{' (flipped)' if winner != winner_apx else ' (unchanged)'}"
            ),
        }
    )

    # --- mean-lane approximation error of the aggregate-activity path ------
    # ``bus_switched_capacitance_arr`` consumers price every wire of a bus at
    # the AGGREGATE activity ``a`` — exactly the mean-lane approximation of
    # the per-lane roll-up (sum of lane activities == a * width), so it is
    # EXACT whenever every segment carries the full bus (the uniform family)
    # and an approximation the moment segment widths vary per lane (multi-pod
    # interior buses carry only the low pod-accumulator lanes).  Quantify
    # both on measured per-lane profiles.
    from repro.core.workloads import measured_design_lane_activities
    from repro.layout import evaluate_layout_space

    lane_space = DesignSpace(
        rows=(8,) if smoke else (32,),
        cols=(8, 16) if smoke else (16, 32),
        input_bits=(8,) if smoke else (16,),
    )
    lane_grid = lane_space.expand()
    lane_layers = layers[:2]
    l_ah, l_av, h_lanes, v_lanes = measured_design_lane_activities(
        lane_grid, lane_layers
    )
    lane_layouts = ("uniform", "pods4x4")
    ev_lane = evaluate_layout_space(
        lane_grid, l_ah, l_av, layouts=lane_layouts,
        h_lanes=h_lanes, v_lanes=v_lanes, use_jit=False,
    )
    ev_mean = evaluate_layout_space(
        lane_grid, l_ah, l_av, layouts=lane_layouts, use_jit=False
    )
    rel = np.abs(ev_lane.bus_power_robust / ev_mean.bus_power_robust - 1.0)
    err_uniform = float(rel[0].max())
    err_pods = float(rel[1].max())
    assert err_uniform < 1e-9, (
        f"mean-lane approximation must be exact on the uniform family "
        f"(got {err_uniform:.2e})"
    )
    assert err_pods > 0.0, "per-lane roll-up identical to mean-lane on pods?"
    out.append(
        {
            "name": "layout/lane_approx_error",
            "us_per_call": 0.0,
            "dataflow": "WS",
            "layout": "+".join(lane_layouts),
            "derived": (
                f"aggregate-a (mean-lane) vs per-lane roll-up over "
                f"{lane_grid.n_points} points x {l_ah.shape[0]} workloads: "
                f"uniform rel err {err_uniform:.1e} (exact), "
                f"pods4x4 rel err {err_pods:.2e} (lane-subset buses)"
            ),
        }
    )

    # --- chunked sweep: cold vs kill-and-resume ----------------------------
    # The checkpointed runner must (a) reproduce the monolithic engine
    # bit-for-bit, (b) resume a completed store in wall-clock dominated by
    # chunk reads (not re-evaluation), and (c) keep warm chunked+validated
    # throughput above the same floor the layout bench enforces.
    import tempfile

    from repro.core.sweep import _DESIGN_FIELDS, SweepConfig

    sweep_floor = 1.0e4  # warm chunked points/s (bench_layout's floor)
    with tempfile.TemporaryDirectory() as td:
        # per-chunk guard cost (~2ms: scalar-oracle cells + f64 gss
        # cross-check) must amortize over enough points to clear the floor
        chunk = 64 if smoke else 512
        sw = lambda: SweepConfig(chunk_size=chunk, store=td)
        t0 = time.perf_counter()
        ev_cold = evaluate_design_space(grid, a_h, a_v, use_jit=use_jit, sweep=sw())
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        ev_res = evaluate_design_space(grid, a_h, a_v, use_jit=use_jit, sweep=sw())
        t_res = time.perf_counter() - t0
        rep_cold, rep_res = ev_cold.sweep_report, ev_res.sweep_report
        for f in _DESIGN_FIELDS:
            a = np.ascontiguousarray(getattr(ev_cold, f))
            b = np.ascontiguousarray(getattr(ev_res, f))
            assert a.tobytes() == b.tobytes(), f"resume not bit-identical: {f}"
        assert np.array_equal(ev_cold.pareto(), ev_res.pareto())
        assert rep_res.chunks_resumed == rep_res.chunks_total, "resume missed chunks"
        assert rep_res.chunks_evaluated == 0, "resume re-evaluated chunks"
        assert rep_cold.guard_failures == 0 and rep_res.guard_failures == 0
        assert t_res < t_cold, (
            f"resumed sweep ({t_res*1e3:.1f}ms) not faster than cold "
            f"({t_cold*1e3:.1f}ms)"
        )
        # warm chunked+validated throughput (compile cache hot, no store I/O)
        t_warm = min(
            _timed(
                lambda: evaluate_design_space(
                    grid, a_h, a_v, use_jit=use_jit,
                    sweep=SweepConfig(chunk_size=chunk),
                )
            )
            for _ in range(3)
        )
        warm_rate = p / t_warm
        assert warm_rate >= sweep_floor, (
            f"warm chunked sweep {warm_rate:,.0f} points/s below floor "
            f"{sweep_floor:,.0f}"
        )
    out.append(
        {
            "name": "design_space/sweep_resume",
            "us_per_call": t_res * 1e6 / p,
            "dataflow": "WS+OS",
            "derived": (
                f"cold {t_cold*1e3:.1f}ms (incl. chunk compile) -> resumed "
                f"{t_res*1e3:.1f}ms over {rep_cold.chunks_total} chunks of "
                f"{chunk}; bit-identical; warm chunked {warm_rate:,.0f} "
                f"points/s (floor {sweep_floor:,.0f})"
            ),
            "sweep": {
                "cold": rep_cold.as_dict(),
                "resumed": rep_res.as_dict(),
            },
        }
    )

    # --- legacy closed-form composition row (continuity with older runs) ---
    geom = SystolicArrayGeometry.paper_32x32()
    act = BusActivity.paper_resnet50()
    geom2, act2 = bus_invert_geometry(geom, act)
    p_square = bus_power(geom, act, 1.0)
    p_asym = bus_power(geom, act, optimal_aspect_power(geom, act))
    p_both = bus_power(geom2, act2, optimal_aspect_power(geom2, act2))
    out.append(
        {
            "name": "design_space/bus_invert_plus_asym",
            "us_per_call": 0.0,
            "dataflow": "WS",
            "derived": (
                f"a_v {act.a_v:.2f}->{act2.a_v:.3f}; bus power vs square: "
                f"asym-only -{(1-p_asym/p_square)*100:.1f}%, "
                f"BI+asym -{(1-p_both/p_square)*100:.1f}%"
            ),
        }
    )
    return out


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
