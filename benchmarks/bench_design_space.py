"""Beyond-paper design-space sweep:

  * array-size scaling (B_v grows as 2B + log2 R -> the optimal asymmetry
    and its savings grow with the array),
  * robust multi-workload design points (average / weighted / minimax),
  * output-stationary dataflow (asymmetry vanishes),
  * bus-invert coding on the vertical bus composed with the asymmetric
    floorplan (the paper's ref [19], quantified jointly).
"""

from __future__ import annotations

from repro.core.energy import compare_sym_asym
from repro.core.floorplan import (
    BusActivity,
    SystolicArrayGeometry,
    accumulator_width,
    bus_power,
    optimal_aspect_power,
)
from repro.core.optimize import (
    bus_invert_geometry,
    max_regret,
    os_dataflow_geometry,
    robust_design_point,
)
from repro.core.switching import ActivityProfile

ACT = BusActivity.paper_resnet50()


def run() -> list[dict]:
    out = []

    # --- array-size scaling --------------------------------------------------
    for r in (8, 16, 32, 64, 128):
        geom = SystolicArrayGeometry(
            rows=r, cols=r, b_h=16, b_v=accumulator_width(16, r)
        )
        c = compare_sym_asym(geom, ACT)
        out.append(
            {
                "name": f"design_space/size_{r}x{r}_int16",
                "us_per_call": 0.0,
                "derived": (
                    f"B_v={geom.b_v} W/H*={optimal_aspect_power(geom, ACT):.2f} "
                    f"interconnect_saving={c.interconnect_saving*100:.1f}%"
                ),
            }
        )

    # --- robust multi-workload design points ---------------------------------
    geom = SystolicArrayGeometry.paper_32x32()
    profiles = [
        ActivityProfile(0.15, 0.30, 16, 37, 1000, 1000, 0.6),
        ActivityProfile(0.25, 0.40, 16, 37, 1000, 1000, 0.5),
        ActivityProfile(0.35, 0.45, 16, 37, 1000, 1000, 0.3),
    ]
    acts = [p.as_bus_activity() for p in profiles]
    for strat in ("average", "minimax"):
        d = robust_design_point(geom, profiles, strat)
        out.append(
            {
                "name": f"design_space/robust_{strat}",
                "us_per_call": 0.0,
                "derived": (
                    f"W/H={d:.2f} max_regret={max_regret(geom, acts, d)*100:.2f}% "
                    f"(vs square {max_regret(geom, acts, 1.0)*100:.2f}%)"
                ),
            }
        )

    # --- output-stationary ----------------------------------------------------
    os_geom = os_dataflow_geometry(16, 32, 32)
    out.append(
        {
            "name": "design_space/output_stationary",
            "us_per_call": 0.0,
            "derived": (
                f"B_h=B_v={os_geom.b_h}: W/H*="
                f"{optimal_aspect_power(os_geom, BusActivity(0.3, 0.3)):.2f} "
                "(asymmetry is a WS-dataflow property)"
            ),
        }
    )

    # --- bus-invert composition ------------------------------------------------
    geom2, act2 = bus_invert_geometry(geom, ACT)
    p_square = bus_power(geom, ACT, 1.0)
    p_asym = bus_power(geom, ACT, optimal_aspect_power(geom, ACT))
    p_both = bus_power(geom2, act2, optimal_aspect_power(geom2, act2))
    out.append(
        {
            "name": "design_space/bus_invert_plus_asym",
            "us_per_call": 0.0,
            "derived": (
                f"a_v {ACT.a_v:.2f}->{act2.a_v:.3f}; bus power vs square: "
                f"asym-only -{(1-p_asym/p_square)*100:.1f}%, "
                f"BI+asym -{(1-p_both/p_square)*100:.1f}%"
            ),
        }
    )
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
