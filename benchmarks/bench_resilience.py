"""Chaos benchmark: the full network-profiling workload under fault injection.

Reuses the Table I + qwen15_4b (WS + OS) job set of
``bench_network_profile`` and runs it with every injector class armed:

  * ``bitflip``     — every on-disk store read is corrupted (the store was
                      pre-seeded by a clean pass), driving the quarantine +
                      recompute path for the whole workload;
  * ``backend``     — the first WS bucket's fused dispatch fails, driving
                      the per-job degradation ladder;
  * ``device_loss`` — the second WS bucket's shard loses its device (a
                      single-device host has no survivor, so the ladder
                      takes over);
  * ``hang``        — a separate mini-batch hangs past ``timeout_s``,
                      driving the dispatch-timeout path.

The module fails loudly unless (a) ``on_error="degrade"`` completes EVERY
job, (b) every recovered profile is bit-exact against the clean pass (and
against the numpy counts oracle: the whole workload in full mode, one job
per geometry in smoke), and (c) every fired injector maps to a
``failure_report`` record with the right typed cause — backend ->
backend-compile, hang -> timeout, device_loss -> device-loss, bitflip ->
cache-corruption.  Chaos must cost recovery work, never correctness.
"""

from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

from benchmarks.bench_network_profile import _counts, _jobs, _oracle_check
from repro.core.pipeline import ProfileJob, run_profile_batch
from repro.core.switching import (
    clear_profile_cache,
    configure_profile_store,
    profile_store,
)
from repro.runtime import faults

# fired injector kind -> failure_report taxonomy kind
KIND_MAP = {
    "backend": "backend-compile",
    "hang": "timeout",
    "device_loss": "device-loss",
    "bitflip": "cache-corruption",
}


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise RuntimeError(f"bench_resilience: {msg}")


def run(smoke: bool = False) -> list[dict]:
    jobs = _jobs(smoke)
    rows = []
    prev_store = profile_store()  # restored below (with its stats intact)
    with tempfile.TemporaryDirectory() as tmp:
        store = configure_profile_store(tmp)
        try:
            # Clean pass: ground truth + pre-seeded store + warm compiles.
            clear_profile_cache()
            baseline, _ = run_profile_batch(jobs, use_cache=True)
            n_entries = store.info()["entries"]
            _check(n_entries > 0, "clean pass persisted nothing")
            clear_profile_cache()  # force the next pass through the store

            # Chaos pass: store reads corrupted, two buckets' dispatch dead.
            specs = [
                faults.FaultSpec("bitflip", match="store-read"),
                faults.FaultSpec("backend", match="b0s0"),
                faults.FaultSpec("device_loss", match="b1s0"),
            ]
            t0 = time.perf_counter()
            with faults.injected(specs, seed=20260807) as inj:
                profiles, stats = run_profile_batch(
                    _jobs(smoke), use_cache=True, on_error="degrade"
                )
            chaos_s = time.perf_counter() - t0

            _check(
                all(p is not None for p in profiles),
                f"{sum(p is None for p in profiles)} jobs skipped under degrade",
            )
            for job, base, got in zip(jobs, baseline, profiles):
                _check(
                    _counts(base) == _counts(got),
                    f"recovered profile not bit-exact on {job.name} "
                    f"({job.dataflow}): {_counts(got)} vs {_counts(base)}",
                )
            # ground truth against the numpy counts oracle
            _oracle_check(
                jobs,
                profiles,
                [0, len(jobs) // 2, len(jobs) - 1] if smoke else range(len(jobs)),
            )

            rep = stats.failure_report
            fired = inj.fired_kinds()
            _check(
                fired == {"bitflip", "backend", "device_loss"},
                f"chaos pass fired {sorted(fired)}, expected all three specs",
            )
            for kind in fired:
                _check(
                    rep.counts().get(KIND_MAP[kind], 0) > 0,
                    f"no {KIND_MAP[kind]!r} record for fired {kind!r} faults",
                )
            n_flips = sum(1 for f in inj.fired if f.kind == "bitflip")
            _check(
                rep.actions().get("quarantined:recomputed", 0) == n_flips,
                f"{n_flips} bitflips but "
                f"{rep.actions().get('quarantined:recomputed', 0)} quarantines",
            )
            _check(
                stats.degraded > 0 and stats.skipped == 0,
                f"expected ladder recoveries, got degraded={stats.degraded} "
                f"skipped={stats.skipped}",
            )
            _check(
                stats.store_hits == 0,
                "corrupted store reads must never count as hits",
            )
            # the recomputes healed every quarantined key
            _check(
                store.info()["entries"] == n_entries,
                "recomputed profiles were not written back to the store",
            )
            rows.append(
                {
                    "name": "resilience/chaos_degrade"
                    + ("_smoke" if smoke else ""),
                    "us_per_call": round(chaos_s * 1e6 / len(jobs), 1),
                    "dataflow": "WS+OS",
                    "derived": (
                        f"jobs={len(jobs)} degraded={stats.degraded} "
                        f"quarantined={n_flips} "
                        f"report=[{rep.summary()}] bit_exact=True"
                    ),
                }
            )
        finally:
            configure_profile_store(prev_store)
            clear_profile_cache()

    # Timeout path: a hung dispatch must trip timeout_s, then recover
    # bit-exactly down the ladder (no survivor device to resubmit to).
    rng = np.random.default_rng(0)
    a = rng.integers(-500, 500, size=(40, 24))
    w = rng.integers(-500, 500, size=(24, 16))
    job = ProfileJob(rows=8, cols=8, b_h=16, b_v=37, a=a, w=w, name="hangjob")
    t0 = time.perf_counter()
    with faults.injected(
        [faults.FaultSpec("hang", match="bucket-exec")],
        hang_s=1.5 if smoke else 2.5,
    ) as inj:
        (p,), tstats = run_profile_batch(
            [job],
            use_cache=False,
            on_error="degrade",
            timeout_s=0.5 if smoke else 0.75,
        )
    hang_s = time.perf_counter() - t0
    _check(inj.fired_kinds() == {"hang"}, "hang fault did not fire")
    _check(p is not None, "hung job was not recovered")
    from repro.kernels.activity_profile.ref import profile_gemm_toggles_ref

    _check(
        _counts(p) == profile_gemm_toggles_ref(a, w, 8, 8, 16, 37),
        "timeout-recovered profile not bit-exact vs oracle",
    )
    _check(
        tstats.failure_report.counts().get("timeout", 0) > 0,
        "no timeout record for a hung dispatch",
    )
    rows.append(
        {
            "name": "resilience/timeout_ladder" + ("_smoke" if smoke else ""),
            "us_per_call": round(hang_s * 1e6, 1),
            "dataflow": "WS",
            "derived": (
                f"timeout_s={0.5 if smoke else 0.75} "
                f"report=[{tstats.failure_report.summary()}] bit_exact=True"
            ),
        }
    )
    return rows


if __name__ == "__main__":
    for r in run("--smoke" in sys.argv):
        print(r)
