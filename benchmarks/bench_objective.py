"""Fused fleet J/op objective: throughput floor and ranking flips.

Two checks, each a CSV/JSON row:

  * ``objective/engine`` — warm throughput of the fused J/op program
    (wire power + clock spine + calibrated static + partition-lowered
    utilization/spill/trunk pricing, coding axis included) in
    (design point x layout family) cells/s over the PR-8 fleet grid
    extended with the bus-invert axis.  Asserts >= 10^6 cells/s warm with
    jax (10^4 on the numpy fallback) — the committed perf floor; the CI
    ``perf-floor`` job fails on regression.  Runs fleet-scale even under
    ``--smoke``: tiny grids are dispatch-bound and can't witness the floor.
  * ``objective/winner_flips`` — cells (workload x design point) where the
    J/op-optimal layout family differs from the bus-power-optimal one.
    Asserts >= 1: utilization and spill/trunk traffic must flip at least
    one ranking, or the fused objective adds nothing over wire power —
    the paper's scale-in argument as a tracked number.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.design_space import DesignSpace
from repro.core.objective import evaluate_fleet_objective
from repro.core.workloads import RESNET50_TABLE1, conv_to_gemm
from repro.layout import pod_layouts
from repro.layout.power import _HAS_JAX

try:
    from benchmarks.bench_layout import THROUGHPUT_FLOOR, THROUGHPUT_FLOOR_NUMPY
except ModuleNotFoundError:  # invoked as a bare script: sibling module import
    from bench_layout import THROUGHPUT_FLOOR, THROUGHPUT_FLOOR_NUMPY

FLEET_FAMILIES = ("uniform", "serpentine2", "serpentine4") + pod_layouts(
    (1, 2, 3, 4, 8)
)


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(smoke: bool = False) -> list[dict]:
    out = []
    # The PR-8 fleet grid with the coding flag as one more free axis: the
    # fused program prices bus-invert points through the lowered activity
    # multipliers, so the axis must not cost a second program.
    big = DesignSpace(
        rows=(8, 16, 32, 64, 96, 128),
        cols=(8, 16, 32, 64, 128, 192, 256, 512),
        input_bits=(4, 8, 16),
        dataflows=("WS", "OS"),
        pe_area_um2=(400.0, 900.0, 1600.0, 2500.0),
        bus_invert=(False, True),
    )
    grid = big.expand()
    # Representative 3-GEMM fleet (largest-MAC ResNet-50 layers): matches the
    # layout bench's 3-workload axis so engine and objective rates compare.
    gemms = sorted(
        (conv_to_gemm(c) for c in RESNET50_TABLE1), key=lambda g: -g.macs
    )[:3]
    rng = np.random.default_rng(0)
    a_h = rng.uniform(0.1, 0.4, (len(gemms), grid.n_points))
    a_v = rng.uniform(0.2, 0.6, (len(gemms), grid.n_points))
    use_jit = _HAS_JAX
    floor = THROUGHPUT_FLOOR if use_jit else THROUGHPUT_FLOOR_NUMPY

    call = lambda: evaluate_fleet_objective(
        grid, a_h, a_v, gemms, layouts=FLEET_FAMILIES, use_jit=use_jit
    )
    ev = call()  # compile + keep the result for the flip row
    call()  # settle device caches before timing
    t_eval = min(_timed(call) for _ in range(5))
    n_cells = grid.n_points * len(FLEET_FAMILIES)
    rate = n_cells / t_eval
    assert rate >= floor, (
        f"fused objective {rate:,.0f} cells/s below the {floor:,.0f} floor"
    )
    out.append(
        {
            "name": "objective/engine",
            "us_per_call": t_eval * 1e6 / n_cells,
            "cells_per_s": rate,
            "layout": "+".join(FLEET_FAMILIES),
            "dataflow": "WS+OS",
            "derived": (
                f"jit={use_jit} {rate:,.0f} (point x layout) J/op cells/s warm "
                f"({grid.n_points} points incl. coding axis x "
                f"{len(FLEET_FAMILIES)} families x {len(gemms)} GEMMs in "
                f"{t_eval*1e3:.1f}ms; floor {floor:,.0f}/s)"
            ),
        }
    )

    # --- J/op winner vs bus-power winner -----------------------------------
    flipped = ev.best_layout != ev.best_layout_jpo
    flips = int(np.sum(flipped))
    total = int(flipped.size)
    assert flips >= 1, "J/op never disagrees with bus power — objective is inert"
    pj = int(np.flatnonzero(flipped)[0])  # name one flip cell
    out.append(
        {
            "name": "objective/winner_flips",
            "us_per_call": 0.0,
            "flips": flips,
            "layout": "+".join(FLEET_FAMILIES),
            "dataflow": "WS+OS",
            "derived": (
                f"{flips}/{total} design points rank a different family under "
                f"fleet J/op than under bus power; e.g. {grid.describe(pj)}: "
                f"{ev.layouts[int(ev.best_layout[pj])]} -> "
                f"{ev.layouts[int(ev.best_layout_jpo[pj])]}"
            ),
        }
    )
    return out


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
